//! End-to-end driver (the repository's full-system validation run).
//!
//! Trains the ~42k-parameter MNIST ODE classifier for several hundred
//! optimizer steps on the procedural digit corpus — unregularized and with
//! TayNODE R_3 — logging the loss curve, and evaluating NFE / accuracy with
//! the adaptive Rust solver throughout training (paper §5.1, Fig 3).
//! Results land in results/e2e_mnist_*.csv and are summarized on stdout.
//!
//! Run: `make artifacts && cargo run --release --example mnist_classification`

use taynode::experiments::common::{
    eval_opts, load_runtime, results_dir, train_mnist, MnistHarness,
};
use taynode::solvers::tableau;
use taynode::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let rt = load_runtime()?;
    let harness = MnistHarness::new(&rt, 640, 0)?;
    let tb = tableau::dopri5();
    let iters = std::env::var("E2E_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300usize);
    println!(
        "training MNIST ODE classifier: {} train / {} test examples, \
         batch {}, {iters} steps\n",
        harness.train.n, harness.test.n, harness.b
    );

    let mut table = Table::new(&["variant", "final_loss", "train_err", "test_err", "NFE", "secs"]);
    for (artifact, lam) in [("mnist_train_unreg_s8", 0.0f32), ("mnist_train_k3_s8", 0.03)] {
        let t0 = std::time::Instant::now();
        let (_tr, log) =
            train_mnist(&rt, &harness, artifact, iters, lam, 0, (iters / 10).max(1), &tb)?;
        let secs = t0.elapsed().as_secs_f64();
        let csv = results_dir().join(format!("e2e_mnist_{artifact}.csv"));
        log.to_csv(&csv)?;
        println!("[{artifact}] loss curve -> {csv:?}");
        for row in &log.rows {
            println!(
                "  step {:>4}  loss {:.4}  ce {:.4}  NFE {:>4}  \
                 train_err {:.3}  test_err {:.3}",
                row[0] as usize, row[1], row[2], row[4] as usize, row[5], row[6]
            );
        }
        table.row(vec![
            artifact.into(),
            format!("{:.4}", log.last("loss")),
            format!("{:.3}", log.last("train_err")),
            format!("{:.3}", log.last("test_err")),
            format!("{}", log.last("nfe") as usize),
            format!("{secs:.1}"),
        ]);
        println!();
    }
    table.print();
    let _ = eval_opts();
    Ok(())
}
