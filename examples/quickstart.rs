//! Quickstart: the whole TayNODE loop in ~60 lines.
//!
//! Loads the AOT-compiled toy model, trains it twice on the map
//! z(1) = z(0) + z(0)^3 — once unregularized, once with the R_3 speed
//! regularizer — then measures how many function evaluations an adaptive
//! dopri5 solver needs on each set of learned dynamics (paper Fig 1).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use taynode::coordinator::{toy_eval, BatchInputs, Trainer};
use taynode::experiments::common::{load_runtime, toy_data};
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::tableau;

fn main() -> anyhow::Result<()> {
    let rt = load_runtime()?; // PJRT CPU client + artifact manifest
    let x = toy_data(128, 0); // batch of initial states

    let mut results = vec![];
    for (artifact, lam) in [("toy_train_unreg_s16", 0.0f32), ("toy_train_k3_s16", 0.3)] {
        // Train: each step executes one fused XLA train step
        // (RK4 solve + MSE + lambda * R_3 via Taylor-mode jet + SGD).
        let mut trainer = Trainer::new(&rt, artifact, 0)?;
        let batch = BatchInputs::default().f("x", x.clone());
        let mut loss = f32::NAN;
        for step in 0..200 {
            let m = trainer.step(&batch, lam, 0.05)?;
            loss = m.loss();
            if step % 50 == 0 {
                println!("[{artifact}] step {step:>4}  loss {loss:.5}");
            }
        }

        // Evaluate: Rust adaptive dopri5 over the exported dynamics,
        // counting every function evaluation (NFE).
        let ev = toy_eval(&rt, &trainer.store, &x, &tableau::dopri5(), &AdaptiveOpts::default())?;
        println!(
            "[{artifact}] final loss {loss:.5}  eval mse {:.5}  NFE {}\n",
            ev.mse, ev.nfe
        );
        results.push((artifact, ev));
    }

    let (unreg, reg) = (&results[0].1, &results[1].1);
    println!(
        "speed regularization: NFE {} -> {} ({:.1}x fewer evaluations), \
         mse {:.5} -> {:.5}",
        unreg.nfe,
        reg.nfe,
        unreg.nfe as f64 / reg.nfe as f64,
        unreg.mse,
        reg.mse
    );
    Ok(())
}
