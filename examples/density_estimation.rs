//! Native density estimation: train a concat-squash CNF on the 2-D
//! two-Gaussians toy density with the exact NLL objective (log-det
//! discrete adjoint), then compare λ = 0 vs λ = 0.1 under the adaptive
//! solver — no artifacts, no Python, no `pjrt`.  (The artifact-backed
//! FFJORD tables live in `benches/table2_ffjord.rs` /
//! `benches/table4_miniboone.rs`.)
//!
//! Run: `cargo run --release --example density_estimation`

use taynode::autodiff::div::{batch_divergence, Divergence};
use taynode::coordinator::train_native::NativeCnfTrainer;
use taynode::data::toy_density;
use taynode::nn::Cnf;
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::tableau;
use taynode::util::bench::Table;

fn main() {
    let x = toy_density::sample("two_gaussians", 32, 11);
    let x_eval = toy_density::sample("two_gaussians", 32, 12);
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts { rtol: 1e-5, atol: 1e-7, ..Default::default() };

    // Divergence engine sanity on the untrained flow: the exact trace vs a
    // 64-probe fixed-seed Hutchinson estimate at one point.
    let cnf = Cnf::new(2, &[16], 42);
    let z = [0.4f64, -0.7];
    let (_, exact) = batch_divergence(&cnf, &[0], &[0.0], &z, &Divergence::Exact);
    let (_, est) = batch_divergence(
        &cnf,
        &[0],
        &[0.0],
        &z,
        &Divergence::Hutchinson { probes: 64, seed: 9 },
    );
    println!(
        "divergence at (0.4, -0.7): exact {:.5}, hutchinson-64 {:.5}\n",
        exact[0], est[0]
    );

    let mut table = Table::new(&["lambda", "train_nll", "eval_nll", "R_K", "mean NFE"]);
    for lam in [0.0f32, 0.1] {
        let cnf = Cnf::new(2, &[16], 42);
        let mut tr = NativeCnfTrainer::new(cnf, 2, lam, 8, tableau::rk4(), 0.02);
        let mut last = f32::NAN;
        for step in 0..60 {
            let m = tr.step_nll(&x);
            last = m.task;
            if step % 20 == 0 {
                println!("λ={lam} step {step:>3}: nll {:.4}  R_K {:.3e}", m.task, m.reg);
            }
        }
        let ev = tr.eval_nll(&x_eval, &tb, &opts);
        let nfe = ev.stats.iter().map(|s| s.nfe as f64).sum::<f64>() / ev.stats.len() as f64;
        table.row(vec![
            format!("{lam}"),
            format!("{last:.4}"),
            format!("{:.4}", ev.nll),
            format!("{:.3e}", ev.mean_r_k),
            format!("{nfe:.1}"),
        ]);
    }
    println!();
    table.print();
}
