//! FFJORD density estimation on synthetic tabular data (paper §5.3 /
//! Table 4): unregularized vs RNODE (Finlay et al.) vs TayNODE R_2,
//! evaluated with adaptive solvers (NFE + nats + integrated R_2/B/K).
//!
//! Run: `make artifacts && cargo run --release --example density_estimation`

use taynode::coordinator::evaluator::cnf_eval;
use taynode::experiments::common::{eval_opts, load_runtime, train_cnf, CnfHarness};
use taynode::solvers::tableau;
use taynode::util::bench::Table;
use taynode::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let rt = load_runtime()?;
    let h = CnfHarness::new(&rt, "cnf_tab", 768, 37)?;
    println!("FFJORD on synthetic tabular data: d={}, batch {}\n", h.d, h.b);
    let tb = tableau::dopri5();
    let opts = eval_opts();
    let iters = 150;

    let mut table = Table::new(&["variant", "lambda", "secs", "test_nll", "NFE", "R_2", "B", "K"]);
    for (artifact, lam) in [
        ("cnf_tab_train_unreg_s8", 0.0f32),
        ("cnf_tab_train_rnode_s8", 0.05),
        ("cnf_tab_train_k2_s8", 0.05),
    ] {
        let (tr, secs, _) = train_cnf(&rt, &h, artifact, iters, lam, 2)?;
        let mut rng = Pcg::new(61);
        let probe = rng.rademacher(h.b * h.d);
        let ev = cnf_eval(&rt, "cnf_tab", &tr.store, &h.test, &probe, &tb, &opts)?;
        println!("[{artifact}] nll {:.3}  NFE {}  R2 {:.2}  B {:.3}  K {:.3}",
                 ev.nll, ev.nfe, ev.r2, ev.jacobian, ev.kinetic);
        table.row(vec![
            artifact.into(),
            format!("{lam}"),
            format!("{secs:.1}"),
            format!("{:.3}", ev.nll),
            format!("{}", ev.nfe),
            format!("{:.2}", ev.r2),
            format!("{:.3}", ev.jacobian),
            format!("{:.3}", ev.kinetic),
        ]);
    }
    println!();
    table.print();
    Ok(())
}
