//! Latent-ODE time-series modelling on synthetic ICU-style data
//! (paper §5.2 / Fig 4): train the VAE with and without R_2 speed
//! regularization, then measure trajectory NFE with the adaptive solver.
//!
//! Run: `make artifacts && cargo run --release --example latent_timeseries`

use taynode::coordinator::evaluator::latent_eval;
use taynode::experiments::common::{eval_opts, load_runtime, train_latent, LatentHarness};
use taynode::solvers::tableau;
use taynode::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let rt = load_runtime()?;
    let h = LatentHarness::new(&rt, 23)?;
    println!(
        "latent ODE on synthetic clinical series: batch {}, {} grid points, \
         {} features (mask rate {:.2})\n",
        h.b,
        h.t,
        h.f,
        h.mask.iter().sum::<f32>() / h.mask.len() as f32
    );
    let tb = tableau::dopri5();
    let opts = eval_opts();
    let iters = 200;

    let mut table = Table::new(&["variant", "lambda", "train_loss", "test_nll", "test_mse", "NFE"]);
    for (artifact, lam) in [("latent_train_unreg", 0.0f32), ("latent_train_k2", 0.1)] {
        let (tr, loss) = train_latent(&rt, &h, artifact, iters, lam, 0)?;
        let ev = latent_eval(&rt, &tr.store, &h.x_test, &h.mask_test, h.t, &tb, &opts)?;
        println!(
            "[{artifact}] loss {loss:.4}  test nll {:.4}  mse {:.4}  NFE {}",
            ev.nll, ev.mse, ev.nfe
        );
        table.row(vec![
            artifact.into(),
            format!("{lam}"),
            format!("{loss:.4}"),
            format!("{:.4}", ev.nll),
            format!("{:.4}", ev.mse),
            format!("{}", ev.nfe),
        ]);
    }
    println!();
    table.print();
    Ok(())
}
