//! Fig 2 standalone: solver order vs polynomial-trajectory order, entirely
//! in Rust (no artifacts needed).  Shows the mechanism the whole paper is
//! built on: an adaptive order-m Runge-Kutta solver is cheap exactly when
//! the trajectory's total derivatives of order > m vanish.
//!
//! Run: `cargo run --release --example solver_orders`

use taynode::experiments::{orders, Scale};
use taynode::taylor::{ode_jet, ode_jet_batch, Series, SeriesFn, SeriesVec};

fn main() -> anyhow::Result<()> {
    // First, the Taylor-mode view: derivative coefficients of a cubic
    // trajectory vanish above order 3 (computed with the in-crate jet).
    let x = ode_jet(|_z, t: &Series| t.mul(t).scale(3.0), 0.0, 0.5, 6);
    println!("jet of dz/dt = 3t^2 at t=0.5 (cubic trajectory):");
    for (k, v) in x.iter().enumerate() {
        println!("  d^{} z/dt^{} = {v:.6}", k + 1, k + 1);
    }

    // The same jet for a whole batch at once: three expansion points of the
    // same cubic, one series sweep (SeriesVec is [B, n] structure-of-arrays,
    // per-row bit-identical to the scalar jet above).
    let mut f = SeriesFn::new(1, |_ids: &[usize], _z: &SeriesVec, t: &SeriesVec| {
        t.mul(t).scale(3.0)
    });
    let t0 = [0.5f64, 0.0, 1.0];
    let jets = ode_jet_batch(&mut f, &[0, 1, 2], &[0.0, 0.0, 0.0], &t0, 6);
    println!("\nbatched jets at t0 = {t0:?} (rows: d^k z/dt^k per point):");
    for (k, xk) in jets.iter().enumerate() {
        println!("  k={}: {:?}", k + 1, xk);
    }

    println!("\nNFE of adaptive solvers on degree-K polynomial trajectories:");
    orders::fig2(Scale::full())?.print();
    println!("\nR_K on the same trajectories (batched Taylor-jet quadrature):");
    orders::fig2_rk(Scale::full())?.print();
    println!(
        "\n(lower-triangle structure: an order-m pair is cheap, and R_K \
         vanishes, exactly where the trajectory's high-order derivatives \
         are zero)"
    );
    Ok(())
}
