//! Fig 2 standalone: solver order vs polynomial-trajectory order, entirely
//! in Rust (no artifacts needed).  Shows the mechanism the whole paper is
//! built on: an adaptive order-m Runge-Kutta solver is cheap exactly when
//! the trajectory's total derivatives of order > m vanish.
//!
//! Run: `cargo run --release --example solver_orders`

use taynode::experiments::{orders, Scale};
use taynode::taylor::{ode_jet, Series};

fn main() -> anyhow::Result<()> {
    // First, the Taylor-mode view: derivative coefficients of a cubic
    // trajectory vanish above order 3 (computed with the in-crate jet).
    let x = ode_jet(|_z, t: &Series| t.mul(t).scale(3.0), 0.0, 0.5, 6);
    println!("jet of dz/dt = 3t^2 at t=0.5 (cubic trajectory):");
    for (k, v) in x.iter().enumerate() {
        println!("  d^{} z/dt^{} = {v:.6}", k + 1, k + 1);
    }
    println!("\nNFE of adaptive solvers on degree-K polynomial trajectories:");
    orders::fig2(Scale::full())?.print();
    println!("\n(lower-triangle structure: an order-m pair is cheap for K <= m)");
    Ok(())
}
