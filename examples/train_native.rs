//! Native training end to end, no artifacts needed: train the toy model
//! (x ↦ x + x³, the paper's Fig 1 task) with the discrete adjoint through
//! the batched fixed-grid solver, unregularized and with `R_2`, then
//! compare what the *adaptive* solver pays on the learned dynamics.
//!
//! This is the paper's headline mechanism in one binary: the λ-regularized
//! run ends with smaller `R_K` and fewer NFE for nearly the same task MSE.
//!
//! Run: `cargo run --release --example train_native`
//! (CI runs it via `make train-demo` on the same tiny budget.)

use taynode::coordinator::train_native::NativeTrainer;
use taynode::nn::Mlp;
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::tableau;
use taynode::util::bench::Table;
use taynode::util::rng::Pcg;

fn main() {
    let iters = 120usize;
    let b = 32usize;
    let mut rng = Pcg::new(11);
    let x0: Vec<f32> = (0..b).map(|_| rng.range(-1.2, 1.2)).collect();
    let targets: Vec<f32> = x0.iter().map(|x| x + x * x * x).collect();
    let x_eval: Vec<f32> = (0..b).map(|_| rng.range(-1.2, 1.2)).collect();
    let t_eval: Vec<f32> = x_eval.iter().map(|x| x + x * x * x).collect();
    let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
    let dopri = tableau::dopri5();

    let mut table = Table::new(&["lambda", "train_loss", "eval_mse", "R_2", "mean NFE"]);
    for lam in [0.0f32, 1.0] {
        // Same seed/init for both runs: λ is the only difference.
        let mlp = Mlp::new(1, &[16, 16], true, 42);
        let mut tr = NativeTrainer::new(mlp, None, 2, lam, 8, tableau::rk4(), 0.02);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for it in 0..iters {
            let m = tr.step_mse(&x0, &targets);
            if it == 0 {
                first = m.loss;
            }
            last = m.loss;
            if it % 30 == 0 {
                println!(
                    "λ={lam}  step {it:>3}: loss {:.5}  task {:.5}  R_2 {:.3e}",
                    m.loss, m.task, m.reg
                );
            }
        }
        // CI honesty gate (`make train-demo`): the demo must actually have
        // trained — a NaN/diverged run must fail the step, not print rows.
        assert!(
            first.is_finite() && last.is_finite(),
            "non-finite loss at λ={lam}: {first} -> {last}"
        );
        assert!(
            last < first,
            "training at λ={lam} did not reduce the loss: {first} -> {last}"
        );
        let ev = tr.eval_rk(&x_eval, &dopri, &opts);
        assert!(
            ev.y.iter().all(|v| v.is_finite()) && ev.mean_r_k.is_finite(),
            "non-finite adaptive evaluation at λ={lam}"
        );
        assert!(ev.stats.iter().all(|s| s.nfe > 0));
        let mse = t_eval
            .iter()
            .zip(&ev.y)
            .map(|(t, y)| ((*y - *t) as f64).powi(2))
            .sum::<f64>()
            / b as f64;
        let nfe = ev.stats.iter().map(|s| s.nfe).sum::<usize>() as f64 / b as f64;
        table.row(vec![
            format!("{lam}"),
            format!("{last:.5}"),
            format!("{mse:.5}"),
            format!("{:.3e}", ev.mean_r_k),
            format!("{nfe:.1}"),
        ]);
    }
    println!("\nadaptive-solver evaluation of the trained dynamics (dopri5, rtol 1e-6):");
    table.print();
    println!(
        "\n(the λ > 0 row trades a little task MSE for much smaller R_K and \
         fewer NFE — the paper's accuracy-vs-solve-cost dial, natively)"
    );
}
