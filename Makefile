# Build-time artifact export: lower the JAX models to HLO text + params for
# the Rust PJRT runtime (see python/compile/aot.py and rust/src/runtime/).
# Run once before any artifact-backed example/experiment; the Rust side
# never invokes Python.  Requires the python/ dependencies (JAX).
.PHONY: artifacts
artifacts:
	cd python && python compile/aot.py --out ../artifacts

# Native training demo (no artifacts, no pjrt): trains the toy model with
# the discrete adjoint at λ = 0 and λ = 1 and prints the adaptive-NFE
# comparison.  CI runs this so the training path can't silently rot.
.PHONY: train-demo
train-demo:
	cargo run --release --example train_native

# Machine-readable perf trajectory: run the parallel-engine benches and
# accumulate ops/sec, speedup vs serial, and the worker count into
# BENCH_parallel.json, and the CNF stack (divergence engine, log-det
# solves, NLL training) into BENCH_cnf.json (each bench merge-writes its
# own section).  Honor TAYNODE_THREADS if set; equality with the serial
# path is asserted inside the benches before anything is timed.
.PHONY: bench-json
bench-json:
	rm -f BENCH_parallel.json BENCH_cnf.json
	cargo bench --bench perf_batch -- --json BENCH_parallel.json
	cargo bench --bench perf_train_native -- --json BENCH_parallel.json
	cargo bench --bench perf_cnf -- --json BENCH_cnf.json
