# Provenance for the perf trajectory: benches stamp the producing commit
# into every BENCH_*.json section they merge-write (see
# `util::bench::merge_bench_json`), reading it through the sanctioned
# env door `util::cli::git_commit`.  Resolved here once so a dirty PATH
# or a non-git checkout degrades to "unknown" instead of failing.
GIT_COMMIT ?= $(shell git rev-parse --short HEAD 2>/dev/null || echo unknown)
export GIT_COMMIT

# Build-time artifact export: lower the JAX models to HLO text + params for
# the Rust PJRT runtime (see python/compile/aot.py and rust/src/runtime/).
# Run once before any artifact-backed example/experiment; the Rust side
# never invokes Python.  Requires the python/ dependencies (JAX).
.PHONY: artifacts
artifacts:
	cd python && python compile/aot.py --out ../artifacts

# Native training demo (no artifacts, no pjrt): trains the toy model with
# the discrete adjoint at λ = 0 and λ = 1 and prints the adaptive-NFE
# comparison.  CI runs this so the training path can't silently rot.
.PHONY: train-demo
train-demo:
	cargo run --release --example train_native

# Machine-readable perf trajectory: run the parallel-engine benches and
# accumulate ops/sec, speedup vs serial, and the worker count into
# BENCH_parallel.json, and the CNF stack (divergence engine, log-det
# solves, NLL training) into BENCH_cnf.json, and the continuous-batching
# serving engine (p50/p99 latency + occupancy vs the drain baseline at
# B in {64, 256, 1024}) into BENCH_serving.json (each bench merge-writes
# its own section).  The blocked-kernel layer (Cauchy / fused MLP / stage
# axpy vs the retained naive references) lands in BENCH_parallel.json
# under "perf_kernels".  Honor TAYNODE_THREADS if set; equality with the
# serial path is asserted inside the benches before anything is timed.
#
# Each file accumulates in a .tmp scratch path and moves into place only
# after every contributing bench succeeded, so a mid-run failure (or ^C)
# leaves the committed baselines untouched.
.PHONY: bench-json
bench-json:
	rm -f BENCH_parallel.json.tmp BENCH_cnf.json.tmp BENCH_serving.json.tmp
	cargo bench --bench perf_batch -- --json BENCH_parallel.json.tmp
	cargo bench --bench perf_train_native -- --json BENCH_parallel.json.tmp
	cargo bench --bench perf_obs -- --json BENCH_parallel.json.tmp
	cargo bench --bench perf_kernels -- --json BENCH_parallel.json.tmp
	cargo bench --bench perf_cnf -- --json BENCH_cnf.json.tmp
	cargo bench --bench perf_serving -- --json BENCH_serving.json.tmp
	mv BENCH_parallel.json.tmp BENCH_parallel.json
	mv BENCH_cnf.json.tmp BENCH_cnf.json
	mv BENCH_serving.json.tmp BENCH_serving.json

# Perf trajectory delta: re-run the bench suite into .new scratch files and
# print per-metric percent deltas against the committed baselines with
# `repro perfdiff`.  The committed BENCH_*.json start life as seeded-empty
# `_meta` stubs (never hand-written numbers); run `make bench-json` once on
# a quiet machine to seed them for real, then `make perf` on later changes
# to see the trajectory.  The .new files are left behind for inspection.
#
# Set PERF_FAIL=<pct> to turn the diff into a gate: any direction-known
# metric (throughput-like suffixes are higher-better, latency-like are
# lower-better; `repro perfdiff` documents the heuristic) that moves the
# wrong way by more than <pct> percent fails the target.  CI runs this
# advisory (no PERF_FAIL, continue-on-error) so shared-runner noise never
# blocks a merge.
PERF_FAIL ?=
PERF_FLAGS = $(if $(PERF_FAIL),--fail-on-regression $(PERF_FAIL),)
.PHONY: perf
perf:
	rm -f BENCH_parallel.json.new BENCH_cnf.json.new BENCH_serving.json.new
	cargo bench --bench perf_batch -- --json BENCH_parallel.json.new
	cargo bench --bench perf_train_native -- --json BENCH_parallel.json.new
	cargo bench --bench perf_obs -- --json BENCH_parallel.json.new
	cargo bench --bench perf_kernels -- --json BENCH_parallel.json.new
	cargo bench --bench perf_cnf -- --json BENCH_cnf.json.new
	cargo bench --bench perf_serving -- --json BENCH_serving.json.new
	cargo run --release --bin repro -- perfdiff BENCH_parallel.json BENCH_parallel.json.new $(PERF_FLAGS)
	cargo run --release --bin repro -- perfdiff BENCH_cnf.json BENCH_cnf.json.new $(PERF_FLAGS)
	cargo run --release --bin repro -- perfdiff BENCH_serving.json BENCH_serving.json.new $(PERF_FLAGS)

# Determinism lint: taylint walks rust/src, rust/tests, benches/, and
# examples/ and enforces the invariant catalog (D1-D7; `taylint --rules`
# prints it).  Exits nonzero on any diagnostic; CI runs this blocking.
.PHONY: lint
lint:
	cargo run --release --bin taylint
