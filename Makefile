# Build-time artifact export: lower the JAX models to HLO text + params for
# the Rust PJRT runtime (see python/compile/aot.py and rust/src/runtime/).
# Run once before any artifact-backed example/experiment; the Rust side
# never invokes Python.  Requires the python/ dependencies (JAX).
.PHONY: artifacts
artifacts:
	cd python && python compile/aot.py --out ../artifacts
