//! §Perf: batched multi-trajectory engine vs the per-example evaluator loop.
//!
//! Scenario: serve per-example adaptive solves of the paper's toy dynamics
//! (Fig 1 / Fig 8b shape: B independent 1-D trajectories, per-example NFE).
//! The dynamics model is a small MLP behind a *per-invocation staging cost*,
//! shaped like a batch-1 `XlaDynamics` launch: every evaluation stages the
//! bound parameter block into the launch buffer (PJRT argument preparation —
//! see the §Perf notes in runtime/client.rs), then runs the math per row.
//!
//! * per-example loop: one full adaptive solve per trajectory => one launch
//!   per trajectory per stage evaluation.
//! * batched engine:  ONE launch per stage evaluation for the whole active
//!   set; per-trajectory step control + compaction keep the NFE identical
//!   per example (asserted below, bit-for-bit).
//!
//! A pure-closure variant (no staging cost) is also reported so the
//! driver-only amortization is visible separately and honestly.

use taynode::solvers::adaptive::{solve_adaptive_mut, AdaptiveOpts};
use taynode::solvers::batch::{solve_adaptive_batch_mut, BatchDynamics};
use taynode::solvers::{tableau, Dynamics};
use taynode::util::bench::{fmt_secs, report, time_fn};
use taynode::util::rng::Pcg;

const B: usize = 64;
const HIDDEN: usize = 16;
/// Parameter block staged per launch (floats).  64 KiB — modest next to the
/// ~42k-parameter mnist_dynamics_b1 artifact this models.
const PARAM_BLOCK: usize = 16_384;

/// Toy dynamics z' = w2 · tanh(w1 z + b1 + 0.1 t) behind a per-launch
/// staging cost.  Implements both the scalar and the batched traits so the
/// two drivers integrate the *identical* model.
struct ServingDynamics {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    params: Vec<f32>,
    staging: Vec<f32>,
    stage_cost: bool,
    launches: usize,
}

impl ServingDynamics {
    fn new(seed: u64, stage_cost: bool) -> ServingDynamics {
        let mut rng = Pcg::new(seed);
        ServingDynamics {
            w1: (0..HIDDEN).map(|_| rng.range(-1.5, 1.5)).collect(),
            b1: (0..HIDDEN).map(|_| rng.range(-0.5, 0.5)).collect(),
            w2: (0..HIDDEN).map(|_| rng.range(-0.7, 0.7)).collect(),
            params: (0..PARAM_BLOCK).map(|_| rng.range(-1.0, 1.0)).collect(),
            staging: vec![0.0; PARAM_BLOCK],
            stage_cost,
            launches: 0,
        }
    }

    /// Fixed per-invocation cost: stage the bound parameters for this
    /// launch, independent of how many rows ride along.
    #[inline]
    fn launch(&mut self) {
        self.launches += 1;
        if self.stage_cost {
            self.staging.copy_from_slice(&self.params);
            std::hint::black_box(&self.staging);
        }
    }

    #[inline]
    fn f(&self, t: f32, z: f32) -> f32 {
        let mut acc = 0.0f32;
        for j in 0..HIDDEN {
            acc += self.w2[j] * (self.w1[j] * z + self.b1[j] + 0.1 * t).tanh();
        }
        acc
    }
}

impl Dynamics for ServingDynamics {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]) {
        self.launch();
        for (d, z) in dy.iter_mut().zip(y) {
            *d = self.f(t, *z);
        }
    }
}

impl BatchDynamics for ServingDynamics {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        self.launch();
        for (r, tr) in t.iter().enumerate() {
            dy[r] = self.f(*tr, y[r]);
        }
    }
}

fn main() {
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts::default();
    let mut rng = Pcg::new(7);
    let x: Vec<f32> = (0..B).map(|_| rng.range(-1.2, 1.2)).collect();

    // -- correctness first: identical per-example results either way -------
    let mut d_loop = ServingDynamics::new(1, true);
    let mut d_batch = ServingDynamics::new(1, true);
    let mut loop_y = Vec::with_capacity(B);
    let mut loop_nfe = Vec::with_capacity(B);
    for i in 0..B {
        let res = solve_adaptive_mut(&mut d_loop, 0.0, 1.0, &x[i..i + 1], &tb, &opts);
        loop_y.push(res.y[0]);
        loop_nfe.push(res.stats.nfe);
    }
    let bres = solve_adaptive_batch_mut(&mut d_batch, 0.0, 1.0, &x, &tb, &opts);
    assert_eq!(loop_nfe, bres.nfes(), "per-example NFE must be identical");
    for i in 0..B {
        assert_eq!(
            loop_y[i].to_bits(),
            bres.y[i].to_bits(),
            "example {i}: batched state must be bit-identical"
        );
    }
    let total_nfe: usize = loop_nfe.iter().sum();
    let min = loop_nfe.iter().min().unwrap();
    let max = loop_nfe.iter().max().unwrap();
    println!(
        "B={B} toy trajectories, dopri5: total NFE {total_nfe}, \
         per-example NFE {min}..{max}"
    );
    println!(
        "launches: per-example loop {}, batched engine {} ({:.1}x fewer)\n",
        d_loop.launches,
        d_batch.launches,
        d_loop.launches as f64 / d_batch.launches.max(1) as f64
    );

    // -- throughput: serving-shaped dynamics (per-launch staging cost) -----
    let mut d1 = ServingDynamics::new(1, true);
    let s_loop = time_fn(3, 20, || {
        for i in 0..B {
            let res = solve_adaptive_mut(&mut d1, 0.0, 1.0, &x[i..i + 1], &tb, &opts);
            std::hint::black_box(res.stats.nfe);
        }
    });
    report("per-example loop (staged launches, B=64)", &s_loop);

    let mut d2 = ServingDynamics::new(1, true);
    let s_batch = time_fn(3, 20, || {
        let res = solve_adaptive_batch_mut(&mut d2, 0.0, 1.0, &x, &tb, &opts);
        std::hint::black_box(res.stats.len());
    });
    report("batched engine     (staged launches, B=64)", &s_batch);

    let speedup = s_loop.mean / s_batch.mean;
    println!(
        "\nbatched speedup over per-example loop: {speedup:.2}x \
         ({} -> {})",
        fmt_secs(s_loop.mean),
        fmt_secs(s_batch.mean)
    );

    // -- driver-only amortization (pure closures, no staging cost) ---------
    let mut c1 = ServingDynamics::new(1, false);
    let s_loop_c = time_fn(3, 20, || {
        for i in 0..B {
            let res = solve_adaptive_mut(&mut c1, 0.0, 1.0, &x[i..i + 1], &tb, &opts);
            std::hint::black_box(res.stats.nfe);
        }
    });
    report("per-example loop (pure closure, B=64)", &s_loop_c);
    let mut c2 = ServingDynamics::new(1, false);
    let s_batch_c = time_fn(3, 20, || {
        let res = solve_adaptive_batch_mut(&mut c2, 0.0, 1.0, &x, &tb, &opts);
        std::hint::black_box(res.stats.len());
    });
    report("batched engine     (pure closure, B=64)", &s_batch_c);
    println!(
        "driver-only amortization: {:.2}x",
        s_loop_c.mean / s_batch_c.mean
    );

    assert!(
        speedup >= 4.0,
        "acceptance: batched engine must be >= 4x over the per-example loop \
         at B=64 on serving-shaped toy dynamics (got {speedup:.2}x)"
    );
    println!("\nacceptance (>= 4x at B=64): PASS");
}
