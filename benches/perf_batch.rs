//! §Perf: batched multi-trajectory engine vs the per-example evaluator loop.
//!
//! Scenario: serve per-example adaptive solves of the paper's toy dynamics
//! (Fig 1 / Fig 8b shape: B independent 1-D trajectories, per-example NFE).
//! The dynamics model is a small MLP behind a *per-invocation staging cost*,
//! shaped like a batch-1 `XlaDynamics` launch: every evaluation stages the
//! bound parameter block into the launch buffer (PJRT argument preparation —
//! see the §Perf notes in runtime/client.rs), then runs the math per row.
//!
//! * per-example loop: one full adaptive solve per trajectory => one launch
//!   per trajectory per stage evaluation.
//! * batched engine:  ONE launch per stage evaluation for the whole active
//!   set; per-trajectory step control + compaction keep the NFE identical
//!   per example (asserted below, bit-for-bit).
//!
//! A pure-closure variant (no staging cost) is also reported so the
//! driver-only amortization is visible separately and honestly.
//!
//! A second section benchmarks the batched Taylor-jet `R_K` path
//! (`RegularizedBatchDynamics` + `taylor::ode_jet_batch`) against the
//! per-row scalar-jet loop it replaces — same staging-cost model, per-row
//! results asserted bit-identical before anything is timed.
//!
//! A third section benchmarks the worker-pool sharded engine
//! (`solve_adaptive_batch_pooled`) against the serial batched driver at
//! B = 256 on compute-bound native dynamics (the pooled path's target
//! shape) — results asserted bit-identical per trajectory first, speedup
//! gated ≥ 2x when ≥ 4 workers are available.  `--json <path>` appends the
//! machine-readable numbers (see `make bench-json`).

use taynode::coordinator::batch_rk_eval;
use taynode::solvers::adaptive::{solve_adaptive, solve_adaptive_mut, AdaptiveOpts, SolveStats};
use taynode::solvers::batch::{solve_adaptive_batch_mut, solve_adaptive_batch_pooled, BatchDynamics};
use taynode::solvers::{tableau, Dynamics};
use taynode::taylor::{ode_jet, ode_jet_batch, BatchSeriesDynamics, Series, SeriesVec};
use taynode::util::bench::{fmt_secs, json_path_arg, merge_bench_json, report, time_fn};
use taynode::util::json::Json;
use taynode::util::pool::Pool;
use taynode::util::rng::Pcg;

const B: usize = 64;
const HIDDEN: usize = 16;
/// Parameter block staged per launch (floats).  64 KiB — modest next to the
/// ~42k-parameter mnist_dynamics_b1 artifact this models.
const PARAM_BLOCK: usize = 16_384;

/// Toy dynamics z' = w2 · tanh(w1 z + b1 + 0.1 t) behind a per-launch
/// staging cost.  Implements both the scalar and the batched traits so the
/// two drivers integrate the *identical* model.
struct ServingDynamics {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    params: Vec<f32>,
    staging: Vec<f32>,
    stage_cost: bool,
    launches: usize,
}

impl ServingDynamics {
    fn new(seed: u64, stage_cost: bool) -> ServingDynamics {
        let mut rng = Pcg::new(seed);
        ServingDynamics {
            w1: (0..HIDDEN).map(|_| rng.range(-1.5, 1.5)).collect(),
            b1: (0..HIDDEN).map(|_| rng.range(-0.5, 0.5)).collect(),
            w2: (0..HIDDEN).map(|_| rng.range(-0.7, 0.7)).collect(),
            params: (0..PARAM_BLOCK).map(|_| rng.range(-1.0, 1.0)).collect(),
            staging: vec![0.0; PARAM_BLOCK],
            stage_cost,
            launches: 0,
        }
    }

    /// Fixed per-invocation cost: stage the bound parameters for this
    /// launch, independent of how many rows ride along.
    #[inline]
    fn launch(&mut self) {
        self.launches += 1;
        if self.stage_cost {
            self.staging.copy_from_slice(&self.params);
            std::hint::black_box(&self.staging);
        }
    }

    #[inline]
    fn f(&self, t: f32, z: f32) -> f32 {
        let mut acc = 0.0f32;
        for j in 0..HIDDEN {
            acc += self.w2[j] * (self.w1[j] * z + self.b1[j] + 0.1 * t).tanh();
        }
        acc
    }
}

impl Dynamics for ServingDynamics {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]) {
        self.launch();
        for (d, z) in dy.iter_mut().zip(y) {
            *d = self.f(t, *z);
        }
    }
}

impl BatchDynamics for ServingDynamics {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        self.launch();
        for (r, tr) in t.iter().enumerate() {
            dy[r] = self.f(*tr, y[r]);
        }
    }
}

/// Batch size of the sharded-engine section (the acceptance shape).
const POOL_B: usize = 256;
/// Hidden width of the compute-bound pooled dynamics.
const POOL_HIDDEN: usize = 64;

/// Compute-bound native dynamics for the sharded-engine benchmark: a wider
/// per-row MLP with NO per-launch dispatch cost — the pooled path's target
/// shape (in-process models whose cost is arithmetic, so splitting the
/// batch across workers splits real work; launch-shaped dynamics should
/// stay on the serial driver, which amortizes dispatch instead).
#[derive(Clone)]
struct ComputeDynamics {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
}

impl ComputeDynamics {
    fn new(seed: u64) -> ComputeDynamics {
        let mut rng = Pcg::new(seed);
        ComputeDynamics {
            w1: (0..POOL_HIDDEN).map(|_| rng.range(-1.5, 1.5)).collect(),
            b1: (0..POOL_HIDDEN).map(|_| rng.range(-0.5, 0.5)).collect(),
            w2: (0..POOL_HIDDEN).map(|_| rng.range(-0.7, 0.7)).collect(),
        }
    }
}

impl BatchDynamics for ComputeDynamics {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        for (r, tr) in t.iter().enumerate() {
            let mut acc = 0.0f32;
            for j in 0..POOL_HIDDEN {
                acc += self.w2[j] * (self.w1[j] * y[r] + self.b1[j] + 0.1 * tr).tanh();
            }
            dy[r] = acc;
        }
    }
}

/// Taylor-jet regularization order benchmarked below (the paper's K).
const JET_ORDER: usize = 3;
/// Parameter block staged per *series* launch.  Smaller than the f32 path's
/// block: one R_K solve spends K launches per NFE, and the baseline loop
/// pays that per row.
const JET_PARAM_BLOCK: usize = 4_096;

/// Series-liftable toy dynamics z' = a·tanh(z) + w·sin(t), conditioned per
/// trajectory (each row has its own a, w keyed on the engine ids), behind
/// the same per-launch staging cost model as [`ServingDynamics`].  The
/// scalar-jet and batched-jet paths evaluate the *identical* expression in
/// the identical operation order, so their results are bit-comparable.
struct JetServing {
    a: Vec<f64>,
    w: Vec<f64>,
    params: Vec<f32>,
    staging: Vec<f32>,
    stage_cost: bool,
    launches: usize,
}

impl JetServing {
    fn new(seed: u64, stage_cost: bool) -> JetServing {
        let mut rng = Pcg::new(seed);
        JetServing {
            a: (0..B).map(|_| rng.range(-1.2, 1.2) as f64).collect(),
            w: (0..B).map(|_| rng.range(0.5, 3.0) as f64).collect(),
            params: (0..JET_PARAM_BLOCK).map(|_| rng.range(-1.0, 1.0)).collect(),
            staging: vec![0.0; JET_PARAM_BLOCK],
            stage_cost,
            launches: 0,
        }
    }

    /// The per-row scalar-jet baseline: one `ode_jet` per trajectory, one
    /// staged launch per series evaluation of that single row.
    fn scalar_jets(&mut self, r: usize, z0: f64, t0: f64, order: usize) -> Vec<f64> {
        let (ar, wr) = (self.a[r], self.w[r]);
        let params = &self.params;
        let staging = &mut self.staging;
        let stage_cost = self.stage_cost;
        let mut launches = 0usize;
        let jets = ode_jet(
            |z: &Series, t: &Series| {
                launches += 1;
                if stage_cost {
                    staging.copy_from_slice(params);
                    std::hint::black_box(&*staging);
                }
                z.tanh().scale(ar).add(&t.sin_cos().0.scale(wr))
            },
            z0,
            t0,
            order,
        );
        self.launches += launches;
        jets
    }
}

impl BatchSeriesDynamics for JetServing {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, ids: &[usize], z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
        self.launches += 1;
        if self.stage_cost {
            self.staging.copy_from_slice(&self.params);
            std::hint::black_box(&self.staging);
        }
        let asel: Vec<f64> = ids.iter().map(|id| self.a[*id]).collect();
        let wsel: Vec<f64> = ids.iter().map(|id| self.w[*id]).collect();
        z.tanh().scale_rows(&asel).add(&t.sin_cos().0.scale_rows(&wsel))
    }
}

/// One trajectory of the baseline: scalar adaptive solve of the augmented
/// system [z, r] with the integrand from per-row scalar jets — exactly what
/// `RegularizedBatchDynamics` replaces.
fn scalar_rk_row(
    d: &mut JetServing,
    r: usize,
    z0: f32,
    order: usize,
    tb: &tableau::Tableau,
    opts: &AdaptiveOpts,
) -> (f32, f32, SolveStats) {
    let f = |t: f32, y: &[f32], dy: &mut [f32]| {
        let jets = d.scalar_jets(r, y[0] as f64, t as f64, order);
        dy[0] = jets[0] as f32;
        let v = jets[order - 1];
        // mirror the batched integrand ops exactly (n = 1)
        dy[1] = (v * v / 1.0) as f32;
    };
    let res = solve_adaptive(f, 0.0, 1.0, &[z0, 0.0], tb, opts);
    (res.y[0], res.y[1], res.stats)
}

fn main() {
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts::default();
    let mut rng = Pcg::new(7);
    let x: Vec<f32> = (0..B).map(|_| rng.range(-1.2, 1.2)).collect();

    // -- correctness first: identical per-example results either way -------
    let mut d_loop = ServingDynamics::new(1, true);
    let mut d_batch = ServingDynamics::new(1, true);
    let mut loop_y = Vec::with_capacity(B);
    let mut loop_nfe = Vec::with_capacity(B);
    for i in 0..B {
        let res = solve_adaptive_mut(&mut d_loop, 0.0, 1.0, &x[i..i + 1], &tb, &opts);
        loop_y.push(res.y[0]);
        loop_nfe.push(res.stats.nfe);
    }
    let bres = solve_adaptive_batch_mut(&mut d_batch, 0.0, 1.0, &x, &tb, &opts);
    assert_eq!(loop_nfe, bres.nfes(), "per-example NFE must be identical");
    for i in 0..B {
        assert_eq!(
            loop_y[i].to_bits(),
            bres.y[i].to_bits(),
            "example {i}: batched state must be bit-identical"
        );
    }
    let total_nfe: usize = loop_nfe.iter().sum();
    let min = loop_nfe.iter().min().unwrap();
    let max = loop_nfe.iter().max().unwrap();
    println!(
        "B={B} toy trajectories, dopri5: total NFE {total_nfe}, \
         per-example NFE {min}..{max}"
    );
    println!(
        "launches: per-example loop {}, batched engine {} ({:.1}x fewer)\n",
        d_loop.launches,
        d_batch.launches,
        d_loop.launches as f64 / d_batch.launches.max(1) as f64
    );

    // -- throughput: serving-shaped dynamics (per-launch staging cost) -----
    let mut d1 = ServingDynamics::new(1, true);
    let s_loop = time_fn(3, 20, || {
        for i in 0..B {
            let res = solve_adaptive_mut(&mut d1, 0.0, 1.0, &x[i..i + 1], &tb, &opts);
            std::hint::black_box(res.stats.nfe);
        }
    });
    report("per-example loop (staged launches, B=64)", &s_loop);

    let mut d2 = ServingDynamics::new(1, true);
    let s_batch = time_fn(3, 20, || {
        let res = solve_adaptive_batch_mut(&mut d2, 0.0, 1.0, &x, &tb, &opts);
        std::hint::black_box(res.stats.len());
    });
    report("batched engine     (staged launches, B=64)", &s_batch);

    let speedup = s_loop.mean / s_batch.mean;
    println!(
        "\nbatched speedup over per-example loop: {speedup:.2}x \
         ({} -> {})",
        fmt_secs(s_loop.mean),
        fmt_secs(s_batch.mean)
    );

    // -- driver-only amortization (pure closures, no staging cost) ---------
    let mut c1 = ServingDynamics::new(1, false);
    let s_loop_c = time_fn(3, 20, || {
        for i in 0..B {
            let res = solve_adaptive_mut(&mut c1, 0.0, 1.0, &x[i..i + 1], &tb, &opts);
            std::hint::black_box(res.stats.nfe);
        }
    });
    report("per-example loop (pure closure, B=64)", &s_loop_c);
    let mut c2 = ServingDynamics::new(1, false);
    let s_batch_c = time_fn(3, 20, || {
        let res = solve_adaptive_batch_mut(&mut c2, 0.0, 1.0, &x, &tb, &opts);
        std::hint::black_box(res.stats.len());
    });
    report("batched engine     (pure closure, B=64)", &s_batch_c);
    println!(
        "driver-only amortization: {:.2}x",
        s_loop_c.mean / s_batch_c.mean
    );

    assert!(
        speedup >= 4.0,
        "acceptance: batched engine must be >= 4x over the per-example loop \
         at B=64 on serving-shaped toy dynamics (got {speedup:.2}x)"
    );
    println!("\nacceptance (>= 4x at B=64): PASS");

    // -- batched Taylor-jet R_K vs the per-row scalar-jet loop -------------
    println!("\n== R_K (K={JET_ORDER}) via batched Taylor jets ==");
    let z0: Vec<f64> = x.iter().map(|v| *v as f64).collect();
    let t0 = vec![0.0f64; B];
    let ids: Vec<usize> = (0..B).collect();

    // correctness first: raw jets bit-identical per row
    let mut jb = JetServing::new(11, true);
    let jets_b = ode_jet_batch(&mut jb, &ids, &z0, &t0, JET_ORDER);
    let mut js = JetServing::new(11, true);
    for r in 0..B {
        let jets_s = js.scalar_jets(r, z0[r], 0.0, JET_ORDER);
        for k in 0..JET_ORDER {
            assert_eq!(
                jets_s[k].to_bits(),
                jets_b[k][r].to_bits(),
                "jet row {r} order {k} must be bit-identical"
            );
        }
    }
    println!(
        "raw jet sweep: bit-identical per row; series launches \
         per-row loop {}, batched {} ({:.1}x fewer)",
        js.launches,
        jb.launches,
        js.launches as f64 / jb.launches.max(1) as f64
    );

    // correctness: the full R_K quadrature solve, bit-identical per row
    // (state and R_K) with identical per-trajectory NFE.
    let mut db = JetServing::new(11, true);
    let ev = batch_rk_eval(&mut db, JET_ORDER, 0.0, 1.0, &x, &tb, &opts);
    let mut ds = JetServing::new(11, true);
    for r in 0..B {
        let (zf, rk, stats) = scalar_rk_row(&mut ds, r, x[r], JET_ORDER, &tb, &opts);
        assert_eq!(rk.to_bits(), ev.r_k[r].to_bits(), "R_K row {r}");
        assert_eq!(zf.to_bits(), ev.y[r].to_bits(), "state row {r}");
        assert_eq!(stats.nfe, ev.stats[r].nfe, "NFE row {r}");
    }
    println!(
        "R_K quadrature: bit-identical per row, NFE identical; launches \
         per-row loop {}, batched {}\n",
        ds.launches, db.launches
    );

    // throughput: staged launches (the serving/XLA shape)
    let mut q1 = JetServing::new(11, true);
    let s_rk_loop = time_fn(2, 10, || {
        for r in 0..B {
            let out = scalar_rk_row(&mut q1, r, x[r], JET_ORDER, &tb, &opts);
            std::hint::black_box(out.1);
        }
    });
    report("per-row scalar-jet R_K loop (staged, B=64)", &s_rk_loop);
    let mut q2 = JetServing::new(11, true);
    let s_rk_batch = time_fn(2, 10, || {
        let ev = batch_rk_eval(&mut q2, JET_ORDER, 0.0, 1.0, &x, &tb, &opts);
        std::hint::black_box(ev.r_k.len());
    });
    report("batched SeriesVec R_K eval  (staged, B=64)", &s_rk_batch);
    let jet_speedup = s_rk_loop.mean / s_rk_batch.mean;
    println!(
        "\nbatched R_K speedup over per-row scalar jets: {jet_speedup:.2}x \
         ({} -> {})",
        fmt_secs(s_rk_loop.mean),
        fmt_secs(s_rk_batch.mean)
    );

    // driver+series amortization alone (pure closures, no staging cost)
    let mut p1 = JetServing::new(11, false);
    let s_rk_loop_c = time_fn(2, 10, || {
        for r in 0..B {
            let out = scalar_rk_row(&mut p1, r, x[r], JET_ORDER, &tb, &opts);
            std::hint::black_box(out.1);
        }
    });
    report("per-row scalar-jet R_K loop (pure, B=64)", &s_rk_loop_c);
    let mut p2 = JetServing::new(11, false);
    let s_rk_batch_c = time_fn(2, 10, || {
        let ev = batch_rk_eval(&mut p2, JET_ORDER, 0.0, 1.0, &x, &tb, &opts);
        std::hint::black_box(ev.r_k.len());
    });
    report("batched SeriesVec R_K eval  (pure, B=64)", &s_rk_batch_c);
    println!(
        "jet driver-only amortization: {:.2}x",
        s_rk_loop_c.mean / s_rk_batch_c.mean
    );

    assert!(
        jet_speedup >= 2.0,
        "acceptance: batched R_K evaluation must be >= 2x over the per-row \
         scalar-jet loop at B=64 (got {jet_speedup:.2}x)"
    );
    println!("\njet acceptance (>= 2x at B=64): PASS");

    // -- worker-pool sharding vs the serial batched driver at B=256 --------
    let pool = Pool::from_env();
    println!(
        "\n== sharded engine (worker pool, {} thread(s)) ==",
        pool.threads()
    );
    let mut rng = Pcg::new(29);
    let xp: Vec<f32> = (0..POOL_B).map(|_| rng.range(-1.2, 1.2)).collect();
    let fp = ComputeDynamics::new(17);

    // correctness first: per-trajectory bit-identity at several thread
    // counts, including the environment's
    let mut serial_f = fp.clone();
    let sres = solve_adaptive_batch_mut(&mut serial_f, 0.0, 1.0, &xp, &tb, &opts);
    for threads in [2usize, 4, pool.threads()] {
        let check = Pool::new(threads.max(1));
        let pres = solve_adaptive_batch_pooled(&check, &fp, 0.0, 1.0, &xp, &tb, &opts);
        assert_eq!(sres.nfes(), pres.nfes(), "pooled NFE threads={threads}");
        for r in 0..POOL_B {
            assert_eq!(
                sres.row(r)[0].to_bits(),
                pres.row(r)[0].to_bits(),
                "pooled row {r} must be bit-identical at {threads} threads"
            );
        }
    }
    println!("pooled == serial bit-for-bit at B={POOL_B} (threads 2, 4, env)");

    let mut f_serial = fp.clone();
    let s_serial = time_fn(3, 20, || {
        let res = solve_adaptive_batch_mut(&mut f_serial, 0.0, 1.0, &xp, &tb, &opts);
        std::hint::black_box(res.stats.len());
    });
    report(&format!("serial batched engine (B={POOL_B})"), &s_serial);
    let s_pooled = time_fn(3, 20, || {
        let res = solve_adaptive_batch_pooled(&pool, &fp, 0.0, 1.0, &xp, &tb, &opts);
        std::hint::black_box(res.stats.len());
    });
    report(&format!("pooled batched engine (B={POOL_B})"), &s_pooled);
    let pool_speedup = s_serial.mean / s_pooled.mean;
    let serial_tps = POOL_B as f64 / s_serial.mean;
    let pooled_tps = POOL_B as f64 / s_pooled.mean;
    println!(
        "\nsharded speedup over serial at B={POOL_B}: {pool_speedup:.2}x \
         ({:.0} -> {:.0} trajectories/sec, {} worker(s))",
        serial_tps,
        pooled_tps,
        pool.threads()
    );
    if pool.threads() >= 4 {
        assert!(
            pool_speedup >= 2.0,
            "acceptance: sharded engine must be >= 2x over serial at \
             B={POOL_B} with >= 4 workers (got {pool_speedup:.2}x)"
        );
        println!("pool acceptance (>= 2x at B={POOL_B}, >= 4 workers): PASS");
    } else {
        println!(
            "pool acceptance gate skipped: only {} worker(s) available \
             (needs >= 4)",
            pool.threads()
        );
    }

    if let Some(path) = json_path_arg() {
        merge_bench_json(&path, "threads", Json::num(pool.threads() as f64));
        merge_bench_json(
            &path,
            "perf_batch",
            Json::obj(vec![
                ("b", Json::num(POOL_B as f64)),
                ("serial_trajs_per_sec", Json::num(serial_tps)),
                ("pooled_trajs_per_sec", Json::num(pooled_tps)),
                ("speedup_vs_serial", Json::num(pool_speedup)),
                ("batched_vs_per_example_speedup", Json::num(speedup)),
                ("jet_speedup", Json::num(jet_speedup)),
            ]),
        );
        println!("\nwrote perf_batch section to {path}");
    }
}
