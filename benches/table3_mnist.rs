//! Bench harness for paper Table 3 — runs the same regenerator as
//! `repro experiment table3` at reduced scale and reports wall-clock.
use taynode::experiments::{run, Scale};
use taynode::util::bench;

fn main() {
    let t0 = std::time::Instant::now();
    run("table3", Scale::quick()).expect("artifacts built? run `make artifacts`");
    println!("\ntable3_mnist: total {}", bench::fmt_secs(t0.elapsed().as_secs_f64()));
}
