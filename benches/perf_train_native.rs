//! §Perf: the native discrete-adjoint training step, serial vs worker-pool.
//!
//! Reports the forward (recorded fixed-grid solve of the quadrature-
//! augmented system), the serial full train step, and the pooled full
//! train step (sharded forward + sharded per-stage tape VJPs + Adam) at
//! three model shapes: the 1-D toy, and a projected-MNIST-sized state at
//! K = 2 and K = 3.  The
//! adjoint/forward overhead (full step minus its forward half, over the
//! forward) is the cost of reverse-over-Taylor on the tape — the number to
//! watch when optimizing the tape (arena reuse, fewer zero-coefficient
//! nodes, SIMD columns).
//!
//! Correctness is asserted before anything is timed: adjoint gradients are
//! finite and nonzero, and the pooled step's loss and gradients are
//! **bit-identical** to the single-thread step (their FD equivalence is
//! property-tested in `coordinator::train_native`).  The ≥ 1.5x pooled
//! speedup gate applies when ≥ 4 workers are available.  `--json <path>`
//! appends the machine-readable numbers (see `make bench-json`).

use taynode::coordinator::train_native::NativeTrainer;
use taynode::nn::Mlp;
use taynode::solvers::tableau;
use taynode::util::bench::{json_path_arg, merge_bench_json, report, time_fn};
use taynode::util::json::Json;
use taynode::util::pool::Pool;
use taynode::util::rng::Pcg;

fn batch(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg::new(seed);
    let x0: Vec<f32> = (0..n * dim).map(|_| rng.range(-1.2, 1.2)).collect();
    let targets = x0.iter().map(|x| x + 0.3 * x * x * x).collect();
    (x0, targets)
}

struct ShapeResult {
    key: &'static str,
    serial_steps_per_sec: f64,
    pooled_steps_per_sec: f64,
    speedup: f64,
}

fn bench_shape(
    name: &str,
    key: &'static str,
    dim: usize,
    hidden: &[usize],
    b: usize,
    order: usize,
    threads: usize,
) -> ShapeResult {
    let (x0, targets) = batch(b, dim, 7);
    let make = |thr: usize| {
        let mlp = Mlp::new(dim, hidden, true, 42);
        NativeTrainer::new(mlp, None, order, 0.1, 8, tableau::rk4(), 0.01).with_threads(thr)
    };

    // Honesty gates: the step must produce real gradients, and the pooled
    // step must reproduce the serial one bit-for-bit.
    {
        let mut tr = make(1);
        let (m, grads) = tr.mse_grads(&x0, &targets);
        assert!(m.loss.is_finite(), "{name}: loss not finite");
        assert!(
            grads.iter().all(|g| g.is_finite()),
            "{name}: non-finite gradient"
        );
        assert!(
            grads.iter().any(|g| g.abs() > 1e-10),
            "{name}: gradients all zero"
        );
        let mut tp = make(threads);
        let (mp, gp) = tp.mse_grads(&x0, &targets);
        assert_eq!(
            m.loss.to_bits(),
            mp.loss.to_bits(),
            "{name}: pooled loss must be bit-identical"
        );
        for (i, (a, w)) in gp.iter().zip(&grads).enumerate() {
            assert_eq!(
                a.to_bits(),
                w.to_bits(),
                "{name}: pooled grad[{i}] must be bit-identical"
            );
        }
    }

    let mut tr = make(threads);
    let fwd = time_fn(2, 8, || {
        std::hint::black_box(tr.forward_record(&x0));
    });
    report(&format!("{name}: forward record (pooled)"), &fwd);
    let mut ts = make(1);
    let step_serial = time_fn(2, 8, || {
        std::hint::black_box(ts.step_mse(&x0, &targets));
    });
    report(&format!("{name}: full step (serial)"), &step_serial);
    let mut tp = make(threads);
    let step_pooled = time_fn(2, 8, || {
        std::hint::black_box(tp.step_mse(&x0, &targets));
    });
    report(&format!("{name}: full step (pooled)"), &step_pooled);
    // The adjoint's own cost relative to one forward (the full step minus
    // its forward half, over the forward), plus the pooled speedup.
    let speedup = step_serial.p50 / step_pooled.p50;
    println!(
        "{:<44} adjoint/forward overhead ~{:.1}x, pooled step speedup {:.2}x",
        name,
        ((step_pooled.p50 - fwd.p50) / fwd.p50.max(1e-12)).max(0.0),
        speedup
    );
    ShapeResult {
        key,
        serial_steps_per_sec: 1.0 / step_serial.p50.max(1e-12),
        pooled_steps_per_sec: 1.0 / step_pooled.p50.max(1e-12),
        speedup,
    }
}

fn main() {
    let pool = Pool::from_env();
    let threads = pool.threads();
    println!("== native train-step throughput, serial vs {threads} worker(s) (K = R_K order) ==");
    let shapes = [
        bench_shape(
            "toy 1-d, hidden [16,16], B=64, K=2",
            "toy_b64_k2",
            1,
            &[16, 16],
            64,
            2,
            threads,
        ),
        bench_shape(
            "proj-mnist 16-d, hidden [32], B=32, K=2",
            "mnist16_b32_k2",
            16,
            &[32],
            32,
            2,
            threads,
        ),
        bench_shape(
            "proj-mnist 16-d, hidden [32], B=32, K=3",
            "mnist16_b32_k3",
            16,
            &[32],
            32,
            3,
            threads,
        ),
    ];

    if threads >= 4 {
        let got = shapes[0].speedup;
        assert!(
            got >= 1.5,
            "acceptance: pooled fwd+adjoint step must be >= 1.5x serial \
             with >= 4 workers (toy B=64: got {got:.2}x)"
        );
        println!("\ntrain acceptance (>= 1.5x step speedup, >= 4 workers): PASS");
    } else {
        println!(
            "\ntrain acceptance gate skipped: only {threads} worker(s) \
             available (needs >= 4)"
        );
    }

    if let Some(path) = json_path_arg() {
        merge_bench_json(&path, "threads", Json::num(threads as f64));
        let mut sections = Vec::new();
        for s in &shapes {
            sections.push((
                s.key,
                Json::obj(vec![
                    ("serial_steps_per_sec", Json::num(s.serial_steps_per_sec)),
                    ("pooled_steps_per_sec", Json::num(s.pooled_steps_per_sec)),
                    ("speedup_vs_serial", Json::num(s.speedup)),
                ]),
            ));
        }
        merge_bench_json(&path, "perf_train_native", Json::obj(sections));
        println!("wrote perf_train_native section to {path}");
    }
}
