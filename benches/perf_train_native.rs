//! §Perf: the native discrete-adjoint training step.
//!
//! Reports the forward (recorded fixed-grid solve of the quadrature-
//! augmented system) and the full train step (forward + per-stage tape
//! VJPs + Adam) separately, at two model shapes: the 1-D toy and a
//! projected-MNIST-sized state.  The adjoint/forward overhead (full step
//! minus its forward half, over the forward) is the cost of
//! reverse-over-Taylor on the tape — the number to watch when optimizing
//! the tape (node pooling, SIMD columns, fewer zero-coefficient nodes).
//!
//! Correctness is asserted before anything is timed: adjoint gradients are
//! finite and nonzero (their FD equivalence is property-tested in
//! `coordinator::train_native`).

use taynode::coordinator::train_native::NativeTrainer;
use taynode::nn::Mlp;
use taynode::solvers::tableau;
use taynode::util::bench::{report, time_fn};
use taynode::util::rng::Pcg;

fn batch(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg::new(seed);
    let x0: Vec<f32> = (0..n * dim).map(|_| rng.range(-1.2, 1.2)).collect();
    let targets = x0.iter().map(|x| x + 0.3 * x * x * x).collect();
    (x0, targets)
}

fn bench_shape(name: &str, dim: usize, hidden: &[usize], b: usize, order: usize) {
    let (x0, targets) = batch(b, dim, 7);
    let make = || {
        let mlp = Mlp::new(dim, hidden, true, 42);
        NativeTrainer::new(mlp, None, order, 0.1, 8, tableau::rk4(), 0.01)
    };

    // Honesty gate: the step must produce real gradients.
    {
        let mut tr = make();
        let (m, grads) = tr.mse_grads(&x0, &targets);
        assert!(m.loss.is_finite(), "{name}: loss not finite");
        assert!(
            grads.iter().all(|g| g.is_finite()),
            "{name}: non-finite gradient"
        );
        assert!(
            grads.iter().any(|g| g.abs() > 1e-10),
            "{name}: gradients all zero"
        );
    }

    let mut tr = make();
    let fwd = time_fn(2, 8, || {
        std::hint::black_box(tr.forward_record(&x0));
    });
    report(&format!("{name}: forward record (grid)"), &fwd);
    let mut tr = make();
    let step = time_fn(2, 8, || {
        std::hint::black_box(tr.step_mse(&x0, &targets));
    });
    report(&format!("{name}: full train step (fwd+adjoint)"), &step);
    // The adjoint's own cost relative to one forward (the full step minus
    // its forward half, over the forward).
    println!(
        "{:<44} adjoint/forward overhead ~{:.1}x",
        name,
        ((step.p50 - fwd.p50) / fwd.p50.max(1e-12)).max(0.0)
    );
}

fn main() {
    println!("== native train-step throughput (K = R_K order) ==");
    bench_shape("toy 1-d, hidden [16,16], B=64, K=2", 1, &[16, 16], 64, 2);
    bench_shape("proj-mnist 16-d, hidden [32], B=32, K=2", 16, &[32], 32, 2);
    bench_shape("proj-mnist 16-d, hidden [32], B=32, K=3", 16, &[32], 32, 3);
}
