//! §Perf: the telemetry overhead gate.
//!
//! The observability layer's contract is "zero-cost off": every hot-path
//! record call is an early return on a `None` check when the recorder is
//! disabled.  This bench holds that to ≤ 5% — the traced pooled driver
//! with recording OFF (the shipped default everywhere telemetry isn't
//! explicitly enabled) against the untraced driver on the same
//! compute-bound batch solve.
//!
//! Correctness is asserted before anything is timed: telemetry off OR on
//! must not perturb the solve — per-trajectory states and NFE bit-identical
//! to the untraced result.  The enabled-recording cost is reported too,
//! ungated (turning tracing on is an explicit opt-in, not the default).
//!
//! The gate compares min-of-samples across up to five attempts so a noisy
//! neighbor can't fail the build; a genuine hot-path regression shows up
//! in every attempt.

use taynode::obs::Recorder;
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::batch::{
    solve_adaptive_batch_pooled, solve_adaptive_batch_traced_pooled, BatchDynamics,
};
use taynode::solvers::tableau;
use taynode::util::bench::{json_path_arg, merge_bench_json, report, time_fn};
use taynode::util::json::Json;
use taynode::util::pool::Pool;
use taynode::util::rng::Pcg;

const B: usize = 64;
const HIDDEN: usize = 64;

/// Compute-bound native dynamics (the pooled path's target shape; same
/// model as `perf_batch`'s sharded-engine section).
#[derive(Clone)]
struct ComputeDynamics {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
}

impl ComputeDynamics {
    fn new(seed: u64) -> ComputeDynamics {
        let mut rng = Pcg::new(seed);
        ComputeDynamics {
            w1: (0..HIDDEN).map(|_| rng.range(-1.5, 1.5)).collect(),
            b1: (0..HIDDEN).map(|_| rng.range(-0.5, 0.5)).collect(),
            w2: (0..HIDDEN).map(|_| rng.range(-0.7, 0.7)).collect(),
        }
    }
}

impl BatchDynamics for ComputeDynamics {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        for (r, tr) in t.iter().enumerate() {
            let mut acc = 0.0f32;
            for j in 0..HIDDEN {
                acc += self.w2[j] * (self.w1[j] * y[r] + self.b1[j] + 0.1 * tr).tanh();
            }
            dy[r] = acc;
        }
    }
}

fn main() {
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts::default();
    let pool = Pool::from_env();
    let mut rng = Pcg::new(23);
    let x: Vec<f32> = (0..B).map(|_| rng.range(-1.2, 1.2)).collect();
    let f = ComputeDynamics::new(19);

    // -- correctness first: telemetry must never perturb the solve ---------
    let base = solve_adaptive_batch_pooled(&pool, &f, 0.0, 1.0, &x, &tb, &opts);
    let mut off = Recorder::off();
    let r_off = solve_adaptive_batch_traced_pooled(&pool, &f, 0.0, 1.0, &x, &tb, &opts, &mut off);
    let mut on = Recorder::enabled();
    let r_on = solve_adaptive_batch_traced_pooled(&pool, &f, 0.0, 1.0, &x, &tb, &opts, &mut on);
    assert_eq!(base.nfes(), r_off.nfes(), "traced-off NFE");
    assert_eq!(base.nfes(), r_on.nfes(), "traced-on NFE");
    for r in 0..B {
        assert_eq!(base.y[r].to_bits(), r_off.y[r].to_bits(), "traced-off row {r}");
        assert_eq!(base.y[r].to_bits(), r_on.y[r].to_bits(), "traced-on row {r}");
    }
    assert!(!on.events().is_empty(), "enabled recorder must capture events");
    println!(
        "traced(off) == traced(on) == untraced bit-for-bit at B={B} \
         ({} thread(s), {} events recorded)\n",
        pool.threads(),
        on.events().len()
    );

    // -- the gate: disabled telemetry <= 5% over the untraced driver -------
    let mut best = f64::INFINITY;
    let mut plain_min = f64::NAN;
    let mut off_min = f64::NAN;
    for attempt in 1..=5 {
        let s_plain = time_fn(3, 20, || {
            let res = solve_adaptive_batch_pooled(&pool, &f, 0.0, 1.0, &x, &tb, &opts);
            std::hint::black_box(res.stats.len());
        });
        let s_off = time_fn(3, 20, || {
            let mut rec = Recorder::off();
            let res =
                solve_adaptive_batch_traced_pooled(&pool, &f, 0.0, 1.0, &x, &tb, &opts, &mut rec);
            std::hint::black_box(res.stats.len());
        });
        let ratio = s_off.min / s_plain.min;
        if ratio < best {
            best = ratio;
            plain_min = s_plain.min;
            off_min = s_off.min;
        }
        report(&format!("untraced pooled solve  (B={B}, attempt {attempt})"), &s_plain);
        report(&format!("traced-off pooled solve (B={B}, attempt {attempt})"), &s_off);
        println!("overhead ratio (min/min): {ratio:.4}\n");
        if best <= 1.05 {
            break;
        }
    }
    assert!(
        best <= 1.05,
        "acceptance: disabled telemetry must cost <= 5% over the untraced \
         driver (best ratio {best:.4})"
    );
    println!("acceptance (traced-off <= 1.05x untraced): PASS ({best:.4})");

    // -- informational: what enabling recording actually costs -------------
    let s_on = time_fn(3, 20, || {
        let mut rec = Recorder::enabled();
        let res =
            solve_adaptive_batch_traced_pooled(&pool, &f, 0.0, 1.0, &x, &tb, &opts, &mut rec);
        std::hint::black_box(rec.events().len() + res.stats.len());
    });
    report(&format!("traced-on pooled solve  (B={B}, ungated)"), &s_on);
    let on_ratio = s_on.min / plain_min;
    println!("enabled-recording cost: {on_ratio:.3}x the untraced driver");

    if let Some(path) = json_path_arg() {
        merge_bench_json(
            &path,
            "perf_obs",
            Json::obj(vec![
                ("b", Json::num(B as f64)),
                ("threads", Json::num(pool.threads() as f64)),
                ("untraced_min_secs", Json::num(plain_min)),
                ("traced_off_min_secs", Json::num(off_min)),
                ("off_overhead_ratio", Json::num(best)),
                ("on_cost_ratio", Json::num(on_ratio)),
            ]),
        );
        println!("\nwrote perf_obs section to {path}");
    }
}
