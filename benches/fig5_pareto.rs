//! Bench harness for paper Fig 5 (and 11/12) — runs the same regenerator as
//! `repro experiment fig5` at reduced scale and reports wall-clock.
use taynode::experiments::{run, Scale};
use taynode::util::bench;

fn main() {
    let t0 = std::time::Instant::now();
    run("fig5", Scale::quick()).expect("artifacts built? run `make artifacts`");
    println!("\nfig5_pareto: total {}", bench::fmt_secs(t0.elapsed().as_secs_f64()));
}
