//! §Perf micro-benchmarks over the three layers' hot paths:
//!
//!   L3a  dynamics-executable invocation latency (one NFE), jnp vs pallas
//!   L3b  adaptive-solver driver overhead (native dynamics, no XLA)
//!   L3c  fixed-grid train-step latency per variant (jet cost vs K)
//!   L3d  data-generator throughput
//!
//! Before/after numbers for the optimization pass are recorded in
//! EXPERIMENTS.md §Perf.

use taynode::coordinator::{BatchInputs, Trainer};
use taynode::data::synth_mnist;
use taynode::experiments::common::{load_runtime, MnistHarness};
use taynode::runtime::XlaDynamics;
use taynode::solvers::adaptive::{solve_adaptive, AdaptiveOpts};
use taynode::solvers::{tableau, Dynamics};
use taynode::util::bench::{report, time_fn};
use taynode::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // Equality before timing (lint rule D5): the native adaptive solve the
    // L3b section times must be run-to-run deterministic, bit for bit.
    {
        let tb = tableau::dopri5();
        let y0 = vec![0.1f32; 64];
        let solve = || {
            solve_adaptive(
                |t: f32, y: &[f32], dy: &mut [f32]| {
                    for i in 0..y.len() {
                        dy[i] = (t + y[i]).sin();
                    }
                },
                0.0,
                1.0,
                &y0,
                &tb,
                &AdaptiveOpts::default(),
            )
        };
        let (a, b) = (solve(), solve());
        assert_eq!(a.stats.nfe, b.stats.nfe, "perf_hotpath: NFE must be deterministic");
        assert!(
            a.y.iter().zip(&b.y).all(|(p, q)| p.to_bits() == q.to_bits()),
            "perf_hotpath: repeated solves must agree bit for bit"
        );
    }

    let rt = load_runtime()?;
    let h = MnistHarness::new(&rt, 256, 0)?;
    let tr = Trainer::new(&rt, "mnist_train_unreg_s2", 0)?;
    let (x, _) = h.eval_batch(&h.train, 0);

    // L3a: one NFE = one PJRT execution of the dynamics over the batch
    for exec in ["mnist_dynamics", "mnist_dynamics_pallas"] {
        let mut dyn_f = XlaDynamics::from_store(&rt, exec, &tr.store, None)?;
        let n = dyn_f.state_len();
        let mut dy = vec![0.0f32; n];
        let s = time_fn(5, 50, || dyn_f.eval(0.3, &x[..n], &mut dy));
        report(&format!("L3a {exec} (one NFE, B=64)"), &s);
    }

    // L3b: pure solver-driver overhead on native dynamics (no XLA), so the
    // axpy/controller cost is visible in isolation.
    let tb = tableau::dopri5();
    let dims = [64usize, 1024, 12544];
    for d in dims {
        let y0 = vec![0.1f32; d];
        let s = time_fn(3, 30, || {
            let res = solve_adaptive(
                |t: f32, y: &[f32], dy: &mut [f32]| {
                    for i in 0..y.len() {
                        dy[i] = (t + y[i]).sin();
                    }
                },
                0.0,
                1.0,
                &y0,
                &tb,
                &AdaptiveOpts::default(),
            );
            std::hint::black_box(res.stats.nfe);
        });
        report(&format!("L3b adaptive driver, native dyn, d={d}"), &s);
    }

    // L3c: full train-step latency — the price of the jet rises with K
    // (paper §6.3 "ours is slower per step"; the payoff is test-time NFE).
    for artifact in [
        "mnist_train_unreg_s8",
        "mnist_train_rnode_s8",
        "mnist_train_k1_s8",
        "mnist_train_k2_s8",
        "mnist_train_k3_s8",
    ] {
        let mut t = Trainer::new(&rt, artifact, 0)?;
        let raw = synth_mnist::generate(h.b, 3);
        let inputs = BatchInputs::default()
            .f("x", raw.images)
            .i("labels", raw.labels);
        let s = time_fn(2, 10, || {
            t.step(&inputs, 0.01, 0.05).expect("step");
        });
        report(&format!("L3c train step {artifact}"), &s);
    }

    // L3d: data generation throughput
    let mut rng = Pcg::new(0);
    let s = time_fn(2, 20, || {
        std::hint::black_box(synth_mnist::render(3, &mut rng));
    });
    report("L3d synth_mnist::render (one 14x14 digit)", &s);

    Ok(())
}
