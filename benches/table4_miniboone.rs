//! Bench harness for paper Table 4 — runs the same regenerator as
//! `repro experiment table4` at reduced scale and reports wall-clock.
use taynode::experiments::{run, Scale};
use taynode::util::bench;

fn main() {
    let t0 = std::time::Instant::now();
    run("table4", Scale::quick()).expect("artifacts built? run `make artifacts`");
    println!("\ntable4_miniboone: total {}", bench::fmt_secs(t0.elapsed().as_secs_f64()));
}
