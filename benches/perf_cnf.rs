//! §Perf: the native CNF stack — the divergence engine (exact VJP sweeps
//! vs one-probe Hutchinson), the log-det + `R_K` augmented adaptive solve
//! (serial vs the chunk-queue pooled driver), and the full NLL train step
//! (recorded forward + log-det discrete adjoint + Adam).
//!
//! Correctness is asserted before anything is timed: both divergence modes
//! see identical forward values, the fixed-seed estimator is exactly
//! reproducible, the pooled solve is **bit-identical** to serial, and the
//! pooled train step reproduces the serial loss and gradients bit-for-bit
//! (their FD correctness is property-tested in
//! `coordinator::train_native`).  The ≥ 1.5x pooled-solve speedup gate
//! applies when ≥ 4 workers are available.  `--json <path>` appends the
//! machine-readable numbers (see `make bench-json`, which routes this
//! bench into `BENCH_cnf.json`).

use taynode::autodiff::div::{batch_divergence, Divergence};
use taynode::coordinator::train_native::NativeCnfTrainer;
use taynode::data::toy_density;
use taynode::nn::Cnf;
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::batch::{
    solve_adaptive_batch, solve_adaptive_batch_mut, solve_adaptive_batch_pooled,
    LogDetBatchDynamics,
};
use taynode::solvers::tableau;
use taynode::util::bench::{json_path_arg, merge_bench_json, report, time_fn};
use taynode::util::json::Json;
use taynode::util::pool::Pool;
use taynode::util::rng::Pcg;

fn main() {
    let pool = Pool::from_env();
    let threads = pool.threads();
    println!("== native CNF stack: divergence engine, log-det solves, NLL training ==");

    // -- divergence engine: exact (d sweeps) vs hutchinson (1 sweep) --------
    let d = 8usize;
    let b = 64usize;
    let mut rng = Pcg::new(3);
    let mut cnf = Cnf::new(d, &[32], 42);
    for p in cnf.params.iter_mut() {
        if *p == 0.0 {
            *p = rng.range(-0.5, 0.5);
        }
    }
    let z: Vec<f64> = (0..b * d).map(|_| rng.range(-1.0, 1.0) as f64).collect();
    let t: Vec<f64> = (0..b).map(|_| rng.range(0.0, 1.0) as f64).collect();
    let ids: Vec<usize> = (0..b).collect();
    let hutch = Divergence::Hutchinson { probes: 1, seed: 7 };
    let (dy_e, div_e) = batch_divergence(&cnf, &ids, &t, &z, &Divergence::Exact);
    let (dy_h, div_h) = batch_divergence(&cnf, &ids, &t, &z, &hutch);
    let (_, div_h2) = batch_divergence(&cnf, &ids, &t, &z, &hutch);
    assert!(div_e.iter().all(|v| v.is_finite()), "exact divergence not finite");
    for (a, w) in dy_h.iter().zip(&dy_e) {
        assert_eq!(a.to_bits(), w.to_bits(), "modes must share the forward");
    }
    for (a, w) in div_h.iter().zip(&div_h2) {
        assert_eq!(a.to_bits(), w.to_bits(), "fixed-seed estimate must reproduce");
    }
    let ex = time_fn(2, 10, || {
        std::hint::black_box(batch_divergence(&cnf, &ids, &t, &z, &Divergence::Exact));
    });
    report(&format!("divergence d={d} B={b}: exact ({d} VJP sweeps)"), &ex);
    let hu = time_fn(2, 10, || {
        std::hint::black_box(batch_divergence(&cnf, &ids, &t, &z, &hutch));
    });
    report(&format!("divergence d={d} B={b}: hutchinson-1 (1 sweep)"), &hu);
    println!("exact/hutchinson cost ratio: {:.2}x\n", ex.p50 / hu.p50.max(1e-12));

    // -- log-det + R_2 adaptive solve, serial vs chunk-queue pooled ----------
    let d2 = 2usize;
    let b2 = 128usize;
    let mut flow = Cnf::new(d2, &[16], 11);
    for p in flow.params.iter_mut() {
        if *p == 0.0 {
            *p = rng.range(-0.5, 0.5);
        }
    }
    let y0: Vec<f32> = (0..b2 * d2).map(|_| rng.range(-1.5, 1.5)).collect();
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts { rtol: 1e-5, atol: 1e-7, ..Default::default() };
    let ld = LogDetBatchDynamics::new(flow, Divergence::Exact).with_regularizer(2);
    let aug = ld.augment(&y0);
    let serial = solve_adaptive_batch(ld.clone(), 0.0, 1.0, &aug, &tb, &opts);
    let pooled = solve_adaptive_batch_pooled(&pool, &ld, 0.0, 1.0, &aug, &tb, &opts);
    for (i, (a, w)) in pooled.y.iter().zip(&serial.y).enumerate() {
        assert_eq!(a.to_bits(), w.to_bits(), "pooled logdet y[{i}] must be bit-identical");
    }
    let mut own = ld.clone();
    let s1 = time_fn(1, 5, || {
        std::hint::black_box(solve_adaptive_batch_mut(&mut own, 0.0, 1.0, &aug, &tb, &opts));
    });
    report(&format!("logdet+R_2 adaptive solve B={b2} (serial)"), &s1);
    let sp = time_fn(1, 5, || {
        std::hint::black_box(solve_adaptive_batch_pooled(&pool, &ld, 0.0, 1.0, &aug, &tb, &opts));
    });
    report(&format!("logdet+R_2 adaptive solve B={b2} ({threads} workers, chunk queue)"), &sp);
    let solve_speedup = s1.p50 / sp.p50.max(1e-12);
    println!("pooled solve speedup: {solve_speedup:.2}x\n");

    // -- the full NLL train step ---------------------------------------------
    let x = toy_density::sample("two_gaussians", 64, 5);
    let make = |thr: usize| {
        NativeCnfTrainer::new(Cnf::new(2, &[16], 42), 2, 0.1, 8, tableau::rk4(), 0.01)
            .with_threads(thr)
    };
    {
        let mut a = make(1);
        let (m1, g1) = a.nll_grads(&x);
        assert!(m1.loss.is_finite(), "CNF loss not finite");
        assert!(g1.iter().any(|g| g.abs() > 1e-10), "CNF gradients all zero");
        let mut bp = make(threads);
        let (mt, gt) = bp.nll_grads(&x);
        assert_eq!(
            m1.loss.to_bits(),
            mt.loss.to_bits(),
            "pooled CNF loss must be bit-identical"
        );
        for (i, (p, w)) in gt.iter().zip(&g1).enumerate() {
            assert_eq!(p.to_bits(), w.to_bits(), "pooled CNF grad[{i}] must be bit-identical");
        }
    }
    let mut tr = make(1);
    let fwd = time_fn(2, 8, || {
        std::hint::black_box(tr.forward_record(&x));
    });
    report("cnf forward record (fixed grid, exact divergence)", &fwd);
    let step_serial = time_fn(2, 8, || {
        std::hint::black_box(tr.step_nll(&x));
    });
    report("cnf full NLL step (serial)", &step_serial);
    let mut tp = make(threads);
    let step_pooled = time_fn(2, 8, || {
        std::hint::black_box(tp.step_nll(&x));
    });
    report("cnf full NLL step (pooled)", &step_pooled);
    let step_speedup = step_serial.p50 / step_pooled.p50.max(1e-12);
    println!(
        "adjoint/forward overhead ~{:.1}x, pooled step speedup {step_speedup:.2}x",
        ((step_serial.p50 - fwd.p50) / fwd.p50.max(1e-12)).max(0.0)
    );

    if threads >= 4 {
        assert!(
            solve_speedup >= 1.5,
            "acceptance: pooled logdet solve must be >= 1.5x serial with \
             >= 4 workers (got {solve_speedup:.2}x)"
        );
        println!("\ncnf acceptance (>= 1.5x pooled solve speedup, >= 4 workers): PASS");
    } else {
        println!(
            "\ncnf acceptance gate skipped: only {threads} worker(s) \
             available (needs >= 4)"
        );
    }

    if let Some(path) = json_path_arg() {
        merge_bench_json(&path, "threads", Json::num(threads as f64));
        merge_bench_json(
            &path,
            "perf_cnf",
            Json::obj(vec![
                ("divergence_exact_evals_per_sec", Json::num(1.0 / ex.p50.max(1e-12))),
                ("divergence_hutch1_evals_per_sec", Json::num(1.0 / hu.p50.max(1e-12))),
                ("logdet_solve_speedup_vs_serial", Json::num(solve_speedup)),
                ("nll_steps_per_sec_serial", Json::num(1.0 / step_serial.p50.max(1e-12))),
                ("nll_steps_per_sec_pooled", Json::num(1.0 / step_pooled.p50.max(1e-12))),
                ("nll_step_speedup", Json::num(step_speedup)),
            ]),
        );
        println!("wrote perf_cnf section to {path}");
    }
}
