//! Bench harness for paper Fig 3 — runs the same regenerator as
//! `repro experiment fig3` at reduced scale and reports wall-clock.
use taynode::experiments::{run, Scale};
use taynode::util::bench;

fn main() {
    let t0 = std::time::Instant::now();
    run("fig3", Scale::quick()).expect("artifacts built? run `make artifacts`");
    println!("\nfig3_mnist_training: total {}", bench::fmt_secs(t0.elapsed().as_secs_f64()));
}
