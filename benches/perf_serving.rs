//! §Perf: the continuous-batching serving engine under Poisson load.
//!
//! Drives the demo host (`toy` / `mnist` / `density`) at capacities
//! B ∈ {64, 256, 1024} with arrival rate B/4 per step and 6·B total
//! requests, stamping wall-clock submit→retire latency per request and
//! reporting p50/p99 alongside mean batch occupancy.  Each load point is
//! replayed under the drain-to-stragglers admission baseline at the
//! identical seed (same requests, same arrivals) and the continuous
//! drive's occupancy is **asserted strictly higher** — the acceptance
//! criterion behind the serving tentpole.
//!
//! Determinism is asserted before anything is timed: two same-seed
//! drives must replay bit-identical traces (wall-clock latency lives
//! only in this bench; the trace itself is a pure function of the seed).
//! `--json <path>` appends the machine-readable numbers (see
//! `make bench-json`, which routes this bench into `BENCH_serving.json`).

use std::time::Instant;

use taynode::serving::{
    demo_host, run_poisson, run_poisson_drain, trace_hash, PoissonArrivals, RequestGen,
    ServeResponse,
};
use taynode::util::bench::{json_path_arg, merge_bench_json, Table};
use taynode::util::json::Json;
use taynode::util::stats::summarize;

/// One wall-clock-stamped drive.  The trace fields replicate
/// `serving::drive_poisson` exactly (same arrivals, same generator, same
/// submit/step interleaving) — the stamps only observe, never steer.
struct TimedDrive {
    responses: Vec<ServeResponse>,
    latencies_ms: Vec<f64>,
    /// Per-retirement `(tolerance class, latency ms, deadline missed)`,
    /// in retirement order — the raw material for the per-class SLO view.
    by_class: Vec<(String, f64, bool)>,
    steps: u64,
    occupancy: f64,
}

/// Group the per-retirement stamps by tolerance class, sorted by class
/// name: `(class, latencies_ms, deadline_misses)`.
fn class_groups(by_class: &[(String, f64, bool)]) -> Vec<(String, Vec<f64>, u64)> {
    let mut groups: Vec<(String, Vec<f64>, u64)> = Vec::new();
    for (class, lat, miss) in by_class {
        let at = match groups.iter().position(|(c, _, _)| c == class) {
            Some(i) => i,
            None => {
                groups.push((class.clone(), Vec::new(), 0));
                groups.len() - 1
            }
        };
        groups[at].1.push(*lat);
        groups[at].2 += u64::from(*miss);
    }
    groups.sort_by(|a, b| a.0.cmp(&b.0));
    groups
}

fn drive_timed(seed: u64, capacity: usize, rate: f64, total: u64) -> TimedDrive {
    let mut host = demo_host(seed, capacity);
    let mut arrivals = PoissonArrivals::new(seed, rate);
    let mut gen = RequestGen::new(seed, host.model_specs());
    // Request ids are the sequential submit index, so they index straight
    // into the stamp vector.
    let mut submit_at: Vec<Instant> = Vec::with_capacity(total as usize);
    let mut responses = Vec::new();
    let mut latencies_ms = Vec::new();
    let mut by_class = Vec::new();
    let mut submitted = 0u64;
    let mut steps = 0u64;
    while submitted < total || !host.is_idle() {
        if submitted < total {
            let k = (arrivals.next_count() as u64).min(total - submitted);
            for _ in 0..k {
                let req = gen.next(submitted);
                submitted += 1;
                submit_at.push(Instant::now());
                if let Some(err) = host.submit(&req) {
                    responses.push(err);
                }
            }
        }
        let done = host.step();
        let now = Instant::now();
        for r in done {
            let dt = now.duration_since(submit_at[r.id as usize]);
            let ms = dt.as_secs_f64() * 1e3;
            latencies_ms.push(ms);
            by_class.push((r.class.clone(), ms, r.deadline_miss));
            responses.push(r);
        }
        steps += 1;
    }
    TimedDrive { responses, latencies_ms, by_class, steps, occupancy: host.occupancy() }
}

fn main() {
    println!("== continuous-batching serving: latency + occupancy under Poisson load ==");

    // -- determinism, asserted before anything is timed ----------------------
    let a = run_poisson(11, 64, 16.0, 400);
    let b = run_poisson(11, 64, 16.0, 400);
    assert_eq!(a.submitted, 400);
    assert_eq!(a.errors, 0, "demo request stream must be well-formed");
    assert_eq!(a, b, "same-seed serving traces must replay bit-identically");
    assert_eq!(trace_hash(&a.responses), trace_hash(&b.responses));
    println!(
        "replay OK: 400 requests, {} steps, trace hash {:016x}\n",
        a.steps,
        trace_hash(&a.responses)
    );

    let seed = 17u64;
    let mut table = Table::new(&[
        "B", "rate", "requests", "steps", "p50 ms", "p99 ms", "occupancy", "drain occ", "miss",
    ]);
    let mut class_table = Table::new(&["B", "class", "requests", "p50 ms", "p99 ms", "miss"]);
    let mut sections: Vec<(String, Json)> = Vec::new();
    for capacity in [64usize, 256, 1024] {
        let rate = capacity as f64 / 4.0;
        let total = 6 * capacity as u64;
        let timed = drive_timed(seed, capacity, rate, total);
        assert_eq!(timed.responses.len() as u64, total, "every request must answer");
        let drain = run_poisson_drain(seed, capacity, rate, total);
        // The tentpole claim: at equal load (same seed → same requests and
        // arrivals), continuous admission keeps the batch strictly fuller
        // than draining to stragglers.
        assert!(
            timed.occupancy > drain.mean_occupancy,
            "B={capacity}: continuous occupancy {} must beat drain {}",
            timed.occupancy,
            drain.mean_occupancy
        );
        let s = summarize(&timed.latencies_ms);
        let misses = timed.responses.iter().filter(|r| r.deadline_miss).count();
        let hash = trace_hash(&timed.responses);
        table.row(vec![
            capacity.to_string(),
            format!("{rate:.0}"),
            total.to_string(),
            timed.steps.to_string(),
            format!("{:.3}", s.p50),
            format!("{:.3}", s.p99),
            format!("{:.3}", timed.occupancy),
            format!("{:.3}", drain.mean_occupancy),
            misses.to_string(),
        ]);
        // Per-tolerance-class SLO view: misses concentrate in the class
        // with the tightest step budget, not uniformly across the batch.
        let groups = class_groups(&timed.by_class);
        let mut class_json: Vec<(String, Json)> = Vec::new();
        for (class, lats, class_misses) in &groups {
            let cs = summarize(lats);
            class_table.row(vec![
                capacity.to_string(),
                class.clone(),
                lats.len().to_string(),
                format!("{:.3}", cs.p50),
                format!("{:.3}", cs.p99),
                class_misses.to_string(),
            ]);
            class_json.push((
                class.clone(),
                Json::obj(vec![
                    ("count", Json::num(lats.len() as f64)),
                    ("p50_ms", Json::num(cs.p50)),
                    ("p99_ms", Json::num(cs.p99)),
                    ("deadline_misses", Json::num(*class_misses as f64)),
                ]),
            ));
        }
        let class_pairs: Vec<(&str, Json)> =
            class_json.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        sections.push((
            format!("b{capacity}"),
            Json::obj(vec![
                ("batch", Json::num(capacity as f64)),
                ("rate", Json::num(rate)),
                ("requests", Json::num(total as f64)),
                ("steps", Json::num(timed.steps as f64)),
                ("p50_ms", Json::num(s.p50)),
                ("p99_ms", Json::num(s.p99)),
                ("mean_occupancy", Json::num(timed.occupancy)),
                ("drain_occupancy", Json::num(drain.mean_occupancy)),
                ("deadline_misses", Json::num(misses as f64)),
                ("classes", Json::obj(class_pairs)),
                ("trace_hash", Json::str(format!("{hash:016x}"))),
            ]),
        ));
    }
    table.print();
    println!("\n-- per tolerance class --");
    class_table.print();

    if let Some(path) = json_path_arg() {
        let pairs: Vec<(&str, Json)> =
            sections.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        merge_bench_json(&path, "serving", Json::obj(pairs));
        println!("\nwrote serving section to {path}");
    }
}
