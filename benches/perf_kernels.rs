//! §Perf: the cache-blocked kernel layer (`taynode::kern`) against its
//! retained naive references (`kern::naive` — the exact pre-kernel loops,
//! not strawmen).
//!
//! Three sections, each following the same discipline: assert the blocked
//! kernel bit-identical to the naive reference on the benchmark inputs
//! FIRST (lint rule D5 — a speedup over a result you didn't verify is a
//! bug report, not a benchmark), then time both sides on identical data.
//!
//!   K1  flat-slab Cauchy product at jet orders K = 4 and K = 6 over a
//!       [2048, 8] batch — the `ode_jet_batch` inner op (gated ≥ 1.5x)
//!   K2  fused f32 MLP layer chain (9→128→128→8) at B = 256 — the
//!       `BatchDynamics` NFE hot path (gated ≥ 2x)
//!   K3  fused RK stage combination, 7 stages at n = 65536 — the dopri5
//!       per-step axpy (reported, no gate: purely memory-bound)
//!
//! `--json <path>` appends the machine-readable numbers under
//! "perf_kernels" (see `make bench-json`); `repro perfdiff` diffs them.

use taynode::kern::{axpy, cauchy, mlp, naive};
use taynode::util::bench::{fmt_secs, json_path_arg, merge_bench_json, report, time_fn};
use taynode::util::json::Json;
use taynode::util::ptest::gen;
use taynode::util::rng::Pcg;

/// Batch shape of the Cauchy section: a [2048, 8] state, m = 16384 lanes.
const ROWS: usize = 2048;
const COLS: usize = 8;
const M: usize = ROWS * COLS;

/// State length of the stage-axpy section (the synth-MNIST batch shape:
/// 256 rows x 256 augmented features).
const AXPY_N: usize = 65_536;

/// Random `[k1, m]` coefficient rows for the naive side; the blocked side
/// flattens the same values, so both consume identical inputs.
fn random_rows(rng: &mut Pcg, k1: usize, m: usize) -> Vec<Vec<f64>> {
    (0..k1).map(|_| gen::vec_f64(rng, m, -1.0, 1.0)).collect()
}

fn flatten(rows: &[Vec<f64>]) -> Vec<f64> {
    rows.iter().flat_map(|r| r.iter().copied()).collect()
}

fn assert_slab_eq(rows: &[Vec<f64>], slab: &[f64], m: usize, ctx: &str) {
    for (k, row) in rows.iter().enumerate() {
        for (e, v) in row.iter().enumerate() {
            assert_eq!(
                v.to_bits(),
                slab[k * m + e].to_bits(),
                "{ctx}: coeff {k} elem {e}: {v} vs {}",
                slab[k * m + e]
            );
        }
    }
}

/// One Cauchy-section pass at jet order K: verify bit-identity on the
/// benchmark inputs, time naive vs blocked mul, return the speedup.
fn cauchy_section(order: usize) -> f64 {
    let k1 = order + 1;
    let mut rng = Pcg::new(0xCA0C + order as u64);
    let z_rows = random_rows(&mut rng, k1, M);
    let w_rows = random_rows(&mut rng, k1, M);
    let z = flatten(&z_rows);
    let w = flatten(&w_rows);

    // D5: equality before timing, on the exact arrays about to be timed.
    let want = naive::mul(&z_rows, &w_rows);
    let mut got = vec![0.0f64; k1 * M];
    cauchy::mul_into(k1, M, &z, &w, &mut got);
    assert_slab_eq(&want, &got, M, &format!("cauchy mul K={order}"));
    let want_t = naive::tanh(&z_rows);
    cauchy::tanh_into(k1, M, &z, &mut got);
    assert_slab_eq(&want_t, &got, M, &format!("cauchy tanh K={order}"));
    println!("K1 Cauchy K={order}: blocked == naive bit-for-bit at [{ROWS}, {COLS}]");

    let s_naive = time_fn(3, 20, || {
        std::hint::black_box(naive::mul(&z_rows, &w_rows));
    });
    report(&format!("naive Cauchy mul   (K={order}, m={M})"), &s_naive);
    let mut out = vec![0.0f64; k1 * M];
    let s_blocked = time_fn(3, 20, || {
        cauchy::mul_into(k1, M, &z, &w, &mut out);
        std::hint::black_box(&out);
    });
    report(&format!("blocked Cauchy mul (K={order}, m={M})"), &s_blocked);
    let speedup = s_naive.mean / s_blocked.mean;
    println!(
        "Cauchy K={order} speedup: {speedup:.2}x ({} -> {})\n",
        fmt_secs(s_naive.mean),
        fmt_secs(s_blocked.mean)
    );
    speedup
}

/// MLP layer widths of the fused-layer section: the synth-MNIST dynamics
/// shape (n = 8 state dims + time through two 128-wide tanh layers).
const MLP_SIZES: [usize; 4] = [9, 128, 128, 8];
const MLP_B: usize = 256;

/// The naive chain: per-access-cast row-serial layers (the old
/// `BatchDynamics for Mlp` inner loop).
fn mlp_chain_naive(acts0: &[f64], ws: &[Vec<f32>], bs: &[Vec<f32>]) -> Vec<f64> {
    let mut acts = acts0.to_vec();
    for l in 0..MLP_SIZES.len() - 1 {
        let (win, wout) = (MLP_SIZES[l], MLP_SIZES[l + 1]);
        let hidden = l + 1 < MLP_SIZES.len() - 1;
        acts = naive::mlp_layer(MLP_B, win, wout, &acts, &ws[l], &bs[l], hidden);
    }
    acts
}

/// The fused chain: widen once per layer, tile over rows x output columns
/// (exactly what `BatchDynamics for Mlp` now runs per NFE).
fn mlp_chain_fused(
    acts0: &[f64],
    ws: &[Vec<f32>],
    bs: &[Vec<f32>],
    w64: &mut Vec<f64>,
    b64: &mut Vec<f64>,
    stage_in: &mut Vec<f64>,
    stage_out: &mut Vec<f64>,
) {
    stage_in.clear();
    stage_in.extend_from_slice(acts0);
    for l in 0..MLP_SIZES.len() - 1 {
        let (win, wout) = (MLP_SIZES[l], MLP_SIZES[l + 1]);
        let hidden = l + 1 < MLP_SIZES.len() - 1;
        mlp::widen(&ws[l], w64);
        mlp::widen(&bs[l], b64);
        mlp::layer_into(MLP_B, win, wout, stage_in, w64, b64, hidden, stage_out);
        std::mem::swap(stage_in, stage_out);
    }
}

fn mlp_section() -> f64 {
    let mut rng = Pcg::new(0x3147);
    let ws: Vec<Vec<f32>> = (0..MLP_SIZES.len() - 1)
        .map(|l| gen::vec_f32(&mut rng, MLP_SIZES[l] * MLP_SIZES[l + 1], 0.5))
        .collect();
    let bs: Vec<Vec<f32>> = (0..MLP_SIZES.len() - 1)
        .map(|l| gen::vec_f32(&mut rng, MLP_SIZES[l + 1], 0.2))
        .collect();
    let acts0 = gen::vec_f64(&mut rng, MLP_B * MLP_SIZES[0], -1.2, 1.2);

    // D5: equality before timing.
    let want = mlp_chain_naive(&acts0, &ws, &bs);
    let (mut w64, mut b64) = (vec![], vec![]);
    let (mut si, mut so) = (vec![], vec![]);
    mlp_chain_fused(&acts0, &ws, &bs, &mut w64, &mut b64, &mut si, &mut so);
    assert_eq!(si.len(), want.len());
    for (e, (g, v)) in si.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), v.to_bits(), "mlp elem {e}: {g} vs {v}");
    }
    println!(
        "K2 MLP {MLP_SIZES:?} B={MLP_B}: fused == naive bit-for-bit over the full chain"
    );

    let s_naive = time_fn(3, 30, || {
        std::hint::black_box(mlp_chain_naive(&acts0, &ws, &bs));
    });
    report(&format!("naive MLP chain (B={MLP_B})"), &s_naive);
    let s_fused = time_fn(3, 30, || {
        mlp_chain_fused(&acts0, &ws, &bs, &mut w64, &mut b64, &mut si, &mut so);
        std::hint::black_box(&si);
    });
    report(&format!("fused MLP chain (B={MLP_B})"), &s_fused);
    let speedup = s_naive.mean / s_fused.mean;
    println!(
        "fused MLP speedup: {speedup:.2}x ({} -> {})\n",
        fmt_secs(s_naive.mean),
        fmt_secs(s_fused.mean)
    );
    speedup
}

fn axpy_section() -> f64 {
    let mut rng = Pcg::new(0xA09D);
    let ks: Vec<Vec<f32>> = (0..7).map(|_| gen::vec_f32(&mut rng, AXPY_N, 1.0)).collect();
    let y = gen::vec_f32(&mut rng, AXPY_N, 1.0);
    // dopri5's b row: one zero coefficient, skipped by both sides.
    let coeffs = [0.091f32, 0.0, 0.449, 0.651, -0.322, 0.131, 0.0];
    let h = 0.05f32;

    // D5: equality before timing.
    let mut want = vec![0.0f32; AXPY_N];
    naive::multi_axpy(&coeffs, h, &ks, &y, &mut want);
    let mut got = vec![0.0f32; AXPY_N];
    axpy::fused_axpy_into(&coeffs, h, &ks, &y, &mut got);
    for (e, (g, v)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), v.to_bits(), "axpy elem {e}");
    }
    println!("K3 stage axpy n={AXPY_N}: fused == per-stage sweeps bit-for-bit");

    let s_naive = time_fn(5, 50, || {
        naive::multi_axpy(&coeffs, h, &ks, &y, &mut want);
        std::hint::black_box(&want);
    });
    report(&format!("per-stage sweeps (7 stages, n={AXPY_N})"), &s_naive);
    let s_fused = time_fn(5, 50, || {
        axpy::fused_axpy_into(&coeffs, h, &ks, &y, &mut got);
        std::hint::black_box(&got);
    });
    report(&format!("fused one-pass   (7 stages, n={AXPY_N})"), &s_fused);
    let speedup = s_naive.mean / s_fused.mean;
    println!(
        "fused axpy speedup: {speedup:.2}x ({} -> {}) [memory-bound; no gate]\n",
        fmt_secs(s_naive.mean),
        fmt_secs(s_fused.mean)
    );
    speedup
}

fn main() {
    println!("== kern: blocked kernels vs retained naive references ==\n");
    let cauchy_k4 = cauchy_section(4);
    let cauchy_k6 = cauchy_section(6);
    let mlp_speedup = mlp_section();
    let axpy_speedup = axpy_section();

    assert!(
        cauchy_k4 >= 1.5 && cauchy_k6 >= 1.5,
        "acceptance: blocked Cauchy product must be >= 1.5x at K >= 4 \
         (got {cauchy_k4:.2}x at K=4, {cauchy_k6:.2}x at K=6)"
    );
    println!("Cauchy acceptance (>= 1.5x at K=4 and K=6): PASS");
    assert!(
        mlp_speedup >= 2.0,
        "acceptance: fused MLP layer must be >= 2x at B={MLP_B} \
         (got {mlp_speedup:.2}x)"
    );
    println!("MLP acceptance (>= 2x at B={MLP_B}): PASS");

    if let Some(path) = json_path_arg() {
        merge_bench_json(
            &path,
            "perf_kernels",
            Json::obj(vec![
                ("cauchy_m", Json::num(M as f64)),
                ("cauchy_k4_speedup", Json::num(cauchy_k4)),
                ("cauchy_k6_speedup", Json::num(cauchy_k6)),
                ("mlp_b", Json::num(MLP_B as f64)),
                ("mlp_fused_speedup", Json::num(mlp_speedup)),
                ("axpy_n", Json::num(AXPY_N as f64)),
                ("axpy_fused_speedup", Json::num(axpy_speedup)),
            ]),
        );
        println!("wrote perf_kernels section to {path}");
    }
}
