//! Bench harness for paper Figs 6-7 — runs the same regenerator as
//! `repro experiment fig6` at reduced scale and reports wall-clock.
use taynode::experiments::{run, Scale};
use taynode::util::bench;

fn main() {
    let t0 = std::time::Instant::now();
    run("fig6", Scale::quick()).expect("artifacts built? run `make artifacts`");
    println!("\nfig6_order_vs_solver: total {}", bench::fmt_secs(t0.elapsed().as_secs_f64()));
}
