//! Bench harness for paper Figs 8/10 — runs the same regenerator as
//! `repro experiment fig8` at reduced scale and reports wall-clock.
use taynode::experiments::{run, Scale};
use taynode::util::bench;

fn main() {
    let t0 = std::time::Instant::now();
    run("fig8", Scale::quick()).expect("artifacts built? run `make artifacts`");
    println!("\nfig8_calibration: total {}", bench::fmt_secs(t0.elapsed().as_secs_f64()));
}
