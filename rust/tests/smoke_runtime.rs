//! Integration smoke test: artifacts load, compile, train and evaluate.
//! Requires `make artifacts` to have run (skips otherwise).

use std::path::Path;

use taynode::coordinator::{toy_eval, BatchInputs, Trainer};
use taynode::runtime::Runtime;
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::tableau;
use taynode::util::rng::Pcg;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        // Runtime::load always errors in stub builds; skip even when a
        // previous pjrt build left artifacts behind.
        return None;
    }
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn toy_train_step_reduces_loss_and_eval_runs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let mut tr = Trainer::new(&rt, "toy_train_unreg_s16", 0).unwrap();
    let mut rng = Pcg::new(1);
    let x: Vec<f32> = (0..128).map(|_| rng.range(-1.5, 1.5)).collect();
    let batch = BatchInputs::default().f("x", x.clone());
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let m = tr.step(&batch, 0.0, 0.05).unwrap();
        if first.is_none() {
            first = Some(m.loss());
        }
        last = m.loss();
        assert!(last.is_finite());
    }
    assert!(last < first.unwrap(), "{last} !< {first:?}");

    let tb = tableau::dopri5();
    let ev = toy_eval(&rt, &tr.store, &x, &tb, &AdaptiveOpts::default()).unwrap();
    assert!(ev.nfe > 0);
    assert!(ev.mse.is_finite());
}
