//! Acceptance tests for the deterministic telemetry layer: same-seed
//! traces must serialize to byte-identical Chrome-Trace NDJSON at
//! `TAYNODE_THREADS` ∈ {1, 2, 4} for the pooled adaptive solve, the native
//! train step, and the serving drive — the exported NDJSON must
//! round-trip through the strict JSON parser, and the `repro report`
//! rendering over each trace must be byte-identical too.

use taynode::coordinator::NativeTrainer;
use taynode::nn::Mlp;
use taynode::obs::analyze::TraceView;
use taynode::obs::report::trace_report;
use taynode::obs::trace::parse_ndjson;
use taynode::obs::{Recorder, TraceDoc};
use taynode::serving::{run_poisson_traced, run_poisson_traced_pooled};
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::batch::solve_adaptive_batch_traced_pooled;
use taynode::solvers::{solve_adaptive_batch, tableau};
use taynode::util::pool::Pool;
use taynode::util::rng::Pcg;

const B: usize = 48;

/// Render the `repro report` text for an exported trace — the end-to-end
/// path the CLI takes (strict parse, then deterministic rendering).
fn report_text(ndjson: &str) -> String {
    let view = TraceView::parse(ndjson).expect("exported trace must parse");
    trace_report(&view).expect("report must render").text
}

fn solve_inputs() -> (Mlp, Vec<f32>) {
    let mlp = Mlp::new(2, &[8], true, 5);
    let mut rng = Pcg::new(9);
    let y0: Vec<f32> = (0..B * 2).map(|_| rng.range(-1.0, 1.0)).collect();
    (mlp, y0)
}

#[test]
fn solve_adaptive_batch_traced_pooled_ndjson_bit_identical_across_threads() {
    let (f, y0) = solve_inputs();
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts::default();

    // The untraced serial driver is the numerical reference: tracing and
    // pooling together must not move a single bit.
    let sres = solve_adaptive_batch(f.clone(), 0.0, 1.0, &y0, &tb, &opts);

    let export = |threads: usize| {
        let pool = if threads == 1 { Pool::new(1) } else { Pool::new(threads) };
        let mut rec = Recorder::enabled();
        let res =
            solve_adaptive_batch_traced_pooled(&pool, &f, 0.0, 1.0, &y0, &tb, &opts, &mut rec);
        for r in 0..B * 2 {
            assert_eq!(res.y[r].to_bits(), sres.y[r].to_bits(), "state {r} threads={threads}");
        }
        for r in 0..B {
            assert_eq!(res.stats[r].nfe, sres.stats[r].nfe, "NFE {r} threads={threads}");
        }
        let mut doc = TraceDoc::new();
        doc.add_process(0, "solve/pooled", &rec);
        (doc.to_ndjson(), doc.hash())
    };

    let (base, base_hash) = export(1);
    assert!(base.lines().count() > B, "expected per-trajectory records");
    let base_report = report_text(&base);
    assert!(base_report.contains("cost ledger"), "solve trace must attribute cost");
    for threads in [2usize, 4] {
        let (ndjson, hash) = export(threads);
        assert_eq!(ndjson, base, "threads={threads}");
        assert_eq!(hash, base_hash, "threads={threads}");
        assert_eq!(report_text(&ndjson), base_report, "report threads={threads}");
    }
}

#[test]
fn native_train_step_trace_bit_identical_across_threads() {
    let export = |threads: usize| {
        let mlp = Mlp::new(2, &[8, 8], true, 11);
        let mut tr = NativeTrainer::new(mlp, None, 2, 0.05, 6, tableau::bosh3(), 0.05)
            .with_threads(threads);
        tr.enable_recording();
        let mut rng = Pcg::new(3);
        let x0: Vec<f32> = (0..40 * 2).map(|_| rng.range(-1.0, 1.0)).collect();
        let targets: Vec<f32> = x0.iter().map(|v| 0.5 * v).collect();
        for _ in 0..2 {
            tr.step_mse(&x0, &targets);
        }
        let rec = tr.take_recorder();
        assert!(!rec.events().is_empty(), "train trace must record events");
        let mut doc = TraceDoc::new();
        doc.add_process(0, "train/native", &rec);
        (doc.to_ndjson(), doc.hash())
    };
    let (base, base_hash) = export(1);
    let base_report = report_text(&base);
    for threads in [2usize, 4] {
        let (ndjson, hash) = export(threads);
        assert_eq!(ndjson, base, "threads={threads}");
        assert_eq!(hash, base_hash, "threads={threads}");
        assert_eq!(report_text(&ndjson), base_report, "report threads={threads}");
    }
}

#[test]
fn serve_trace_ndjson_bit_identical_across_threads_and_round_trips() {
    let export = |recs: &[(String, Recorder)]| {
        let mut doc = TraceDoc::new();
        for (pid, (name, rec)) in recs.iter().enumerate() {
            doc.add_process(pid as u64, name, rec);
        }
        (doc.to_ndjson(), doc.hash())
    };
    let (_, srecs) = run_poisson_traced(17, 6, 2.5, 24);
    let (base, base_hash) = export(&srecs);
    let base_report = report_text(&base);
    for threads in [1usize, 2, 4] {
        let pool = Pool::new(threads);
        let (_, precs) = run_poisson_traced_pooled(&pool, 17, 6, 2.5, 24);
        let (ndjson, hash) = export(&precs);
        assert_eq!(ndjson, base, "threads={threads}");
        assert_eq!(hash, base_hash, "threads={threads}");
        assert_eq!(report_text(&ndjson), base_report, "report threads={threads}");
    }
    // Every exported line is strict, canonical JSON.
    let parsed = parse_ndjson(&base).expect("trace must round-trip");
    assert_eq!(parsed.len(), base.lines().count());
}

#[test]
fn trace_view_rejects_adversarial_traces_naming_lines() {
    // An `E` with no open `B` on its lane: rejected, naming the E's line.
    let orphan_end = concat!(
        r#"{"args":{"name":"x"},"name":"process_name","ph":"M","pid":0,"tid":0}"#,
        "\n",
        r#"{"args":{},"name":"step","ph":"E","pid":0,"tid":3,"ts":7}"#,
        "\n",
    );
    let err = TraceView::parse(orphan_end).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("ndjson line 2"), "{msg}");
    assert!(msg.contains("no open begin"), "{msg}");

    // A `B` left unclosed at end of input: rejected, naming the B's line.
    let unclosed = concat!(
        r#"{"args":{},"name":"step","ph":"B","pid":0,"tid":0,"ts":1}"#,
        "\n",
    );
    let err = TraceView::parse(unclosed).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("ndjson line 1"), "{msg}");
    assert!(msg.contains("never closed"), "{msg}");

    // Two `process_name` records for one pid: rejected at the second.
    let dup = concat!(
        r#"{"args":{"name":"a"},"name":"process_name","ph":"M","pid":4,"tid":0}"#,
        "\n",
        r#"{"args":{"name":"b"},"name":"process_name","ph":"M","pid":4,"tid":0}"#,
        "\n",
    );
    let err = TraceView::parse(dup).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("ndjson line 2"), "{msg}");
    assert!(msg.contains("duplicate process_name"), "{msg}");

    // An unknown phase letter: rejected, named.
    let unknown = r#"{"args":{},"name":"z","ph":"Q","pid":0,"tid":0,"ts":0}"#;
    let err = TraceView::parse(unknown).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("ndjson line 1"), "{msg}");
    assert!(msg.contains("unknown trace phase"), "{msg}");

    // A negative timestamp: rejected (fields must be finite and >= 0).
    let negative = r#"{"args":{},"dur":1,"name":"s","ph":"X","pid":0,"tid":0,"ts":-3}"#;
    assert!(TraceView::parse(negative).is_err());
}

#[test]
fn ndjson_parser_rejects_corrupt_traces_with_line_numbers() {
    assert!(parse_ndjson("{\"name\":\"x\"}\n{truncated").is_err());
    let err = parse_ndjson("{}\nnot json\n").unwrap_err();
    assert!(format!("{err:#}").contains("ndjson line 2"), "{err:#}");
}
