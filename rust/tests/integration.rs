//! Cross-module integration tests over real artifacts (skipped when
//! `make artifacts` has not run).  These exercise the full L3 stack:
//! manifest -> PJRT compile -> trainer -> adaptive solvers -> metrics.

use std::path::Path;

use taynode::coordinator::evaluator;
use taynode::coordinator::{BatchInputs, Trainer};
use taynode::data::{synth_mnist, Batcher, Dataset};
use taynode::runtime::Runtime;
use taynode::solvers::adaptive::AdaptiveOpts;
use taynode::solvers::tableau;
use taynode::util::rng::Pcg;

fn runtime() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        // Runtime::load always errors in stub builds; skip even when a
        // previous pjrt build left artifacts behind.
        return None;
    }
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json")
        .exists()
        .then(|| Runtime::load(&p).unwrap())
}

#[test]
fn manifest_covers_every_model_and_file() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.models.len() >= 5);
    assert!(rt.manifest.executables.len() >= 40);
    for e in rt.manifest.executables.values() {
        assert!(rt.manifest.dir.join(&e.file).exists(), "{}", e.file);
        assert!(rt.manifest.models.contains_key(&e.model), "{}", e.model);
    }
}

#[test]
fn params_blob_matches_layout() {
    let Some(rt) = runtime() else { return };
    for name in rt.manifest.models.keys() {
        let vals = rt.load_params(name).unwrap();
        let spec = rt.manifest.model(name).unwrap();
        assert_eq!(vals.len(), spec.layout.len());
        for (v, e) in vals.iter().zip(&spec.layout) {
            assert_eq!(v.len(), e.size, "{name}:{}", e.name);
            assert!(v.iter().all(|x| x.is_finite()));
        }
    }
}

#[test]
fn mnist_train_and_adaptive_eval() {
    let Some(rt) = runtime() else { return };
    let mut tr = Trainer::new(&rt, "mnist_train_k2_s2", 0).unwrap();
    let hyper = rt.manifest.model("mnist").unwrap().hyper.clone();
    let b = hyper.usize_of("batch").unwrap();
    let d = hyper.usize_of("d").unwrap();
    let ds = synth_mnist::generate(4 * b, 7);
    let data = Dataset::new(ds.images, d).with_labels(ds.labels);
    let mut batcher = Batcher::new(&data, b, 0);

    let mut losses = vec![];
    for _ in 0..8 {
        let bt = batcher.next();
        let inputs = BatchInputs::default().f("x", bt.x).i("labels", bt.labels);
        let m = tr.step(&inputs, 0.01, 0.1).unwrap();
        assert!(m.loss().is_finite());
        losses.push(m.loss());
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "{losses:?}"
    );

    // adaptive evaluation: NFE + head metrics + instrumented quantities
    let bt = batcher.next();
    let tb = tableau::dopri5();
    let opts = AdaptiveOpts::default();
    let ev = evaluator::mnist_eval(&rt, &tr.store, &bt.x, &bt.labels, &tb, &opts)
        .unwrap();
    assert!(ev.nfe >= 7, "nfe {}", ev.nfe);
    assert!(ev.ce.is_finite() && ev.err_rate <= 1.0);

    let mut rng = Pcg::new(3);
    let probe = rng.rademacher(b * d);
    let rq = evaluator::mnist_reg_quantities(&rt, &tr.store, &bt.x, &probe,
                                             &tb, &opts)
        .unwrap();
    // R_1 is the kinetic energy — identical definitions must agree.
    assert!(
        (rq.r[0] - rq.kinetic).abs() < 1e-3 * (1.0 + rq.kinetic.abs()),
        "{rq:?}"
    );
    assert!(rq.r.iter().all(|v| *v >= 0.0));
}

#[test]
fn pallas_and_jnp_dynamics_artifacts_agree() {
    let Some(rt) = runtime() else { return };
    let tr = Trainer::new(&rt, "mnist_train_unreg_s2", 0).unwrap();
    let store = &tr.store;
    use taynode::runtime::XlaDynamics;
    use taynode::solvers::Dynamics;
    let mut a = XlaDynamics::from_store(&rt, "mnist_dynamics", store, None).unwrap();
    let mut b = XlaDynamics::from_store(&rt, "mnist_dynamics_pallas", store, None).unwrap();
    let mut rng = Pcg::new(5);
    let n = a.state_len();
    let y: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
    let mut da = vec![0.0; n];
    let mut db = vec![0.0; n];
    a.eval(0.3, &y, &mut da);
    b.eval(0.3, &y, &mut db);
    for (x, z) in da.iter().zip(&db) {
        assert!((x - z).abs() < 1e-4, "{x} vs {z}");
    }
}

#[test]
fn cnf_eval_runs_and_latent_eval_runs() {
    let Some(rt) = runtime() else { return };
    // CNF tabular
    let mut tr = Trainer::new(&rt, "cnf_tab_train_k2_s4", 0).unwrap();
    let hyper = rt.manifest.model("cnf_tab").unwrap().hyper.clone();
    let (b, d) = (hyper.usize_of("batch").unwrap(), hyper.usize_of("d").unwrap());
    let gen = taynode::data::miniboone_sim::TabularGen::new(d, 3, 1);
    let sample = gen.sample(b, 2);
    let mut rng = Pcg::new(4);
    let inputs = BatchInputs::default().f("x", sample.x.clone());
    for _ in 0..3 {
        let m = tr.step(&inputs, 0.01, 1e-3).unwrap();
        assert!(m.loss().is_finite());
    }
    let probe = rng.rademacher(b * d);
    let tb = tableau::dopri5();
    let ev = evaluator::cnf_eval(&rt, "cnf_tab", &tr.store, &sample.x, &probe,
                                 &tb, &AdaptiveOpts::default())
        .unwrap();
    assert!(ev.nfe > 0 && ev.nll.is_finite() && ev.r2 >= 0.0);

    // Latent ODE
    let mut ltr = Trainer::new(&rt, "latent_train_k2", 0).unwrap();
    let lh = rt.manifest.model("latent").unwrap().hyper.clone();
    let (lb, lt, lf) = (
        lh.usize_of("batch").unwrap(),
        lh.usize_of("t").unwrap(),
        lh.usize_of("f").unwrap(),
    );
    let pg = taynode::data::physionet_sim::PhysioGen::new(lf, 3);
    let pd = pg.sample(lb, lt, 1);
    let linputs = BatchInputs::default().f("x", pd.x.clone()).f("mask", pd.mask.clone());
    for _ in 0..2 {
        let m = ltr.step(&linputs, 0.001, 1e-2).unwrap();
        assert!(m.loss().is_finite());
    }
    let lev = evaluator::latent_eval(&rt, &ltr.store, &pd.x, &pd.mask, lt, &tb,
                                     &AdaptiveOpts::default())
        .unwrap();
    assert!(lev.nfe > 0 && lev.mse.is_finite());
}

#[test]
fn native_training_end_to_end_without_artifacts() {
    // The native subsystem needs no runtime: MLP dynamics, discrete
    // adjoint, Adam, then adaptive evaluation through the batched engine —
    // the full train-then-measure loop of the paper, in the stub build.
    use taynode::coordinator::train_native::NativeTrainer;
    use taynode::nn::Mlp;

    let mut rng = Pcg::new(3);
    let x0: Vec<f32> = (0..12).map(|_| rng.range(-1.0, 1.0)).collect();
    let targets: Vec<f32> = x0.iter().map(|x| x + x * x * x).collect();
    let mlp = Mlp::new(1, &[8], true, 1);
    let mut tr = NativeTrainer::new(mlp, None, 2, 0.5, 4, tableau::rk4(), 0.02);
    let first = tr.step_mse(&x0, &targets);
    let mut last = first.clone();
    for _ in 0..20 {
        last = tr.step_mse(&x0, &targets);
    }
    assert!(first.loss.is_finite() && last.loss.is_finite());
    assert!(last.nfe > 0);
    let ev = tr.eval_rk(&x0, &tableau::dopri5(), &AdaptiveOpts::default());
    assert_eq!(ev.r_k.len(), 12);
    assert!(ev.stats.iter().all(|s| s.nfe > 0));
    assert!(ev.y.iter().all(|v| v.is_finite()));

    // The training loop must not diverge (a small transient overshoot is
    // tolerated; strict descent is asserted by the train_native tests).
    assert!(
        last.loss <= first.loss * 1.1 + 1e-3,
        "loss diverged over 20 steps: {} -> {}",
        first.loss,
        last.loss
    );
}
