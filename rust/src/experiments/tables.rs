//! Tables 2, 3 and 4: fixed-step-trained variants of each model evaluated
//! with adaptive solvers — Hours(→seconds at this scale), task loss, NFE,
//! and the integrated regularization quantities R_2, B, K.
//!
//! The paper's "∞ steps" rows train with an *adaptive* solver; our exported
//! train steps are fixed-grid (discretize-then-optimize), so those rows are
//! approximated by the finest exported grid and flagged `~inf` (DESIGN.md
//! §3 substitutions).

use anyhow::Result;

use super::common::{self, Scale};
use crate::coordinator::evaluator;
use crate::coordinator::{BatchInputs, Trainer};
use crate::data::Batcher;
use crate::solvers::tableau;
use crate::util::bench::Table;
use crate::util::rng::Pcg;

/// Table 3: MNIST classification.
pub fn table3(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::MnistHarness::new(&rt, scale.data, 31)?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let rows: Vec<(&str, &str, f32)> = vec![
        ("No Regularization", "mnist_train_unreg_s2", 0.0),
        ("No Regularization", "mnist_train_unreg_s8", 0.0),
        ("RNODE", "mnist_train_rnode_s2", 0.03),
        ("RNODE", "mnist_train_rnode_s8", 0.03),
        ("TayNODE (K=2)", "mnist_train_k2_s2", 0.03),
        ("TayNODE (K=2)", "mnist_train_k2_s8", 0.03),
        ("TayNODE (K=3)", "mnist_train_k3_s8", 0.03),
    ];
    let mut table = Table::new(&["method", "steps", "secs", "loss", "NFE", "R_2", "B", "K"]);
    for (label, artifact, lam) in rows {
        let steps = artifact.rsplit("_s").next().unwrap_or("").to_string();
        let t0 = crate::util::clock::Stopwatch::start();
        let (tr, _) = common::train_mnist(&rt, &h, artifact, scale.iters, lam, 1, 0, &tb)?;
        let secs = t0.elapsed_secs();
        let (x, l) = h.eval_batch(&h.train, 0);
        let ev = evaluator::mnist_eval(&rt, &tr.store, &x, &l, &tb, &opts)?;
        let mut rng = Pcg::new(51);
        let probe = rng.rademacher(h.b * h.d);
        let rq = evaluator::mnist_reg_quantities(&rt, &tr.store, &x, &probe, &tb, &opts)?;
        table.row(vec![
            label.to_string(),
            steps,
            format!("{secs:.1}"),
            format!("{:.4}", ev.ce),
            format!("{}", ev.nfe),
            format!("{:.2}", rq.r[1]),
            format!("{:.3}", rq.jacobian),
            format!("{:.3}", rq.kinetic),
        ]);
    }
    Ok(table)
}

/// Tables 2 and 4: FFJORD density estimation (img / tab).
pub fn cnf_table(model: &str, scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::CnfHarness::new(&rt, model, scale.data, 37)?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let steps_list: Vec<usize> = if model == "cnf_img" { vec![5, 8] } else { vec![4, 8, 16] };
    let methods: Vec<(&str, &str, f32)> = vec![
        ("Unregularized", "unreg", 0.0),
        ("RNODE", "rnode", 0.05),
        ("TayNODE (K=2)", "k2", 0.05),
    ];
    let loss_label = if model == "cnf_img" { "bits/dim" } else { "loss(nats)" };
    let mut table = Table::new(&["method", "steps", "secs", loss_label, "NFE", "R_2", "B", "K"]);
    for (label, tag, lam) in methods {
        for &s in &steps_list {
            let artifact = format!("{model}_train_{tag}_s{s}");
            if rt.manifest.exec_spec(&artifact).is_err() {
                continue;
            }
            let (tr, secs, _) = common::train_cnf(&rt, &h, &artifact, scale.iters, lam, 2)?;
            let mut rng = Pcg::new(61);
            let probe = rng.rademacher(h.b * h.d);
            let ev =
                evaluator::cnf_eval(&rt, model, &tr.store, &h.test, &probe, &tb, &opts)?;
            let loss = if model == "cnf_img" { ev.bpd } else { ev.nll };
            table.row(vec![
                label.to_string(),
                format!("{s}"),
                format!("{secs:.1}"),
                if loss.is_finite() { format!("{loss:.3}") } else { "NaN".into() },
                format!("{}", ev.nfe),
                format!("{:.2}", ev.r2),
                format!("{:.3}", ev.jacobian),
                format!("{:.3}", ev.kinetic),
            ]);
        }
    }
    Ok(table)
}

/// Fig 5's CNF-tabular pareto sweep (shares machinery with Table 4).
pub fn fig5_cnf(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::CnfHarness::new(&rt, "cnf_tab", scale.data, 41)?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let lams = [0.0f32, 0.01, 0.05, 0.2, 1.0];
    let mut table = Table::new(&["lambda", "nll", "NFE"]);
    for &lam in &lams[..scale.sweep.min(5)] {
        let tag = if lam == 0.0 { "unreg" } else { "k2" };
        let artifact = format!("cnf_tab_train_{tag}_s8");
        let (tr, _, _) = common::train_cnf(&rt, &h, &artifact, scale.iters, lam, 4)?;
        let mut rng = Pcg::new(71);
        let probe = rng.rademacher(h.b * h.d);
        let ev =
            evaluator::cnf_eval(&rt, "cnf_tab", &tr.store, &h.test, &probe, &tb, &opts)?;
        table.row(vec![
            format!("{lam}"),
            format!("{:.3}", ev.nll),
            format!("{}", ev.nfe),
        ]);
    }
    Ok(table)
}

/// §6.3-style fixed-grid stability probe: does the unregularized model train
/// stably at very few steps?  (Paper: unregularized diverges at 8 steps on
/// MNIST-FFJORD while regularized variants survive.)
pub fn stability_probe(model: &str, steps: usize, iters: usize) -> Result<Vec<(String, bool)>> {
    let rt = common::load_runtime()?;
    let h = common::CnfHarness::new(&rt, model, 256, 43)?;
    let mut out = vec![];
    for tag in ["unreg", "rnode", "k2"] {
        let artifact = format!("{model}_train_{tag}_s{steps}");
        if rt.manifest.exec_spec(&artifact).is_err() {
            continue;
        }
        let mut tr = Trainer::new(&rt, &artifact, 0)?;
        let mut rng = Pcg::new(5);
        let mut ok = true;
        // aggressive lr to expose instability at coarse grids
        for _ in 0..iters {
            let x = h.batch(&mut rng);
            let lam = if tag == "unreg" { 0.0 } else { 0.05 };
            match tr.step(&BatchInputs::default().f("x", x), lam, 5e-3) {
                Ok(m) if m.loss().is_finite() => {}
                _ => {
                    ok = false;
                    break;
                }
            }
        }
        out.push((artifact, ok));
    }
    Ok(out)
}

#[allow(dead_code)]
fn unused(_b: Batcher) {}
