//! Experiment registry: one regenerator per paper table/figure (DESIGN.md
//! §6 experiment index).  Every entry prints the paper-shaped table and is
//! also wrapped by a `benches/` target.

pub mod common;
pub mod density;
pub mod latent_figs;
pub mod mnist_figs;
pub mod native_train;
pub mod orders;
pub mod tables;
pub mod toy_figs;

use anyhow::{bail, Result};

pub use common::Scale;

/// Unique regenerators: fig6 covers fig7, fig8 covers fig10, fig5 covers
/// fig11 and fig12 (shared sweeps printed together).  `native` is the
/// artifact-free λ-sweep through the native training subsystem; `cnf` is
/// its density-estimation counterpart (native CNF, NLL + log-det adjoint).
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig8", "fig9",
    "native", "cnf", "table2", "table3", "table4",
];

/// Run one experiment by paper id, printing its table(s).
pub fn run(id: &str, scale: Scale) -> Result<()> {
    println!("== {id} ==");
    match id {
        "fig1" => toy_figs::fig1(scale)?.print(),
        "fig2" => {
            println!("-- NFE of adaptive solvers on polynomial trajectories --");
            orders::fig2(scale)?.print();
            println!("-- R_K on the same trajectories (batched Taylor jets) --");
            orders::fig2_rk(scale)?.print();
        }
        "fig3" => mnist_figs::fig3(scale)?.print(),
        "fig4" => latent_figs::fig4(scale)?.print(),
        "fig5" => {
            println!("-- mnist (R_2 sweep) --");
            mnist_figs::fig5_mnist(scale)?.print();
            println!("-- cnf tabular (R_2 sweep) --");
            tables::fig5_cnf(scale)?.print();
            println!("-- latent time-series (R_2 sweep) --");
            latent_figs::fig12(scale)?.print();
        }
        "fig6" | "fig7" => {
            // the two figures share one sweep; print both rather than
            // recomputing under each id
            let (f6, f7) = mnist_figs::fig6_fig7(scale)?;
            println!("-- fig6: order-vs-solver tradeoff --");
            f6.print();
            println!("-- fig7: R_K vs NFE --");
            f7.print();
        }
        "fig8" | "fig10" => mnist_figs::fig8_fig10(scale)?.print(),
        "fig9" => toy_figs::fig9(scale)?.print(),
        "native" => {
            let (sweep, corr) = native_train::lambda_sweep_tables(scale)?;
            println!("-- native λ-sweep: toy regression, discrete adjoint --");
            sweep.print();
            println!("-- R_K vs NFE correlation (per-trajectory, per λ) --");
            corr.print();
            println!("-- native synth-MNIST (projected) + classifier head --");
            native_train::mnist_native(scale)?.print();
        }
        "cnf" => {
            println!("-- native CNF λ-sweep: 2-D toy density, NLL + log-det adjoint --");
            density::cnf_lambda_sweep(scale)?.print();
            println!("-- native CNF tabular (miniboone_sim): exact vs Hutchinson --");
            density::cnf_tabular(scale)?.print();
        }
        "fig11" => mnist_figs::fig5_mnist(scale)?.print(),
        "fig12" => latent_figs::fig12(scale)?.print(),
        "table2" => tables::cnf_table("cnf_img", scale)?.print(),
        "table3" => tables::table3(scale)?.print(),
        "table4" => tables::cnf_table("cnf_tab", scale)?.print(),
        "all" => {
            for e in ALL {
                run(e, scale)?;
            }
        }
        other => bail!("unknown experiment {other:?}; known: {ALL:?} or 'all'"),
    }
    Ok(())
}
