//! MNIST-classifier figures:
//!   Fig 3  — NFE and training error *during* training, unreg vs R_3.
//!   Fig 5/11 — pareto: loss (and classification error) vs NFE sweeping λ.
//!   Fig 6  — regularization order K vs solver order m.
//!   Fig 7  — R_K vs NFE monotone relationship.
//!   Fig 8/10 — solver calibration, NFE overfitting, generalization.

use anyhow::Result;

use super::common::{self, Scale};
use crate::coordinator::evaluator;
use crate::runtime::XlaDynamics;
use crate::solvers::adaptive::{solve_adaptive_mut, AdaptiveOpts};
use crate::solvers::tableau;
use crate::util::bench::Table;
use crate::util::rng::Pcg;
use crate::util::stats::{spearman, summarize};

pub fn fig3(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::MnistHarness::new(&rt, scale.data, 11)?;
    let tb = tableau::dopri5();
    let every = (scale.iters / 6).max(1);
    let mut table = Table::new(&["variant", "step", "train_err", "NFE"]);
    for (artifact, lam) in [("mnist_train_unreg_s8", 0.0f32), ("mnist_train_k3_s8", 0.03)] {
        let (_tr, log) = common::train_mnist(&rt, &h, artifact, scale.iters, lam, 0, every, &tb)?;
        log.to_csv(&common::results_dir().join(format!("fig3_{artifact}.csv")))?;
        for row in &log.rows {
            table.row(vec![
                artifact.to_string(),
                format!("{}", row[0] as usize),
                format!("{:.4}", row[5]),
                format!("{}", row[4] as usize),
            ]);
        }
    }
    Ok(table)
}

/// λ sweep on R_2: (λ, final train loss, final NFE, test err) per point.
pub fn mnist_lambda_sweep(
    rt: &crate::runtime::Runtime,
    h: &common::MnistHarness,
    artifact: &str,
    lams: &[f32],
    iters: usize,
) -> Result<Vec<(f32, f64, f64, f64, f64)>> {
    let tb = tableau::dopri5();
    let mut out = vec![];
    for (i, &lam) in lams.iter().enumerate() {
        let (_tr, log) =
            common::train_mnist(rt, h, artifact, iters, lam, 100 + i as u64, iters, &tb)?;
        out.push((
            lam,
            log.last("task"),
            log.last("nfe"),
            log.last("test_err"),
            log.last("train_err"),
        ));
    }
    Ok(out)
}

pub fn fig5_mnist(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::MnistHarness::new(&rt, scale.data, 13)?;
    let lams: Vec<f32> = [0.0, 0.01, 0.03, 0.1, 0.3, 1.0][..scale.sweep.min(6)].to_vec();
    let pts = mnist_lambda_sweep(&rt, &h, "mnist_train_k2_s8", &lams, scale.iters)?;
    let mut table = Table::new(&["lambda", "train_ce", "NFE", "test_err", "train_err"]);
    for (lam, ce, nfe, te, tre) in &pts {
        table.row(vec![
            format!("{lam}"),
            format!("{ce:.4}"),
            format!("{nfe:.0}"),
            format!("{te:.4}"),
            format!("{tre:.4}"),
        ]);
    }
    Ok(table)
}

/// Fig 6 + Fig 7: train each regularization order, evaluate under each
/// solver order; report the (K, solver, NFE, loss, R_K) grid.
pub fn fig6_fig7(scale: Scale) -> Result<(Table, Table)> {
    let rt = common::load_runtime()?;
    let h = common::MnistHarness::new(&rt, scale.data, 17)?;
    let opts = common::eval_opts();
    let mut variants = vec![
        ("unreg", "mnist_train_unreg_s8", 0.0f32),
        ("K=1", "mnist_train_k1_s8", 0.03),
        ("K=2", "mnist_train_k2_s8", 0.03),
        ("K=3", "mnist_train_k3_s8", 0.03),
        ("K=4", "mnist_train_k4_s8", 0.03),
    ];
    if scale.iters < 50 {
        // bench-scale: drop the outer orders, keep the comparison's spine
        variants = vec![variants[0], variants[2], variants[3]];
    }
    let mut fig6 = Table::new(&["reg", "solver(order)", "NFE", "train_ce"]);
    let mut fig7 = Table::new(&["reg", "solver(order)", "R_1", "R_2", "R_3", "R_4", "NFE"]);
    let mut per_solver: Vec<(u32, Vec<f64>, Vec<f64>)> = vec![];
    let dtb = tableau::dopri5();
    for (label, artifact, lam) in variants {
        let (tr, _log) = common::train_mnist(&rt, &h, artifact, scale.iters, lam, 5, 0, &dtb)?;
        let (x, l) = h.eval_batch(&h.train, 0);
        let mut rng = Pcg::new(41);
        let probe = rng.rademacher(h.b * h.d);
        for (si, (sname, order, tb)) in common::solver_suite().into_iter().enumerate() {
            let ev = evaluator::mnist_eval(&rt, &tr.store, &x, &l, &tb, &opts)?;
            fig6.row(vec![
                label.to_string(),
                format!("{sname}({order})"),
                format!("{}", ev.nfe),
                format!("{:.4}", ev.ce),
            ]);
            let rq = evaluator::mnist_reg_quantities(&rt, &tr.store, &x, &probe, &tb, &opts)?;
            fig7.row(vec![
                label.to_string(),
                format!("{sname}({order})"),
                format!("{:.3}", rq.r[0]),
                format!("{:.3}", rq.r[1]),
                format!("{:.3}", rq.r[2]),
                format!("{:.3}", rq.r[3]),
                format!("{}", ev.nfe),
            ]);
            if per_solver.len() <= si {
                per_solver.push((order, vec![], vec![]));
            }
            let k_idx = (order as usize - 1).min(3);
            per_solver[si].1.push(rq.r[k_idx]);
            per_solver[si].2.push(ev.nfe as f64);
        }
    }
    // Fig 7's claim: R_K and NFE vary together (monotone) per solver order.
    for (order, rk, nfe) in &per_solver {
        let rho = spearman(rk, nfe);
        fig7.row(vec![
            format!("spearman(R_m, NFE) order {order}"),
            String::new(),
            format!("{rho:.2}"),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
    }
    Ok((fig6, fig7))
}

/// Fig 8a (solver calibration), 8b + Fig 10 (NFE overfitting), 8c
/// (generalization vs λ — covered by the fig5 sweep's test_err column).
pub fn fig8_fig10(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::MnistHarness::new(&rt, scale.data, 19)?;
    let dtb = tableau::dopri5();
    let mut table = Table::new(&["quantity", "unregularized", "regularized(K=3)"]);

    let mut rows: Vec<Vec<String>> = vec![
        vec!["solver err @rtol1e-3 (vs 1e-7 ref)".into()],
        vec!["train NFE (mean/example)".into()],
        vec!["test NFE (mean/example)".into()],
        vec!["|train-test| NFE".into()],
        vec!["NFE std across examples".into()],
    ];
    for (artifact, lam) in [("mnist_train_unreg_s8", 0.0f32), ("mnist_train_k3_s8", 0.03)] {
        let (tr, _) = common::train_mnist(&rt, &h, artifact, scale.iters, lam, 7, 0, &dtb)?;
        // 8a: actual solve error at loose tolerance vs tight reference
        let (x, _) = h.eval_batch(&h.train, 0);
        let mut dyn_f = XlaDynamics::from_store(&rt, "mnist_dynamics", &tr.store, None)?;
        let loose = AdaptiveOpts { rtol: 1e-3, atol: 1e-5, ..Default::default() };
        let tight = AdaptiveOpts { rtol: 1e-7, atol: 1e-9, ..Default::default() };
        let yl = solve_adaptive_mut(&mut dyn_f, 0.0, 1.0, &x, &dtb, &loose).y;
        let yt = solve_adaptive_mut(&mut dyn_f, 0.0, 1.0, &x, &dtb, &tight).y;
        let err = yl
            .iter()
            .zip(&yt)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            / (yt.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt() + 1e-12);
        rows[0].push(format!("{err:.2e}"));

        // 8b/10: per-example NFE on train vs test
        let n_ex = 24.min(h.b);
        let (xtr, _) = h.eval_batch(&h.train, 0);
        let (xte, _) = h.eval_batch(&h.test, 0);
        let opts = common::eval_opts();
        let tr_nfe = evaluator::mnist_per_example_nfe(
            &rt, &tr.store, &xtr[..n_ex * h.d], &dtb, &opts)?;
        let te_nfe = evaluator::mnist_per_example_nfe(
            &rt, &tr.store, &xte[..n_ex * h.d], &dtb, &opts)?;
        let s_tr = summarize(&tr_nfe.iter().map(|v| *v as f64).collect::<Vec<_>>());
        let s_te = summarize(&te_nfe.iter().map(|v| *v as f64).collect::<Vec<_>>());
        rows[1].push(format!("{:.1}", s_tr.mean));
        rows[2].push(format!("{:.1}", s_te.mean));
        rows[3].push(format!("{:.1}", (s_tr.mean - s_te.mean).abs()));
        rows[4].push(format!("{:.1}", s_tr.std));
    }
    for r in rows {
        table.row(r);
    }
    Ok(table)
}
