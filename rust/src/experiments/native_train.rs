//! Native λ-sweep training experiment — the paper's headline tradeoff
//! (Figs 3–5 direction) reproduced end-to-end **without any XLA
//! artifacts**: train the MLP dynamics with the discrete adjoint on
//! `L = task + λ·R_K`, then evaluate with the adaptive batched engine.
//!
//! Larger λ must buy lower `R_K` and with it fewer adaptive-solver NFE at
//! evaluation, at some task-metric cost — the direction the tables printed
//! here make visible per λ.  Two workloads:
//!
//! * [`lambda_sweep`] — the 1-D toy regression (x ↦ x + x³, Fig 1's task);
//! * [`mnist_native`] — synthetic MNIST through a fixed random projection
//!   (the full 196-dim state is the XLA path's job; the native tape is for
//!   training-subsystem correctness and the λ direction, not peak scale).

use anyhow::Result;

use super::common::{eval_opts, toy_data, Scale};
use crate::coordinator::train_native::{LinearHead, NativeTrainer};
use crate::data::{synth_mnist, Batcher, Dataset};
use crate::nn::Mlp;
use crate::obs::RkNfeTable;
use crate::solvers::tableau;
use crate::util::bench::Table;
use crate::util::rng::Pcg;

/// The λ grid both tables sweep (0 = unregularized baseline).
pub const LAMBDAS: [f32; 4] = [0.0, 0.01, 0.1, 1.0];

fn mean_f64(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut n) = (0.0f64, 0usize);
    for x in xs {
        s += x;
        n += 1;
    }
    s / n.max(1) as f64
}

/// Train the toy model per λ and report the paper-shaped row:
/// final train loss, held-out MSE under the adaptive solver, `R_K`, and
/// the adaptive NFE — the accuracy-vs-cost tradeoff per λ.
pub fn lambda_sweep(scale: Scale) -> Result<Table> {
    Ok(lambda_sweep_tables(scale)?.0)
}

/// [`lambda_sweep`] plus the per-trajectory R_K-vs-NFE correlation table
/// ([`RkNfeTable`]): per λ, does a trajectory's regularizer quadrature
/// actually predict its adaptive solve cost?  A strong positive
/// correlation is the mechanism behind the paper's tradeoff — training
/// pushes `R_K` down and the solver's NFE follows.
pub fn lambda_sweep_tables(scale: Scale) -> Result<(Table, Table)> {
    let mut table = Table::new(&["lambda", "train_loss", "eval_mse", "R_K", "mean NFE"]);
    let mut corr = RkNfeTable::new();
    let b = scale.data.clamp(8, 64);
    let x0 = toy_data(b, 11);
    let targets: Vec<f32> = x0.iter().map(|x| x + x * x * x).collect();
    let x_eval = toy_data(b, 12);
    let t_eval: Vec<f32> = x_eval.iter().map(|x| x + x * x * x).collect();
    let opts = eval_opts();
    let dopri = tableau::dopri5();
    for lam in LAMBDAS {
        let mlp = Mlp::new(1, &[16, 16], true, 42);
        let mut tr = NativeTrainer::new(mlp, None, 2, lam, 8, tableau::rk4(), 0.02);
        let mut last_loss = f32::NAN;
        for _ in 0..scale.iters {
            last_loss = tr.step_mse(&x0, &targets).loss;
        }
        let ev = tr.eval_rk(&x_eval, &dopri, &opts);
        corr.push(lam as f64, &ev.r_k, &ev.stats);
        let mse = mean_f64(
            ev.y
                .iter()
                .zip(&t_eval)
                .map(|(y, t)| (*y as f64 - *t as f64) * (*y as f64 - *t as f64)),
        );
        let nfe = mean_f64(ev.stats.iter().map(|s| s.nfe as f64));
        table.row(vec![
            format!("{lam}"),
            format!("{last_loss:.5}"),
            format!("{mse:.5}"),
            format!("{:.3e}", ev.mean_r_k),
            format!("{nfe:.1}"),
        ]);
    }
    Ok((table, corr.table()))
}

/// Synth-MNIST through a fixed seeded random projection to `d` features,
/// classified by the ODE flow + linear head; λ ∈ {0, 0.1} rows report
/// cross-entropy, error rate, `R_K`, and adaptive NFE on held-out data.
pub fn mnist_native(scale: Scale) -> Result<Table> {
    let d = 16usize;
    let b = 32usize;
    let n = scale.data.max(4 * b);
    let raw = synth_mnist::generate(n, 21);
    // Fixed random projection: the native tape trains a compact state; the
    // full-resolution path stays with the exported XLA artifacts.
    let mut rng = Pcg::new(33);
    let scale_p = 1.0 / (synth_mnist::DIM as f32).sqrt();
    let proj: Vec<f32> = (0..synth_mnist::DIM * d).map(|_| rng.normal() * scale_p).collect();
    let mut x = vec![0.0f32; n * d];
    for r in 0..n {
        let img = &raw.images[r * synth_mnist::DIM..(r + 1) * synth_mnist::DIM];
        for j in 0..d {
            let mut acc = 0.0f32;
            for (i, v) in img.iter().enumerate() {
                acc += v * proj[i * d + j];
            }
            x[r * d + j] = acc;
        }
    }
    let ds = Dataset::new(x, d).with_labels(raw.labels);
    let (train, test) = ds.split(0.25);
    let xt = test.x[..b * d].to_vec();
    let lt = test.labels.as_ref().expect("labels")[..b].to_vec(); // taylint: allow(D4) -- the dataset was built with_labels four lines up
    let opts = eval_opts();
    let dopri = tableau::dopri5();
    let mut table = Table::new(&["lambda", "test_ce", "test_err", "R_K", "mean NFE"]);
    for lam in [0.0f32, 0.1] {
        let mlp = Mlp::new(d, &[32], true, 7);
        let head = LinearHead::new(d, synth_mnist::N_CLASS, 8);
        let mut tr = NativeTrainer::new(mlp, Some(head), 2, lam, 8, tableau::rk4(), 0.01);
        let mut batcher = Batcher::new(&train, b, 5);
        for _ in 0..scale.iters {
            let bt = batcher.next();
            tr.step_ce(&bt.x, &bt.labels);
        }
        let ev = tr.eval_rk(&xt, &dopri, &opts);
        let (ce, err) = tr.head.as_ref().expect("head").metrics(&ev.y, &lt); // taylint: allow(D4) -- the trainer was constructed with Some(head) above
        let nfe = mean_f64(ev.stats.iter().map(|s| s.nfe as f64));
        table.row(vec![
            format!("{lam}"),
            format!("{ce:.4}"),
            format!("{err:.3}"),
            format!("{:.3e}", ev.mean_r_k),
            format!("{nfe:.1}"),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_sweep_smoke_shape() {
        // Micro scale: the table machinery, training loop, and adaptive
        // eval all run without artifacts; one row per λ.
        let t = lambda_sweep(Scale { iters: 2, sweep: 1, data: 8 }).unwrap();
        assert_eq!(t.row_count(), LAMBDAS.len());
    }

    #[test]
    fn lambda_sweep_correlation_table_has_a_row_per_lambda() {
        // The R_K-vs-NFE attribution table rides the same sweep: one
        // correlation row per λ, built from the per-trajectory eval stats.
        let (sweep, corr) = lambda_sweep_tables(Scale { iters: 2, sweep: 1, data: 8 }).unwrap();
        assert_eq!(sweep.row_count(), LAMBDAS.len());
        assert_eq!(corr.row_count(), LAMBDAS.len());
    }
}
