//! Shared harness for the experiment regenerators: dataset construction,
//! train-loop drivers with periodic adaptive-NFE evaluation, and result
//! persistence.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::evaluator;
use crate::coordinator::{BatchInputs, MetricsLog, Schedule, Trainer};
use crate::data::{miniboone_sim, physionet_sim, synth_mnist, Batcher, Dataset};
use crate::runtime::Runtime;
use crate::solvers::adaptive::AdaptiveOpts;
use crate::solvers::tableau::{self, Tableau};
use crate::util::rng::Pcg;

/// Experiment scale: `full` regenerates the paper artifacts; `quick` is the
/// bench-harness setting (same code, smaller budgets).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub iters: usize,
    pub sweep: usize,
    pub data: usize,
}

impl Scale {
    pub fn full() -> Scale {
        Scale { iters: 240, sweep: 5, data: 640 }
    }

    pub fn quick() -> Scale {
        Scale { iters: 30, sweep: 3, data: 256 }
    }
}

pub fn results_dir() -> PathBuf {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&p);
    p
}

pub fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn load_runtime() -> Result<Runtime> {
    Runtime::load(&artifacts_dir())
}

/// Evaluation tolerance: the paper uses 1.4e-8 in f64; the tightest
/// productive setting for f32 states is ~1e-6 relative (below that the
/// error estimate drowns in roundoff and NFE saturates).
pub fn eval_opts() -> AdaptiveOpts {
    AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() }
}

// ---------------------------------------------------------------------------
// MNIST harness
// ---------------------------------------------------------------------------

pub struct MnistHarness {
    pub b: usize,
    pub d: usize,
    pub train: Dataset,
    pub test: Dataset,
}

impl MnistHarness {
    pub fn new(rt: &Runtime, n: usize, seed: u64) -> Result<MnistHarness> {
        let hyper = rt.manifest.model("mnist")?.hyper.clone();
        let b = hyper.usize_of("batch")?;
        let d = hyper.usize_of("d")?;
        let raw = synth_mnist::generate(n.max(3 * b), seed);
        let ds = Dataset::new(raw.images, d).with_labels(raw.labels);
        let (train, test) = ds.split(0.25);
        Ok(MnistHarness { b, d, train, test })
    }

    pub fn eval_batch<'a>(&'a self, which: &'a Dataset, idx: usize) -> (Vec<f32>, Vec<i32>) {
        let start = (idx * self.b) % (which.n - self.b + 1);
        let x = which.x[start * self.d..(start + self.b) * self.d].to_vec();
        let l = which.labels.as_ref().unwrap()[start..start + self.b].to_vec(); // taylint: allow(D4) -- the harness constructor always attaches labels
        (x, l)
    }
}

/// Train an MNIST artifact for `iters` steps; if `eval_every > 0`, record
/// (step, loss, ce, reg, nfe, train_err, test_err) via adaptive evaluation.
pub fn train_mnist<'rt>(
    rt: &'rt Runtime,
    harness: &MnistHarness,
    artifact: &str,
    iters: usize,
    lam: f32,
    seed: u64,
    eval_every: usize,
    tb: &Tableau,
) -> Result<(Trainer<'rt>, MetricsLog)> {
    let mut tr = Trainer::new(rt, artifact, seed)?;
    let mut batcher = Batcher::new(&harness.train, harness.b, seed ^ 0xb17c);
    let lr = Schedule::mnist_lr(0.1, iters);
    let mut log = MetricsLog::new(&[
        "step", "loss", "task", "reg", "nfe", "train_err", "test_err",
    ]);
    let opts = eval_opts();
    for it in 0..iters {
        let bt = batcher.next();
        let inputs = BatchInputs::default().f("x", bt.x).i("labels", bt.labels);
        let m = tr.step(&inputs, lam, lr.at(it))?;
        let do_eval = eval_every > 0 && (it % eval_every == 0 || it == iters - 1);
        if do_eval {
            let (x, l) = harness.eval_batch(&harness.train, 0);
            let ev = evaluator::mnist_eval(rt, &tr.store, &x, &l, tb, &opts)?;
            let (xt, lt) = harness.eval_batch(&harness.test, 0);
            let et = evaluator::mnist_eval(rt, &tr.store, &xt, &lt, tb, &opts)?;
            log.push_step(it, &m, &[ev.nfe as f64, ev.err_rate as f64, et.err_rate as f64]);
        }
    }
    Ok((tr, log))
}

// ---------------------------------------------------------------------------
// CNF harness
// ---------------------------------------------------------------------------

pub struct CnfHarness {
    pub model: String,
    pub b: usize,
    pub d: usize,
    pub train: Vec<f32>,
    pub test: Vec<f32>,
}

impl CnfHarness {
    pub fn new(rt: &Runtime, model: &str, n: usize, seed: u64) -> Result<CnfHarness> {
        let hyper = rt.manifest.model(model)?.hyper.clone();
        let b = hyper.usize_of("batch")?;
        let d = hyper.usize_of("d")?;
        let n = n.max(2 * b);
        let x = if model == "cnf_img" {
            miniboone_sim::image_density(n, (d as f64).sqrt() as usize, seed).x
        } else {
            miniboone_sim::TabularGen::new(d, 3, seed).sample(n, seed ^ 1).x
        };
        let cut = (n - b) * d;
        Ok(CnfHarness {
            model: model.to_string(),
            b,
            d,
            train: x[..cut].to_vec(),
            test: x[cut..].to_vec(),
        })
    }

    pub fn batch(&self, rng: &mut Pcg) -> Vec<f32> {
        let n = self.train.len() / self.d;
        let mut out = Vec::with_capacity(self.b * self.d);
        for _ in 0..self.b {
            let i = rng.below(n);
            out.extend_from_slice(&self.train[i * self.d..(i + 1) * self.d]);
        }
        out
    }
}

/// Train a CNF artifact; returns (trainer, seconds, final-loss).
pub fn train_cnf<'rt>(
    rt: &'rt Runtime,
    harness: &CnfHarness,
    artifact: &str,
    iters: usize,
    lam: f32,
    seed: u64,
) -> Result<(Trainer<'rt>, f64, f32)> {
    let mut tr = Trainer::new(rt, artifact, seed)?;
    let mut rng = Pcg::new(seed ^ 0xc4f);
    let t0 = crate::util::clock::Stopwatch::start();
    let mut last = f32::NAN;
    for _ in 0..iters {
        let x = harness.batch(&mut rng);
        let m = tr.step(&BatchInputs::default().f("x", x), lam, 1e-3)?;
        last = m.loss();
    }
    Ok((tr, t0.elapsed_secs(), last))
}

// ---------------------------------------------------------------------------
// Latent-ODE harness
// ---------------------------------------------------------------------------

pub struct LatentHarness {
    pub b: usize,
    pub t: usize,
    pub f: usize,
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub x_test: Vec<f32>,
    pub mask_test: Vec<f32>,
}

impl LatentHarness {
    pub fn new(rt: &Runtime, seed: u64) -> Result<LatentHarness> {
        let hyper = rt.manifest.model("latent")?.hyper.clone();
        let b = hyper.usize_of("batch")?;
        let t = hyper.usize_of("t")?;
        let f = hyper.usize_of("f")?;
        let gen = physionet_sim::PhysioGen::new(f, seed);
        let tr = gen.sample(b, t, seed ^ 2);
        let te = gen.sample(b, t, seed ^ 3);
        Ok(LatentHarness {
            b,
            t,
            f,
            x: tr.x,
            mask: tr.mask,
            x_test: te.x,
            mask_test: te.mask,
        })
    }
}

pub fn train_latent<'rt>(
    rt: &'rt Runtime,
    h: &LatentHarness,
    artifact: &str,
    iters: usize,
    lam: f32,
    seed: u64,
) -> Result<(Trainer<'rt>, f32)> {
    let mut tr = Trainer::new(rt, artifact, seed)?;
    let inputs = BatchInputs::default()
        .f("x", h.x.clone())
        .f("mask", h.mask.clone());
    let mut last = f32::NAN;
    for _ in 0..iters {
        let m = tr.step(&inputs, lam, 5e-3)?;
        last = m.loss();
    }
    Ok((tr, last))
}

// ---------------------------------------------------------------------------
// Toy harness
// ---------------------------------------------------------------------------

pub fn toy_data(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.range(-1.2, 1.2)).collect()
}

pub fn train_toy<'rt>(
    rt: &'rt Runtime,
    artifact: &str,
    iters: usize,
    lam: f32,
    seed: u64,
) -> Result<(Trainer<'rt>, f32)> {
    let mut tr = Trainer::new(rt, artifact, seed)?;
    let x = toy_data(128, seed ^ 9);
    let inputs = BatchInputs::default().f("x", x);
    let mut last = f32::NAN;
    for _ in 0..iters {
        let m = tr.step(&inputs, lam, 0.05)?;
        last = m.loss();
    }
    Ok((tr, last))
}

/// Solver lookup shared by experiments that sweep solver orders.
pub fn solver_suite() -> Vec<(&'static str, u32, Tableau)> {
    vec![
        ("heun_euler", 2, tableau::heun_euler()),
        ("bosh3", 3, tableau::bosh3()),
        ("dopri5", 5, tableau::dopri5()),
    ]
}
