//! Native density-estimation experiments (`repro experiment cnf`) — the
//! paper's FFJORD tradeoff (Tables 2/4 direction) reproduced end-to-end
//! **without any XLA artifacts**: train a concat-squash CNF on the exact
//! NLL with the log-det discrete adjoint, then evaluate with the adaptive
//! batched engine.
//!
//! Larger λ must buy lower `R_K` and with it fewer adaptive-solver NFE at
//! evaluation, at some NLL cost — the tradeoff each table row makes
//! visible.  Two workloads:
//!
//! * [`cnf_lambda_sweep`] — the 2-D toy densities
//!   ([`toy_density`](crate::data::toy_density));
//! * [`cnf_tabular`] — the synthetic MINIBOONE substitute
//!   ([`miniboone_sim`](crate::data::miniboone_sim)), with exact-trace and
//!   Hutchinson-estimator evaluation rows side by side.

use anyhow::Result;

use super::common::{eval_opts, Scale};
use super::native_train::LAMBDAS;
use crate::autodiff::div::Divergence;
use crate::coordinator::evaluator::cnf_nll_eval_pooled;
use crate::coordinator::train_native::NativeCnfTrainer;
use crate::data::miniboone_sim::TabularGen;
use crate::data::toy_density;
use crate::nn::Cnf;
use crate::solvers::tableau;
use crate::util::bench::Table;
use crate::util::pool::Pool;

fn mean_nfe(stats: &[crate::solvers::adaptive::SolveStats]) -> f64 {
    stats.iter().map(|s| s.nfe as f64).sum::<f64>() / stats.len().max(1) as f64
}

/// Train the 2-D toy-density CNF per λ and report the paper-shaped row:
/// final train NLL, held-out NLL under the adaptive solver, `R_K`, and the
/// adaptive NFE — larger λ should walk NFE down while NLL degrades
/// gracefully.
pub fn cnf_lambda_sweep(scale: Scale) -> Result<Table> {
    let mut table = Table::new(&["lambda", "train_nll", "eval_nll", "R_K", "mean NFE"]);
    let b = scale.data.clamp(16, 64);
    let x = toy_density::sample("two_gaussians", b, 11);
    let x_eval = toy_density::sample("two_gaussians", b, 12);
    let opts = eval_opts();
    let dopri = tableau::dopri5();
    for lam in LAMBDAS {
        let cnf = Cnf::new(2, &[16], 42);
        let mut tr = NativeCnfTrainer::new(cnf, 2, lam, 8, tableau::rk4(), 0.02);
        let mut last_nll = f32::NAN;
        for _ in 0..scale.iters {
            last_nll = tr.step_nll(&x).task;
        }
        let ev = tr.eval_nll(&x_eval, &dopri, &opts);
        table.row(vec![
            format!("{lam}"),
            format!("{last_nll:.4}"),
            format!("{:.4}", ev.nll),
            format!("{:.3e}", ev.mean_r_k),
            format!("{:.1}", mean_nfe(&ev.stats)),
        ]);
    }
    Ok(table)
}

/// The tabular workload (synthetic MINIBOONE, d = 5): λ ∈ {0, 0.1} rows,
/// each evaluated with the exact trace AND the fixed-seed Hutchinson
/// estimator — same flow, same NFE mechanics, estimator noise only in the
/// log-det column (the stub-vs-pjrt story's native half).
pub fn cnf_tabular(scale: Scale) -> Result<Table> {
    let d = 5usize;
    let b = 32usize;
    let tabgen = TabularGen::new(d, 4, 3);
    let train = tabgen.sample(b.max(scale.data.min(96)), 5);
    let test = tabgen.sample(b, 6);
    let opts = eval_opts();
    let dopri = tableau::dopri5();
    let iters = scale.iters.min(120);
    let mut table = Table::new(&["lambda", "divergence", "test_nll", "R_K", "mean NFE"]);
    for lam in [0.0f32, 0.1] {
        let cnf = Cnf::new(d, &[16], 7);
        let mut tr = NativeCnfTrainer::new(cnf, 2, lam, 6, tableau::rk4(), 0.01);
        for _ in 0..iters {
            tr.step_nll(&train.x);
        }
        for (tag, div) in [
            ("exact", Divergence::Exact),
            ("hutch-1", Divergence::Hutchinson { probes: 1, seed: 61 }),
        ] {
            let ev = cnf_nll_eval_pooled(
                &Pool::from_env(),
                &tr.cnf,
                tr.order,
                &div,
                &test.x,
                &dopri,
                &opts,
            );
            table.row(vec![
                format!("{lam}"),
                tag.into(),
                format!("{:.4}", ev.nll),
                format!("{:.3e}", ev.mean_r_k),
                format!("{:.1}", mean_nfe(&ev.stats)),
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnf_lambda_sweep_smoke_shape() {
        // Micro scale: the table machinery, CNF training loop, and
        // adaptive NLL eval all run without artifacts; one row per λ.
        let t = cnf_lambda_sweep(Scale { iters: 2, sweep: 1, data: 16 }).unwrap();
        assert_eq!(t.row_count(), LAMBDAS.len());
    }
}
