//! Fig 2: which orders of (adaptive) Runge-Kutta solvers can efficiently
//! solve which orders of polynomial trajectories.
//!
//! Pure-Rust experiment: dynamics dz/dt = p'(t) with deg p = K give
//! trajectories whose total derivatives of order > K vanish identically
//! (verified by the `taylor` module's jet).  An order-m adaptive solver's
//! local error model is exact on them when K <= m, so its error estimate is
//! ~0 and it takes maximal steps; for K > m the step count grows — the
//! paper's lower-triangle heatmap.

use anyhow::Result;

use super::common::Scale;
use crate::solvers::adaptive::{solve_adaptive, AdaptiveOpts};
use crate::solvers::tableau;
use crate::util::bench::Table;
use crate::util::rng::Pcg;

/// NFE needed by `solver` on a random polynomial trajectory of degree `k`.
pub fn poly_nfe(solver: &tableau::Tableau, k: usize, seed: u64) -> usize {
    let mut rng = Pcg::new(seed);
    // coefficients of p'(t): degree k-1 (k = 0 -> zero dynamics)
    let coeffs: Vec<f32> = (0..k).map(|_| rng.range(0.5, 2.0)).collect();
    let opts = AdaptiveOpts {
        rtol: 1e-6,
        atol: 1e-8,
        h_init: Some(0.05),
        ..Default::default()
    };
    let res = solve_adaptive(
        move |t: f32, _y: &[f32], dy: &mut [f32]| {
            let mut acc = 0.0f32;
            for (i, c) in coeffs.iter().enumerate() {
                acc += (i as f32 + 1.0) * c * t.powi(i as i32);
            }
            dy[0] = acc;
        },
        0.0,
        1.0,
        &[0.0f32],
        solver,
        &opts,
    );
    res.stats.nfe
}

pub fn fig2(_scale: Scale) -> Result<Table> {
    let solvers = [
        ("heun_euler(2)", tableau::heun_euler()),
        ("bosh3(3)", tableau::bosh3()),
        ("fehlberg(4)", tableau::fehlberg45()),
        ("cash_karp(5)", tableau::cash_karp()),
        ("dopri5(5)", tableau::dopri5()),
    ];
    let degrees: Vec<usize> = (0..=8).collect();
    let mut headers: Vec<String> = vec!["solver \\ poly K".to_string()];
    headers.extend(degrees.iter().map(|k| format!("K={k}")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);
    for (name, tb) in &solvers {
        let mut row = vec![name.to_string()];
        for &k in &degrees {
            // median over seeds for stability
            let mut nfes: Vec<usize> =
                (0..5).map(|s| poly_nfe(tb, k, 31 + s)).collect();
            nfes.sort_unstable();
            row.push(format!("{}", nfes[2]));
        }
        table.row(row);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_degree_cheap_high_degree_expensive() {
        // The paper's Fig 2 structure: for an order-m solver, NFE jumps
        // once the trajectory's polynomial order exceeds m.
        let tb = tableau::bosh3(); // order 3
        let cheap = poly_nfe(&tb, 2, 1);
        let expensive = poly_nfe(&tb, 7, 1);
        assert!(
            expensive > cheap,
            "bosh3: deg7 {expensive} !> deg2 {cheap}"
        );
        let tb5 = tableau::dopri5();
        let cheap5 = poly_nfe(&tb5, 4, 1);
        let exp5 = poly_nfe(&tb5, 8, 1);
        assert!(exp5 > cheap5, "dopri5: {exp5} !> {cheap5}");
    }
}
