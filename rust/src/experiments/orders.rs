//! Fig 2: which orders of (adaptive) Runge-Kutta solvers can efficiently
//! solve which orders of polynomial trajectories.
//!
//! Pure-Rust experiment: dynamics dz/dt = p'(t) with deg p = K give
//! trajectories whose total derivatives of order > K vanish identically
//! (verified by the `taylor` module's jet).  An order-m adaptive solver's
//! local error model is exact on them when K <= m, so its error estimate is
//! ~0 and it takes maximal steps; for K > m the step count grows — the
//! paper's lower-triangle heatmap.

use anyhow::Result;

use super::common::Scale;
use crate::coordinator::evaluator::batch_rk_eval_pooled;
use crate::solvers::adaptive::{solve_adaptive, AdaptiveOpts};
use crate::solvers::batch::{solve_adaptive_batch_pooled, BatchDynamics};
use crate::solvers::tableau;
use crate::taylor::{BatchSeriesDynamics, SeriesVec};
use crate::util::bench::Table;
use crate::util::pool::Pool;
use crate::util::rng::Pcg;

/// Coefficients of p'(t) for one seeded trajectory: degree k-1 (k = 0 ->
/// zero dynamics).  Shared by the scalar reference and the batched sweep so
/// the two stay bit-identical by construction.
fn poly_coeffs(k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..k).map(|_| rng.range(0.5, 2.0)).collect()
}

/// The fig2 solver setting (also shared scalar/batched).
fn fig2_opts() -> AdaptiveOpts {
    AdaptiveOpts {
        rtol: 1e-6,
        atol: 1e-8,
        h_init: Some(0.05),
        ..Default::default()
    }
}

/// NFE needed by `solver` on a random polynomial trajectory of degree `k`.
pub fn poly_nfe(solver: &tableau::Tableau, k: usize, seed: u64) -> usize {
    let coeffs = poly_coeffs(k, seed);
    let opts = fig2_opts();
    let res = solve_adaptive(
        move |t: f32, _y: &[f32], dy: &mut [f32]| {
            let mut acc = 0.0f32;
            for (i, c) in coeffs.iter().enumerate() {
                acc += (i as f32 + 1.0) * c * t.powi(i as i32);
            }
            dy[0] = acc;
        },
        0.0,
        1.0,
        &[0.0f32],
        solver,
        &opts,
    );
    res.stats.nfe
}

/// A batch of degree-k polynomial trajectories, one per seed.  Dynamics are
/// conditioned per trajectory (each row has its own coefficients), so the
/// model keys rows on the engine-provided stable `ids` — row position
/// changes as finished trajectories compact out of the working set, and the
/// pooled drivers hand each worker shard global ids.
#[derive(Clone)]
struct PolySweep {
    coeffs: Vec<Vec<f32>>,
}

impl BatchDynamics for PolySweep {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], _y: &[f32], dy: &mut [f32]) {
        for (r, (&id, ts)) in ids.iter().zip(t).enumerate() {
            let mut acc = 0.0f32;
            for (i, c) in self.coeffs[id].iter().enumerate() {
                acc += (i as f32 + 1.0) * c * ts.powi(i as i32);
            }
            dy[r] = acc;
        }
    }
}

/// The series lift of [`PolySweep`]: the same per-seed dynamics
/// dz/dt = p'(t) evaluated on truncated Taylor series, so the fig2
/// trajectories can be jetted **for all seeds at once** by
/// `taylor::ode_jet_batch`.  Rows are keyed on the engine's stable `ids`,
/// exactly like the f32 path; the elementwise series ops apply the scalar
/// operation order, so each row's jet is bit-identical to a scalar one.
impl BatchSeriesDynamics for PolySweep {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, ids: &[usize], _z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
        let ord = t.order();
        let rows = t.rows();
        let terms = ids.iter().map(|id| self.coeffs[*id].len()).max().unwrap_or(0);
        let mut acc = SeriesVec::fill(0.0, rows, 1, ord);
        let mut tp = SeriesVec::fill(1.0, rows, 1, ord);
        for i in 0..terms {
            let scales: Vec<f64> = ids
                .iter()
                .map(|id| {
                    let c = &self.coeffs[*id];
                    if i < c.len() { (i as f64 + 1.0) * c[i] as f64 } else { 0.0 }
                })
                .collect();
            acc = acc.add(&tp.scale_rows(&scales));
            tp = tp.mul(t);
        }
        acc
    }
}

/// Batched variant of [`poly_nfe`]: all seeds of one (solver, degree) cell
/// integrate as one batch with per-trajectory step control, sharded across
/// the `TAYNODE_THREADS` worker pool.  Per-seed NFE is identical to the
/// scalar loop (verified in tests, at any thread count); the sweep costs
/// one solve instead of `seeds.len()`.
pub fn poly_nfe_batch(solver: &tableau::Tableau, k: usize, seeds: &[u64]) -> Vec<usize> {
    let coeffs: Vec<Vec<f32>> = seeds.iter().map(|s| poly_coeffs(k, *s)).collect();
    let y0 = vec![0.0f32; seeds.len()];
    let sweep = PolySweep { coeffs };
    let pool = Pool::from_env();
    let res = solve_adaptive_batch_pooled(&pool, &sweep, 0.0, 1.0, &y0, solver, &fig2_opts());
    res.nfes()
}

/// Per-seed `R_K = ∫‖d^K z/dt^K‖² dt` of the degree-k fig2 trajectories,
/// measured natively: all seeds solve as ONE quadrature-augmented batch
/// through `RegularizedBatchDynamics`/`ode_jet_batch` — no per-row scalar
/// jet loop anywhere on this path (each row is still bit-identical to one;
/// see tests).
pub fn poly_rk_batch(k: usize, seeds: &[u64], order: usize) -> Vec<f32> {
    let coeffs: Vec<Vec<f32>> = seeds.iter().map(|s| poly_coeffs(k, *s)).collect();
    let y0 = vec![0.0f32; seeds.len()];
    let sweep = PolySweep { coeffs };
    let ev = batch_rk_eval_pooled(
        &Pool::from_env(),
        &sweep,
        order,
        0.0,
        1.0,
        &y0,
        &tableau::dopri5(),
        &fig2_opts(),
    );
    ev.r_k
}

pub fn fig2(_scale: Scale) -> Result<Table> {
    let solvers = [
        ("heun_euler(2)", tableau::heun_euler()),
        ("bosh3(3)", tableau::bosh3()),
        ("fehlberg(4)", tableau::fehlberg45()),
        ("cash_karp(5)", tableau::cash_karp()),
        ("dopri5(5)", tableau::dopri5()),
    ];
    let degrees: Vec<usize> = (0..=8).collect();
    let mut headers: Vec<String> = vec!["solver \\ poly K".to_string()];
    headers.extend(degrees.iter().map(|k| format!("K={k}")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);
    let seeds: Vec<u64> = (0..5).map(|s| 31 + s).collect();
    for (name, tb) in &solvers {
        let mut row = vec![name.to_string()];
        for &k in &degrees {
            // median over seeds for stability; all seeds solve as one batch
            let mut nfes = poly_nfe_batch(tb, k, &seeds);
            nfes.sort_unstable();
            row.push(format!("{}", nfes[2]));
        }
        table.row(row);
    }
    Ok(table)
}

/// Companion heatmap to [`fig2`]: the regularizer `R_K` measured on the
/// same random polynomial trajectories (mean over seeds, batched Taylor
/// jets).  A degree-k trajectory has d^K z/dt^K ≡ 0 exactly when K > k, so
/// the matrix is lower-triangular — the quantity the paper's regularizer
/// drives toward zero is literally zero where Fig 2 shows solvers are
/// cheap.
pub fn fig2_rk(_scale: Scale) -> Result<Table> {
    let degrees: Vec<usize> = (0..=8).collect();
    let mut headers: Vec<String> = vec!["R_K \\ traj deg".to_string()];
    headers.extend(degrees.iter().map(|k| format!("deg={k}")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);
    let seeds: Vec<u64> = (0..5).map(|s| 31 + s).collect();
    for order in 1..=5usize {
        let mut row = vec![format!("K={order}")];
        for &k in &degrees {
            let rks = poly_rk_batch(k, &seeds, order);
            let mean = rks.iter().map(|v| *v as f64).sum::<f64>() / rks.len() as f64;
            row.push(format!("{mean:.3e}"));
        }
        table.row(row);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_degree_cheap_high_degree_expensive() {
        // The paper's Fig 2 structure: for an order-m solver, NFE jumps
        // once the trajectory's polynomial order exceeds m.
        let tb = tableau::bosh3(); // order 3
        let cheap = poly_nfe(&tb, 2, 1);
        let expensive = poly_nfe(&tb, 7, 1);
        assert!(
            expensive > cheap,
            "bosh3: deg7 {expensive} !> deg2 {cheap}"
        );
        let tb5 = tableau::dopri5();
        let cheap5 = poly_nfe(&tb5, 4, 1);
        let exp5 = poly_nfe(&tb5, 8, 1);
        assert!(exp5 > cheap5, "dopri5: {exp5} !> {cheap5}");
    }

    #[test]
    fn batched_sweep_matches_scalar_per_seed() {
        // The fig2 conversion must not change any reported number: each
        // seed's NFE from the batched sweep equals its scalar solve.
        let seeds = [31u64, 32, 33];
        for tb in [tableau::bosh3(), tableau::dopri5(), tableau::heun_euler()] {
            for k in [0usize, 2, 5, 8] {
                let batched = poly_nfe_batch(&tb, k, &seeds);
                let scalar: Vec<usize> = seeds.iter().map(|s| poly_nfe(&tb, k, *s)).collect();
                assert_eq!(batched, scalar, "{} k={k}", tb.name);
            }
        }
    }

    /// The old-style per-seed reference: a scalar augmented solve whose
    /// quadrature integrand comes from the scalar `ode_jet`, with the exact
    /// operation sequence the batched series lift applies per row.
    fn poly_rk_scalar(k: usize, seed: u64, order: usize) -> f32 {
        use crate::taylor::{ode_jet, Series};
        let coeffs = poly_coeffs(k, seed);
        let f = |t: f32, y: &[f32], dy: &mut [f32]| {
            let jets = ode_jet(
                |_z: &Series, ts: &Series| {
                    let ord = ts.order();
                    let mut acc = Series::constant(0.0, ord);
                    let mut tp = Series::constant(1.0, ord);
                    for (i, c) in coeffs.iter().enumerate() {
                        acc = acc.add(&tp.scale((i as f64 + 1.0) * *c as f64));
                        tp = tp.mul(ts);
                    }
                    acc
                },
                y[0] as f64,
                t as f64,
                order,
            );
            dy[0] = jets[0] as f32;
            let v = jets[order - 1];
            // mirror the batched integrand ops exactly (n = 1)
            dy[1] = (v * v / 1.0) as f32;
        };
        let res = solve_adaptive(f, 0.0, 1.0, &[0.0f32, 0.0], &tableau::dopri5(), &fig2_opts());
        res.y[1]
    }

    #[test]
    fn batched_rk_matches_scalar_jet_path_per_seed() {
        // Regression pin for the fig2 R_K conversion: every cell value the
        // batched jet/quadrature path reports equals — bit-for-bit — the
        // per-seed scalar-jet solve it replaced.
        let seeds = [31u64, 32, 33];
        for k in [0usize, 1, 3, 6] {
            for order in [1usize, 2, 4] {
                let batched = poly_rk_batch(k, &seeds, order);
                for (r, seed) in seeds.iter().enumerate() {
                    let scalar = poly_rk_scalar(k, *seed, order);
                    assert_eq!(
                        scalar.to_bits(),
                        batched[r].to_bits(),
                        "deg {k} K={order} seed {seed}: {scalar} vs {}",
                        batched[r]
                    );
                }
            }
        }
    }

    #[test]
    fn rk_vanishes_exactly_above_trajectory_degree() {
        // The fig2_rk triangle: a degree-k trajectory has d^K z ≡ 0 for
        // K > k (exactly — polynomial series arithmetic produces true
        // zeros), and strictly positive R_K at K = k.
        let seeds = [31u64, 32, 33];
        for k in [1usize, 2, 4] {
            for v in poly_rk_batch(k, &seeds, k + 1) {
                assert_eq!(v, 0.0, "deg {k}: R_{} must vanish", k + 1);
            }
            for v in poly_rk_batch(k, &seeds, k) {
                assert!(v > 1e-4, "deg {k}: R_{k} suspiciously small: {v}");
            }
        }
    }

    #[test]
    fn rk_linear_trajectory_matches_closed_form() {
        // deg 1: p'(t) = c0, so z(t) = c0·t and R_1 = ∫ c0² dt = c0².
        let seed = 31u64;
        let c0 = poly_coeffs(1, seed)[0] as f64;
        let rk = poly_rk_batch(1, &[seed], 1);
        let want = c0 * c0;
        assert!(
            (rk[0] as f64 - want).abs() < 1e-4 * want.max(1.0),
            "{} vs {want}",
            rk[0]
        );
    }
}
