//! Fig 2: which orders of (adaptive) Runge-Kutta solvers can efficiently
//! solve which orders of polynomial trajectories.
//!
//! Pure-Rust experiment: dynamics dz/dt = p'(t) with deg p = K give
//! trajectories whose total derivatives of order > K vanish identically
//! (verified by the `taylor` module's jet).  An order-m adaptive solver's
//! local error model is exact on them when K <= m, so its error estimate is
//! ~0 and it takes maximal steps; for K > m the step count grows — the
//! paper's lower-triangle heatmap.

use anyhow::Result;

use super::common::Scale;
use crate::solvers::adaptive::{solve_adaptive, AdaptiveOpts};
use crate::solvers::batch::{solve_adaptive_batch, BatchDynamics};
use crate::solvers::tableau;
use crate::util::bench::Table;
use crate::util::rng::Pcg;

/// Coefficients of p'(t) for one seeded trajectory: degree k-1 (k = 0 ->
/// zero dynamics).  Shared by the scalar reference and the batched sweep so
/// the two stay bit-identical by construction.
fn poly_coeffs(k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..k).map(|_| rng.range(0.5, 2.0)).collect()
}

/// The fig2 solver setting (also shared scalar/batched).
fn fig2_opts() -> AdaptiveOpts {
    AdaptiveOpts {
        rtol: 1e-6,
        atol: 1e-8,
        h_init: Some(0.05),
        ..Default::default()
    }
}

/// NFE needed by `solver` on a random polynomial trajectory of degree `k`.
pub fn poly_nfe(solver: &tableau::Tableau, k: usize, seed: u64) -> usize {
    let coeffs = poly_coeffs(k, seed);
    let opts = fig2_opts();
    let res = solve_adaptive(
        move |t: f32, _y: &[f32], dy: &mut [f32]| {
            let mut acc = 0.0f32;
            for (i, c) in coeffs.iter().enumerate() {
                acc += (i as f32 + 1.0) * c * t.powi(i as i32);
            }
            dy[0] = acc;
        },
        0.0,
        1.0,
        &[0.0f32],
        solver,
        &opts,
    );
    res.stats.nfe
}

/// A batch of degree-k polynomial trajectories, one per seed.  Dynamics are
/// conditioned per trajectory (each row has its own coefficients), so the
/// model keys rows on the engine-provided stable `ids` — row position
/// changes as finished trajectories compact out of the working set.
struct PolySweep {
    coeffs: Vec<Vec<f32>>,
}

impl BatchDynamics for PolySweep {
    fn dim(&self) -> usize {
        1
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], _y: &[f32], dy: &mut [f32]) {
        for (r, (&id, ts)) in ids.iter().zip(t).enumerate() {
            let mut acc = 0.0f32;
            for (i, c) in self.coeffs[id].iter().enumerate() {
                acc += (i as f32 + 1.0) * c * ts.powi(i as i32);
            }
            dy[r] = acc;
        }
    }
}

/// Batched variant of [`poly_nfe`]: all seeds of one (solver, degree) cell
/// integrate as one batch with per-trajectory step control.  Per-seed NFE
/// is identical to the scalar loop (verified in tests); the sweep costs one
/// solve instead of `seeds.len()`.
pub fn poly_nfe_batch(solver: &tableau::Tableau, k: usize, seeds: &[u64]) -> Vec<usize> {
    let coeffs: Vec<Vec<f32>> = seeds.iter().map(|s| poly_coeffs(k, *s)).collect();
    let y0 = vec![0.0f32; seeds.len()];
    let res = solve_adaptive_batch(PolySweep { coeffs }, 0.0, 1.0, &y0, solver, &fig2_opts());
    res.nfes()
}

pub fn fig2(_scale: Scale) -> Result<Table> {
    let solvers = [
        ("heun_euler(2)", tableau::heun_euler()),
        ("bosh3(3)", tableau::bosh3()),
        ("fehlberg(4)", tableau::fehlberg45()),
        ("cash_karp(5)", tableau::cash_karp()),
        ("dopri5(5)", tableau::dopri5()),
    ];
    let degrees: Vec<usize> = (0..=8).collect();
    let mut headers: Vec<String> = vec!["solver \\ poly K".to_string()];
    headers.extend(degrees.iter().map(|k| format!("K={k}")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);
    let seeds: Vec<u64> = (0..5).map(|s| 31 + s).collect();
    for (name, tb) in &solvers {
        let mut row = vec![name.to_string()];
        for &k in &degrees {
            // median over seeds for stability; all seeds solve as one batch
            let mut nfes = poly_nfe_batch(tb, k, &seeds);
            nfes.sort_unstable();
            row.push(format!("{}", nfes[2]));
        }
        table.row(row);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_degree_cheap_high_degree_expensive() {
        // The paper's Fig 2 structure: for an order-m solver, NFE jumps
        // once the trajectory's polynomial order exceeds m.
        let tb = tableau::bosh3(); // order 3
        let cheap = poly_nfe(&tb, 2, 1);
        let expensive = poly_nfe(&tb, 7, 1);
        assert!(
            expensive > cheap,
            "bosh3: deg7 {expensive} !> deg2 {cheap}"
        );
        let tb5 = tableau::dopri5();
        let cheap5 = poly_nfe(&tb5, 4, 1);
        let exp5 = poly_nfe(&tb5, 8, 1);
        assert!(exp5 > cheap5, "dopri5: {exp5} !> {cheap5}");
    }

    #[test]
    fn batched_sweep_matches_scalar_per_seed() {
        // The fig2 conversion must not change any reported number: each
        // seed's NFE from the batched sweep equals its scalar solve.
        let seeds = [31u64, 32, 33];
        for tb in [tableau::bosh3(), tableau::dopri5(), tableau::heun_euler()] {
            for k in [0usize, 2, 5, 8] {
                let batched = poly_nfe_batch(&tb, k, &seeds);
                let scalar: Vec<usize> =
                    seeds.iter().map(|s| poly_nfe(&tb, k, *s)).collect();
                assert_eq!(batched, scalar, "{} k={k}", tb.name);
            }
        }
    }
}
