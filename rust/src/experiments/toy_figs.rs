//! Fig 1 (regularizing R_3 on the toy 1-D map z0 + z0^3 reduces NFE without
//! hurting the fit) and Fig 9 (same with R_6 / 6th-order local Taylor
//! approximation quality).

use anyhow::Result;

use super::common::{self, Scale};
use crate::coordinator::toy_eval;
use crate::solvers::tableau;
use crate::util::bench::Table;

pub fn fig1(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let x = common::toy_data(128, 99);
    let mut table = Table::new(&["variant", "lambda", "train_loss", "eval_mse", "NFE"]);
    for (artifact, lam) in [
        ("toy_train_unreg_s16", 0.0f32),
        ("toy_train_k3_s16", 0.3),
    ] {
        let (tr, loss) = common::train_toy(&rt, artifact, scale.iters, lam, 0)?;
        let ev = toy_eval(&rt, &tr.store, &x, &tb, &opts)?;
        table.row(vec![
            artifact.to_string(),
            format!("{lam}"),
            format!("{loss:.5}"),
            format!("{:.5}", ev.mse),
            format!("{}", ev.nfe),
        ]);
    }
    Ok(table)
}

pub fn fig9(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let x = common::toy_data(128, 77);
    let mut table = Table::new(&["variant", "lambda", "train_loss", "eval_mse", "NFE"]);
    for (artifact, lam) in [
        ("toy_train_unreg_s16", 0.0f32),
        ("toy_train_k6_s16", 0.3),
        ("toy_train_k2_s16", 0.3),
    ] {
        let (tr, loss) = common::train_toy(&rt, artifact, scale.iters, lam, 1)?;
        let ev = toy_eval(&rt, &tr.store, &x, &tb, &opts)?;
        table.row(vec![
            artifact.to_string(),
            format!("{lam}"),
            format!("{loss:.5}"),
            format!("{:.5}", ev.mse),
            format!("{}", ev.nfe),
        ]);
    }
    Ok(table)
}
