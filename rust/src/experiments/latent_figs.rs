//! Fig 4 (latent-ODE PhysioNet: NFE reduction at small loss increase) and
//! Fig 12 (pareto on MSE for the time-series task).

use anyhow::Result;

use super::common::{self, Scale};
use crate::coordinator::evaluator;
use crate::solvers::tableau;
use crate::util::bench::Table;

pub fn fig4(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::LatentHarness::new(&rt, 23)?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let mut table = Table::new(&["variant", "lambda", "train_loss", "eval_nll", "eval_mse", "NFE"]);
    for (artifact, lam) in [("latent_train_unreg", 0.0f32), ("latent_train_k2", 0.1)] {
        let (tr, loss) = common::train_latent(&rt, &h, artifact, scale.iters, lam, 0)?;
        let ev = evaluator::latent_eval(&rt, &tr.store, &h.x, &h.mask, h.t, &tb, &opts)?;
        table.row(vec![
            artifact.to_string(),
            format!("{lam}"),
            format!("{loss:.4}"),
            format!("{:.4}", ev.nll),
            format!("{:.4}", ev.mse),
            format!("{}", ev.nfe),
        ]);
    }
    Ok(table)
}

pub fn fig12(scale: Scale) -> Result<Table> {
    let rt = common::load_runtime()?;
    let h = common::LatentHarness::new(&rt, 29)?;
    let tb = tableau::dopri5();
    let opts = common::eval_opts();
    let lams = [0.0f32, 0.03, 0.1, 0.3, 1.0];
    let mut table = Table::new(&["lambda", "eval_mse", "eval_nll", "NFE"]);
    for &lam in &lams[..scale.sweep.min(5)] {
        let artifact = if lam == 0.0 { "latent_train_unreg" } else { "latent_train_k2" };
        let (tr, _) = common::train_latent(&rt, &h, artifact, scale.iters, lam, 3)?;
        let ev = evaluator::latent_eval(&rt, &tr.store, &h.x_test, &h.mask_test, h.t, &tb, &opts)?;
        table.row(vec![
            format!("{lam}"),
            format!("{:.4}", ev.mse),
            format!("{:.4}", ev.nll),
            format!("{}", ev.nfe),
        ]);
    }
    Ok(table)
}
