//! Concat-squash dynamics for continuous normalizing flows — the native
//! counterpart of the exported FFJORD models (`cnf_tab` / `cnf_img`), and
//! the density-estimation workload of the training subsystem.
//!
//! A [`ConcatSquash`] layer (Grathwohl et al., FFJORD) modulates a linear
//! map of the state by a *time gate* and a *time bias*:
//!
//! ```text
//! out_j = (b_j + Σ_i act_i W_ij) · σ(g_j t + gb_j) + h_j t
//! ```
//!
//! so the vector field can reshape itself along the flow without `t` ever
//! being concatenated into the state.  [`Cnf`] stacks these layers with
//! tanh between them, written **once** against [`Value`] — exactly like
//! [`Mlp`](super::Mlp), so the same forward serves
//!
//! * the f32 solver path ([`BatchDynamics`], order-0 series columns),
//! * the Taylor-jet `R_K` path ([`BatchSeriesDynamics`], so
//!   [`RegularizedBatchDynamics`](crate::solvers::batch::RegularizedBatchDynamics)
//!   and the quadrature column of
//!   [`LogDetBatchDynamics`](crate::solvers::batch::LogDetBatchDynamics)
//!   consume it unchanged),
//! * the divergence engine ([`ValueDynamics`], tape columns — where the
//!   instantaneous change-of-variables term comes from), and
//! * the training tape (reverse-mode [`Var`](crate::autodiff::Var)
//!   parameters in `coordinator::train_native`).
//!
//! ```
//! use taynode::nn::Cnf;
//! use taynode::taylor::Series;
//!
//! // One forward pass, two scalar types: plain f64 and truncated series.
//! let cnf = Cnf::new(2, &[8], 0);
//! let dz = cnf.forward_f64(&[0.3, -0.1], 0.5);
//! assert_eq!(dz.len(), 2);
//! let p: Vec<Series> = cnf.lift_params(&Series::constant(0.0, 2));
//! let z = [Series::constant(0.3, 2), Series::constant(-0.1, 2)];
//! let t = Series::time(0.5, 2);
//! let ds = cnf.forward(&p, &z, &t);
//! assert!((ds[0].c[0] - dz[0]).abs() < 1e-12);
//! ```

use super::{Value, ValueDynamics};
use crate::solvers::batch::BatchDynamics;
use crate::taylor::{BatchSeriesDynamics, SeriesVec};
use crate::util::rng::Pcg;

/// One concat-squash layer: shapes plus the offset of its parameters in
/// the model's flat vector.  Layout at `off`: `W` (row-major `[win, wout]`),
/// then `b`, `g` (gate weight on t), `gb` (gate bias), `h` (time bias),
/// each `[wout]`.
#[derive(Clone, Copy, Debug)]
pub struct ConcatSquash {
    win: usize,
    wout: usize,
    off: usize,
}

impl ConcatSquash {
    /// Parameters this layer owns: `win·wout` weights + 4 `wout` vectors.
    pub fn n_params(&self) -> usize {
        self.win * self.wout + 4 * self.wout
    }

    /// Apply the layer generically: `(b + acts·W) ⊙ σ(g t + gb) + h t`,
    /// with the linear accumulation in [`Mlp`](super::Mlp)'s op order
    /// (bias, then `+= act·w` ascending i).
    pub fn apply<T: Value>(&self, p: &[T], acts: &[T], t: &T) -> Vec<T> {
        let (win, wout) = (self.win, self.wout);
        debug_assert_eq!(acts.len(), win, "ConcatSquash::apply: input arity");
        let boff = self.off + win * wout;
        let goff = boff + wout;
        let gboff = goff + wout;
        let hoff = gboff + wout;
        let mut out = Vec::with_capacity(wout);
        for j in 0..wout {
            let mut lin = p[boff + j].clone();
            for i in 0..win {
                lin = lin.add(&acts[i].mul(&p[self.off + i * wout + j]));
            }
            let gate = t.mul(&p[goff + j]).add(&p[gboff + j]).sigmoid();
            out.push(lin.mul(&gate).add(&t.mul(&p[hoff + j])));
        }
        out
    }
}

/// A concat-squash MLP vector field dz/dt = CNF(z, t) over flat `[B, n]`
/// SoA state — tanh between layers, linear n-dimensional output.
/// Parameters are one flat `Vec<f32>` (per layer, the [`ConcatSquash`]
/// layout), shared with the flat-vector optimizer and the tape's
/// parameter leaves.
#[derive(Clone, Debug)]
pub struct Cnf {
    layers: Vec<ConcatSquash>,
    n: usize,
    /// Flat parameter vector (per layer: `W`, `b`, `g`, `gb`, `h`).
    pub params: Vec<f32>,
}

impl Cnf {
    /// Build with deterministic N(0, 1/in) weight init; biases, gate, and
    /// time-bias parameters start at zero (every gate opens at σ(0) = ½).
    pub fn new(n: usize, hidden: &[usize], seed: u64) -> Cnf {
        assert!(n > 0, "Cnf: state dimension must be positive");
        let mut sizes = vec![n];
        sizes.extend_from_slice(hidden);
        sizes.push(n);
        let mut rng = Pcg::new(seed);
        let mut layers = Vec::with_capacity(sizes.len() - 1);
        let mut params = Vec::new();
        for l in 0..sizes.len() - 1 {
            let (win, wout) = (sizes[l], sizes[l + 1]);
            layers.push(ConcatSquash { win, wout, off: params.len() });
            let sd = 1.0 / (win as f32).sqrt();
            for _ in 0..win * wout {
                params.push(rng.normal() * sd);
            }
            for _ in 0..4 * wout {
                params.push(0.0);
            }
        }
        Cnf { layers, n, params }
    }

    /// The per-trajectory state dimension n.
    pub fn state_dim(&self) -> usize {
        self.n
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Lift the flat f32 parameters into any [`Value`] carrier, using
    /// `like`'s shape.  The training tape does NOT use this — it creates
    /// gradient-tracked parameter leaves instead.
    pub fn lift_params<T: Value>(&self, like: &T) -> Vec<T> {
        self.params.iter().map(|p| like.lift(*p as f64)).collect()
    }

    /// The generic forward pass: activations, parameters, and time all in
    /// the same [`Value`] carrier `T`.  `p` must be this model's parameters
    /// lifted into `T` (see [`lift_params`](Cnf::lift_params)).
    pub fn forward<T: Value>(&self, p: &[T], z: &[T], t: &T) -> Vec<T> {
        assert_eq!(z.len(), self.n, "Cnf::forward: state arity");
        assert_eq!(p.len(), self.params.len(), "Cnf::forward: parameter arity");
        let mut acts: Vec<T> = z.to_vec();
        for (l, layer) in self.layers.iter().enumerate() {
            acts = layer.apply(p, &acts, t);
            if l + 1 < self.layers.len() {
                for a in acts.iter_mut() {
                    *a = a.tanh();
                }
            }
        }
        acts
    }

    /// Plain per-example evaluation (the reference semantics for tests and
    /// docs): `z` is one example's n features.
    pub fn forward_f64(&self, z: &[f64], t: f64) -> Vec<f64> {
        let p = self.lift_params(&t);
        self.forward(&p, z, &t)
    }
}

/// The series lift, exactly like [`Mlp`](super::Mlp)'s: split the `[rows,
/// n]` batch into `[rows, 1]` columns, run the generic forward, reassemble
/// — so the batched-jet `R_K` machinery consumes the CNF unchanged.
impl BatchSeriesDynamics for Cnf {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, _ids: &[usize], z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
        let p = self.lift_params(t);
        let cols: Vec<SeriesVec> = (0..self.n).map(|j| z.col(j)).collect();
        let out = self.forward(&p, &cols, t);
        SeriesVec::from_cols(&out)
    }
}

/// The f32 solver path, routed through order-0 series columns (the same
/// arithmetic as every other carrier by construction).  The CNF's real
/// serving path is the divergence-augmented
/// [`LogDetBatchDynamics`](crate::solvers::batch::LogDetBatchDynamics); a
/// hand-staged fast path like [`Mlp`](super::Mlp)'s is not worth the
/// duplication here.
impl BatchDynamics for Cnf {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let rows = t.len();
        debug_assert_eq!(y.len(), rows * self.n);
        debug_assert_eq!(dy.len(), rows * self.n);
        let z64: Vec<f64> = y.iter().map(|v| *v as f64).collect();
        let t64: Vec<f64> = t.iter().map(|v| *v as f64).collect();
        let zs = SeriesVec::constant(&z64, rows, self.n, 0);
        let ts = SeriesVec::time(&t64, 0);
        let out = BatchSeriesDynamics::eval(self, ids, &zs, &ts);
        for (d, v) in dy.iter_mut().zip(out.coeff(0)) {
            *d = *v as f32;
        }
    }
}

/// The divergence-engine hook: the same generic forward on any carrier,
/// parameters lifted as constants of the carrier's shape.
impl ValueDynamics for Cnf {
    fn dim(&self) -> usize {
        self.n
    }

    fn forward_values<T: Value>(&self, z: &[T], t: &T) -> Vec<T> {
        let p = self.lift_params(t);
        self.forward(&p, z, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ode_jet_values, SeriesOf};
    use crate::taylor::ode_jet_batch;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn param_count_matches_layout() {
        let cnf = Cnf::new(3, &[5, 4], 0);
        // 3x5 + 4·5, 5x4 + 4·4, 4x3 + 4·3
        assert_eq!(cnf.n_params(), 3 * 5 + 20 + 5 * 4 + 16 + 4 * 3 + 12);
        assert_eq!(cnf.state_dim(), 3);
        let out = cnf.forward_f64(&[0.1, -0.2, 0.3], 0.5);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn single_layer_closed_form() {
        // One layer, n = 2: out = (b + z W) · σ(g t + gb) + h t, checkable
        // by hand with W = I.
        let mut cnf = Cnf::new(2, &[], 3);
        // layout: W [2, 2], b [2], g [2], gb [2], h [2]
        cnf.params = vec![
            1.0, 0.0, 0.0, 1.0, // W = I
            0.5, -0.5, // b
            2.0, 0.0, // g
            0.0, 1.0, // gb
            3.0, 0.0, // h
        ];
        let t = 0.25f64;
        let out = cnf.forward_f64(&[2.0, 3.0], t);
        let s0 = 1.0 / (1.0 + (-(2.0 * t)).exp());
        let s1 = 1.0 / (1.0 + (-1.0f64).exp());
        assert!(close(out[0], 2.5 * s0 + 3.0 * t, 1e-12), "{}", out[0]);
        assert!(close(out[1], 2.5 * s1, 1e-12), "{}", out[1]);
    }

    #[test]
    fn batched_order0_matches_per_example_f64_property() {
        // The f32 BatchDynamics path (order-0 SeriesVec columns) must equal
        // the per-example f64 forward up to the final f32 cast.
        Prop::new(40).run("cnf-batch-vs-scalar", |rng: &mut Pcg, _| {
            let n = 1 + rng.below(3);
            let h = 1 + rng.below(6);
            let b = 1 + rng.below(5);
            let mut cnf = Cnf::new(n, &[h], rng.next_u64());
            // give the gates and time biases non-trivial values
            for p in cnf.params.iter_mut() {
                if *p == 0.0 {
                    *p = rng.range(-0.8, 0.8);
                }
            }
            let y = gen::vec_f32(rng, b * n, 1.2);
            let t: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
            let ids: Vec<usize> = (0..b).collect();
            let mut dy = vec![0.0f32; b * n];
            BatchDynamics::eval(&mut cnf, &ids, &t, &y, &mut dy);
            for r in 0..b {
                let z: Vec<f64> = y[r * n..(r + 1) * n].iter().map(|v| *v as f64).collect();
                let want = cnf.forward_f64(&z, t[r] as f64);
                for i in 0..n {
                    assert!(
                        close(dy[r * n + i] as f64, want[i], 1e-6),
                        "row {r} dim {i}: {} vs {}",
                        dy[r * n + i],
                        want[i]
                    );
                }
            }
        });
    }

    #[test]
    fn batched_jets_match_generic_jets_per_example_property() {
        // ode_jet_batch over the SeriesVec lift vs ode_jet_values with
        // T = f64 per example: the two series flavors must agree — the
        // sigmoid gate's propagation rule included.
        Prop::new(25).run("cnf-jet-batch-vs-values", |rng: &mut Pcg, _| {
            let n = 1 + rng.below(2);
            let b = 1 + rng.below(4);
            let order = 1 + rng.below(3);
            let mut cnf = Cnf::new(n, &[3], rng.next_u64());
            for p in cnf.params.iter_mut() {
                if *p == 0.0 {
                    *p = rng.range(-0.8, 0.8);
                }
            }
            let z0 = gen::vec_f64(rng, b * n, -1.0, 1.0);
            let t0 = gen::vec_f64(rng, b, -0.5, 0.5);
            let ids: Vec<usize> = (0..b).collect();
            let jets = ode_jet_batch(&mut cnf, &ids, &z0, &t0, order);
            for r in 0..b {
                let zr: Vec<f64> = z0[r * n..(r + 1) * n].to_vec();
                let cnf_ref = &cnf;
                let want = ode_jet_values(
                    &mut |zs: &[SeriesOf<f64>], ts: &SeriesOf<f64>| {
                        let p = cnf_ref.lift_params(ts);
                        cnf_ref.forward(&p, zs, ts)
                    },
                    &zr,
                    &t0[r],
                    order,
                );
                for k in 0..order {
                    for i in 0..n {
                        assert!(
                            close(jets[k][r * n + i], want[k][i], 1e-9),
                            "row {r} order {k} dim {i}: {} vs {}",
                            jets[k][r * n + i],
                            want[k][i]
                        );
                    }
                }
            }
        });
    }
}
