//! A small MLP vector field over flat `[B, n]` SoA state — the native
//! dynamics model of the training subsystem.
//!
//! The forward pass is written **once**, generically over [`Value`], and the
//! three consumers instantiate it:
//!
//! * the solver hot path ([`BatchDynamics`]) runs a staged direct f64
//!   evaluation over reusable buffers that is bit-for-bit the order-0
//!   specialization of the series lift (property-tested), cast to the
//!   engine's f32 at the boundary;
//! * the jet path ([`BatchSeriesDynamics`]) runs it on truncated series
//!   columns, so `taylor::ode_jet_batch` and with it the whole native `R_K`
//!   machinery (`RegularizedBatchDynamics`, `batch_rk_eval`) work on the
//!   model unchanged;
//! * the training path runs it on reverse-mode tape values
//!   ([`Var`](crate::autodiff::Var), possibly inside
//!   [`SeriesOf`](super::SeriesOf)), which is where the discrete adjoint
//!   gets its VJPs.
//!
//! Architecture: `z` (n features), optionally with the time appended as an
//! extra input, through `hidden` tanh layers to a linear n-dimensional
//! output.  Parameters are one flat `Vec<f32>`: per layer, `W` (row-major
//! `[in, out]`) then `b` (`[out]`) — the layout the flat-vector optimizer
//! (`autodiff::Adam`) and the tape's parameter leaves share.

use super::{Value, ValueDynamics};
use crate::solvers::batch::BatchDynamics;
use crate::taylor::{BatchSeriesDynamics, SeriesVec};
use crate::util::rng::Pcg;

/// A multilayer perceptron vector field dz/dt = MLP([z, t]).
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Feature widths at each layer boundary; `sizes[0]` includes the time
    /// input when `with_time`, `sizes.last()` is always the state dim.
    sizes: Vec<usize>,
    n: usize,
    with_time: bool,
    /// Flat parameter vector (per layer: row-major `W [in, out]`, then `b`).
    pub params: Vec<f32>,
    /// Reusable `[rows, width]` activation staging for the f32 solver hot
    /// path (ping-pong pair) — scratch only, never observable.
    stage_in: Vec<f64>,
    stage_out: Vec<f64>,
    /// Per-layer widened parameter scratch for the fused kernel (the
    /// f32→f64 cast is exact, so pre-widening is bit-invisible; see
    /// [`crate::kern::mlp::widen`]).  Re-widened every eval because
    /// `params` is public and may have been updated by the optimizer.
    w64: Vec<f64>,
    b64: Vec<f64>,
}

impl Mlp {
    /// Build with deterministic N(0, 1/in) weight init and zero biases.
    pub fn new(n: usize, hidden: &[usize], with_time: bool, seed: u64) -> Mlp {
        assert!(n > 0, "Mlp: state dimension must be positive");
        let mut sizes = vec![n + usize::from(with_time)];
        sizes.extend_from_slice(hidden);
        sizes.push(n);
        let mut rng = Pcg::new(seed);
        let mut params = Vec::new();
        for l in 0..sizes.len() - 1 {
            let (win, wout) = (sizes[l], sizes[l + 1]);
            let sd = 1.0 / (win as f32).sqrt();
            for _ in 0..win * wout {
                params.push(rng.normal() * sd);
            }
            for _ in 0..wout {
                params.push(0.0);
            }
        }
        Mlp {
            sizes,
            n,
            with_time,
            params,
            stage_in: vec![],
            stage_out: vec![],
            w64: vec![],
            b64: vec![],
        }
    }

    /// The per-trajectory state dimension n.
    pub fn state_dim(&self) -> usize {
        self.n
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Lift the flat f32 parameters into any [`Value`] carrier, using
    /// `like`'s shape (its rows / order / tape).  The tape path does NOT use
    /// this — it creates gradient-tracked parameter leaves instead.
    pub fn lift_params<T: Value>(&self, like: &T) -> Vec<T> {
        self.params.iter().map(|p| like.lift(*p as f64)).collect()
    }

    /// The generic forward pass: activations, parameters, and time all live
    /// in the same [`Value`] carrier `T`.  `p` must be this model's
    /// parameters lifted into `T` (see [`lift_params`](Mlp::lift_params));
    /// `t` is required exactly when the model was built `with_time`.
    pub fn forward<T: Value>(&self, p: &[T], z: &[T], t: Option<&T>) -> Vec<T> {
        assert_eq!(z.len(), self.n, "Mlp::forward: state arity");
        assert_eq!(p.len(), self.params.len(), "Mlp::forward: parameter arity");
        let mut acts: Vec<T> = z.to_vec();
        if self.with_time {
            acts.push(t.expect("Mlp built with_time needs t").clone()); // taylint: allow(D4) -- documented contract of forward()
        }
        let mut off = 0;
        for l in 0..self.sizes.len() - 1 {
            let (win, wout) = (self.sizes[l], self.sizes[l + 1]);
            let boff = off + win * wout;
            let mut next: Vec<T> = Vec::with_capacity(wout);
            for j in 0..wout {
                // acc = b_j + sum_i act_i * W_ij, ascending i
                let mut acc = p[boff + j].clone();
                for i in 0..win {
                    acc = acc.add(&acts[i].mul(&p[off + i * wout + j]));
                }
                if l + 1 < self.sizes.len() - 1 {
                    acc = acc.tanh();
                }
                next.push(acc);
            }
            acts = next;
            off = boff + wout;
        }
        acts
    }

    /// Plain per-example evaluation (the reference semantics for tests and
    /// docs): `z` is one example's n features.
    pub fn forward_f64(&self, z: &[f64], t: f64) -> Vec<f64> {
        let p = self.lift_params(&t);
        self.forward(&p, z, Some(&t))
    }
}

/// The series lift: split the `[rows, n]` batch into `[rows, 1]` columns,
/// run the generic forward, reassemble.  Elementwise `SeriesVec` ops apply
/// the scalar op order, so each row is bit-identical to a per-example
/// `Series` forward — which is what lets the existing batched-jet `R_K`
/// machinery consume the model unchanged.
impl BatchSeriesDynamics for Mlp {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, _ids: &[usize], z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
        let p = self.lift_params(t);
        let cols: Vec<SeriesVec> = (0..self.n).map(|j| z.col(j)).collect();
        let out = self.forward(&p, &cols, Some(t));
        SeriesVec::from_cols(&out)
    }
}

/// The solver hot path: the fused layer kernel
/// ([`crate::kern::mlp::layer_into`]) over reusable `[rows, width]`
/// activation buffers — zero allocation per NFE once the buffers are warm.
/// Per element the kernel applies the **identical f64 operation sequence**
/// as the generic forward on order-0 series columns (bias, then `+= act·w`
/// in ascending input order, tanh on hidden layers; its register tile
/// spans independent outputs only, never the reduction axis), so it is
/// bit-for-bit the order-0 specialization of the series lift
/// (property-tested below) — the f32 engine, the jets, and the tape still
/// cannot disagree about what the model computes.
impl BatchDynamics for Mlp {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let rows = t.len();
        let n = self.n;
        debug_assert_eq!(y.len(), rows * n);
        debug_assert_eq!(dy.len(), rows * n);
        // Stage the inputs: features, then the time column when present.
        self.stage_in.clear();
        self.stage_in.reserve(rows * self.sizes[0]);
        for (r, tr) in t.iter().enumerate() {
            for v in &y[r * n..(r + 1) * n] {
                self.stage_in.push(*v as f64);
            }
            if self.with_time {
                self.stage_in.push(*tr as f64);
            }
        }
        let mut off = 0;
        for l in 0..self.sizes.len() - 1 {
            let (win, wout) = (self.sizes[l], self.sizes[l + 1]);
            let boff = off + win * wout;
            let hidden = l + 1 < self.sizes.len() - 1;
            crate::kern::mlp::widen(&self.params[off..boff], &mut self.w64);
            crate::kern::mlp::widen(&self.params[boff..boff + wout], &mut self.b64);
            crate::kern::mlp::layer_into(
                rows,
                win,
                wout,
                &self.stage_in,
                &self.w64,
                &self.b64,
                hidden,
                &mut self.stage_out,
            );
            std::mem::swap(&mut self.stage_in, &mut self.stage_out);
            off = boff + wout;
        }
        for (d, v) in dy.iter_mut().zip(&self.stage_in) {
            *d = *v as f32;
        }
    }
}

/// The divergence-engine hook ([`crate::autodiff::div`]): the same generic
/// forward on any carrier, parameters lifted as constants of the carrier's
/// shape (no gradients — the training tape builds its own leaves).
impl ValueDynamics for Mlp {
    fn dim(&self) -> usize {
        self.n
    }

    fn forward_values<T: Value>(&self, z: &[T], t: &T) -> Vec<T> {
        let p = self.lift_params(t);
        self.forward(&p, z, Some(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ode_jet_values, SeriesOf};
    use crate::taylor::ode_jet_batch;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn param_count_matches_layout() {
        let mlp = Mlp::new(3, &[5, 4], true, 0);
        // (3+1)x5 + 5, 5x4 + 4, 4x3 + 3
        assert_eq!(mlp.n_params(), 4 * 5 + 5 + 5 * 4 + 4 + 4 * 3 + 3);
        assert_eq!(mlp.state_dim(), 3);
        let out = mlp.forward_f64(&[0.1, -0.2, 0.3], 0.5);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn f32_hot_path_matches_order0_series_route_bit_for_bit() {
        // The staged-buffer fast path must be the exact order-0
        // specialization of the series lift: same f64 ops in the same
        // order, so the f32 outputs agree bit-for-bit with the (previous)
        // SeriesVec round-trip — and repeated evaluations through the
        // reused buffers stay bit-stable.
        Prop::new(40).run("mlp-fast-vs-series", |rng: &mut Pcg, _| {
            let n = 1 + rng.below(3);
            let hidden: Vec<usize> = (0..rng.below(3)).map(|_| 1 + rng.below(6)).collect();
            let b = 1 + rng.below(6);
            let with_time = rng.below(2) == 0;
            let mut mlp = Mlp::new(n, &hidden, with_time, rng.next_u64());
            let y = gen::vec_f32(rng, b * n, 1.2);
            let t: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
            let ids: Vec<usize> = (0..b).collect();
            let mut dy = vec![0.0f32; b * n];
            for _ in 0..2 {
                // twice: the second pass reuses warm staging buffers
                BatchDynamics::eval(&mut mlp, &ids, &t, &y, &mut dy);
                let z64: Vec<f64> = y.iter().map(|v| *v as f64).collect();
                let t64: Vec<f64> = t.iter().map(|v| *v as f64).collect();
                let zs = SeriesVec::constant(&z64, b, n, 0);
                let ts = SeriesVec::time(&t64, 0);
                let out = BatchSeriesDynamics::eval(&mut mlp, &ids, &zs, &ts);
                for (e, (d, v)) in dy.iter().zip(out.coeff(0)).enumerate() {
                    assert_eq!(
                        d.to_bits(),
                        (*v as f32).to_bits(),
                        "elem {e}: fast {d} vs series {v}"
                    );
                }
            }
        });
    }

    #[test]
    fn batched_order0_matches_per_example_f64_property() {
        // The f32 BatchDynamics path (order-0 SeriesVec columns) must equal
        // the per-example f64 forward up to the final f32 cast.
        Prop::new(40).run("mlp-batch-vs-scalar", |rng: &mut Pcg, _| {
            let n = 1 + rng.below(3);
            let h = 1 + rng.below(6);
            let b = 1 + rng.below(5);
            let with_time = rng.below(2) == 0;
            let mut mlp = Mlp::new(n, &[h], with_time, rng.next_u64());
            let y = gen::vec_f32(rng, b * n, 1.2);
            let t: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
            let ids: Vec<usize> = (0..b).collect();
            let mut dy = vec![0.0f32; b * n];
            BatchDynamics::eval(&mut mlp, &ids, &t, &y, &mut dy);
            for r in 0..b {
                let z: Vec<f64> = y[r * n..(r + 1) * n].iter().map(|v| *v as f64).collect();
                let want = mlp.forward_f64(&z, t[r] as f64);
                for i in 0..n {
                    assert!(
                        close(dy[r * n + i] as f64, want[i], 1e-6),
                        "row {r} dim {i}: {} vs {}",
                        dy[r * n + i],
                        want[i]
                    );
                }
            }
        });
    }

    #[test]
    fn batched_jets_match_generic_jets_per_example_property() {
        // ode_jet_batch over the SeriesVec lift vs ode_jet_values with
        // T = f64 per example: the two series flavors must agree.
        Prop::new(25).run("mlp-jet-batch-vs-values", |rng: &mut Pcg, _| {
            let n = 1 + rng.below(2);
            let b = 1 + rng.below(4);
            let order = 1 + rng.below(3);
            let mut mlp = Mlp::new(n, &[3], true, rng.next_u64());
            let z0 = gen::vec_f64(rng, b * n, -1.0, 1.0);
            let t0 = gen::vec_f64(rng, b, -0.5, 0.5);
            let ids: Vec<usize> = (0..b).collect();
            let jets = ode_jet_batch(&mut mlp, &ids, &z0, &t0, order);
            for r in 0..b {
                let zr: Vec<f64> = z0[r * n..(r + 1) * n].to_vec();
                let mlp_ref = &mlp;
                let want = ode_jet_values(
                    &mut |zs: &[SeriesOf<f64>], ts: &SeriesOf<f64>| {
                        let p = mlp_ref.lift_params(ts);
                        mlp_ref.forward(&p, zs, Some(ts))
                    },
                    &zr,
                    &t0[r],
                    order,
                );
                for k in 0..order {
                    for i in 0..n {
                        assert!(
                            close(jets[k][r * n + i], want[k][i], 1e-9),
                            "row {r} order {k} dim {i}: {} vs {}",
                            jets[k][r * n + i],
                            want[k][i]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn kernel_backed_solves_are_thread_count_invariant_bit_for_bit() {
        // Full adaptive solves over the fused-kernel Mlp at TAYNODE_THREADS
        // ∈ {1, 3, 4}: the kernels only regroup independent elements, so
        // sharding the batch differently must not move a single bit.
        use crate::solvers::adaptive::AdaptiveOpts;
        use crate::solvers::batch::solve_adaptive_batch_pooled;
        use crate::solvers::tableau;
        use crate::util::pool::Pool;
        let mut rng = Pcg::new(0x7EAD);
        let (n, b) = (3usize, 7usize);
        let mlp = Mlp::new(n, &[8, 8], true, 42);
        let y0 = gen::vec_f32(&mut rng, b * n, 1.0);
        let tb = tableau::by_name("dopri5").unwrap();
        let opts = AdaptiveOpts::default();
        let base = solve_adaptive_batch_pooled(&Pool::new(1), &mlp, 0.0, 0.5, &y0, &tb, &opts);
        for threads in [3usize, 4] {
            let pool = Pool::new(threads);
            let res = solve_adaptive_batch_pooled(&pool, &mlp, 0.0, 0.5, &y0, &tb, &opts);
            assert_eq!(res.batch(), base.batch());
            for (e, (a, c)) in base.y.iter().zip(&res.y).enumerate() {
                assert_eq!(a.to_bits(), c.to_bits(), "threads={threads} elem {e}");
            }
            for (r, (a, c)) in base.t.iter().zip(&res.t).enumerate() {
                assert_eq!(a.to_bits(), c.to_bits(), "threads={threads} t row {r}");
            }
        }
    }

    #[test]
    fn headless_single_layer_is_affine() {
        // No hidden layers: the model is exactly z W + b, checkable by hand.
        let mut mlp = Mlp::new(2, &[], false, 3);
        mlp.params = vec![1.0, 0.0, 0.0, 1.0, 0.5, -0.5]; // W = I, b = (.5, -.5)
        let out = mlp.forward_f64(&[2.0, 3.0], 0.0);
        assert!(close(out[0], 2.5, 1e-12));
        assert!(close(out[1], 2.5, 1e-12));
    }
}
