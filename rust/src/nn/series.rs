//! Truncated Taylor series *over* an arbitrary [`Value`] carrier, plus the
//! value-generic ODE-solution jet.
//!
//! [`SeriesOf<T>`] applies the scalar propagation rules of
//! [`crate::taylor::Series`] with coefficients in `T` instead of `f64`.
//! With `T = f64` it reproduces the scalar series; with
//! `T = `[`Var`](crate::autodiff::Var) every coefficient is a reverse-mode
//! tape node, so [`ode_jet_values`] — Algorithm 1 with value coefficients —
//! makes the Taylor-mode `R_K` integrand itself differentiable: the
//! discrete-adjoint backward pass seeds the K-th derivative's square and
//! gets exact parameter cotangents through the whole jet recursion.
//!
//! Every series carries a **structural-zero mask**: coefficients known to
//! be exactly zero by construction (the pads of a constant parameter
//! series, the orders ≥ 2 of the time series) are marked, and the
//! propagation rules skip them — a product term with a structurally-zero
//! factor is never computed, an addition with a structurally-zero side is a
//! clone of the other side.  On a tape carrier that means the jet closure
//! stops recording the zero columns of every parameter series (the bulk of
//! a constant-parameter Cauchy product), shrinking the per-stage VJP tape;
//! on any carrier the skipped work is exactly zero-valued (results can
//! differ from the dense evaluation only in the sign of zero, or when an
//! operand is non-finite).
//!
//! ```
//! use taynode::nn::{ode_jet_values, SeriesOf};
//!
//! // dz/dt = z with f64 coefficients: every derivative equals z0.
//! let jets = ode_jet_values(
//!     &mut |z: &[SeriesOf<f64>], _t: &SeriesOf<f64>| vec![z[0].clone()],
//!     &[2.0f64],
//!     &0.0,
//!     3,
//! );
//! for x in &jets {
//!     assert_eq!(x[0], 2.0);
//! }
//! ```

use super::Value;
use crate::taylor::factorial;

/// A truncated Taylor polynomial `sum_k c[k] t^k` with coefficients in any
/// [`Value`] carrier.
#[derive(Clone, Debug)]
pub struct SeriesOf<T> {
    c: Vec<T>,
    /// Structural-zero mask: `nz[k] == false` guarantees `c[k]` holds an
    /// exact zero value, so ops may skip it (see module docs).
    nz: Vec<bool>,
}

impl<T: Value> SeriesOf<T> {
    /// A dense series from explicit coefficients (none assumed zero).
    pub fn new(c: Vec<T>) -> SeriesOf<T> {
        assert!(!c.is_empty(), "SeriesOf needs at least the order-0 coefficient");
        let nz = vec![true; c.len()];
        SeriesOf { c, nz }
    }

    /// A constant series: `x` at order 0, structural zeros above.
    pub fn constant(x: T, order: usize) -> SeriesOf<T> {
        let zero = x.lift(0.0);
        SeriesOf::constant_padded(x, &zero, order)
    }

    /// [`constant`](SeriesOf::constant) with a caller-supplied zero value
    /// (which must be an exact 0.0 of `x`'s shape): the pads are clones of
    /// `zero`, so on a tape carrier every constant series built from the
    /// same `zero` shares ONE zero node instead of lifting its own.
    pub fn constant_padded(x: T, zero: &T, order: usize) -> SeriesOf<T> {
        let mut c = Vec::with_capacity(order + 1);
        let mut nz = Vec::with_capacity(order + 1);
        c.push(x);
        nz.push(true);
        for _ in 0..order {
            c.push(zero.clone());
            nz.push(false);
        }
        SeriesOf { c, nz }
    }

    /// The independent variable itself: `t0 + 1·t` (structural zeros above
    /// order 1).
    pub fn time(t0: T, order: usize) -> SeriesOf<T> {
        let one = t0.lift(1.0);
        let zero = t0.lift(0.0);
        let mut c = Vec::with_capacity(order + 1);
        let mut nz = Vec::with_capacity(order + 1);
        c.push(t0);
        nz.push(true);
        if order >= 1 {
            c.push(one);
            nz.push(true);
        }
        for _ in 1..order {
            c.push(zero.clone());
            nz.push(false);
        }
        SeriesOf { c, nz }
    }

    pub fn order(&self) -> usize {
        self.c.len() - 1
    }

    pub fn coeff(&self, k: usize) -> &T {
        &self.c[k]
    }

    /// An exact zero of this series' coefficient shape, preferring a clone
    /// of an existing structurally-zero coefficient (no new tape node) over
    /// lifting a fresh one.
    fn zero_like(&self, o: &SeriesOf<T>) -> T {
        if let Some(k) = self.nz.iter().position(|z| !*z) {
            return self.c[k].clone();
        }
        if let Some(k) = o.nz.iter().position(|z| !*z) {
            return o.c[k].clone();
        }
        self.c[0].lift(0.0)
    }
}

/// The scalar propagation rules of [`crate::taylor::Series`], coefficient
/// arithmetic delegated to `T` — so a `SeriesOf<Var>` records every
/// (structurally nonzero) coefficient operation on the tape.
impl<T: Value> Value for SeriesOf<T> {
    fn lift(&self, a: f64) -> Self {
        SeriesOf::constant(self.c[0].lift(a), self.order())
    }

    fn add(&self, o: &Self) -> Self {
        assert_eq!(self.order(), o.order(), "SeriesOf::add: order mismatch");
        let k1 = self.c.len();
        let mut c = Vec::with_capacity(k1);
        let mut nz = Vec::with_capacity(k1);
        for k in 0..k1 {
            match (self.nz[k], o.nz[k]) {
                (true, true) => {
                    c.push(self.c[k].add(&o.c[k]));
                    nz.push(true);
                }
                (true, false) => {
                    c.push(self.c[k].clone());
                    nz.push(true);
                }
                (false, true) => {
                    c.push(o.c[k].clone());
                    nz.push(true);
                }
                (false, false) => {
                    c.push(self.c[k].clone());
                    nz.push(false);
                }
            }
        }
        SeriesOf { c, nz }
    }

    fn sub(&self, o: &Self) -> Self {
        assert_eq!(self.order(), o.order(), "SeriesOf::sub: order mismatch");
        let k1 = self.c.len();
        let mut c = Vec::with_capacity(k1);
        let mut nz = Vec::with_capacity(k1);
        for k in 0..k1 {
            if !o.nz[k] {
                // x − 0: clone the left side (still zero if both are).
                c.push(self.c[k].clone());
                nz.push(self.nz[k]);
            } else {
                // 0 − x is recorded as a real subtraction (not a negation)
                // to keep the exact scalar semantics, including zero signs.
                c.push(self.c[k].sub(&o.c[k]));
                nz.push(true);
            }
        }
        SeriesOf { c, nz }
    }

    /// Truncated Cauchy product (Table 1 row 2), inner terms in the scalar
    /// operation order (ascending j), structurally-zero terms skipped.
    fn mul(&self, o: &Self) -> Self {
        assert_eq!(self.order(), o.order(), "SeriesOf::mul: order mismatch");
        let k1 = self.c.len();
        let mut c = Vec::with_capacity(k1);
        let mut nz = Vec::with_capacity(k1);
        let mut zero: Option<T> = None;
        for k in 0..k1 {
            let mut acc: Option<T> = None;
            for j in 0..=k {
                if !self.nz[j] || !o.nz[k - j] {
                    continue; // a structurally-zero factor: the term is 0
                }
                let term = self.c[j].mul(&o.c[k - j]);
                acc = Some(match acc {
                    Some(a) => a.add(&term),
                    None => term,
                });
            }
            match acc {
                Some(v) => {
                    c.push(v);
                    nz.push(true);
                }
                None => {
                    let z = zero.get_or_insert_with(|| self.zero_like(o));
                    c.push(z.clone());
                    nz.push(false);
                }
            }
        }
        SeriesOf { c, nz }
    }

    fn scale(&self, a: f64) -> Self {
        let mut c = Vec::with_capacity(self.c.len());
        for (ck, nzk) in self.c.iter().zip(&self.nz) {
            // a·0 stays an exact zero: keep the shared zero coefficient.
            c.push(if *nzk { ck.scale(a) } else { ck.clone() });
        }
        SeriesOf { c, nz: self.nz.clone() }
    }

    /// tanh via the ODE s' = (1 - s²) z', coefficients in `T`.  A constant
    /// series short-circuits to a constant result (its derivative
    /// coefficients are structurally zero).
    fn tanh(&self) -> Self {
        let k1 = self.c.len();
        if self.nz.iter().skip(1).all(|z| !*z) {
            let mut c = Vec::with_capacity(k1);
            let mut nz = Vec::with_capacity(k1);
            c.push(self.c[0].tanh());
            nz.push(true);
            for k in 1..k1 {
                c.push(self.c[k].clone()); // the input's exact zeros
                nz.push(false);
            }
            return SeriesOf { c, nz };
        }
        let mut s: Vec<T> = Vec::with_capacity(k1);
        s.push(self.c[0].tanh());
        for k in 1..k1 {
            let mut acc: Option<T> = None;
            for j in 1..=k {
                if !self.nz[j] {
                    continue; // z' term with a structurally-zero coefficient
                }
                let m = k - j;
                // u[m] = delta_{m0} - (s*s)[m], with s[0..=m] already known
                let mut ssm = s[0].mul(&s[m]);
                for i in 1..=m {
                    ssm = ssm.add(&s[i].mul(&s[m - i]));
                }
                let u = if m == 0 { ssm.lift(1.0).sub(&ssm) } else { ssm.scale(-1.0) };
                let term = self.c[j].scale(j as f64).mul(&u);
                acc = Some(match acc {
                    Some(a) => a.add(&term),
                    None => term,
                });
            }
            // At least one order 1..=k coefficient is structurally nonzero
            // (the constant case returned above), but not necessarily one
            // with j <= k — pad with an exact zero when every term skipped.
            s.push(match acc {
                Some(a) => a.scale(1.0 / k as f64),
                None => s[0].lift(0.0),
            });
        }
        SeriesOf { c: s, nz: vec![true; k1] }
    }

    /// exp via the ODE y' = y z', coefficients in `T` (the recurrence of
    /// [`Series::exp`](crate::taylor::Series::exp)).  A constant series
    /// short-circuits to a constant result.
    fn exp(&self) -> Self {
        let k1 = self.c.len();
        if self.nz.iter().skip(1).all(|z| !*z) {
            let mut c = Vec::with_capacity(k1);
            let mut nz = Vec::with_capacity(k1);
            c.push(self.c[0].exp());
            nz.push(true);
            for k in 1..k1 {
                c.push(self.c[k].clone()); // the input's exact zeros
                nz.push(false);
            }
            return SeriesOf { c, nz };
        }
        let mut y: Vec<T> = Vec::with_capacity(k1);
        y.push(self.c[0].exp());
        for k in 1..k1 {
            let mut acc: Option<T> = None;
            for j in 1..=k {
                if !self.nz[j] {
                    continue; // z' term with a structurally-zero coefficient
                }
                let term = self.c[j].scale(j as f64).mul(&y[k - j]);
                acc = Some(match acc {
                    Some(a) => a.add(&term),
                    None => term,
                });
            }
            y.push(match acc {
                Some(a) => a.scale(1.0 / k as f64),
                None => y[0].lift(0.0),
            });
        }
        SeriesOf { c: y, nz: vec![true; k1] }
    }

    /// Logistic sigmoid via the ODE s' = s (1 - s) z', coefficients in `T`.
    /// A constant series short-circuits to a constant result.
    fn sigmoid(&self) -> Self {
        let k1 = self.c.len();
        if self.nz.iter().skip(1).all(|z| !*z) {
            let mut c = Vec::with_capacity(k1);
            let mut nz = Vec::with_capacity(k1);
            c.push(self.c[0].sigmoid());
            nz.push(true);
            for k in 1..k1 {
                c.push(self.c[k].clone()); // the input's exact zeros
                nz.push(false);
            }
            return SeriesOf { c, nz };
        }
        let mut s: Vec<T> = Vec::with_capacity(k1);
        s.push(self.c[0].sigmoid());
        for k in 1..k1 {
            let mut acc: Option<T> = None;
            for j in 1..=k {
                if !self.nz[j] {
                    continue; // z' term with a structurally-zero coefficient
                }
                let m = k - j;
                // u[m] = s[m] - (s*s)[m], with s[0..=m] already known
                let mut ssm = s[0].mul(&s[m]);
                for i in 1..=m {
                    ssm = ssm.add(&s[i].mul(&s[m - i]));
                }
                let term = self.c[j].scale(j as f64).mul(&s[m].sub(&ssm));
                acc = Some(match acc {
                    Some(a) => a.add(&term),
                    None => term,
                });
            }
            s.push(match acc {
                Some(a) => a.scale(1.0 / k as f64),
                None => s[0].lift(0.0),
            });
        }
        SeriesOf { c: s, nz: vec![true; k1] }
    }
}

/// Derivative coefficients `[x_1, ..., x_order]` (each a length-n vector of
/// `T`) of the solution of dz/dt = f(z, t) through `(z0, t0)` — Algorithm 1
/// with [`Value`] coefficients, mirroring
/// [`ode_jet`](crate::taylor::ode_jet) / the batched
/// [`ode_jet_batch`](crate::taylor::ode_jet_batch).
///
/// With `T = `[`Var`](crate::autodiff::Var), the returned jets are tape
/// nodes: seeding a cotangent on (a function of) `x_K` back-propagates
/// through the whole Taylor-mode recursion, including every inner `f`
/// evaluation — exact reverse-over-Taylor, no truncation.
pub fn ode_jet_values<T, F>(f: &mut F, z0: &[T], t0: &T, order: usize) -> Vec<Vec<T>>
where
    T: Value,
    F: FnMut(&[SeriesOf<T>], &SeriesOf<T>) -> Vec<SeriesOf<T>>,
{
    let n = z0.len();
    assert!(n > 0, "ode_jet_values: state must be non-empty");
    assert!(order >= 1, "ode_jet_values: order must be >= 1");
    let mut x: Vec<Vec<T>> = Vec::with_capacity(order);
    // x_1 = f(z0, t0)
    let zs: Vec<SeriesOf<T>> = z0.iter().map(|z| SeriesOf::constant(z.clone(), 0)).collect();
    let y = f(&zs, &SeriesOf::time(t0.clone(), 0));
    assert_eq!(y.len(), n, "ode_jet_values: f output arity");
    x.push(y.into_iter().map(|s| s.c[0].clone()).collect());
    for k in 1..order {
        // The k-truncated solution path: [z0, x_1/1!, ..., x_k/k!].
        let zs: Vec<SeriesOf<T>> = (0..n)
            .map(|i| {
                let mut c: Vec<T> = Vec::with_capacity(k + 1);
                c.push(z0[i].clone());
                for (j, xj) in x.iter().enumerate() {
                    c.push(xj[i].scale(1.0 / factorial(j + 1)));
                }
                SeriesOf::new(c)
            })
            .collect();
        let y = f(&zs, &SeriesOf::time(t0.clone(), k));
        assert_eq!(y.len(), n, "ode_jet_values: f output arity");
        // dz/dt = y  =>  x_{k+1} = k! * y_[k]
        let fct = factorial(k);
        x.push(y.into_iter().map(|s| s.c[k].scale(fct)).collect());
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Tape;
    use crate::taylor::{ode_jet, Series};
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    fn to_f64_series(s: &Series) -> SeriesOf<f64> {
        SeriesOf::new(s.c.clone())
    }

    #[test]
    fn generic_ops_match_scalar_series_property() {
        // SeriesOf<f64> must reproduce the taylor::Series propagation rules
        // (tolerance-level: the generic code uses scale where the scalar
        // uses division, which differs in the last ulp).
        Prop::new(80).run("seriesof-vs-series", |rng: &mut Pcg, _| {
            let k = 1 + rng.below(5);
            let a = Series::new(gen::vec_f64(rng, k + 1, -1.5, 1.5));
            let b = Series::new(gen::vec_f64(rng, k + 1, -1.5, 1.5));
            let (ga, gb) = (to_f64_series(&a), to_f64_series(&b));
            let checks: [(Series, SeriesOf<f64>); 7] = [
                (a.add(&b), ga.add(&gb)),
                (a.sub(&b), ga.sub(&gb)),
                (a.mul(&b), ga.mul(&gb)),
                (a.scale(0.7), ga.scale(0.7)),
                (a.tanh(), ga.tanh()),
                (a.exp(), ga.exp()),
                (a.sigmoid(), ga.sigmoid()),
            ];
            for (want, got) in &checks {
                for (j, w) in want.c.iter().enumerate() {
                    assert!(
                        close(*got.coeff(j), *w, 1e-12),
                        "coeff {j}: {} vs {w}",
                        got.coeff(j)
                    );
                }
            }
        });
    }

    #[test]
    fn masked_constants_match_dense_evaluation_property() {
        // The structural-zero skip must not change any value: random
        // expressions over a masked constant (SeriesOf::constant), the
        // masked time series, and a dense series must agree with the same
        // expressions over fully-dense operands.  Plain `==` is the right
        // comparison: skipped terms are exact zeros, so only the sign of a
        // zero may differ, and -0.0 == 0.0.
        Prop::new(60).run("seriesof-masked-vs-dense", |rng: &mut Pcg, _| {
            let ord = 1 + rng.below(5);
            let p = rng.range(-1.5, 1.5) as f64;
            let t0 = rng.range(-1.0, 1.0) as f64;
            let z = SeriesOf::new(gen::vec_f64(rng, ord + 1, -1.5, 1.5));
            // dense twins: same values, no masks
            let mut cp = vec![0.0f64; ord + 1];
            cp[0] = p;
            let mut ct = vec![0.0f64; ord + 1];
            ct[0] = t0;
            if ord >= 1 {
                ct[1] = 1.0;
            }
            let (pm, pd) = (SeriesOf::constant(p, ord), SeriesOf::new(cp));
            let (tm, td) = (SeriesOf::time(t0, ord), SeriesOf::new(ct));
            let run = |pv: &SeriesOf<f64>, tv: &SeriesOf<f64>| {
                // the shape of one concat-squash neuron: a tanh body, a
                // sigmoid time gate, and a linear time bias
                z.mul(pv)
                    .add(&pv.scale(0.5))
                    .tanh()
                    .mul(&tv.mul(pv).sigmoid())
                    .sub(&tv.scale(-0.7))
                    .add(&z.mul(pv).exp().scale(0.1))
            };
            let (got, want) = (run(&pm, &tm), run(&pd, &td));
            for k in 0..=ord {
                assert!(
                    got.coeff(k) == want.coeff(k),
                    "coeff {k}: masked {} vs dense {}",
                    got.coeff(k),
                    want.coeff(k)
                );
            }
        });
    }

    #[test]
    fn masked_constants_record_fewer_tape_nodes() {
        // The point of the mask: a constant parameter series on the tape
        // must not record arithmetic for its structurally-zero columns.
        let ord = 4usize;
        let count = |dense: bool| {
            let tape = Tape::new(2);
            let z = SeriesOf::new(
                (0..=ord).map(|k| tape.input(&[0.3 + k as f64, -0.9])).collect(),
            );
            let p = tape.param(0, 0.7);
            let ps = if dense {
                let zero = tape.constant(0.0);
                let mut c = vec![p];
                c.extend((0..ord).map(|_| zero.clone()));
                SeriesOf::new(c)
            } else {
                SeriesOf::constant(p, ord)
            };
            let before = tape.len();
            let _ = z.mul(&ps).tanh();
            tape.len() - before
        };
        let (dense, masked) = (count(true), count(false));
        assert!(
            masked < dense,
            "masked {masked} nodes should beat dense {dense}"
        );
    }

    #[test]
    fn jet_matches_scalar_ode_jet_property() {
        // ode_jet_values with T = f64 must agree with taylor::ode_jet on
        // random nonlinear dynamics, orders, and expansion points.
        Prop::new(60).run("jet-values-vs-scalar", |rng: &mut Pcg, _| {
            let order = 1 + rng.below(5);
            let z0 = rng.range(-1.2, 1.2) as f64;
            let t0 = rng.range(-1.0, 1.0) as f64;
            let (a, w) = (rng.range(-1.0, 1.0) as f64, rng.range(0.5, 2.0) as f64);
            // dz/dt = a·tanh(z) + w·z·t, written once per series flavor with
            // the same op sequence.
            let scalar = ode_jet(
                |z: &Series, t: &Series| z.tanh().scale(a).add(&z.mul(t).scale(w)),
                z0,
                t0,
                order,
            );
            let generic = ode_jet_values(
                &mut |z: &[SeriesOf<f64>], t: &SeriesOf<f64>| {
                    vec![z[0].tanh().scale(a).add(&z[0].mul(t).scale(w))]
                },
                &[z0],
                &t0,
                order,
            );
            assert_eq!(generic.len(), order);
            for (k, want) in scalar.iter().enumerate() {
                assert!(
                    close(generic[k][0], *want, 1e-10),
                    "order {k}: {} vs {want}",
                    generic[k][0]
                );
            }
        });
    }

    #[test]
    fn jet_multi_dim_coupled_system() {
        // (x, v)' = (v, -x) through (1, 0) at t = 0: x^(k) cycles
        // 1, 0, -1, 0 and v^(k) cycles 0, -1, 0, 1.
        let jets = ode_jet_values(
            &mut |z: &[SeriesOf<f64>], _t: &SeriesOf<f64>| {
                vec![z[1].clone(), z[0].scale(-1.0)]
            },
            &[1.0f64, 0.0],
            &0.0,
            4,
        );
        let want_x = [0.0, -1.0, 0.0, 1.0];
        let want_v = [-1.0, 0.0, 1.0, 0.0];
        for k in 0..4 {
            assert!(close(jets[k][0], want_x[k], 1e-12), "x order {k}");
            assert!(close(jets[k][1], want_v[k], 1e-12), "v order {k}");
        }
    }

    #[test]
    fn time_and_constant_builders() {
        let t = SeriesOf::time(0.5f64, 3);
        assert_eq!(t.order(), 3);
        assert_eq!(*t.coeff(0), 0.5);
        assert_eq!(*t.coeff(1), 1.0);
        assert_eq!(*t.coeff(2), 0.0);
        let c = SeriesOf::constant(2.0f64, 0);
        assert_eq!(c.order(), 0);
        let l = c.lift(7.0);
        assert_eq!(*l.coeff(0), 7.0);
        // the shared-zero builder pins the same structure as `constant`
        let shared = SeriesOf::constant_padded(2.0f64, &0.0, 3);
        for k in 0..=3 {
            assert_eq!(shared.coeff(k), SeriesOf::constant(2.0f64, 3).coeff(k));
        }
    }
}
