//! Truncated Taylor series *over* an arbitrary [`Value`] carrier, plus the
//! value-generic ODE-solution jet.
//!
//! [`SeriesOf<T>`] applies the scalar propagation rules of
//! [`crate::taylor::Series`] with coefficients in `T` instead of `f64`.
//! With `T = f64` it reproduces the scalar series; with
//! `T = `[`Var`](crate::autodiff::Var) every coefficient is a reverse-mode
//! tape node, so [`ode_jet_values`] — Algorithm 1 with value coefficients —
//! makes the Taylor-mode `R_K` integrand itself differentiable: the
//! discrete-adjoint backward pass seeds the K-th derivative's square and
//! gets exact parameter cotangents through the whole jet recursion.
//!
//! ```
//! use taynode::nn::{ode_jet_values, SeriesOf};
//!
//! // dz/dt = z with f64 coefficients: every derivative equals z0.
//! let jets = ode_jet_values(
//!     &mut |z: &[SeriesOf<f64>], _t: &SeriesOf<f64>| vec![z[0].clone()],
//!     &[2.0f64],
//!     &0.0,
//!     3,
//! );
//! for x in &jets {
//!     assert_eq!(x[0], 2.0);
//! }
//! ```

use super::Value;
use crate::taylor::factorial;

/// A truncated Taylor polynomial `sum_k c[k] t^k` with coefficients in any
/// [`Value`] carrier.
#[derive(Clone, Debug)]
pub struct SeriesOf<T> {
    c: Vec<T>,
}

impl<T: Value> SeriesOf<T> {
    pub fn new(c: Vec<T>) -> SeriesOf<T> {
        assert!(!c.is_empty(), "SeriesOf needs at least the order-0 coefficient");
        SeriesOf { c }
    }

    /// A constant series: `x` at order 0, zeros (of `x`'s shape) above.
    pub fn constant(x: T, order: usize) -> SeriesOf<T> {
        let zero = x.lift(0.0);
        let mut c = Vec::with_capacity(order + 1);
        c.push(x);
        for _ in 0..order {
            c.push(zero.clone());
        }
        SeriesOf { c }
    }

    /// The independent variable itself: `t0 + 1·t`.
    pub fn time(t0: T, order: usize) -> SeriesOf<T> {
        let one = t0.lift(1.0);
        let zero = t0.lift(0.0);
        let mut c = Vec::with_capacity(order + 1);
        c.push(t0);
        if order >= 1 {
            c.push(one);
        }
        for _ in 1..order {
            c.push(zero.clone());
        }
        SeriesOf { c }
    }

    pub fn order(&self) -> usize {
        self.c.len() - 1
    }

    pub fn coeff(&self, k: usize) -> &T {
        &self.c[k]
    }
}

/// The scalar propagation rules of [`crate::taylor::Series`], coefficient
/// arithmetic delegated to `T` — so a `SeriesOf<Var>` records every
/// coefficient operation on the tape.
impl<T: Value> Value for SeriesOf<T> {
    fn lift(&self, a: f64) -> Self {
        SeriesOf::constant(self.c[0].lift(a), self.order())
    }

    fn add(&self, o: &Self) -> Self {
        assert_eq!(self.order(), o.order(), "SeriesOf::add: order mismatch");
        let c = self.c.iter().zip(&o.c).map(|(a, b)| a.add(b)).collect();
        SeriesOf { c }
    }

    fn sub(&self, o: &Self) -> Self {
        assert_eq!(self.order(), o.order(), "SeriesOf::sub: order mismatch");
        let c = self.c.iter().zip(&o.c).map(|(a, b)| a.sub(b)).collect();
        SeriesOf { c }
    }

    /// Truncated Cauchy product (Table 1 row 2), inner terms in the scalar
    /// operation order (ascending j).
    fn mul(&self, o: &Self) -> Self {
        assert_eq!(self.order(), o.order(), "SeriesOf::mul: order mismatch");
        let k1 = self.c.len();
        let mut out = Vec::with_capacity(k1);
        for k in 0..k1 {
            let mut acc = self.c[0].mul(&o.c[k]);
            for j in 1..=k {
                acc = acc.add(&self.c[j].mul(&o.c[k - j]));
            }
            out.push(acc);
        }
        SeriesOf { c: out }
    }

    fn scale(&self, a: f64) -> Self {
        let c = self.c.iter().map(|x| x.scale(a)).collect();
        SeriesOf { c }
    }

    /// tanh via the ODE s' = (1 - s²) z', coefficients in `T`.
    fn tanh(&self) -> Self {
        let k1 = self.c.len();
        let mut s: Vec<T> = Vec::with_capacity(k1);
        s.push(self.c[0].tanh());
        for k in 1..k1 {
            let mut acc: Option<T> = None;
            for j in 1..=k {
                let m = k - j;
                // u[m] = delta_{m0} - (s*s)[m], with s[0..=m] already known
                let mut ssm = s[0].mul(&s[m]);
                for i in 1..=m {
                    ssm = ssm.add(&s[i].mul(&s[m - i]));
                }
                let u = if m == 0 { ssm.lift(1.0).sub(&ssm) } else { ssm.scale(-1.0) };
                let term = self.c[j].scale(j as f64).mul(&u);
                acc = Some(match acc {
                    Some(a) => a.add(&term),
                    None => term,
                });
            }
            s.push(acc.expect("k >= 1 always yields a term").scale(1.0 / k as f64));
        }
        SeriesOf { c: s }
    }
}

/// Derivative coefficients `[x_1, ..., x_order]` (each a length-n vector of
/// `T`) of the solution of dz/dt = f(z, t) through `(z0, t0)` — Algorithm 1
/// with [`Value`] coefficients, mirroring
/// [`ode_jet`](crate::taylor::ode_jet) / the batched
/// [`ode_jet_batch`](crate::taylor::ode_jet_batch).
///
/// With `T = `[`Var`](crate::autodiff::Var), the returned jets are tape
/// nodes: seeding a cotangent on (a function of) `x_K` back-propagates
/// through the whole Taylor-mode recursion, including every inner `f`
/// evaluation — exact reverse-over-Taylor, no truncation.
pub fn ode_jet_values<T, F>(f: &mut F, z0: &[T], t0: &T, order: usize) -> Vec<Vec<T>>
where
    T: Value,
    F: FnMut(&[SeriesOf<T>], &SeriesOf<T>) -> Vec<SeriesOf<T>>,
{
    let n = z0.len();
    assert!(n > 0, "ode_jet_values: state must be non-empty");
    assert!(order >= 1, "ode_jet_values: order must be >= 1");
    let mut x: Vec<Vec<T>> = Vec::with_capacity(order);
    // x_1 = f(z0, t0)
    let zs: Vec<SeriesOf<T>> = z0.iter().map(|z| SeriesOf::constant(z.clone(), 0)).collect();
    let y = f(&zs, &SeriesOf::time(t0.clone(), 0));
    assert_eq!(y.len(), n, "ode_jet_values: f output arity");
    x.push(y.into_iter().map(|s| s.c[0].clone()).collect());
    for k in 1..order {
        // The k-truncated solution path: [z0, x_1/1!, ..., x_k/k!].
        let zs: Vec<SeriesOf<T>> = (0..n)
            .map(|i| {
                let mut c: Vec<T> = Vec::with_capacity(k + 1);
                c.push(z0[i].clone());
                for (j, xj) in x.iter().enumerate() {
                    c.push(xj[i].scale(1.0 / factorial(j + 1)));
                }
                SeriesOf::new(c)
            })
            .collect();
        let y = f(&zs, &SeriesOf::time(t0.clone(), k));
        assert_eq!(y.len(), n, "ode_jet_values: f output arity");
        // dz/dt = y  =>  x_{k+1} = k! * y_[k]
        let fct = factorial(k);
        x.push(y.into_iter().map(|s| s.c[k].scale(fct)).collect());
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taylor::{ode_jet, Series};
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    fn to_f64_series(s: &Series) -> SeriesOf<f64> {
        SeriesOf::new(s.c.clone())
    }

    #[test]
    fn generic_ops_match_scalar_series_property() {
        // SeriesOf<f64> must reproduce the taylor::Series propagation rules
        // (tolerance-level: the generic code uses scale where the scalar
        // uses division, which differs in the last ulp).
        Prop::new(80).run("seriesof-vs-series", |rng: &mut Pcg, _| {
            let k = 1 + rng.below(5);
            let a = Series::new(gen::vec_f64(rng, k + 1, -1.5, 1.5));
            let b = Series::new(gen::vec_f64(rng, k + 1, -1.5, 1.5));
            let (ga, gb) = (to_f64_series(&a), to_f64_series(&b));
            let checks: [(Series, SeriesOf<f64>); 5] = [
                (a.add(&b), ga.add(&gb)),
                (a.sub(&b), ga.sub(&gb)),
                (a.mul(&b), ga.mul(&gb)),
                (a.scale(0.7), ga.scale(0.7)),
                (a.tanh(), ga.tanh()),
            ];
            for (want, got) in &checks {
                for (j, w) in want.c.iter().enumerate() {
                    assert!(
                        close(*got.coeff(j), *w, 1e-12),
                        "coeff {j}: {} vs {w}",
                        got.coeff(j)
                    );
                }
            }
        });
    }

    #[test]
    fn jet_matches_scalar_ode_jet_property() {
        // ode_jet_values with T = f64 must agree with taylor::ode_jet on
        // random nonlinear dynamics, orders, and expansion points.
        Prop::new(60).run("jet-values-vs-scalar", |rng: &mut Pcg, _| {
            let order = 1 + rng.below(5);
            let z0 = rng.range(-1.2, 1.2) as f64;
            let t0 = rng.range(-1.0, 1.0) as f64;
            let (a, w) = (rng.range(-1.0, 1.0) as f64, rng.range(0.5, 2.0) as f64);
            // dz/dt = a·tanh(z) + w·z·t, written once per series flavor with
            // the same op sequence.
            let scalar = ode_jet(
                |z: &Series, t: &Series| z.tanh().scale(a).add(&z.mul(t).scale(w)),
                z0,
                t0,
                order,
            );
            let generic = ode_jet_values(
                &mut |z: &[SeriesOf<f64>], t: &SeriesOf<f64>| {
                    vec![z[0].tanh().scale(a).add(&z[0].mul(t).scale(w))]
                },
                &[z0],
                &t0,
                order,
            );
            assert_eq!(generic.len(), order);
            for (k, want) in scalar.iter().enumerate() {
                assert!(
                    close(generic[k][0], *want, 1e-10),
                    "order {k}: {} vs {want}",
                    generic[k][0]
                );
            }
        });
    }

    #[test]
    fn jet_multi_dim_coupled_system() {
        // (x, v)' = (v, -x) through (1, 0) at t = 0: x^(k) cycles
        // 1, 0, -1, 0 and v^(k) cycles 0, -1, 0, 1.
        let jets = ode_jet_values(
            &mut |z: &[SeriesOf<f64>], _t: &SeriesOf<f64>| {
                vec![z[1].clone(), z[0].scale(-1.0)]
            },
            &[1.0f64, 0.0],
            &0.0,
            4,
        );
        let want_x = [0.0, -1.0, 0.0, 1.0];
        let want_v = [-1.0, 0.0, 1.0, 0.0];
        for k in 0..4 {
            assert!(close(jets[k][0], want_x[k], 1e-12), "x order {k}");
            assert!(close(jets[k][1], want_v[k], 1e-12), "v order {k}");
        }
    }

    #[test]
    fn time_and_constant_builders() {
        let t = SeriesOf::time(0.5f64, 3);
        assert_eq!(t.order(), 3);
        assert_eq!(*t.coeff(0), 0.5);
        assert_eq!(*t.coeff(1), 1.0);
        assert_eq!(*t.coeff(2), 0.0);
        let c = SeriesOf::constant(2.0f64, 0);
        assert_eq!(c.order(), 0);
        let l = c.lift(7.0);
        assert_eq!(*l.coeff(0), 7.0);
    }
}
