//! Neural dynamics models, generic over the scalar type — the native
//! (no-XLA) counterpart of the exported `python/` models, and the substrate
//! of the native training subsystem.
//!
//! The central abstraction is [`Value`]: the closed set of operations a
//! dynamics model is allowed to use (affine maps, products, `tanh`).  One
//! generic forward pass ([`Mlp::forward`]) then runs unchanged on
//!
//! * `f64` — plain per-example evaluation (the reference semantics);
//! * [`Series`](crate::taylor::Series) / [`SeriesVec`](crate::taylor::SeriesVec)
//!   — truncated Taylor series, so the *same* forward code is jetted by
//!   `taylor::ode_jet_batch` and the paper's `R_K` regularizer rides the
//!   batched solver engine for free;
//! * [`Var`](crate::autodiff::Var) — reverse-mode tape values, so the same
//!   forward code yields exact parameter/input gradients for the discrete
//!   adjoint (`coordinator::train_native`);
//! * [`SeriesOf<T>`](series::SeriesOf) — series *over* any of the above,
//!   which is how `R_K`'s Taylor-mode integrand itself is differentiated
//!   (series of tape values: Taylor mode outside, reverse mode inside).
//!
//! ```
//! use taynode::nn::Mlp;
//! use taynode::taylor::Series;
//!
//! // One forward pass, two scalar types: plain f64 and truncated series.
//! let mlp = Mlp::new(1, &[4], true, 0);
//! let y = mlp.forward_f64(&[0.3], 0.0);
//! let p: Vec<Series> = mlp.lift_params(&Series::constant(0.0, 2));
//! let z = Series::constant(0.3, 2);
//! let t = Series::time(0.0, 2);
//! let ys = mlp.forward(&p, &[z], Some(&t));
//! assert!((ys[0].c[0] - y[0]).abs() < 1e-12);
//! ```

pub mod cnf;
pub mod mlp;
pub mod series;

pub use cnf::{Cnf, ConcatSquash};
pub use mlp::Mlp;
pub use series::{ode_jet_values, SeriesOf};

use crate::taylor::{Series, SeriesVec};

/// The scalar algebra a [`Mlp`] forward pass is written against.
///
/// Implementations must apply the *same* mathematical operation whatever
/// the carrier is — a number, a whole `[B, 1]` batch column, a truncated
/// Taylor series, or a tape node recording itself for reverse mode.
/// `lift` turns an `f64` constant (a parameter, a coefficient) into a value
/// of the receiver's shape; it is how shape-free constants meet shaped
/// carriers without the trait needing shape arguments.
pub trait Value: Clone {
    /// A constant `a` with `self`'s shape (rows / series order / tape).
    fn lift(&self, a: f64) -> Self;
    fn add(&self, o: &Self) -> Self;
    fn sub(&self, o: &Self) -> Self;
    fn mul(&self, o: &Self) -> Self;
    /// Multiply by an `f64` constant (cheaper than `lift` + `mul`).
    fn scale(&self, a: f64) -> Self;
    fn tanh(&self) -> Self;
    fn exp(&self) -> Self;
    /// Logistic sigmoid `1/(1 + e^{-x})` — the concat-squash gate
    /// nonlinearity ([`cnf::ConcatSquash`]).
    fn sigmoid(&self) -> Self;
}

/// A vector field `dz/dt = f(z, t)` written **once** against [`Value`] and
/// evaluable on *any* carrier per call — the capability the divergence
/// engine ([`crate::autodiff::div`]) needs: it runs the same forward on
/// reverse-mode tape columns (`T = `[`Var`](crate::autodiff::Var)) to pull
/// exact or Hutchinson-estimated divergences out of one recording, and
/// tests run it on plain `f64`.  Parameters are lifted internally as
/// constants of the carrier's shape (the *training* tape path does not go
/// through this trait — it creates gradient-tracked parameter leaves).
///
/// Unlike [`BatchDynamics`](crate::solvers::batch::BatchDynamics) this is
/// carrier-polymorphic per call, so it cannot be a trait object; use it as
/// a generic bound.
pub trait ValueDynamics {
    /// Per-trajectory state dimension n.
    fn dim(&self) -> usize;
    /// Evaluate `f(z, t)` with activations, parameters, and time in `T`.
    fn forward_values<T: Value>(&self, z: &[T], t: &T) -> Vec<T>;
}

impl Value for f64 {
    fn lift(&self, a: f64) -> f64 {
        a
    }

    fn add(&self, o: &f64) -> f64 {
        self + o
    }

    fn sub(&self, o: &f64) -> f64 {
        self - o
    }

    fn mul(&self, o: &f64) -> f64 {
        self * o
    }

    fn scale(&self, a: f64) -> f64 {
        a * self
    }

    fn tanh(&self) -> f64 {
        f64::tanh(*self)
    }

    fn exp(&self) -> f64 {
        f64::exp(*self)
    }

    fn sigmoid(&self) -> f64 {
        1.0 / (1.0 + f64::exp(-self))
    }
}

/// Scalar truncated Taylor series: the propagation rules of
/// [`crate::taylor`], seen through the model-facing algebra.
impl Value for Series {
    fn lift(&self, a: f64) -> Series {
        Series::constant(a, self.order())
    }

    fn add(&self, o: &Series) -> Series {
        Series::add(self, o)
    }

    fn sub(&self, o: &Series) -> Series {
        Series::sub(self, o)
    }

    fn mul(&self, o: &Series) -> Series {
        Series::mul(self, o)
    }

    fn scale(&self, a: f64) -> Series {
        Series::scale(self, a)
    }

    fn tanh(&self) -> Series {
        Series::tanh(self)
    }

    fn exp(&self) -> Series {
        Series::exp(self)
    }

    fn sigmoid(&self) -> Series {
        Series::sigmoid(self)
    }
}

/// Batched truncated Taylor series (an SoA `[rows, cols]` matrix per
/// coefficient): model activations are `[B, 1]` columns, so one generic
/// forward pass evaluates the whole active set.
impl Value for SeriesVec {
    fn lift(&self, a: f64) -> SeriesVec {
        SeriesVec::fill(a, self.rows(), self.cols(), self.order())
    }

    fn add(&self, o: &SeriesVec) -> SeriesVec {
        SeriesVec::add(self, o)
    }

    fn sub(&self, o: &SeriesVec) -> SeriesVec {
        SeriesVec::sub(self, o)
    }

    fn mul(&self, o: &SeriesVec) -> SeriesVec {
        SeriesVec::mul(self, o)
    }

    fn scale(&self, a: f64) -> SeriesVec {
        SeriesVec::scale(self, a)
    }

    fn tanh(&self) -> SeriesVec {
        SeriesVec::tanh(self)
    }

    fn exp(&self) -> SeriesVec {
        SeriesVec::exp(self)
    }

    fn sigmoid(&self) -> SeriesVec {
        SeriesVec::sigmoid(self)
    }
}
