//! Model-backed request handlers and the serving loop drivers.
//!
//! A [`ServeHost`] routes [`ServeRequest`]s onto per-model
//! [`ServingEngine`]s — the demo set serves the repo's three workloads
//! (`toy`: a 2-d MLP field, `mnist`: a synth-MNIST-shaped MLP, `density`:
//! a CNF scored by NLL at retirement) — and turns retired trajectories
//! into [`ServeResponse`]s.
//!
//! [`run_poisson`] / [`run_poisson_pooled`] / [`run_poisson_drain`] drive
//! a demo host under seeded Poisson arrivals ([`PoissonArrivals`]) with a
//! seeded request generator ([`RequestGen`]), producing a [`ServeTrace`]
//! that is a pure function of the seed: the pooled drive is bit-identical
//! to the serial one at any thread count (D5 test below), and two
//! same-seed runs replay the identical trace ([`trace_hash`] witnesses
//! this cheaply).

use crate::autodiff::div::Divergence;
use crate::coordinator::evaluator::latent_nll;
use crate::data::synth_mnist;
use crate::nn::{Cnf, Mlp};
use crate::obs::{Recorder, SloTracker};
use crate::serving::arrivals::PoissonArrivals;
use crate::serving::engine::{AdmissionPolicy, ServeOutcome, ServingEngine, ToleranceClass};
use crate::serving::wire::{ServeRequest, ServeResponse};
use crate::solvers::batch::{BatchDynamics, LogDetBatchDynamics, PooledEval};
use crate::solvers::tableau;
use crate::util::pool::Pool;
use crate::util::rng::Pcg;

/// The one dynamics type every demo engine runs, so hosts stay a single
/// generic instantiation (and pooled hosts just wrap it in
/// [`PooledEval`]).
#[derive(Clone)]
pub enum ServeDynamics {
    /// Plain MLP vector field (`toy`, `mnist`).
    Mlp(Mlp),
    /// Log-det-augmented CNF (`density`): state `[z, ℓ]`, scored by NLL.
    Density(LogDetBatchDynamics<Cnf>),
}

impl BatchDynamics for ServeDynamics {
    fn dim(&self) -> usize {
        match self {
            ServeDynamics::Mlp(m) => BatchDynamics::dim(m),
            ServeDynamics::Density(d) => BatchDynamics::dim(d),
        }
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        match self {
            ServeDynamics::Mlp(m) => BatchDynamics::eval(m, ids, t, y, dy),
            ServeDynamics::Density(d) => BatchDynamics::eval(d, ids, t, y, dy),
        }
    }
}

struct HostedModel<F: BatchDynamics> {
    name: String,
    /// Request-visible input dimension (pre-augmentation).
    data_dim: usize,
    /// Density models carry a log-det column: requests are augmented with
    /// `ℓ(0) = 0` at admission and scored by NLL at retirement.
    density: bool,
    engine: ServingEngine<F>,
}

/// A set of hosted models sharing one admission step clock; see the
/// module docs.
pub struct ServeHost<F: BatchDynamics> {
    models: Vec<HostedModel<F>>,
}

/// The demo host: `toy`, `mnist`, and `density` engines with `capacity`
/// rows each, deterministically initialized from `seed`.
pub fn demo_host(seed: u64, capacity: usize) -> ServeHost<ServeDynamics> {
    demo_host_with(seed, capacity, |d| d)
}

/// [`demo_host`] with each model's dynamics passed through `wrap` — how
/// the pooled drive substitutes [`PooledEval`] without a second host type.
pub fn demo_host_with<F: BatchDynamics>(
    seed: u64,
    capacity: usize,
    wrap: impl Fn(ServeDynamics) -> F,
) -> ServeHost<F> {
    let tb = tableau::dopri5();
    let models = vec![
        HostedModel {
            name: "toy".to_string(),
            data_dim: 2,
            density: false,
            engine: ServingEngine::new(
                wrap(ServeDynamics::Mlp(Mlp::new(2, &[16, 16], true, seed ^ 0x7071))),
                &tb,
                capacity,
                0.0,
                1.0,
            ),
        },
        HostedModel {
            name: "mnist".to_string(),
            data_dim: synth_mnist::DIM,
            density: false,
            engine: ServingEngine::new(
                wrap(ServeDynamics::Mlp(Mlp::new(
                    synth_mnist::DIM,
                    &[32],
                    true,
                    seed ^ 0x7072,
                ))),
                &tb,
                capacity,
                0.0,
                1.0,
            ),
        },
        HostedModel {
            name: "density".to_string(),
            data_dim: 2,
            density: true,
            engine: ServingEngine::new(
                wrap(ServeDynamics::Density(LogDetBatchDynamics::new(
                    Cnf::new(2, &[8], seed ^ 0x7073),
                    Divergence::Exact,
                ))),
                &tb,
                capacity,
                0.0,
                1.0,
            ),
        },
    ];
    ServeHost { models }
}

impl<F: BatchDynamics> ServeHost<F> {
    pub fn set_policy(&mut self, policy: AdmissionPolicy) {
        for m in &mut self.models {
            m.engine.set_policy(policy);
        }
    }

    /// `(name, data_dim)` per hosted model, for request generation.
    pub fn model_specs(&self) -> Vec<(String, usize)> {
        self.models.iter().map(|m| (m.name.clone(), m.data_dim)).collect()
    }

    /// Turn on telemetry on every hosted engine (see
    /// [`ServingEngine::enable_recording`]).
    pub fn enable_recording(&mut self) {
        for m in &mut self.models {
            m.engine.enable_recording();
        }
    }

    /// Take every engine's recorder as `(model name, recorder)` in
    /// declaration order — the fixed order the trace export relies on.
    pub fn take_recorders(&mut self) -> Vec<(String, Recorder)> {
        self.models
            .iter_mut()
            .map(|m| (m.name.clone(), m.engine.take_recorder()))
            .collect()
    }

    /// Turn on per-class SLO scoring on every hosted engine, each with the
    /// default budgets (see [`ServingEngine::enable_slo`]).
    pub fn enable_slo(&mut self) {
        for m in &mut self.models {
            m.engine.enable_slo(SloTracker::standard());
        }
    }

    /// Take every engine's SLO tracker as `(model name, tracker)` in
    /// declaration order; engines that never had scoring on contribute an
    /// empty tracker so the report shape stays fixed.
    pub fn take_slos(&mut self) -> Vec<(String, SloTracker)> {
        self.models
            .iter_mut()
            .map(|m| {
                (
                    m.name.clone(),
                    m.engine.take_slo().unwrap_or_else(SloTracker::standard),
                )
            })
            .collect()
    }

    pub fn in_flight(&self) -> usize {
        self.models.iter().map(|m| m.engine.in_flight()).sum()
    }

    pub fn queued(&self) -> usize {
        self.models.iter().map(|m| m.engine.queued()).sum()
    }

    pub fn is_idle(&self) -> bool {
        self.models.iter().all(|m| m.engine.is_idle())
    }

    /// Aggregate mean occupancy across engines, weighted by busy steps.
    pub fn occupancy(&self) -> f64 {
        let rows: u64 = self.models.iter().map(|m| m.engine.active_row_steps()).sum();
        let cap: f64 = self
            .models
            .iter()
            .map(|m| m.engine.busy_steps() as f64 * m.engine.capacity() as f64)
            .sum();
        if cap == 0.0 {
            0.0
        } else {
            rows as f64 / cap
        }
    }

    /// Route a request to its model's queue.  A malformed request gets an
    /// immediate error response (`Some`); a routed one answers through a
    /// later [`step`](ServeHost::step).
    pub fn submit(&mut self, req: &ServeRequest) -> Option<ServeResponse> {
        let m = match self.models.iter_mut().find(|m| m.name == req.model) {
            Some(m) => m,
            None => return Some(error_response(req, "unknown model")),
        };
        let class = match ToleranceClass::by_name(&req.class) {
            Some(c) => c,
            None => return Some(error_response(req, "unknown tolerance class")),
        };
        if req.x.len() != m.data_dim {
            return Some(error_response(
                req,
                &format!("input length {} != model dimension {}", req.x.len(), m.data_dim),
            ));
        }
        let mut y0 = req.x.clone();
        if m.density {
            y0.push(0.0); // ℓ(0) = 0 — the log-det column
        }
        match m.engine.submit(req.id, class, y0) {
            Ok(()) => None,
            Err(e) => Some(error_response(req, &format!("{e}"))),
        }
    }

    /// One engine step across every hosted model, in declaration order.
    pub fn step(&mut self) -> Vec<ServeResponse> {
        let mut out = Vec::new();
        for m in &mut self.models {
            let outcomes = m.engine.step();
            for o in outcomes {
                out.push(response_of(&m.name, m.data_dim, m.density, o));
            }
        }
        out
    }
}

fn error_response(req: &ServeRequest, msg: &str) -> ServeResponse {
    ServeResponse {
        id: req.id,
        model: req.model.clone(),
        class: req.class.clone(),
        ok: false,
        error: msg.to_string(),
        ..ServeResponse::default()
    }
}

/// Score a retired trajectory: density models split `[z, ℓ]` and attach
/// the latent NLL; non-finite states are sanitized into error responses
/// (the wire rejects NaN/Inf by design).
fn response_of(model: &str, data_dim: usize, density: bool, o: ServeOutcome) -> ServeResponse {
    let (mut y, mut score) = if density {
        let z = o.y[..data_dim].to_vec();
        let nll = latent_nll(&z, o.y[data_dim]);
        (z, vec![nll])
    } else {
        (o.y, Vec::new())
    };
    let finite =
        y.iter().all(|v| v.is_finite()) && score.iter().all(|v| v.is_finite());
    let error = if finite {
        String::new()
    } else {
        y.clear();
        score.clear();
        "non-finite state at retirement".to_string()
    };
    ServeResponse {
        id: o.id,
        model: model.to_string(),
        class: o.class.name.to_string(),
        ok: finite,
        error,
        y,
        score,
        nfe: o.stats.nfe as u64,
        accepted: o.stats.accepted as u64,
        rejected: o.stats.rejected as u64,
        admit_step: o.admit_step,
        done_step: o.done_step,
        deadline_miss: o.deadline_miss,
    }
}

/// Seeded synthetic request stream: uniform model choice, a 50/40/10
/// realtime/standard/precise class mix, rendered digits for `mnist` and
/// standard-normal inputs elsewhere.  A pure function of `(seed, specs)`.
pub struct RequestGen {
    rng: Pcg,
    specs: Vec<(String, usize)>,
}

impl RequestGen {
    pub fn new(seed: u64, specs: Vec<(String, usize)>) -> RequestGen {
        assert!(!specs.is_empty(), "RequestGen: no models to draw from");
        RequestGen { rng: Pcg::with_stream(seed, 0x5E9F_D007), specs }
    }

    /// The `id`-th request of the stream.
    pub fn next(&mut self, id: u64) -> ServeRequest {
        let m = self.rng.below(self.specs.len());
        let (name, dim) = (self.specs[m].0.clone(), self.specs[m].1);
        let class = match self.rng.below(10) {
            0..=4 => "realtime",
            5..=8 => "standard",
            _ => "precise",
        };
        let x = if name == "mnist" && dim == synth_mnist::DIM {
            synth_mnist::render(id as usize % synth_mnist::N_CLASS, &mut self.rng)
        } else {
            (0..dim).map(|_| 0.5 * self.rng.normal()).collect()
        };
        ServeRequest { id, model: name, class: class.to_string(), x }
    }
}

/// Everything one serving drive produced.  Fully deterministic given the
/// seed (wall-clock latency lives in the bench, not here), so traces can
/// be compared with `==` across runs and thread counts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeTrace {
    /// Responses in completion order (error responses at submit time).
    pub responses: Vec<ServeResponse>,
    pub submitted: u64,
    pub errors: u64,
    /// Engine steps until the last request drained.
    pub steps: u64,
    /// Aggregate mean batch occupancy over busy steps.
    pub mean_occupancy: f64,
}

/// Drive `host` under Poisson(`rate`) arrivals until `total` requests
/// have been submitted and all have answered.
pub fn drive_poisson<F: BatchDynamics>(
    host: &mut ServeHost<F>,
    seed: u64,
    rate: f64,
    total: u64,
) -> ServeTrace {
    let mut arrivals = PoissonArrivals::new(seed, rate);
    let mut gen = RequestGen::new(seed, host.model_specs());
    let mut trace = ServeTrace::default();
    let guard = 20_000 + total.saturating_mul(8192);
    while trace.submitted < total || !host.is_idle() {
        assert!(trace.steps < guard, "serving loop failed to drain");
        if trace.submitted < total {
            let k = (arrivals.next_count() as u64).min(total - trace.submitted);
            for _ in 0..k {
                let req = gen.next(trace.submitted);
                trace.submitted += 1;
                if let Some(err) = host.submit(&req) {
                    trace.errors += 1;
                    trace.responses.push(err);
                }
            }
        }
        trace.responses.extend(host.step());
        trace.steps += 1;
    }
    trace.mean_occupancy = host.occupancy();
    trace
}

/// Serve `total` demo requests under Poisson arrivals, serially.
pub fn run_poisson(seed: u64, capacity: usize, rate: f64, total: u64) -> ServeTrace {
    let mut host = demo_host(seed, capacity);
    drive_poisson(&mut host, seed, rate, total)
}

/// [`run_poisson`] with every model evaluation sharded across `pool` via
/// [`PooledEval`] — bit-identical to the serial drive at any thread count
/// (rows are independent and global ids pass through shards verbatim; the
/// D5 proof is below).
pub fn run_poisson_pooled(
    pool: &Pool,
    seed: u64,
    capacity: usize,
    rate: f64,
    total: u64,
) -> ServeTrace {
    let mut host = demo_host_with(seed, capacity, |d| PooledEval::new(pool, d));
    drive_poisson(&mut host, seed, rate, total)
}

/// [`run_poisson`] with telemetry on: returns the trace plus each model's
/// recorder in declaration order.  Recording never touches the numerics,
/// so the returned trace is bit-identical to the untraced run's.
pub fn run_poisson_traced(
    seed: u64,
    capacity: usize,
    rate: f64,
    total: u64,
) -> (ServeTrace, Vec<(String, Recorder)>) {
    let mut host = demo_host(seed, capacity);
    host.enable_recording();
    let trace = drive_poisson(&mut host, seed, rate, total);
    let recs = host.take_recorders();
    (trace, recs)
}

/// [`run_poisson_traced`] with pooled model evaluation.  The engine loop
/// stays serial (pooling lives inside [`PooledEval`]), so the recorded
/// streams are bit-identical to the serial traced drive at any thread
/// count (D5 proof below).
pub fn run_poisson_traced_pooled(
    pool: &Pool,
    seed: u64,
    capacity: usize,
    rate: f64,
    total: u64,
) -> (ServeTrace, Vec<(String, Recorder)>) {
    let mut host = demo_host_with(seed, capacity, |d| PooledEval::new(pool, d));
    host.enable_recording();
    let trace = drive_poisson(&mut host, seed, rate, total);
    let recs = host.take_recorders();
    (trace, recs)
}

/// [`run_poisson`] with per-class SLO scoring on: returns the trace plus
/// each model's SLO tracker in declaration order.  Scoring only reads
/// the retirement stream, so the trace is bit-identical to the unscored
/// run's.
pub fn run_poisson_slo(
    seed: u64,
    capacity: usize,
    rate: f64,
    total: u64,
) -> (ServeTrace, Vec<(String, SloTracker)>) {
    let mut host = demo_host(seed, capacity);
    host.enable_slo();
    let trace = drive_poisson(&mut host, seed, rate, total);
    let slos = host.take_slos();
    (trace, slos)
}

/// [`run_poisson_slo`] with pooled model evaluation — the SLO fold runs
/// in the serial engine loop over a retirement stream that is itself
/// thread-count independent, so the trackers are bit-identical to the
/// serial drive's at any thread count (D5 proof below).
pub fn run_poisson_slo_pooled(
    pool: &Pool,
    seed: u64,
    capacity: usize,
    rate: f64,
    total: u64,
) -> (ServeTrace, Vec<(String, SloTracker)>) {
    let mut host = demo_host_with(seed, capacity, |d| PooledEval::new(pool, d));
    host.enable_slo();
    let trace = drive_poisson(&mut host, seed, rate, total);
    let slos = host.take_slos();
    (trace, slos)
}

/// The drain-to-stragglers baseline: identical load, but requests are
/// only admitted into an empty active set.  The serving bench asserts the
/// continuous drive's occupancy strictly beats this at equal load.
pub fn run_poisson_drain(seed: u64, capacity: usize, rate: f64, total: u64) -> ServeTrace {
    let mut host = demo_host(seed, capacity);
    host.set_policy(AdmissionPolicy::Drain);
    drive_poisson(&mut host, seed, rate, total)
}

/// FNV-1a over every deterministic response field (ids, step stamps,
/// solver stats, state bits, names) — equal hashes across runs and thread
/// counts witness replay equality without shipping whole traces around.
pub fn trace_hash(responses: &[ServeResponse]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for r in responses {
        mix(r.id);
        mix(r.ok as u64);
        mix(r.deadline_miss as u64);
        mix(r.nfe);
        mix(r.accepted);
        mix(r.rejected);
        mix(r.admit_step);
        mix(r.done_step);
        for v in r.y.iter().chain(&r.score) {
            mix(v.to_bits() as u64);
        }
        for b in r.model.bytes().chain(r.class.bytes()).chain(r.error.bytes()) {
            mix(b as u64);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::solve_adaptive_batch;

    #[test]
    fn run_poisson_pooled_bit_identical_to_serial_across_thread_counts() {
        // The serving determinism acceptance (and the D5 proof for
        // `run_poisson_pooled`): the full loop — arrivals, admission,
        // solves, scoring, response order — replays bit-identically
        // against `run_poisson` at TAYNODE_THREADS ∈ {1, 2, 4}.
        let serial = run_poisson(41, 8, 3.0, 30);
        assert_eq!(serial.submitted, 30);
        assert_eq!(serial.errors, 0);
        assert_eq!(serial.responses.len(), 30);
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let pooled = run_poisson_pooled(&pool, 41, 8, 3.0, 30);
            assert_eq!(serial, pooled, "threads={threads}");
            assert_eq!(
                trace_hash(&serial.responses),
                trace_hash(&pooled.responses),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn run_poisson_traced_pooled_matches_run_poisson_traced_bitwise() {
        // The D5 proof for `run_poisson_traced_pooled`, and the recording
        // no-perturbation guarantee: telemetry on, the drive still equals
        // the untraced `run_poisson`, and the recorded event streams and
        // registries are identical across TAYNODE_THREADS ∈ {1, 2, 4}.
        let untraced = run_poisson(41, 8, 3.0, 30);
        let (serial, srecs) = run_poisson_traced(41, 8, 3.0, 30);
        assert_eq!(untraced, serial, "recording must not perturb the drive");
        assert_eq!(srecs.len(), 3);
        assert!(srecs.iter().any(|(_, r)| !r.events().is_empty()));
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let (pooled, precs) = run_poisson_traced_pooled(&pool, 41, 8, 3.0, 30);
            assert_eq!(serial, pooled, "threads={threads}");
            for ((sn, sr), (pn, pr)) in srecs.iter().zip(&precs) {
                assert_eq!(sn, pn);
                assert_eq!(sr.events(), pr.events(), "model={sn} threads={threads}");
                assert_eq!(sr.registry(), pr.registry(), "model={sn} threads={threads}");
            }
        }
    }

    #[test]
    fn run_poisson_slo_pooled_matches_serial_trackers_bitwise() {
        // The D5 proof for `run_poisson_slo_pooled`, and the scoring
        // no-perturbation guarantee: SLOs on, the drive still equals the
        // unscored `run_poisson`, and every class's windowed tallies are
        // identical across TAYNODE_THREADS ∈ {1, 2, 4}.
        let unscored = run_poisson(41, 8, 3.0, 30);
        let (serial, sslos) = run_poisson_slo(41, 8, 3.0, 30);
        assert_eq!(unscored, serial, "SLO scoring must not perturb the drive");
        assert_eq!(sslos.len(), 3);
        let scored: u64 = sslos
            .iter()
            .map(|(_, s)| s.classes.iter().map(|c| c.done).sum::<u64>())
            .sum();
        assert_eq!(scored, 30, "every retirement must be scored exactly once");
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let (pooled, pslos) = run_poisson_slo_pooled(&pool, 41, 8, 3.0, 30);
            assert_eq!(serial, pooled, "threads={threads}");
            assert_eq!(sslos, pslos, "threads={threads}");
        }
    }

    #[test]
    fn same_seed_replays_the_same_trace() {
        let a = run_poisson(90, 4, 2.0, 16);
        let b = run_poisson(90, 4, 2.0, 16);
        assert_eq!(a, b);
        assert_eq!(trace_hash(&a.responses), trace_hash(&b.responses));
        let c = run_poisson(91, 4, 2.0, 16);
        assert_ne!(trace_hash(&a.responses), trace_hash(&c.responses));
    }

    #[test]
    fn continuous_admission_beats_drain_occupancy_at_equal_load() {
        let cont = run_poisson(5, 8, 6.0, 64);
        let drain = run_poisson_drain(5, 8, 6.0, 64);
        // Identical load (same seed → same requests), so the occupancy
        // gap is purely the admission policy.
        assert_eq!(cont.submitted, drain.submitted);
        assert!(
            cont.mean_occupancy > drain.mean_occupancy,
            "continuous {} vs drain {}",
            cont.mean_occupancy,
            drain.mean_occupancy
        );
    }

    #[test]
    fn malformed_requests_answer_immediately_with_errors() {
        let mut host = demo_host(1, 4);
        let bad_model = ServeRequest {
            id: 1,
            model: "nope".into(),
            class: "standard".into(),
            x: vec![0.0, 0.0],
        };
        let r = host.submit(&bad_model).unwrap();
        assert!(!r.ok);
        assert!(r.error.contains("unknown model"), "{}", r.error);

        let bad_class = ServeRequest {
            class: "warp9".into(),
            model: "toy".into(),
            ..bad_model.clone()
        };
        assert!(host.submit(&bad_class).unwrap().error.contains("class"));

        let bad_dim = ServeRequest { model: "toy".into(), x: vec![1.0; 3], ..bad_model.clone() };
        assert!(host.submit(&bad_dim).unwrap().error.contains("length"));

        let bad_val = ServeRequest {
            model: "toy".into(),
            x: vec![f32::INFINITY, 0.0],
            ..bad_model
        };
        assert!(host.submit(&bad_val).unwrap().error.contains("non-finite"));
        assert!(host.is_idle(), "no malformed request may enter a queue");
    }

    #[test]
    fn density_responses_score_the_solo_latent_nll_bitwise() {
        // One density request through the host == the solo augmented
        // solve + `latent_nll`, bit for bit.
        let seed = 33u64;
        let mut host = demo_host(seed, 4);
        let x = vec![0.45f32, -0.8];
        let req = ServeRequest {
            id: 0,
            model: "density".into(),
            class: "standard".into(),
            x: x.clone(),
        };
        assert!(host.submit(&req).is_none());
        let mut responses = Vec::new();
        while !host.is_idle() {
            responses.extend(host.step());
        }
        assert_eq!(responses.len(), 1);
        let r = &responses[0];
        assert!(r.ok);

        let f = LogDetBatchDynamics::new(Cnf::new(2, &[8], seed ^ 0x7073), Divergence::Exact);
        let y0 = f.augment(&x);
        let opts = crate::serving::engine::STANDARD.opts();
        let solo = solve_adaptive_batch(f, 0.0, 1.0, &y0, &tableau::dopri5(), &opts);
        assert_eq!(r.y.len(), 2);
        for i in 0..2 {
            assert_eq!(r.y[i].to_bits(), solo.y[i].to_bits());
        }
        assert_eq!(r.score.len(), 1);
        assert_eq!(
            r.score[0].to_bits(),
            latent_nll(&solo.y[..2], solo.y[2]).to_bits()
        );
        assert_eq!(r.nfe, solo.stats[0].nfe as u64);
    }
}
