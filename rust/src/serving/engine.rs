//! The continuous-batching core: an admission queue in front of a
//! [`BatchStepper`].
//!
//! A [`ServingEngine`] owns one model's dynamics and a FIFO queue of
//! requests.  Every [`step`](ServingEngine::step) first admits queued
//! requests into free rows of the active set (continuous batching — the
//! batch stays full under load instead of draining to stragglers), then
//! advances every active trajectory by one solver attempt and hands back
//! the requests that retired.
//!
//! **Determinism.** The engine adds no arithmetic of its own: admission
//! only regroups model evaluations, and the [`BatchStepper`] guarantees
//! per-row arithmetic never crosses rows.  A request's state, NFE, and
//! accept/reject history are therefore bit-identical to a solo solve with
//! the same [`ToleranceClass`] — whenever it was admitted, whatever else
//! shared the batch (property-tested below and at the stepper layer).
//!
//! **Deadlines.** A class's `deadline_steps` is its per-request attempt
//! budget, enforced by the solver's own `max_steps` (one engine step is
//! one attempt for every active row), so the deadline changes *when* a
//! request retires but never the arithmetic along the way.  A request
//! that runs out retires with [`ServeOutcome::deadline_miss`] set and the
//! furthest state reached.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::obs::{Counter, Hist, Recorder, SloTracker};
use crate::solvers::batch::{BatchDynamics, BatchStepper, Retired};
use crate::solvers::{AdaptiveOpts, SolveStats, Tableau};

/// A named (tolerance, deadline) service level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ToleranceClass {
    pub name: &'static str,
    pub rtol: f32,
    pub atol: f32,
    /// Attempt budget counted from admission; see the module docs.
    pub deadline_steps: usize,
}

/// Loose tolerance, tight deadline: interactive traffic.
pub const REALTIME: ToleranceClass =
    ToleranceClass { name: "realtime", rtol: 1e-3, atol: 1e-5, deadline_steps: 64 };

/// The solver defaults, with a generous deadline.
pub const STANDARD: ToleranceClass =
    ToleranceClass { name: "standard", rtol: 1e-5, atol: 1e-7, deadline_steps: 512 };

/// Paper-grade tolerance for offline evaluation traffic.
pub const PRECISE: ToleranceClass =
    ToleranceClass { name: "precise", rtol: 1e-7, atol: 1e-9, deadline_steps: 4096 };

/// The wire-nameable classes, loosest first.
pub const CLASSES: &[ToleranceClass] = &[REALTIME, STANDARD, PRECISE];

impl ToleranceClass {
    /// Look up a wire name (`realtime` / `standard` / `precise`).
    pub fn by_name(name: &str) -> Option<ToleranceClass> {
        CLASSES.iter().copied().find(|c| c.name == name)
    }

    /// The solver options this class maps onto.  `deadline_steps` becomes
    /// the per-row `max_steps` budget, which is what keeps a served solve
    /// bit-identical to a solo [`crate::solvers::solve_adaptive_batch`]
    /// call under the same options.
    pub fn opts(&self) -> AdaptiveOpts {
        AdaptiveOpts {
            rtol: self.rtol,
            atol: self.atol,
            max_steps: self.deadline_steps,
            ..AdaptiveOpts::default()
        }
    }
}

/// When queued requests may join the active set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Fill free rows before every step (continuous batching).
    Continuous,
    /// Only admit into an empty active set — the drain-to-stragglers
    /// baseline the serving bench compares occupancy against.
    Drain,
}

/// A retired request: the engine's answer before model-specific scoring.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// The caller's request id.
    pub id: u64,
    /// The class it ran under.
    pub class: ToleranceClass,
    /// Final state (`dim()` entries), at time `t`.
    pub y: Vec<f32>,
    /// Final integration time (`t1`, or short of it on a deadline miss).
    pub t: f32,
    pub stats: SolveStats,
    /// Engine step at which the request was admitted.
    pub admit_step: u64,
    /// Engine step at which it retired.
    pub done_step: u64,
    /// True when the attempt budget expired before reaching `t1`.
    pub deadline_miss: bool,
}

struct QueuedRequest {
    id: u64,
    class: ToleranceClass,
    y0: Vec<f32>,
}

/// Request-id / class bookkeeping per trajectory id.
struct ReqMeta {
    id: u64,
    class: ToleranceClass,
    admit_step: u64,
}

/// One model's continuous-batching loop; see the module docs.
pub struct ServingEngine<F: BatchDynamics> {
    stepper: BatchStepper<F>,
    queue: VecDeque<QueuedRequest>,
    /// Indexed by trajectory id — ids are assigned densely at admission,
    /// so a `Vec` is the map (and stays D1-friendly by construction).
    meta: Vec<ReqMeta>,
    capacity: usize,
    policy: AdmissionPolicy,
    t0: f32,
    t1: f32,
    step_no: u64,
    busy_steps: u64,
    active_row_steps: u64,
    /// Optional per-class SLO scoring, fed on the retirement path.  Boxed
    /// and off by default for the same zero-cost-off reason as the
    /// recorder.
    slo: Option<Box<SloTracker>>,
}

impl<F: BatchDynamics> ServingEngine<F> {
    /// An empty engine integrating requests over `t0 → t1` with at most
    /// `capacity` concurrently active rows.
    pub fn new(f: F, tb: &Tableau, capacity: usize, t0: f32, t1: f32) -> ServingEngine<F> {
        assert!(capacity > 0, "ServingEngine: capacity must be positive");
        assert!(t0 != t1, "ServingEngine: empty integration segment");
        ServingEngine {
            stepper: BatchStepper::new(f, tb),
            queue: VecDeque::new(),
            meta: Vec::new(),
            capacity,
            policy: AdmissionPolicy::Continuous,
            t0,
            t1,
            step_no: 0,
            busy_steps: 0,
            active_row_steps: 0,
            slo: None,
        }
    }

    pub fn set_policy(&mut self, policy: AdmissionPolicy) {
        self.policy = policy;
    }

    /// Turn on telemetry: the stepper records per-row solver data and the
    /// engine adds its serial timeline — queue depth, admission waves,
    /// per-request admit→retire spans — stamped with engine-step ticks.
    /// The engine's step loop is structurally serial (pooling happens
    /// inside the dynamics), so the stream is deterministic for a given
    /// drive at any thread count.
    pub fn enable_recording(&mut self) {
        self.stepper.set_recorder(Recorder::enabled());
    }

    /// Take the recorder out, leaving telemetry off.
    pub fn take_recorder(&mut self) -> Recorder {
        self.stepper.take_recorder()
    }

    /// Turn on per-class SLO scoring: every retirement is tallied against
    /// its tolerance class's deadline-miss budget, in tumbling windows of
    /// engine steps (see [`crate::obs::slo`]).  Independent of the
    /// recorder — SLOs need no event stream.
    pub fn enable_slo(&mut self, slo: SloTracker) {
        self.slo = Some(Box::new(slo));
    }

    /// Take the SLO tracker out, leaving scoring off.
    pub fn take_slo(&mut self) -> Option<SloTracker> {
        self.slo.take().map(|b| *b)
    }

    /// Per-trajectory state dimension.
    pub fn dim(&self) -> usize {
        self.stepper.dim()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests currently in the active set.
    pub fn in_flight(&self) -> usize {
        self.stepper.active()
    }

    /// Requests waiting for a free row.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn is_idle(&self) -> bool {
        self.stepper.active() == 0 && self.queue.is_empty()
    }

    /// Engine steps taken so far.
    pub fn steps(&self) -> u64 {
        self.step_no
    }

    /// Steps on which the active set was non-empty.
    pub fn busy_steps(&self) -> u64 {
        self.busy_steps
    }

    /// Sum over busy steps of the active-set size — the occupancy
    /// numerator.
    pub fn active_row_steps(&self) -> u64 {
        self.active_row_steps
    }

    /// Mean fraction of capacity in use over busy steps (idle steps count
    /// against nobody).  This is the number continuous batching raises
    /// over the [`Drain`](AdmissionPolicy::Drain) baseline.
    pub fn mean_occupancy(&self) -> f64 {
        if self.busy_steps == 0 {
            0.0
        } else {
            self.active_row_steps as f64 / (self.busy_steps as f64 * self.capacity as f64)
        }
    }

    /// Enqueue a request.  It joins the active set at the next [`step`]
    /// with a free row (subject to the [`AdmissionPolicy`]).
    ///
    /// [`step`]: ServingEngine::step
    pub fn submit(&mut self, id: u64, class: ToleranceClass, y0: Vec<f32>) -> Result<()> {
        if y0.len() != self.stepper.dim() {
            bail!(
                "request {id}: state length {} != model dimension {}",
                y0.len(),
                self.stepper.dim()
            );
        }
        if y0.iter().any(|v| !v.is_finite()) {
            bail!("request {id}: non-finite initial state");
        }
        self.queue.push_back(QueuedRequest { id, class, y0 });
        Ok(())
    }

    /// One engine step: admit queued requests into free rows, then advance
    /// every active trajectory by one attempt.  Returns the requests that
    /// retired (reached `t1`, exhausted their deadline, or were dead on
    /// arrival).
    pub fn step(&mut self) -> Vec<ServeOutcome> {
        let mut out = Vec::new();
        let queued = self.queue.len();
        let step_no = self.step_no;
        let rec = self.stepper.recorder_mut();
        if rec.is_on() {
            rec.set_ticks(step_no);
            rec.counter("queue_depth", step_no, queued as f64);
            rec.observe(Hist::QueueDepth, queued as f32);
        }
        let admit = match self.policy {
            AdmissionPolicy::Continuous => true,
            AdmissionPolicy::Drain => self.stepper.active() == 0,
        };
        if admit {
            self.admit_waves(&mut out);
        }
        let act = self.stepper.active();
        if act > 0 {
            self.busy_steps += 1;
            self.active_row_steps += act as u64;
            let rec = self.stepper.recorder_mut();
            if rec.is_on() {
                rec.counter("active_rows", step_no, act as f64);
            }
            let retired = self.stepper.step();
            self.collect(retired, &mut out);
        }
        self.step_no += 1;
        out
    }

    /// Admit maximal FIFO runs of same-class requests while rows are free.
    /// Each run shares one batched stage-0 evaluation and one batched
    /// Hairer probe — the same grouping `solve_adaptive_batch` gives a
    /// whole batch, so per-request NFE accounting is unchanged.
    fn admit_waves(&mut self, out: &mut Vec<ServeOutcome>) {
        let n = self.stepper.dim();
        while self.stepper.active() < self.capacity {
            let class = match self.queue.front() {
                Some(r) => r.class,
                None => break,
            };
            let free = self.capacity - self.stepper.active();
            let mut ids = Vec::new();
            let mut y0 = Vec::with_capacity(free * n);
            while ids.len() < free {
                match self.queue.front() {
                    Some(r) if r.class == class => {}
                    _ => break,
                }
                if let Some(r) = self.queue.pop_front() {
                    let tid = self.meta.len();
                    self.meta.push(ReqMeta {
                        id: r.id,
                        class: r.class,
                        admit_step: self.step_no,
                    });
                    ids.push(tid);
                    y0.extend_from_slice(&r.y0);
                }
            }
            let rec = self.stepper.recorder_mut();
            if rec.is_on() {
                let cls = CLASSES.iter().position(|c| c.name == class.name);
                rec.observe(Hist::AdmitWave, ids.len() as f32);
                rec.instant(
                    "admit_wave",
                    0,
                    self.step_no,
                    [("rows", ids.len() as f64), ("class", cls.map_or(-1.0, |i| i as f64))],
                );
            }
            let retired =
                self.stepper.admit(&ids, &y0, self.t0, self.t1, &class.opts(), None);
            self.collect(retired, out);
        }
    }

    fn collect(&mut self, retired: Vec<Retired>, out: &mut Vec<ServeOutcome>) {
        for r in retired {
            let m = &self.meta[r.id];
            let deadline_miss = (r.t - self.t1).abs() > 1e-9;
            let o = ServeOutcome {
                id: m.id,
                class: m.class,
                y: r.y,
                t: r.t,
                stats: r.stats,
                admit_step: m.admit_step,
                done_step: self.step_no,
                deadline_miss,
            };
            if let Some(slo) = &mut self.slo {
                slo.record(m.class.name, self.step_no, deadline_miss);
            }
            let rec = self.stepper.recorder_mut();
            if rec.is_on() {
                let latency = o.done_step - o.admit_step;
                rec.observe(Hist::LatencySteps, latency as f32);
                if deadline_miss {
                    rec.inc(Counter::DeadlineMiss, 1);
                }
                // The span covers [admit, done] inclusive — one tick per
                // engine step the request was live — so the stepper's
                // `traj` span (ending at done + 1) nests inside it.
                rec.span(
                    "request",
                    o.id,
                    o.admit_step,
                    latency + 1,
                    [("nfe", o.stats.nfe as f64), ("miss", if deadline_miss { 1.0 } else { 0.0 })],
                );
            }
            out.push(o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::arrivals::PoissonArrivals;
    use crate::solvers::{solve_adaptive_batch, tableau};
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    /// Id-independent two-dimensional dynamics (ids must not condition the
    /// field here: the solo reference below renumbers rows from zero).
    #[derive(Clone)]
    struct Spiral;

    impl BatchDynamics for Spiral {
        fn dim(&self) -> usize {
            2
        }

        fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
            for r in 0..t.len() {
                let (a, b) = (y[2 * r], y[2 * r + 1]);
                dy[2 * r] = -b + 0.3 * (t[r] + a).sin();
                dy[2 * r + 1] = a - 0.2 * b;
            }
        }
    }

    fn random_class(rng: &mut Pcg) -> ToleranceClass {
        let rtol = 10f32.powf(rng.range(-7.0, -2.0));
        ToleranceClass {
            name: "custom",
            rtol,
            atol: rtol * 1e-2,
            deadline_steps: [24usize, 200, 4000][rng.below(3)],
        }
    }

    #[test]
    fn served_requests_match_solo_solves_bit_for_bit() {
        // The admission/retire equivalence property at the engine level:
        // under a seeded Poisson arrival process, random capacities, and
        // random tolerance classes, every outcome equals its solo solve —
        // states, time, and stats — and the deadline flag agrees with it.
        Prop::new(12).run("engine-admission-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(["bosh3", "dopri5", "cash_karp"][case % 3]).unwrap();
            let capacity = 1 + rng.below(6);
            let total = 5 + rng.below(10);
            let classes: Vec<ToleranceClass> =
                (0..total).map(|_| random_class(rng)).collect();
            let y0s: Vec<Vec<f32>> = (0..total).map(|_| gen::vec_f32(rng, 2, 1.0)).collect();

            let mut eng = ServingEngine::new(Spiral, &tb, capacity, 0.0, 1.0);
            let mut arrivals = PoissonArrivals::new(rng.next_u64(), 1.5);
            let mut outcomes = Vec::new();
            let mut submitted = 0usize;
            let mut guard = 0usize;
            while submitted < total || !eng.is_idle() {
                guard += 1;
                assert!(guard < 200_000, "engine failed to drain");
                if submitted < total {
                    let k = arrivals.next_count().min(total - submitted);
                    for _ in 0..k {
                        eng.submit(
                            submitted as u64,
                            classes[submitted],
                            y0s[submitted].clone(),
                        )
                        .unwrap();
                        submitted += 1;
                    }
                }
                outcomes.extend(eng.step());
            }

            assert_eq!(outcomes.len(), total);
            assert!(eng.busy_steps() <= eng.steps());
            assert!(eng.active_row_steps() <= eng.busy_steps() * capacity as u64);
            for o in outcomes {
                let r = o.id as usize;
                let solo =
                    solve_adaptive_batch(Spiral, 0.0, 1.0, &y0s[r], &tb, &classes[r].opts());
                assert_eq!(o.y.len(), 2);
                for i in 0..2 {
                    assert_eq!(
                        o.y[i].to_bits(),
                        solo.y[i].to_bits(),
                        "{} request {r} dim {i}",
                        tb.name
                    );
                }
                assert_eq!(o.t.to_bits(), solo.t[0].to_bits());
                assert_eq!(o.stats.nfe, solo.stats[0].nfe, "request {r}");
                assert_eq!(o.stats.accepted, solo.stats[0].accepted);
                assert_eq!(o.stats.rejected, solo.stats[0].rejected);
                assert_eq!(o.deadline_miss, (solo.t[0] - 1.0).abs() > 1e-9);
                assert!(o.admit_step <= o.done_step);
            }
        });
    }

    #[test]
    fn submit_rejects_malformed_requests() {
        let tb = tableau::dopri5();
        let mut eng = ServingEngine::new(Spiral, &tb, 4, 0.0, 1.0);
        assert!(eng.submit(1, STANDARD, vec![0.1]).is_err(), "wrong dimension");
        assert!(eng.submit(2, STANDARD, vec![0.1, f32::NAN]).is_err(), "non-finite");
        assert!(eng.submit(3, STANDARD, vec![0.1, 0.2]).is_ok());
        assert_eq!(eng.queued(), 1);
    }

    #[test]
    fn zero_deadline_retires_immediately_as_a_miss() {
        let tb = tableau::dopri5();
        let mut eng = ServingEngine::new(Spiral, &tb, 2, 0.0, 1.0);
        let dead = ToleranceClass { name: "dead", deadline_steps: 0, ..STANDARD };
        eng.submit(7, dead, vec![0.3, -0.1]).unwrap();
        let out = eng.step();
        assert_eq!(out.len(), 1);
        assert!(out[0].deadline_miss);
        assert_eq!(out[0].admit_step, out[0].done_step);
        assert_eq!(out[0].t, 0.0);
        assert!(eng.is_idle());
    }

    #[test]
    fn slo_tracker_scores_retirements_per_class() {
        let tb = tableau::dopri5();
        let mut eng = ServingEngine::new(Spiral, &tb, 2, 0.0, 1.0);
        eng.enable_slo(SloTracker::standard());
        // A zero-step "realtime" deadline retires dead on arrival as a
        // deterministic miss; the standard request finishes comfortably.
        let dead = ToleranceClass { name: "realtime", deadline_steps: 0, ..REALTIME };
        eng.submit(0, dead, vec![0.3, -0.1]).unwrap();
        eng.submit(1, STANDARD, vec![0.2, 0.4]).unwrap();
        let mut guard = 0;
        while !eng.is_idle() {
            guard += 1;
            assert!(guard < 10_000);
            eng.step();
        }
        let slo = eng.take_slo().unwrap();
        let rt = slo.class("realtime").unwrap();
        assert_eq!((rt.done, rt.missed), (1, 1));
        let st = slo.class("standard").unwrap();
        assert_eq!((st.done, st.missed), (1, 0));
        assert!(slo.worst_burn("realtime").unwrap() > 1.0, "budget blown");
        assert!(eng.take_slo().is_none(), "take leaves scoring off");
    }

    #[test]
    fn drain_policy_holds_the_queue_until_the_set_empties() {
        let tb = tableau::dopri5();
        let mut eng = ServingEngine::new(Spiral, &tb, 2, 0.0, 1.0);
        eng.set_policy(AdmissionPolicy::Drain);
        for id in 0..5u64 {
            eng.submit(id, REALTIME, vec![0.2 + 0.1 * id as f32, -0.4]).unwrap();
        }
        let mut done = 0usize;
        let mut guard = 0;
        let mut prev_queued = eng.queued();
        while !eng.is_idle() {
            guard += 1;
            assert!(guard < 10_000);
            let was_empty = eng.in_flight() == 0;
            done += eng.step().len();
            assert!(eng.in_flight() <= 2);
            // Drain only admits from an empty set: the queue must be
            // untouched by any step that started with live rows.
            if !was_empty {
                assert_eq!(eng.queued(), prev_queued, "admitted while rows were live");
            }
            prev_queued = eng.queued();
        }
        assert_eq!(done, 5);
    }
}
