//! The serving wire format: derive-style request/response structs over
//! [`util::json`](crate::util::json).
//!
//! Shaped like `nanoserde`'s `SerJson`/`DeJson` pair (the manifest idiom —
//! a struct declares its fields once and the [`wire_struct!`] macro derives
//! both directions), since serde/nanoserde are unavailable offline.  The
//! format is strict where it matters for serving:
//!
//! * **Canonical output** — object keys are sorted (the underlying
//!   [`Json`] writer), so serialize → parse → re-serialize is the identity
//!   on strings and replayed traces diff cleanly.
//! * **Total parsing** — truncated or malformed payloads return errors,
//!   never panic (taylint D4: this layer feeds on untrusted bytes).
//! * **No non-finite numbers** — JSON cannot represent NaN/Inf; they are
//!   rejected on decode, and response construction sanitizes states before
//!   they reach the wire ([`super::handlers`]).
//! * **Forward compatibility** — unknown keys are ignored; missing fields
//!   are errors naming the struct and field.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Largest integer exactly representable in the wire's f64 numbers;
/// request ids must stay below it (sequential ids always do).
pub const MAX_SAFE_INT: u64 = 1 << 53;

/// Serialize to the canonical wire JSON (nanoserde's `SerJson` shape).
pub trait SerWire {
    /// The wire `Json` value.
    fn ser_wire(&self) -> Json;

    /// The canonical wire string (sorted keys).
    fn serialize_wire(&self) -> String {
        self.ser_wire().to_string()
    }
}

/// Parse from wire JSON (nanoserde's `DeJson` shape).  Decoding is total:
/// any malformed input is an `Err`, never a panic.
pub trait DeWire: Sized {
    fn de_wire(j: &Json) -> Result<Self>;

    fn deserialize_wire(s: &str) -> Result<Self> {
        Self::de_wire(&Json::parse(s)?)
    }
}

impl SerWire for u64 {
    fn ser_wire(&self) -> Json {
        Json::Num(*self as f64)
    }
}

impl DeWire for u64 {
    fn de_wire(j: &Json) -> Result<u64> {
        let x = j.as_f64().ok_or_else(|| anyhow!("expected an integer"))?;
        if !(x.is_finite() && x >= 0.0 && x == x.trunc()) {
            bail!("expected a non-negative integer, got {x}");
        }
        if x >= MAX_SAFE_INT as f64 {
            bail!("integer {x} exceeds the wire's exact range (2^53)");
        }
        Ok(x as u64)
    }
}

impl SerWire for bool {
    fn ser_wire(&self) -> Json {
        Json::Bool(*self)
    }
}

impl DeWire for bool {
    fn de_wire(j: &Json) -> Result<bool> {
        match j {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected a bool"),
        }
    }
}

impl SerWire for f32 {
    fn ser_wire(&self) -> Json {
        Json::Num(f64::from(*self))
    }
}

impl DeWire for f32 {
    fn de_wire(j: &Json) -> Result<f32> {
        let x = j.as_f64().ok_or_else(|| anyhow!("expected a number"))?;
        let v = x as f32;
        if !v.is_finite() {
            bail!("number {x} is not finite in f32");
        }
        Ok(v)
    }
}

impl SerWire for String {
    fn ser_wire(&self) -> Json {
        Json::Str(self.clone())
    }
}

impl DeWire for String {
    fn de_wire(j: &Json) -> Result<String> {
        match j {
            Json::Str(s) => Ok(s.clone()),
            _ => bail!("expected a string"),
        }
    }
}

impl<T: SerWire> SerWire for Vec<T> {
    fn ser_wire(&self) -> Json {
        Json::Arr(self.iter().map(SerWire::ser_wire).collect())
    }
}

impl<T: DeWire> DeWire for Vec<T> {
    fn de_wire(j: &Json) -> Result<Vec<T>> {
        let arr = j.as_arr().ok_or_else(|| anyhow!("expected an array"))?;
        let mut out = Vec::with_capacity(arr.len());
        for (i, v) in arr.iter().enumerate() {
            out.push(T::de_wire(v).map_err(|e| anyhow!("[{i}]: {e}"))?);
        }
        Ok(out)
    }
}

/// Declares a wire struct and derives its [`SerWire`]/[`DeWire`] impls
/// from the field list — the `#[derive(SerJson, DeJson)]` idiom without
/// the proc macro.  Unknown keys are ignored on decode; missing fields
/// error with the struct and field name.
macro_rules! wire_struct {
    (
        $(#[$meta:meta])*
        pub struct $name:ident {
            $( $(#[$fmeta:meta])* pub $field:ident : $ty:ty, )+
        }
    ) => {
        $(#[$meta])*
        #[derive(Clone, Debug, Default, PartialEq)]
        pub struct $name {
            $( $(#[$fmeta])* pub $field: $ty, )+
        }

        impl SerWire for $name {
            fn ser_wire(&self) -> Json {
                let mut m = std::collections::BTreeMap::new();
                $( m.insert(
                    stringify!($field).to_string(),
                    <$ty as SerWire>::ser_wire(&self.$field),
                ); )+
                Json::Obj(m)
            }
        }

        impl DeWire for $name {
            fn de_wire(j: &Json) -> Result<$name> {
                let obj = j.as_obj().ok_or_else(|| {
                    anyhow!(concat!(stringify!($name), ": expected an object"))
                })?;
                Ok($name {
                    $( $field: match obj.get(stringify!($field)) {
                        Some(v) => <$ty as DeWire>::de_wire(v).map_err(|e| {
                            anyhow!(
                                "{}.{}: {e}",
                                stringify!($name),
                                stringify!($field)
                            )
                        })?,
                        None => bail!(
                            "{} missing field {:?}",
                            stringify!($name),
                            stringify!($field)
                        ),
                    }, )+
                })
            }
        }
    };
}

wire_struct! {
    /// One inference request: integrate `x` through the named model's
    /// dynamics under the named tolerance class.
    pub struct ServeRequest {
        /// Caller-chosen stable id, echoed on the response (< 2^53).
        pub id: u64,
        /// Hosted model name (`toy`, `mnist`, `density`, ...).
        pub model: String,
        /// Tolerance-class name (see [`super::engine::CLASSES`]).
        pub class: String,
        /// Initial state, the model's data dimension.
        pub x: Vec<f32>,
    }
}

wire_struct! {
    /// The answer to one [`ServeRequest`].
    pub struct ServeResponse {
        /// The request's id.
        pub id: u64,
        /// The model that served it.
        pub model: String,
        /// The tolerance class it ran under.
        pub class: String,
        /// False when the request was malformed or the solve produced a
        /// non-finite state; `error` then says why and `y` is empty.
        pub ok: bool,
        /// Human-readable failure reason (empty when `ok`).
        pub error: String,
        /// Final state at `t1` (for density models: the latent `z`).
        pub y: Vec<f32>,
        /// Model-specific score (density models: `[NLL]`), else empty.
        pub score: Vec<f32>,
        /// Solver function evaluations spent on this request.
        pub nfe: u64,
        /// Accepted solver steps.
        pub accepted: u64,
        /// Rejected solver steps.
        pub rejected: u64,
        /// Engine step at which the request joined the active set.
        pub admit_step: u64,
        /// Engine step at which it retired.
        pub done_step: u64,
        /// True when the step-budget deadline expired before reaching `t1`
        /// (`y` is then the furthest state reached).
        pub deadline_miss: bool,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::Prop;
    use crate::util::rng::Pcg;

    fn wire_string(rng: &mut Pcg) -> String {
        // Adversarial-ish content: quotes, backslashes, control chars,
        // multi-byte utf8.
        const PIECES: &[&str] = &["a", "\"", "\\", "\n", "\t", "é", "λ", "\u{1}", "z9"];
        let k = rng.below(8);
        (0..k).map(|_| PIECES[rng.below(PIECES.len())]).collect()
    }

    fn wire_floats(rng: &mut Pcg, max_len: usize) -> Vec<f32> {
        let k = rng.below(max_len + 1);
        (0..k).map(|_| rng.range(-1e6, 1e6)).collect()
    }

    #[test]
    fn request_and_response_round_trip_to_identical_json() {
        Prop::new(50).run("wire-roundtrip", |rng: &mut Pcg, _case| {
            let req = ServeRequest {
                id: rng.next_u64() >> 11, // 53-bit: exactly representable
                model: wire_string(rng),
                class: wire_string(rng),
                x: wire_floats(rng, 6),
            };
            let s = req.serialize_wire();
            let back = ServeRequest::deserialize_wire(&s).unwrap();
            assert_eq!(req, back);
            assert_eq!(s, back.serialize_wire(), "canonical form must be a fixpoint");

            let resp = ServeResponse {
                id: rng.next_u64() >> 11,
                model: wire_string(rng),
                class: wire_string(rng),
                ok: rng.below(2) == 0,
                error: wire_string(rng),
                y: wire_floats(rng, 6),
                score: wire_floats(rng, 2),
                nfe: rng.below(100_000) as u64,
                accepted: rng.below(1000) as u64,
                rejected: rng.below(1000) as u64,
                admit_step: rng.below(1 << 20) as u64,
                done_step: rng.below(1 << 20) as u64,
                deadline_miss: rng.below(2) == 0,
            };
            let s = resp.serialize_wire();
            let back = ServeResponse::deserialize_wire(&s).unwrap();
            assert_eq!(resp, back);
            assert_eq!(s, back.serialize_wire());
        });
    }

    #[test]
    fn truncated_payloads_error_not_panic() {
        let req = ServeRequest {
            id: 42,
            model: "toy \"quoted\" \\ é".into(),
            class: "standard".into(),
            x: vec![0.5, -1.25, 3.0e-7],
        };
        let s = req.serialize_wire();
        // Every proper byte prefix must be a clean error (cut points inside
        // escapes, numbers, and multi-byte utf8 included).
        for cut in 0..s.len() {
            if !s.is_char_boundary(cut) {
                continue;
            }
            assert!(
                ServeRequest::deserialize_wire(&s[..cut]).is_err(),
                "prefix of {cut} bytes should fail to parse"
            );
        }
        assert!(ServeRequest::deserialize_wire(&s).is_ok());
    }

    #[test]
    fn non_finite_and_out_of_range_numbers_are_rejected() {
        // Overflowing literal: rejected by the JSON layer.
        let huge = r#"{"class":"c","id":1,"model":"m","x":[1e999]}"#;
        assert!(ServeRequest::deserialize_wire(huge).is_err());
        // Finite in f64, infinite in f32: rejected by the field decoder.
        let wide = r#"{"class":"c","id":1,"model":"m","x":[1e300]}"#;
        assert!(ServeRequest::deserialize_wire(wide).is_err());
        // null is not a number (a writer-side NaN would serialize as null).
        let nan = r#"{"class":"c","id":1,"model":"m","x":[null]}"#;
        assert!(ServeRequest::deserialize_wire(nan).is_err());
        // Fractional / oversized / negative ids.
        for id in ["1.5", "9007199254740992", "-1"] {
            let s = format!(r#"{{"class":"c","id":{id},"model":"m","x":[]}}"#);
            assert!(ServeRequest::deserialize_wire(&s).is_err(), "id {id}");
        }
    }

    #[test]
    fn missing_fields_error_and_unknown_keys_are_ignored() {
        let missing = r#"{"class":"c","id":1,"x":[]}"#;
        let err = ServeRequest::deserialize_wire(missing).unwrap_err();
        assert!(format!("{err}").contains("model"), "{err}");

        let extra = r#"{"class":"c","id":1,"model":"m","x":[1],"future_knob":{"a":[1,2]}}"#;
        let req = ServeRequest::deserialize_wire(extra).unwrap();
        assert_eq!(req.model, "m");
        assert_eq!(req.x, vec![1.0]);

        assert!(ServeRequest::deserialize_wire("[]").is_err());
        assert!(ServeRequest::deserialize_wire("7").is_err());
    }
}
