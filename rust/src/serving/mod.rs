//! The continuous-batching serving layer — the paper's cheap-inference
//! claim turned into a serving loop.
//!
//! Requests ([`wire::ServeRequest`]) carry a stable id, a model name, and
//! a [`ToleranceClass`](engine::ToleranceClass) (per-request tolerances +
//! a step-budget deadline).  A [`ServingEngine`](engine::ServingEngine)
//! admits them into the batched adaptive driver's active set *between*
//! solver attempts while finished trajectories retire
//! ([`BatchStepper`](crate::solvers::batch::BatchStepper)), so the batch
//! stays full under load instead of draining to stragglers — the
//! occupancy win `benches/perf_serving.rs` measures against the drain
//! baseline.
//!
//! Layering:
//! * [`wire`] — `ServeRequest`/`ServeResponse`, nanoserde-shaped
//!   derive-style JSON structs over `util::json` (strict: canonical
//!   output, total parsing, no NaN/Inf).
//! * [`arrivals`] — the seeded Poisson arrival process (load generation
//!   through the sanctioned RNG door, taylint D3).
//! * [`engine`] — tolerance classes, admission policies, and the
//!   per-model continuous-batching loop.
//! * [`handlers`] — model-backed hosts (toy / synth-MNIST / CNF-NLL),
//!   request generation, and the seeded drivers
//!   ([`run_poisson`](handlers::run_poisson) and friends).
//!
//! Everything here is deterministic by construction: a drive's trace is a
//! pure function of its seed, bit-identical across thread counts (rule
//! D5) and replays.

pub mod arrivals;
pub mod engine;
pub mod handlers;
pub mod wire;

pub use arrivals::PoissonArrivals;
pub use engine::{
    AdmissionPolicy, ServeOutcome, ServingEngine, ToleranceClass, CLASSES, PRECISE, REALTIME,
    STANDARD,
};
pub use handlers::{
    demo_host, demo_host_with, drive_poisson, run_poisson, run_poisson_drain,
    run_poisson_pooled, run_poisson_slo, run_poisson_slo_pooled, run_poisson_traced,
    run_poisson_traced_pooled, trace_hash, RequestGen, ServeDynamics, ServeHost, ServeTrace,
};
pub use wire::{DeWire, SerWire, ServeRequest, ServeResponse};
