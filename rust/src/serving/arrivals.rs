//! Seeded Poisson arrival process for serving load generation.
//!
//! One [`PoissonArrivals`] draws the number of requests arriving in each
//! unit time slot (one engine step).  It owns a [`Pcg`] stream derived
//! from the caller's seed — `util::rng` is the sanctioned RNG door
//! (taylint D3) — so the whole arrival sequence is a pure function of the
//! seed and replays bit-identically.

use crate::util::rng::Pcg;

/// Poisson-process load generator: `next_count() ~ Poisson(rate)` per slot.
pub struct PoissonArrivals {
    rng: Pcg,
    rate: f64,
}

impl PoissonArrivals {
    /// A process with the given mean arrivals per engine step.
    pub fn new(seed: u64, rate: f64) -> PoissonArrivals {
        assert!(
            rate.is_finite() && rate >= 0.0,
            "PoissonArrivals: rate must be finite and non-negative"
        );
        PoissonArrivals { rng: Pcg::with_stream(seed, 0xA221_7E55), rate }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Arrivals in the next slot.
    ///
    /// Knuth's product-of-uniforms, chunked at λ = 30: a Poisson(λ₁+λ₂)
    /// draw is the sum of independent Poisson(λ₁) and Poisson(λ₂) draws,
    /// and e^{−30} ≈ 9e-14 keeps the threshold comfortably inside f64
    /// range at any serving rate (e^{−λ} underflows outright near λ = 745,
    /// turning the textbook loop into an infinite one).
    pub fn next_count(&mut self) -> usize {
        let mut remaining = self.rate;
        let mut k = 0usize;
        while remaining > 0.0 {
            let lambda = remaining.min(30.0);
            remaining -= lambda;
            let l = (-lambda).exp();
            let mut p = 1.0f64;
            loop {
                p *= self.rng.uniform() as f64;
                if p <= l {
                    break;
                }
                k += 1;
            }
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_the_same_arrival_sequence() {
        let mut a = PoissonArrivals::new(99, 7.5);
        let mut b = PoissonArrivals::new(99, 7.5);
        let xs: Vec<usize> = (0..200).map(|_| a.next_count()).collect();
        let ys: Vec<usize> = (0..200).map(|_| b.next_count()).collect();
        assert_eq!(xs, ys);
        // ... and a different seed diverges somewhere.
        let mut c = PoissonArrivals::new(100, 7.5);
        let zs: Vec<usize> = (0..200).map(|_| c.next_count()).collect();
        assert_ne!(xs, zs);
    }

    #[test]
    fn sample_mean_tracks_the_rate_including_the_chunked_regime() {
        // λ = 120 exercises the chunked path (4 sub-draws per slot); the
        // sample mean over 4000 slots stays within a few percent of λ for
        // a correct sampler (variance λ/n → σ ≈ 0.17 here).
        for (rate, slots) in [(0.5f64, 20_000usize), (6.0, 8_000), (120.0, 4_000)] {
            let mut p = PoissonArrivals::new(7, rate);
            let total: usize = (0..slots).map(|_| p.next_count()).sum();
            let mean = total as f64 / slots as f64;
            let sigma = (rate / slots as f64).sqrt();
            assert!(
                (mean - rate).abs() < 6.0 * sigma.max(1e-3),
                "rate {rate}: sample mean {mean}"
            );
        }
    }

    #[test]
    fn zero_rate_never_produces_arrivals() {
        let mut p = PoissonArrivals::new(3, 0.0);
        assert!((0..100).all(|_| p.next_count() == 0));
    }
}
