//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and defaults.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.flags.insert(
                        stripped[..eq].to_string(),
                        stripped[eq + 1..].to_string(),
                    );
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.str_opt(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }
}

/// The build/run provenance stamp: the `GIT_COMMIT` environment variable
/// `make bench-json` exports (`git rev-parse --short HEAD`), or
/// `"unknown"` outside make.  Lives here because `util/cli.rs` is a
/// sanctioned nondeterminism door (lint rule D3) — benches and reports
/// read provenance through this one accessor instead of touching the
/// environment themselves.
pub fn git_commit() -> String {
    std::env::var("GIT_COMMIT")
        .ok()
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        // note: a bare boolean flag must not be followed by a positional —
        // `--verbose extra` would bind "extra" as its value (documented
        // greedy-value semantics); positionals go first or use --flag=true.
        let a = Args::parse(&v(&["train", "extra", "--lam", "0.1", "--steps=8", "--verbose"]));
        assert_eq!(a.pos(0), Some("train"));
        assert_eq!(a.pos(1), Some("extra"));
        assert_eq!(a.f32_or("lam", 0.0).unwrap(), 0.1);
        assert_eq!(a.usize_or("steps", 1).unwrap(), 8);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&v(&["--bad", "xyz"]));
        assert!(a.f32_or("bad", 1.0).is_err());
        assert_eq!(a.f32_or("missing", 2.5).unwrap(), 2.5);
        assert!(a.require("absent").is_err());
    }

    #[test]
    fn git_commit_always_yields_a_stamp() {
        // Set or not, the accessor never returns an empty provenance.
        assert!(!git_commit().is_empty());
    }

    #[test]
    fn negative_number_values() {
        // "--lo -3" : the -3 doesn't start with --, so it's a value.
        let a = Args::parse(&v(&["--lo", "-3.5"]));
        assert_eq!(a.f32_or("lo", 0.0).unwrap(), -3.5);
    }
}
