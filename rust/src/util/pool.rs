//! Dependency-free scoped worker pool — the parallel substrate of the
//! batched solver and the discrete adjoint.
//!
//! A [`Pool`] is only a thread *count*: every [`run_shards`](Pool::run_shards)
//! call spins up at most that many scoped workers (`std::thread::scope`, so
//! borrowed data crosses into workers without `'static` bounds or `Arc`),
//! drains a shared index queue, and joins before returning.  There are no
//! long-lived threads, channels, or locks — idle cost is zero, and a pool of
//! one thread executes every shard inline on the caller's stack.
//!
//! **Determinism contract:** shard *outputs* are returned in shard order, no
//! matter which worker computed what or in what order shards finished.  As
//! long as the shard layout is a pure function of the problem (see
//! [`shard_ranges`]) and each shard's computation is deterministic, results
//! are bit-identical at every thread count — the property the solver and
//! adjoint tests pin.
//!
//! The thread count comes from the `TAYNODE_THREADS` environment variable
//! when set (a positive integer; `1` disables threading entirely), else
//! from [`std::thread::available_parallelism`].
//!
//! The one disjointness property the static lint (`taylint`, rule D2)
//! cannot see — that shards merged into one output buffer claim
//! non-overlapping ranges — is checked dynamically in debug builds:
//! [`run_range_shards`](Pool::run_range_shards) records every shard's
//! claimed output range at dispatch and panics with both shard ids if any
//! two overlap, so every `cargo test` run doubles as a race audit.
//!
//! ```
//! use taynode::util::pool::{shard_ranges, Pool};
//!
//! let pool = Pool::new(4);
//! let shards = shard_ranges(10, pool.threads());
//! let sums: Vec<usize> = pool.run_shards(shards.len(), |s| shards[s].clone().sum());
//! assert_eq!(sums.iter().sum::<usize>(), (0..10usize).sum::<usize>());
//! ```

use std::ops::Range;
// taylint: allow(D2) -- pool.rs IS the sanctioned index queue (rule D2's one exception)
use std::sync::atomic::{AtomicUsize, Ordering};

/// The environment variable that pins the worker count (see [`Pool::from_env`]).
pub const THREADS_ENV: &str = "TAYNODE_THREADS";

/// A scoped worker pool: a thread budget plus the shard-dispatch logic.
/// Cheap to construct and to clone; holds no OS resources.
#[derive(Clone, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool of exactly `threads` workers (>= 1).  `Pool::new(1)` runs
    /// every shard inline with no thread spawns at all.
    pub fn new(threads: usize) -> Pool {
        assert!(threads >= 1, "Pool: thread count must be positive");
        Pool { threads }
    }

    /// Thread count from `TAYNODE_THREADS` (positive integer), defaulting
    /// to the machine's available parallelism (1 if unknown).  An invalid
    /// setting (zero, negative, non-numeric) is never silently honored or
    /// dropped: it warns once per call and falls back to the default.
    pub fn from_env() -> Pool {
        let default = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = match std::env::var(THREADS_ENV) {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) if n >= 1 => n,
                _ => {
                    eprintln!(
                        "warning: {THREADS_ENV}={v:?} is not a positive integer; \
                         using the default worker count"
                    );
                    default()
                }
            },
            Err(_) => default(),
        };
        Pool { threads }
    }

    /// The worker budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), ..., f(n - 1)` on up to `threads` scoped workers and
    /// return the results **in shard order** (never in completion order).
    /// Shards are drained from a shared atomic queue, so any worker may
    /// compute any shard; with one worker (or one shard) everything runs
    /// inline on the caller's thread.  A panicking shard propagates.
    pub fn run_shards<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return vec![];
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        // taylint: allow(D2) -- the shared claim counter of the sanctioned queue
        let next = AtomicUsize::new(0);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut got: Vec<(usize, T)> = Vec::new();
                        loop {
                            // Relaxed is sufficient: the counter is claim-only.
                            // Each fetch_add hands out a unique index (RMW
                            // atomicity needs no ordering), no worker reads or
                            // writes data published by another worker's claim,
                            // and the happens-before edges that make the shard
                            // *results* visible come from scope join, not from
                            // this counter.  Claim order affects scheduling
                            // only; outputs are merged by index, so results
                            // are identical at any interleaving.
                            // taylint: allow(D2) -- claim-only fetch_add of the sanctioned queue
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            got.push((i, f(i)));
                        }
                        got
                    })
                })
                .collect();
            for h in handles {
                // taylint: allow(D4) -- a panicked worker must re-panic the caller
                for (i, v) in h.join().expect("pool worker panicked") {
                    out[i] = Some(v);
                }
            }
        });
        // taylint: allow(D4) -- the queue hands out every index exactly once
        out.into_iter().map(|v| v.expect("pool shard produced no result")).collect()
    }

    /// [`run_shards`](Pool::run_shards) for callers whose shards each own a
    /// contiguous output range (the batched solvers, the adjoint's gradient
    /// shards): `f(s, &shards[s])` runs for every shard, results return in
    /// shard order.  In debug builds the claimed ranges are recorded at
    /// dispatch and any overlap panics with both shard ids — the shard-write
    /// race detector.  Release builds skip the check entirely.
    pub fn run_range_shards<T, F>(&self, shards: &[Range<usize>], f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &Range<usize>) -> T + Sync,
    {
        if cfg!(debug_assertions) {
            let mut claims = ShardClaims::default();
            for (s, r) in shards.iter().enumerate() {
                claims.claim(s, r);
            }
        }
        self.run_shards(shards.len(), |s| f(s, &shards[s]))
    }
}

/// Debug-build shard-write race detector: ownership ranges recorded at
/// dispatch (on the caller's thread, before any worker runs — no
/// synchronization needed), with overlap a panic naming both shards.
#[derive(Debug, Default)]
struct ShardClaims {
    claims: Vec<(usize, Range<usize>)>,
}

impl ShardClaims {
    fn claim(&mut self, shard: usize, r: &Range<usize>) {
        if r.is_empty() {
            return; // an empty range owns nothing and cannot race
        }
        for (other, prev) in &self.claims {
            if r.start < prev.end && prev.start < r.end {
                panic!(
                    "shard race: shard {shard} claims output range {r:?} \
                     overlapping shard {other}'s range {prev:?}"
                );
            }
        }
        self.claims.push((shard, r.clone()));
    }
}

/// Chunks per worker in [`chunk_ranges`]: enough oversubscription that a
/// worker finishing a cheap chunk pulls another off the queue instead of
/// idling behind a straggler, small enough that per-chunk setup (dynamics
/// clones, working-set allocation) stays amortized.
pub const CHUNKS_PER_WORKER: usize = 4;

/// Work-stealing chunk layout for [`Pool::run_shards`]'s atomic queue:
/// `threads · CHUNKS_PER_WORKER` balanced contiguous ranges (capped at one
/// row each), instead of one static range per worker.  Workers claim chunks
/// dynamically from the shared queue, so a skew-heavy chunk tails on *one*
/// worker while the others drain the rest — but results still come back in
/// chunk order, so a reduction in range order is unchanged.  With one
/// thread the layout collapses to a single range (everything runs inline).
///
/// Like [`shard_ranges`] this is a pure function of its arguments; note the
/// layout depends on the thread count, which is fine for row-independent
/// work merged by stable ids (the batched solvers) but NOT for reductions
/// that must be bit-stable across thread counts — those keep deriving a
/// fixed layout from the problem size alone (see
/// `coordinator::train_native`'s gradient shards).
pub fn chunk_ranges(total: usize, threads: usize) -> Vec<Range<usize>> {
    if threads <= 1 {
        return shard_ranges(total, 1);
    }
    shard_ranges(total, threads.saturating_mul(CHUNKS_PER_WORKER))
}

/// Balanced contiguous shard ranges covering `0..total`: `min(total,
/// max_shards)` non-empty ranges whose lengths differ by at most one, in
/// ascending order.  A **pure function** of its arguments — callers that
/// need bit-stable reductions across thread counts derive `max_shards` from
/// the problem size alone and feed the ranges to [`Pool::run_shards`],
/// reducing in range order.
pub fn shard_ranges(total: usize, max_shards: usize) -> Vec<Range<usize>> {
    if total == 0 || max_shards == 0 {
        return vec![];
    }
    let n = max_shards.min(total);
    let base = total / n;
    let extra = total % n; // the first `extra` shards get one more row
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for s in 0..n {
        let len = base + usize::from(s < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, total);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_and_balance() {
        for total in 0..40usize {
            for max in 1..9usize {
                let shards = shard_ranges(total, max);
                if total == 0 {
                    assert!(shards.is_empty());
                    continue;
                }
                assert_eq!(shards.len(), max.min(total));
                // contiguous cover, each non-empty, sizes within one of each
                // other
                let mut next = 0usize;
                let (mut lo, mut hi) = (usize::MAX, 0usize);
                for r in &shards {
                    assert_eq!(r.start, next);
                    assert!(r.end > r.start);
                    lo = lo.min(r.end - r.start);
                    hi = hi.max(r.end - r.start);
                    next = r.end;
                }
                assert_eq!(next, total);
                assert!(hi - lo <= 1, "unbalanced: {shards:?}");
            }
        }
    }

    #[test]
    fn shard_layout_is_independent_of_threads() {
        // The determinism precondition: the layout depends on the problem,
        // never on the pool.
        assert_eq!(shard_ranges(10, 4), shard_ranges(10, 4));
        assert_eq!(shard_ranges(10, 4), vec![0..3, 3..6, 6..8, 8..10]);
    }

    #[test]
    fn chunk_ranges_oversubscribe_without_changing_the_cover() {
        // threads = 1 stays a single inline range; otherwise the layout is
        // threads · CHUNKS_PER_WORKER balanced ranges (capped at one row
        // each), covering 0..total contiguously.
        assert_eq!(chunk_ranges(10, 1), vec![0..10]);
        assert_eq!(chunk_ranges(0, 4), Vec::<Range<usize>>::new());
        for total in 1..50usize {
            for threads in 2..6usize {
                let chunks = chunk_ranges(total, threads);
                assert_eq!(chunks.len(), (threads * CHUNKS_PER_WORKER).min(total));
                let mut next = 0usize;
                for r in &chunks {
                    assert_eq!(r.start, next);
                    assert!(r.end > r.start);
                    next = r.end;
                }
                assert_eq!(next, total);
            }
        }
    }

    #[test]
    fn run_shards_returns_in_shard_order() {
        for threads in [1usize, 2, 3, 8] {
            let pool = Pool::new(threads);
            let out = pool.run_shards(17, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_shards_borrows_caller_data() {
        let data: Vec<u64> = (0..100).collect();
        let pool = Pool::new(4);
        let shards = shard_ranges(data.len(), 7);
        let partial: Vec<u64> =
            pool.run_shards(shards.len(), |s| shards[s].clone().map(|i| data[i]).sum());
        assert_eq!(partial.len(), 7);
        assert_eq!(partial.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn zero_shards_is_empty() {
        let pool = Pool::new(4);
        let out: Vec<usize> = pool.run_shards(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "thread count must be positive")]
    fn zero_threads_rejected() {
        let _ = Pool::new(0);
    }

    #[test]
    fn run_range_shards_matches_run_shards_on_disjoint_layouts() {
        let data: Vec<u64> = (0..101).collect();
        for threads in [1usize, 2, 3, 4, 8] {
            let pool = Pool::new(threads);
            let shards = shard_ranges(data.len(), 7);
            let got: Vec<u64> =
                pool.run_range_shards(&shards, |_, r| r.clone().map(|i| data[i]).sum());
            let want: Vec<u64> =
                pool.run_shards(shards.len(), |s| shards[s].clone().map(|i| data[i]).sum());
            assert_eq!(got, want);
            assert_eq!(got.iter().sum::<u64>(), data.iter().sum::<u64>());
        }
    }

    #[test]
    fn run_range_shards_passes_shard_ids_in_order() {
        let pool = Pool::new(3);
        let shards = chunk_ranges(23, pool.threads());
        let ids: Vec<usize> = pool.run_range_shards(&shards, |s, _| s);
        assert_eq!(ids, (0..shards.len()).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ranges_do_not_trip_the_race_detector() {
        let pool = Pool::new(2);
        let shards = vec![0..4, 4..4, 4..9, 9..9];
        let lens: Vec<usize> = pool.run_range_shards(&shards, |_, r| r.len());
        assert_eq!(lens, vec![4, 0, 5, 0]);
    }

    // The detector only exists in debug builds (`cargo test --release`
    // would see no panic), so the should_panic tests are debug-gated.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "shard race")]
    fn overlapping_ranges_panic_in_debug_builds() {
        let pool = Pool::new(2);
        let shards = vec![0..6, 4..9]; // rows 4 and 5 claimed twice
        let _: Vec<usize> = pool.run_range_shards(&shards, |_, r| r.len());
    }

    #[cfg(debug_assertions)]
    #[test]
    fn race_panic_names_both_shards() {
        let pool = Pool::new(2);
        let shards = vec![0..3, 5..8, 2..6];
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _: Vec<usize> = pool.run_range_shards(&shards, |_, r| r.len());
        }));
        let Err(payload) = caught else {
            panic!("overlapping claim did not panic");
        };
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("shard 2"), "missing claimer id: {msg}");
        assert!(msg.contains("shard 0"), "missing prior owner id: {msg}");
    }
}
