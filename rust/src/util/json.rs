//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serializes metrics/experiment results.  Supports the full JSON grammar
//! except for exotic escapes beyond \uXXXX.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("{key:?} not a string"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("{key:?} not a number"))
    }

    // -- construction / writing ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut v = vec![];
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        c => bail!("expected , or ] got {:?}", c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    let v = self.value()?;
                    m.insert(k, v);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        c => bail!("expected , or }} got {:?}", c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            // A truncated payload must be an error, not a
                            // slice panic: check there are 4 hex digits left.
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape at byte {}", self.i);
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // recover full utf8 sequences
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let x = s.parse::<f64>()?;
        // "1e999" parses to +inf, which the writer cannot represent (JSON
        // has no non-finite numbers) — reject instead of round-tripping to
        // null.
        if !x.is_finite() {
            bail!("number {s:?} overflows to a non-finite value");
        }
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").unwrap().as_arr().unwrap()[2].str_of("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"xs": [1, 2.5, -3], "s": "q\"uote", "n": null, "b": false}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn truncated_payloads_error_instead_of_panicking() {
        // Regression: a payload cut off inside a \uXXXX escape used to slice
        // past the end of the buffer (the serving wire feeds untrusted
        // bytes here).
        for cut in ["\"\\u", "\"\\u1", "\"\\u12", "\"\\u123", "\"\\", "\"abc", "{\"k\": 1", "[1, 2"]
        {
            assert!(Json::parse(cut).is_err(), "{cut:?} should error");
        }
    }

    #[test]
    fn non_finite_numbers_are_rejected() {
        // An overflowing literal must not round-trip to null via Num(inf).
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        assert_eq!(Json::parse("1e308").unwrap(), Json::Num(1e308));
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = Json::parse("\"\\u00e9t\\u00e9 caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "été café");
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(j.usize_of("n").unwrap(), 3);
        assert_eq!(j.str_of("s").unwrap(), "x");
        assert!(j.usize_of("missing").is_err());
    }
}
