//! Deterministic PRNG (PCG-XSH-RR 64/32) with the distribution helpers the
//! data generators and property tests need.  No external `rand` crate is
//! available offline, and determinism across runs is a requirement for the
//! experiment harness anyway (every table row records its seed).

/// PCG-XSH-RR 64/32 — small, fast, statistically solid, reproducible.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-shard/per-epoch use).
    pub fn split(&mut self, tag: u64) -> Pcg {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg::with_stream(s, tag | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for our n << 2^32
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; the generators are not throughput-critical).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-7 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Rademacher (+1/-1) probe vector, as used by Hutchinson estimators.
    pub fn rademacher(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u32() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Pcg::new(7);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            buckets[(x * 10.0) as usize] += 1;
        }
        for b in buckets {
            assert!((700..1300).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(9);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn rademacher_signs() {
        let mut r = Pcg::new(5);
        let v = r.rademacher(1000);
        assert!(v.iter().all(|x| *x == 1.0 || *x == -1.0));
        let pos = v.iter().filter(|x| **x > 0.0).count();
        assert!((400..600).contains(&pos));
    }

    #[test]
    fn split_independent() {
        let mut r = Pcg::new(6);
        let mut a = r.split(1);
        let mut b = r.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
