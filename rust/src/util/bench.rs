//! Benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that use this module
//! for warmup, timed repetitions, percentile reporting, the aligned table
//! printer the table/figure regenerators share, and machine-readable JSON
//! emission (`--json <path>` merges a section per bench into one file, so
//! `make bench-json` accumulates `BENCH_parallel.json` across targets).

use super::clock::Stopwatch;
use super::json::Json;
use super::stats::{summarize, Summary};

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
/// Wall time flows through [`Stopwatch`] so `std::time` stays confined
/// to `util::clock` (lint rule D6).
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Stopwatch::start();
        f();
        samples.push(t0.elapsed_secs());
    }
    summarize(&samples)
}

pub fn report(name: &str, s: &Summary) {
    println!(
        "{name:<44} mean {:>10} p50 {:>10} p90 {:>10} (n={})",
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p90),
        s.n
    );
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Fixed-width table printer used by every table/figure regenerator so the
/// output can be diffed against EXPERIMENTS.md.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn row_count(&self) -> usize {
        self.rows.len()
    }

    /// The fixed-width text `print` writes, as a string with a trailing
    /// newline — the deterministic-report path (`repro report` / `repro
    /// slo`) captures tables instead of printing them, so rendering must
    /// not touch stdout.
    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>width$}  ", c, width = w[i]));
            }
            out.push_str(s.trim_end());
            out.push('\n');
        };
        line(&self.headers, &mut out);
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
        out.push('\n');
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// The `--json <path>` argument of a bench invocation, if present
/// (`cargo bench --bench X -- --json BENCH_parallel.json`).
pub fn json_path_arg() -> Option<String> {
    let mut args = std::env::args().skip(1); // taylint: allow(D3) -- bench CLI flag parsing, not numerics
    while let Some(a) = args.next() {
        if a == "--json" {
            return args.next();
        }
    }
    None
}

/// Merge-write one bench's results into a shared JSON report: reads `path`
/// if it already holds a JSON object, replaces key `section` with `value`,
/// and writes the whole object back — so several bench binaries can
/// accumulate sections in one machine-readable file.
///
/// Object-shaped sections are stamped with a `provenance` record (git
/// commit from the `GIT_COMMIT` env `make bench-json` exports, worker
/// count from `TAYNODE_THREADS`) so `repro perfdiff` can name what two
/// reports actually compare.  Scalar sections pass through unstamped.
pub fn merge_bench_json(path: &str, section: &str, value: Json) {
    let value = match value {
        Json::Obj(mut m) => {
            m.insert(
                "provenance".to_string(),
                Json::obj(vec![
                    ("git_commit", Json::str(super::cli::git_commit())),
                    ("threads", Json::num(super::pool::Pool::from_env().threads() as f64)),
                ]),
            );
            Json::Obj(m)
        }
        v => v,
    };
    let existing = std::fs::read_to_string(path).ok();
    let mut root = existing
        .as_deref()
        .map(|s| match Json::parse(s) {
            Ok(Json::Obj(m)) => m,
            _ => {
                // An unreadable report (interrupted run, hand edit) is
                // replaced, but never silently.
                eprintln!("warning: {path} held no JSON object; starting a fresh report");
                Default::default()
            }
        })
        .unwrap_or_default();
    root.insert(section.to_string(), value);
    std::fs::write(path, Json::Obj(root).to_string())
        .unwrap_or_else(|e| panic!("writing bench json {path}: {e}"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_positive() {
        let s = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("us"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    #[should_panic]
    fn table_checks_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn render_is_print_shaped_and_deterministic() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "22".into()]);
        let s = t.render();
        assert!(s.ends_with('\n'));
        assert_eq!(s.lines().count(), 4, "{s:?}"); // header, rule, 2 rows
        assert!(s.lines().next().unwrap().contains("name"));
        assert_eq!(s, t.render(), "rendering must be a pure function");
    }

    #[test]
    fn merge_bench_json_stamps_provenance_on_object_sections() {
        let path = std::env::temp_dir().join("taynode_bench_prov_test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        merge_bench_json(&path, "s", Json::obj(vec![("x", Json::num(1.0))]));
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let prov = j.req("s").unwrap().req("provenance").unwrap();
        assert!(prov.get("git_commit").is_some());
        assert!(prov.req("threads").unwrap().as_f64().unwrap() >= 1.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_bench_json_accumulates_sections() {
        let path = std::env::temp_dir().join("taynode_bench_json_test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        merge_bench_json(&path, "a", Json::obj(vec![("x", Json::num(1.0))]));
        merge_bench_json(&path, "b", Json::num(2.0));
        // overwrite an existing section, keep the other
        merge_bench_json(&path, "a", Json::num(3.0));
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.req("a").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.req("b").unwrap().as_f64(), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }
}
