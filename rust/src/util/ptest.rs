//! Micro property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing seed/case and (for shrinkable generators) retries with smaller
//! magnitudes to present a more minimal counterexample.

use super::rng::Pcg;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 128, seed: 0x5eed }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `prop(rng, case_index)`; it should panic (assert!) on violation.
    /// On a panicking case we re-run it to surface the panic after printing
    /// reproduction info.
    pub fn run<F: Fn(&mut Pcg, usize) + std::panic::RefUnwindSafe>(
        &self,
        name: &str,
        prop: F,
    ) {
        for case in 0..self.cases {
            let mut rng = Pcg::with_stream(self.seed + case as u64, 77);
            let result = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| prop(&mut rng, case)),
            );
            if result.is_err() {
                eprintln!(
                    "property {name:?} failed: case {case}, seed {} \
                     (rerun with Prop {{ seed: {}, .. }})",
                    self.seed, self.seed
                );
                let mut rng = Pcg::with_stream(self.seed + case as u64, 77);
                prop(&mut rng, case); // re-panic with the original message
                unreachable!();
            }
        }
    }
}

/// Generators for common test inputs.
pub mod gen {
    use super::Pcg;

    /// Vector of finite f32 with bounded magnitude.
    pub fn vec_f32(rng: &mut Pcg, len: usize, mag: f32) -> Vec<f32> {
        (0..len).map(|_| rng.range(-mag, mag)).collect()
    }

    /// Vector of f64 in [lo, hi).
    pub fn vec_f64(rng: &mut Pcg, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len)
            .map(|_| lo + (hi - lo) * rng.uniform() as f64)
            .collect()
    }

    /// Random polynomial coefficients (degree `deg`, leading coeff != 0).
    pub fn poly(rng: &mut Pcg, deg: usize, mag: f32) -> Vec<f32> {
        let mut c = vec_f32(rng, deg + 1, mag);
        if c[deg].abs() < 0.1 {
            c[deg] = 0.5 * c[deg].signum().max(0.5);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        Prop::new(32).run("tautology", |rng, _| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic]
    fn fails_on_false_property() {
        Prop::new(64).run("falsehood", |rng, _| {
            assert!(rng.uniform() < 0.9, "found counterexample");
        });
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Pcg::new(1);
        let v = gen::vec_f32(&mut rng, 100, 2.0);
        assert!(v.iter().all(|x| x.abs() <= 2.0));
        let p = gen::poly(&mut rng, 3, 1.0);
        assert_eq!(p.len(), 4);
        assert!(p[3].abs() >= 0.1);
    }
}
