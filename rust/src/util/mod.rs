//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, bench harness, clocks (the one
//! door to `std::time`), property testing, and the scoped worker pool
//! behind the parallel execution layer.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod pool;
pub mod ptest;
pub mod rng;
pub mod stats;
