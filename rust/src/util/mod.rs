//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, bench harness, property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod ptest;
pub mod rng;
pub mod stats;
