//! Clocks: the one sanctioned door to `std::time` (lint rule D6).
//!
//! Everything that *records* time in this crate goes through the
//! [`Clock`] trait.  Deterministic paths (the telemetry recorder, tests,
//! trace replay) use [`StepClock`], which only advances when the driver
//! says so — a solver attempt, an engine step — so same-seed runs produce
//! bit-identical timestamps at any thread count.  Wall time exists only
//! here: [`WallClock`] for tick-shaped readings and [`Stopwatch`] for the
//! bench harness' elapsed-seconds measurements.  No other module may
//! touch `std::time` (taylint D6 fails the build otherwise), which keeps
//! wall-clock nondeterminism quarantined the way D3 quarantines env/RNG.

use std::time::Instant;

/// A monotonic tick source.  Ticks are dimensionless; each driver defines
/// its own unit (solver attempts, engine steps, microseconds).
pub trait Clock {
    /// The current tick count.
    fn now_ticks(&self) -> u64;
}

/// The deterministic clock: a counter advanced explicitly by the driver
/// that owns it.  This is what the telemetry recorder stamps events with,
/// and why same-seed traces are bit-identical across thread counts.
#[derive(Clone, Debug, Default)]
pub struct StepClock {
    ticks: u64,
}

impl StepClock {
    pub fn new() -> StepClock {
        StepClock { ticks: 0 }
    }

    /// Advance by one tick (e.g. one solver attempt).
    pub fn advance(&mut self) {
        self.ticks += 1;
    }

    /// Jump to an externally-maintained tick count (e.g. the serving
    /// engine's step number).  Monotonicity is the caller's contract.
    pub fn set_ticks(&mut self, ticks: u64) {
        self.ticks = ticks;
    }
}

impl Clock for StepClock {
    fn now_ticks(&self) -> u64 {
        self.ticks
    }
}

/// Wall clock in whole microseconds since construction.  For operator
/// reporting only — never for anything a deterministic trace contains.
#[derive(Clone, Debug)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn start() -> WallClock {
        WallClock { origin: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now_ticks(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// Elapsed-seconds stopwatch for the bench harness (`util::bench` times
/// through this, so `std::time` stays confined to this module).
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_clock_advances_only_on_command() {
        let mut c = StepClock::new();
        assert_eq!(c.now_ticks(), 0);
        c.advance();
        c.advance();
        assert_eq!(c.now_ticks(), 2);
        c.set_ticks(100);
        assert_eq!(c.now_ticks(), 100);
    }

    #[test]
    fn wall_clock_and_stopwatch_are_monotonic() {
        let w = WallClock::start();
        let s = Stopwatch::start();
        let a = w.now_ticks();
        std::hint::black_box((0..20_000).sum::<u64>());
        assert!(w.now_ticks() >= a);
        assert!(s.elapsed_secs() >= 0.0);
    }
}
