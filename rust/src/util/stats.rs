//! Summary statistics used by the metrics pipeline and bench harness.

#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(1) as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 0.50),
        p90: percentile(&sorted, 0.90),
        p99: percentile(&sorted, 0.99),
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Pearson correlation — used to verify the Fig 7 monotone R_K <-> NFE link.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt() + 1e-300)
}

/// Spearman rank correlation (monotonicity, robust to scale).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut out = vec![0.0; xs.len()];
    for (rank, &i) in idx.iter().enumerate() {
        out[i] = rank as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.9) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn correlations() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
        let yd = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yd) + 1.0).abs() < 1e-9);
        // monotone nonlinear map: spearman 1, pearson < 1
        let ym = [1.0, 8.0, 27.0, 4096.0];
        assert!((spearman(&xs, &ym) - 1.0).abs() < 1e-9);
        assert!(pearson(&xs, &ym) < 1.0);
    }

    #[test]
    fn empty_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
    }
}
