//! Batching and train/test splitting over in-memory datasets.
//!
//! The trainer consumes fixed-size batches (artifact shapes are static), so
//! the loader guarantees every yielded batch has exactly `batch` rows,
//! dropping the epoch remainder (standard drop-last semantics).

use crate::util::rng::Pcg;

/// Row-major feature matrix with optional integer labels.
pub struct Dataset {
    pub x: Vec<f32>,
    pub labels: Option<Vec<i32>>,
    pub n: usize,
    /// Row width (product of per-example feature dims).
    pub row: usize,
    /// Optional second stream with its own row width (e.g. masks).
    pub x2: Option<(Vec<f32>, usize)>,
}

impl Dataset {
    pub fn new(x: Vec<f32>, row: usize) -> Dataset {
        assert_eq!(x.len() % row, 0);
        let n = x.len() / row;
        Dataset { x, labels: None, n, row, x2: None }
    }

    pub fn with_labels(mut self, labels: Vec<i32>) -> Dataset {
        assert_eq!(labels.len(), self.n);
        self.labels = Some(labels);
        self
    }

    pub fn with_second(mut self, x2: Vec<f32>, row2: usize) -> Dataset {
        assert_eq!(x2.len() / row2, self.n);
        self.x2 = Some((x2, row2));
        self
    }

    /// Split off the last `frac` of rows as a held-out set.
    pub fn split(self, frac: f32) -> (Dataset, Dataset) {
        let n_test = ((self.n as f32 * frac) as usize).clamp(1, self.n - 1);
        let n_train = self.n - n_test;
        let cut = n_train * self.row;
        let (xtr, xte) = (self.x[..cut].to_vec(), self.x[cut..].to_vec());
        let (ltr, lte) = match &self.labels {
            Some(l) => (Some(l[..n_train].to_vec()), Some(l[n_train..].to_vec())),
            None => (None, None),
        };
        let (s_tr, s_te) = match &self.x2 {
            Some((x2, r2)) => {
                let c2 = n_train * r2;
                (
                    Some((x2[..c2].to_vec(), *r2)),
                    Some((x2[c2..].to_vec(), *r2)),
                )
            }
            None => (None, None),
        };
        (
            Dataset { x: xtr, labels: ltr, n: n_train, row: self.row, x2: s_tr },
            Dataset { x: xte, labels: lte, n: n_test, row: self.row, x2: s_te },
        )
    }
}

/// One materialized batch (contiguous copies — the PJRT transfer needs
/// contiguous host buffers anyway).
pub struct Batch {
    pub x: Vec<f32>,
    pub labels: Vec<i32>,
    pub x2: Vec<f32>,
    pub indices: Vec<usize>,
}

/// Shuffling batcher with drop-last semantics.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Pcg,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64) -> Batcher<'a> {
        assert!(batch <= ds.n, "batch {batch} > dataset {n}", n = ds.n);
        let mut b = Batcher {
            ds,
            batch,
            order: (0..ds.n).collect(),
            cursor: 0,
            rng: Pcg::new(seed),
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.n / self.batch
    }

    /// Next batch, reshuffling at epoch boundaries.
    pub fn next(&mut self) -> Batch {
        if self.cursor + self.batch > self.ds.n {
            self.reshuffle();
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        let row = self.ds.row;
        let mut x = Vec::with_capacity(self.batch * row);
        let mut labels = Vec::with_capacity(self.batch);
        let r2 = match &self.ds.x2 {
            Some((_, r2)) => *r2,
            None => 0,
        };
        let mut x2 = Vec::with_capacity(self.batch * r2);
        for &i in idx {
            x.extend_from_slice(&self.ds.x[i * row..(i + 1) * row]);
            if let Some(l) = &self.ds.labels {
                labels.push(l[i]);
            }
            if let Some((xs, _)) = &self.ds.x2 {
                x2.extend_from_slice(&xs[i * r2..(i + 1) * r2]);
            }
        }
        Batch { x, labels, x2, indices: idx.to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::Prop;

    fn toy_ds(n: usize) -> Dataset {
        let x: Vec<f32> = (0..n * 3).map(|i| i as f32).collect();
        let l: Vec<i32> = (0..n as i32).collect();
        Dataset::new(x, 3).with_labels(l)
    }

    #[test]
    fn batches_have_exact_size_and_pairing() {
        let ds = toy_ds(10);
        let mut b = Batcher::new(&ds, 4, 0);
        for _ in 0..7 {
            let batch = b.next();
            assert_eq!(batch.x.len(), 12);
            assert_eq!(batch.labels.len(), 4);
            // row pairing: row i begins with 3*label
            for (k, l) in batch.labels.iter().enumerate() {
                assert_eq!(batch.x[k * 3], (*l * 3) as f32);
            }
        }
    }

    #[test]
    fn epoch_covers_all_examples_property() {
        Prop::new(30).run("epoch-coverage", |rng, _| {
            let n = 8 + rng.below(40);
            let bsz = 1 + rng.below(n.min(9));
            let ds = toy_ds(n);
            let mut b = Batcher::new(&ds, bsz, rng.next_u64());
            let per = b.batches_per_epoch();
            let mut seen = vec![false; n];
            for _ in 0..per {
                for &i in &b.next().indices {
                    assert!(!seen[i], "duplicate within epoch");
                    seen[i] = true;
                }
            }
            let covered = seen.iter().filter(|s| **s).count();
            assert_eq!(covered, per * bsz);
        });
    }

    #[test]
    fn split_preserves_rows() {
        let ds = toy_ds(10).with_second(vec![1.0; 20], 2);
        let (tr, te) = ds.split(0.25);
        assert_eq!(tr.n, 8);
        assert_eq!(te.n, 2);
        assert_eq!(tr.x.len(), 24);
        assert_eq!(te.labels.as_ref().unwrap().len(), 2);
        assert_eq!(te.x2.as_ref().unwrap().0.len(), 4);
    }
}
