//! 2-D toy densities for the native CNF experiments (the classic
//! normalizing-flow picture targets): multi-modal, curved, and
//! rotation-structured shapes that a Gaussian base cannot fit without a
//! real flow.  Deterministic per `(name, n, seed)` — every table row
//! records its seed.

use crate::util::rng::Pcg;

/// The available density names.
pub const NAMES: &[&str] = &["two_gaussians", "ring", "pinwheel"];

/// Sample `n` points (row-major `[n, 2]`) from the named density:
///
/// * `"two_gaussians"` — equal mixture at (±1.2, 0), σ = 0.5;
/// * `"ring"` — radius 1.5 annulus with σ = 0.15 radial noise;
/// * `"pinwheel"` — three Gaussian arms, each sheared by a rotation that
///   grows with the radius.
///
/// Panics on an unknown name (see [`NAMES`]).
pub fn sample(name: &str, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed ^ 0xd3a5);
    let mut x = Vec::with_capacity(n * 2);
    match name {
        "two_gaussians" => {
            for _ in 0..n {
                let c = if rng.below(2) == 0 { 1.2f32 } else { -1.2 };
                x.push(c + 0.5 * rng.normal());
                x.push(0.5 * rng.normal());
            }
        }
        "ring" => {
            for _ in 0..n {
                let th = rng.range(0.0, 2.0 * std::f32::consts::PI);
                let r = 1.5 + 0.15 * rng.normal();
                x.push(r * th.cos());
                x.push(r * th.sin());
            }
        }
        "pinwheel" => {
            for _ in 0..n {
                let arm = rng.below(3) as f32;
                let base = arm * 2.0 * std::f32::consts::PI / 3.0;
                let rad = 0.3 + rng.normal().abs();
                let th = base + 0.25 * rng.normal() + 0.6 * rad;
                x.push(rad * th.cos());
                x.push(rad * th.sin());
            }
        }
        other => panic!("unknown toy density {other:?}; known: {NAMES:?}"),
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_deterministic_and_shaped() {
        for name in NAMES {
            let a = sample(name, 64, 5);
            let b = sample(name, 64, 5);
            assert_eq!(a.len(), 128, "{name}");
            assert_eq!(a, b, "{name}: same seed, same sample");
            assert_ne!(a, sample(name, 64, 6), "{name}: seed matters");
            assert!(a.iter().all(|v| v.is_finite() && v.abs() < 10.0), "{name}");
        }
    }

    #[test]
    fn two_gaussians_is_bimodal_in_x() {
        let x = sample("two_gaussians", 400, 1);
        let (mut left, mut right) = (0usize, 0usize);
        for r in 0..400 {
            if x[2 * r] < 0.0 {
                left += 1;
            } else {
                right += 1;
            }
        }
        assert!(left > 100 && right > 100, "left {left} right {right}");
    }

    #[test]
    #[should_panic(expected = "unknown toy density")]
    fn unknown_name_panics() {
        let _ = sample("nope", 8, 0);
    }
}
