//! Synthetic tabular density-estimation data (MINIBOONE substitute,
//! DESIGN.md §3): a correlated Gaussian mixture — continuous, multi-modal,
//! anisotropic — the properties the FFJORD tabular experiment exercises.

use crate::util::rng::Pcg;

pub struct TabularSim {
    pub x: Vec<f32>, // [n, d], standardized
    pub n: usize,
    pub d: usize,
}

pub struct TabularGen {
    means: Vec<Vec<f32>>,
    chols: Vec<Vec<f32>>, // lower-triangular [d*d]
    weights: Vec<f32>,
    d: usize,
}

impl TabularGen {
    pub fn new(d: usize, components: usize, seed: u64) -> TabularGen {
        let mut rng = Pcg::new(seed ^ 0xb00e);
        let mut means = vec![];
        let mut chols = vec![];
        let mut weights = vec![];
        for _ in 0..components {
            means.push((0..d).map(|_| rng.normal() * 1.8).collect());
            let mut l = vec![0.0f32; d * d];
            for i in 0..d {
                for j in 0..i {
                    l[i * d + j] = 0.35 * rng.normal();
                }
                l[i * d + i] = rng.range(0.4, 1.0);
            }
            chols.push(l);
            weights.push(rng.range(0.5, 1.5));
        }
        let s: f32 = weights.iter().sum();
        for w in &mut weights {
            *w /= s;
        }
        TabularGen { means, chols, weights, d }
    }

    pub fn sample(&self, n: usize, seed: u64) -> TabularSim {
        let mut rng = Pcg::new(seed);
        let d = self.d;
        let mut x = vec![0.0f32; n * d];
        for i in 0..n {
            // pick component
            let u = rng.uniform();
            let mut acc = 0.0;
            let mut comp = 0;
            for (k, w) in self.weights.iter().enumerate() {
                acc += w;
                if u <= acc {
                    comp = k;
                    break;
                }
            }
            let z: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let l = &self.chols[comp];
            let m = &self.means[comp];
            for r in 0..d {
                let mut v = m[r];
                for c in 0..=r {
                    v += l[r * d + c] * z[c];
                }
                x[i * d + r] = v;
            }
        }
        // standardize (FFJORD preprocessing)
        for c in 0..d {
            let mut mean = 0.0f32;
            for i in 0..n {
                mean += x[i * d + c];
            }
            mean /= n as f32;
            let mut var = 0.0f32;
            for i in 0..n {
                let v = x[i * d + c] - mean;
                var += v * v;
            }
            let std = (var / n as f32).sqrt().max(1e-6);
            for i in 0..n {
                x[i * d + c] = (x[i * d + c] - mean) / std;
            }
        }
        TabularSim { x, n, d }
    }
}

/// Image-like density data for the MNIST-CNF experiment (Table 2): dequantized
/// low-res digits from the stroke renderer, logit-transformed as in FFJORD.
pub fn image_density(n: usize, side: usize, seed: u64) -> TabularSim {
    let mut rng = Pcg::new(seed);
    let d = side * side;
    let mut x = vec![0.0f32; n * d];
    for i in 0..n {
        let class = i % crate::data::synth_mnist::N_CLASS;
        let img14 = crate::data::synth_mnist::render(class, &mut rng);
        // downsample 14x14 -> side x side by box averaging
        for oy in 0..side {
            for ox in 0..side {
                let mut acc = 0.0f32;
                let mut cnt = 0.0f32;
                let y0 = oy * 14 / side;
                let y1 = ((oy + 1) * 14).div_ceil(side);
                let x0 = ox * 14 / side;
                let x1 = ((ox + 1) * 14).div_ceil(side);
                for yy in y0..y1 {
                    for xx in x0..x1 {
                        acc += img14[yy * 14 + xx];
                        cnt += 1.0;
                    }
                }
                let v = acc / cnt;
                // dequantize + logit transform (alpha=0.05), FFJORD-style
                let u = (v * 255.0 + rng.uniform()) / 256.0;
                let p = 0.05 + 0.9 * u;
                x[i * d + oy * side + ox] = (p / (1.0 - p)).ln();
            }
        }
    }
    TabularSim { x, n, d }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardized_moments() {
        let g = TabularGen::new(8, 3, 1);
        let s = g.sample(4000, 2);
        for c in 0..8 {
            let mean: f32 =
                (0..s.n).map(|i| s.x[i * 8 + c]).sum::<f32>() / s.n as f32;
            let var: f32 = (0..s.n)
                .map(|i| (s.x[i * 8 + c] - mean).powi(2))
                .sum::<f32>()
                / s.n as f32;
            assert!(mean.abs() < 0.05, "col {c} mean {mean}");
            assert!((var - 1.0).abs() < 0.05, "col {c} var {var}");
        }
    }

    #[test]
    fn multimodal_structure_visible() {
        // With well-separated means the 1-D marginal must be non-gaussian:
        // check excess spread between mixture draws vs a refit gaussian by
        // comparing 4th moment (kurtosis signature of multimodality).
        let g = TabularGen::new(4, 2, 7);
        let s = g.sample(4000, 3);
        let col: Vec<f32> = (0..s.n).map(|i| s.x[i * 4]).collect();
        let m4: f32 =
            col.iter().map(|v| v.powi(4)).sum::<f32>() / col.len() as f32;
        // standardized gaussian has kurtosis 3; bimodal mixtures deviate
        assert!((m4 - 3.0).abs() > 0.1, "kurtosis {m4}");
    }

    #[test]
    fn image_density_shapes_and_finite() {
        let s = image_density(30, 8, 4);
        assert_eq!(s.d, 64);
        assert_eq!(s.x.len(), 30 * 64);
        assert!(s.x.iter().all(|v| v.is_finite()));
        // logit range for p in [0.05, 0.95]
        let lo = (0.05f32 / 0.95).ln();
        let hi = (0.95f32 / 0.05).ln();
        assert!(s.x.iter().all(|v| *v >= lo - 1e-4 && *v <= hi + 1e-4));
    }

    #[test]
    fn deterministic() {
        let g = TabularGen::new(8, 3, 1);
        assert_eq!(g.sample(50, 5).x, g.sample(50, 5).x);
    }
}
