//! Synthetic ICU-like irregular multivariate time series (PhysioNet 2012
//! substitute, DESIGN.md §3).
//!
//! Generator: a stable random linear latent ODE  dx/dt = A x  observed
//! through a fixed random nonlinear map with per-feature sampling rates and
//! missingness — matching the structure the Latent-ODE model assumes
//! (smooth shared latent dynamics, sparse irregular observations).  Like
//! the paper's preprocessing (hourly quantization to 49 shared stamps), all
//! trajectories share one T-point grid; irregularity enters via the mask.

use crate::solvers::{solve_fixed, tableau};
use crate::util::rng::Pcg;

pub const LATENT: usize = 6;

pub struct PhysioSim {
    /// [n, t, f] observations (0 where unobserved)
    pub x: Vec<f32>,
    /// [n, t, f] observation mask in {0, 1}
    pub mask: Vec<f32>,
    pub n: usize,
    pub t: usize,
    pub f: usize,
}

pub struct PhysioGen {
    a: Vec<f32>,       // [LATENT, LATENT] stable dynamics
    w: Vec<f32>,       // [f, LATENT] observation map
    b: Vec<f32>,       // [f]
    rates: Vec<f32>,   // per-feature observation probability
    f: usize,
}

impl PhysioGen {
    pub fn new(f: usize, seed: u64) -> PhysioGen {
        let mut rng = Pcg::new(seed ^ 0x9e1c);
        // A = -0.6 I + 1.2 * skew + 0.15 * noise: oscillatory but decaying.
        let mut a = vec![0.0f32; LATENT * LATENT];
        let mut skew = vec![0.0f32; LATENT * LATENT];
        for i in 0..LATENT {
            for j in (i + 1)..LATENT {
                let v = rng.normal();
                skew[i * LATENT + j] = v;
                skew[j * LATENT + i] = -v;
            }
        }
        for i in 0..LATENT {
            for j in 0..LATENT {
                a[i * LATENT + j] = 1.2 * skew[i * LATENT + j] + 0.15 * rng.normal();
            }
            a[i * LATENT + i] -= 0.6;
        }
        let w = (0..f * LATENT).map(|_| rng.normal() * 0.8).collect();
        let b = (0..f).map(|_| rng.normal() * 0.3).collect();
        let rates = (0..f).map(|_| rng.range(0.25, 0.8)).collect();
        PhysioGen { a, w, b, rates, f }
    }

    /// Latent trajectory on a uniform grid via the in-crate RK4 solver.
    fn latent_traj(&self, x0: &[f32], t_pts: usize) -> Vec<Vec<f32>> {
        let tb = tableau::rk4();
        let mut out = vec![x0.to_vec()];
        let mut x = x0.to_vec();
        for i in 0..t_pts - 1 {
            let t0 = i as f32 / (t_pts - 1) as f32;
            let t1 = (i + 1) as f32 / (t_pts - 1) as f32;
            let a = &self.a;
            let (xn, _) = solve_fixed(
                move |_t: f32, y: &[f32], dy: &mut [f32]| {
                    crate::tensor::matvec(a, LATENT, LATENT, y, dy);
                },
                t0,
                t1,
                &x,
                4,
                &tb,
            );
            x = xn.clone();
            out.push(xn);
        }
        out
    }

    pub fn sample(&self, n: usize, t_pts: usize, seed: u64) -> PhysioSim {
        let mut rng = Pcg::new(seed);
        let f = self.f;
        let mut x = vec![0.0f32; n * t_pts * f];
        let mut mask = vec![0.0f32; n * t_pts * f];
        for i in 0..n {
            let x0: Vec<f32> = (0..LATENT).map(|_| rng.normal()).collect();
            let traj = self.latent_traj(&x0, t_pts);
            for (ti, lat) in traj.iter().enumerate() {
                for fi in 0..f {
                    let mut v = self.b[fi];
                    for (k, l) in lat.iter().enumerate() {
                        v += self.w[fi * LATENT + k] * l;
                    }
                    // bounded vitals-like signal + measurement noise
                    let obs = v.tanh() + 0.05 * rng.normal();
                    let seen = rng.uniform() < self.rates[fi];
                    let idx = (i * t_pts + ti) * f + fi;
                    if seen {
                        x[idx] = obs;
                        mask[idx] = 1.0;
                    }
                }
            }
        }
        PhysioSim { x, mask, n, t: t_pts, f }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_mask_consistency() {
        let g = PhysioGen::new(8, 3);
        let d = g.sample(10, 16, 1);
        assert_eq!(d.x.len(), 10 * 16 * 8);
        assert_eq!(d.mask.len(), d.x.len());
        for (xi, mi) in d.x.iter().zip(&d.mask) {
            assert!(*mi == 0.0 || *mi == 1.0);
            if *mi == 0.0 {
                assert_eq!(*xi, 0.0);
            }
        }
    }

    #[test]
    fn observation_rate_in_expected_band() {
        let g = PhysioGen::new(8, 3);
        let d = g.sample(50, 16, 2);
        let rate = d.mask.iter().sum::<f32>() / d.mask.len() as f32;
        assert!((0.2..0.85).contains(&rate), "rate {rate}");
    }

    #[test]
    fn signals_bounded_and_smooth() {
        let g = PhysioGen::new(4, 5);
        let d = g.sample(5, 24, 3);
        assert!(d.x.iter().all(|v| v.abs() <= 1.3));
        // observed values at adjacent times shouldn't jump wildly
        // (latent dynamics are smooth; noise is 0.05)
        let mut max_jump = 0.0f32;
        for i in 0..d.n {
            for ti in 0..d.t - 1 {
                for fi in 0..d.f {
                    let a = (i * d.t + ti) * d.f + fi;
                    let b = (i * d.t + ti + 1) * d.f + fi;
                    if d.mask[a] == 1.0 && d.mask[b] == 1.0 {
                        max_jump = max_jump.max((d.x[a] - d.x[b]).abs());
                    }
                }
            }
        }
        assert!(max_jump < 1.0, "max jump {max_jump}");
    }

    #[test]
    fn deterministic() {
        let g = PhysioGen::new(8, 3);
        let a = g.sample(5, 16, 9);
        let b = g.sample(5, 16, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.mask, b.mask);
    }
}
