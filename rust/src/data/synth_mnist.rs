//! Procedural MNIST substitute: 14x14 grayscale digits rendered from
//! per-class stroke templates with random affine jitter, stroke width
//! variation and pixel noise (DESIGN.md §3: the MNIST experiments probe
//! NFE/loss trade-offs on a learnable 10-class image problem; the exact
//! glyph corpus is irrelevant to the code path being reproduced).

use crate::util::rng::Pcg;

pub const SIDE: usize = 14;
pub const DIM: usize = SIDE * SIDE;
pub const N_CLASS: usize = 10;

/// Polyline stroke templates per digit, in the unit square (y down).
fn template(class: usize) -> Vec<Vec<(f32, f32)>> {
    fn ellipse(cx: f32, cy: f32, rx: f32, ry: f32, n: usize,
               a0: f32, a1: f32) -> Vec<(f32, f32)> {
        (0..=n)
            .map(|i| {
                let a = a0 + (a1 - a0) * i as f32 / n as f32;
                (cx + rx * a.cos(), cy + ry * a.sin())
            })
            .collect()
    }
    use std::f32::consts::PI;
    match class {
        0 => vec![ellipse(0.5, 0.5, 0.26, 0.36, 16, 0.0, 2.0 * PI)],
        1 => vec![vec![(0.38, 0.25), (0.52, 0.12), (0.52, 0.88)]],
        2 => vec![
            ellipse(0.5, 0.3, 0.22, 0.18, 8, PI, 2.2 * PI),
            vec![(0.68, 0.42), (0.3, 0.85), (0.72, 0.85)],
        ],
        3 => vec![
            ellipse(0.48, 0.3, 0.2, 0.17, 8, 1.2 * PI, 2.6 * PI),
            ellipse(0.48, 0.67, 0.22, 0.19, 8, 1.4 * PI, 2.8 * PI),
        ],
        4 => vec![
            vec![(0.62, 0.1), (0.28, 0.6), (0.78, 0.6)],
            vec![(0.62, 0.35), (0.62, 0.9)],
        ],
        5 => vec![
            vec![(0.7, 0.12), (0.34, 0.12), (0.32, 0.45)],
            ellipse(0.48, 0.65, 0.22, 0.22, 10, 1.5 * PI, 2.9 * PI),
        ],
        6 => vec![
            vec![(0.62, 0.1), (0.4, 0.45)],
            ellipse(0.5, 0.65, 0.2, 0.22, 12, 0.0, 2.0 * PI),
        ],
        7 => vec![vec![(0.28, 0.14), (0.74, 0.14), (0.44, 0.88)]],
        8 => vec![
            ellipse(0.5, 0.3, 0.18, 0.17, 12, 0.0, 2.0 * PI),
            ellipse(0.5, 0.68, 0.21, 0.2, 12, 0.0, 2.0 * PI),
        ],
        9 => vec![
            ellipse(0.52, 0.33, 0.19, 0.2, 12, 0.0, 2.0 * PI),
            vec![(0.71, 0.35), (0.64, 0.9)],
        ],
        _ => panic!("class out of range"),
    }
}

fn dist_to_segment(px: f32, py: f32, a: (f32, f32), b: (f32, f32)) -> f32 {
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 < 1e-12 {
        0.0
    } else {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    ((px - cx) * (px - cx) + (py - cy) * (py - cy)).sqrt()
}

/// Render one digit with random jitter. Output: DIM floats in [0, 1].
pub fn render(class: usize, rng: &mut Pcg) -> Vec<f32> {
    let angle = rng.range(-0.25, 0.25);
    let scale = rng.range(0.85, 1.12);
    let (tx, ty) = (rng.range(-0.07, 0.07), rng.range(-0.07, 0.07));
    let width = rng.range(0.045, 0.075);
    let (sin, cos) = (angle.sin(), angle.cos());
    let warp = |(x, y): (f32, f32)| {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (rx, ry) = (cos * cx - sin * cy, sin * cx + cos * cy);
        (0.5 + scale * rx + tx, 0.5 + scale * ry + ty)
    };
    let strokes: Vec<Vec<(f32, f32)>> = template(class)
        .into_iter()
        .map(|s| s.into_iter().map(warp).collect())
        .collect();

    let mut img = vec![0.0f32; DIM];
    for iy in 0..SIDE {
        for ix in 0..SIDE {
            let px = (ix as f32 + 0.5) / SIDE as f32;
            let py = (iy as f32 + 0.5) / SIDE as f32;
            let mut best = f32::MAX;
            for s in &strokes {
                for w in s.windows(2) {
                    best = best.min(dist_to_segment(px, py, w[0], w[1]));
                }
            }
            let v = (-(best * best) / (2.0 * width * width)).exp();
            let noise = rng.range(-0.04, 0.04);
            img[iy * SIDE + ix] = (v + noise).clamp(0.0, 1.0);
        }
    }
    img
}

/// A full dataset: `n` examples with balanced random classes.
pub struct MnistSim {
    pub images: Vec<f32>, // [n, DIM]
    pub labels: Vec<i32>, // [n]
    pub n: usize,
}

pub fn generate(n: usize, seed: u64) -> MnistSim {
    let mut rng = Pcg::new(seed);
    let mut images = Vec::with_capacity(n * DIM);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % N_CLASS; // balanced
        let img = render(class, &mut rng);
        images.extend_from_slice(&img);
        labels.push(class as i32);
    }
    // shuffle examples (keeping image/label pairing)
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut im2 = vec![0.0f32; n * DIM];
    let mut lb2 = vec![0i32; n];
    for (dst, &src) in order.iter().enumerate() {
        im2[dst * DIM..(dst + 1) * DIM]
            .copy_from_slice(&images[src * DIM..(src + 1) * DIM]);
        lb2[dst] = labels[src];
    }
    MnistSim { images: im2, labels: lb2, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_in_range_and_nonempty() {
        let mut rng = Pcg::new(0);
        for class in 0..N_CLASS {
            let img = render(class, &mut rng);
            assert_eq!(img.len(), DIM);
            assert!(img.iter().all(|v| (0.0..=1.0).contains(v)));
            let mass: f32 = img.iter().sum();
            assert!(mass > 3.0, "class {class} too faint: {mass}");
            assert!(mass < DIM as f32 * 0.8, "class {class} saturated");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of different classes must differ substantially more
        // than two draws of the same class — otherwise the classification
        // experiment is vacuous.
        let mut rng = Pcg::new(1);
        let mean_img = |class: usize, rng: &mut Pcg| {
            let mut acc = vec![0.0f32; DIM];
            for _ in 0..24 {
                for (a, v) in acc.iter_mut().zip(render(class, rng)) {
                    *a += v / 24.0;
                }
            }
            acc
        };
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        let m3a = mean_img(3, &mut rng);
        let m3b = mean_img(3, &mut rng);
        let m7 = mean_img(7, &mut rng);
        let m1 = mean_img(1, &mut rng);
        assert!(dist(&m3a, &m7) > 3.0 * dist(&m3a, &m3b));
        assert!(dist(&m1, &m7) > 3.0 * dist(&m3a, &m3b));
    }

    #[test]
    fn generate_balanced_and_deterministic() {
        let d1 = generate(100, 7);
        let d2 = generate(100, 7);
        assert_eq!(d1.images, d2.images);
        assert_eq!(d1.labels, d2.labels);
        let mut counts = [0usize; N_CLASS];
        for l in &d1.labels {
            counts[*l as usize] += 1;
        }
        assert!(counts.iter().all(|c| *c == 10), "{counts:?}");
    }
}
