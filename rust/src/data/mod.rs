//! Synthetic dataset generators (paper-dataset substitutions, DESIGN.md §3)
//! and the batching pipeline.

pub mod loader;
pub mod miniboone_sim;
pub mod physionet_sim;
pub mod synth_mnist;
pub mod toy_density;

pub use loader::{Batch, Batcher, Dataset};
