//! # taynode
//!
//! A Rust + JAX + Pallas reproduction of *Learning Differential Equations
//! that are Easy to Solve* (Kelly, Bettencourt, Johnson, Duvenaud — NeurIPS
//! 2020): neural-ODE training with Taylor-mode `R_K` speed regularization,
//! with the evaluation/serving hot path (adaptive solvers + NFE accounting)
//! entirely in Rust over AOT-compiled XLA executables.
//!
//! Layer map (see DESIGN.md):
//! * [`solvers`] — fixed & adaptive Runge-Kutta suite with NFE accounting,
//!   shared stage machinery, and the batched multi-trajectory engine
//!   (`solvers::batch`: per-trajectory step control, active-set compaction
//!   over a `WorkingSet`, `RegularizedBatchDynamics` — native `R_K`
//!   quadrature over batched Taylor jets — and `LogDetBatchDynamics`, the
//!   CNF log-det augmentation over the divergence engine).
//! * [`taylor`] — truncated Taylor-series arithmetic / jets in pure Rust:
//!   scalar `Series`/`ode_jet` plus the SoA `SeriesVec`/`ode_jet_batch`
//!   that jets a whole `[B, n]` active set per sweep.
//! * [`nn`] — native dynamics models (`Mlp`, the concat-squash `Cnf`)
//!   written once against the scalar-generic `Value` algebra, so one
//!   forward pass serves the f32 solver path, the Taylor-jet path, and
//!   the reverse-mode tape.
//! * [`autodiff`] — tape-based reverse-mode VJP over batch columns, the
//!   divergence engine (`autodiff::div`: exact trace + fixed-seed
//!   Hutchinson), plus the flat-vector `Adam` optimizer.
//! * [`kern`] — cache-blocked SIMD-friendly kernels (the Rust port of the
//!   Pallas specs in `python/compile/kernels/`): flat-slab Cauchy/series
//!   recurrences, the fused MLP layer, and the fused RK stage axpy, each
//!   with its retained naive reference and a bit-identity contract
//!   (`benches/perf_kernels.rs` gates speedups on pre-timing equality).
//! * [`runtime`] — PJRT client (behind the `pjrt` feature; a thin stub
//!   substitutes by default), artifact registry, parameter store.
//! * [`serving`] — the continuous-batching inference engine: an admission
//!   queue over the batched driver (`BatchStepper`) with per-request
//!   deadline/tolerance classes, the `ServeRequest`/`ServeResponse` wire
//!   format, seeded Poisson load generation, and model-backed hosts for
//!   the toy / synth-MNIST / CNF workloads.
//! * [`coordinator`] — training loop (XLA-artifact and native
//!   discrete-adjoint paths), schedules, sweeps, metrics.
//! * [`data`] — synthetic MNIST / PhysioNet / MINIBOONE generators.
//! * [`experiments`] — one regenerator per paper table and figure.
//! * [`tensor`], [`util`] — substrates (vec math, PRNG, JSON, CLI, bench,
//!   and the scoped worker pool `util::pool` behind the parallel
//!   execution layer).
//! * [`obs`] — deterministic telemetry: the zero-cost-off `Recorder`,
//!   fixed log₂ histogram / counter registry, and Chrome-Trace NDJSON
//!   export (`repro trace`), with per-shard buffers merged in fixed order
//!   so same-seed traces are bit-identical at any thread count; plus the
//!   offline analytics over those traces — span rollups and critical
//!   paths (`obs::analyze`), per-trajectory solve-cost attribution
//!   (`obs::cost`), deadline-miss SLO budgets (`obs::slo`), and the
//!   `repro report` / `repro slo` renderers (`obs::report`).
//! * [`analysis`] — `taylint`, the in-repo determinism lint: a
//!   dependency-free tokenizer + rule catalog (D1–D7) that machine-checks
//!   the bit-identity invariants the pool guarantees (run via `make lint`
//!   or the `taylint` binary).

// Numerical-kernel style: index loops over parallel slices mirror the
// reference equations (Hairer et al.) more faithfully than iterator chains;
// keep clippy's stylistic lints from fighting that.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod analysis;
pub mod autodiff;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kern;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod serving;
pub mod solvers;
pub mod taylor;
pub mod tensor;
pub mod util;
