//! The divergence engine: `∇·f(z, t)` for a whole batch, out of one tape
//! recording — the instantaneous change-of-variables term of a continuous
//! normalizing flow (`d log p(z(t))/dt = −∇·f`).
//!
//! [`batch_divergence`] records a [`ValueDynamics`] forward **once** on a
//! reverse-mode [`Tape`] over `[B]` columns and then pulls the trace of the
//! state Jacobian out of it two ways:
//!
//! * **[`Divergence::Exact`]** — one backward sweep per state dimension,
//!   each seeding `e_i` on output `f_i` and reading `∂f_i/∂z_i` (n VJPs for
//!   the exact trace; the FFJORD `O(n)` cost).
//! * **[`Divergence::Hutchinson`]** — the stochastic trace estimator
//!   `E_v[vᵀ(∂f/∂z)v] = tr(∂f/∂z)`: one backward sweep per probe, seeding a
//!   **fixed-seed Rademacher** vector `v` and dotting the VJP `vᵀJ` with
//!   `v` again.  Probes are keyed on the *trajectory id* (never the row
//!   position), so the estimate is a deterministic function of the
//!   trajectory: the augmented vector field stays continuous across solver
//!   steps, active-set compaction, and worker-pool sharding — pooled and
//!   serial solves are bit-identical.
//!
//! [`divergence_values`] is the *forward-mode* twin over any [`Value`]
//! carrier (n first-order [`SeriesOf`] probes).  With `T = `[`Var`] the
//! divergence comes out as a **tape node**, which is how the training path
//! differentiates *through* the log-det dynamics (reverse-over-forward —
//! the tape cannot run reverse-over-reverse).
//!
//! ```
//! use taynode::autodiff::div::{batch_divergence, Divergence};
//! use taynode::nn::Mlp;
//!
//! // A linear field f = z·W + b has ∇·f = tr(W) everywhere.
//! let mut mlp = Mlp::new(2, &[], false, 0);
//! mlp.params = vec![0.5, 2.0, -1.0, 0.25, 0.1, -0.2]; // W, then b
//! let (dy, div) = batch_divergence(
//!     &mlp,
//!     &[0, 1],
//!     &[0.0, 0.3],
//!     &[1.0, -1.0, 0.5, 2.0],
//!     &Divergence::Exact,
//! );
//! assert_eq!(dy.len(), 4);
//! for d in &div {
//!     assert!((d - 0.75).abs() < 1e-12); // tr(W) = 0.5 + 0.25
//! }
//! ```

use super::{Tape, Var};
use crate::nn::{SeriesOf, Value, ValueDynamics};
use crate::util::rng::Pcg;

/// How [`batch_divergence`] turns the recorded Jacobian into a trace.
#[derive(Clone, Debug)]
pub enum Divergence {
    /// n backward sweeps — the exact trace.
    Exact,
    /// `probes` Rademacher sweeps averaged — the Hutchinson estimate.
    /// `seed` pins the probe vectors; together with the trajectory id it
    /// fully determines them (see [`rademacher_probe`]).
    Hutchinson { probes: usize, seed: u64 },
}

/// The fixed-seed Rademacher probe for one trajectory: n entries in
/// `{−1, +1}`, a pure function of `(seed, id, probe)` — never of the row
/// position or the thread count, which is what keeps Hutchinson-augmented
/// solves deterministic under compaction and pooling.
pub fn rademacher_probe(seed: u64, id: usize, probe: usize, n: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; n];
    fill_rademacher_probe(seed, id, probe, &mut out);
    out
}

/// [`rademacher_probe`] into a caller-owned buffer — the engine's
/// no-allocation path (one probe fill per row per sweep on the solver hot
/// path).
pub fn fill_rademacher_probe(seed: u64, id: usize, probe: usize, out: &mut [f64]) {
    let mut rng = Pcg::with_stream(
        seed ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        0x5bd1_e995 ^ probe as u64,
    );
    for v in out.iter_mut() {
        *v = if rng.next_u32() & 1 == 0 { 1.0 } else { -1.0 };
    }
}

/// Evaluate `f` and its divergence for a batch: `z` is row-major `[B, n]`,
/// `t` the per-row times, `ids` the stable trajectory ids (Hutchinson keys
/// its probes on them).  Returns `(dy, div)` with `dy` row-major `[B, n]`
/// and `div[r] = ∇·f(z_r, t_r)` (or its estimate).
///
/// One forward recording serves every sweep; parameters enter as tape
/// constants (this is the *solver-path* engine — the training path builds
/// gradient-tracked leaves and uses [`divergence_values`] instead).
pub fn batch_divergence<D: ValueDynamics>(
    f: &D,
    ids: &[usize],
    t: &[f64],
    z: &[f64],
    mode: &Divergence,
) -> (Vec<f64>, Vec<f64>) {
    let n = f.dim();
    assert!(n > 0, "batch_divergence: dim must be positive");
    let b = t.len();
    assert_eq!(z.len(), b * n, "batch_divergence: state shape");
    assert_eq!(ids.len(), b, "batch_divergence: ids length");
    if b == 0 {
        return (vec![], vec![]);
    }
    let tape = Tape::new(b);
    let mut colbuf = vec![0.0f64; b];
    let zvars: Vec<Var> = (0..n)
        .map(|j| {
            for (r, cv) in colbuf.iter_mut().enumerate() {
                *cv = z[r * n + j];
            }
            tape.input(&colbuf)
        })
        .collect();
    let tvar = tape.input(t);
    let out = f.forward_values(&zvars, &tvar);
    assert_eq!(out.len(), n, "batch_divergence: f output arity");
    let mut dy = vec![0.0f64; b * n];
    for (j, oj) in out.iter().enumerate() {
        for (r, v) in oj.value().iter().enumerate() {
            dy[r * n + j] = *v;
        }
    }
    let mut div = vec![0.0f64; b];
    match mode {
        Divergence::Exact => {
            let ones = vec![1.0f64; b];
            for i in 0..n {
                let g = tape.backward(&[(&out[i], ones.as_slice())]);
                for (dv, gr) in div.iter_mut().zip(g.wrt(&zvars[i])) {
                    *dv += *gr;
                }
            }
        }
        Divergence::Hutchinson { probes, seed } => {
            assert!(*probes >= 1, "Hutchinson needs at least one probe");
            let mut vcols: Vec<Vec<f64>> = vec![vec![0.0f64; b]; n];
            let mut vr = vec![0.0f64; n];
            for p in 0..*probes {
                for (r, id) in ids.iter().enumerate() {
                    fill_rademacher_probe(*seed, *id, p, &mut vr);
                    for (vc, vi) in vcols.iter_mut().zip(&vr) {
                        vc[r] = *vi;
                    }
                }
                let seeds: Vec<(&Var, &[f64])> = out
                    .iter()
                    .zip(&vcols)
                    .map(|(o, v)| (o, v.as_slice()))
                    .collect();
                let g = tape.backward(&seeds);
                for (i, vc) in vcols.iter().enumerate() {
                    let gz = g.wrt(&zvars[i]);
                    for r in 0..b {
                        div[r] += gz[r] * vc[r];
                    }
                }
            }
            let inv = 1.0 / *probes as f64;
            for dv in div.iter_mut() {
                *dv *= inv;
            }
        }
    }
    (dy, div)
}

/// Forward-mode exact divergence over any [`Value`] carrier: n first-order
/// series probes through `f` (direction `e_i` in z, time held constant),
/// summing coefficient 1 of output i.  `f` takes the same closure shape as
/// [`ode_jet_values`](crate::nn::ode_jet_values), so the training path
/// records jets and divergence through ONE closure on one tape — with
/// `T = `[`Var`], seeding a cotangent on the returned value
/// back-propagates through the whole Jacobian-trace computation.
pub fn divergence_values<T, F>(f: &mut F, z: &[T], t: &T) -> T
where
    T: Value,
    F: FnMut(&[SeriesOf<T>], &SeriesOf<T>) -> Vec<SeriesOf<T>>,
{
    let n = z.len();
    assert!(n > 0, "divergence_values: state must be non-empty");
    let zero = t.lift(0.0);
    let one = t.lift(1.0);
    let ts = SeriesOf::constant_padded(t.clone(), &zero, 1);
    let mut div: Option<T> = None;
    for i in 0..n {
        let zs: Vec<SeriesOf<T>> = (0..n)
            .map(|j| {
                if j == i {
                    SeriesOf::new(vec![z[j].clone(), one.clone()])
                } else {
                    SeriesOf::constant_padded(z[j].clone(), &zero, 1)
                }
            })
            .collect();
        let out = f(&zs, &ts);
        assert_eq!(out.len(), n, "divergence_values: f output arity");
        let d = out[i].coeff(1).clone();
        div = Some(match div {
            Some(a) => a.add(&d),
            None => d,
        });
    }
    div.expect("n > 0") // taylint: allow(D4) -- arity asserted above; the fold ran at least once
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Cnf, Mlp};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    /// A headless linear Mlp: f = z·W + b, so the Jacobian is Wᵀ and the
    /// divergence is exactly tr(W) everywhere.
    fn linear_field(w: &[f32], b: &[f32]) -> Mlp {
        let n = b.len();
        assert_eq!(w.len(), n * n);
        let mut mlp = Mlp::new(n, &[], false, 0);
        let mut params = w.to_vec();
        params.extend_from_slice(b);
        mlp.params = params;
        mlp
    }

    #[test]
    fn exact_trace_matches_analytic_divergence_on_linear_field() {
        // W row-major [in, out]: tr(W) = 0.7 - 0.3 + 0.2 = 0.6.
        let mlp = linear_field(
            &[0.7, 0.4, -0.9, 0.1, -0.3, 0.6, 0.2, -0.5, 0.2],
            &[0.1, -0.2, 0.3],
        );
        let mut rng = Pcg::new(5);
        let b = 6usize;
        let z: Vec<f64> = (0..b * 3).map(|_| rng.range(-2.0, 2.0) as f64).collect();
        let t: Vec<f64> = (0..b).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let ids: Vec<usize> = (0..b).collect();
        let (dy, div) = batch_divergence(&mlp, &ids, &t, &z, &Divergence::Exact);
        for (r, d) in div.iter().enumerate() {
            assert!(close(*d, 0.6, 1e-12), "row {r}: {d}");
        }
        // dy is the plain forward
        for r in 0..b {
            let want = mlp.forward_f64(&z[r * 3..(r + 1) * 3], t[r]);
            for i in 0..3 {
                assert!(close(dy[r * 3 + i], want[i], 1e-12), "row {r} dim {i}");
            }
        }
    }

    #[test]
    fn hutchinson_is_exact_on_a_diagonal_jacobian() {
        // With J diagonal, vᵀJv = Σ v_i² J_ii = tr(J) for EVERY Rademacher
        // v (v_i² = 1) — the estimator has zero variance, so one probe must
        // already equal the exact trace.
        let mlp = linear_field(&[1.3, 0.0, 0.0, -0.8], &[0.0, 0.5]);
        let mut rng = Pcg::new(9);
        let b = 5usize;
        let z: Vec<f64> = (0..b * 2).map(|_| rng.range(-1.5, 1.5) as f64).collect();
        let t = vec![0.0f64; b];
        let ids: Vec<usize> = (0..b).map(|r| 10 + r).collect();
        let (_, exact) = batch_divergence(&mlp, &ids, &t, &z, &Divergence::Exact);
        let (_, est) =
            batch_divergence(&mlp, &ids, &t, &z, &Divergence::Hutchinson { probes: 1, seed: 7 });
        for (e, x) in est.iter().zip(&exact) {
            assert!(close(*e, *x, 1e-12), "{e} vs {x}");
        }
    }

    #[test]
    fn hutchinson_single_probe_structure_and_mean_convergence() {
        // 2-D: a single-probe estimate is tr(W) ± (W01 + W10) exactly
        // (v0·v1 = ±1), and averaging many probes converges to the trace —
        // the unbiasedness direction, deterministic under the fixed seed.
        let (tr, off) = (0.4f64, 0.5f64);
        let mlp = linear_field(&[0.7, 0.4, 0.1, -0.3], &[0.0, 0.0]);
        let mut rng = Pcg::new(3);
        let b = 8usize;
        let z: Vec<f64> = (0..b * 2).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let t = vec![0.2f64; b];
        let ids: Vec<usize> = (0..b).collect();
        let (_, one) =
            batch_divergence(&mlp, &ids, &t, &z, &Divergence::Hutchinson { probes: 1, seed: 11 });
        for (r, e) in one.iter().enumerate() {
            let hit = close(*e, tr + off, 1e-10) || close(*e, tr - off, 1e-10);
            assert!(hit, "row {r}: {e} is not tr ± off");
        }
        // both signs occur across trajectories (it IS an estimator)
        assert!(one.iter().any(|e| *e > tr) && one.iter().any(|e| *e < tr));
        let (_, many) =
            batch_divergence(&mlp, &ids, &t, &z, &Divergence::Hutchinson { probes: 256, seed: 11 });
        let mean: f64 = many.iter().sum::<f64>() / b as f64;
        assert!((mean - tr).abs() < 0.05, "mean {mean} vs trace {tr}");
        for (r, e) in many.iter().enumerate() {
            assert!((e - tr).abs() < 0.2, "row {r}: {e} vs {tr}");
        }
    }

    #[test]
    fn probes_are_keyed_on_trajectory_ids_not_rows() {
        // Swapping two rows AND their ids must swap the estimates exactly —
        // the invariant that makes Hutchinson solves compaction- and
        // pool-safe.
        let mlp = linear_field(&[0.7, 0.4, 0.1, -0.3], &[0.1, -0.1]);
        let z = [0.3f64, -0.8, 1.1, 0.4];
        let zsw = [1.1f64, 0.4, 0.3, -0.8];
        let t = [0.1f64, 0.9];
        let tsw = [0.9f64, 0.1];
        let mode = Divergence::Hutchinson { probes: 3, seed: 21 };
        let (_, a) = batch_divergence(&mlp, &[5, 9], &t, &z, &mode);
        let (_, b) = batch_divergence(&mlp, &[9, 5], &tsw, &zsw, &mode);
        assert_eq!(a[0].to_bits(), b[1].to_bits());
        assert_eq!(a[1].to_bits(), b[0].to_bits());
        // same (seed, id, probe) => same probe vector; different id => not
        // all equal
        assert_eq!(rademacher_probe(21, 5, 0, 4), rademacher_probe(21, 5, 0, 4));
        assert_ne!(
            (0..8).map(|p| rademacher_probe(21, 5, p, 4)).collect::<Vec<_>>(),
            (0..8).map(|p| rademacher_probe(21, 9, p, 4)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn forward_mode_divergence_matches_tape_vjp_on_the_cnf() {
        // divergence_values (n first-order series probes) vs the exact
        // tape-VJP engine, on a real concat-squash field: the training
        // path's divergence and the solver path's divergence must agree.
        let mut rng = Pcg::new(17);
        let mut cnf = Cnf::new(2, &[4], 77);
        for p in cnf.params.iter_mut() {
            if *p == 0.0 {
                *p = rng.range(-0.6, 0.6);
            }
        }
        for case in 0..10 {
            let z = [rng.range(-1.2, 1.2) as f64, rng.range(-1.2, 1.2) as f64];
            let t = rng.range(-0.5, 0.5) as f64;
            let (_, div) = batch_divergence(&cnf, &[0], &[t], &z, &Divergence::Exact);
            let cnf_ref = &cnf;
            let fwd = divergence_values(
                &mut |zs: &[SeriesOf<f64>], ts: &SeriesOf<f64>| {
                    let p = cnf_ref.lift_params(ts);
                    cnf_ref.forward(&p, zs, ts)
                },
                &z,
                &t,
            );
            assert!(close(fwd, div[0], 1e-10), "case {case}: {fwd} vs {}", div[0]);
        }
    }
}
