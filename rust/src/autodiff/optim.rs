//! Flat-parameter-vector optimizers for the native training path.
//!
//! The tape's parameter gradients come back as one dense `Vec<f64>` over
//! the flat layout `nn::Mlp` (plus any head) exposes; [`Adam`] consumes
//! exactly that.  Moments are kept in f64 — the parameters themselves are
//! the solver-facing f32, the optimizer state is not.

/// Adam (Kingma & Ba 2015) over a flat f32 parameter vector.
///
/// ```
/// use taynode::autodiff::Adam;
///
/// // Minimize (p - 3)²: the iterates walk towards 3.
/// let mut p = vec![0.0f32];
/// let mut opt = Adam::new(1, 0.1);
/// for _ in 0..200 {
///     let g = vec![2.0 * (p[0] as f64 - 3.0)];
///     opt.step(&mut p, &g);
/// }
/// assert!((p[0] - 3.0).abs() < 0.05, "{}", p[0]);
/// ```
pub struct Adam {
    pub lr: f32,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    t: usize,
    m: Vec<f64>,
    v: Vec<f64>,
}

impl Adam {
    /// Standard hyperparameters (β₁ 0.9, β₂ 0.999, ε 1e-8) over `n` slots.
    pub fn new(n: usize, lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// Number of optimizer steps taken so far.
    pub fn steps(&self) -> usize {
        self.t
    }

    /// One bias-corrected update of `params` in place.
    pub fn step(&mut self, params: &mut [f32], grads: &[f64]) {
        assert_eq!(params.len(), self.m.len(), "Adam: parameter arity");
        assert_eq!(grads.len(), self.m.len(), "Adam: gradient arity");
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t as i32);
        let b2c = 1.0 - self.beta2.powi(self.t as i32);
        let lr = self.lr as f64;
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1c;
            let vhat = self.v[i] / b2c;
            params[i] -= (lr * mhat / (vhat.sqrt() + self.eps)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic_bowl() {
        // L(p) = Σ (p_i - c_i)²
        let c = [1.0f64, -2.0, 0.5];
        let mut p = vec![0.0f32; 3];
        let mut opt = Adam::new(3, 0.05);
        for _ in 0..400 {
            let g: Vec<f64> = p.iter().zip(&c).map(|(pi, ci)| 2.0 * (*pi as f64 - ci)).collect();
            opt.step(&mut p, &g);
        }
        for (pi, ci) in p.iter().zip(&c) {
            assert!((*pi as f64 - ci).abs() < 0.05, "{pi} vs {ci}");
        }
        assert_eq!(opt.steps(), 400);
    }

    #[test]
    fn first_step_is_lr_sized() {
        // Bias correction makes the very first step ≈ lr · sign(g).
        let mut p = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut p, &[123.4]);
        assert!((p[0] + 0.01).abs() < 1e-4, "{}", p[0]);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut opt = Adam::new(2, 0.1);
        let mut p = vec![0.0f32; 2];
        opt.step(&mut p, &[1.0]);
    }
}
