//! Reverse-mode automatic differentiation on a tape of batch columns — the
//! gradient engine of the native training subsystem.
//!
//! A [`Tape`] records a DAG of elementwise operations over `[rows]` f64
//! columns (rows = batch size, so one node covers the whole batch); a
//! [`Var`] is a handle to one node and implements the model-facing
//! [`Value`](crate::nn::Value) algebra, which means any code written
//! against `Value` — the MLP forward, the generic series arithmetic, the
//! value-generic jet — records itself for free.  [`Tape::backward`] then
//! walks the tape once in reverse, accumulating vector-Jacobian products:
//! per-column cotangents for [`input`](Tape::input) leaves and row-summed
//! scalar gradients for broadcast [`param`](Tape::param) leaves.
//!
//! **Storage is a bump arena**: one flat node table (`Vec` of ops) plus one
//! coefficient slab holding every node's `[rows]` column back to back, so
//! recording a node is a table push plus a slab extension — no per-node
//! allocation.  [`Tape::clear`] recycles both buffers for the next
//! recording (the discrete adjoint builds one tape per RK stage, on the
//! same arena, per worker shard); clearing bumps an epoch so stale [`Var`]s
//! from the previous recording panic instead of silently aliasing new
//! nodes.
//!
//! ```
//! use taynode::autodiff::Tape;
//! use taynode::nn::Value;
//!
//! // d/dx of tanh(w·x) at x = [0.5, -1], w = 0.3.
//! let tape = Tape::new(2);
//! let x = tape.input(&[0.5, -1.0]);
//! let w = tape.param(0, 0.3);
//! let y = x.mul(&w).tanh();
//! let g = tape.backward(&[(&y, &[1.0, 1.0])]);
//! for (x0, g0) in [0.5f64, -1.0].iter().zip(g.wrt(&x)) {
//!     let t = (0.3 * x0).tanh();
//!     assert!((g0 - 0.3 * (1.0 - t * t)).abs() < 1e-12);
//! }
//! // The broadcast param's gradient sums over the batch rows.
//! assert!(g.param(0).is_finite());
//! ```

pub mod div;
pub mod optim;

pub use div::{batch_divergence, divergence_values, Divergence};
pub use optim::Adam;

use std::cell::RefCell;
use std::rc::Rc;

use crate::kern::axpy::{add_assign, axpy_f64, mul_acc, sub_assign};
use crate::nn::Value;

/// One recorded elementwise operation (operands are node ids).
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Gradient-free constant (from `Value::lift` / `Tape::constant`).
    Const,
    /// Differentiable per-row input column.
    Input,
    /// Broadcast scalar parameter; gradient row-sums into slot `usize`.
    Param(usize),
    Add(usize, usize),
    Sub(usize, usize),
    Mul(usize, usize),
    Scale(usize, f64),
    Tanh(usize),
    Exp(usize),
    Sigmoid(usize),
}

struct TapeInner {
    rows: usize,
    /// Recording generation; bumped by `clear` to invalidate old `Var`s.
    epoch: u64,
    /// Flat node table: `ops[k]` is node k's operation.
    ops: Vec<Op>,
    /// Bump arena: node k's forward column is `vals[k * rows..(k + 1) * rows]`.
    vals: Vec<f64>,
}

impl TapeInner {
    #[inline]
    fn col(&self, id: usize) -> &[f64] {
        &self.vals[id * self.rows..(id + 1) * self.rows]
    }
}

/// A recording of elementwise column operations, shared by its [`Var`]s.
#[derive(Clone)]
pub struct Tape {
    inner: Rc<RefCell<TapeInner>>,
}

/// A handle to one tape node.  Cheap to clone; all arithmetic goes through
/// the [`Value`](crate::nn::Value) impl.
#[derive(Clone)]
pub struct Var {
    inner: Rc<RefCell<TapeInner>>,
    id: usize,
    epoch: u64,
}

impl Tape {
    /// A fresh tape over `rows`-long batch columns.
    pub fn new(rows: usize) -> Tape {
        Tape {
            inner: Rc::new(RefCell::new(TapeInner {
                rows,
                epoch: 0,
                ops: vec![],
                vals: vec![],
            })),
        }
    }

    pub fn rows(&self) -> usize {
        self.inner.borrow().rows
    }

    /// Number of recorded nodes (for perf accounting in tests/benches).
    pub fn len(&self) -> usize {
        self.inner.borrow().ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.borrow().ops.is_empty()
    }

    /// Bytes held by the value arena and node table (capacity, not
    /// length — what the process actually pays for the recording).
    pub fn arena_bytes(&self) -> usize {
        let t = self.inner.borrow();
        t.vals.capacity() * std::mem::size_of::<f64>()
            + t.ops.capacity() * std::mem::size_of::<Op>()
    }

    /// Drop every recorded node but keep the arena's allocations for the
    /// next recording — how a worker reuses one tape across the per-stage
    /// VJPs of the discrete adjoint.  `Var`s from before the clear belong
    /// to the old recording; using one afterwards panics (epoch check)
    /// rather than aliasing a new node.
    pub fn clear(&self) {
        let mut t = self.inner.borrow_mut();
        t.ops.clear();
        t.vals.clear();
        t.epoch += 1;
    }

    /// A differentiable per-row input column.
    pub fn input(&self, vals: &[f64]) -> Var {
        assert_eq!(vals.len(), self.rows(), "Tape::input: column length vs rows");
        push_slice(&self.inner, Op::Input, vals)
    }

    /// A differentiable broadcast scalar (a model parameter): every row
    /// sees `val`, and the backward pass row-sums the cotangent into
    /// gradient slot `idx`.
    pub fn param(&self, idx: usize, val: f64) -> Var {
        push_fill(&self.inner, Op::Param(idx), val)
    }

    /// A gradient-free broadcast constant.
    pub fn constant(&self, val: f64) -> Var {
        push_fill(&self.inner, Op::Const, val)
    }

    /// Current forward value of a node.
    pub fn value(&self, v: &Var) -> Vec<f64> {
        assert!(Rc::ptr_eq(&self.inner, &v.inner), "Var from a different tape");
        let t = self.inner.borrow();
        v.check(&t);
        t.col(v.id).to_vec()
    }

    /// Reverse sweep: seed the given output cotangent columns, walk the
    /// tape backwards once, and return every node's accumulated adjoint
    /// plus the row-summed parameter gradients.  Seeding the same `Var`
    /// twice accumulates.
    pub fn backward(&self, seeds: &[(&Var, &[f64])]) -> Grads {
        let t = self.inner.borrow();
        let rows = t.rows;
        let n = t.ops.len();
        // One flat adjoint slab mirroring the value arena.
        let mut adj = vec![0.0f64; n * rows];
        for (v, g) in seeds {
            assert!(Rc::ptr_eq(&self.inner, &v.inner), "seed from a different tape");
            v.check(&t);
            assert_eq!(g.len(), rows, "seed column length vs rows");
            add_assign(g, &mut adj[v.id * rows..(v.id + 1) * rows]);
        }
        let mut params: Vec<f64> = Vec::new();
        for id in (0..n).rev() {
            // Operand ids are strictly smaller than `id` (the tape only
            // appends), so splitting the slab at this node borrows its
            // adjoint and its operands' simultaneously — no per-node clone.
            let (lo, hi) = adj.split_at_mut(id * rows);
            let g = &hi[..rows];
            if g.iter().all(|v| *v == 0.0) {
                continue;
            }
            match t.ops[id] {
                Op::Const | Op::Input => {}
                Op::Param(pi) => {
                    if params.len() <= pi {
                        params.resize(pi + 1, 0.0);
                    }
                    params[pi] += g.iter().sum::<f64>();
                }
                Op::Add(a, b) => {
                    add_assign(g, &mut lo[a * rows..(a + 1) * rows]);
                    add_assign(g, &mut lo[b * rows..(b + 1) * rows]);
                }
                Op::Sub(a, b) => {
                    add_assign(g, &mut lo[a * rows..(a + 1) * rows]);
                    sub_assign(g, &mut lo[b * rows..(b + 1) * rows]);
                }
                Op::Mul(a, b) => {
                    let (va, vb) = (t.col(a), t.col(b));
                    mul_acc(g, vb, &mut lo[a * rows..(a + 1) * rows]);
                    mul_acc(g, va, &mut lo[b * rows..(b + 1) * rows]);
                }
                Op::Scale(a, sc) => {
                    // ā += sc·ḡ — bit-identical to the recorded ḡ·sc since
                    // IEEE multiplication commutes bitwise on numeric values.
                    axpy_f64(sc, g, &mut lo[a * rows..(a + 1) * rows]);
                }
                Op::Tanh(a) => {
                    let y = t.col(id);
                    for r in 0..rows {
                        lo[a * rows + r] += g[r] * (1.0 - y[r] * y[r]);
                    }
                }
                Op::Exp(a) => {
                    let y = t.col(id);
                    for r in 0..rows {
                        lo[a * rows + r] += g[r] * y[r];
                    }
                }
                Op::Sigmoid(a) => {
                    let y = t.col(id);
                    for r in 0..rows {
                        lo[a * rows + r] += g[r] * y[r] * (1.0 - y[r]);
                    }
                }
            }
        }
        Grads {
            tape: self.inner.clone(),
            epoch: t.epoch,
            rows,
            adj,
            params,
        }
    }
}

impl Var {
    /// This node's forward value.
    pub fn value(&self) -> Vec<f64> {
        let t = self.inner.borrow();
        self.check(&t);
        t.col(self.id).to_vec()
    }

    #[inline]
    fn check(&self, t: &TapeInner) {
        assert_eq!(
            self.epoch, t.epoch,
            "Var from a cleared tape recording (epoch {} vs {})",
            self.epoch, t.epoch
        );
    }
}

fn push_slice(inner: &Rc<RefCell<TapeInner>>, op: Op, vals: &[f64]) -> Var {
    let mut t = inner.borrow_mut();
    debug_assert_eq!(vals.len(), t.rows);
    t.vals.extend_from_slice(vals);
    t.ops.push(op);
    Var { inner: inner.clone(), id: t.ops.len() - 1, epoch: t.epoch }
}

fn push_fill(inner: &Rc<RefCell<TapeInner>>, op: Op, val: f64) -> Var {
    let mut t = inner.borrow_mut();
    let end = t.vals.len() + t.rows;
    t.vals.resize(end, val);
    t.ops.push(op);
    Var { inner: inner.clone(), id: t.ops.len() - 1, epoch: t.epoch }
}

fn push_unary(a: &Var, op: Op, f: impl Fn(f64) -> f64) -> Var {
    let mut t = a.inner.borrow_mut();
    a.check(&t);
    let rows = t.rows;
    let base = a.id * rows;
    t.vals.reserve(rows);
    for r in 0..rows {
        let v = f(t.vals[base + r]);
        t.vals.push(v);
    }
    t.ops.push(op);
    Var { inner: a.inner.clone(), id: t.ops.len() - 1, epoch: t.epoch }
}

fn push_binary(a: &Var, b: &Var, op: Op, f: impl Fn(f64, f64) -> f64) -> Var {
    assert!(Rc::ptr_eq(&a.inner, &b.inner), "Vars from different tapes");
    let mut t = a.inner.borrow_mut();
    a.check(&t);
    b.check(&t);
    let rows = t.rows;
    let (ba, bb) = (a.id * rows, b.id * rows);
    t.vals.reserve(rows);
    for r in 0..rows {
        let v = f(t.vals[ba + r], t.vals[bb + r]);
        t.vals.push(v);
    }
    t.ops.push(op);
    Var { inner: a.inner.clone(), id: t.ops.len() - 1, epoch: t.epoch }
}

impl Value for Var {
    fn lift(&self, a: f64) -> Var {
        // Same staleness guard as every other op: lifting through a Var
        // from a cleared recording must not silently mint current nodes.
        self.check(&self.inner.borrow());
        push_fill(&self.inner, Op::Const, a)
    }

    fn add(&self, o: &Var) -> Var {
        push_binary(self, o, Op::Add(self.id, o.id), |x, y| x + y)
    }

    fn sub(&self, o: &Var) -> Var {
        push_binary(self, o, Op::Sub(self.id, o.id), |x, y| x - y)
    }

    fn mul(&self, o: &Var) -> Var {
        push_binary(self, o, Op::Mul(self.id, o.id), |x, y| x * y)
    }

    fn scale(&self, a: f64) -> Var {
        push_unary(self, Op::Scale(self.id, a), |x| a * x)
    }

    fn tanh(&self) -> Var {
        push_unary(self, Op::Tanh(self.id), |x| x.tanh())
    }

    fn exp(&self) -> Var {
        push_unary(self, Op::Exp(self.id), f64::exp)
    }

    fn sigmoid(&self) -> Var {
        push_unary(self, Op::Sigmoid(self.id), |x| 1.0 / (1.0 + (-x).exp()))
    }
}

/// The result of one [`Tape::backward`] sweep.
pub struct Grads {
    /// The tape the sweep ran on — `wrt` refuses foreign `Var`s, since a
    /// node id from another tape would silently alias a wrong adjoint.
    tape: Rc<RefCell<TapeInner>>,
    epoch: u64,
    rows: usize,
    /// Flat adjoint slab, laid out like the tape's value arena.
    adj: Vec<f64>,
    params: Vec<f64>,
}

impl Grads {
    /// Cotangent column of any node (zeros if untouched by the sweep).
    pub fn wrt(&self, v: &Var) -> &[f64] {
        assert!(Rc::ptr_eq(&self.tape, &v.inner), "Var from a different tape");
        assert_eq!(self.epoch, v.epoch, "Var from a different tape recording");
        &self.adj[v.id * self.rows..(v.id + 1) * self.rows]
    }

    /// Row-summed gradient of parameter slot `idx` (0 if untouched).
    pub fn param(&self, idx: usize) -> f64 {
        self.params.get(idx).copied().unwrap_or(0.0)
    }

    /// The dense parameter-gradient vector, padded to `n` slots.
    pub fn param_vec(&self, n: usize) -> Vec<f64> {
        let mut out = self.params.clone();
        out.resize(n.max(out.len()), 0.0);
        out.truncate(n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    /// Central-difference gradient of `f` (a scalar function of `x`) —
    /// the per-op reference every VJP is checked against.
    fn fd_grad(f: &dyn Fn(&[f64]) -> f64, x: &[f64], eps: f64) -> Vec<f64> {
        let mut g = Vec::with_capacity(x.len());
        let mut xp = x.to_vec();
        for i in 0..x.len() {
            xp[i] = x[i] + eps;
            let fp = f(&xp);
            xp[i] = x[i] - eps;
            let fm = f(&xp);
            xp[i] = x[i];
            g.push((fp - fm) / (2.0 * eps));
        }
        g
    }

    /// Build y = expr(vars) on a 1-row tape, seed 1.0, return input grads.
    fn tape_grad(expr: fn(&[Var]) -> Var, x: &[f64]) -> Vec<f64> {
        let tape = Tape::new(1);
        let vars: Vec<Var> = x.iter().map(|v| tape.input(&[*v])).collect();
        let y = expr(&vars);
        let g = tape.backward(&[(&y, &[1.0])]);
        vars.iter().map(|v| g.wrt(v)[0]).collect()
    }

    #[test]
    fn per_op_gradients_match_finite_differences_property() {
        // Every Op's VJP, alone and composed, vs central differences.
        Prop::new(60).run("tape-op-fd", |rng: &mut Pcg, case| {
            let x = gen::vec_f64(rng, 3, -1.5, 1.5);
            let exprs: [fn(&[Var]) -> Var; 8] = [
                |v| v[0].add(&v[1]).mul(&v[2]),
                |v| v[0].sub(&v[1]).tanh(),
                |v| v[0].mul(&v[1]).mul(&v[2]),
                |v| v[0].scale(1.7).add(&v[1].scale(-0.4)),
                |v| v[0].tanh().mul(&v[1].tanh()).add(&v[2]),
                |v| v[0].mul(&v[0]).sub(&v[1].mul(&v[2]).scale(0.5)),
                // the CNF gate ops: exp and sigmoid, alone and composed
                |v| v[0].exp().mul(&v[1].sigmoid()).add(&v[2]),
                |v| v[0].mul(&v[1]).sigmoid().sub(&v[2].scale(0.3).exp()),
            ];
            let expr = exprs[case % exprs.len()];
            let fns = |x: &[f64]| -> f64 {
                // evaluate via a throwaway tape (forward values only)
                let tape = Tape::new(1);
                let vars: Vec<Var> = x.iter().map(|v| tape.input(&[*v])).collect();
                expr(&vars).value()[0]
            };
            let want = fd_grad(&fns, &x, 1e-5);
            let got = tape_grad(expr, &x);
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!(close(*g, *w, 1e-7), "input {i}: {g} vs {w}");
            }
        });
    }

    #[test]
    fn param_gradients_sum_over_rows_and_uses() {
        // y_r = p·x_r + p·x_r²: dL/dp with L = sum_r y_r must accumulate
        // over both uses of p and over all rows.
        let x = [0.5f64, -1.0, 2.0];
        let p0 = 0.7f64;
        let tape = Tape::new(3);
        let xs = tape.input(&x);
        let p = tape.param(0, p0);
        let y = p.mul(&xs).add(&p.mul(&xs.mul(&xs)));
        let g = tape.backward(&[(&y, &[1.0, 1.0, 1.0])]);
        let want: f64 = x.iter().map(|v| v + v * v).sum();
        assert!(close(g.param(0), want, 1e-12), "{} vs {want}", g.param(0));
        // inputs: d y_r / d x_r = p + 2 p x_r
        for (r, xv) in x.iter().enumerate() {
            let w = p0 + 2.0 * p0 * xv;
            assert!(close(g.wrt(&xs)[r], w, 1e-12), "row {r}");
        }
        // an untouched parameter slot reads as zero
        assert_eq!(g.param(5), 0.0);
        assert_eq!(g.param_vec(2), vec![want, 0.0]);
    }

    #[test]
    fn constants_carry_no_gradient() {
        let tape = Tape::new(2);
        let x = tape.input(&[1.0, 2.0]);
        let c = tape.constant(3.0);
        let l = x.lift(4.0);
        let y = x.mul(&c).add(&l);
        let g = tape.backward(&[(&y, &[1.0, 1.0])]);
        assert_eq!(g.wrt(&x), &[3.0, 3.0]);
        // const/lift nodes accumulate adjoints but emit no param grads
        assert!(g.param_vec(4).iter().all(|v| *v == 0.0));
        assert_eq!(tape.value(&c), vec![3.0, 3.0]);
    }

    #[test]
    fn multi_output_seeds_accumulate() {
        // Seeding two outputs in one sweep equals the sum of separate
        // sweeps (linearity of the adjoint).
        let tape = Tape::new(1);
        let x = tape.input(&[0.8]);
        let y1 = x.tanh();
        let y2 = x.mul(&x);
        let joint = tape.backward(&[(&y1, &[1.0]), (&y2, &[2.0])]);
        let a = tape.backward(&[(&y1, &[1.0])]);
        let b = tape.backward(&[(&y2, &[2.0])]);
        assert!(close(joint.wrt(&x)[0], a.wrt(&x)[0] + b.wrt(&x)[0], 1e-15));
    }

    #[test]
    #[should_panic(expected = "different tape")]
    fn wrt_rejects_vars_from_another_tape() {
        let t1 = Tape::new(1);
        let t2 = Tape::new(1);
        let x1 = t1.input(&[1.0]);
        let x2 = t2.input(&[2.0]);
        let y = x1.tanh();
        let g = t1.backward(&[(&y, &[1.0])]);
        let _ = g.wrt(&x2); // same node id, wrong tape: must panic, not alias
    }

    #[test]
    fn columns_are_rowwise_independent() {
        // Elementwise ops must not mix rows: per-row grads of y = x²·w
        // depend only on that row's x.
        let tape = Tape::new(4);
        let x = tape.input(&[1.0, 2.0, 3.0, 4.0]);
        let w = tape.param(0, 0.5);
        let y = x.mul(&x).mul(&w);
        let g = tape.backward(&[(&y, &[1.0, 0.0, 0.0, 1.0])]);
        let gx = g.wrt(&x);
        assert!(close(gx[0], 1.0, 1e-12)); // 2·x·w = 1
        assert_eq!(gx[1], 0.0);
        assert_eq!(gx[2], 0.0);
        assert!(close(gx[3], 4.0, 1e-12));
        // param grad only sums the seeded rows: x0² + x3² = 1 + 16
        assert!(close(g.param(0), 17.0, 1e-12));
    }

    #[test]
    fn clear_recycles_the_arena_and_reproduces_results() {
        // Recording the same computation on a fresh tape and on a cleared
        // (recycled-arena) tape must agree bit-for-bit — the invariant the
        // per-shard tape reuse in the discrete adjoint relies on.
        let fresh = |x: &[f64]| {
            let tape = Tape::new(x.len());
            let v = tape.input(x);
            let w = tape.param(0, 0.4);
            let y = v.mul(&w).tanh().add(&v.scale(0.25));
            let ones = vec![1.0; x.len()];
            let g = tape.backward(&[(&y, ones.as_slice())]);
            (g.wrt(&v).to_vec(), g.param(0), tape.len())
        };
        let tape = Tape::new(3);
        let (want, wantp, nodes) = fresh(&[0.3, -0.7, 1.1]);
        for _ in 0..3 {
            tape.clear();
            let v = tape.input(&[0.3, -0.7, 1.1]);
            let w = tape.param(0, 0.4);
            let y = v.mul(&w).tanh().add(&v.scale(0.25));
            let g = tape.backward(&[(&y, &[1.0, 1.0, 1.0])]);
            assert_eq!(tape.len(), nodes);
            for (a, b) in g.wrt(&v).iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(g.param(0).to_bits(), wantp.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "cleared tape")]
    fn stale_vars_panic_after_clear() {
        let tape = Tape::new(1);
        let x = tape.input(&[1.0]);
        tape.clear();
        let _ = x.tanh(); // old recording: must panic, not alias node 0
    }
}
