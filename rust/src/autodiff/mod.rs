//! Reverse-mode automatic differentiation on a tape of batch columns — the
//! gradient engine of the native training subsystem.
//!
//! A [`Tape`] records a DAG of elementwise operations over `[rows]` f64
//! columns (rows = batch size, so one node covers the whole batch); a
//! [`Var`] is a handle to one node and implements the model-facing
//! [`Value`](crate::nn::Value) algebra, which means any code written
//! against `Value` — the MLP forward, the generic series arithmetic, the
//! value-generic jet — records itself for free.  [`Tape::backward`] then
//! walks the tape once in reverse, accumulating vector-Jacobian products:
//! per-column cotangents for [`input`](Tape::input) leaves and row-summed
//! scalar gradients for broadcast [`param`](Tape::param) leaves.
//!
//! Each tape is built for one VJP and dropped — the discrete adjoint
//! (`coordinator::train_native`) constructs one per RK stage from the
//! cached stage state, so tape lifetime never spans solver steps.
//!
//! ```
//! use taynode::autodiff::Tape;
//! use taynode::nn::Value;
//!
//! // d/dx of tanh(w·x) at x = [0.5, -1], w = 0.3.
//! let tape = Tape::new(2);
//! let x = tape.input(&[0.5, -1.0]);
//! let w = tape.param(0, 0.3);
//! let y = x.mul(&w).tanh();
//! let g = tape.backward(&[(&y, &[1.0, 1.0])]);
//! for (x0, g0) in [0.5f64, -1.0].iter().zip(g.wrt(&x)) {
//!     let t = (0.3 * x0).tanh();
//!     assert!((g0 - 0.3 * (1.0 - t * t)).abs() < 1e-12);
//! }
//! // The broadcast param's gradient sums over the batch rows.
//! assert!(g.param(0).is_finite());
//! ```

pub mod optim;

pub use optim::Adam;

use std::cell::RefCell;
use std::rc::Rc;

use crate::nn::Value;

/// One recorded elementwise operation (operands are node ids).
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Gradient-free constant (from `Value::lift` / `Tape::constant`).
    Const,
    /// Differentiable per-row input column.
    Input,
    /// Broadcast scalar parameter; gradient row-sums into slot `usize`.
    Param(usize),
    Add(usize, usize),
    Sub(usize, usize),
    Mul(usize, usize),
    Scale(usize, f64),
    Tanh(usize),
}

struct Node {
    op: Op,
    val: Vec<f64>,
}

struct TapeInner {
    rows: usize,
    nodes: Vec<Node>,
}

/// A recording of elementwise column operations, shared by its [`Var`]s.
#[derive(Clone)]
pub struct Tape {
    inner: Rc<RefCell<TapeInner>>,
}

/// A handle to one tape node.  Cheap to clone; all arithmetic goes through
/// the [`Value`](crate::nn::Value) impl.
#[derive(Clone)]
pub struct Var {
    inner: Rc<RefCell<TapeInner>>,
    id: usize,
}

impl Tape {
    /// A fresh tape over `rows`-long batch columns.
    pub fn new(rows: usize) -> Tape {
        Tape { inner: Rc::new(RefCell::new(TapeInner { rows, nodes: vec![] })) }
    }

    pub fn rows(&self) -> usize {
        self.inner.borrow().rows
    }

    /// Number of recorded nodes (for perf accounting in tests/benches).
    pub fn len(&self) -> usize {
        self.inner.borrow().nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.borrow().nodes.is_empty()
    }

    /// A differentiable per-row input column.
    pub fn input(&self, vals: &[f64]) -> Var {
        assert_eq!(vals.len(), self.rows(), "Tape::input: column length vs rows");
        push(&self.inner, Op::Input, vals.to_vec())
    }

    /// A differentiable broadcast scalar (a model parameter): every row
    /// sees `val`, and the backward pass row-sums the cotangent into
    /// gradient slot `idx`.
    pub fn param(&self, idx: usize, val: f64) -> Var {
        let rows = self.rows();
        push(&self.inner, Op::Param(idx), vec![val; rows])
    }

    /// A gradient-free broadcast constant.
    pub fn constant(&self, val: f64) -> Var {
        let rows = self.rows();
        push(&self.inner, Op::Const, vec![val; rows])
    }

    /// Current forward value of a node.
    pub fn value(&self, v: &Var) -> Vec<f64> {
        assert!(Rc::ptr_eq(&self.inner, &v.inner), "Var from a different tape");
        self.inner.borrow().nodes[v.id].val.clone()
    }

    /// Reverse sweep: seed the given output cotangent columns, walk the
    /// tape backwards once, and return every node's accumulated adjoint
    /// plus the row-summed parameter gradients.  Seeding the same `Var`
    /// twice accumulates.
    pub fn backward(&self, seeds: &[(&Var, &[f64])]) -> Grads {
        let t = self.inner.borrow();
        let rows = t.rows;
        let mut adj = vec![vec![0.0f64; rows]; t.nodes.len()];
        for (v, g) in seeds {
            assert!(Rc::ptr_eq(&self.inner, &v.inner), "seed from a different tape");
            assert_eq!(g.len(), rows, "seed column length vs rows");
            for (a, gi) in adj[v.id].iter_mut().zip(*g) {
                *a += *gi;
            }
        }
        let mut params: Vec<f64> = Vec::new();
        for id in (0..t.nodes.len()).rev() {
            if adj[id].iter().all(|v| *v == 0.0) {
                continue;
            }
            // Operand ids are strictly smaller than `id` (the tape only
            // appends), so a split borrows this node's adjoint and its
            // operands' simultaneously — no per-node clone in the sweep.
            let (lo, hi) = adj.split_at_mut(id);
            let g = &hi[0];
            match t.nodes[id].op {
                Op::Const | Op::Input => {}
                Op::Param(pi) => {
                    if params.len() <= pi {
                        params.resize(pi + 1, 0.0);
                    }
                    params[pi] += g.iter().sum::<f64>();
                }
                Op::Add(a, b) => {
                    for r in 0..rows {
                        lo[a][r] += g[r];
                    }
                    for r in 0..rows {
                        lo[b][r] += g[r];
                    }
                }
                Op::Sub(a, b) => {
                    for r in 0..rows {
                        lo[a][r] += g[r];
                    }
                    for r in 0..rows {
                        lo[b][r] -= g[r];
                    }
                }
                Op::Mul(a, b) => {
                    for r in 0..rows {
                        lo[a][r] += g[r] * t.nodes[b].val[r];
                    }
                    for r in 0..rows {
                        lo[b][r] += g[r] * t.nodes[a].val[r];
                    }
                }
                Op::Scale(a, sc) => {
                    for r in 0..rows {
                        lo[a][r] += g[r] * sc;
                    }
                }
                Op::Tanh(a) => {
                    let y = &t.nodes[id].val;
                    for r in 0..rows {
                        lo[a][r] += g[r] * (1.0 - y[r] * y[r]);
                    }
                }
            }
        }
        Grads { tape: self.inner.clone(), adj, params }
    }
}

fn push(inner: &Rc<RefCell<TapeInner>>, op: Op, val: Vec<f64>) -> Var {
    let mut t = inner.borrow_mut();
    t.nodes.push(Node { op, val });
    Var { inner: inner.clone(), id: t.nodes.len() - 1 }
}

impl Var {
    /// This node's forward value.
    pub fn value(&self) -> Vec<f64> {
        self.inner.borrow().nodes[self.id].val.clone()
    }
}

impl Value for Var {
    fn lift(&self, a: f64) -> Var {
        let rows = self.inner.borrow().rows;
        push(&self.inner, Op::Const, vec![a; rows])
    }

    fn add(&self, o: &Var) -> Var {
        assert!(Rc::ptr_eq(&self.inner, &o.inner), "Vars from different tapes");
        let val: Vec<f64> = {
            let t = self.inner.borrow();
            let (a, b) = (&t.nodes[self.id].val, &t.nodes[o.id].val);
            a.iter().zip(b).map(|(x, y)| x + y).collect()
        };
        push(&self.inner, Op::Add(self.id, o.id), val)
    }

    fn sub(&self, o: &Var) -> Var {
        assert!(Rc::ptr_eq(&self.inner, &o.inner), "Vars from different tapes");
        let val: Vec<f64> = {
            let t = self.inner.borrow();
            let (a, b) = (&t.nodes[self.id].val, &t.nodes[o.id].val);
            a.iter().zip(b).map(|(x, y)| x - y).collect()
        };
        push(&self.inner, Op::Sub(self.id, o.id), val)
    }

    fn mul(&self, o: &Var) -> Var {
        assert!(Rc::ptr_eq(&self.inner, &o.inner), "Vars from different tapes");
        let val: Vec<f64> = {
            let t = self.inner.borrow();
            let (a, b) = (&t.nodes[self.id].val, &t.nodes[o.id].val);
            a.iter().zip(b).map(|(x, y)| x * y).collect()
        };
        push(&self.inner, Op::Mul(self.id, o.id), val)
    }

    fn scale(&self, a: f64) -> Var {
        let val: Vec<f64> = {
            let t = self.inner.borrow();
            t.nodes[self.id].val.iter().map(|x| a * x).collect()
        };
        push(&self.inner, Op::Scale(self.id, a), val)
    }

    fn tanh(&self) -> Var {
        let val: Vec<f64> = {
            let t = self.inner.borrow();
            t.nodes[self.id].val.iter().map(|x| x.tanh()).collect()
        };
        push(&self.inner, Op::Tanh(self.id), val)
    }
}

/// The result of one [`Tape::backward`] sweep.
pub struct Grads {
    /// The tape the sweep ran on — `wrt` refuses foreign `Var`s, since a
    /// node id from another tape would silently alias a wrong adjoint.
    tape: Rc<RefCell<TapeInner>>,
    adj: Vec<Vec<f64>>,
    params: Vec<f64>,
}

impl Grads {
    /// Cotangent column of any node (zeros if untouched by the sweep).
    pub fn wrt(&self, v: &Var) -> &[f64] {
        assert!(Rc::ptr_eq(&self.tape, &v.inner), "Var from a different tape");
        &self.adj[v.id]
    }

    /// Row-summed gradient of parameter slot `idx` (0 if untouched).
    pub fn param(&self, idx: usize) -> f64 {
        self.params.get(idx).copied().unwrap_or(0.0)
    }

    /// The dense parameter-gradient vector, padded to `n` slots.
    pub fn param_vec(&self, n: usize) -> Vec<f64> {
        let mut out = self.params.clone();
        out.resize(n.max(out.len()), 0.0);
        out.truncate(n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    /// Central-difference gradient of `f` (a scalar function of `x`) —
    /// the per-op reference every VJP is checked against.
    fn fd_grad(f: &dyn Fn(&[f64]) -> f64, x: &[f64], eps: f64) -> Vec<f64> {
        let mut g = Vec::with_capacity(x.len());
        let mut xp = x.to_vec();
        for i in 0..x.len() {
            xp[i] = x[i] + eps;
            let fp = f(&xp);
            xp[i] = x[i] - eps;
            let fm = f(&xp);
            xp[i] = x[i];
            g.push((fp - fm) / (2.0 * eps));
        }
        g
    }

    /// Build y = expr(vars) on a 1-row tape, seed 1.0, return input grads.
    fn tape_grad(expr: fn(&[Var]) -> Var, x: &[f64]) -> Vec<f64> {
        let tape = Tape::new(1);
        let vars: Vec<Var> = x.iter().map(|v| tape.input(&[*v])).collect();
        let y = expr(&vars);
        let g = tape.backward(&[(&y, &[1.0])]);
        vars.iter().map(|v| g.wrt(v)[0]).collect()
    }

    #[test]
    fn per_op_gradients_match_finite_differences_property() {
        // Every Op's VJP, alone and composed, vs central differences.
        Prop::new(60).run("tape-op-fd", |rng: &mut Pcg, case| {
            let x = gen::vec_f64(rng, 3, -1.5, 1.5);
            let exprs: [fn(&[Var]) -> Var; 6] = [
                |v| v[0].add(&v[1]).mul(&v[2]),
                |v| v[0].sub(&v[1]).tanh(),
                |v| v[0].mul(&v[1]).mul(&v[2]),
                |v| v[0].scale(1.7).add(&v[1].scale(-0.4)),
                |v| v[0].tanh().mul(&v[1].tanh()).add(&v[2]),
                |v| v[0].mul(&v[0]).sub(&v[1].mul(&v[2]).scale(0.5)),
            ];
            let expr = exprs[case % exprs.len()];
            let fns = |x: &[f64]| -> f64 {
                // evaluate via a throwaway tape (forward values only)
                let tape = Tape::new(1);
                let vars: Vec<Var> = x.iter().map(|v| tape.input(&[*v])).collect();
                expr(&vars).value()[0]
            };
            let want = fd_grad(&fns, &x, 1e-5);
            let got = tape_grad(expr, &x);
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!(close(*g, *w, 1e-7), "input {i}: {g} vs {w}");
            }
        });
    }

    #[test]
    fn param_gradients_sum_over_rows_and_uses() {
        // y_r = p·x_r + p·x_r²: dL/dp with L = sum_r y_r must accumulate
        // over both uses of p and over all rows.
        let x = [0.5f64, -1.0, 2.0];
        let p0 = 0.7f64;
        let tape = Tape::new(3);
        let xs = tape.input(&x);
        let p = tape.param(0, p0);
        let y = p.mul(&xs).add(&p.mul(&xs.mul(&xs)));
        let g = tape.backward(&[(&y, &[1.0, 1.0, 1.0])]);
        let want: f64 = x.iter().map(|v| v + v * v).sum();
        assert!(close(g.param(0), want, 1e-12), "{} vs {want}", g.param(0));
        // inputs: d y_r / d x_r = p + 2 p x_r
        for (r, xv) in x.iter().enumerate() {
            let w = p0 + 2.0 * p0 * xv;
            assert!(close(g.wrt(&xs)[r], w, 1e-12), "row {r}");
        }
        // an untouched parameter slot reads as zero
        assert_eq!(g.param(5), 0.0);
        assert_eq!(g.param_vec(2), vec![want, 0.0]);
    }

    #[test]
    fn constants_carry_no_gradient() {
        let tape = Tape::new(2);
        let x = tape.input(&[1.0, 2.0]);
        let c = tape.constant(3.0);
        let l = x.lift(4.0);
        let y = x.mul(&c).add(&l);
        let g = tape.backward(&[(&y, &[1.0, 1.0])]);
        assert_eq!(g.wrt(&x), &[3.0, 3.0]);
        // const/lift nodes accumulate adjoints but emit no param grads
        assert!(g.param_vec(4).iter().all(|v| *v == 0.0));
        assert_eq!(tape.value(&c), vec![3.0, 3.0]);
    }

    #[test]
    fn multi_output_seeds_accumulate() {
        // Seeding two outputs in one sweep equals the sum of separate
        // sweeps (linearity of the adjoint).
        let tape = Tape::new(1);
        let x = tape.input(&[0.8]);
        let y1 = x.tanh();
        let y2 = x.mul(&x);
        let joint = tape.backward(&[(&y1, &[1.0]), (&y2, &[2.0])]);
        let a = tape.backward(&[(&y1, &[1.0])]);
        let b = tape.backward(&[(&y2, &[2.0])]);
        assert!(close(joint.wrt(&x)[0], a.wrt(&x)[0] + b.wrt(&x)[0], 1e-15));
    }

    #[test]
    #[should_panic(expected = "different tape")]
    fn wrt_rejects_vars_from_another_tape() {
        let t1 = Tape::new(1);
        let t2 = Tape::new(1);
        let x1 = t1.input(&[1.0]);
        let x2 = t2.input(&[2.0]);
        let y = x1.tanh();
        let g = t1.backward(&[(&y, &[1.0])]);
        let _ = g.wrt(&x2); // same node id, wrong tape: must panic, not alias
    }

    #[test]
    fn columns_are_rowwise_independent() {
        // Elementwise ops must not mix rows: per-row grads of y = x²·w
        // depend only on that row's x.
        let tape = Tape::new(4);
        let x = tape.input(&[1.0, 2.0, 3.0, 4.0]);
        let w = tape.param(0, 0.5);
        let y = x.mul(&x).mul(&w);
        let g = tape.backward(&[(&y, &[1.0, 0.0, 0.0, 1.0])]);
        let gx = g.wrt(&x);
        assert!(close(gx[0], 1.0, 1e-12)); // 2·x·w = 1
        assert_eq!(gx[1], 0.0);
        assert_eq!(gx[2], 0.0);
        assert!(close(gx[3], 4.0, 1e-12));
        // param grad only sums the seeded rows: x0² + x3² = 1 + 16
        assert!(close(g.param(0), 17.0, 1e-12));
    }
}
