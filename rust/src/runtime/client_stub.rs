//! Stub PJRT client — compiled when the `pjrt` feature is OFF (the
//! default).
//!
//! Mirrors the API surface of `client.rs` (`Runtime`, `Executable`,
//! `Literal`, the literal builders) so the coordinator, evaluator, and
//! experiment layers type-check and unit-test everywhere, with zero native
//! dependencies.  Every entry point that would touch a device returns a
//! descriptive error instead; nothing downstream can observe a half-working
//! runtime because `Runtime::load` itself refuses to construct one.

use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::manifest::{ExecSpec, Manifest};

fn unavailable(what: &str) -> anyhow::Error {
    anyhow!(
        "{what}: taynode was built without the `pjrt` feature; \
         rebuild with `cargo build --features pjrt` (requires the vendored \
         `xla` crate and a PJRT CPU plugin) to run exported artifacts"
    )
}

/// Stand-in for `xla::Literal`.  Constructible (so literal-building code
/// paths stay exercised and shape-validated) but never device-backed;
/// reads fail with the feature-gate error.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn scalar<T>(_v: T) -> Literal {
        Literal(())
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn copy_raw_to(&self, _dst: &mut [f32]) -> Result<()> {
        Err(unavailable("Literal::copy_raw_to"))
    }
}

/// Stand-in for `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer(());

/// Stand-in for the PJRT client handle (`repro info` surface).
#[derive(Debug)]
pub struct StubClient(());

impl StubClient {
    pub fn platform_name(&self) -> String {
        "stub (built without the pjrt feature)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Build an f32 literal with the given shape.  Shape/length validation is
/// identical to the real client so callers fail the same way in both
/// builds; the value itself is inert.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal shape {shape:?} needs {n} elems, got {}", data.len());
    }
    Ok(Literal(()))
}

/// Build an i32 literal with the given shape.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal shape {shape:?} needs {n} elems, got {}", data.len());
    }
    Ok(Literal(()))
}

pub fn literal_to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
}

pub fn literal_scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
}

/// A compiled artifact plus its manifest spec (stub: never constructed).
pub struct Executable {
    pub spec: ExecSpec,
}

impl Executable {
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Literal>> {
        Err(unavailable(&format!("Executable::run({})", self.spec.name)))
    }

    pub fn run_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        Err(unavailable(&format!("Executable::run_b({})", self.spec.name)))
    }
}

/// The runtime handle.  `load` always errors in stub builds, so no method
/// past construction is reachable; they exist so the coordinator layers
/// compile unchanged.
pub struct Runtime {
    pub client: StubClient,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn load(_artifacts_dir: &Path) -> Result<Runtime> {
        Err(unavailable("Runtime::load"))
    }

    pub fn exec(&self, name: &str) -> Result<Rc<Executable>> {
        Err(unavailable(&format!("Runtime::exec({name})")))
    }

    pub fn to_device(&self, _shape: &[usize], _data: &[f32]) -> Result<PjRtBuffer> {
        Err(unavailable("Runtime::to_device"))
    }

    pub fn load_params(&self, model: &str) -> Result<Vec<Vec<f32>>> {
        Err(unavailable(&format!("Runtime::load_params({model})")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_errors_with_feature_hint() {
        let err = Runtime::load(Path::new("/nonexistent")).err().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn literal_builders_still_validate_shapes() {
        assert!(literal_f32(&[2, 2], &[0.0; 4]).is_ok());
        assert!(literal_f32(&[2, 2], &[0.0; 3]).is_err());
        assert!(literal_i32(&[], &[7]).is_ok());
        assert!(literal_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn literal_reads_error() {
        let l = Literal::scalar(1.0f32);
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.get_first_element::<f32>().is_err());
    }
}
