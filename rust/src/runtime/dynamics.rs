//! Bridge from the Rust solver suite to XLA dynamics executables — the NFE
//! hot path.  One `eval` = one NFE = one PJRT execution of the exported
//! dynamics function over the whole batch.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::client::{literal_f32, Executable, Literal, Runtime};
use super::params::ParamStore;
use crate::solvers::Dynamics;

enum Slot {
    /// Fixed input prepared once (parameters, probes).
    Fixed(Literal),
    /// The solver state (batch:z or batch:state).
    State,
    /// The scalar time.
    Time,
}

/// An exported dynamics function bound to concrete parameters.
///
/// State layout: row-major [batch, dim] flattened — matching both the
/// artifact's input shape and the solver's flat state vector.
pub struct XlaDynamics {
    exec: Rc<Executable>,
    slots: Vec<Slot>,
    pub batch: usize,
    pub dim: usize,
    state_shape: Vec<usize>,
    /// Device-buffer parameter cache for the buffer hot path (perf pass).
    pub calls: usize,
}

impl XlaDynamics {
    /// Bind `exec_name` to parameters from `store`, generating `rng:*`
    /// probe inputs with `probe` (rademacher) when the artifact needs them.
    pub fn from_store(
        rt: &Runtime,
        exec_name: &str,
        store: &ParamStore,
        probe: Option<&[f32]>,
    ) -> Result<XlaDynamics> {
        let exec = rt.exec(exec_name)?;
        let mut slots = vec![];
        let mut state_shape = vec![];
        for inp in &exec.spec.inputs {
            match inp.role_kind() {
                "param" => {
                    let val = store.value(&inp.name)?;
                    slots.push(Slot::Fixed(literal_f32(&inp.shape, val)?));
                }
                "batch" => {
                    state_shape = inp.shape.clone();
                    slots.push(Slot::State);
                }
                "scalar" => slots.push(Slot::Time),
                "rng" => {
                    let p = probe.ok_or_else(|| {
                        anyhow!("{exec_name} needs probe input {}", inp.name)
                    })?;
                    slots.push(Slot::Fixed(literal_f32(&inp.shape, p)?));
                }
                other => bail!("{exec_name}: unsupported role {other}"),
            }
        }
        if state_shape.len() != 2 {
            bail!("{exec_name}: expected one [B, D] batch input");
        }
        Ok(XlaDynamics {
            exec,
            slots,
            batch: state_shape[0],
            dim: state_shape[1],
            state_shape,
            calls: 0,
        })
    }

    pub fn state_len(&self) -> usize {
        self.batch * self.dim
    }

    /// The single NFE hot-path implementation: parameters/probes are bound
    /// once at construction, only the state and time literals are created
    /// per call, and the output tuple element is copied straight into the
    /// solver's stage buffer (no Vec allocation per NFE — §Perf L3a).  The
    /// old allocating `run` variant is gone; every caller goes through the
    /// `Dynamics` impl below.
    fn run_into(&mut self, t: f32, y: &[f32], dy: &mut [f32]) -> Result<()> {
        let state_lit = literal_f32(&self.state_shape, y)?;
        let t_lit = Literal::scalar(t);
        let inputs: Vec<&Literal> = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Fixed(l) => l,
                Slot::State => &state_lit,
                Slot::Time => &t_lit,
            })
            .collect();
        self.calls += 1;
        let out = self.exec.run(&inputs)?;
        out[0].copy_raw_to(dy)?;
        Ok(())
    }
}

impl Dynamics for XlaDynamics {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]) {
        self.run_into(t, y, dy)
            .unwrap_or_else(|e| panic!("dynamics {}: {e:?}", self.exec.spec.name));
    }
}
