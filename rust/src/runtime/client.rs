//! PJRT runtime: loads HLO-text artifacts, compiles them on the CPU client,
//! caches the executables, and provides typed invoke helpers.
//!
//! Exported computations are lowered with `return_tuple=True`, so every
//! execution returns a single tuple literal that we decompose.  Interchange
//! is HLO *text* (see aot.py for why).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ExecSpec, Manifest};

// Re-exported so downstream layers (dynamics, trainer) name these through
// `runtime::client::*` and compile identically against `client_stub.rs`
// when the `pjrt` feature is off.
pub use xla::{Literal, PjRtBuffer};

/// Build an f32 literal with the given shape.
///
/// Perf note (§Perf L3a iteration 1): this is on the per-NFE hot path, so
/// the literal is created in ONE host copy via
/// `create_from_shape_and_untyped_data` instead of `vec1(..).reshape(..)`
/// (which materializes an intermediate rank-1 literal = two copies + an
/// extra C-API round-trip).
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal shape {shape:?} needs {n} elems, got {}", data.len());
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Build an i32 literal with the given shape.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal shape {shape:?} needs {n} elems, got {}", data.len());
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs (owned or borrowed); returns the
    /// decomposed output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let bufs = self.exe.execute(inputs)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute with device-resident buffers (hot path: parameters stay on
    /// device across NFE calls).  Returns the raw output buffers.
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute_b(inputs)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// The runtime owns the PJRT client, the manifest, and an executable cache
/// (artifacts compile lazily on first use, once per process).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an executable by manifest name.
    pub fn exec(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.exec_spec(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let rc = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Host -> device transfer for the buffer-based hot path.
    pub fn to_device(&self, shape: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, shape, None)
            .map_err(|e| anyhow!("to_device: {e:?}"))
    }

    /// Load a model's initial parameter blob as per-entry f32 vectors.
    pub fn load_params(&self, model: &str) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.model(model)?;
        let path = self.manifest.dir.join(&spec.params_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != spec.total * 4 {
            bail!(
                "{model}: params blob {} bytes, expected {}",
                bytes.len(),
                spec.total * 4
            );
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(spec
            .layout
            .iter()
            .map(|e| flat[e.offset..e.offset + e.size].to_vec())
            .collect())
    }
}
