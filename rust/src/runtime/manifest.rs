//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust coordinator.  Input *roles* (`param:w1`, `opt:m:w1`, `batch:x`,
//! `rng:eps`, `scalar:lam`) let the trainer assemble executable inputs
//! generically for any model.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub role: String,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn role_kind(&self) -> &str {
        self.role.split(':').next().unwrap_or("")
    }
}

#[derive(Clone, Debug)]
pub struct OutputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub kind: String,
    pub meta: Json,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<OutputSpec>,
}

impl ExecSpec {
    /// Number of leading outputs that are the updated training state
    /// (params + optimizer slots), fed back as next-step inputs.
    pub fn n_state(&self) -> usize {
        self.inputs
            .iter()
            .filter(|i| {
                let k = i.role_kind();
                k == "param" || k == "opt"
            })
            .count()
    }
}

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub hyper: Json,
    pub params_file: String,
    pub layout: Vec<ParamEntry>,
    pub total: usize,
}

impl ModelSpec {
    pub fn hyper_usize(&self, key: &str) -> Result<usize> {
        self.hyper.usize_of(key)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    pub executables: BTreeMap<String, ExecSpec>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().unwrap_or(0))
        .collect())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in root
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            let p = m.req("params")?;
            let mut layout = vec![];
            for e in p.req("layout")?.as_arr().unwrap_or(&[]) {
                layout.push(ParamEntry {
                    name: e.str_of("name")?.to_string(),
                    shape: parse_shape(e.req("shape")?)?,
                    offset: e.usize_of("offset")?,
                    size: e.usize_of("size")?,
                });
            }
            models.insert(
                name.clone(),
                ModelSpec {
                    hyper: m.req("hyper")?.clone(),
                    params_file: p.str_of("file")?.to_string(),
                    layout,
                    total: p.usize_of("total")?,
                },
            );
        }

        let mut executables = BTreeMap::new();
        for (name, e) in root
            .req("executables")?
            .as_obj()
            .ok_or_else(|| anyhow!("executables not an object"))?
        {
            let mut inputs = vec![];
            for i in e.req("inputs")?.as_arr().unwrap_or(&[]) {
                inputs.push(InputSpec {
                    role: i.str_of("role")?.to_string(),
                    name: i.str_of("name")?.to_string(),
                    shape: parse_shape(i.req("shape")?)?,
                    dtype: i.str_of("dtype")?.to_string(),
                });
            }
            let mut outputs = vec![];
            for o in e.req("outputs")?.as_arr().unwrap_or(&[]) {
                outputs.push(OutputSpec {
                    shape: parse_shape(o.req("shape")?)?,
                    dtype: o.str_of("dtype")?.to_string(),
                });
            }
            executables.insert(
                name.clone(),
                ExecSpec {
                    name: name.clone(),
                    file: e.str_of("file")?.to_string(),
                    model: e.str_of("model")?.to_string(),
                    kind: e.str_of("kind")?.to_string(),
                    meta: e.get("meta").cloned().unwrap_or(Json::Null),
                    inputs,
                    outputs,
                },
            );
        }

        if models.is_empty() || executables.is_empty() {
            bail!("manifest is empty");
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, executables })
    }

    pub fn exec_spec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable {name:?}"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "m": {"hyper": {"d": 4, "batch": 2},
               "params": {"file": "m_params.bin", "total": 6,
                          "layout": [{"name": "w", "shape": [2, 2],
                                      "offset": 0, "size": 4},
                                     {"name": "b", "shape": [2],
                                      "offset": 4, "size": 2}]}}
      },
      "executables": {
        "m_train": {"file": "m_train.hlo.txt", "model": "m", "kind": "train",
          "meta": {"steps": 8},
          "inputs": [{"role": "param:w", "name": "w", "shape": [2, 2],
                      "dtype": "float32"},
                     {"role": "opt:m:w", "name": "m_w", "shape": [2, 2],
                      "dtype": "float32"},
                     {"role": "batch:x", "name": "x", "shape": [2, 4],
                      "dtype": "float32"},
                     {"role": "scalar:lam", "name": "lam", "shape": [],
                      "dtype": "float32"}],
          "outputs": [{"shape": [2, 2], "dtype": "float32"},
                      {"shape": [], "dtype": "float32"}]}
      }
    }"#;

    fn sample_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "taynode-manifest-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        dir
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::load(&sample_dir()).unwrap();
        let e = m.exec_spec("m_train").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.n_state(), 2);
        assert_eq!(e.inputs[2].elems(), 8);
        assert_eq!(e.inputs[3].elems(), 1); // scalar
        assert_eq!(e.inputs[0].role_kind(), "param");
        let model = m.model("m").unwrap();
        assert_eq!(model.total, 6);
        assert_eq!(model.layout[1].offset, 4);
        assert_eq!(model.hyper_usize("d").unwrap(), 4);
    }

    #[test]
    fn missing_exec_errors() {
        let m = Manifest::load(&sample_dir()).unwrap();
        assert!(m.exec_spec("nope").is_err());
    }
}
