//! Training-state store: named parameter and optimizer-slot arrays, kept as
//! host vectors (checkpointable) and refreshed from train-step outputs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::ParamEntry;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamStore {
    pub layout: Vec<ParamEntry>,
    /// Parameter values, in layout order.
    pub values: Vec<Vec<f32>>,
    /// Optimizer slots ("m", "v", ...) in layout order.
    pub slots: BTreeMap<String, Vec<Vec<f32>>>,
}

impl ParamStore {
    pub fn new(layout: Vec<ParamEntry>, values: Vec<Vec<f32>>) -> ParamStore {
        assert_eq!(layout.len(), values.len());
        for (e, v) in layout.iter().zip(&values) {
            assert_eq!(e.size, v.len(), "{}", e.name);
        }
        ParamStore { layout, values, slots: BTreeMap::new() }
    }

    /// Add a zero-initialized optimizer slot (adam m/v, sgd momentum).
    pub fn add_slot(&mut self, slot: &str) {
        let zeros: Vec<Vec<f32>> =
            self.layout.iter().map(|e| vec![0.0; e.size]).collect();
        self.slots.insert(slot.to_string(), zeros);
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.layout
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))
    }

    pub fn value(&self, name: &str) -> Result<&Vec<f32>> {
        Ok(&self.values[self.index_of(name)?])
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self.layout[self.index_of(name)?].shape)
    }

    pub fn slot_value(&self, slot: &str, name: &str) -> Result<&Vec<f32>> {
        let s = self
            .slots
            .get(slot)
            .ok_or_else(|| anyhow!("unknown slot {slot:?}"))?;
        Ok(&s[self.index_of(name)?])
    }

    pub fn set_value(&mut self, idx: usize, data: Vec<f32>) {
        assert_eq!(data.len(), self.layout[idx].size);
        self.values[idx] = data;
    }

    pub fn set_slot_value(&mut self, slot: &str, idx: usize, data: Vec<f32>) {
        let s = self.slots.get_mut(slot).expect("slot exists"); // taylint: allow(D4) -- slots are fixed at store construction
        assert_eq!(data.len(), s[idx].len());
        s[idx] = data;
    }

    /// L2 norm of all parameters (divergence tripwire in the trainer).
    pub fn global_norm(&self) -> f32 {
        self.values
            .iter()
            .flat_map(|v| v.iter())
            .map(|x| x * x)
            .sum::<f32>()
            .sqrt()
    }

    // -- checkpointing -------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut bytes: Vec<u8> = vec![];
        let mut meta_slots = vec![];
        for v in &self.values {
            for x in v {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        for (slot, vs) in &self.slots {
            meta_slots.push(Json::str(slot.clone()));
            for v in vs {
                for x in v {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        let meta = Json::obj(vec![
            ("slots", Json::Arr(meta_slots)),
            (
                "layout",
                Json::Arr(
                    self.layout
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("name", Json::str(e.name.clone())),
                                ("size", Json::num(e.size as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path.with_extension("json"), meta.to_string())?;
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load(&mut self, path: &Path) -> Result<()> {
        let meta_path = path.with_extension("json");
        let meta = Json::parse(
            &std::fs::read_to_string(&meta_path)
                .with_context(|| format!("reading {meta_path:?}"))?,
        )?;
        let bytes = std::fs::read(path)?;
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let per_copy: usize = self.layout.iter().map(|e| e.size).sum();
        let slots: Vec<String> = meta
            .req("slots")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|s| s.as_str().map(|x| x.to_string()))
            .collect();
        if flat.len() != per_copy * (1 + slots.len()) {
            bail!("checkpoint size mismatch");
        }
        let mut off = 0;
        for i in 0..self.layout.len() {
            let n = self.layout[i].size;
            self.values[i] = flat[off..off + n].to_vec();
            off += n;
        }
        self.slots.clear();
        for slot in slots {
            let mut vs = vec![];
            for e in &self.layout {
                vs.push(flat[off..off + e.size].to_vec());
                off += e.size;
            }
            self.slots.insert(slot, vs);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        let layout = vec![
            ParamEntry { name: "w".into(), shape: vec![2, 2], offset: 0, size: 4 },
            ParamEntry { name: "b".into(), shape: vec![2], offset: 4, size: 2 },
        ];
        ParamStore::new(layout, vec![vec![1., 2., 3., 4.], vec![5., 6.]])
    }

    #[test]
    fn lookup_and_update() {
        let mut s = store();
        assert_eq!(s.value("b").unwrap(), &vec![5., 6.]);
        assert_eq!(s.shape("w").unwrap(), &[2, 2]);
        s.add_slot("m");
        assert_eq!(s.slot_value("m", "w").unwrap(), &vec![0.0; 4]);
        s.set_value(1, vec![7., 8.]);
        assert_eq!(s.value("b").unwrap(), &vec![7., 8.]);
        assert!(s.value("nope").is_err());
        let gn = s.global_norm();
        assert!((gn - (1.0f32 + 4.0 + 9.0 + 16.0 + 49.0 + 64.0).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut s = store();
        s.add_slot("m");
        s.set_slot_value("m", 0, vec![9., 9., 9., 9.]);
        let dir = std::env::temp_dir()
            .join(format!("taynode-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        s.save(&path).unwrap();

        let mut s2 = store();
        s2.add_slot("m");
        s2.load(&path).unwrap();
        assert_eq!(s2.values, s.values);
        assert_eq!(s2.slots, s.slots);
    }
}
