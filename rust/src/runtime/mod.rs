//! L3 runtime: PJRT client + artifact registry (`client`), the
//! python→rust interface contract (`manifest`), training-state store
//! (`params`), and the solver↔executable bridge (`dynamics`).
//!
//! Python never runs at this layer: artifacts are HLO text produced once by
//! `make artifacts` and compiled here through the PJRT C API.
//!
//! The PJRT/XLA half is feature-gated: with `--features pjrt` the real
//! client (`client.rs`, needs the vendored `xla` crate and its native
//! deps) is compiled; by default `client_stub.rs` supplies the same API
//! surface with constructors that return errors, so every downstream layer
//! (dynamics, trainer, evaluator, experiments) builds and unit-tests on any
//! machine with no native dependency.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub mod dynamics;
pub mod manifest;
pub mod params;

pub use client::{literal_f32, literal_i32, Executable, Runtime};
pub use dynamics::XlaDynamics;
pub use manifest::{ExecSpec, InputSpec, Manifest, ModelSpec};
pub use params::ParamStore;
