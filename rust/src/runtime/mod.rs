//! L3 runtime: PJRT client + artifact registry (`client`), the
//! python→rust interface contract (`manifest`), training-state store
//! (`params`), and the solver↔executable bridge (`dynamics`).
//!
//! Python never runs at this layer: artifacts are HLO text produced once by
//! `make artifacts` and compiled here through the PJRT C API.

pub mod client;
pub mod dynamics;
pub mod manifest;
pub mod params;

pub use client::{literal_f32, literal_i32, Executable, Runtime};
pub use dynamics::XlaDynamics;
pub use manifest::{ExecSpec, InputSpec, Manifest, ModelSpec};
pub use params::ParamStore;
