//! Cache-blocked, autovectorization-friendly compute kernels for the
//! repo's three hot paths — the Rust port of the Pallas kernel specs in
//! `python/compile/kernels/{cauchy_prod,fused_mlp}.py`.
//!
//! * [`cauchy`] — truncated-series arithmetic over flat `[K+1, m]`
//!   coefficient slabs: the triangular Cauchy product and the ODE
//!   recurrences (`div`/`exp`/`ln`/`sqrt`/`sin_cos`/`tanh`/`sigmoid`),
//!   walked in [`BLOCK`]-wide lane blocks with the k-loop unrolled at
//!   compile time for K ≤ 7 (the paper's operating range).  Backs
//!   [`SeriesVec`](crate::taylor::SeriesVec).
//! * [`mlp`] — the fused MLP layer (bias → GEMV → optional tanh) over
//!   register tiles of independent (row, output) pairs.  Backs the f32
//!   [`BatchDynamics`](crate::solvers::batch::BatchDynamics) hot path of
//!   [`Mlp`](crate::nn::Mlp).
//! * [`axpy`] — the fused RK stage combination `y + h Σ cⱼ·kⱼ` in one
//!   blocked pass (backs `solvers::stage` and `tensor::multi_axpy_into`)
//!   plus the f64 column primitives of the discrete adjoint
//!   (`autodiff::Tape::backward`, `coordinator::train_native`).
//! * [`naive`] — the pre-kernel reference loops, retained verbatim: the
//!   test oracle for bit-equality and the honest baseline
//!   `benches/perf_kernels.rs` times the blocked kernels against.
//!
//! **Bit-identity discipline.**  Blocking regroups *independent elements*
//! only; it never reorders any single element's floating-point operation
//! sequence.  Concretely: accumulators start at the same value as the
//! scalar recurrence (0.0 where the scalar starts at 0.0 — never a hoisted
//! first term, because `0.0 + (-0.0)` is `+0.0` while `-0.0` alone is
//! not), j-sums run in the same ascending order, multiplies keep the same
//! association, and the MLP/axpy kernels tile over independent outputs and
//! never split a reduction axis.  Every consumer's existing bit-equality
//! property suite therefore passes unchanged, and `kern`'s own tests pin
//! blocked == naive bit-for-bit at awkward shapes (m not a multiple of
//! [`BLOCK`], K ∈ 0..=7, B ∈ {1, 3, 257}).
//!
//! ```
//! use taynode::kern::{cauchy, naive};
//!
//! // (1 + t)² = 1 + 2t + t² on a 3-element batch: k1 = 3 rows, m = 3.
//! let z = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0];
//! let mut out = vec![0.0; 9];
//! cauchy::mul_into(3, 3, &z, &z, &mut out);
//! assert_eq!(&out[3..6], &[2.0, 2.0, 2.0]);
//!
//! // Bit-identical to the naive triangular loop on the same data.
//! let rows: Vec<Vec<f64>> = z.chunks(3).map(|r| r.to_vec()).collect();
//! let want = naive::mul(&rows, &rows);
//! for (k, wk) in want.iter().enumerate() {
//!     assert_eq!(&out[k * 3..(k + 1) * 3], &wk[..]);
//! }
//! ```

pub mod axpy;
pub mod cauchy;
pub mod mlp;
pub mod naive;

/// Lane-block width (elements per tile).  64 f64 lanes = 512 bytes = 8
/// AVX-512 / 16 AVX2 vectors per coefficient row — small enough that a
/// full K ≤ 7 recurrence's block working set stays in L1, large enough to
/// amortize the per-block bookkeeping.
pub const BLOCK: usize = 64;
