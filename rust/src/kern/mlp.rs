//! The fused MLP layer kernel — the Rust mapping of
//! `python/compile/kernels/fused_mlp.py`: bias load → GEMV accumulate →
//! optional tanh, staged once per layer over register tiles of
//! [`RB`] batch rows × [`JB`] output columns.
//!
//! The tile spans *independent outputs only* (batch rows and output
//! columns); the reduction axis i — the dot product over the layer's
//! inputs — is never split or reordered, so every output element runs the
//! exact scalar sequence `acc = b[j]; acc += acts[r,i]·w[i,j]` in
//! ascending i, then tanh.  With the weights widened to f64 once per
//! layer ([`widen`]; the f32→f64 cast is exact, so precomputing it is
//! bit-invisible), the inner loop is a pure fused multiply-add sweep over
//! `JB` contiguous weight lanes — the autovectorizer's best case — while
//! the old loop re-cast every weight scalar inside the dependent
//! accumulator chain.

/// Output-column tile width: one cache line of f64 weights per load.
pub const JB: usize = 8;

/// Batch-row tile height: `RB · JB` accumulators fit comfortably in
/// registers (32 f64 = 8 AVX2 / 4 AVX-512 vectors).
pub const RB: usize = 4;

/// Widen an f32 parameter slice to a reusable f64 scratch buffer.  The
/// cast is exact (every f32 is representable as f64), so kernels that
/// consume the widened copy are bit-identical to per-access casting.
#[inline]
pub fn widen(src: &[f32], dst: &mut Vec<f64>) {
    dst.clear();
    dst.extend(src.iter().map(|v| *v as f64));
}

/// One fused layer: `out[r, j] = b[j] + Σ_i acts[r, i] · w[i, j]` (tanh
/// applied when `tanh` is set), over `rows` examples; `w` is row-major
/// `[win, wout]`, already widened.  `out` is cleared and refilled (a
/// reusable staging buffer).
///
/// ```
/// use taynode::kern::mlp::{layer_into, widen};
/// // One example through a 2→2 identity layer with bias (0.5, -0.5).
/// let (mut w64, mut b64) = (vec![], vec![]);
/// widen(&[1.0, 0.0, 0.0, 1.0], &mut w64);
/// widen(&[0.5, -0.5], &mut b64);
/// let mut out = vec![];
/// layer_into(1, 2, 2, &[2.0, 3.0], &w64, &b64, false, &mut out);
/// assert_eq!(out, [2.5, 2.5]);
/// ```
pub fn layer_into(
    rows: usize,
    win: usize,
    wout: usize,
    acts: &[f64],
    w: &[f64],
    b: &[f64],
    tanh: bool,
    out: &mut Vec<f64>,
) {
    debug_assert_eq!(acts.len(), rows * win);
    debug_assert_eq!(w.len(), win * wout);
    debug_assert_eq!(b.len(), wout);
    out.clear();
    out.resize(rows * wout, 0.0);
    let mut r0 = 0;
    while r0 < rows {
        let rb = RB.min(rows - r0);
        let mut j0 = 0;
        while j0 < wout {
            let jb = JB.min(wout - j0);
            // acc[rr][u] accumulates output (r0 + rr, j0 + u): bias first,
            // exactly the scalar start.
            let mut acc = [[0.0f64; JB]; RB];
            let brow = &b[j0..j0 + jb];
            for arr in acc[..rb].iter_mut() {
                arr[..jb].copy_from_slice(brow);
            }
            for i in 0..win {
                let wrow = &w[i * wout + j0..i * wout + j0 + jb];
                for (rr, arr) in acc[..rb].iter_mut().enumerate() {
                    let ai = acts[(r0 + rr) * win + i];
                    for (av, wv) in arr[..jb].iter_mut().zip(wrow) {
                        *av += ai * *wv;
                    }
                }
            }
            for (rr, arr) in acc[..rb].iter().enumerate() {
                let o0 = (r0 + rr) * wout + j0;
                let dst = &mut out[o0..o0 + jb];
                if tanh {
                    for (d, av) in dst.iter_mut().zip(&arr[..jb]) {
                        *d = av.tanh();
                    }
                } else {
                    dst.copy_from_slice(&arr[..jb]);
                }
            }
            j0 += jb;
        }
        r0 += rb;
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive;
    use super::*;
    use crate::util::ptest::gen;
    use crate::util::rng::Pcg;

    #[test]
    fn fused_layer_matches_naive_bit_for_bit_at_awkward_shapes() {
        // Batch sizes off the RB tile (1, 3, 257), widths off the JB tile
        // (1, 3, 7, 9, 130), hidden and linear heads — every output must
        // be bitwise the serial per-access-cast loop.
        let mut rng = Pcg::new(0xB10C);
        for &rows in &[1usize, 3, 4, 5, 257] {
            for &(win, wout) in &[(1usize, 1usize), (3, 7), (9, 130), (16, 16), (7, 3)] {
                for &tanh in &[false, true] {
                    let acts = gen::vec_f64(&mut rng, rows * win, -1.2, 1.2);
                    let w = gen::vec_f32(&mut rng, win * wout, 1.0);
                    let b = gen::vec_f32(&mut rng, wout, 0.5);
                    let want = naive::mlp_layer(rows, win, wout, &acts, &w, &b, tanh);
                    let (mut w64, mut b64) = (vec![], vec![]);
                    widen(&w, &mut w64);
                    widen(&b, &mut b64);
                    let mut got = vec![];
                    layer_into(rows, win, wout, &acts, &w64, &b64, tanh, &mut got);
                    assert_eq!(got.len(), want.len());
                    for (e, (g, v)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            v.to_bits(),
                            "rows={rows} {win}x{wout} tanh={tanh} elem {e}: {g} vs {v}"
                        );
                    }
                }
            }
        }
    }
}
