//! Blocked truncated-series kernels over flat `[K+1, m]` coefficient
//! slabs — the Rust mapping of `python/compile/kernels/cauchy_prod.py`.
//!
//! Layout: an order-K series batch over m elements is ONE contiguous slab
//! of length `(K+1)·m`; coefficient row k is `slab[k·m..(k+1)·m]`.  Every
//! kernel walks the elements in [`BLOCK`]-wide lane blocks with the
//! k-recurrence innermost, so a block's coefficient column stays in L1
//! across all K+1 orders and the element loops are unit-stride maps the
//! autovectorizer lowers to SIMD.  The jet hot paths (mul, div, exp,
//! tanh, sigmoid) additionally dispatch through [`unroll_k1!`] for
//! k1 ≤ 8 — a monomorphized body with a literal order count, so the
//! triangular k/j loops fully unroll: the "trace-time unrolling" of the
//! Pallas spec, done by constant propagation instead of tracing.
//!
//! Per element, each kernel applies the EXACT operation sequence of the
//! scalar `Series` recurrence (see the naive references in
//! [`super::naive`], kept verbatim from the pre-kernel code): same 0.0
//! accumulator starts, same ascending-j order, same multiply association.
//! The tests below pin blocked == naive bit-for-bit at awkward shapes.

use super::BLOCK;

/// Bind `$kk` to a compile-time-constant order count for k1 ≤ 8: each
/// match arm inlines `$body` with a literal trip count, so LLVM fully
/// unrolls the triangular recurrence loops in the paper's operating range
/// (K ≤ 7) while the `_` arm keeps arbitrary orders correct.
macro_rules! unroll_k1 {
    ($k1:expr, $kk:ident, $body:expr) => {
        match $k1 {
            1 => {
                let $kk: usize = 1;
                $body
            }
            2 => {
                let $kk: usize = 2;
                $body
            }
            3 => {
                let $kk: usize = 3;
                $body
            }
            4 => {
                let $kk: usize = 4;
                $body
            }
            5 => {
                let $kk: usize = 5;
                $body
            }
            6 => {
                let $kk: usize = 6;
                $body
            }
            7 => {
                let $kk: usize = 7;
                $body
            }
            8 => {
                let $kk: usize = 8;
                $body
            }
            _ => {
                let $kk: usize = $k1;
                $body
            }
        }
    };
}

/// Truncated Cauchy product `out[k] = Σ_{j=0..=k} z[j] ⊙ w[k-j]` (paper
/// Table 1 row 2) on `[k1, m]` slabs.  `out` is overwritten.
///
/// ```
/// use taynode::kern::cauchy::mul_into;
/// // (1 + 2t)·(3 + 4t) = 3 + 10t + 8t², one element (k1 = 3, m = 1).
/// let mut out = vec![0.0; 3];
/// mul_into(3, 1, &[1.0, 2.0, 0.0], &[3.0, 4.0, 0.0], &mut out);
/// assert_eq!(out, [3.0, 10.0, 8.0]);
/// ```
pub fn mul_into(k1: usize, m: usize, z: &[f64], w: &[f64], out: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(w.len(), k1 * m);
    debug_assert_eq!(out.len(), k1 * m);
    unroll_k1!(k1, kk, mul_slab(kk, m, z, w, out));
}

#[inline(always)]
fn mul_slab(k1: usize, m: usize, z: &[f64], w: &[f64], out: &mut [f64]) {
    let mut acc = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for k in 0..k1 {
            for a in acc[..bl].iter_mut() {
                *a = 0.0;
            }
            for j in 0..=k {
                let zr = &z[j * m + e0..j * m + e0 + bl];
                let wr = &w[(k - j) * m + e0..(k - j) * m + e0 + bl];
                for ((a, zv), wv) in acc[..bl].iter_mut().zip(zr).zip(wr) {
                    *a += *zv * *wv;
                }
            }
            out[k * m + e0..k * m + e0 + bl].copy_from_slice(&acc[..bl]);
        }
        e0 += bl;
    }
}

/// Series division (Table 1 row 3): `out[k] = (z[k] - Σ_{j<k} out[j] ⊙
/// w[k-j]) / w[0]`.  `out` is overwritten; earlier rows feed later ones.
pub fn div_into(k1: usize, m: usize, z: &[f64], w: &[f64], out: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(w.len(), k1 * m);
    debug_assert_eq!(out.len(), k1 * m);
    unroll_k1!(k1, kk, div_slab(kk, m, z, w, out));
}

#[inline(always)]
fn div_slab(k1: usize, m: usize, z: &[f64], w: &[f64], out: &mut [f64]) {
    let mut acc = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for k in 0..k1 {
            acc[..bl].copy_from_slice(&z[k * m + e0..k * m + e0 + bl]);
            for j in 0..k {
                let or = &out[j * m + e0..j * m + e0 + bl];
                let wr = &w[(k - j) * m + e0..(k - j) * m + e0 + bl];
                for ((a, ov), wv) in acc[..bl].iter_mut().zip(or).zip(wr) {
                    *a -= *ov * *wv;
                }
            }
            let w0 = &w[e0..e0 + bl];
            let dst = &mut out[k * m + e0..k * m + e0 + bl];
            for ((d, a), wv) in dst.iter_mut().zip(&acc[..bl]).zip(w0) {
                *d = *a / *wv;
            }
        }
        e0 += bl;
    }
}

/// Series exponential via y' = y z': `y[0] = exp(z[0])`, then
/// `y[k] = (Σ_{j=1..=k} j·z[j] ⊙ y[k-j]) / k`.
pub fn exp_into(k1: usize, m: usize, z: &[f64], y: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(y.len(), k1 * m);
    unroll_k1!(k1, kk, exp_slab(kk, m, z, y));
}

#[inline(always)]
fn exp_slab(k1: usize, m: usize, z: &[f64], y: &mut [f64]) {
    let mut acc = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for (yv, zv) in y[e0..e0 + bl].iter_mut().zip(&z[e0..e0 + bl]) {
            *yv = zv.exp();
        }
        for k in 1..k1 {
            for a in acc[..bl].iter_mut() {
                *a = 0.0;
            }
            for j in 1..=k {
                let jf = j as f64;
                let zr = &z[j * m + e0..j * m + e0 + bl];
                let yr = &y[(k - j) * m + e0..(k - j) * m + e0 + bl];
                for ((a, zv), yv) in acc[..bl].iter_mut().zip(zr).zip(yr) {
                    *a += jf * *zv * *yv;
                }
            }
            let kf = k as f64;
            let dst = &mut y[k * m + e0..k * m + e0 + bl];
            for (d, a) in dst.iter_mut().zip(&acc[..bl]) {
                *d = *a / kf;
            }
        }
        e0 += bl;
    }
}

/// Series logarithm via y' = z'/z: `y[0] = ln(z[0])`, then
/// `y[k] = (k·z[k] - Σ_{j=1..k} (k-j)·y[k-j] ⊙ z[j]) / (k·z[0])`.
pub fn ln_into(k1: usize, m: usize, z: &[f64], y: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(y.len(), k1 * m);
    let mut acc = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for (yv, zv) in y[e0..e0 + bl].iter_mut().zip(&z[e0..e0 + bl]) {
            *yv = zv.ln();
        }
        for k in 1..k1 {
            let kf = k as f64;
            let zk = &z[k * m + e0..k * m + e0 + bl];
            for (a, zv) in acc[..bl].iter_mut().zip(zk) {
                *a = kf * *zv;
            }
            for j in 1..k {
                let cf = (k - j) as f64;
                let yr = &y[(k - j) * m + e0..(k - j) * m + e0 + bl];
                let zr = &z[j * m + e0..j * m + e0 + bl];
                for ((a, yv), zv) in acc[..bl].iter_mut().zip(yr).zip(zr) {
                    *a -= cf * *yv * *zv;
                }
            }
            let z0 = &z[e0..e0 + bl];
            let dst = &mut y[k * m + e0..k * m + e0 + bl];
            for ((d, a), zv) in dst.iter_mut().zip(&acc[..bl]).zip(z0) {
                *d = *a / (kf * *zv);
            }
        }
        e0 += bl;
    }
}

/// Series square root via y² = z: `y[0] = sqrt(z[0])`, then
/// `y[k] = (z[k] - Σ_{j=1..k} y[j] ⊙ y[k-j]) / (2·y[0])`.
pub fn sqrt_into(k1: usize, m: usize, z: &[f64], y: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(y.len(), k1 * m);
    let mut acc = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for (yv, zv) in y[e0..e0 + bl].iter_mut().zip(&z[e0..e0 + bl]) {
            *yv = zv.sqrt();
        }
        for k in 1..k1 {
            acc[..bl].copy_from_slice(&z[k * m + e0..k * m + e0 + bl]);
            for j in 1..k {
                let yj = &y[j * m + e0..j * m + e0 + bl];
                let ymj = &y[(k - j) * m + e0..(k - j) * m + e0 + bl];
                for ((a, u), v) in acc[..bl].iter_mut().zip(yj).zip(ymj) {
                    *a -= *u * *v;
                }
            }
            let (head, tail) = y.split_at_mut(k * m);
            let y0 = &head[e0..e0 + bl];
            let dst = &mut tail[e0..e0 + bl];
            for ((d, a), yv) in dst.iter_mut().zip(&acc[..bl]).zip(y0) {
                *d = *a / (2.0 * *yv);
            }
        }
        e0 += bl;
    }
}

/// Coupled sine/cosine recurrence: `s[k] = (Σ j·z[j] ⊙ c[k-j]) / k`,
/// `c[k] = -(Σ j·z[j] ⊙ s[k-j]) / k`, both sums over j = 1..=k with the
/// per-j term `j·z[j]` shared — the scalar interleaving preserved.
pub fn sin_cos_into(k1: usize, m: usize, z: &[f64], s: &mut [f64], c: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(s.len(), k1 * m);
    debug_assert_eq!(c.len(), k1 * m);
    let mut sacc = [0.0f64; BLOCK];
    let mut cacc = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for ((sv, cv), zv) in s[e0..e0 + bl]
            .iter_mut()
            .zip(c[e0..e0 + bl].iter_mut())
            .zip(&z[e0..e0 + bl])
        {
            *sv = zv.sin();
            *cv = zv.cos();
        }
        for k in 1..k1 {
            for (sa, ca) in sacc[..bl].iter_mut().zip(cacc[..bl].iter_mut()) {
                *sa = 0.0;
                *ca = 0.0;
            }
            for j in 1..=k {
                let jf = j as f64;
                let zr = &z[j * m + e0..j * m + e0 + bl];
                let cr = &c[(k - j) * m + e0..(k - j) * m + e0 + bl];
                let sr = &s[(k - j) * m + e0..(k - j) * m + e0 + bl];
                for e in 0..bl {
                    let zj = jf * zr[e];
                    sacc[e] += zj * cr[e];
                    cacc[e] += zj * sr[e];
                }
            }
            let kf = k as f64;
            let sdst = &mut s[k * m + e0..k * m + e0 + bl];
            for (d, a) in sdst.iter_mut().zip(&sacc[..bl]) {
                *d = *a / kf;
            }
            let cdst = &mut c[k * m + e0..k * m + e0 + bl];
            for (d, a) in cdst.iter_mut().zip(&cacc[..bl]) {
                *d = -*a / kf;
            }
        }
        e0 += bl;
    }
}

/// Series tanh via s' = (1 - s²) z': per j the inner sum
/// `ssm = (s ⊙ s)[k-j]` runs ascending, then
/// `acc += j·z[j] ⊙ (δ_{k-j,0} - ssm)` — the scalar op sequence exactly.
pub fn tanh_into(k1: usize, m: usize, z: &[f64], s: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(s.len(), k1 * m);
    unroll_k1!(k1, kk, tanh_slab(kk, m, z, s));
}

#[inline(always)]
fn tanh_slab(k1: usize, m: usize, z: &[f64], s: &mut [f64]) {
    let mut acc = [0.0f64; BLOCK];
    let mut ssm = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for (sv, zv) in s[e0..e0 + bl].iter_mut().zip(&z[e0..e0 + bl]) {
            *sv = zv.tanh();
        }
        for k in 1..k1 {
            for a in acc[..bl].iter_mut() {
                *a = 0.0;
            }
            for j in 1..=k {
                let mj = k - j;
                for v in ssm[..bl].iter_mut() {
                    *v = 0.0;
                }
                for i in 0..=mj {
                    let si = &s[i * m + e0..i * m + e0 + bl];
                    let sr = &s[(mj - i) * m + e0..(mj - i) * m + e0 + bl];
                    for ((v, a), b) in ssm[..bl].iter_mut().zip(si).zip(sr) {
                        *v += *a * *b;
                    }
                }
                let jf = j as f64;
                let zr = &z[j * m + e0..j * m + e0 + bl];
                for e in 0..bl {
                    let u = if mj == 0 { 1.0 - ssm[e] } else { -ssm[e] };
                    acc[e] += jf * zr[e] * u;
                }
            }
            let kf = k as f64;
            let dst = &mut s[k * m + e0..k * m + e0 + bl];
            for (d, a) in dst.iter_mut().zip(&acc[..bl]) {
                *d = *a / kf;
            }
        }
        e0 += bl;
    }
}

/// Logistic sigmoid via s' = s (1 - s) z': per j the inner sum
/// `ssm = (s ⊙ s)[k-j]` runs ascending, then
/// `acc += j·z[j] ⊙ (s[k-j] - ssm)` — the scalar op sequence exactly.
pub fn sigmoid_into(k1: usize, m: usize, z: &[f64], s: &mut [f64]) {
    debug_assert_eq!(z.len(), k1 * m);
    debug_assert_eq!(s.len(), k1 * m);
    unroll_k1!(k1, kk, sigmoid_slab(kk, m, z, s));
}

#[inline(always)]
fn sigmoid_slab(k1: usize, m: usize, z: &[f64], s: &mut [f64]) {
    let mut acc = [0.0f64; BLOCK];
    let mut ssm = [0.0f64; BLOCK];
    let mut e0 = 0;
    while e0 < m {
        let bl = BLOCK.min(m - e0);
        for (sv, zv) in s[e0..e0 + bl].iter_mut().zip(&z[e0..e0 + bl]) {
            *sv = 1.0 / (1.0 + (-*zv).exp());
        }
        for k in 1..k1 {
            for a in acc[..bl].iter_mut() {
                *a = 0.0;
            }
            for j in 1..=k {
                let mj = k - j;
                for v in ssm[..bl].iter_mut() {
                    *v = 0.0;
                }
                for i in 0..=mj {
                    let si = &s[i * m + e0..i * m + e0 + bl];
                    let sr = &s[(mj - i) * m + e0..(mj - i) * m + e0 + bl];
                    for ((v, a), b) in ssm[..bl].iter_mut().zip(si).zip(sr) {
                        *v += *a * *b;
                    }
                }
                let jf = j as f64;
                let zr = &z[j * m + e0..j * m + e0 + bl];
                let smj = &s[mj * m + e0..mj * m + e0 + bl];
                for e in 0..bl {
                    acc[e] += jf * zr[e] * (smj[e] - ssm[e]);
                }
            }
            let kf = k as f64;
            let dst = &mut s[k * m + e0..k * m + e0 + bl];
            for (d, a) in dst.iter_mut().zip(&acc[..bl]) {
                *d = *a / kf;
            }
        }
        e0 += bl;
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive;
    use super::*;
    use crate::util::ptest::gen;
    use crate::util::rng::Pcg;

    /// The awkward-shape sweep the kernels must survive bit-for-bit:
    /// element counts off the lane width (m % BLOCK ≠ 0, including the
    /// remainder-only m = 1/3 and the one-past m = BLOCK + 1 / 257) and
    /// every order the paper operates at (K ∈ 0..=7, i.e. k1 ∈ 1..=8)
    /// plus one past the unroll dispatch (k1 = 9).
    const SHAPES_M: [usize; 7] = [1, 3, BLOCK - 1, BLOCK, BLOCK + 1, 257, 2 * BLOCK + 17];

    fn rows_of(slab: &[f64], k1: usize, m: usize) -> Vec<Vec<f64>> {
        (0..k1).map(|k| slab[k * m..(k + 1) * m].to_vec()).collect()
    }

    fn assert_slab_eq(got: &[f64], want: &[Vec<f64>], m: usize, ctx: &str) {
        for (k, wk) in want.iter().enumerate() {
            for (e, wv) in wk.iter().enumerate() {
                let gv = got[k * m + e];
                assert_eq!(
                    gv.to_bits(),
                    wv.to_bits(),
                    "{ctx}: k={k} e={e}: {gv} vs {wv}"
                );
            }
        }
    }

    #[test]
    fn blocked_kernels_match_naive_bit_for_bit_at_awkward_shapes() {
        let mut rng = Pcg::new(0xC0FFEE);
        for &m in &SHAPES_M {
            for k1 in 1..=9usize {
                let z = gen::vec_f64(&mut rng, k1 * m, -1.5, 1.5);
                let w = gen::vec_f64(&mut rng, k1 * m, -1.5, 1.5);
                // keep divisors / ln / sqrt arguments away from 0
                let mut wpos = w.clone();
                for v in wpos[..m].iter_mut() {
                    *v = v.abs() + 0.5;
                }
                let zr = rows_of(&z, k1, m);
                let wr = rows_of(&w, k1, m);
                let wposr = rows_of(&wpos, k1, m);
                let ctx = format!("m={m} k1={k1}");

                let mut out = vec![0.0; k1 * m];
                mul_into(k1, m, &z, &w, &mut out);
                assert_slab_eq(&out, &naive::mul(&zr, &wr), m, &format!("mul {ctx}"));

                div_into(k1, m, &z, &wpos, &mut out);
                assert_slab_eq(&out, &naive::div(&zr, &wposr), m, &format!("div {ctx}"));

                exp_into(k1, m, &z, &mut out);
                assert_slab_eq(&out, &naive::exp(&zr), m, &format!("exp {ctx}"));

                ln_into(k1, m, &wpos, &mut out);
                assert_slab_eq(&out, &naive::ln(&wposr), m, &format!("ln {ctx}"));

                sqrt_into(k1, m, &wpos, &mut out);
                assert_slab_eq(&out, &naive::sqrt(&wposr), m, &format!("sqrt {ctx}"));

                tanh_into(k1, m, &z, &mut out);
                assert_slab_eq(&out, &naive::tanh(&zr), m, &format!("tanh {ctx}"));

                sigmoid_into(k1, m, &z, &mut out);
                assert_slab_eq(&out, &naive::sigmoid(&zr), m, &format!("sigmoid {ctx}"));

                let mut cout = vec![0.0; k1 * m];
                sin_cos_into(k1, m, &z, &mut out, &mut cout);
                let (sn, cn) = naive::sin_cos(&zr);
                assert_slab_eq(&out, &sn, m, &format!("sin {ctx}"));
                assert_slab_eq(&cout, &cn, m, &format!("cos {ctx}"));
            }
        }
    }

    #[test]
    fn unrolled_dispatch_agrees_with_generic_arm() {
        // k1 = 4 goes through the unrolled arm, k1 = 12 through the `_`
        // arm; slicing the k1 = 12 slab down to its first 4 rows must
        // reproduce the k1 = 4 product (the triangular sum only ever reads
        // rows ≤ k), so both arms share one oracle.
        let mut rng = Pcg::new(7);
        let m = 129;
        let z = gen::vec_f64(&mut rng, 12 * m, -1.0, 1.0);
        let w = gen::vec_f64(&mut rng, 12 * m, -1.0, 1.0);
        let mut big = vec![0.0; 12 * m];
        mul_into(12, m, &z, &w, &mut big);
        let mut small = vec![0.0; 4 * m];
        mul_into(4, m, &z[..4 * m], &w[..4 * m], &mut small);
        for e in 0..4 * m {
            assert_eq!(big[e].to_bits(), small[e].to_bits(), "elem {e}");
        }
    }
}
