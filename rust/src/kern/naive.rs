//! Naive reference loops, retained verbatim from the pre-kernel code —
//! the oracles the blocked kernels are bit-compared against (here and in
//! `benches/perf_kernels.rs`, before anything is timed) and the honest
//! baselines those benches report speedups over.
//!
//! Series references keep the old `Vec<Vec<f64>>` row-per-order storage
//! (including its per-row allocations); the MLP reference keeps the
//! per-access f32→f64 casts and the serial dependent accumulator chain;
//! the multi-axpy reference keeps the one-pass-per-stage sweeps.  None of
//! this is dead weight: a speedup claimed against a strawman would be
//! meaningless, so the baselines are exactly the loops the kernels
//! replaced.

/// Truncated Cauchy product on row-per-order storage (the old
/// `SeriesVec::mul` body).
pub fn mul(z: &[Vec<f64>], w: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut out = vec![vec![0.0; m]; k1];
    for k in 0..k1 {
        for j in 0..=k {
            for e in 0..m {
                out[k][e] += z[j][e] * w[k - j][e];
            }
        }
    }
    out
}

/// Series division (the old `SeriesVec::div` body).
pub fn div(z: &[Vec<f64>], w: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut out = vec![vec![0.0; m]; k1];
    for k in 0..k1 {
        for e in 0..m {
            let mut acc = z[k][e];
            for j in 0..k {
                acc -= out[j][e] * w[k - j][e];
            }
            out[k][e] = acc / w[0][e];
        }
    }
    out
}

/// Series exponential (the old `SeriesVec::exp` body).
pub fn exp(z: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut y: Vec<Vec<f64>> = Vec::with_capacity(k1);
    y.push(z[0].iter().map(|v| v.exp()).collect());
    for k in 1..k1 {
        let mut out = vec![0.0; m];
        for e in 0..m {
            let mut acc = 0.0;
            for j in 1..=k {
                acc += j as f64 * z[j][e] * y[k - j][e];
            }
            out[e] = acc / k as f64;
        }
        y.push(out);
    }
    y
}

/// Series logarithm (the old `SeriesVec::ln` body).
pub fn ln(z: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut y: Vec<Vec<f64>> = Vec::with_capacity(k1);
    y.push(z[0].iter().map(|v| v.ln()).collect());
    for k in 1..k1 {
        let mut out = vec![0.0; m];
        for e in 0..m {
            let mut acc = k as f64 * z[k][e];
            for j in 1..k {
                acc -= (k - j) as f64 * y[k - j][e] * z[j][e];
            }
            out[e] = acc / (k as f64 * z[0][e]);
        }
        y.push(out);
    }
    y
}

/// Series square root (the old `SeriesVec::sqrt` body).
pub fn sqrt(z: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut y: Vec<Vec<f64>> = Vec::with_capacity(k1);
    y.push(z[0].iter().map(|v| v.sqrt()).collect());
    for k in 1..k1 {
        let mut out = vec![0.0; m];
        for e in 0..m {
            let mut acc = z[k][e];
            for j in 1..k {
                acc -= y[j][e] * y[k - j][e];
            }
            out[e] = acc / (2.0 * y[0][e]);
        }
        y.push(out);
    }
    y
}

/// Coupled sine/cosine (the old `SeriesVec::sin_cos` body).
pub fn sin_cos(z: &[Vec<f64>]) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let k1 = z.len();
    let m = z[0].len();
    let mut s: Vec<Vec<f64>> = Vec::with_capacity(k1);
    let mut c: Vec<Vec<f64>> = Vec::with_capacity(k1);
    s.push(z[0].iter().map(|v| v.sin()).collect());
    c.push(z[0].iter().map(|v| v.cos()).collect());
    for k in 1..k1 {
        let mut sk = vec![0.0; m];
        let mut ck = vec![0.0; m];
        for e in 0..m {
            let mut sa = 0.0;
            let mut ca = 0.0;
            for j in 1..=k {
                let zj = j as f64 * z[j][e];
                sa += zj * c[k - j][e];
                ca += zj * s[k - j][e];
            }
            sk[e] = sa / k as f64;
            ck[e] = -ca / k as f64;
        }
        s.push(sk);
        c.push(ck);
    }
    (s, c)
}

/// Series tanh (the old `SeriesVec::tanh` body).
pub fn tanh(z: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut s: Vec<Vec<f64>> = Vec::with_capacity(k1);
    s.push(z[0].iter().map(|v| v.tanh()).collect());
    for k in 1..k1 {
        let mut out = vec![0.0; m];
        for e in 0..m {
            let mut acc = 0.0;
            for j in 1..=k {
                let mj = k - j;
                // u[mj] = delta_{mj,0} - (s*s)[mj], s[0..=mj] known
                let mut ssm = 0.0;
                for i in 0..=mj {
                    ssm += s[i][e] * s[mj - i][e];
                }
                let u = if mj == 0 { 1.0 - ssm } else { -ssm };
                acc += j as f64 * z[j][e] * u;
            }
            out[e] = acc / k as f64;
        }
        s.push(out);
    }
    s
}

/// Logistic sigmoid (the old `SeriesVec::sigmoid` body).
pub fn sigmoid(z: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let k1 = z.len();
    let m = z[0].len();
    let mut s: Vec<Vec<f64>> = Vec::with_capacity(k1);
    s.push(z[0].iter().map(|v| 1.0 / (1.0 + (-v).exp())).collect());
    for k in 1..k1 {
        let mut out = vec![0.0; m];
        for e in 0..m {
            let mut acc = 0.0;
            for j in 1..=k {
                let mj = k - j;
                // u[mj] = s[mj] - (s*s)[mj], s[0..=mj] known
                let mut ssm = 0.0;
                for i in 0..=mj {
                    ssm += s[i][e] * s[mj - i][e];
                }
                acc += j as f64 * z[j][e] * (s[mj][e] - ssm);
            }
            out[e] = acc / k as f64;
        }
        s.push(out);
    }
    s
}

/// One MLP layer, row-serial with per-access f32→f64 widening (the old
/// `Mlp` f32 hot-path inner loop): `out[r, j] = b[j] + Σ_i acts[r, i] ·
/// w[i, j]`, tanh on hidden layers.
pub fn mlp_layer(
    rows: usize,
    win: usize,
    wout: usize,
    acts: &[f64],
    w: &[f32],
    b: &[f32],
    tanh: bool,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(rows * wout);
    for r in 0..rows {
        let arow = &acts[r * win..(r + 1) * win];
        for j in 0..wout {
            // acc = b_j + sum_i act_i * W_ij, ascending i
            let mut acc = b[j] as f64;
            for (i, ai) in arow.iter().enumerate() {
                acc += ai * w[i * wout + j] as f64;
            }
            out.push(if tanh { acc.tanh() } else { acc });
        }
    }
    out
}

/// Stage combination as one full-length pass per stage (the old
/// `solvers::stage::accumulate` / `tensor::multi_axpy_into` sweep order):
/// `out = y`, then per stage j with `cⱼ = coeffs[j]·h ≠ 0`,
/// `out += cⱼ·kⱼ` over the whole vector.
pub fn multi_axpy<K: AsRef<[f32]>>(coeffs: &[f32], h: f32, ks: &[K], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(coeffs.len(), ks.len());
    out.copy_from_slice(y);
    for (j, aj) in coeffs.iter().enumerate() {
        let cj = *aj * h;
        if cj != 0.0 {
            for (o, xv) in out.iter_mut().zip(ks[j].as_ref()) {
                *o += cj * *xv;
            }
        }
    }
}

/// Zero-base variant (the old `accumulate_err` sweep order).
pub fn multi_axpy_zero<K: AsRef<[f32]>>(coeffs: &[f32], h: f32, ks: &[K], out: &mut [f32]) {
    debug_assert_eq!(coeffs.len(), ks.len());
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for (j, aj) in coeffs.iter().enumerate() {
        let cj = *aj * h;
        if cj != 0.0 {
            for (o, xv) in out.iter_mut().zip(ks[j].as_ref()) {
                *o += cj * *xv;
            }
        }
    }
}
