//! Blocked stage-combination and adjoint column kernels.
//!
//! The RK drivers combine stage derivatives as `y + h Σⱼ cⱼ·kⱼ`; the old
//! code made one full-length memory sweep per stage (S passes over an
//! n-vector that may not fit in L1).  [`fused_axpy_into`] makes ONE pass:
//! per [`BLOCK`](super::BLOCK)-wide chunk it seeds from `y` and folds every
//! stage in before moving on, so the destination chunk stays cache-hot
//! across all stages.  Per element the operation sequence is unchanged —
//! seed with `y[e]`, then `+= cⱼ·kⱼ[e]` in ascending stage order with
//! exactly the old `cⱼ = coeffs[j]·h ≠ 0` skip — so results are
//! bit-identical to the sequential sweeps (retained as
//! [`naive::multi_axpy`](super::naive::multi_axpy) and asserted in the
//! tests below and in `benches/perf_kernels.rs`).
//!
//! The f64 helpers below are the column primitives of the discrete
//! adjoint (`Tape::backward` arms, the stage-cotangent recursion in
//! `coordinator::train_native`): single-pass unit-stride maps whose loop
//! shapes the autovectorizer handles outright, centralized here so every
//! consumer shares one audited op order.

use super::BLOCK;

/// `out = y + h Σⱼ coeffs[j]·kⱼ` in one blocked pass; stages with
/// `coeffs[j]·h == 0` are skipped (the RK tableaus are sparse).
///
/// ```
/// use taynode::kern::axpy::fused_axpy_into;
/// let (k0, k1) = ([1.0f32, 2.0], [3.0f32, -1.0]);
/// let mut out = [0.0f32; 2];
/// fused_axpy_into(&[0.5, 1.0], 2.0, &[&k0[..], &k1[..]], &[10.0, 10.0], &mut out);
/// assert_eq!(out, [17.0, 10.0]);
/// ```
#[inline]
pub fn fused_axpy_into<K: AsRef<[f32]>>(
    coeffs: &[f32],
    h: f32,
    ks: &[K],
    y: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(coeffs.len(), ks.len());
    debug_assert_eq!(y.len(), out.len());
    let n = out.len();
    let mut e0 = 0;
    while e0 < n {
        let bl = BLOCK.min(n - e0);
        let dst = &mut out[e0..e0 + bl];
        dst.copy_from_slice(&y[e0..e0 + bl]);
        for (j, aj) in coeffs.iter().enumerate() {
            let cj = *aj * h;
            if cj != 0.0 {
                let kr = &ks[j].as_ref()[e0..e0 + bl];
                for (o, kv) in dst.iter_mut().zip(kr) {
                    *o += cj * *kv;
                }
            }
        }
        e0 += bl;
    }
}

/// `out = h Σⱼ coeffs[j]·kⱼ` (zero base) in one blocked pass — the error
/// estimate's combination.
#[inline]
pub fn fused_axpy_zero<K: AsRef<[f32]>>(coeffs: &[f32], h: f32, ks: &[K], out: &mut [f32]) {
    debug_assert_eq!(coeffs.len(), ks.len());
    let n = out.len();
    let mut e0 = 0;
    while e0 < n {
        let bl = BLOCK.min(n - e0);
        let dst = &mut out[e0..e0 + bl];
        for v in dst.iter_mut() {
            *v = 0.0;
        }
        for (j, aj) in coeffs.iter().enumerate() {
            let cj = *aj * h;
            if cj != 0.0 {
                let kr = &ks[j].as_ref()[e0..e0 + bl];
                for (o, kv) in dst.iter_mut().zip(kr) {
                    *o += cj * *kv;
                }
            }
        }
        e0 += bl;
    }
}

// -- f64 adjoint column primitives -------------------------------------------

/// `out[e] = c · x[e]` — the stage-cotangent seed `k̄ᵢ = h·bᵢ·ȳ`.
#[inline]
pub fn scale_into(c: f64, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, xv) in out.iter_mut().zip(x) {
        *o = c * *xv;
    }
}

/// `y[e] += c · x[e]` — the coupling fold `k̄ⱼ += h·aᵢⱼ·ūᵢ` (and, since
/// IEEE multiplication commutes bitwise on numeric values, the tape's
/// `Scale` arm `ā += ḡ·s`).
#[inline]
pub fn axpy_f64(c: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += c * *xv;
    }
}

/// `y[e] += x[e]` — cotangent accumulation (`ȳ += ū`, the tape's `Add`
/// arm and seed injection).
#[inline]
pub fn add_assign(x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += *xv;
    }
}

/// `y[e] -= x[e]` — the tape's `Sub` right-operand arm.
#[inline]
pub fn sub_assign(x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv -= *xv;
    }
}

/// `dst[e] += a[e] · b[e]` — the tape's `Mul` arm (`ā += ḡ ⊙ v_b`).
#[inline]
pub fn mul_acc(a: &[f64], b: &[f64], dst: &mut [f64]) {
    debug_assert_eq!(a.len(), dst.len());
    debug_assert_eq!(b.len(), dst.len());
    for ((d, av), bv) in dst.iter_mut().zip(a).zip(b) {
        *d += *av * *bv;
    }
}

/// Widen column j of a row-major f32 `[rows, w]` matrix into `out`
/// (`out[r] = src[r·w + j]`) — how the stage VJP lifts engine state into
/// tape inputs.
#[inline]
pub fn gather_col_f32(src: &[f32], w: usize, j: usize, out: &mut [f64]) {
    debug_assert!(j < w);
    debug_assert!(src.len() >= out.len() * w);
    for (r, o) in out.iter_mut().enumerate() {
        *o = src[r * w + j] as f64;
    }
}

/// Extract column j of a row-major f64 `[rows, w]` matrix into `out` —
/// the cotangent seed columns.
#[inline]
pub fn gather_col(src: &[f64], w: usize, j: usize, out: &mut [f64]) {
    debug_assert!(j < w);
    debug_assert!(src.len() >= out.len() * w);
    for (r, o) in out.iter_mut().enumerate() {
        *o = src[r * w + j];
    }
}

/// Scatter `vals` into column j of a row-major `[rows, w]` matrix —
/// writing per-column VJP results back into the interleaved cotangent.
#[inline]
pub fn scatter_col(vals: &[f64], w: usize, j: usize, dst: &mut [f64]) {
    debug_assert!(j < w);
    debug_assert!(dst.len() >= vals.len() * w);
    for (r, v) in vals.iter().enumerate() {
        dst[r * w + j] = *v;
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive;
    use super::*;
    use crate::util::ptest::gen;
    use crate::util::rng::Pcg;

    #[test]
    fn fused_pass_matches_sequential_sweeps_bit_for_bit() {
        // Lengths off the block width, sparse coefficient rows (zeros and
        // a -0.0, both skipped identically), h folded in: the one-pass
        // kernel must reproduce the old per-stage sweeps exactly.
        let mut rng = Pcg::new(0xA11);
        for &n in &[1usize, 3, 63, 64, 65, 257, 1000] {
            let ks: Vec<Vec<f32>> = (0..5).map(|_| gen::vec_f32(&mut rng, n, 2.0)).collect();
            let y = gen::vec_f32(&mut rng, n, 1.0);
            let coeffs = [0.25f32, 0.0, -0.75, -0.0, 1.5];
            for &h in &[0.1f32, 1.0, 0.0] {
                let mut want = vec![0.0f32; n];
                naive::multi_axpy(&coeffs, h, &ks, &y, &mut want);
                let mut got = vec![0.0f32; n];
                fused_axpy_into(&coeffs, h, &ks, &y, &mut got);
                for (e, (g, v)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), v.to_bits(), "n={n} h={h} elem {e}");
                }
                naive::multi_axpy_zero(&coeffs, h, &ks, &mut want);
                fused_axpy_zero(&coeffs, h, &ks, &mut got);
                for (e, (g, v)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), v.to_bits(), "zero n={n} h={h} elem {e}");
                }
            }
        }
    }

    #[test]
    fn column_primitives_shapes() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let mut col = [0.0f64; 3];
        gather_col_f32(&src, 2, 1, &mut col);
        assert_eq!(col, [2.0, 4.0, 6.0]);
        let srcd = [1.0f64, 2.0, 3.0, 4.0];
        gather_col(&srcd, 2, 0, &mut col[..2]);
        assert_eq!(&col[..2], &[1.0, 3.0]);
        let mut mat = [0.0f64; 4];
        scatter_col(&[7.0, 8.0], 2, 1, &mut mat);
        assert_eq!(mat, [0.0, 7.0, 0.0, 8.0]);
        let mut y = [1.0f64, 1.0];
        scale_into(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, [6.0, 8.0]);
        axpy_f64(0.5, &[2.0, 2.0], &mut y);
        assert_eq!(y, [7.0, 9.0]);
        add_assign(&[1.0, -1.0], &mut y);
        assert_eq!(y, [8.0, 8.0]);
        sub_assign(&[8.0, 0.0], &mut y);
        assert_eq!(y, [0.0, 8.0]);
        let mut d = [1.0f64, 1.0];
        mul_acc(&[2.0, 3.0], &[4.0, 5.0], &mut d);
        assert_eq!(d, [9.0, 16.0]);
    }
}
