//! Explicit Runge-Kutta Butcher tableaux, with embedded error weights where
//! a classical pair exists.  Coefficients are standard (Hairer-Norsett-Wanner
//! I; Dormand & Prince 1980; Bogacki & Shampine 1989; Fehlberg 1969;
//! Cash & Karp 1990) and are validated by order-exactness property tests in
//! `super::tests` (an order-m tableau must integrate polynomials of degree
//! <= m-1 exactly and show an h^m convergence rate).

#[derive(Clone, Debug)]
pub struct Tableau {
    pub name: &'static str,
    /// Classical order of the propagating solution.
    pub order: u32,
    pub stages: usize,
    /// Strictly-lower-triangular coupling coefficients; row i has i entries.
    pub a: Vec<Vec<f64>>,
    /// Solution weights.
    pub b: Vec<f64>,
    /// Error weights e = b - b_hat (None for fixed-step-only tableaux;
    /// adaptivity then falls back to step doubling).
    pub e: Option<Vec<f64>>,
    /// Stage abscissae.
    pub c: Vec<f64>,
    /// First-same-as-last: stage `stages-1` equals f at the accepted point.
    pub fsal: bool,
}

impl Tableau {
    pub fn validate(&self) {
        assert_eq!(self.a.len(), self.stages - 1, "{}", self.name);
        for (i, row) in self.a.iter().enumerate() {
            assert_eq!(row.len(), i + 1, "{} row {i}", self.name);
        }
        assert_eq!(self.b.len(), self.stages, "{}", self.name);
        assert_eq!(self.c.len(), self.stages, "{}", self.name);
        let bs: f64 = self.b.iter().sum();
        assert!((bs - 1.0).abs() < 1e-12, "{}: sum b = {bs}", self.name);
        for (i, row) in self.a.iter().enumerate() {
            let rs: f64 = row.iter().sum();
            assert!(
                (rs - self.c[i + 1]).abs() < 1e-9,
                "{}: row {i} sum {rs} != c {}",
                self.name,
                self.c[i + 1]
            );
        }
        if let Some(e) = &self.e {
            assert_eq!(e.len(), self.stages, "{}", self.name);
            // e = b - b_hat and both weight rows sum to 1 => sum e = 0.
            let es: f64 = e.iter().sum();
            assert!(es.abs() < 1e-10, "{}: sum e = {es}", self.name);
        }
    }
}

pub fn euler() -> Tableau {
    Tableau {
        name: "euler",
        order: 1,
        stages: 1,
        a: vec![],
        b: vec![1.0],
        e: None,
        c: vec![0.0],
        fsal: false,
    }
}

pub fn midpoint() -> Tableau {
    Tableau {
        name: "midpoint",
        order: 2,
        stages: 2,
        a: vec![vec![0.5]],
        b: vec![0.0, 1.0],
        e: None,
        c: vec![0.0, 0.5],
        fsal: false,
    }
}

pub fn ralston() -> Tableau {
    Tableau {
        name: "ralston",
        order: 2,
        stages: 2,
        a: vec![vec![2.0 / 3.0]],
        b: vec![0.25, 0.75],
        e: None,
        c: vec![0.0, 2.0 / 3.0],
        fsal: false,
    }
}

/// Heun-Euler 2(1) embedded pair — the lowest-order adaptive solver.
pub fn heun_euler() -> Tableau {
    Tableau {
        name: "heun_euler",
        order: 2,
        stages: 2,
        a: vec![vec![1.0]],
        b: vec![0.5, 0.5],
        e: Some(vec![-0.5, 0.5]), // b - [1, 0]
        c: vec![0.0, 1.0],
        fsal: false,
    }
}

/// Bogacki-Shampine 3(2), FSAL (ode23 / jax bosh3).
pub fn bosh3() -> Tableau {
    let b = [2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0];
    let bh = [7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125];
    Tableau {
        name: "bosh3",
        order: 3,
        stages: 4,
        a: vec![
            vec![0.5],
            vec![0.0, 0.75],
            vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
        ],
        b: b.to_vec(),
        e: Some(b.iter().zip(&bh).map(|(x, y)| x - y).collect()),
        c: vec![0.0, 0.5, 0.75, 1.0],
        fsal: true,
    }
}

/// The classical RK4.
pub fn rk4() -> Tableau {
    Tableau {
        name: "rk4",
        order: 4,
        stages: 4,
        a: vec![vec![0.5], vec![0.0, 0.5], vec![0.0, 0.0, 1.0]],
        b: vec![1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
        e: None,
        c: vec![0.0, 0.5, 0.5, 1.0],
        fsal: false,
    }
}

/// 3/8-rule fourth-order method (Kutta 1901).
pub fn rk38() -> Tableau {
    Tableau {
        name: "rk38",
        order: 4,
        stages: 4,
        a: vec![
            vec![1.0 / 3.0],
            vec![-1.0 / 3.0, 1.0],
            vec![1.0, -1.0, 1.0],
        ],
        b: vec![0.125, 0.375, 0.375, 0.125],
        e: None,
        c: vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0],
        fsal: false,
    }
}

/// Fehlberg 4(5): propagate the 4th-order solution, 5th-order error est.
pub fn fehlberg45() -> Tableau {
    let b4 = [
        25.0 / 216.0,
        0.0,
        1408.0 / 2565.0,
        2197.0 / 4104.0,
        -0.2,
        0.0,
    ];
    let b5 = [
        16.0 / 135.0,
        0.0,
        6656.0 / 12825.0,
        28561.0 / 56430.0,
        -9.0 / 50.0,
        2.0 / 55.0,
    ];
    Tableau {
        name: "fehlberg45",
        order: 4,
        stages: 6,
        a: vec![
            vec![0.25],
            vec![3.0 / 32.0, 9.0 / 32.0],
            vec![1932.0 / 2197.0, -7200.0 / 2197.0, 7296.0 / 2197.0],
            vec![439.0 / 216.0, -8.0, 3680.0 / 513.0, -845.0 / 4104.0],
            vec![
                -8.0 / 27.0,
                2.0,
                -3544.0 / 2565.0,
                1859.0 / 4104.0,
                -11.0 / 40.0,
            ],
        ],
        b: b4.to_vec(),
        e: Some(b4.iter().zip(&b5).map(|(x, y)| x - y).collect()),
        c: vec![0.0, 0.25, 0.375, 12.0 / 13.0, 1.0, 0.5],
        fsal: false,
    }
}

/// Cash-Karp 5(4).
pub fn cash_karp() -> Tableau {
    let b5 = [
        37.0 / 378.0,
        0.0,
        250.0 / 621.0,
        125.0 / 594.0,
        0.0,
        512.0 / 1771.0,
    ];
    let b4 = [
        2825.0 / 27648.0,
        0.0,
        18575.0 / 48384.0,
        13525.0 / 55296.0,
        277.0 / 14336.0,
        0.25,
    ];
    Tableau {
        name: "cash_karp",
        order: 5,
        stages: 6,
        a: vec![
            vec![0.2],
            vec![3.0 / 40.0, 9.0 / 40.0],
            vec![0.3, -0.9, 1.2],
            vec![-11.0 / 54.0, 2.5, -70.0 / 27.0, 35.0 / 27.0],
            vec![
                1631.0 / 55296.0,
                175.0 / 512.0,
                575.0 / 13824.0,
                44275.0 / 110592.0,
                253.0 / 4096.0,
            ],
        ],
        b: b5.to_vec(),
        e: Some(b5.iter().zip(&b4).map(|(x, y)| x - y).collect()),
        c: vec![0.0, 0.2, 0.3, 0.6, 1.0, 7.0 / 8.0],
        fsal: false,
    }
}

/// Dormand-Prince 5(4), FSAL — `dopri5`, the paper's default solver.
pub fn dopri5() -> Tableau {
    let b = [
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
        0.0,
    ];
    let bh = [
        5179.0 / 57600.0,
        0.0,
        7571.0 / 16695.0,
        393.0 / 640.0,
        -92097.0 / 339200.0,
        187.0 / 2100.0,
        1.0 / 40.0,
    ];
    Tableau {
        name: "dopri5",
        order: 5,
        stages: 7,
        a: vec![
            vec![0.2],
            vec![3.0 / 40.0, 9.0 / 40.0],
            vec![44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
            vec![
                19372.0 / 6561.0,
                -25360.0 / 2187.0,
                64448.0 / 6561.0,
                -212.0 / 729.0,
            ],
            vec![
                9017.0 / 3168.0,
                -355.0 / 33.0,
                46732.0 / 5247.0,
                49.0 / 176.0,
                -5103.0 / 18656.0,
            ],
            vec![
                35.0 / 384.0,
                0.0,
                500.0 / 1113.0,
                125.0 / 192.0,
                -2187.0 / 6784.0,
                11.0 / 84.0,
            ],
        ],
        b: b.to_vec(),
        e: Some(b.iter().zip(&bh).map(|(x, y)| x - y).collect()),
        c: vec![0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
        fsal: true,
    }
}

/// Look up a tableau by name (CLI / config surface).
pub fn by_name(name: &str) -> Option<Tableau> {
    Some(match name {
        "euler" => euler(),
        "midpoint" => midpoint(),
        "ralston" => ralston(),
        "heun_euler" | "heun" => heun_euler(),
        "bosh3" => bosh3(),
        "rk4" => rk4(),
        "rk38" => rk38(),
        "fehlberg45" | "rkf45" => fehlberg45(),
        "cash_karp" => cash_karp(),
        "dopri5" => dopri5(),
        _ => return None,
    })
}

pub const ALL: &[&str] = &[
    "euler", "midpoint", "ralston", "heun_euler", "bosh3", "rk4", "rk38",
    "fehlberg45", "cash_karp", "dopri5",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tableaux_validate() {
        for name in ALL {
            by_name(name).unwrap().validate();
        }
    }

    #[test]
    fn adaptive_pairs_have_error_weights() {
        for name in ["heun_euler", "bosh3", "fehlberg45", "cash_karp", "dopri5"] {
            assert!(by_name(name).unwrap().e.is_some(), "{name}");
        }
    }

    #[test]
    fn fsal_consistency() {
        // For FSAL tableaux the last row of `a` must equal b[..stages-1]
        // and c[last] must be 1.
        for name in ["bosh3", "dopri5"] {
            let t = by_name(name).unwrap();
            assert!(t.fsal);
            let last = &t.a[t.stages - 2];
            for (i, v) in last.iter().enumerate() {
                assert!((v - t.b[i]).abs() < 1e-12, "{name} col {i}");
            }
            assert_eq!(t.c[t.stages - 1], 1.0);
        }
    }

    #[test]
    fn unknown_name_none() {
        assert!(by_name("tsit99").is_none());
    }
}
