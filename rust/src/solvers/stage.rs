//! Shared explicit-RK stage machinery.
//!
//! Everything arithmetic about taking one embedded RK step lives here —
//! f32-cast tableau coefficients, stage-state accumulation, solution/error
//! combination, the scaled RMS error norm, the PI controller factors, and
//! the two halves of Hairer's automatic initial-step heuristic.  The scalar
//! drivers (`super::adaptive`, `super::fixed`) and the batched engine
//! (`super::batch`) call the *same* functions in the *same* order, so a
//! batched trajectory reproduces the scalar solve bit-for-bit — a property
//! verified exhaustively in `super::tests`.

use super::adaptive::AdaptiveOpts;
use super::tableau::Tableau;
use crate::kern::axpy::{fused_axpy_into, fused_axpy_zero};

/// Tableau coefficients cast to f32 once per solve, so the per-step hot loop
/// performs no `as` casts and allocates nothing (the seed code built a fresh
/// coefficient `Vec` per stage per step).
pub struct TableauCoeffs {
    pub stages: usize,
    pub order: u32,
    pub fsal: bool,
    /// Strictly-lower-triangular coupling rows; row i has i+1 entries.
    pub a: Vec<Vec<f32>>,
    /// Solution weights.
    pub b: Vec<f32>,
    /// Embedded error weights; empty when the tableau has no pair.
    pub e: Vec<f32>,
    /// Stage abscissae.
    pub c: Vec<f32>,
}

impl TableauCoeffs {
    pub fn new(tb: &Tableau) -> TableauCoeffs {
        TableauCoeffs {
            stages: tb.stages,
            order: tb.order,
            fsal: tb.fsal,
            a: tb
                .a
                .iter()
                .map(|row| row.iter().map(|x| *x as f32).collect())
                .collect(),
            b: tb.b.iter().map(|x| *x as f32).collect(),
            e: tb
                .e
                .as_ref()
                .map(|e| e.iter().map(|x| *x as f32).collect())
                .unwrap_or_default(),
            c: tb.c.iter().map(|x| *x as f32).collect(),
        }
    }

    pub fn has_embedded(&self) -> bool {
        !self.e.is_empty()
    }

    /// 1 / (order + 1), the error-exponent the controller uses.
    pub fn inv_order(&self) -> f32 {
        1.0 / (self.order as f32 + 1.0)
    }
}

/// ystage = y + h * Σ_j a_row[j] · k_j, zero coefficients skipped, stages
/// applied in order — one blocked pass over the state via
/// [`crate::kern::axpy::fused_axpy_into`], bit-identical per element to
/// the old one-sweep-per-stage order (the kernel's retained naive
/// reference).  The batched engine applies this same per-row op sequence
/// to row slices of its per-stage matrices
/// (`batch::solve_embedded_batch`); the bit-level equivalence property
/// tests keep the two in lockstep.
#[inline]
pub fn accumulate<K: AsRef<[f32]>>(a_row: &[f32], h: f32, ks: &[K], y: &[f32], out: &mut [f32]) {
    fused_axpy_into(a_row, h, ks, y, out);
}

/// errv = h * Σ_j e[j] · k_j (zero base, zero coefficients skipped), one
/// blocked pass.
#[inline]
pub fn accumulate_err<K: AsRef<[f32]>>(e: &[f32], h: f32, ks: &[K], errv: &mut [f32]) {
    fused_axpy_zero(e, h, ks, errv);
}

/// Scaled RMS error norm (Hairer eq. II.4.11).
///
/// A zero-length state has nothing to control: return 0 ("always accept")
/// instead of the seed's 0/0 = NaN, which poisoned every comparison in the
/// controller (NaN ≤ 1 is false, so each step was rejected until the
/// step-size floor forced a blind accept).
pub fn error_norm(err: &[f32], y0: &[f32], y1: &[f32], atol: f32, rtol: f32) -> f32 {
    if err.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for i in 0..err.len() {
        let sc = atol + rtol * y0[i].abs().max(y1[i].abs());
        let r = (err[i] / sc) as f64;
        acc += r * r;
    }
    ((acc / err.len() as f64) as f32).sqrt()
}

/// PI-controller growth factor after an accepted step (unclamped).
#[inline]
pub fn accept_factor(opts: &AdaptiveOpts, inv_order: f32, errc: f32, prev_err: f32) -> f32 {
    opts.safety * errc.powf(-inv_order + opts.pi_beta) * prev_err.powf(opts.pi_beta)
}

/// Shrink factor after a rejected step (unclamped; caller clamps to ≤ 1).
#[inline]
pub fn reject_factor(opts: &AdaptiveOpts, inv_order: f32, err: f32) -> f32 {
    opts.safety * err.powf(-inv_order)
}

/// First half of Hairer's automatic initial step (II.4 "starting step
/// size"): a crude h0 from ‖y0‖ and ‖f0‖.  The caller then takes one Euler
/// probe step of size h0, evaluates f there (one NFE), and feeds the result
/// to [`h1_estimate`].
pub fn h0_estimate(y0: &[f32], f0: &[f32], atol: f32, rtol: f32) -> f32 {
    let n = y0.len();
    if n == 0 {
        return 1e-6;
    }
    let d0 = (y0
        .iter()
        .map(|y| {
            let s = atol + rtol * y.abs();
            ((y / s) as f64).powi(2)
        })
        .sum::<f64>()
        / n as f64)
        .sqrt();
    let d1 = (f0
        .iter()
        .zip(y0)
        .map(|(g, y)| {
            let s = atol + rtol * y.abs();
            ((g / s) as f64).powi(2)
        })
        .sum::<f64>()
        / n as f64)
        .sqrt();
    if d0 < 1e-5 || d1 < 1e-5 {
        1e-6
    } else {
        0.01 * (d0 / d1) as f32
    }
}

/// Second half of the starting-step heuristic: refine h0 with the probe
/// derivative `f1` evaluated at t0 + h0 on y0 + h0·f0.
pub fn h1_estimate(
    y0: &[f32],
    f0: &[f32],
    f1: &[f32],
    h0: f32,
    order: u32,
    atol: f32,
    rtol: f32,
) -> f32 {
    let n = y0.len();
    if n == 0 {
        return (100.0 * h0).min((h0 * 1e-3).max(1e-6));
    }
    let d1 = (f0
        .iter()
        .zip(y0)
        .map(|(g, y)| {
            let s = atol + rtol * y.abs();
            ((g / s) as f64).powi(2)
        })
        .sum::<f64>()
        / n as f64)
        .sqrt();
    let d2 = (f1
        .iter()
        .zip(f0)
        .zip(y0)
        .map(|((a, b), y)| {
            let s = atol + rtol * y.abs();
            (((a - b) / s) as f64).powi(2)
        })
        .sum::<f64>()
        / n as f64)
        .sqrt() as f32
        / h0;
    let h1 = if d1.max(d2 as f64) <= 1e-15 {
        (h0 * 1e-3).max(1e-6)
    } else {
        (0.01 / d1.max(d2 as f64) as f32).powf(1.0 / (order as f32 + 1.0))
    };
    (100.0 * h0).min(h1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::tableau;

    #[test]
    fn coeffs_match_tableau_casts() {
        for name in tableau::ALL {
            let tb = tableau::by_name(name).unwrap();
            let tc = TableauCoeffs::new(&tb);
            assert_eq!(tc.stages, tb.stages);
            for (i, row) in tb.a.iter().enumerate() {
                for (j, v) in row.iter().enumerate() {
                    assert_eq!(tc.a[i][j], *v as f32, "{name} a[{i}][{j}]");
                }
            }
            for (j, v) in tb.b.iter().enumerate() {
                assert_eq!(tc.b[j], *v as f32, "{name} b[{j}]");
            }
            assert_eq!(tc.has_embedded(), tb.e.is_some(), "{name}");
        }
    }

    #[test]
    fn error_norm_empty_state_is_zero_not_nan() {
        let e = error_norm(&[], &[], &[], 1e-8, 1e-6);
        assert_eq!(e, 0.0);
        assert!(!e.is_nan());
    }

    #[test]
    fn error_norm_scales_like_rms() {
        // err = atol everywhere, y = 0: each scaled residual is exactly 1.
        let err = [1e-6f32; 4];
        let y = [0.0f32; 4];
        let e = error_norm(&err, &y, &y, 1e-6, 1e-3);
        assert!((e - 1.0).abs() < 1e-6, "{e}");
    }

    #[test]
    fn accumulate_matches_multi_axpy() {
        use crate::tensor::multi_axpy_into;
        let k0 = [1.0f32, 2.0];
        let k1 = [3.0f32, -1.0];
        let y = [0.5f32, 0.5];
        let a_row = [0.25f32, 0.75];
        let h = 0.1f32;
        let mut want = [0.0f32; 2];
        let coeffs: Vec<f32> = a_row.iter().map(|a| a * h).collect();
        multi_axpy_into(&coeffs, &[&k0, &k1], &y, &mut want);
        let mut got = [0.0f32; 2];
        accumulate(&a_row, h, &[&k0, &k1], &y, &mut got);
        assert_eq!(got, want);
    }
}
