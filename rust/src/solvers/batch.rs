//! Batched multi-trajectory RK engine — the serving-path primitive.
//!
//! Integrates B independent ODE systems in one pass over an SoA state
//! matrix `[B, n]`.  A [`BatchDynamics`] is evaluated **once per stage for
//! the whole active batch** instead of once per trajectory, which is where
//! the throughput comes from when one model evaluation has fixed dispatch
//! cost (an XLA executable launch, a GPU kernel, a closure call).
//!
//! Each trajectory keeps its own adaptive step size, PI-controller history,
//! and NFE/accepted/rejected counters; **finished trajectories are swapped
//! out of the working set** (active-set compaction) so stragglers don't pay
//! for the whole batch.  All per-trajectory arrays live in one
//! `WorkingSet` whose `retire` method is the *only* compaction point, so
//! adding a per-row field cannot silently skip compaction.  The
//! per-trajectory arithmetic is the shared stage machinery of
//! [`super::stage`], applied in the same operation order as the scalar
//! driver — a batched trajectory therefore reproduces
//! [`super::adaptive::solve_adaptive`] **bit-for-bit** in state, NFE,
//! accepted and rejected counts (property-tested below).
//!
//! Tableaux without an embedded pair fall back to per-trajectory scalar
//! step-doubling solves (still through the same entry points, still
//! per-trajectory stats), since step doubling re-enters the fixed driver
//! and cannot share stage evaluations across rows with distinct h.
//!
//! Every driver also has a `_pooled` variant that splits the batch into
//! contiguous row chunks ([`chunk_ranges`]: several per worker) claimed
//! dynamically from the [`Pool`](crate::util::pool::Pool)'s atomic queue —
//! each chunk runs the full driver with its own active set, step control,
//! and RK scratch on a clone of the dynamics, and the per-trajectory
//! results merge back in stable trajectory order.  Oversubscribing the
//! workers is what absorbs skewed per-trajectory costs: a straggler-heavy
//! chunk tails on one worker while the rest drain the queue.  Because no
//! arithmetic ever crosses rows and the merge is by stable id, the pooled
//! results are **bit-identical to the serial driver at every thread count**
//! (property-tested below).  Sharding is for natively-vectorized in-process
//! dynamics; dynamics with a fixed per-launch dispatch cost (an XLA
//! executable) lose launch amortization when split and should stay on the
//! serial entry points.
//!
//! [`RegularizedBatchDynamics`] closes the loop with the paper: it lifts a
//! series-generic vector field ([`BatchSeriesDynamics`]) into an augmented
//! system whose extra column integrates the regularizer
//! `R_K = ∫ ‖d^K y/dt^K‖²/n dt`, with the K-th total derivatives computed
//! by [`taylor::ode_jet_batch`](crate::taylor::ode_jet_batch) for the whole
//! active set at once.
//!
//! ```
//! use taynode::solvers::batch::{solve_adaptive_batch, Rowwise};
//! use taynode::solvers::{tableau, AdaptiveOpts};
//!
//! // Two independent trajectories of dy/dt = -y, solved in one batch.
//! let res = solve_adaptive_batch(
//!     Rowwise::new(|_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = -y[0], 1),
//!     0.0,
//!     1.0,
//!     &[1.0, 2.0],
//!     &tableau::dopri5(),
//!     &AdaptiveOpts::default(),
//! );
//! let e1 = (-1.0f32).exp();
//! assert!((res.row(0)[0] - e1).abs() < 1e-3);
//! assert!((res.row(1)[0] - 2.0 * e1).abs() < 1e-3);
//! assert!(res.nfes().iter().all(|nfe| *nfe > 0));
//! ```

use super::adaptive::{solve_adaptive_mut, AdaptiveOpts, SolveStats};
use super::stage::{self, TableauCoeffs};
use super::tableau::Tableau;
use super::Dynamics;
use crate::autodiff::div::{batch_divergence, Divergence};
use crate::nn::ValueDynamics;
use crate::obs::{Counter, Hist, Recorder};
use crate::taylor::{ode_jet_batch, BatchSeriesDynamics};
use crate::tensor::axpy;
use crate::util::pool::{chunk_ranges, Pool};

/// Dynamics over a batch of trajectories: `dy[r] = f(t[r], y[r])` for every
/// active row r, where `y` and `dy` are row-major `[t.len(), dim()]`.
/// Implementations see one call per RK stage for the whole active set; rows
/// carry *per-trajectory* times because adaptive trajectories decouple.
///
/// `ids[r]` is the **original trajectory index** of row r.  The engine
/// compacts finished trajectories out of the working set, so row position
/// is not stable — models with per-trajectory conditioning (per-request
/// parameters, per-seed coefficients) must key on `ids`, never on r.
pub trait BatchDynamics {
    /// Per-trajectory state dimension n (must be positive).
    fn dim(&self) -> usize;
    /// Evaluate all rows: `t.len()` trajectories, `y`/`dy` of `t.len() * dim()`.
    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]);
}

/// A `&mut` reference is itself a [`BatchDynamics`]: drivers that take
/// ownership (the [`BatchStepper`], the serving engine) can be driven off a
/// borrow without cloning the model.
impl<F: BatchDynamics + ?Sized> BatchDynamics for &mut F {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        (**self).eval(ids, t, y, dy)
    }
}

/// Adapter: drive a scalar [`Dynamics`] once per row.  This is how
/// per-example XLA executables (batch-1 artifacts) and test closures plug
/// into the batched engine; a native vectorized model should implement
/// [`BatchDynamics`] directly (see [`BatchFn`]).
pub struct Rowwise<F> {
    f: F,
    n: usize,
}

impl<F: Dynamics> Rowwise<F> {
    pub fn new(f: F, n: usize) -> Rowwise<F> {
        assert!(n > 0, "Rowwise: state dimension must be positive");
        Rowwise { f, n }
    }

    /// Recover the wrapped dynamics (e.g. to read eval counters).
    pub fn into_inner(self) -> F {
        self.f
    }
}

impl<F: Dynamics> BatchDynamics for Rowwise<F> {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let n = self.n;
        debug_assert_eq!(y.len(), t.len() * n);
        debug_assert_eq!(dy.len(), t.len() * n);
        for (r, tr) in t.iter().enumerate() {
            self.f
                .eval(*tr, &y[r * n..(r + 1) * n], &mut dy[r * n..(r + 1) * n]);
        }
    }
}

/// Adapter: a natively-vectorized batch closure `(ids, t_per_row, Y, dY)`
/// plus its row dimension.  The closure receives the engine's stable
/// trajectory ids so per-trajectory-conditioned models can key their
/// parameters correctly under compaction (row position is NOT stable).
pub struct BatchFn<F> {
    f: F,
    n: usize,
}

impl<F: FnMut(&[usize], &[f32], &[f32], &mut [f32])> BatchFn<F> {
    pub fn new(n: usize, f: F) -> BatchFn<F> {
        assert!(n > 0, "BatchFn: state dimension must be positive");
        BatchFn { f, n }
    }
}

impl<F: FnMut(&[usize], &[f32], &[f32], &mut [f32])> BatchDynamics for BatchFn<F> {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        (self.f)(ids, t, y, dy)
    }
}

/// View one trajectory of a [`BatchDynamics`] as a scalar [`Dynamics`]
/// (used by the step-doubling fallback).
struct OneRow<'a, F: BatchDynamics> {
    f: &'a mut F,
    id: usize,
}

impl<F: BatchDynamics> Dynamics for OneRow<'_, F> {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]) {
        self.f.eval(&[self.id], &[t], y, dy);
    }
}

// ---------------------------------------------------------------------------
// Native R_K: quadrature-augmented dynamics over batched Taylor jets
// ---------------------------------------------------------------------------

/// Append `extra` zero-initialized columns to a row-major `[B, n]` state,
/// producing the `[B, n + extra]` augmented state the quadrature/log-det
/// adapters integrate.
pub fn augment_cols(y0: &[f32], n: usize, extra: usize) -> Vec<f32> {
    assert!(n > 0, "augment_cols: dim must be positive");
    assert_eq!(y0.len() % n, 0, "augment_cols: state length vs dim");
    let b = y0.len() / n;
    let mut out = Vec::with_capacity(b * (n + extra));
    for r in 0..b {
        out.extend_from_slice(&y0[r * n..(r + 1) * n]);
        for _ in 0..extra {
            out.push(0.0);
        }
    }
    out
}

/// Append one zero-initialized quadrature column to a row-major `[B, n]`
/// state, producing the `[B, n + 1]` augmented state a
/// [`RegularizedBatchDynamics`] integrates.
pub fn augment_quadrature(y0: &[f32], n: usize) -> Vec<f32> {
    augment_cols(y0, n, 1)
}

/// Split an augmented result `[B, n + extra]` into the plain `[B, n]`
/// states and one `[B]` vector per augmented column (for
/// [`LogDetBatchDynamics`]: the log-determinant, then the `R_K` quadrature
/// when the adapter carries one).
pub fn split_aug_cols(res: &BatchResult, n: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
    let w = res.n;
    assert!(w > n, "split_aug_cols needs an augmented [B, n + extra] result");
    let b = res.batch();
    let mut y = Vec::with_capacity(b * n);
    // (vec![..; k] would clone away the capacity)
    let mut cols: Vec<Vec<f32>> = (0..w - n).map(|_| Vec::with_capacity(b)).collect();
    for r in 0..b {
        let row = res.row(r);
        y.extend_from_slice(&row[..n]);
        for (k, c) in cols.iter_mut().enumerate() {
            c.push(row[n + k]);
        }
    }
    (y, cols)
}

/// Split the result of a quadrature-augmented solve back into the plain
/// `[B, n]` final states and the per-trajectory quadrature values
/// (`R_K` when the augmented system came from [`RegularizedBatchDynamics`]).
pub fn split_quadrature(res: &BatchResult) -> (Vec<f32>, Vec<f32>) {
    let w = res.n;
    assert!(w >= 2, "split_quadrature needs an augmented [B, n + 1] result");
    let n = w - 1;
    let b = res.batch();
    let mut y = Vec::with_capacity(b * n);
    let mut q = Vec::with_capacity(b);
    for r in 0..b {
        let row = res.row(r);
        y.extend_from_slice(&row[..n]);
        q.push(row[n]);
    }
    (y, q)
}

/// Adapter that turns a series-generic vector field into a
/// [`BatchDynamics`] over the augmented state `[y, r]` with
/// `dr/dt = ‖d^K y/dt^K‖² / n` — so an ordinary batched adaptive solve
/// integrates the paper's regularizer `R_K` (eq. 1, dimension-normalized as
/// in Appendix B) alongside the trajectories, for the whole active set per
/// evaluation.
///
/// Every solver NFE spends one [`ode_jet_batch`] sweep (= `K` series
/// evaluations of the inner field, batched over all active rows): the jet's
/// first derivative matrix *is* `f(t, y)`, so the state derivatives and the
/// regularizer integrand come out of the same sweep.  Per-row results are
/// bit-identical to a scalar augmented solve built on the scalar
/// [`ode_jet`](crate::taylor::ode_jet) (tested below).
#[derive(Clone)]
pub struct RegularizedBatchDynamics<F> {
    inner: F,
    order: usize,
    // f64 staging for the jet sweep, reused across evaluations
    z0: Vec<f64>,
    t0: Vec<f64>,
}

impl<F: BatchSeriesDynamics> RegularizedBatchDynamics<F> {
    /// Wrap `inner` to integrate `R_order` (order = the paper's K, ≥ 1).
    pub fn new(inner: F, order: usize) -> RegularizedBatchDynamics<F> {
        assert!(order >= 1, "RegularizedBatchDynamics: R_K needs K >= 1");
        assert!(inner.dim() > 0, "RegularizedBatchDynamics: dim must be positive");
        RegularizedBatchDynamics { inner, order, z0: vec![], t0: vec![] }
    }

    /// The un-augmented per-trajectory state dimension.
    pub fn state_dim(&self) -> usize {
        self.inner.dim()
    }

    /// The regularization order K.
    pub fn order(&self) -> usize {
        self.order
    }

    /// Build the `[B, n + 1]` augmented initial state for this adapter.
    pub fn augment(&self, y0: &[f32]) -> Vec<f32> {
        augment_quadrature(y0, self.inner.dim())
    }

    pub fn into_inner(self) -> F {
        self.inner
    }
}

impl<F: BatchSeriesDynamics> BatchDynamics for RegularizedBatchDynamics<F> {
    fn dim(&self) -> usize {
        self.inner.dim() + 1
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let n = self.inner.dim();
        let w = n + 1;
        let m = t.len();
        self.z0.clear();
        self.t0.clear();
        for (r, tr) in t.iter().enumerate() {
            self.t0.push(*tr as f64);
            for i in 0..n {
                self.z0.push(y[r * w + i] as f64);
            }
        }
        let jets = ode_jet_batch(&mut self.inner, ids, &self.z0, &self.t0, self.order);
        let x1 = &jets[0];
        let xk = &jets[self.order - 1];
        for r in 0..m {
            for i in 0..n {
                dy[r * w + i] = x1[r * n + i] as f32;
            }
            let mut sq = 0.0f64;
            for i in 0..n {
                let v = xk[r * n + i];
                sq += v * v;
            }
            dy[r * w + n] = (sq / n as f64) as f32;
        }
    }
}

// ---------------------------------------------------------------------------
// Native CNF: log-det (+ R_K) augmented dynamics over the divergence engine
// ---------------------------------------------------------------------------

/// Adapter that turns a divergence-capable vector field into a
/// [`BatchDynamics`] over the augmented state `[z, ℓ]` with
/// `dℓ/dt = ∇·f(z, t)` — the instantaneous change-of-variables term of a
/// continuous normalizing flow, so one ordinary batched solve yields final
/// states *and* per-trajectory log-determinants
/// (`log p(z(0)) = log p_base(z(1)) + ℓ(1)` when integrating data → base
/// over `t ∈ [0, 1]`).
///
/// Mirrors [`RegularizedBatchDynamics`] and **composes** with it:
/// [`with_regularizer`](LogDetBatchDynamics::with_regularizer) adds the
/// `R_K` quadrature as a third block, `dq/dt = ‖d^K z/dt^K‖²/n` over
/// batched Taylor jets of the *state* dynamics, so a single augmented solve
/// yields dy, log-det, and `R_K` (the log-det column is a function of z
/// alone and feeds nothing back, so the state jets are self-contained).
///
/// Per solver NFE the adapter spends one tape recording of the inner
/// forward plus the trace sweeps of its [`Divergence`] mode (n backward
/// sweeps exact, one per Hutchinson probe), and — when regularizing — one
/// [`ode_jet_batch`] sweep.  Hutchinson probes are keyed on trajectory ids,
/// so pooled and serial solves are bit-identical in every mode (tested
/// below).
#[derive(Clone)]
pub struct LogDetBatchDynamics<F> {
    inner: F,
    div: Divergence,
    reg_order: Option<usize>,
    // f64 staging for the divergence/jet sweeps, reused across evaluations
    z0: Vec<f64>,
    t0: Vec<f64>,
}

impl<F: ValueDynamics + BatchSeriesDynamics> LogDetBatchDynamics<F> {
    /// Wrap `inner` to integrate its divergence alongside the state.
    pub fn new(inner: F, div: Divergence) -> LogDetBatchDynamics<F> {
        assert!(
            ValueDynamics::dim(&inner) > 0,
            "LogDetBatchDynamics: dim must be positive"
        );
        assert_eq!(
            ValueDynamics::dim(&inner),
            BatchSeriesDynamics::dim(&inner),
            "LogDetBatchDynamics: inner trait dims disagree"
        );
        LogDetBatchDynamics { inner, div, reg_order: None, z0: vec![], t0: vec![] }
    }

    /// Also integrate `R_order` (the paper's K ≥ 1) as a third state block.
    pub fn with_regularizer(mut self, order: usize) -> LogDetBatchDynamics<F> {
        assert!(order >= 1, "LogDetBatchDynamics: R_K needs K >= 1");
        self.reg_order = Some(order);
        self
    }

    /// The un-augmented per-trajectory state dimension.
    pub fn state_dim(&self) -> usize {
        ValueDynamics::dim(&self.inner)
    }

    /// Augmented columns beyond the state: ℓ, plus q when regularizing.
    fn extra(&self) -> usize {
        1 + usize::from(self.reg_order.is_some())
    }

    /// Build the `[B, n + 1]` (or `[B, n + 2]`) augmented initial state.
    pub fn augment(&self, y0: &[f32]) -> Vec<f32> {
        augment_cols(y0, self.state_dim(), self.extra())
    }

    pub fn into_inner(self) -> F {
        self.inner
    }
}

impl<F: ValueDynamics + BatchSeriesDynamics> BatchDynamics for LogDetBatchDynamics<F> {
    fn dim(&self) -> usize {
        self.state_dim() + self.extra()
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let n = self.state_dim();
        let w = n + self.extra();
        let m = t.len();
        self.z0.clear();
        self.t0.clear();
        for (r, tr) in t.iter().enumerate() {
            self.t0.push(*tr as f64);
            for i in 0..n {
                self.z0.push(y[r * w + i] as f64);
            }
        }
        // The tape inside batch_divergence is rebuilt per evaluation: a
        // cached Tape is Rc-backed (!Send) and would disqualify this
        // adapter from the pooled drivers' Clone + Send bounds.
        let (f0, div) = batch_divergence(&self.inner, ids, &self.t0, &self.z0, &self.div);
        for r in 0..m {
            for i in 0..n {
                dy[r * w + i] = f0[r * n + i] as f32;
            }
            dy[r * w + n] = div[r] as f32;
        }
        if let Some(order) = self.reg_order {
            let jets = ode_jet_batch(&mut self.inner, ids, &self.z0, &self.t0, order);
            let xk = &jets[order - 1];
            for r in 0..m {
                let mut sq = 0.0f64;
                for i in 0..n {
                    let v = xk[r * n + i];
                    sq += v * v;
                }
                dy[r * w + n + 1] = (sq / n as f64) as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Results and the per-trajectory working set
// ---------------------------------------------------------------------------

/// Result of a batched solve, in the caller's original trajectory order
/// (compaction is internal and never observable).
#[derive(Clone, Debug)]
pub struct BatchResult {
    /// Per-trajectory state dimension.
    pub n: usize,
    /// Final states, row-major `[B, n]`.
    pub y: Vec<f32>,
    /// Final integration time per trajectory.
    pub t: Vec<f32>,
    /// Per-trajectory solver statistics.
    pub stats: Vec<SolveStats>,
}

impl BatchResult {
    pub fn batch(&self) -> usize {
        self.stats.len()
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.y[r * self.n..(r + 1) * self.n]
    }

    /// Per-trajectory NFE — the paper's headline metric, per example.
    pub fn nfes(&self) -> Vec<usize> {
        self.stats.iter().map(|s| s.nfe).collect()
    }
}

/// A trajectory handed back by the stepping driver when it leaves the
/// active set: it reached its `t1`, exhausted its step budget, or was dead
/// on arrival (`t0 == t1`).  `id` is the stable trajectory id the row was
/// admitted under — never a slot position.
#[derive(Clone, Debug)]
pub struct Retired {
    /// Stable trajectory id (as passed to [`BatchStepper::admit`]).
    pub id: usize,
    /// Final state, `dim()` entries.
    pub y: Vec<f32>,
    /// Final integration time.
    pub t: f32,
    /// Per-trajectory solver statistics.
    pub stats: SolveStats,
}

/// The embedded driver's per-trajectory state, bundled so compaction is
/// exhaustive **by construction**: every parallel per-row array lives here,
/// and [`WorkingSet::retire`] is the single place rows move.  A new per-row
/// field (a jet cache, a quadrature accumulator, a deadline) is added to
/// this struct and compacted in `retire`, or it does not exist — it cannot
/// be threaded past the compaction point as a forgotten loose argument.
///
/// Slot `s < act` holds a live trajectory; `idx[s]` is its stable id.  Rows
/// are *admitted* (appended to the active prefix) and *retired* (extracted
/// and back-filled with the last active row), so the set can both drain and
/// grow between attempts — this is what the serving engine's continuous
/// batching rides on.  Each row carries its own solve target (`t1`,
/// direction, step cap) and [`AdaptiveOpts`], so trajectories with
/// different tolerance classes share one stage loop.
struct WorkingSet {
    n: usize,
    /// Active prefix length: slots `0..act` are live.
    act: usize,
    idx: Vec<usize>,
    t: Vec<f32>,
    /// Per-row integration target.
    t1: Vec<f32>,
    /// Per-row integration direction: `(t1 - t0).signum()`.
    sg: Vec<f32>,
    /// Per-row step-size cap: `opts.h_max` or the row's own span.
    hcap: Vec<f32>,
    h: Vec<f32>,
    prev_err: Vec<f32>,
    stats: Vec<SolveStats>,
    /// Per-row solve options (tolerance class, controller constants).
    opts: Vec<AdaptiveOpts>,
    /// Row-major `[act, n]` working states.
    y: Vec<f32>,
    /// One `[act, n]` matrix per RK stage.
    ks: Vec<Vec<f32>>,
}

impl WorkingSet {
    fn new(n: usize, stages: usize) -> WorkingSet {
        WorkingSet {
            n,
            act: 0,
            idx: Vec::new(),
            t: Vec::new(),
            t1: Vec::new(),
            sg: Vec::new(),
            hcap: Vec::new(),
            h: Vec::new(),
            prev_err: Vec::new(),
            stats: Vec::new(),
            opts: Vec::new(),
            y: Vec::new(),
            ks: (0..stages).map(|_| Vec::new()).collect(),
        }
    }

    /// Append `ids.len()` new live rows after the current active prefix.
    /// Step sizes and stage-0 derivatives are left for the stepper to fill
    /// (they cost model evaluations).
    fn push_rows(&mut self, ids: &[usize], y0: &[f32], t0: f32, t1: f32, opts: &AdaptiveOpts) {
        let n = self.n;
        let k = ids.len();
        let hcap = opts.h_max.unwrap_or((t1 - t0).abs());
        let sg = (t1 - t0).signum();
        // Vectors may still hold stale tails from earlier retirements; the
        // live prefix is `act`, so truncate before appending.
        self.idx.truncate(self.act);
        self.t.truncate(self.act);
        self.t1.truncate(self.act);
        self.sg.truncate(self.act);
        self.hcap.truncate(self.act);
        self.h.truncate(self.act);
        self.prev_err.truncate(self.act);
        self.stats.truncate(self.act);
        self.opts.truncate(self.act);
        self.y.truncate(self.act * n);
        self.idx.extend_from_slice(ids);
        self.t.resize(self.act + k, t0);
        self.t1.resize(self.act + k, t1);
        self.sg.resize(self.act + k, sg);
        self.hcap.resize(self.act + k, hcap);
        self.h.resize(self.act + k, 0.0);
        self.prev_err.resize(self.act + k, 1.0); // neutral PI history
        self.stats.resize(self.act + k, SolveStats::default());
        self.opts.resize(self.act + k, opts.clone());
        self.y.extend_from_slice(y0);
        for ks in &mut self.ks {
            ks.truncate(self.act * n);
            ks.resize((self.act + k) * n, 0.0);
        }
        self.act += k;
    }

    /// Extract finished trajectories (in `finished` order) and compact the
    /// active prefix by moving the last active row into each vacated slot.
    /// `finished` must be ascending slot indices from the current attempt.
    fn retire(&mut self, finished: &[usize]) -> Vec<Retired> {
        let n = self.n;
        let mut out = Vec::with_capacity(finished.len());
        for &s in finished {
            let mut st = self.stats[s].clone();
            st.h_final = self.h[s];
            out.push(Retired {
                id: self.idx[s],
                y: self.y[s * n..(s + 1) * n].to_vec(),
                t: self.t[s],
                stats: st,
            });
        }
        // Descending order: every slot above the one being filled is already
        // retired, so the last active row is always a live trajectory.
        for &s in finished.iter().rev() {
            self.act -= 1;
            let last = self.act;
            if s != last {
                {
                    let (head, tail) = self.y.split_at_mut(last * n);
                    head[s * n..(s + 1) * n].copy_from_slice(&tail[..n]);
                }
                // Only stage 0 survives across attempts (FSAL / refresh);
                // the other stage matrices are rewritten from scratch before
                // every read, so compacting them would be wasted memcpy.
                {
                    let k0 = &mut self.ks[0];
                    let (kh, kt) = k0.split_at_mut(last * n);
                    kh[s * n..(s + 1) * n].copy_from_slice(&kt[..n]);
                }
                self.t[s] = self.t[last];
                self.t1[s] = self.t1[last];
                self.sg[s] = self.sg[last];
                self.hcap[s] = self.hcap[last];
                self.h[s] = self.h[last];
                self.prev_err[s] = self.prev_err[last];
                self.stats[s] = self.stats[last].clone();
                self.opts[s] = self.opts[last].clone();
                self.idx[s] = self.idx[last];
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------------

/// Adaptively integrate B trajectories from t0 to t1.  `y0` is row-major
/// `[B, dim]`; B is inferred from `y0.len() / f.dim()`.
pub fn solve_adaptive_batch<F: BatchDynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> BatchResult {
    solve_adaptive_batch_mut(&mut f, t0, t1, y0, tb, opts)
}

/// `&mut`-receiver variant (keeps ownership with the caller).
pub fn solve_adaptive_batch_mut<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> BatchResult {
    batch_segment(f, t0, t1, y0, tb, opts, None)
}

/// One batched segment, optionally warm-started with a per-trajectory
/// initial step magnitude (grid solving re-uses each trajectory's own
/// final h, exactly like the scalar `solve_to_times`).
fn batch_segment<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    h_init_rows: Option<&[f32]>,
) -> BatchResult {
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(
        y0.len() % n,
        0,
        "batch state length {} is not a multiple of dim {n}",
        y0.len()
    );
    if tb.e.is_some() {
        solve_embedded_batch(f, t0, t1, y0, tb, opts, h_init_rows)
    } else {
        solve_doubling_rows(f, t0, t1, y0, tb, opts, h_init_rows)
    }
}

/// The batched embedded-pair stepping driver, opened up for *incremental
/// admission*: trajectories can join the active [`WorkingSet`] between
/// attempts ([`BatchStepper::admit`]) while finished ones retire
/// ([`BatchStepper::step`] returns them), so a serving loop can keep the
/// batch full under load instead of draining to stragglers.
///
/// [`solve_adaptive_batch`] is exactly `admit`-everything-then-`step`-until-
/// drained over this type, so there is **one** attempt loop in the crate
/// and the bit-identity properties (batched == scalar, pooled == serial,
/// incremental admission == solo solve) hold by construction: every row's
/// arithmetic uses only its own state, target, and [`AdaptiveOpts`] — batch
/// composition only changes how rows are grouped into model evaluations.
///
/// Rows admitted in one `admit` call share their stage-0 evaluation and (if
/// no initial step is given) one batched Hairer probe evaluation, matching
/// the scalar driver's NFE accounting per trajectory.
pub struct BatchStepper<F: BatchDynamics> {
    f: F,
    tbf: TableauCoeffs,
    inv_order: f32,
    ws: WorkingSet,
    // Per-attempt scratch (no per-trajectory identity, so never compacted).
    ystage: Vec<f32>,
    ynew: Vec<f32>,
    errv: Vec<f32>,
    tstage: Vec<f32>,
    finished: Vec<usize>,
    refresh: Vec<usize>,
    ids_scratch: Vec<usize>,
    /// Telemetry ([`Recorder::off`] by default: a no-op branch per record
    /// site).  Only per-row data is ever recorded here — histograms of each
    /// row's own steps/errors, counters and one span per retired trajectory
    /// — because the pooled drivers chunk rows by worker count and anything
    /// batch-shaped would make traces depend on the chunking (the merge
    /// contract of [`Recorder::absorb_by_track`]).
    rec: Recorder,
}

impl<F: BatchDynamics> BatchStepper<F> {
    /// A stepper with an empty working set.  Panics if the tableau has no
    /// embedded pair (a silently-empty `e` would zero every error estimate
    /// and accept every step) or the dynamics' dimension is zero.
    pub fn new(f: F, tb: &Tableau) -> BatchStepper<F> {
        let n = f.dim();
        assert!(n > 0, "BatchDynamics::dim() must be positive");
        let tbf = TableauCoeffs::new(tb);
        assert!(tbf.has_embedded(), "BatchStepper needs an embedded pair");
        let inv_order = tbf.inv_order();
        let stages = tbf.stages;
        BatchStepper {
            f,
            tbf,
            inv_order,
            ws: WorkingSet::new(n, stages),
            ystage: Vec::new(),
            ynew: Vec::new(),
            errv: vec![0.0f32; n],
            tstage: Vec::new(),
            finished: Vec::new(),
            refresh: Vec::new(),
            ids_scratch: Vec::new(),
            rec: Recorder::off(),
        }
    }

    /// Per-trajectory state dimension.
    pub fn dim(&self) -> usize {
        self.ws.n
    }

    /// Number of live trajectories in the working set.
    pub fn active(&self) -> usize {
        self.ws.act
    }

    /// Stable ids of the live trajectories (slot order; unstable across
    /// attempts because of compaction).
    pub fn active_ids(&self) -> &[usize] {
        &self.ws.idx[..self.ws.act]
    }

    /// Borrow the wrapped dynamics.
    pub fn dynamics(&self) -> &F {
        &self.f
    }

    /// Mutably borrow the wrapped dynamics.
    pub fn dynamics_mut(&mut self) -> &mut F {
        &mut self.f
    }

    /// Recover the wrapped dynamics.
    pub fn into_dynamics(self) -> F {
        self.f
    }

    /// Replace the telemetry recorder (off by default).
    pub fn set_recorder(&mut self, rec: Recorder) {
        self.rec = rec;
    }

    /// Borrow the telemetry recorder — e.g. so a serving loop can stamp
    /// engine-step ticks and emit its own timeline events alongside the
    /// stepper's per-row stream.
    pub fn recorder_mut(&mut self) -> &mut Recorder {
        &mut self.rec
    }

    /// Take the recorder out, leaving telemetry off.
    pub fn take_recorder(&mut self) -> Recorder {
        std::mem::replace(&mut self.rec, Recorder::off())
    }

    fn grow_scratch(&mut self) {
        let rows = self.ws.act;
        let n = self.ws.n;
        if self.tstage.len() < rows {
            self.tstage.resize(rows, 0.0);
            self.ids_scratch.resize(rows, 0);
            self.ystage.resize(rows * n, 0.0);
            self.ynew.resize(rows * n, 0.0);
        }
    }

    /// Admit `ids.len()` new trajectories (row-major states `y0`, shared
    /// segment `t0 → t1`, shared options) into the active set.  Spends one
    /// stage-0 evaluation for the admitted group, plus — when neither
    /// `h_init_rows` nor `opts.h_init` supplies an initial step — one
    /// batched Hairer probe evaluation (one extra NFE per trajectory,
    /// exactly like the scalar driver).  Trajectories that are already done
    /// on arrival (`t0 == t1`, or `max_steps == 0`) retire immediately and
    /// are returned.
    pub fn admit(
        &mut self,
        ids: &[usize],
        y0: &[f32],
        t0: f32,
        t1: f32,
        opts: &AdaptiveOpts,
        h_init_rows: Option<&[f32]>,
    ) -> Vec<Retired> {
        let n = self.ws.n;
        let k = ids.len();
        assert_eq!(y0.len(), k * n, "admit: state length != ids.len() * dim");
        if k == 0 {
            return Vec::new();
        }
        let base = self.ws.act;
        self.ws.push_rows(ids, y0, t0, t1, opts);
        self.grow_scratch();
        self.rec.inc(Counter::Admitted, k as u64);
        let ws = &mut self.ws;
        let f = &mut self.f;

        // Stage-0 derivative for the admitted group: one batched evaluation
        // (reused by FSAL across accepted steps, exactly like the scalar
        // path).
        f.eval(
            &ws.idx[base..base + k],
            &ws.t[base..base + k],
            &ws.y[base * n..(base + k) * n],
            &mut ws.ks[0][base * n..(base + k) * n],
        );
        for s in ws.stats[base..base + k].iter_mut() {
            s.nfe += 1;
        }

        // Initial step per trajectory: warm-start rows > explicit
        // opts.h_init > the batched Hairer heuristic (h0 per row, ONE probe
        // evaluation for the admitted group, h1 per row).
        if let Some(rows) = h_init_rows {
            assert_eq!(rows.len(), k, "h_init_rows length");
            for q in 0..k {
                let s = base + q;
                ws.h[s] = rows[q].abs().min(ws.hcap[s]).max(1e-10);
            }
        } else if let Some(h0) = opts.h_init {
            for s in base..base + k {
                ws.h[s] = h0.abs().min(ws.hcap[s]).max(1e-10);
            }
        } else {
            let ystage = &mut self.ystage;
            let ynew = &mut self.ynew;
            let tstage = &mut self.tstage;
            for q in 0..k {
                let s = base + q;
                let yr = &ws.y[s * n..(s + 1) * n];
                let f0 = &ws.ks[0][s * n..(s + 1) * n];
                let h0 = stage::h0_estimate(yr, f0, opts.atol, opts.rtol);
                // Euler probe state, staged for one batched evaluation.
                let pr = &mut ystage[q * n..(q + 1) * n];
                for i in 0..n {
                    pr[i] = yr[i] + h0 * f0[i];
                }
                tstage[q] = ws.t[s] + h0;
                ws.h[s] = h0; // stash h0 until the probe comes back
            }
            f.eval(
                &ws.idx[base..base + k],
                &tstage[..k],
                &ystage[..k * n],
                &mut ynew[..k * n],
            );
            for q in 0..k {
                let s = base + q;
                ws.stats[s].nfe += 1;
                let yr = &ws.y[s * n..(s + 1) * n];
                let f0 = &ws.ks[0][s * n..(s + 1) * n];
                let f1 = &ynew[q * n..(q + 1) * n];
                let h1 =
                    stage::h1_estimate(yr, f0, f1, ws.h[s], self.tbf.order, opts.atol, opts.rtol);
                ws.h[s] = h1.min(ws.hcap[s]).max(1e-10);
            }
        }

        // Trajectories that are already done (t0 == t1, or max_steps == 0).
        // Slots below `base` were live after the last attempt and stay live.
        self.finished.clear();
        for s in base..base + k {
            let live = (ws.t[s] - ws.t1[s]).abs() > 1e-9 && (ws.t1[s] - ws.t[s]) * ws.sg[s] > 0.0;
            let exhausted = ws.stats[s].accepted + ws.stats[s].rejected >= ws.opts[s].max_steps;
            if !live || exhausted {
                self.finished.push(s);
            }
        }
        let out = ws.retire(&self.finished);
        record_retired(&mut self.rec, &out);
        out
    }

    /// One adaptive attempt (stage evaluations, per-row accept/reject,
    /// controller update) for every live trajectory, returning the rows
    /// that finished on this attempt.  No-op on an empty working set.
    pub fn step(&mut self) -> Vec<Retired> {
        if self.ws.act == 0 {
            return Vec::new();
        }
        let n = self.ws.n;
        let tbf = &self.tbf;
        let inv_order = self.inv_order;
        let ws = &mut self.ws;
        let f = &mut self.f;
        let ystage = &mut self.ystage;
        let ynew = &mut self.ynew;
        let errv = &mut self.errv;
        let tstage = &mut self.tstage;
        let finished = &mut self.finished;
        let refresh = &mut self.refresh;
        let ids_scratch = &mut self.ids_scratch;
        let rec = &mut self.rec;
        let recording = rec.is_on();
        let act = ws.act;

        // Clamp and sign each trajectory's attempted step.
        for s in 0..act {
            ws.h[s] = ws.h[s].min((ws.t1[s] - ws.t[s]).abs()).min(ws.hcap[s]) * ws.sg[s];
        }

        // Stages 1..S: stage state for all rows, then ONE model evaluation
        // for the whole active batch.  Per-row operation order matches
        // `stage::accumulate` exactly (copy, then axpy in ascending stage
        // order, zero coefficients skipped) so results are bit-identical to
        // the scalar driver.
        for i in 0..tbf.a.len() {
            let a_row = &tbf.a[i];
            ystage[..act * n].copy_from_slice(&ws.y[..act * n]);
            for (j, aj) in a_row.iter().enumerate() {
                let kj = &ws.ks[j];
                for s in 0..act {
                    let cj = *aj * ws.h[s];
                    if cj != 0.0 {
                        axpy(cj, &kj[s * n..(s + 1) * n], &mut ystage[s * n..(s + 1) * n]);
                    }
                }
            }
            let ci = tbf.c[i + 1];
            for s in 0..act {
                tstage[s] = ws.t[s] + ci * ws.h[s];
            }
            let (_, rest) = ws.ks.split_at_mut(i + 1);
            f.eval(&ws.idx[..act], &tstage[..act], &ystage[..act * n], &mut rest[0][..act * n]);
            for s in ws.stats.iter_mut().take(act) {
                s.nfe += 1;
            }
        }

        // Propagating solution for all rows.
        ynew[..act * n].copy_from_slice(&ws.y[..act * n]);
        for (j, bj) in tbf.b.iter().enumerate() {
            let kj = &ws.ks[j];
            for s in 0..act {
                let cj = *bj * ws.h[s];
                if cj != 0.0 {
                    axpy(cj, &kj[s * n..(s + 1) * n], &mut ynew[s * n..(s + 1) * n]);
                }
            }
        }

        // Per-trajectory embedded error, accept/reject, controller update —
        // each row against its own tolerance class.
        finished.clear();
        refresh.clear();
        for s in 0..act {
            for v in errv.iter_mut() {
                *v = 0.0;
            }
            for (j, ej) in tbf.e.iter().enumerate() {
                let cj = *ej * ws.h[s];
                if cj != 0.0 {
                    axpy(cj, &ws.ks[j][s * n..(s + 1) * n], errv);
                }
            }
            let err = stage::error_norm(
                errv,
                &ws.y[s * n..(s + 1) * n],
                &ynew[s * n..(s + 1) * n],
                ws.opts[s].atol,
                ws.opts[s].rtol,
            );
            let hs = ws.h[s];
            if err <= 1.0 || hs.abs() <= 1e-9 {
                // accept
                ws.t[s] += hs;
                ws.y[s * n..(s + 1) * n].copy_from_slice(&ynew[s * n..(s + 1) * n]);
                ws.stats[s].accepted += 1;
                if recording {
                    rec.observe(Hist::StepSize, hs.abs());
                    rec.observe(Hist::ErrNorm, err);
                    // Per-attempt cost attribution (obs::cost): one instant
                    // per accept on the trajectory's own track, timestamped
                    // with the row's own attempt index — chunking-
                    // independent, so `absorb_by_track` canonicalizes the
                    // pooled stream (see `record_retired`).
                    let attempt = (ws.stats[s].accepted + ws.stats[s].rejected - 1) as u64;
                    rec.instant(
                        "accept",
                        ws.idx[s] as u64,
                        attempt,
                        [("err", err as f64), ("h", hs.abs() as f64)],
                    );
                }
                if tbf.fsal {
                    // per-row FSAL: k_last at the accepted point becomes k0
                    let last = tbf.stages - 1;
                    let (k0, tail) = ws.ks.split_at_mut(1);
                    k0[0][s * n..(s + 1) * n]
                        .swap_with_slice(&mut tail[last - 1][s * n..(s + 1) * n]);
                } else if (ws.t[s] - ws.t1[s]).abs() > 1e-9 {
                    refresh.push(s); // fresh f(t, y), batched below
                }
                let errc = err.max(1e-10);
                let factor = stage::accept_factor(&ws.opts[s], inv_order, errc, ws.prev_err[s]);
                ws.h[s] = hs.abs() * factor.clamp(ws.opts[s].factor_min, ws.opts[s].factor_max);
                ws.prev_err[s] = errc;
            } else {
                // reject: shrink and retry (FSAL stage 0 is still valid)
                ws.stats[s].rejected += 1;
                if recording {
                    rec.observe(Hist::ErrNorm, err);
                    let attempt = (ws.stats[s].accepted + ws.stats[s].rejected - 1) as u64;
                    rec.instant(
                        "reject",
                        ws.idx[s] as u64,
                        attempt,
                        [("err", err as f64), ("h", hs.abs() as f64)],
                    );
                }
                let factor = stage::reject_factor(&ws.opts[s], inv_order, err);
                ws.h[s] = hs.abs() * factor.clamp(ws.opts[s].factor_min, 1.0);
            }
            let live = (ws.t[s] - ws.t1[s]).abs() > 1e-9 && (ws.t1[s] - ws.t[s]) * ws.sg[s] > 0.0;
            let exhausted = ws.stats[s].accepted + ws.stats[s].rejected >= ws.opts[s].max_steps;
            if !live || exhausted {
                finished.push(s);
            }
        }

        // Batched stage-0 refresh for non-FSAL accepts still in flight
        // (the scalar driver spends this NFE immediately after accepting;
        // the value is identical, the dispatch is amortized).
        if !refresh.is_empty() {
            let m = refresh.len();
            for (q, &s) in refresh.iter().enumerate() {
                ystage[q * n..(q + 1) * n].copy_from_slice(&ws.y[s * n..(s + 1) * n]);
                tstage[q] = ws.t[s];
                ids_scratch[q] = ws.idx[s];
            }
            f.eval(&ids_scratch[..m], &tstage[..m], &ystage[..m * n], &mut ynew[..m * n]);
            for (q, &s) in refresh.iter().enumerate() {
                ws.ks[0][s * n..(s + 1) * n].copy_from_slice(&ynew[q * n..(q + 1) * n]);
                ws.stats[s].nfe += 1;
            }
        }

        let out = ws.retire(finished);
        record_retired(rec, &out);
        out
    }
}

/// Fold retired trajectories into the telemetry recorder: `Retired` +
/// stats counters (the single stats→counters conversion, see
/// [`crate::obs::Registry::absorb_solve_stats`]) plus one span per
/// trajectory on `track = id` whose duration is the row's **own** attempt
/// count.  Attempt counts are chunking-independent — every attempt
/// advances each active row exactly once — so the recorded stream is
/// identical however the pooled drivers group rows into chunks.
///
/// The span is anchored so it *ends* at the recorder's current tick: under
/// an externally-clocked driver (the serving engine sets ticks to its step
/// number) a trajectory's span covers exactly the engine steps it was
/// active on and nests inside the engine's `request` span; in a plain
/// solve the clock stays at zero and the span starts at tick 0 as before.
fn record_retired(rec: &mut Recorder, out: &[Retired]) {
    if !rec.is_on() {
        return;
    }
    let now = rec.now_ticks();
    for r in out {
        rec.inc(Counter::Retired, 1);
        rec.absorb_stats(&r.stats);
        let steps = (r.stats.accepted + r.stats.rejected) as u64;
        let ts = (now + 1).saturating_sub(steps.max(1));
        rec.span(
            "traj",
            r.id as u64,
            ts,
            steps,
            [("nfe", r.stats.nfe as f64), ("rejected", r.stats.rejected as f64)],
        );
    }
}

/// The batched embedded-pair driver: admit every trajectory at `t0`, then
/// step the shared [`BatchStepper`] until the working set drains, and
/// assemble the retired rows back into the caller's original order.
fn solve_embedded_batch<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    h_init_rows: Option<&[f32]>,
) -> BatchResult {
    let n = f.dim();
    let b = y0.len() / n;
    let mut y = y0.to_vec();
    let mut t = vec![t0; b];
    let mut stats = vec![SolveStats::default(); b];
    if b == 0 {
        return BatchResult { n, y, t, stats };
    }
    let mut stepper = BatchStepper::new(&mut *f, tb);
    let ids: Vec<usize> = (0..b).collect();
    let mut done = stepper.admit(&ids, y0, t0, t1, opts, h_init_rows);
    while stepper.active() > 0 {
        done.append(&mut stepper.step());
    }
    for r in done {
        y[r.id * n..(r.id + 1) * n].copy_from_slice(&r.y);
        t[r.id] = r.t;
        stats[r.id] = r.stats;
    }
    BatchResult { n, y, t, stats }
}

/// Per-trajectory fallback for tableaux without an embedded pair: scalar
/// step-doubling solves through a one-row view of the batch dynamics.
fn solve_doubling_rows<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    h_init_rows: Option<&[f32]>,
) -> BatchResult {
    let n = f.dim();
    let b = y0.len() / n;
    let mut out_y = vec![0.0f32; b * n];
    let mut out_t = vec![t0; b];
    let mut out_stats = vec![SolveStats::default(); b];
    for r in 0..b {
        let mut row_opts = opts.clone();
        if let Some(rows) = h_init_rows {
            row_opts.h_init = Some(rows[r].abs());
        }
        let mut one = OneRow { f: &mut *f, id: r };
        let res = solve_adaptive_mut(&mut one, t0, t1, &y0[r * n..(r + 1) * n], tb, &row_opts);
        out_y[r * n..(r + 1) * n].copy_from_slice(&res.y);
        out_t[r] = res.t;
        out_stats[r] = res.stats;
    }
    BatchResult { n, y: out_y, t: out_t, stats: out_stats }
}

/// Fixed-grid batched driver: B trajectories share one uniform step grid
/// (one model evaluation per stage per step for the whole batch).  Returns
/// the final `[B, n]` state and the exact per-trajectory NFE.
pub fn solve_fixed_batch<F: BatchDynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, Vec<usize>) {
    solve_fixed_batch_mut(&mut f, t0, t1, y0, steps, tb)
}

pub fn solve_fixed_batch_mut<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, Vec<usize>) {
    let b = y0.len() / f.dim().max(1);
    let (y, stages) = fixed_batch_drive(f, t0, t1, y0, steps, tb, None);
    if b == 0 {
        return (y, vec![]);
    }
    (y, vec![steps * stages; b])
}

/// The single fixed-grid stage loop behind [`solve_fixed_batch`] and
/// [`solve_fixed_batch_record`]: recording is a pure observer (clones of
/// stage inputs), so the two entry points are arithmetically identical
/// **by construction**, not by parallel maintenance.  Returns the final
/// state and the stage count.
fn fixed_batch_drive<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
    mut rec: Option<&mut FixedGridRecord>,
) -> (Vec<f32>, usize) {
    assert!(steps > 0);
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(y0.len() % n, 0, "batch state length vs dim");
    let b = y0.len() / n;
    let tbf = TableauCoeffs::new(tb);
    let dt = (t1 - t0) / steps as f32;
    let mut y = y0.to_vec();
    let mut ynew = vec![0.0f32; b * n];
    let mut ystage = vec![0.0f32; b * n];
    let mut ks: Vec<Vec<f32>> = (0..tbf.stages).map(|_| vec![0.0f32; b * n]).collect();
    let mut tstage = vec![0.0f32; b];
    let ids: Vec<usize> = (0..b).collect();
    if b == 0 {
        return (y, tbf.stages);
    }

    for s in 0..steps {
        let t = t0 + s as f32 * dt;
        // stage 0
        for ts in tstage.iter_mut() {
            *ts = t;
        }
        if let Some(r) = &mut rec {
            r.stage_t.push(Vec::with_capacity(tbf.stages));
            r.stage_y.push(Vec::with_capacity(tbf.stages));
            // the step's caches were pushed two lines up, so last_mut is Some
            r.stage_t.last_mut().unwrap().push(t); // taylint: allow(D4) -- see above
            r.stage_y.last_mut().unwrap().push(y.clone());
        }
        {
            let (k0, _) = ks.split_at_mut(1);
            f.eval(&ids, &tstage, &y, &mut k0[0]);
        }
        // stages 1..S — the grid is shared, so the whole [B, n] matrix gets
        // one flat axpy per stage coefficient (elementwise identical to the
        // per-row scalar op sequence).
        for i in 0..tbf.a.len() {
            ystage.copy_from_slice(&y);
            for (j, aj) in tbf.a[i].iter().enumerate() {
                let cj = *aj * dt;
                if cj != 0.0 {
                    axpy(cj, &ks[j], &mut ystage);
                }
            }
            let tc = t + tbf.c[i + 1] * dt;
            for ts in tstage.iter_mut() {
                *ts = tc;
            }
            if let Some(r) = &mut rec {
                // stage 0 created this step's caches, so last_mut is Some
                r.stage_t.last_mut().unwrap().push(tc); // taylint: allow(D4) -- see above
                r.stage_y.last_mut().unwrap().push(ystage.clone());
            }
            let (_, rest) = ks.split_at_mut(i + 1);
            f.eval(&ids, &tstage, &ystage, &mut rest[0]);
        }
        // combine
        ynew.copy_from_slice(&y);
        for (j, bj) in tbf.b.iter().enumerate() {
            let cj = *bj * dt;
            if cj != 0.0 {
                axpy(cj, &ks[j], &mut ynew);
            }
        }
        std::mem::swap(&mut y, &mut ynew);
    }
    (y, tbf.stages)
}

/// Everything the discrete-adjoint backward pass needs from a fixed-grid
/// forward solve: every stage's input state and time, cached as the solve
/// runs.  Recording and plain solving share ONE stage loop
/// (`fixed_batch_drive`), so the final state is bit-identical to
/// [`solve_fixed_batch`] by construction (pinned by a regression test
/// below) — recording only adds copies, never changes the arithmetic.
#[derive(Clone, Debug)]
pub struct FixedGridRecord {
    /// Per-trajectory state dimension of the recorded system.
    pub n: usize,
    /// Number of trajectories.
    pub batch: usize,
    pub steps: usize,
    pub t0: f32,
    /// Uniform step size (t1 - t0) / steps.
    pub dt: f32,
    /// Stage times, `[steps][stages]` (the grid is shared by every row).
    pub stage_t: Vec<Vec<f32>>,
    /// Stage input states, `[steps][stages]`, each row-major `[B, n]` —
    /// stage 0's input is the step's starting state.
    pub stage_y: Vec<Vec<Vec<f32>>>,
    /// Final states, row-major `[B, n]`.
    pub y: Vec<f32>,
    /// Per-trajectory NFE spent (steps · stages).
    pub nfe: usize,
}

/// [`solve_fixed_batch`] with stage-state caching — the forward half of the
/// discrete adjoint (`coordinator::train_native`).  The backward pass
/// re-evaluates the dynamics on a reverse-mode tape at exactly these cached
/// `(state, time)` pairs, so no checkpointing/recomputation scheme is
/// needed at fixed-grid training scale.
pub fn solve_fixed_batch_record<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> FixedGridRecord {
    assert!(steps > 0);
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(y0.len() % n, 0, "batch state length vs dim");
    let b = y0.len() / n;
    let mut rec = FixedGridRecord {
        n,
        batch: b,
        steps,
        t0,
        dt: (t1 - t0) / steps as f32,
        stage_t: Vec::with_capacity(steps),
        stage_y: Vec::with_capacity(steps),
        y: vec![],
        nfe: 0,
    };
    let (y, stages) = fixed_batch_drive(f, t0, t1, y0, steps, tb, Some(&mut rec));
    rec.y = y;
    if b > 0 {
        rec.nfe = steps * stages;
    }
    rec
}

/// Batched grid-output solve (the latent-ODE evaluation path): adaptively
/// integrate all B trajectories through a shared grid of output times,
/// returning the `[B, n]` state at every grid point plus per-trajectory
/// cumulative stats.  Each trajectory's step size is warm-started from its
/// own previous segment (magnitude only, so decreasing/reverse-time grids
/// are safe), exactly like the scalar `solve_to_times`.
pub fn solve_to_times_batch<F: BatchDynamics>(
    mut f: F,
    times: &[f32],
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> (Vec<Vec<f32>>, Vec<SolveStats>) {
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(y0.len() % n, 0, "batch state length vs dim");
    let b = y0.len() / n;
    let mut traj = Vec::with_capacity(times.len());
    traj.push(y0.to_vec());
    let mut agg = vec![SolveStats::default(); b];
    let mut y = y0.to_vec();
    let mut warm: Option<Vec<f32>> = None;
    for w in times.windows(2) {
        if (w[1] - w[0]).abs() <= 1e-9 {
            traj.push(y.clone());
            continue;
        }
        let res = batch_segment(&mut f, w[0], w[1], &y, tb, opts, warm.as_deref());
        y = res.y;
        for (a, s) in agg.iter_mut().zip(&res.stats) {
            a.nfe += s.nfe;
            a.accepted += s.accepted;
            a.rejected += s.rejected;
            a.h_final = s.h_final;
        }
        warm = Some(
            res.stats
                .iter()
                .map(|s| s.h_final.abs().max(1e-6))
                .collect(),
        );
        traj.push(y.clone());
    }
    (traj, agg)
}

// ---------------------------------------------------------------------------
// Worker-pool sharding: every driver over per-worker sub-batches
// ---------------------------------------------------------------------------

/// Adapter that shifts the engine's shard-local trajectory ids back to the
/// caller's global ids, so per-trajectory-conditioned models stay correctly
/// keyed inside a worker shard (shard row 0 is global trajectory `base`).
struct OffsetIds<F> {
    f: F,
    base: usize,
    ids: Vec<usize>,
}

impl<F: BatchDynamics> OffsetIds<F> {
    fn new(f: F, base: usize) -> OffsetIds<F> {
        OffsetIds { f, base, ids: vec![] }
    }
}

impl<F: BatchDynamics> BatchDynamics for OffsetIds<F> {
    fn dim(&self) -> usize {
        self.f.dim()
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        self.ids.clear();
        self.ids.extend(ids.iter().map(|id| id + self.base));
        self.f.eval(&self.ids, t, y, dy);
    }
}

/// A [`BatchDynamics`] whose every evaluation is sharded across a worker
/// pool: the rows split into contiguous chunks ([`chunk_ranges`]), each
/// chunk is evaluated by a clone of the wrapped dynamics on its slice of
/// `ids`/`t`/`y`, and the derivatives copy back in fixed chunk order.  The
/// wrapped model is row-independent and each shard sees the caller's
/// stable ids verbatim, so the output is **bit-identical to the serial
/// evaluation at every thread count**.  This is how a structurally-serial
/// driver (the serving engine's single attempt loop) goes wide without
/// forking its control flow; the whole-solve `_pooled` drivers below
/// amortize dispatch better when the batch composition is fixed up front.
pub struct PooledEval<'p, F> {
    pool: &'p Pool,
    f: F,
}

impl<'p, F: BatchDynamics + Clone + Send + Sync> PooledEval<'p, F> {
    pub fn new(pool: &'p Pool, f: F) -> PooledEval<'p, F> {
        PooledEval { pool, f }
    }

    /// Recover the wrapped dynamics.
    pub fn into_inner(self) -> F {
        self.f
    }
}

impl<'p, F: BatchDynamics + Clone + Send + Sync> BatchDynamics for PooledEval<'p, F> {
    fn dim(&self) -> usize {
        self.f.dim()
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let n = self.f.dim();
        let shards = chunk_ranges(t.len(), self.pool.threads());
        if shards.len() <= 1 {
            return self.f.eval(ids, t, y, dy);
        }
        let f = &self.f;
        let parts = self.pool.run_range_shards(&shards, |_, r| {
            let mut g = f.clone();
            let mut out = vec![0.0f32; (r.end - r.start) * n];
            g.eval(
                &ids[r.start..r.end],
                &t[r.start..r.end],
                &y[r.start * n..r.end * n],
                &mut out,
            );
            out
        });
        for (r, part) in shards.iter().zip(parts) {
            dy[r.start * n..r.end * n].copy_from_slice(&part);
        }
    }
}

/// Chunk layout shared by the pooled drivers, plus the common shape
/// checks.  Several chunks per worker ([`chunk_ranges`]) are claimed from
/// the pool's atomic queue, so skewed per-trajectory costs rebalance
/// dynamically instead of tailing on whichever worker drew the straggler
/// shard; the merge in the callers stays in fixed chunk order.
fn solver_shards<F: BatchDynamics>(
    pool: &Pool,
    f: &F,
    y0: &[f32],
) -> (usize, usize, Vec<std::ops::Range<usize>>) {
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(y0.len() % n, 0, "batch state length vs dim");
    let b = y0.len() / n;
    (n, b, chunk_ranges(b, pool.threads()))
}

/// [`solve_adaptive_batch`] sharded across a worker pool: the batch splits
/// into contiguous row chunks (several per worker, claimed from the pool's
/// atomic queue), each with its own working set, active-set compaction,
/// and clone of the dynamics; results merge by stable trajectory id.
/// Bit-identical to the serial driver at any thread count (no arithmetic
/// crosses rows).
pub fn solve_adaptive_batch_pooled<F>(
    pool: &Pool,
    f: &F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> BatchResult
where
    F: BatchDynamics + Clone + Send + Sync,
{
    let (n, b, shards) = solver_shards(pool, f, y0);
    if shards.len() <= 1 {
        let mut own = f.clone();
        return batch_segment(&mut own, t0, t1, y0, tb, opts, None);
    }
    let parts = pool.run_range_shards(&shards, |_, r| {
        let mut g = OffsetIds::new(f.clone(), r.start);
        batch_segment(&mut g, t0, t1, &y0[r.start * n..r.end * n], tb, opts, None)
    });
    let mut y = Vec::with_capacity(b * n);
    let mut t = Vec::with_capacity(b);
    let mut stats = Vec::with_capacity(b);
    for p in parts {
        // shard order == ascending original trajectory id
        y.extend_from_slice(&p.y);
        t.extend_from_slice(&p.t);
        stats.extend(p.stats);
    }
    BatchResult { n, y, t, stats }
}

/// [`solve_embedded_batch`] with *global* trajectory ids and an optional
/// per-chunk recorder — the traced pooled driver's worker body.  Admitting
/// under global ids (instead of wrapping in [`OffsetIds`]) makes every
/// recorded track a stable global id, so chunk streams can merge
/// canonically.
fn solve_embedded_traced<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    id_base: usize,
    tracing: bool,
) -> (BatchResult, Recorder) {
    let n = f.dim();
    let b = y0.len() / n;
    let mut y = y0.to_vec();
    let mut t = vec![t0; b];
    let mut stats = vec![SolveStats::default(); b];
    if b == 0 {
        return (BatchResult { n, y, t, stats }, Recorder::off());
    }
    let mut stepper = BatchStepper::new(&mut *f, tb);
    if tracing {
        stepper.set_recorder(Recorder::enabled());
    }
    let ids: Vec<usize> = (id_base..id_base + b).collect();
    let mut done = stepper.admit(&ids, y0, t0, t1, opts, None);
    while stepper.active() > 0 {
        done.append(&mut stepper.step());
    }
    let rec = stepper.take_recorder();
    for r in done {
        let s = r.id - id_base;
        y[s * n..(s + 1) * n].copy_from_slice(&r.y);
        t[s] = r.t;
        stats[s] = r.stats;
    }
    (BatchResult { n, y, t, stats }, rec)
}

/// [`solve_adaptive_batch_pooled`] with telemetry: each chunk records into
/// its own sub-recorder (no shared state between workers), and the chunk
/// streams merge into `rec` via [`Recorder::absorb_by_track`] — the
/// per-trajectory canonicalization that makes the merged trace
/// bit-identical at every thread count even though the chunk layout is
/// not.  Results are bit-identical to [`solve_adaptive_batch_pooled`]
/// whether or not `rec` is on.  Requires a tableau with an embedded pair
/// (the recorder lives on the [`BatchStepper`]).
pub fn solve_adaptive_batch_traced_pooled<F>(
    pool: &Pool,
    f: &F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    rec: &mut Recorder,
) -> BatchResult
where
    F: BatchDynamics + Clone + Send + Sync,
{
    assert!(tb.e.is_some(), "traced solves need an embedded pair");
    let (n, b, shards) = solver_shards(pool, f, y0);
    let tracing = rec.is_on();
    if shards.len() <= 1 {
        let mut own = f.clone();
        let (res, sub) = solve_embedded_traced(&mut own, t0, t1, y0, tb, opts, 0, tracing);
        rec.absorb_by_track(vec![sub]);
        return res;
    }
    let parts = pool.run_range_shards(&shards, |_, r| {
        let mut g = f.clone();
        let rows = &y0[r.start * n..r.end * n];
        solve_embedded_traced(&mut g, t0, t1, rows, tb, opts, r.start, tracing)
    });
    let mut y = Vec::with_capacity(b * n);
    let mut t = Vec::with_capacity(b);
    let mut stats = Vec::with_capacity(b);
    let mut subs = Vec::with_capacity(parts.len());
    for (p, sub) in parts {
        // chunk order == ascending original trajectory id
        y.extend_from_slice(&p.y);
        t.extend_from_slice(&p.t);
        stats.extend(p.stats);
        subs.push(sub);
    }
    rec.absorb_by_track(subs);
    BatchResult { n, y, t, stats }
}

/// [`solve_fixed_batch`] sharded across a worker pool (per-shard dynamics
/// clones, merge by stable trajectory id; bit-identical to serial).
pub fn solve_fixed_batch_pooled<F>(
    pool: &Pool,
    f: &F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, Vec<usize>)
where
    F: BatchDynamics + Clone + Send + Sync,
{
    let (n, b, shards) = solver_shards(pool, f, y0);
    if shards.len() <= 1 {
        return solve_fixed_batch(f.clone(), t0, t1, y0, steps, tb);
    }
    let parts = pool.run_range_shards(&shards, |_, r| {
        let mut g = OffsetIds::new(f.clone(), r.start);
        fixed_batch_drive(&mut g, t0, t1, &y0[r.start * n..r.end * n], steps, tb, None)
    });
    // Every shard ran the same tableau, so the stage count is uniform.
    let stages = parts[0].1;
    let mut y = Vec::with_capacity(b * n);
    for (py, ps) in parts {
        debug_assert_eq!(ps, stages);
        y.extend_from_slice(&py);
    }
    (y, vec![steps * stages; b])
}

/// [`solve_fixed_batch_record`] sharded across a worker pool: each shard
/// records its own rows; the per-stage caches concatenate back in stable
/// trajectory order, so the merged record is bit-identical to a serial
/// recording (the stage grid is shared, the rows never interact).
pub fn solve_fixed_batch_record_pooled<F>(
    pool: &Pool,
    f: &F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> FixedGridRecord
where
    F: BatchDynamics + Clone + Send + Sync,
{
    let (n, b, shards) = solver_shards(pool, f, y0);
    if shards.len() <= 1 {
        let mut own = f.clone();
        return solve_fixed_batch_record(&mut own, t0, t1, y0, steps, tb);
    }
    let parts = pool.run_range_shards(&shards, |_, r| {
        let mut g = OffsetIds::new(f.clone(), r.start);
        solve_fixed_batch_record(&mut g, t0, t1, &y0[r.start * n..r.end * n], steps, tb)
    });
    let mut rec = FixedGridRecord {
        n,
        batch: b,
        steps,
        t0,
        dt: parts[0].dt,
        stage_t: parts[0].stage_t.clone(),
        stage_y: Vec::with_capacity(parts[0].stage_y.len()),
        y: Vec::with_capacity(b * n),
        nfe: parts[0].nfe,
    };
    for s in 0..parts[0].stage_y.len() {
        let stages = parts[0].stage_y[s].len();
        let mut step_cache = Vec::with_capacity(stages);
        for i in 0..stages {
            let mut m = Vec::with_capacity(b * n);
            for p in &parts {
                m.extend_from_slice(&p.stage_y[s][i]);
            }
            step_cache.push(m);
        }
        rec.stage_y.push(step_cache);
    }
    for p in parts {
        rec.y.extend_from_slice(&p.y);
    }
    rec
}

/// [`solve_to_times_batch`] sharded across a worker pool: each shard walks
/// the whole output grid for its rows (per-trajectory warm starts stay
/// per-trajectory), and every grid snapshot merges back in stable
/// trajectory order.  Bit-identical to the serial grid driver.
pub fn solve_to_times_batch_pooled<F>(
    pool: &Pool,
    f: &F,
    times: &[f32],
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> (Vec<Vec<f32>>, Vec<SolveStats>)
where
    F: BatchDynamics + Clone + Send + Sync,
{
    let (n, b, shards) = solver_shards(pool, f, y0);
    if shards.len() <= 1 {
        return solve_to_times_batch(f.clone(), times, y0, tb, opts);
    }
    let parts = pool.run_range_shards(&shards, |_, r| {
        let g = OffsetIds::new(f.clone(), r.start);
        solve_to_times_batch(g, times, &y0[r.start * n..r.end * n], tb, opts)
    });
    let snaps = parts[0].0.len();
    let mut traj: Vec<Vec<f32>> = (0..snaps).map(|_| Vec::with_capacity(b * n)).collect();
    let mut stats = Vec::with_capacity(b);
    for (ptraj, pstats) in parts {
        for (k, snap) in ptraj.into_iter().enumerate() {
            traj[k].extend(snap);
        }
        stats.extend(pstats);
    }
    (traj, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::adaptive::{solve_adaptive, solve_to_times};
    use crate::solvers::fixed::solve_fixed;
    use crate::solvers::tableau;
    use crate::taylor::{ode_jet, Series, SeriesFn, SeriesVec};
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    const EMBEDDED: &[&str] = &["heun_euler", "bosh3", "fehlberg45", "cash_karp", "dopri5"];

    /// A nonlinear, time-dependent test dynamics parameterized by (w, a, c);
    /// state-dependent stiffness makes different rows take different step
    /// sequences.  Stateless, so scalar and batched evaluation orders agree.
    fn test_dynamics(w: f32, a: f32, c: f32) -> impl FnMut(f32, &[f32], &mut [f32]) {
        move |t, y, dy| {
            for (d, yi) in dy.iter_mut().zip(y) {
                *d = a * (w * t + yi).sin() + c * yi;
            }
        }
    }

    fn random_opts(rng: &mut Pcg) -> AdaptiveOpts {
        let rtol = 10f32.powi(-(2 + rng.below(5) as i32)); // 1e-2 .. 1e-6
        AdaptiveOpts {
            rtol,
            atol: rtol * 1e-2,
            h_init: if rng.below(2) == 0 { None } else { Some(rng.range(0.01, 0.3)) },
            max_steps: 50_000,
            ..Default::default()
        }
    }

    fn assert_stats_eq(
        a: &crate::solvers::adaptive::SolveStats,
        b: &crate::solvers::adaptive::SolveStats,
        ctx: &str,
    ) {
        assert_eq!(a.nfe, b.nfe, "{ctx}: nfe");
        assert_eq!(a.accepted, b.accepted, "{ctx}: accepted");
        assert_eq!(a.rejected, b.rejected, "{ctx}: rejected");
        assert_eq!(a.h_final.to_bits(), b.h_final.to_bits(), "{ctx}: h_final");
    }

    #[test]
    fn b1_reproduces_scalar_driver_bit_for_bit() {
        // The acceptance property: batched at B=1 == solve_adaptive exactly
        // (state bits, NFE, accepted, rejected), over random embedded
        // tableaux, tolerances, dims, directions, and warm starts.
        Prop::new(60).run("batch-b1-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let n = 1 + rng.below(4);
            let y0 = gen::vec_f32(rng, n, 1.5);
            let (w, a, c) = (rng.range(1.0, 25.0), rng.range(0.2, 2.0), rng.range(-1.0, 1.0));
            let opts = random_opts(rng);
            let (t0, t1) = if rng.below(4) == 0 { (1.0, 0.0) } else { (0.0, 1.0) };

            let scalar = solve_adaptive(test_dynamics(w, a, c), t0, t1, &y0, &tb, &opts);
            let batched = solve_adaptive_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                t0,
                t1,
                &y0,
                &tb,
                &opts,
            );
            assert_eq!(batched.batch(), 1);
            for i in 0..n {
                assert_eq!(
                    scalar.y[i].to_bits(),
                    batched.y[i].to_bits(),
                    "{}: y[{i}] {} vs {}",
                    tb.name,
                    scalar.y[i],
                    batched.y[i]
                );
            }
            assert_eq!(scalar.t.to_bits(), batched.t[0].to_bits(), "{}", tb.name);
            assert_stats_eq(&scalar.stats, &batched.stats[0], tb.name);
        });
    }

    #[test]
    fn batch_matches_independent_scalar_solves_per_trajectory() {
        // B > 1: every trajectory must match its own scalar solve even
        // though rows accept/reject on different schedules and the working
        // set compacts as rows finish.
        Prop::new(40).run("batch-bn-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let n = 1 + rng.below(3);
            let b = 2 + rng.below(4);
            // Rows at very different magnitudes => very different NFE, so
            // stragglers exercise the compaction path.
            let mut y0 = Vec::with_capacity(b * n);
            for r in 0..b {
                let mag = 0.2 * 3f32.powi(r as i32 % 4);
                y0.extend(gen::vec_f32(rng, n, mag));
            }
            let (w, a, c) = (rng.range(1.0, 30.0), rng.range(0.2, 2.0), rng.range(-1.0, 1.0));
            let opts = random_opts(rng);

            let batched = solve_adaptive_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                0.0,
                1.0,
                &y0,
                &tb,
                &opts,
            );
            for r in 0..b {
                let scalar = solve_adaptive(
                    test_dynamics(w, a, c),
                    0.0,
                    1.0,
                    &y0[r * n..(r + 1) * n],
                    &tb,
                    &opts,
                );
                for i in 0..n {
                    assert_eq!(
                        scalar.y[i].to_bits(),
                        batched.row(r)[i].to_bits(),
                        "{} row {r} y[{i}]",
                        tb.name
                    );
                }
                assert_stats_eq(&scalar.stats, &batched.stats[r], &format!("{} row {r}", tb.name));
            }
        });
    }

    #[test]
    fn doubling_fallback_matches_scalar() {
        // rk4 has no embedded pair; the batch API must still give
        // per-trajectory results identical to scalar step doubling.
        let tb = tableau::rk4();
        let opts = AdaptiveOpts { rtol: 1e-5, atol: 1e-7, ..Default::default() };
        let y0 = [1.0f32, 0.5, -0.25];
        let batched = solve_adaptive_batch(
            Rowwise::new(|_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = -y[0], 1),
            0.0,
            2.0,
            &y0,
            &tb,
            &opts,
        );
        for r in 0..3 {
            let scalar = solve_adaptive(
                |_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = -y[0],
                0.0,
                2.0,
                &y0[r..r + 1],
                &tb,
                &opts,
            );
            assert_eq!(scalar.y[0].to_bits(), batched.row(r)[0].to_bits(), "row {r}");
            assert_stats_eq(&scalar.stats, &batched.stats[r], &format!("row {r}"));
        }
    }

    #[test]
    fn fixed_batch_matches_scalar_rows() {
        Prop::new(30).run("fixed-batch-equiv", |rng: &mut Pcg, case| {
            let names = tableau::ALL;
            let tb = tableau::by_name(names[case % names.len()]).unwrap();
            let n = 1 + rng.below(3);
            let b = 1 + rng.below(4);
            let steps = 1 + rng.below(5);
            let y0 = gen::vec_f32(rng, b * n, 1.0);
            let (w, a, c) = (rng.range(1.0, 10.0), rng.range(0.2, 1.5), rng.range(-1.0, 1.0));
            let (yb, nfes) = solve_fixed_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                0.0,
                1.0,
                &y0,
                steps,
                &tb,
            );
            for r in 0..b {
                let (ys, nfe) = solve_fixed(
                    test_dynamics(w, a, c),
                    0.0,
                    1.0,
                    &y0[r * n..(r + 1) * n],
                    steps,
                    &tb,
                );
                assert_eq!(nfes[r], nfe, "{} row {r}", tb.name);
                for i in 0..n {
                    assert_eq!(
                        ys[i].to_bits(),
                        yb[r * n + i].to_bits(),
                        "{} row {r} y[{i}]",
                        tb.name
                    );
                }
            }
        });
    }

    #[test]
    fn record_driver_matches_fixed_batch_bit_for_bit() {
        // Recording must not change the arithmetic: final states equal
        // solve_fixed_batch exactly, and the cache has the right shape
        // (stage 0's input is the step's starting state).
        Prop::new(30).run("record-vs-fixed", |rng: &mut Pcg, case| {
            let names = tableau::ALL;
            let tb = tableau::by_name(names[case % names.len()]).unwrap();
            let n = 1 + rng.below(3);
            let b = 1 + rng.below(4);
            let steps = 1 + rng.below(5);
            let y0 = gen::vec_f32(rng, b * n, 1.0);
            let (w, a, c) = (rng.range(1.0, 10.0), rng.range(0.2, 1.5), rng.range(-1.0, 1.0));
            let (yb, nfes) = solve_fixed_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                0.0,
                1.0,
                &y0,
                steps,
                &tb,
            );
            let mut dynr = Rowwise::new(test_dynamics(w, a, c), n);
            let rec = solve_fixed_batch_record(&mut dynr, 0.0, 1.0, &y0, steps, &tb);
            assert_eq!(rec.batch, b);
            assert_eq!(rec.steps, steps);
            assert_eq!(rec.nfe, nfes[0], "{}", tb.name);
            assert_eq!(rec.stage_t.len(), steps);
            assert_eq!(rec.stage_y.len(), steps);
            for s in 0..steps {
                assert_eq!(rec.stage_t[s].len(), tb.stages);
                assert_eq!(rec.stage_y[s].len(), tb.stages);
                for u in &rec.stage_y[s] {
                    assert_eq!(u.len(), b * n);
                }
            }
            // stage 0 of step 0 is the initial state
            assert_eq!(rec.stage_y[0][0], y0);
            for (i, (ya, yw)) in rec.y.iter().zip(&yb).enumerate() {
                assert_eq!(ya.to_bits(), yw.to_bits(), "{} y[{i}]", tb.name);
            }
        });
    }

    #[test]
    fn to_times_batch_matches_scalar_grid_solves() {
        // Forward and reverse grids, warm-started per trajectory.
        for times in [
            vec![0.0f32, 0.25, 0.5, 0.75, 1.0],
            vec![1.0f32, 0.6, 0.3, 0.0],
            vec![0.0f32, 0.5, 0.5, 1.0], // duplicate grid point
        ] {
            let tb = tableau::dopri5();
            let opts = AdaptiveOpts::default();
            let n = 2;
            let y0 = [1.0f32, 0.0, 0.4, -0.8]; // B = 2
            let (traj_b, stats_b) = solve_to_times_batch(
                Rowwise::new(test_dynamics(6.0, 1.0, -0.3), n),
                &times,
                &y0,
                &tb,
                &opts,
            );
            assert_eq!(traj_b.len(), times.len());
            for r in 0..2 {
                let (traj_s, stats_s) = solve_to_times(
                    test_dynamics(6.0, 1.0, -0.3),
                    &times,
                    &y0[r * n..(r + 1) * n],
                    &tb,
                    &opts,
                );
                assert_eq!(stats_s.nfe, stats_b[r].nfe, "row {r} {times:?}");
                assert_eq!(stats_s.accepted, stats_b[r].accepted, "row {r}");
                for (k, snap) in traj_s.iter().enumerate() {
                    for i in 0..n {
                        assert_eq!(
                            snap[i].to_bits(),
                            traj_b[k][r * n + i].to_bits(),
                            "row {r} time {k} y[{i}]"
                        );
                    }
                }
            }
        }
    }

    // -- worker-pool sharding ----------------------------------------------

    /// Per-trajectory-conditioned, Clone-able dynamics for the pooled
    /// tests: keyed on the engine's stable *global* ids, so a shard that
    /// leaked local row indices would produce visibly wrong trajectories.
    #[derive(Clone)]
    struct CondDyn {
        a: Vec<f32>,
        w: Vec<f32>,
    }

    impl CondDyn {
        fn new(rng: &mut Pcg, b: usize) -> CondDyn {
            CondDyn {
                a: (0..b).map(|_| rng.range(0.3, 1.5)).collect(),
                w: (0..b).map(|_| rng.range(1.0, 20.0)).collect(),
            }
        }
    }

    impl BatchDynamics for CondDyn {
        fn dim(&self) -> usize {
            1
        }

        fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
            for (r, (id, tr)) in ids.iter().zip(t).enumerate() {
                dy[r] = self.a[*id] * (self.w[*id] * tr + y[r]).sin() - 0.3 * y[r];
            }
        }
    }

    /// Presents every row of `inner` under one fixed global id, so a solo
    /// (B=1) solve of an id-conditioned dynamics reproduces trajectory `id`
    /// of the batch exactly.
    #[derive(Clone)]
    struct PinnedId {
        inner: CondDyn,
        id: usize,
    }

    impl BatchDynamics for PinnedId {
        fn dim(&self) -> usize {
            self.inner.dim()
        }

        fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
            let ids = vec![self.id; t.len()];
            self.inner.eval(&ids, t, y, dy);
        }
    }

    #[test]
    fn pooled_drivers_bit_identical_to_serial_across_thread_counts() {
        // The determinism acceptance: sharded adaptive and fixed solves
        // must equal the serial driver bit-for-bit (states, times, stats)
        // at thread counts 1, 2, and 4, over random embedded tableaux,
        // tolerances, and per-trajectory-conditioned dynamics.
        Prop::new(12).run("pooled-solver-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let b = 5 + rng.below(8);
            let steps = 1 + rng.below(4);
            let f = CondDyn::new(rng, b);
            let y0 = gen::vec_f32(rng, b, 1.0);
            let opts = random_opts(rng);

            let serial = solve_adaptive_batch(f.clone(), 0.0, 1.0, &y0, &tb, &opts);
            let (fy, fnfe) = solve_fixed_batch(f.clone(), 0.0, 1.0, &y0, steps, &tb);
            for threads in [1usize, 2, 4] {
                let pool = Pool::new(threads);
                let pooled = solve_adaptive_batch_pooled(&pool, &f, 0.0, 1.0, &y0, &tb, &opts);
                assert_eq!(pooled.batch(), b);
                for r in 0..b {
                    assert_eq!(
                        serial.row(r)[0].to_bits(),
                        pooled.row(r)[0].to_bits(),
                        "{} threads={threads} row {r}",
                        tb.name
                    );
                    assert_eq!(serial.t[r].to_bits(), pooled.t[r].to_bits());
                    assert_stats_eq(
                        &serial.stats[r],
                        &pooled.stats[r],
                        &format!("{} threads={threads} row {r}", tb.name),
                    );
                }
                let (py, pnfe) = solve_fixed_batch_pooled(&pool, &f, 0.0, 1.0, &y0, steps, &tb);
                assert_eq!(fnfe, pnfe, "fixed NFE threads={threads}");
                for (i, (a, p)) in fy.iter().zip(&py).enumerate() {
                    assert_eq!(a.to_bits(), p.to_bits(), "fixed y[{i}] threads={threads}");
                }
            }
        });
    }

    #[test]
    fn pooled_record_and_grid_drivers_match_serial() {
        // The stage caches and grid snapshots must merge back in stable
        // trajectory order, bit-identical to the serial recordings.
        let mut rng = Pcg::new(91);
        let b = 7usize;
        let f = CondDyn::new(&mut rng, b);
        let y0 = gen::vec_f32(&mut rng, b, 1.0);
        let tb = tableau::dopri5();
        let steps = 3usize;
        let mut serial_f = f.clone();
        let rec_s = solve_fixed_batch_record(&mut serial_f, 0.0, 1.0, &y0, steps, &tb);
        let times = [0.0f32, 0.4, 1.0];
        let (traj_s, stats_s) =
            solve_to_times_batch(f.clone(), &times, &y0, &tb, &AdaptiveOpts::default());
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let rec_p = solve_fixed_batch_record_pooled(&pool, &f, 0.0, 1.0, &y0, steps, &tb);
            assert_eq!(rec_p.batch, b);
            assert_eq!(rec_p.nfe, rec_s.nfe);
            assert_eq!(rec_p.dt.to_bits(), rec_s.dt.to_bits());
            assert_eq!(rec_p.stage_t, rec_s.stage_t);
            assert_eq!(rec_p.stage_y.len(), rec_s.stage_y.len());
            for (sp, ss) in rec_p.stage_y.iter().zip(&rec_s.stage_y) {
                assert_eq!(sp.len(), ss.len());
                for (up, us) in sp.iter().zip(ss) {
                    for (a, w) in up.iter().zip(us) {
                        assert_eq!(a.to_bits(), w.to_bits(), "stage cache threads={threads}");
                    }
                }
            }
            for (a, w) in rec_p.y.iter().zip(&rec_s.y) {
                assert_eq!(a.to_bits(), w.to_bits());
            }
            let (traj_p, stats_p) =
                solve_to_times_batch_pooled(&pool, &f, &times, &y0, &tb, &AdaptiveOpts::default());
            assert_eq!(traj_p.len(), traj_s.len());
            for (k, (sp, ss)) in traj_p.iter().zip(&traj_s).enumerate() {
                for (a, w) in sp.iter().zip(ss) {
                    assert_eq!(a.to_bits(), w.to_bits(), "snap {k} threads={threads}");
                }
            }
            for (r, (a, w)) in stats_p.iter().zip(&stats_s).enumerate() {
                assert_stats_eq(a, w, &format!("grid row {r} threads={threads}"));
            }
        }
    }

    #[test]
    fn heterogeneous_batch_has_per_trajectory_nfe() {
        // The serving-path property: cheap rows must not pay for stragglers
        // (per-trajectory step control + compaction), so NFE varies by row.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        // Row identity must travel with the state (slots reorder under
        // compaction): y = [phase, freq], dy = [cos(freq*t), 0].
        let n = 2;
        let f = BatchFn::new(n, |_ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]| {
            for (r, tr) in t.iter().enumerate() {
                dy[2 * r] = (y[2 * r + 1] * tr).cos();
                dy[2 * r + 1] = 0.0;
            }
        });
        let y0 = [0.0f32, 2.0, 0.0, 10.0, 0.0, 40.0, 0.0, 160.0];
        let res = solve_adaptive_batch(f, 0.0, 1.0, &y0, &tb, &opts);
        let nfes = res.nfes();
        assert!(
            nfes.iter().any(|v| *v != nfes[0]),
            "expected heterogeneous NFE, got {nfes:?}"
        );
        // Fast oscillation must cost more than slow (paper Fig 8 mechanism).
        assert!(nfes[3] > nfes[0], "{nfes:?}");
        // Frequencies came through untouched (row order preserved).
        assert_eq!(res.row(2)[1], 40.0);
    }

    #[test]
    fn zero_batch_is_empty_result() {
        let tb = tableau::dopri5();
        let res = solve_adaptive_batch(
            Rowwise::new(|_t: f32, _y: &[f32], _dy: &mut [f32]| {}, 3),
            0.0,
            1.0,
            &[],
            &tb,
            &AdaptiveOpts::default(),
        );
        assert_eq!(res.batch(), 0);
        assert!(res.y.is_empty());
    }

    #[test]
    fn degenerate_span_finishes_immediately() {
        let tb = tableau::dopri5();
        let res = solve_adaptive_batch(
            Rowwise::new(|_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = y[0], 1),
            0.5,
            0.5,
            &[1.0, 2.0],
            &tb,
            &AdaptiveOpts::default(),
        );
        assert_eq!(res.y, vec![1.0, 2.0]);
        for s in &res.stats {
            assert_eq!(s.accepted, 0);
            assert!(s.nfe >= 1); // the stage-0 evaluation still happened
        }
    }

    // -- RegularizedBatchDynamics -----------------------------------------

    #[test]
    fn augment_and_split_roundtrip() {
        let y0 = [1.0f32, 2.0, 3.0, 4.0]; // [2, 2]
        let aug = augment_quadrature(&y0, 2);
        assert_eq!(aug, vec![1.0, 2.0, 0.0, 3.0, 4.0, 0.0]);
        let res = BatchResult {
            n: 3,
            y: aug,
            t: vec![1.0; 2],
            stats: vec![SolveStats::default(); 2],
        };
        let (y, q) = split_quadrature(&res);
        assert_eq!(y, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(q, vec![0.0, 0.0]);
    }

    #[test]
    fn regularized_constant_dynamics_quadrature() {
        // dz/dt = 1.5: d¹z = 1.5, d²z = 0.  R_1 = ∫1.5² dt = 2.25 over
        // [0, 1]; R_2 = 0 exactly (the jet of a constant field vanishes).
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        for (order, want) in [(1usize, 2.25f64), (2, 0.0)] {
            let f = SeriesFn::new(1, |_ids: &[usize], z: &SeriesVec, _t: &SeriesVec| {
                SeriesVec::fill(1.5, z.rows(), z.cols(), z.order())
            });
            let reg = RegularizedBatchDynamics::new(f, order);
            let y0 = reg.augment(&[0.0, 2.0]);
            let res = solve_adaptive_batch(reg, 0.0, 1.0, &y0, &tb, &opts);
            let (y, q) = split_quadrature(&res);
            for (r, qr) in q.iter().enumerate() {
                assert!(
                    (*qr as f64 - want).abs() < 1e-5,
                    "K={order} row {r}: {qr} vs {want}"
                );
            }
            // states integrated alongside: z(1) = z0 + 1.5
            assert!((y[0] - 1.5).abs() < 1e-5);
            assert!((y[1] - 3.5).abs() < 1e-5);
        }
    }

    #[test]
    fn regularized_rows_match_scalar_jet_augmentation_bit_for_bit() {
        // End-to-end acceptance: a batched quadrature-augmented solve must
        // reproduce, per row and bit-for-bit (state, R_K, and stats), the
        // scalar augmented solve built on the scalar ode_jet — over random
        // per-row-conditioned dynamics, embedded tableaux, and orders.
        Prop::new(25).run("regularized-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let order = 1 + rng.below(4);
            let b = 1 + rng.below(4);
            let a: Vec<f64> = gen::vec_f64(rng, b, -1.2, 1.2);
            let w: Vec<f64> = gen::vec_f64(rng, b, 0.5, 3.0);
            let y0 = gen::vec_f32(rng, b, 1.0);
            let opts = AdaptiveOpts {
                rtol: 1e-5,
                atol: 1e-7,
                h_init: Some(0.1),
                ..Default::default()
            };

            // z' = a_id · tanh(z) + w_id · sin(t), series-generic.
            let f = SeriesFn::new(1, |ids: &[usize], z: &SeriesVec, t: &SeriesVec| {
                let asel: Vec<f64> = ids.iter().map(|id| a[*id]).collect();
                let wsel: Vec<f64> = ids.iter().map(|id| w[*id]).collect();
                z.tanh().scale_rows(&asel).add(&t.sin_cos().0.scale_rows(&wsel))
            });
            let reg = RegularizedBatchDynamics::new(f, order);
            let aug0 = reg.augment(&y0);
            let batched = solve_adaptive_batch(reg, 0.0, 1.0, &aug0, &tb, &opts);

            for r in 0..b {
                let (ar, wr) = (a[r], w[r]);
                let scalar_aug = |t: f32, y: &[f32], dy: &mut [f32]| {
                    let jets = ode_jet(
                        |z: &Series, ts: &Series| {
                            z.tanh().scale(ar).add(&ts.sin_cos().0.scale(wr))
                        },
                        y[0] as f64,
                        t as f64,
                        order,
                    );
                    dy[0] = jets[0] as f32;
                    let v = jets[order - 1];
                    dy[1] = (v * v / 1.0) as f32;
                };
                let scalar = solve_adaptive(
                    scalar_aug,
                    0.0,
                    1.0,
                    &[y0[r], 0.0],
                    &tb,
                    &opts,
                );
                for i in 0..2 {
                    assert_eq!(
                        scalar.y[i].to_bits(),
                        batched.row(r)[i].to_bits(),
                        "{} K={order} row {r} y[{i}]",
                        tb.name
                    );
                }
                assert_stats_eq(
                    &scalar.stats,
                    &batched.stats[r],
                    &format!("{} K={order} row {r}", tb.name),
                );
            }
        });
    }

    // -- LogDetBatchDynamics ----------------------------------------------

    #[test]
    fn logdet_linear_field_integrates_the_trace() {
        // f = z·W + b has constant divergence tr(W), so ℓ(1) = tr(W)
        // whatever the trajectory does.
        use crate::nn::Mlp;
        let mut mlp = Mlp::new(2, &[], false, 0);
        mlp.params = vec![0.4, 0.9, -0.2, -0.1, 0.3, -0.6]; // W, then b
        let tr = 0.4 - 0.1;
        let ld = LogDetBatchDynamics::new(mlp, Divergence::Exact);
        let y0 = [0.5f32, -1.0, 2.0, 0.25];
        let aug = ld.augment(&y0);
        assert_eq!(aug.len(), 6);
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        let res = solve_adaptive_batch(ld, 0.0, 1.0, &aug, &tb, &opts);
        let (y, cols) = split_aug_cols(&res, 2);
        assert_eq!(y.len(), 4);
        assert_eq!(cols.len(), 1);
        for (r, l) in cols[0].iter().enumerate() {
            assert!((*l as f64 - tr).abs() < 1e-5, "row {r}: {l} vs {tr}");
        }
    }

    #[test]
    fn logdet_composes_with_the_quadrature_column() {
        // A constant field dz/dt = c: divergence 0 (ℓ stays 0), and
        // R_1 = ∫‖c‖²/n dt = (1.5² + 0.5²)/2 over [0, 1] — one augmented
        // solve yields dy, log-det, and the R_K quadrature.
        use crate::nn::Mlp;
        let mut mlp = Mlp::new(2, &[], false, 0);
        mlp.params = vec![0.0, 0.0, 0.0, 0.0, 1.5, 0.5]; // W = 0, b = c
        let ld = LogDetBatchDynamics::new(mlp, Divergence::Exact).with_regularizer(1);
        let y0 = [0.0f32, 2.0];
        let aug = ld.augment(&y0);
        assert_eq!(aug, vec![0.0, 2.0, 0.0, 0.0]);
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        let res = solve_adaptive_batch(ld, 0.0, 1.0, &aug, &tb, &opts);
        let (y, cols) = split_aug_cols(&res, 2);
        assert!((y[0] - 1.5).abs() < 1e-5);
        assert!((y[1] - 2.5).abs() < 1e-5);
        assert!(cols[0][0].abs() < 1e-6, "log-det of a constant field");
        assert!((cols[1][0] - 1.25).abs() < 1e-5, "R_1 = {}", cols[1][0]);
    }

    #[test]
    fn pooled_logdet_solves_bit_identical_to_serial() {
        // The satellite acceptance: log-det-augmented solves (exact AND
        // fixed-seed Hutchinson, with the composed R_K column) must be
        // bit-identical between the serial driver and the chunk-queue
        // pooled driver at threads 1, 2, and 4 — id-keyed probes included.
        use crate::nn::Cnf;
        let mut rng = Pcg::new(41);
        let mut cnf = Cnf::new(2, &[4], 13);
        for p in cnf.params.iter_mut() {
            if *p == 0.0 {
                *p = rng.range(-0.6, 0.6);
            }
        }
        let b = 9usize;
        let y0 = gen::vec_f32(&mut rng, b * 2, 1.0);
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-4, atol: 1e-6, ..Default::default() };
        for div in [Divergence::Exact, Divergence::Hutchinson { probes: 2, seed: 3 }] {
            let ld = LogDetBatchDynamics::new(cnf.clone(), div).with_regularizer(2);
            let aug = ld.augment(&y0);
            let serial = solve_adaptive_batch(ld.clone(), 0.0, 1.0, &aug, &tb, &opts);
            for threads in [1usize, 2, 4] {
                let pool = Pool::new(threads);
                let pooled = solve_adaptive_batch_pooled(&pool, &ld, 0.0, 1.0, &aug, &tb, &opts);
                assert_eq!(pooled.batch(), b);
                for r in 0..b {
                    for i in 0..4 {
                        assert_eq!(
                            serial.row(r)[i].to_bits(),
                            pooled.row(r)[i].to_bits(),
                            "threads={threads} row {r} col {i}"
                        );
                    }
                    assert_stats_eq(
                        &serial.stats[r],
                        &pooled.stats[r],
                        &format!("threads={threads} row {r}"),
                    );
                }
            }
        }
    }

    #[test]
    fn regularized_multi_dim_normalizes_by_dim() {
        // n = 2 with identical decoupled columns: the integrand averages
        // ‖d^K y‖² over dims (Appendix B), so R_K equals the 1-D value.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        let make = |n: usize| {
            SeriesFn::new(n, move |_ids: &[usize], z: &SeriesVec, _t: &SeriesVec| z.clone())
        };
        let reg1 = RegularizedBatchDynamics::new(make(1), 2);
        let res1 = solve_adaptive_batch(reg1, 0.0, 1.0, &[0.7, 0.0], &tb, &opts);
        let reg2 = RegularizedBatchDynamics::new(make(2), 2);
        let res2 = solve_adaptive_batch(reg2, 0.0, 1.0, &[0.7, 0.7, 0.0], &tb, &opts);
        let (_, q1) = split_quadrature(&res1);
        let (_, q2) = split_quadrature(&res2);
        assert!(
            (q1[0] - q2[0]).abs() < 1e-4 * q1[0].abs().max(1.0),
            "{} vs {}",
            q1[0],
            q2[0]
        );
    }

    // -- working-set negative paths and incremental admission ---------------

    /// Build a working set of `b` one-dim rows with distinguishable states.
    fn seeded_ws(b: usize) -> WorkingSet {
        let mut ws = WorkingSet::new(1, 2);
        let ids: Vec<usize> = (0..b).map(|r| 100 + r).collect();
        let y0: Vec<f32> = (0..b).map(|r| r as f32 + 0.5).collect();
        ws.push_rows(&ids, &y0, 0.0, 1.0, &AdaptiveOpts::default());
        for s in 0..b {
            ws.h[s] = 0.01 * (s + 1) as f32;
            ws.stats[s].nfe = s + 1;
        }
        ws
    }

    #[test]
    fn retire_none_is_a_noop() {
        let mut ws = seeded_ws(4);
        let before_y = ws.y.clone();
        let out = ws.retire(&[]);
        assert!(out.is_empty());
        assert_eq!(ws.act, 4);
        assert_eq!(ws.y, before_y);
        assert_eq!(ws.idx, vec![100, 101, 102, 103]);
    }

    #[test]
    fn retire_all_drains_in_finished_order() {
        let mut ws = seeded_ws(3);
        let out = ws.retire(&[0, 1, 2]);
        assert_eq!(ws.act, 0);
        assert_eq!(out.len(), 3);
        for (k, r) in out.iter().enumerate() {
            assert_eq!(r.id, 100 + k, "retired rows keep finished order");
            assert_eq!(r.y, vec![k as f32 + 0.5]);
            assert_eq!(r.stats.nfe, k + 1);
            assert_eq!(r.stats.h_final, 0.01 * (k + 1) as f32);
        }
    }

    #[test]
    fn retire_last_row_needs_no_backfill() {
        let mut ws = seeded_ws(3);
        let out = ws.retire(&[2]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 102);
        assert_eq!(ws.act, 2);
        // Surviving rows are untouched (no spurious swap from the tail).
        assert_eq!(ws.idx[..2], [100, 101]);
        assert_eq!(ws.y[..2], [0.5, 1.5]);
    }

    #[test]
    fn retire_middle_backfills_with_last_live_row() {
        let mut ws = seeded_ws(4);
        let out = ws.retire(&[1]);
        assert_eq!(out[0].id, 101);
        assert_eq!(ws.act, 3);
        // Slot 1 now holds what was the last active row; slot order is
        // unstable but ids and states travel together.
        assert_eq!(ws.idx[..3], [100, 103, 102]);
        assert_eq!(ws.y[..3], [0.5, 3.5, 2.5]);
        assert_eq!(ws.stats[1].nfe, 4);
    }

    #[test]
    fn to_times_batch_single_point_grid_is_identity() {
        // A one-entry grid has no segments: the snapshot is y0 itself and
        // no model evaluation is spent.
        let tb = tableau::dopri5();
        let y0 = [1.25f32, -0.5, 0.75, 2.0];
        let (traj, stats) = solve_to_times_batch(
            Rowwise::new(test_dynamics(5.0, 1.0, -0.2), 2),
            &[0.37],
            &y0,
            &tb,
            &AdaptiveOpts::default(),
        );
        assert_eq!(traj.len(), 1);
        for (a, w) in traj[0].iter().zip(&y0) {
            assert_eq!(a.to_bits(), w.to_bits());
        }
        for s in &stats {
            assert_eq!(s.nfe, 0);
            assert_eq!(s.accepted, 0);
            assert_eq!(s.rejected, 0);
        }
    }

    #[test]
    fn incremental_admission_matches_solo_solves_bit_for_bit() {
        // The serving-path property at the stepper level: trajectories that
        // join the active set at random attempts (a seeded arrival process)
        // must produce exactly the states/stats of their own solo solves —
        // batch composition only regroups model evaluations.  Where every
        // request is admitted up front the stepper must also reproduce
        // `solve_adaptive_batch` (which is itself built on it).
        Prop::new(20).run("stepper-admission-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let b = 3 + rng.below(6);
            let f = CondDyn::new(rng, b);
            let y0 = gen::vec_f32(rng, b, 1.0);
            let opts = random_opts(rng);

            // Arrival schedule: request r joins after `arrive[r]` attempts.
            let arrive: Vec<usize> = (0..b).map(|_| rng.below(6)).collect();
            let mut stepper = BatchStepper::new(f.clone(), &tb);
            let mut done: Vec<Retired> = Vec::new();
            let mut step_no = 0usize;
            let mut next = 0usize; // requests admitted in id order
            let mut order: Vec<usize> = (0..b).collect();
            order.sort_by_key(|r| arrive[*r]);
            while next < b || stepper.active() > 0 {
                while next < b && arrive[order[next]] <= step_no {
                    let r = order[next];
                    done.extend(stepper.admit(
                        &[r],
                        &y0[r..r + 1],
                        0.0,
                        1.0,
                        &opts,
                        None,
                    ));
                    next += 1;
                }
                done.extend(stepper.step());
                step_no += 1;
            }
            assert_eq!(done.len(), b);
            for r in done {
                // The solo reference sees the same conditioning: the batch
                // driver numbers its single row 0, so pin the global id.
                let solo = solve_adaptive_batch(
                    PinnedId { inner: f.clone(), id: r.id },
                    0.0,
                    1.0,
                    &y0[r.id..r.id + 1],
                    &tb,
                    &opts,
                );
                assert_eq!(
                    r.y[0].to_bits(),
                    solo.y[0].to_bits(),
                    "{} id {}",
                    tb.name,
                    r.id
                );
                assert_eq!(r.t.to_bits(), solo.t[0].to_bits());
                assert_stats_eq(&r.stats, &solo.stats[0], &format!("{} id {}", tb.name, r.id));
            }

            // All-admitted-at-t0 == the batch driver, bit for bit.
            let mut all = BatchStepper::new(f.clone(), &tb);
            let ids: Vec<usize> = (0..b).collect();
            let mut got = all.admit(&ids, &y0, 0.0, 1.0, &opts, None);
            while all.active() > 0 {
                got.append(&mut all.step());
            }
            let batch = solve_adaptive_batch(f.clone(), 0.0, 1.0, &y0, &tb, &opts);
            for r in got {
                assert_eq!(r.y[0].to_bits(), batch.row(r.id)[0].to_bits());
                assert_stats_eq(&r.stats, &batch.stats[r.id], "all-at-t0");
            }
        });
    }

    #[test]
    fn pooled_eval_bit_identical_to_serial_across_thread_counts() {
        // PooledEval shards each model evaluation across workers; the solve
        // it feeds must equal the serial one bit-for-bit at 1, 2, and 4
        // threads (rows are independent and ids pass through verbatim).
        let mut rng = Pcg::new(733);
        let b = 13usize;
        let f = CondDyn::new(&mut rng, b);
        let y0 = gen::vec_f32(&mut rng, b, 1.0);
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let serial = solve_adaptive_batch(f.clone(), 0.0, 1.0, &y0, &tb, &opts);
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let wrapped =
                solve_adaptive_batch(PooledEval::new(&pool, f.clone()), 0.0, 1.0, &y0, &tb, &opts);
            for r in 0..b {
                assert_eq!(
                    serial.row(r)[0].to_bits(),
                    wrapped.row(r)[0].to_bits(),
                    "threads={threads} row {r}"
                );
                assert_stats_eq(
                    &serial.stats[r],
                    &wrapped.stats[r],
                    &format!("threads={threads} row {r}"),
                );
            }
        }
    }
}
