//! Batched multi-trajectory RK engine — the serving-path primitive.
//!
//! Integrates B independent ODE systems in one pass over an SoA state
//! matrix `[B, n]`.  A [`BatchDynamics`] is evaluated **once per stage for
//! the whole active batch** instead of once per trajectory, which is where
//! the throughput comes from when one model evaluation has fixed dispatch
//! cost (an XLA executable launch, a GPU kernel, a closure call).
//!
//! Each trajectory keeps its own adaptive step size, PI-controller history,
//! and NFE/accepted/rejected counters; **finished trajectories are swapped
//! out of the working set** (active-set compaction) so stragglers don't pay
//! for the whole batch.  The per-trajectory arithmetic is the shared stage
//! machinery of [`super::stage`], applied in the same operation order as the
//! scalar driver — a batched trajectory therefore reproduces
//! [`super::adaptive::solve_adaptive`] **bit-for-bit** in state, NFE,
//! accepted and rejected counts (property-tested below).
//!
//! Tableaux without an embedded pair fall back to per-trajectory scalar
//! step-doubling solves (still through the same entry points, still
//! per-trajectory stats), since step doubling re-enters the fixed driver
//! and cannot share stage evaluations across rows with distinct h.

use super::adaptive::{solve_adaptive_mut, AdaptiveOpts, SolveStats};
use super::stage::{self, TableauCoeffs};
use super::tableau::Tableau;
use super::Dynamics;
use crate::tensor::axpy;

/// Dynamics over a batch of trajectories: `dy[r] = f(t[r], y[r])` for every
/// active row r, where `y` and `dy` are row-major `[t.len(), dim()]`.
/// Implementations see one call per RK stage for the whole active set; rows
/// carry *per-trajectory* times because adaptive trajectories decouple.
///
/// `ids[r]` is the **original trajectory index** of row r.  The engine
/// compacts finished trajectories out of the working set, so row position
/// is not stable — models with per-trajectory conditioning (per-request
/// parameters, per-seed coefficients) must key on `ids`, never on r.
pub trait BatchDynamics {
    /// Per-trajectory state dimension n (must be positive).
    fn dim(&self) -> usize;
    /// Evaluate all rows: `t.len()` trajectories, `y`/`dy` of `t.len() * dim()`.
    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]);
}

/// Adapter: drive a scalar [`Dynamics`] once per row.  This is how
/// per-example XLA executables (batch-1 artifacts) and test closures plug
/// into the batched engine; a native vectorized model should implement
/// [`BatchDynamics`] directly (see [`BatchFn`]).
pub struct Rowwise<F> {
    f: F,
    n: usize,
}

impl<F: Dynamics> Rowwise<F> {
    pub fn new(f: F, n: usize) -> Rowwise<F> {
        assert!(n > 0, "Rowwise: state dimension must be positive");
        Rowwise { f, n }
    }

    /// Recover the wrapped dynamics (e.g. to read eval counters).
    pub fn into_inner(self) -> F {
        self.f
    }
}

impl<F: Dynamics> BatchDynamics for Rowwise<F> {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, _ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        let n = self.n;
        debug_assert_eq!(y.len(), t.len() * n);
        debug_assert_eq!(dy.len(), t.len() * n);
        for (r, tr) in t.iter().enumerate() {
            self.f
                .eval(*tr, &y[r * n..(r + 1) * n], &mut dy[r * n..(r + 1) * n]);
        }
    }
}

/// Adapter: a natively-vectorized batch closure `(ids, t_per_row, Y, dY)`
/// plus its row dimension.  The closure receives the engine's stable
/// trajectory ids so per-trajectory-conditioned models can key their
/// parameters correctly under compaction (row position is NOT stable).
pub struct BatchFn<F> {
    f: F,
    n: usize,
}

impl<F: FnMut(&[usize], &[f32], &[f32], &mut [f32])> BatchFn<F> {
    pub fn new(n: usize, f: F) -> BatchFn<F> {
        assert!(n > 0, "BatchFn: state dimension must be positive");
        BatchFn { f, n }
    }
}

impl<F: FnMut(&[usize], &[f32], &[f32], &mut [f32])> BatchDynamics for BatchFn<F> {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]) {
        (self.f)(ids, t, y, dy)
    }
}

/// View one trajectory of a [`BatchDynamics`] as a scalar [`Dynamics`]
/// (used by the step-doubling fallback).
struct OneRow<'a, F: BatchDynamics> {
    f: &'a mut F,
    id: usize,
}

impl<F: BatchDynamics> Dynamics for OneRow<'_, F> {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]) {
        self.f.eval(&[self.id], &[t], y, dy);
    }
}

/// Result of a batched solve, in the caller's original trajectory order
/// (compaction is internal and never observable).
#[derive(Clone, Debug)]
pub struct BatchResult {
    /// Per-trajectory state dimension.
    pub n: usize,
    /// Final states, row-major `[B, n]`.
    pub y: Vec<f32>,
    /// Final integration time per trajectory.
    pub t: Vec<f32>,
    /// Per-trajectory solver statistics.
    pub stats: Vec<SolveStats>,
}

impl BatchResult {
    pub fn batch(&self) -> usize {
        self.stats.len()
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.y[r * self.n..(r + 1) * self.n]
    }

    /// Per-trajectory NFE — the paper's headline metric, per example.
    pub fn nfes(&self) -> Vec<usize> {
        self.stats.iter().map(|s| s.nfe).collect()
    }
}

/// Adaptively integrate B trajectories from t0 to t1.  `y0` is row-major
/// `[B, dim]`; B is inferred from `y0.len() / f.dim()`.
pub fn solve_adaptive_batch<F: BatchDynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> BatchResult {
    solve_adaptive_batch_mut(&mut f, t0, t1, y0, tb, opts)
}

/// `&mut`-receiver variant (keeps ownership with the caller).
pub fn solve_adaptive_batch_mut<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> BatchResult {
    batch_segment(f, t0, t1, y0, tb, opts, None)
}

/// One batched segment, optionally warm-started with a per-trajectory
/// initial step magnitude (grid solving re-uses each trajectory's own
/// final h, exactly like the scalar `solve_to_times`).
fn batch_segment<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    h_init_rows: Option<&[f32]>,
) -> BatchResult {
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(
        y0.len() % n,
        0,
        "batch state length {} is not a multiple of dim {n}",
        y0.len()
    );
    if tb.e.is_some() {
        solve_embedded_batch(f, t0, t1, y0, tb, opts, h_init_rows)
    } else {
        solve_doubling_rows(f, t0, t1, y0, tb, opts, h_init_rows)
    }
}

/// The batched embedded-pair driver: per-trajectory adaptive step control
/// with active-set compaction.
fn solve_embedded_batch<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    h_init_rows: Option<&[f32]>,
) -> BatchResult {
    let n = f.dim();
    let b = y0.len() / n;
    let tbf = TableauCoeffs::new(tb);
    // Hard precondition, matching the scalar driver: a silently-empty `e`
    // would zero every error estimate and accept every step.
    assert!(tbf.has_embedded(), "solve_embedded_batch needs an embedded pair");
    let span = t1 - t0;
    let sg = span.signum();
    let h_max = opts.h_max.unwrap_or(span.abs());
    let inv_order = tbf.inv_order();

    // Outputs, in original trajectory order.
    let mut out_y = y0.to_vec();
    let mut out_t = vec![t0; b];
    let mut out_stats = vec![SolveStats::default(); b];
    if b == 0 {
        return BatchResult { n, y: out_y, t: out_t, stats: out_stats };
    }

    // Working set, compacted to the active prefix.  `idx[s]` is the
    // original trajectory occupying slot s.
    let mut idx: Vec<usize> = (0..b).collect();
    let mut act = b;
    let mut t = vec![t0; b];
    let mut h = vec![0.0f32; b];
    let mut prev_err = vec![1.0f32; b]; // neutral PI history
    let mut stats = vec![SolveStats::default(); b];
    let mut y = y0.to_vec();
    // One [B, n] matrix per stage; allocated once for the whole solve.
    let mut ks: Vec<Vec<f32>> = (0..tbf.stages).map(|_| vec![0.0f32; b * n]).collect();
    let mut ystage = vec![0.0f32; b * n];
    let mut ynew = vec![0.0f32; b * n];
    let mut errv = vec![0.0f32; n];
    let mut tstage = vec![0.0f32; b];
    let mut finished: Vec<usize> = Vec::with_capacity(b);
    let mut refresh: Vec<usize> = Vec::with_capacity(b);
    let mut ids_scratch: Vec<usize> = vec![0; b];

    // Stage-0 derivative for every trajectory: one batched evaluation
    // (reused by FSAL across accepted steps, exactly like the scalar path).
    f.eval(&idx[..act], &t[..act], &y[..act * n], &mut ks[0][..act * n]);
    for s in stats.iter_mut().take(act) {
        s.nfe += 1;
    }

    // Initial step per trajectory: warm-start rows > explicit opts.h_init >
    // the batched Hairer heuristic (h0 per row, ONE probe evaluation for the
    // whole batch, h1 per row — one extra NFE per trajectory, same as
    // scalar).
    if let Some(rows) = h_init_rows {
        assert_eq!(rows.len(), b, "h_init_rows length");
        for s in 0..act {
            h[s] = rows[s].abs().min(h_max).max(1e-10);
        }
    } else if let Some(h0) = opts.h_init {
        for hs in h.iter_mut().take(act) {
            *hs = h0.abs().min(h_max).max(1e-10);
        }
    } else {
        for s in 0..act {
            let yr = &y[s * n..(s + 1) * n];
            let f0 = &ks[0][s * n..(s + 1) * n];
            let h0 = stage::h0_estimate(yr, f0, opts.atol, opts.rtol);
            // Euler probe state, staged for one batched evaluation.
            let pr = &mut ystage[s * n..(s + 1) * n];
            for i in 0..n {
                pr[i] = yr[i] + h0 * f0[i];
            }
            tstage[s] = t[s] + h0;
            h[s] = h0; // stash h0 until the probe comes back
        }
        f.eval(&idx[..act], &tstage[..act], &ystage[..act * n], &mut ynew[..act * n]);
        for s in 0..act {
            stats[s].nfe += 1;
            let yr = &y[s * n..(s + 1) * n];
            let f0 = &ks[0][s * n..(s + 1) * n];
            let f1 = &ynew[s * n..(s + 1) * n];
            let h1 = stage::h1_estimate(yr, f0, f1, h[s], tbf.order, opts.atol, opts.rtol);
            h[s] = h1.min(h_max).max(1e-10);
        }
    }

    // Trajectories that are already done (t0 == t1, or max_steps == 0).
    finished.clear();
    for s in 0..act {
        let live = (t[s] - t1).abs() > 1e-9 && (t1 - t[s]) * sg > 0.0;
        let exhausted = stats[s].accepted + stats[s].rejected >= opts.max_steps;
        if !live || exhausted {
            finished.push(s);
        }
    }
    retire(
        &finished, &mut act, n, &mut idx, &mut t, &mut h, &mut prev_err, &mut stats,
        &mut y, &mut ks, &mut out_y, &mut out_t, &mut out_stats,
    );

    while act > 0 {
        // Clamp and sign each trajectory's attempted step.
        for s in 0..act {
            h[s] = h[s].min((t1 - t[s]).abs()).min(h_max) * sg;
        }

        // Stages 1..S: stage state for all rows, then ONE model evaluation
        // for the whole active batch.  Per-row operation order matches
        // `stage::accumulate` exactly (copy, then axpy in ascending stage
        // order, zero coefficients skipped) so results are bit-identical to
        // the scalar driver.
        for i in 0..tbf.a.len() {
            let a_row = &tbf.a[i];
            ystage[..act * n].copy_from_slice(&y[..act * n]);
            for (j, aj) in a_row.iter().enumerate() {
                let kj = &ks[j];
                for s in 0..act {
                    let cj = *aj * h[s];
                    if cj != 0.0 {
                        axpy(cj, &kj[s * n..(s + 1) * n], &mut ystage[s * n..(s + 1) * n]);
                    }
                }
            }
            let ci = tbf.c[i + 1];
            for s in 0..act {
                tstage[s] = t[s] + ci * h[s];
            }
            let (_, rest) = ks.split_at_mut(i + 1);
            f.eval(&idx[..act], &tstage[..act], &ystage[..act * n], &mut rest[0][..act * n]);
            for s in stats.iter_mut().take(act) {
                s.nfe += 1;
            }
        }

        // Propagating solution for all rows.
        ynew[..act * n].copy_from_slice(&y[..act * n]);
        for (j, bj) in tbf.b.iter().enumerate() {
            let kj = &ks[j];
            for s in 0..act {
                let cj = *bj * h[s];
                if cj != 0.0 {
                    axpy(cj, &kj[s * n..(s + 1) * n], &mut ynew[s * n..(s + 1) * n]);
                }
            }
        }

        // Per-trajectory embedded error, accept/reject, controller update.
        finished.clear();
        refresh.clear();
        for s in 0..act {
            for v in errv.iter_mut() {
                *v = 0.0;
            }
            for (j, ej) in tbf.e.iter().enumerate() {
                let cj = *ej * h[s];
                if cj != 0.0 {
                    axpy(cj, &ks[j][s * n..(s + 1) * n], &mut errv);
                }
            }
            let err = stage::error_norm(
                &errv,
                &y[s * n..(s + 1) * n],
                &ynew[s * n..(s + 1) * n],
                opts.atol,
                opts.rtol,
            );
            let hs = h[s];
            if err <= 1.0 || hs.abs() <= 1e-9 {
                // accept
                t[s] += hs;
                y[s * n..(s + 1) * n].copy_from_slice(&ynew[s * n..(s + 1) * n]);
                stats[s].accepted += 1;
                if tbf.fsal {
                    // per-row FSAL: k_last at the accepted point becomes k0
                    let last = tbf.stages - 1;
                    let (k0, tail) = ks.split_at_mut(1);
                    k0[0][s * n..(s + 1) * n]
                        .swap_with_slice(&mut tail[last - 1][s * n..(s + 1) * n]);
                } else if (t[s] - t1).abs() > 1e-9 {
                    refresh.push(s); // fresh f(t, y), batched below
                }
                let errc = err.max(1e-10);
                let factor = stage::accept_factor(opts, inv_order, errc, prev_err[s]);
                h[s] = hs.abs() * factor.clamp(opts.factor_min, opts.factor_max);
                prev_err[s] = errc;
            } else {
                // reject: shrink and retry (FSAL stage 0 is still valid)
                stats[s].rejected += 1;
                let factor = stage::reject_factor(opts, inv_order, err);
                h[s] = hs.abs() * factor.clamp(opts.factor_min, 1.0);
            }
            let live = (t[s] - t1).abs() > 1e-9 && (t1 - t[s]) * sg > 0.0;
            let exhausted = stats[s].accepted + stats[s].rejected >= opts.max_steps;
            if !live || exhausted {
                finished.push(s);
            }
        }

        // Batched stage-0 refresh for non-FSAL accepts still in flight
        // (the scalar driver spends this NFE immediately after accepting;
        // the value is identical, the dispatch is amortized).
        if !refresh.is_empty() {
            let m = refresh.len();
            for (q, &s) in refresh.iter().enumerate() {
                ystage[q * n..(q + 1) * n].copy_from_slice(&y[s * n..(s + 1) * n]);
                tstage[q] = t[s];
                ids_scratch[q] = idx[s];
            }
            f.eval(&ids_scratch[..m], &tstage[..m], &ystage[..m * n], &mut ynew[..m * n]);
            for (q, &s) in refresh.iter().enumerate() {
                ks[0][s * n..(s + 1) * n].copy_from_slice(&ynew[q * n..(q + 1) * n]);
                stats[s].nfe += 1;
            }
        }

        retire(
            &finished, &mut act, n, &mut idx, &mut t, &mut h, &mut prev_err, &mut stats,
            &mut y, &mut ks, &mut out_y, &mut out_t, &mut out_stats,
        );
    }

    BatchResult { n, y: out_y, t: out_t, stats: out_stats }
}

/// Write finished trajectories to the output arrays and compact the active
/// prefix by moving the last active row into each vacated slot.  `finished`
/// must be ascending slot indices from the current attempt.
fn retire(
    finished: &[usize],
    act: &mut usize,
    n: usize,
    idx: &mut [usize],
    t: &mut [f32],
    h: &mut [f32],
    prev_err: &mut [f32],
    stats: &mut [SolveStats],
    y: &mut [f32],
    ks: &mut [Vec<f32>],
    out_y: &mut [f32],
    out_t: &mut [f32],
    out_stats: &mut [SolveStats],
) {
    for &s in finished {
        let orig = idx[s];
        out_y[orig * n..(orig + 1) * n].copy_from_slice(&y[s * n..(s + 1) * n]);
        out_t[orig] = t[s];
        let mut st = stats[s].clone();
        st.h_final = h[s];
        out_stats[orig] = st;
    }
    // Descending order: every slot above the one being filled is already
    // retired, so the last active row is always a live trajectory.
    for &s in finished.iter().rev() {
        *act -= 1;
        let last = *act;
        if s != last {
            let (head, tail) = y.split_at_mut(last * n);
            head[s * n..(s + 1) * n].copy_from_slice(&tail[..n]);
            // Only stage 0 survives across attempts (FSAL / refresh); the
            // other stage matrices are rewritten from scratch before every
            // read, so compacting them would be wasted memcpy.
            {
                let k0 = &mut ks[0];
                let (kh, kt) = k0.split_at_mut(last * n);
                kh[s * n..(s + 1) * n].copy_from_slice(&kt[..n]);
            }
            t[s] = t[last];
            h[s] = h[last];
            prev_err[s] = prev_err[last];
            stats[s] = stats[last].clone();
            idx[s] = idx[last];
        }
    }
}

/// Per-trajectory fallback for tableaux without an embedded pair: scalar
/// step-doubling solves through a one-row view of the batch dynamics.
fn solve_doubling_rows<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
    h_init_rows: Option<&[f32]>,
) -> BatchResult {
    let n = f.dim();
    let b = y0.len() / n;
    let mut out_y = vec![0.0f32; b * n];
    let mut out_t = vec![t0; b];
    let mut out_stats = vec![SolveStats::default(); b];
    for r in 0..b {
        let mut row_opts = opts.clone();
        if let Some(rows) = h_init_rows {
            row_opts.h_init = Some(rows[r].abs());
        }
        let mut one = OneRow { f: &mut *f, id: r };
        let res = solve_adaptive_mut(&mut one, t0, t1, &y0[r * n..(r + 1) * n], tb, &row_opts);
        out_y[r * n..(r + 1) * n].copy_from_slice(&res.y);
        out_t[r] = res.t;
        out_stats[r] = res.stats;
    }
    BatchResult { n, y: out_y, t: out_t, stats: out_stats }
}

/// Fixed-grid batched driver: B trajectories share one uniform step grid
/// (one model evaluation per stage per step for the whole batch).  Returns
/// the final `[B, n]` state and the exact per-trajectory NFE.
pub fn solve_fixed_batch<F: BatchDynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, Vec<usize>) {
    solve_fixed_batch_mut(&mut f, t0, t1, y0, steps, tb)
}

pub fn solve_fixed_batch_mut<F: BatchDynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, Vec<usize>) {
    assert!(steps > 0);
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(y0.len() % n, 0, "batch state length vs dim");
    let b = y0.len() / n;
    let tbf = TableauCoeffs::new(tb);
    let dt = (t1 - t0) / steps as f32;
    let mut y = y0.to_vec();
    let mut ynew = vec![0.0f32; b * n];
    let mut ystage = vec![0.0f32; b * n];
    let mut ks: Vec<Vec<f32>> = (0..tbf.stages).map(|_| vec![0.0f32; b * n]).collect();
    let mut tstage = vec![0.0f32; b];
    let ids: Vec<usize> = (0..b).collect();
    if b == 0 {
        return (y, vec![]);
    }

    for s in 0..steps {
        let t = t0 + s as f32 * dt;
        // stage 0
        for ts in tstage.iter_mut() {
            *ts = t;
        }
        {
            let (k0, _) = ks.split_at_mut(1);
            f.eval(&ids, &tstage, &y, &mut k0[0]);
        }
        // stages 1..S — the grid is shared, so the whole [B, n] matrix gets
        // one flat axpy per stage coefficient (elementwise identical to the
        // per-row scalar op sequence).
        for i in 0..tbf.a.len() {
            ystage.copy_from_slice(&y);
            for (j, aj) in tbf.a[i].iter().enumerate() {
                let cj = *aj * dt;
                if cj != 0.0 {
                    axpy(cj, &ks[j], &mut ystage);
                }
            }
            let tc = t + tbf.c[i + 1] * dt;
            for ts in tstage.iter_mut() {
                *ts = tc;
            }
            let (_, rest) = ks.split_at_mut(i + 1);
            f.eval(&ids, &tstage, &ystage, &mut rest[0]);
        }
        // combine
        ynew.copy_from_slice(&y);
        for (j, bj) in tbf.b.iter().enumerate() {
            let cj = *bj * dt;
            if cj != 0.0 {
                axpy(cj, &ks[j], &mut ynew);
            }
        }
        std::mem::swap(&mut y, &mut ynew);
    }
    (y, vec![steps * tbf.stages; b])
}

/// Batched grid-output solve (the latent-ODE evaluation path): adaptively
/// integrate all B trajectories through a shared grid of output times,
/// returning the `[B, n]` state at every grid point plus per-trajectory
/// cumulative stats.  Each trajectory's step size is warm-started from its
/// own previous segment (magnitude only, so decreasing/reverse-time grids
/// are safe), exactly like the scalar `solve_to_times`.
pub fn solve_to_times_batch<F: BatchDynamics>(
    mut f: F,
    times: &[f32],
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> (Vec<Vec<f32>>, Vec<SolveStats>) {
    let n = f.dim();
    assert!(n > 0, "BatchDynamics::dim() must be positive");
    assert_eq!(y0.len() % n, 0, "batch state length vs dim");
    let b = y0.len() / n;
    let mut traj = Vec::with_capacity(times.len());
    traj.push(y0.to_vec());
    let mut agg = vec![SolveStats::default(); b];
    let mut y = y0.to_vec();
    let mut warm: Option<Vec<f32>> = None;
    for w in times.windows(2) {
        if (w[1] - w[0]).abs() <= 1e-9 {
            traj.push(y.clone());
            continue;
        }
        let res = batch_segment(&mut f, w[0], w[1], &y, tb, opts, warm.as_deref());
        y = res.y;
        for (a, s) in agg.iter_mut().zip(&res.stats) {
            a.nfe += s.nfe;
            a.accepted += s.accepted;
            a.rejected += s.rejected;
            a.h_final = s.h_final;
        }
        warm = Some(
            res.stats
                .iter()
                .map(|s| s.h_final.abs().max(1e-6))
                .collect(),
        );
        traj.push(y.clone());
    }
    (traj, agg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::adaptive::{solve_adaptive, solve_to_times};
    use crate::solvers::fixed::solve_fixed;
    use crate::solvers::tableau;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    const EMBEDDED: &[&str] = &["heun_euler", "bosh3", "fehlberg45", "cash_karp", "dopri5"];

    /// A nonlinear, time-dependent test dynamics parameterized by (w, a, c);
    /// state-dependent stiffness makes different rows take different step
    /// sequences.  Stateless, so scalar and batched evaluation orders agree.
    fn test_dynamics(w: f32, a: f32, c: f32) -> impl FnMut(f32, &[f32], &mut [f32]) {
        move |t, y, dy| {
            for (d, yi) in dy.iter_mut().zip(y) {
                *d = a * (w * t + yi).sin() + c * yi;
            }
        }
    }

    fn random_opts(rng: &mut Pcg) -> AdaptiveOpts {
        let rtol = 10f32.powi(-(2 + rng.below(5) as i32)); // 1e-2 .. 1e-6
        AdaptiveOpts {
            rtol,
            atol: rtol * 1e-2,
            h_init: if rng.below(2) == 0 { None } else { Some(rng.range(0.01, 0.3)) },
            max_steps: 50_000,
            ..Default::default()
        }
    }

    fn assert_stats_eq(a: &crate::solvers::adaptive::SolveStats, b: &crate::solvers::adaptive::SolveStats, ctx: &str) {
        assert_eq!(a.nfe, b.nfe, "{ctx}: nfe");
        assert_eq!(a.accepted, b.accepted, "{ctx}: accepted");
        assert_eq!(a.rejected, b.rejected, "{ctx}: rejected");
        assert_eq!(a.h_final.to_bits(), b.h_final.to_bits(), "{ctx}: h_final");
    }

    #[test]
    fn b1_reproduces_scalar_driver_bit_for_bit() {
        // The acceptance property: batched at B=1 == solve_adaptive exactly
        // (state bits, NFE, accepted, rejected), over random embedded
        // tableaux, tolerances, dims, directions, and warm starts.
        Prop::new(60).run("batch-b1-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let n = 1 + rng.below(4);
            let y0 = gen::vec_f32(rng, n, 1.5);
            let (w, a, c) = (rng.range(1.0, 25.0), rng.range(0.2, 2.0), rng.range(-1.0, 1.0));
            let opts = random_opts(rng);
            let (t0, t1) = if rng.below(4) == 0 { (1.0, 0.0) } else { (0.0, 1.0) };

            let scalar = solve_adaptive(test_dynamics(w, a, c), t0, t1, &y0, &tb, &opts);
            let batched = solve_adaptive_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                t0,
                t1,
                &y0,
                &tb,
                &opts,
            );
            assert_eq!(batched.batch(), 1);
            for i in 0..n {
                assert_eq!(
                    scalar.y[i].to_bits(),
                    batched.y[i].to_bits(),
                    "{}: y[{i}] {} vs {}",
                    tb.name,
                    scalar.y[i],
                    batched.y[i]
                );
            }
            assert_eq!(scalar.t.to_bits(), batched.t[0].to_bits(), "{}", tb.name);
            assert_stats_eq(&scalar.stats, &batched.stats[0], tb.name);
        });
    }

    #[test]
    fn batch_matches_independent_scalar_solves_per_trajectory() {
        // B > 1: every trajectory must match its own scalar solve even
        // though rows accept/reject on different schedules and the working
        // set compacts as rows finish.
        Prop::new(40).run("batch-bn-equiv", |rng: &mut Pcg, case| {
            let tb = tableau::by_name(EMBEDDED[case % EMBEDDED.len()]).unwrap();
            let n = 1 + rng.below(3);
            let b = 2 + rng.below(4);
            // Rows at very different magnitudes => very different NFE, so
            // stragglers exercise the compaction path.
            let mut y0 = Vec::with_capacity(b * n);
            for r in 0..b {
                let mag = 0.2 * 3f32.powi(r as i32 % 4);
                y0.extend(gen::vec_f32(rng, n, mag));
            }
            let (w, a, c) = (rng.range(1.0, 30.0), rng.range(0.2, 2.0), rng.range(-1.0, 1.0));
            let opts = random_opts(rng);

            let batched = solve_adaptive_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                0.0,
                1.0,
                &y0,
                &tb,
                &opts,
            );
            for r in 0..b {
                let scalar = solve_adaptive(
                    test_dynamics(w, a, c),
                    0.0,
                    1.0,
                    &y0[r * n..(r + 1) * n],
                    &tb,
                    &opts,
                );
                for i in 0..n {
                    assert_eq!(
                        scalar.y[i].to_bits(),
                        batched.row(r)[i].to_bits(),
                        "{} row {r} y[{i}]",
                        tb.name
                    );
                }
                assert_stats_eq(&scalar.stats, &batched.stats[r], &format!("{} row {r}", tb.name));
            }
        });
    }

    #[test]
    fn doubling_fallback_matches_scalar() {
        // rk4 has no embedded pair; the batch API must still give
        // per-trajectory results identical to scalar step doubling.
        let tb = tableau::rk4();
        let opts = AdaptiveOpts { rtol: 1e-5, atol: 1e-7, ..Default::default() };
        let y0 = [1.0f32, 0.5, -0.25];
        let batched = solve_adaptive_batch(
            Rowwise::new(|_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = -y[0], 1),
            0.0,
            2.0,
            &y0,
            &tb,
            &opts,
        );
        for r in 0..3 {
            let scalar = solve_adaptive(
                |_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = -y[0],
                0.0,
                2.0,
                &y0[r..r + 1],
                &tb,
                &opts,
            );
            assert_eq!(scalar.y[0].to_bits(), batched.row(r)[0].to_bits(), "row {r}");
            assert_stats_eq(&scalar.stats, &batched.stats[r], &format!("row {r}"));
        }
    }

    #[test]
    fn fixed_batch_matches_scalar_rows() {
        Prop::new(30).run("fixed-batch-equiv", |rng: &mut Pcg, case| {
            let names = tableau::ALL;
            let tb = tableau::by_name(names[case % names.len()]).unwrap();
            let n = 1 + rng.below(3);
            let b = 1 + rng.below(4);
            let steps = 1 + rng.below(5);
            let y0 = gen::vec_f32(rng, b * n, 1.0);
            let (w, a, c) = (rng.range(1.0, 10.0), rng.range(0.2, 1.5), rng.range(-1.0, 1.0));
            let (yb, nfes) = solve_fixed_batch(
                Rowwise::new(test_dynamics(w, a, c), n),
                0.0,
                1.0,
                &y0,
                steps,
                &tb,
            );
            for r in 0..b {
                let (ys, nfe) = solve_fixed(
                    test_dynamics(w, a, c),
                    0.0,
                    1.0,
                    &y0[r * n..(r + 1) * n],
                    steps,
                    &tb,
                );
                assert_eq!(nfes[r], nfe, "{} row {r}", tb.name);
                for i in 0..n {
                    assert_eq!(
                        ys[i].to_bits(),
                        yb[r * n + i].to_bits(),
                        "{} row {r} y[{i}]",
                        tb.name
                    );
                }
            }
        });
    }

    #[test]
    fn to_times_batch_matches_scalar_grid_solves() {
        // Forward and reverse grids, warm-started per trajectory.
        for times in [
            vec![0.0f32, 0.25, 0.5, 0.75, 1.0],
            vec![1.0f32, 0.6, 0.3, 0.0],
            vec![0.0f32, 0.5, 0.5, 1.0], // duplicate grid point
        ] {
            let tb = tableau::dopri5();
            let opts = AdaptiveOpts::default();
            let n = 2;
            let y0 = [1.0f32, 0.0, 0.4, -0.8]; // B = 2
            let (traj_b, stats_b) = solve_to_times_batch(
                Rowwise::new(test_dynamics(6.0, 1.0, -0.3), n),
                &times,
                &y0,
                &tb,
                &opts,
            );
            assert_eq!(traj_b.len(), times.len());
            for r in 0..2 {
                let (traj_s, stats_s) = solve_to_times(
                    test_dynamics(6.0, 1.0, -0.3),
                    &times,
                    &y0[r * n..(r + 1) * n],
                    &tb,
                    &opts,
                );
                assert_eq!(stats_s.nfe, stats_b[r].nfe, "row {r} {times:?}");
                assert_eq!(stats_s.accepted, stats_b[r].accepted, "row {r}");
                for (k, snap) in traj_s.iter().enumerate() {
                    for i in 0..n {
                        assert_eq!(
                            snap[i].to_bits(),
                            traj_b[k][r * n + i].to_bits(),
                            "row {r} time {k} y[{i}]"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn heterogeneous_batch_has_per_trajectory_nfe() {
        // The serving-path property: cheap rows must not pay for stragglers
        // (per-trajectory step control + compaction), so NFE varies by row.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        // Row identity must travel with the state (slots reorder under
        // compaction): y = [phase, freq], dy = [cos(freq*t), 0].
        let n = 2;
        let f = BatchFn::new(n, |_ids: &[usize], t: &[f32], y: &[f32], dy: &mut [f32]| {
            for (r, tr) in t.iter().enumerate() {
                dy[2 * r] = (y[2 * r + 1] * tr).cos();
                dy[2 * r + 1] = 0.0;
            }
        });
        let y0 = [0.0f32, 2.0, 0.0, 10.0, 0.0, 40.0, 0.0, 160.0];
        let res = solve_adaptive_batch(f, 0.0, 1.0, &y0, &tb, &opts);
        let nfes = res.nfes();
        assert!(
            nfes.iter().any(|v| *v != nfes[0]),
            "expected heterogeneous NFE, got {nfes:?}"
        );
        // Fast oscillation must cost more than slow (paper Fig 8 mechanism).
        assert!(nfes[3] > nfes[0], "{nfes:?}");
        // Frequencies came through untouched (row order preserved).
        assert_eq!(res.row(2)[1], 40.0);
    }

    #[test]
    fn zero_batch_is_empty_result() {
        let tb = tableau::dopri5();
        let res = solve_adaptive_batch(
            Rowwise::new(|_t: f32, _y: &[f32], _dy: &mut [f32]| {}, 3),
            0.0,
            1.0,
            &[],
            &tb,
            &AdaptiveOpts::default(),
        );
        assert_eq!(res.batch(), 0);
        assert!(res.y.is_empty());
    }

    #[test]
    fn degenerate_span_finishes_immediately() {
        let tb = tableau::dopri5();
        let res = solve_adaptive_batch(
            Rowwise::new(|_t: f32, y: &[f32], dy: &mut [f32]| dy[0] = y[0], 1),
            0.5,
            0.5,
            &[1.0, 2.0],
            &tb,
            &AdaptiveOpts::default(),
        );
        assert_eq!(res.y, vec![1.0, 2.0]);
        for s in &res.stats {
            assert_eq!(s.accepted, 0);
            assert!(s.nfe >= 1); // the stage-0 evaluation still happened
        }
    }
}
