//! Runge-Kutta ODE solver suite — the paper's evaluation instrument.
//!
//! The number of function evaluations (NFE) an *adaptive* solver spends on
//! learned dynamics is TayNODE's headline metric; this module provides the
//! fixed-grid and adaptive drivers, the PI step-size controller, NFE
//! accounting, grid-output solving for trajectory models, the batched
//! multi-trajectory engine ([`batch`]), and the quadrature adapter that
//! integrates the paper's `R_K` regularizer over batched Taylor jets
//! ([`batch::RegularizedBatchDynamics`]).  Dynamics are arbitrary
//! `FnMut(t, y, dy)` — in production they invoke a PJRT-compiled XLA
//! executable (`crate::runtime`), in tests they are native Rust closures.

pub mod adaptive;
pub mod batch;
pub mod fixed;
pub mod stage;
pub mod tableau;

pub use adaptive::{solve_adaptive, solve_to_times, AdaptiveOpts, SolveStats};
pub use batch::{
    augment_quadrature, solve_adaptive_batch, solve_adaptive_batch_pooled,
    solve_adaptive_batch_traced_pooled, solve_fixed_batch, solve_fixed_batch_pooled,
    solve_fixed_batch_record, solve_fixed_batch_record_pooled, solve_to_times_batch,
    solve_to_times_batch_pooled, split_quadrature, BatchDynamics, BatchFn, BatchResult,
    BatchStepper, FixedGridRecord, PooledEval, RegularizedBatchDynamics, Retired, Rowwise,
};
pub use fixed::{solve_fixed, solve_fixed_traj};
pub use tableau::Tableau;

/// A dynamics function dy = f(t, y) writing into a preallocated buffer.
pub trait Dynamics {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]);
}

impl<F: FnMut(f32, &[f32], &mut [f32])> Dynamics for F {
    fn eval(&mut self, t: f32, y: &[f32], dy: &mut [f32]) {
        self(t, y, dy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    fn poly_deriv_dynamics(coeffs: Vec<f32>) -> impl FnMut(f32, &[f32], &mut [f32]) {
        // dz/dt = p'(t) so z(t) = p(t) - p(0) + z0: total derivatives of
        // order > deg(p) vanish identically.
        move |t, _y, dy| {
            let mut acc = 0.0f32;
            // p'(t) with p = sum c_i t^i  =>  sum i c_i t^{i-1}
            for (i, c) in coeffs.iter().enumerate().skip(1) {
                acc += i as f32 * c * t.powi(i as i32 - 1);
            }
            for d in dy.iter_mut() {
                *d = acc;
            }
        }
    }

    fn eval_poly(coeffs: &[f32], t: f32) -> f32 {
        coeffs
            .iter()
            .enumerate()
            .map(|(i, c)| c * t.powi(i as i32))
            .sum()
    }

    #[test]
    fn fixed_polynomial_exactness_property() {
        // Property: an order-m tableau integrates dz/dt = p'(t) exactly
        // (up to f32 roundoff) whenever deg p <= m.
        Prop::new(60).run("poly-exactness", |rng: &mut Pcg, case| {
            let names = ["euler", "midpoint", "ralston", "bosh3", "rk4", "rk38"];
            let tb = tableau::by_name(names[case % names.len()]).unwrap();
            let deg = (tb.order as usize).min(1 + rng.below(tb.order as usize));
            let coeffs = gen::poly(rng, deg, 1.0);
            let f = poly_deriv_dynamics(coeffs.clone());
            let (y, nfe) = solve_fixed(f, 0.0, 1.0, &[0.5f32], 4, &tb);
            let want = 0.5 + eval_poly(&coeffs, 1.0) - eval_poly(&coeffs, 0.0);
            assert_eq!(nfe, 4 * tb.stages);
            assert!(
                (y[0] - want).abs() < 2e-4 * (1.0 + want.abs()),
                "{} deg {deg}: {} vs {want}",
                tb.name,
                y[0]
            );
        });
    }

    #[test]
    fn convergence_rates_match_order() {
        // dz/dt = z on [0,1]; error ~ C h^order.
        for name in ["euler", "midpoint", "bosh3", "rk4", "dopri5"] {
            let tb = tableau::by_name(name).unwrap();
            // keep truncation error above the f32 roundoff floor: fewer
            // steps for higher-order methods
            // keep truncation error above the f32 roundoff floor: fewer
            // steps for higher-order methods.  At order >= 5 there is no
            // f32 window where the asymptotic rate is observable, so we
            // assert near-roundoff accuracy instead.
            if tb.order >= 5 {
                let (y, _) = solve_fixed(
                    |_t, y: &[f32], dy: &mut [f32]| dy[0] = y[0],
                    0.0,
                    1.0,
                    &[1.0f32],
                    4,
                    &tb,
                );
                let err = ((y[0] as f64) - std::f64::consts::E).abs();
                assert!(err < 5e-6, "{name}: err {err}");
                continue;
            }
            let pair = match tb.order {
                0..=2 => [16usize, 32],
                3 => [8, 16],
                _ => [2, 4],
            };
            let mut errs = vec![];
            for steps in pair {
                let (y, _) = solve_fixed(
                    |_t, y: &[f32], dy: &mut [f32]| dy[0] = y[0],
                    0.0,
                    1.0,
                    &[1.0f32],
                    steps,
                    &tb,
                );
                errs.push(((y[0] as f64) - std::f64::consts::E).abs());
            }
            let rate = (errs[0] / errs[1]).log2();
            assert!(
                rate > tb.order as f64 - 0.55,
                "{name}: rate {rate} < order {}",
                tb.order
            );
        }
    }

    #[test]
    fn adaptive_matches_analytic_solution() {
        // Linear rotation: (x, v)' = (v, -x); x(pi/2) = 0, v = -1.
        for name in ["heun_euler", "bosh3", "fehlberg45", "cash_karp", "dopri5"] {
            let tb = tableau::by_name(name).unwrap();
            let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
            let res = solve_adaptive(
                |_t, y: &[f32], dy: &mut [f32]| {
                    dy[0] = y[1];
                    dy[1] = -y[0];
                },
                0.0,
                std::f32::consts::FRAC_PI_2,
                &[1.0, 0.0],
                &tb,
                &opts,
            );
            assert!(res.y[0].abs() < 1e-3, "{name}: x={}", res.y[0]);
            assert!((res.y[1] + 1.0).abs() < 1e-3, "{name}: v={}", res.y[1]);
            assert!(res.stats.nfe > 0 && res.stats.accepted > 0);
        }
    }

    #[test]
    fn adaptive_tolerance_monotonicity() {
        // Property: tightening rtol never decreases NFE (controller sanity).
        let mut nfes = vec![];
        for rtol in [1e-2f32, 1e-4, 1e-6, 1e-8] {
            let tb = tableau::dopri5();
            let opts = AdaptiveOpts { rtol, atol: rtol * 1e-2, ..Default::default() };
            let res = solve_adaptive(
                |t: f32, y: &[f32], dy: &mut [f32]| dy[0] = (3.0 * t).sin() * y[0],
                0.0,
                4.0,
                &[1.0f32],
                &tb,
                &opts,
            );
            nfes.push(res.stats.nfe);
        }
        for w in nfes.windows(2) {
            assert!(w[1] >= w[0], "{nfes:?}");
        }
    }

    #[test]
    fn adaptive_stiffer_dynamics_cost_more_nfe() {
        // The mechanism the paper exploits: larger high-order derivatives
        // (here: higher oscillation frequency) => more NFE at fixed tol.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let nfe_of = |freq: f32| {
            solve_adaptive(
                move |t: f32, _y: &[f32], dy: &mut [f32]| dy[0] = (freq * t).cos(),
                0.0,
                1.0,
                &[0.0f32],
                &tb,
                &opts,
            )
            .stats
            .nfe
        };
        assert!(nfe_of(40.0) > nfe_of(2.0));
    }

    #[test]
    fn step_doubling_fallback_for_plain_tableaux() {
        // rk4 has no embedded pair; adaptivity must still work.
        let tb = tableau::rk4();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        let res = solve_adaptive(
            |_t, y: &[f32], dy: &mut [f32]| dy[0] = -y[0],
            0.0,
            2.0,
            &[1.0f32],
            &tb,
            &opts,
        );
        assert!((res.y[0] - (-2.0f32).exp()).abs() < 1e-4);
    }

    #[test]
    fn solve_to_times_hits_grid() {
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let times = [0.0f32, 0.25, 0.5, 0.75, 1.0];
        let (traj, stats) = solve_to_times(
            |_t, y: &[f32], dy: &mut [f32]| dy[0] = y[0],
            &times,
            &[1.0f32],
            &tb,
            &opts,
        );
        assert_eq!(traj.len(), times.len());
        for (z, t) in traj.iter().zip(&times) {
            assert!((z[0] - t.exp()).abs() < 1e-3, "t={t}");
        }
        assert!(stats.nfe > 0);
    }

    #[test]
    fn empty_state_is_safe() {
        // Regression: `error_norm` divided by a zero length, yielding NaN —
        // the controller then rejected every step until the h floor forced
        // blind accepts.  An empty state must now finish cleanly with zero
        // rejections and finite bookkeeping.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let res = solve_adaptive(
            |_t: f32, _y: &[f32], _dy: &mut [f32]| {},
            0.0,
            1.0,
            &[],
            &tb,
            &opts,
        );
        assert!(res.y.is_empty());
        assert!((res.t - 1.0).abs() < 1e-6, "t = {}", res.t);
        assert_eq!(res.stats.rejected, 0);
        assert!(res.stats.accepted > 0);
        assert!(res.stats.h_final.is_finite());
    }

    #[test]
    fn solve_to_times_reverse_grid() {
        // Reverse-time latent-ODE encode: integrate y' = y backward from
        // y(1) = e through a decreasing grid; the warm-started step size is
        // a magnitude, so every segment must land on the analytic values.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        let times = [1.0f32, 0.75, 0.5, 0.25, 0.0];
        let e = std::f32::consts::E;
        let (traj, stats) = solve_to_times(
            |_t, y: &[f32], dy: &mut [f32]| dy[0] = y[0],
            &times,
            &[e],
            &tb,
            &opts,
        );
        assert_eq!(traj.len(), times.len());
        for (z, t) in traj.iter().zip(&times) {
            assert!((z[0] - t.exp()).abs() < 1e-3, "t={t}: {} vs {}", z[0], t.exp());
        }
        assert!(stats.nfe > 0);
    }

    #[test]
    fn solve_to_times_duplicate_grid_points() {
        // Duplicate output times are zero-length segments: skipped, with the
        // state repeated and no solver work spent.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let times = [0.0f32, 0.5, 0.5, 1.0];
        let (traj, _) = solve_to_times(
            |_t, y: &[f32], dy: &mut [f32]| dy[0] = y[0],
            &times,
            &[1.0f32],
            &tb,
            &opts,
        );
        assert_eq!(traj.len(), 4);
        assert_eq!(traj[1], traj[2]);
        assert!((traj[3][0] - times[3].exp()).abs() < 1e-3);
    }

    #[test]
    fn nfe_accounting_exact_for_fixed() {
        for name in tableau::ALL {
            let tb = tableau::by_name(name).unwrap();
            let mut count = 0usize;
            let (_, nfe) = solve_fixed(
                |_t, _y: &[f32], dy: &mut [f32]| {
                    count += 1;
                    dy[0] = 1.0;
                },
                0.0,
                1.0,
                &[0.0f32],
                7,
                &tb,
            );
            assert_eq!(nfe, count, "{name}");
            assert_eq!(nfe, 7 * tb.stages, "{name}");
        }
    }
}
