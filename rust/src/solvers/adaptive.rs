//! Adaptive-step explicit RK driver with embedded error estimates, a PI
//! step-size controller (Hairer-Norsett-Wanner II.4), automatic initial-step
//! selection, FSAL reuse, and step-doubling fallback for tableaux without an
//! embedded pair.  Counts every dynamics evaluation — NFE is the paper's
//! headline metric, so the accounting here is load-bearing and is verified
//! exactly in tests.
//!
//! The per-step arithmetic lives in [`super::stage`] and is shared with the
//! batched engine ([`super::batch`]): this driver is the B=1 specialization,
//! and the equivalence is bit-for-bit (see `super::tests`).

use super::stage::{self, TableauCoeffs};
use super::tableau::Tableau;
use super::Dynamics;

#[derive(Clone, Debug)]
pub struct AdaptiveOpts {
    pub rtol: f32,
    pub atol: f32,
    /// Initial step magnitude; if None, use the Hairer starting-step
    /// heuristic (costs one extra NFE).  The sign is ignored — direction
    /// comes from t0/t1 — so a step warm-started from a forward segment is
    /// safe to reuse on a reverse-time segment.
    pub h_init: Option<f32>,
    pub h_max: Option<f32>,
    pub max_steps: usize,
    pub safety: f32,
    /// Step-size change clamps.
    pub factor_min: f32,
    pub factor_max: f32,
    /// PI controller: h-factor = safety * err^(-alpha) * prev_err^(beta).
    pub pi_beta: f32,
}

impl Default for AdaptiveOpts {
    fn default() -> Self {
        AdaptiveOpts {
            // The paper's default tolerance is 1.4e-8 in f64; our states are
            // f32 so the practical default is looser.  Experiments that need
            // the paper's setting pass their own opts.
            rtol: 1e-5,
            atol: 1e-7,
            h_init: None,
            h_max: None,
            max_steps: 100_000,
            safety: 0.9,
            factor_min: 0.2,
            factor_max: 10.0,
            pi_beta: 0.04,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct SolveStats {
    pub nfe: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// Final step size when the solve finished.
    pub h_final: f32,
}

#[derive(Clone, Debug)]
pub struct SolveResult {
    pub y: Vec<f32>,
    pub t: f32,
    pub stats: SolveStats,
}

/// Hairer's automatic initial step (II.4, "starting step size"): h0 from
/// the state/derivative norms, one Euler probe (the extra NFE), then the
/// refined h1.  The two norm halves live in `stage` so the batched engine
/// can run the identical estimate per trajectory.
fn initial_step<F: Dynamics>(
    f: &mut F,
    t0: f32,
    y0: &[f32],
    f0: &[f32],
    order: u32,
    atol: f32,
    rtol: f32,
    nfe: &mut usize,
) -> f32 {
    let h0 = stage::h0_estimate(y0, f0, atol, rtol);
    let y1: Vec<f32> = y0.iter().zip(f0).map(|(y, g)| y + h0 * g).collect();
    let mut f1 = vec![0.0f32; y0.len()];
    f.eval(t0 + h0, &y1, &mut f1);
    *nfe += 1;
    stage::h1_estimate(y0, f0, &f1, h0, order, atol, rtol)
}

/// Integrate from t0 to t1 with adaptive steps.
pub fn solve_adaptive<F: Dynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> SolveResult {
    solve_adaptive_mut(&mut f, t0, t1, y0, tb, opts)
}

/// `&mut`-receiver variant (keeps ownership with the caller).
pub fn solve_adaptive_mut<F: Dynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> SolveResult {
    if tb.e.is_some() {
        solve_embedded(f, t0, t1, y0, tb, opts)
    } else {
        solve_doubling(f, t0, t1, y0, tb, opts)
    }
}

fn solve_embedded<F: Dynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> SolveResult {
    let n = y0.len();
    let tbf = TableauCoeffs::new(tb);
    // Hard precondition (kept from the seed's `expect`): with no error
    // weights every step would silently pass the error test and h would
    // balloon — panicking beats plausible-looking wrong answers.
    assert!(tbf.has_embedded(), "solve_embedded needs an embedded pair");
    let span = t1 - t0;
    let h_max = opts.h_max.unwrap_or(span.abs());
    let mut stats = SolveStats::default();

    let mut t = t0;
    let mut y = y0.to_vec();
    // All buffers live for the whole solve: no allocation in the step loop.
    let mut ks: Vec<Vec<f32>> = (0..tbf.stages).map(|_| vec![0.0f32; n]).collect();
    let mut ystage = vec![0.0f32; n];
    let mut ynew = vec![0.0f32; n];
    let mut errv = vec![0.0f32; n];

    // first derivative (reused by FSAL across accepted steps)
    f.eval(t, &y, &mut ks[0]);
    stats.nfe += 1;

    let mut h = match opts.h_init {
        Some(h) => h.abs(),
        None => {
            initial_step(f, t, &y, &ks[0], tbf.order, opts.atol, opts.rtol, &mut stats.nfe)
        }
    }
    .min(h_max)
    .max(1e-10);

    let inv_order = tbf.inv_order();
    let mut prev_err: f32 = 1.0; // neutral PI history

    while (t - t1).abs() > 1e-9 && (t1 - t) * span.signum() > 0.0 {
        if stats.accepted + stats.rejected >= opts.max_steps {
            break;
        }
        h = h.min((t1 - t).abs()).min(h_max) * span.signum();

        // stages 1..S (stage 0 already in ks[0])
        for i in 0..tbf.a.len() {
            stage::accumulate(&tbf.a[i], h, &ks[..=i], &y, &mut ystage);
            let (_, rest) = ks.split_at_mut(i + 1);
            f.eval(t + tbf.c[i + 1] * h, &ystage, &mut rest[0]);
            stats.nfe += 1;
        }

        // propagating solution and embedded error
        stage::accumulate(&tbf.b, h, &ks, &y, &mut ynew);
        stage::accumulate_err(&tbf.e, h, &ks, &mut errv);

        let err = stage::error_norm(&errv, &y, &ynew, opts.atol, opts.rtol);
        if err <= 1.0 || h.abs() <= 1e-9 {
            // accept
            t += h;
            std::mem::swap(&mut y, &mut ynew);
            stats.accepted += 1;
            if tbf.fsal {
                let last = ks.len() - 1;
                ks.swap(0, last);
            } else if (t - t1).abs() > 1e-9 {
                f.eval(t, &y, &mut ks[0]);
                stats.nfe += 1;
            }
            let errc = err.max(1e-10);
            let factor = stage::accept_factor(opts, inv_order, errc, prev_err);
            h = h.abs() * factor.clamp(opts.factor_min, opts.factor_max);
            prev_err = errc;
        } else {
            // reject: shrink and retry (FSAL stage 0 is still valid at t)
            stats.rejected += 1;
            let factor = stage::reject_factor(opts, inv_order, err);
            h = h.abs() * factor.clamp(opts.factor_min, 1.0);
        }
    }
    stats.h_final = h;
    SolveResult { y, t, stats }
}

/// Step-doubling adaptivity for tableaux without an embedded pair: compare
/// one step of size h against two of h/2; the difference scaled by
/// 1/(2^order - 1) estimates the local error of the half-step solution.
fn solve_doubling<F: Dynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> SolveResult {
    let span = t1 - t0;
    let h_max = opts.h_max.unwrap_or(span.abs());
    let mut stats = SolveStats::default();
    let mut t = t0;
    let mut y = y0.to_vec();
    let mut h = opts
        .h_init
        .map(f32::abs)
        .unwrap_or(span.abs() / 16.0)
        .min(h_max);
    let scale = 1.0 / ((2f32).powi(tb.order as i32) - 1.0);
    let inv_order = 1.0 / (tb.order as f32 + 1.0);

    while (t - t1).abs() > 1e-9 && (t1 - t) * span.signum() > 0.0 {
        if stats.accepted + stats.rejected >= opts.max_steps {
            break;
        }
        h = h.min((t1 - t).abs()).min(h_max);
        let hs = h * span.signum();

        let (big, n1) = super::fixed::solve_fixed_mut(f, t, t + hs, &y, 1, tb);
        let (half, n2) = super::fixed::solve_fixed_mut(f, t, t + hs, &y, 2, tb);
        stats.nfe += n1 + n2;
        let errv: Vec<f32> = big
            .iter()
            .zip(&half)
            .map(|(a, b)| (a - b) * scale)
            .collect();
        let err = stage::error_norm(&errv, &y, &half, opts.atol, opts.rtol);
        if err <= 1.0 || h <= 1e-9 {
            t += hs;
            y = half;
            stats.accepted += 1;
            let factor = stage::reject_factor(opts, inv_order, err.max(1e-10));
            h *= factor.clamp(opts.factor_min, opts.factor_max);
        } else {
            stats.rejected += 1;
            let factor = stage::reject_factor(opts, inv_order, err);
            h *= factor.clamp(opts.factor_min, 1.0);
        }
    }
    stats.h_final = h;
    SolveResult { y, t, stats }
}

/// Solve sequentially through a grid of output times, returning the state at
/// every grid point (used by the latent-ODE evaluation: NFE for the whole
/// trajectory is the sum over segments).  `times[0]` is t0 and the initial
/// state is returned as the first entry.  The grid may be increasing or
/// decreasing (reverse-time latent-ODE encode) — each segment integrates in
/// its own direction and the warm-started step size is a magnitude, so a
/// direction flip between segments cannot poison the next solve.
/// Zero-length segments (duplicate grid points) are skipped outright.
pub fn solve_to_times<F: Dynamics>(
    mut f: F,
    times: &[f32],
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> (Vec<Vec<f32>>, SolveStats) {
    let mut out = Vec::with_capacity(times.len());
    out.push(y0.to_vec());
    let mut stats = SolveStats::default();
    let mut y = y0.to_vec();
    let mut seg_opts = opts.clone();
    for w in times.windows(2) {
        if (w[1] - w[0]).abs() <= 1e-9 {
            out.push(y.clone());
            continue;
        }
        let res = solve_adaptive_mut(&mut f, w[0], w[1], &y, tb, &seg_opts);
        y = res.y.clone();
        stats.nfe += res.stats.nfe;
        stats.accepted += res.stats.accepted;
        stats.rejected += res.stats.rejected;
        stats.h_final = res.stats.h_final;
        // warm-start the next segment's step size (magnitude only)
        seg_opts.h_init = Some(res.stats.h_final.abs().max(1e-6));
        out.push(res.y);
    }
    (out, stats)
}
