//! Fixed-grid explicit RK driver.
//!
//! Mirrors the JAX-side `odeint_grid` used inside exported train steps (the
//! python/tests and rust tests check both against the same analytic
//! solutions), and is used by experiments that need a deterministic step
//! budget.  Allocation-free inner loop: stage buffers are preallocated once
//! and the per-step solution combine writes into a swap buffer instead of
//! cloning the state (the seed cloned `y` every step).

use super::stage::{self, TableauCoeffs};
use super::tableau::Tableau;
use super::Dynamics;

/// Integrate `f` from t0 to t1 in `steps` uniform steps.  Returns the final
/// state and the exact NFE spent.
pub fn solve_fixed<F: Dynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, usize) {
    solve_fixed_mut(&mut f, t0, t1, y0, steps, tb)
}

/// `&mut`-receiver variant for callers that need to keep ownership of the
/// dynamics (e.g. the step-doubling adaptive driver).
pub fn solve_fixed_mut<F: Dynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, usize) {
    let (y, _traj, nfe) = drive(f, t0, t1, y0, steps, tb, false);
    (y, nfe)
}

/// Like `solve_fixed`, but also record the state after every step.
pub fn solve_fixed_traj<F: Dynamics>(
    mut f: F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
) -> (Vec<f32>, Vec<Vec<f32>>, usize) {
    drive(&mut f, t0, t1, y0, steps, tb, true)
}

fn drive<F: Dynamics>(
    f: &mut F,
    t0: f32,
    t1: f32,
    y0: &[f32],
    steps: usize,
    tb: &Tableau,
    record: bool,
) -> (Vec<f32>, Vec<Vec<f32>>, usize) {
    assert!(steps > 0);
    let n = y0.len();
    let tbf = TableauCoeffs::new(tb);
    let dt = (t1 - t0) / steps as f32;
    let mut y = y0.to_vec();
    let mut ynew = vec![0.0f32; n];
    let mut ystage = vec![0.0f32; n];
    let mut ks: Vec<Vec<f32>> = (0..tbf.stages).map(|_| vec![0.0f32; n]).collect();
    let mut traj = Vec::new();
    let mut nfe = 0usize;

    for s in 0..steps {
        let t = t0 + s as f32 * dt;
        // stage 0
        {
            let (k0, _) = ks.split_at_mut(1);
            f.eval(t, &y, &mut k0[0]);
        }
        nfe += 1;
        // stages 1..S
        for i in 0..tbf.a.len() {
            stage::accumulate(&tbf.a[i], dt, &ks[..=i], &y, &mut ystage);
            let (_, rest) = ks.split_at_mut(i + 1);
            f.eval(t + tbf.c[i + 1] * dt, &ystage, &mut rest[0]);
            nfe += 1;
        }
        // combine into the swap buffer, then promote it to the state
        stage::accumulate(&tbf.b, dt, &ks, &y, &mut ynew);
        std::mem::swap(&mut y, &mut ynew);
        if record {
            traj.push(y.clone());
        }
    }
    (y, traj, nfe)
}
