//! Minimal dense-tensor substrate (f32, row-major).
//!
//! The ODE solver suite operates on flat state vectors; the data generators
//! need small matvec/affine ops.  This is intentionally BLAS-free — the
//! heavy numerics run inside XLA executables, and the solver-side vector
//! updates are memory-bound axpy's that the compiler vectorizes well (see
//! `benches/perf_hotpath.rs`).

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }
}

// -- flat vector ops (solver hot path) ---------------------------------------

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// out = y + a * x  (writes into `out`, no allocation)
#[inline]
pub fn axpy_into(a: f32, x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..out.len() {
        out[i] = y[i] + a * x[i];
    }
}

/// out = y + sum_j coeffs[j] * xs[j], blocked so each destination chunk
/// stays cache-hot across all stages ([`crate::kern::axpy`]; `h = 1` is
/// bit-invisible since `c · 1.0 == c` for every f32).
pub fn multi_axpy_into(coeffs: &[f32], xs: &[&[f32]], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(coeffs.len(), xs.len());
    crate::kern::axpy::fused_axpy_into(coeffs, 1.0, xs, y, out);
}

pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x {
        *xi *= a;
    }
}

pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// RMS norm — the error norm adaptive solvers use (Hairer II.4).
pub fn rms(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (dot(x, x) / x.len() as f32).sqrt()
}

/// Small dense matvec: y = A x, A is [m, n] row-major.
pub fn matvec(a: &[f32], m: usize, n: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for i in 0..m {
        y[i] = dot(&a[i * n..(i + 1) * n], x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.rank(), 2);
        let z = Tensor::zeros(&[4]);
        assert_eq!(z.len(), 4);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn axpy_family() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);

        let mut out = [0.0; 2];
        axpy_into(0.5, &x, &y, &mut out);
        assert_eq!(out, [12.5, 25.0]);

        multi_axpy_into(&[1.0, 0.0, 2.0], &[&x, &x, &x], &[0.0, 0.0], &mut out);
        assert_eq!(out, [3.0, 6.0]);
    }

    #[test]
    fn norms() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert!((rms(&[3.0, 4.0]) - (12.5f32).sqrt()).abs() < 1e-6);
        assert_eq!(rms(&[]), 0.0);
    }

    #[test]
    fn matvec_small() {
        let a = [1.0, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let mut y = [0.0; 2];
        matvec(&a, 2, 2, &[1.0, 1.0], &mut y);
        assert_eq!(y, [3.0, 7.0]);
    }
}
