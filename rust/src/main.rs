//! `repro` — the TayNODE coordinator CLI.
//!
//! Subcommands:
//!   info                         — manifest/runtime summary
//!   train --artifact NAME        — train one exported artifact
//!   eval  --model NAME           — adaptive-solver evaluation of a model
//!   experiment <id|all> [--quick]— regenerate a paper table/figure
//!   solvers                      — list the RK tableau suite
//!   serve [--quick]              — continuous-batching serving demo
//!   trace <serve|experiment>     — telemetry-enabled drive → Chrome Trace NDJSON
//!   report <trace.ndjson>        — offline trace analytics: span rollup, critical
//!                                  path, cost ledger, registry quantiles
//!                                  (`--diff other.ndjson` compares two traces)
//!   slo [--quick]                — serving drive with per-class deadline-miss
//!                                  budgets and burn-rate windows over step ticks
//!   perfdiff <base> <new>        — numeric-leaf delta between two bench JSONs
//!                                  (`--fail-on-regression <pct>` turns it into a gate)
//!
//! `report` and `slo` write byte-identical output at any `TAYNODE_THREADS`
//! (run context goes to stderr), so CI can `cmp` their files across
//! worker counts.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use taynode::coordinator::{evaluator, BatchInputs, NativeTrainer, Trainer};
use taynode::data::{synth_mnist, Batcher, Dataset};
use taynode::experiments::{self, Scale};
use taynode::nn::Mlp;
use taynode::obs::analyze::TraceView;
use taynode::obs::report::{slo_report, trace_diff_report, trace_report};
use taynode::obs::{Counter, Hist, Recorder, TraceDoc};
use taynode::serving;
use taynode::solvers::{solve_adaptive_batch_traced_pooled, tableau, AdaptiveOpts};
use taynode::util::bench::Table;
use taynode::util::cli::Args;
use taynode::util::json::Json;
use taynode::util::pool::Pool;
use taynode::util::rng::Pcg;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.pos(0).unwrap_or("help") {
        "info" => info(),
        "train" => train(args),
        "eval" => eval(args),
        "experiment" => {
            let id = args.pos(1).unwrap_or("all").to_string();
            let scale = if args.bool("quick") { Scale::quick() } else { Scale::full() };
            experiments::run(&id, scale)
        }
        "serve" => serve(args),
        "trace" => trace_cmd(args),
        "report" => report_cmd(args),
        "slo" => slo_cmd(args),
        "perfdiff" => perfdiff(args),
        "solvers" => {
            println!(
                "{:<12} {:>6} {:>7} {:>9} {:>6}",
                "name", "order", "stages", "adaptive", "fsal"
            );
            for name in tableau::ALL {
                let t = tableau::by_name(name).unwrap();
                println!(
                    "{:<12} {:>6} {:>7} {:>9} {:>6}",
                    t.name,
                    t.order,
                    t.stages,
                    if t.e.is_some() { "embedded" } else { "doubling" },
                    t.fsal
                );
            }
            Ok(())
        }
        _ => {
            println!(
                "repro — TayNODE coordinator\n\
                 usage:\n  repro info\n  repro solvers\n  \
                 repro train --artifact mnist_train_k2_s8 [--iters N] [--lam F] [--lr F]\n  \
                 repro eval --model toy|mnist [--solver dopri5] [--rtol F]\n  \
                 repro experiment <fig1..fig12|native|cnf|table2|table3|table4|all> [--quick]\n  \
                 repro serve [--quick] [--seed N] [--requests N] [--batch N] [--rate F]\n  \
                 repro trace <serve|experiment> [--quick] [--seed N] [--out PATH]\n  \
                 repro report <trace.ndjson> [--diff OTHER.ndjson] [--out PATH] [--json PATH]\n  \
                 repro slo [--quick] [--seed N] [--requests N] [--batch N] [--rate F] \
                 [--out PATH] [--json PATH]\n  \
                 repro perfdiff <base.json> <new.json> [--fail-on-regression PCT]"
            );
            Ok(())
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    let quick = args.bool("quick");
    let seed = args.u64_or("seed", 7)?;
    let total = args.usize_or("requests", if quick { 120 } else { 600 })? as u64;
    let capacity = args.usize_or("batch", if quick { 16 } else { 64 })?;
    let rate = args.f64_or("rate", capacity as f64 / 8.0)?;
    let pool = Pool::from_env();
    let threads = pool.threads();

    let run = || {
        if threads > 1 {
            serving::run_poisson_pooled(&pool, seed, capacity, rate, total)
        } else {
            serving::run_poisson(seed, capacity, rate, total)
        }
    };
    let trace = run();
    // The determinism guarantee, checked live: a same-seed replay must be
    // bit-identical (and across thread counts — compare the printed hash).
    if trace != run() {
        bail!("serve: same-seed replay diverged — determinism broken");
    }

    println!(
        "served {} requests in {} steps  (threads {threads}, capacity {capacity}, rate {rate})",
        trace.submitted, trace.steps
    );
    println!(
        "occupancy {:.3}  errors {}  replay OK  trace hash {:016x}",
        trace.mean_occupancy,
        trace.errors,
        serving::trace_hash(&trace.responses)
    );
    let mut table = Table::new(&["class", "count", "miss", "p50 steps", "p99 steps", "mean NFE"]);
    for c in serving::CLASSES {
        let mut lats: Vec<u64> = trace
            .responses
            .iter()
            .filter(|r| r.ok && r.class == c.name)
            .map(|r| r.done_step - r.admit_step + 1)
            .collect();
        lats.sort_unstable();
        let misses = trace
            .responses
            .iter()
            .filter(|r| r.class == c.name && r.deadline_miss)
            .count();
        let nfe: u64 = trace
            .responses
            .iter()
            .filter(|r| r.ok && r.class == c.name)
            .map(|r| r.nfe)
            .sum();
        let mean_nfe = if lats.is_empty() { 0.0 } else { nfe as f64 / lats.len() as f64 };
        table.row(vec![
            c.name.to_string(),
            lats.len().to_string(),
            misses.to_string(),
            pct(&lats, 0.50).to_string(),
            pct(&lats, 0.99).to_string(),
            format!("{mean_nfe:.1}"),
        ]);
    }
    table.print();
    Ok(())
}

/// `repro trace <serve|experiment>` — run a telemetry-enabled drive and
/// export Chrome Trace Event Format NDJSON (Perfetto loads it directly;
/// for `chrome://tracing` wrap the lines in a JSON array).  The trace is
/// deterministic: same seed ⇒ byte-identical file at any `TAYNODE_THREADS`.
fn trace_cmd(args: &Args) -> Result<()> {
    let which = args.pos(1).unwrap_or("serve");
    let out = args.str_or("out", "trace.ndjson").to_string();
    let doc = match which {
        "serve" => trace_serve(args)?,
        "experiment" => trace_experiment(args)?,
        other => bail!("trace supports serve|experiment, got {other:?}"),
    };
    std::fs::write(&out, doc.to_ndjson())?;
    println!("wrote {} trace records to {out}  (hash {:016x})", doc.line_count(), doc.hash());
    Ok(())
}

fn trace_serve(args: &Args) -> Result<TraceDoc> {
    let quick = args.bool("quick");
    let seed = args.u64_or("seed", 7)?;
    let total = args.usize_or("requests", if quick { 40 } else { 200 })? as u64;
    let capacity = args.usize_or("batch", if quick { 8 } else { 32 })?;
    let rate = args.f64_or("rate", capacity as f64 / 8.0)?;
    let pool = Pool::from_env();
    let (trace, recs) = if pool.threads() > 1 {
        serving::run_poisson_traced_pooled(&pool, seed, capacity, rate, total)
    } else {
        serving::run_poisson_traced(seed, capacity, rate, total)
    };
    println!(
        "served {} requests in {} steps  (threads {}, capacity {capacity}, rate {rate})",
        trace.submitted,
        trace.steps,
        pool.threads()
    );
    let mut doc = TraceDoc::new();
    for (pid, (name, rec)) in recs.iter().enumerate() {
        let label = format!("serve/{name}");
        doc.add_process(pid as u64, &label, rec);
        print_registry(&label, rec);
    }
    Ok(doc)
}

fn trace_experiment(args: &Args) -> Result<TraceDoc> {
    let quick = args.bool("quick");
    let seed = args.u64_or("seed", 3)?;
    let iters = args.usize_or("iters", if quick { 2 } else { 8 })?;
    let b = args.usize_or("batch", if quick { 32 } else { 128 })?;
    let pool = Pool::from_env();
    let mut rng = Pcg::new(seed ^ 0x7e57);

    // Process 0: a native train drive — forward + adjoint-shard spans per
    // optimizer step, tape-arena counters.
    let mlp = Mlp::new(2, &[16, 16], true, seed);
    let mut tr = NativeTrainer::new(mlp, None, 2, 0.01, 8, tableau::dopri5(), 0.05)
        .with_threads(pool.threads());
    tr.enable_recording();
    let x0: Vec<f32> = (0..b * 2).map(|_| rng.range(-1.0, 1.0)).collect();
    let targets: Vec<f32> = x0.iter().map(|v| -v).collect();
    let mut last = f32::NAN;
    for _ in 0..iters {
        last = tr.step_mse(&x0, &targets).loss;
    }
    let train_rec = tr.take_recorder();
    println!("trained {iters} steps (threads {}, loss {last:.5})", pool.threads());

    // Process 1: a pooled adaptive solve — per-trajectory spans plus
    // step-size / error-norm histograms.
    let f = Mlp::new(2, &[16, 16], true, seed ^ 1);
    let y0: Vec<f32> = (0..b * 2).map(|_| rng.range(-1.0, 1.0)).collect();
    let opts = AdaptiveOpts { rtol: 1e-5, atol: 1e-7, ..Default::default() };
    let mut solve_rec = Recorder::enabled();
    let res = solve_adaptive_batch_traced_pooled(
        &pool,
        &f,
        0.0,
        1.0,
        &y0,
        &tableau::dopri5(),
        &opts,
        &mut solve_rec,
    );
    let nfe: usize = res.stats.iter().map(|s| s.nfe).sum();
    println!("solved {b} trajectories adaptively (total NFE {nfe})");

    let mut doc = TraceDoc::new();
    doc.add_process(0, "train/native", &train_rec);
    doc.add_process(1, "solve/pooled", &solve_rec);
    print_registry("train/native", &train_rec);
    print_registry("solve/pooled", &solve_rec);
    Ok(doc)
}

/// Print a recorder's non-zero counters and histogram quantiles as
/// tables.
fn print_registry(label: &str, rec: &Recorder) {
    let Some(reg) = rec.registry() else { return };
    let mut table = Table::new(&["counter", "value"]);
    for c in Counter::ALL {
        let v = reg.get(c);
        if v > 0 {
            table.row(vec![format!("{label}/{}", c.name()), v.to_string()]);
        }
    }
    if table.row_count() > 0 {
        table.print();
    }
    let mut hists = Table::new(&["hist", "count", "p50", "p90", "p99"]);
    for h in Hist::ALL {
        let hist = reg.hist(h);
        if hist.count() > 0 {
            hists.row(vec![
                format!("{label}/{}", h.name()),
                hist.count().to_string(),
                format!("{:.3e}", hist.quantile(0.5)),
                format!("{:.3e}", hist.quantile(0.9)),
                format!("{:.3e}", hist.quantile(0.99)),
            ]);
        }
    }
    if hists.row_count() > 0 {
        hists.print();
    }
}

/// `repro report <trace.ndjson>` — offline analytics over an exported
/// trace: span rollup with self-vs-child attribution, critical path, the
/// per-trajectory cost ledger, and registry quantiles.  With `--diff
/// OTHER` the two traces' rollups are compared instead.  Output is a
/// pure function of the input files — byte-identical at any thread
/// count — so CI `cmp`s it across `TAYNODE_THREADS`.
fn report_cmd(args: &Args) -> Result<()> {
    let path = args
        .pos(1)
        .ok_or_else(|| anyhow::anyhow!("report needs a <trace.ndjson> argument"))?;
    let view = TraceView::parse(&std::fs::read_to_string(path)?)?;
    let doc = match args.str_opt("diff") {
        Some(other) => {
            let view_b = TraceView::parse(&std::fs::read_to_string(other)?)?;
            trace_diff_report(&view, path, &view_b, other)
        }
        None => trace_report(&view)?,
    };
    emit_report(args, &doc.text, &doc.json)
}

/// `repro slo` — run the demo serving drive with per-class deadline-miss
/// budgets on and print the burn-rate report.  Run context (threads) goes
/// to stderr so stdout/`--out` stay byte-identical across worker counts.
fn slo_cmd(args: &Args) -> Result<()> {
    let quick = args.bool("quick");
    let seed = args.u64_or("seed", 7)?;
    let total = args.usize_or("requests", if quick { 120 } else { 600 })? as u64;
    let capacity = args.usize_or("batch", if quick { 16 } else { 64 })?;
    let rate = args.f64_or("rate", capacity as f64 / 8.0)?;
    let pool = Pool::from_env();
    eprintln!(
        "slo drive: threads {}, capacity {capacity}, rate {rate}, {total} requests",
        pool.threads()
    );
    let (trace, slos) = if pool.threads() > 1 {
        serving::run_poisson_slo_pooled(&pool, seed, capacity, rate, total)
    } else {
        serving::run_poisson_slo(seed, capacity, rate, total)
    };
    let mut text = format!(
        "served {} requests in {} steps  (capacity {capacity}, rate {rate})\n",
        trace.submitted, trace.steps
    );
    let mut sections = Vec::new();
    for (name, slo) in &slos {
        let doc = slo_report(slo);
        text.push_str(&format!("\n== model {name} ==\n"));
        text.push_str(&doc.text);
        sections.push((name.as_str(), doc.json));
    }
    emit_report(args, &text, &Json::obj(sections))
}

/// Shared output plumbing for the deterministic reports: text to stdout
/// or `--out`, canonical JSON to `--json`.
fn emit_report(args: &Args, text: &str, json: &Json) -> Result<()> {
    match args.str_opt("out") {
        Some(path) => {
            std::fs::write(path, text)?;
            eprintln!("wrote report text to {path}");
        }
        None => print!("{text}"),
    }
    if let Some(path) = args.str_opt("json") {
        std::fs::write(path, json.to_string())?;
        eprintln!("wrote report JSON to {path}");
    }
    Ok(())
}

/// `repro perfdiff <base.json> <new.json>` — flatten every numeric leaf of
/// both files to a dotted path and print per-path deltas (the `make perf`
/// target runs this against the committed BENCH_*.json baselines).
///
/// With `--fail-on-regression <pct>` the diff becomes a gate: every metric
/// whose name declares a direction (see [`higher_is_better`]) and which
/// moved the wrong way by more than `<pct>` percent is listed and the
/// command exits nonzero.  Direction-unknown metrics are reported but
/// never gated.
fn perfdiff(args: &Args) -> Result<()> {
    let base_path = args.pos(1).ok_or_else(|| anyhow::anyhow!("perfdiff needs <base> <new>"))?;
    let new_path = args.pos(2).ok_or_else(|| anyhow::anyhow!("perfdiff needs <base> <new>"))?;
    let fail_pct: Option<f64> = match args.str_opt("fail-on-regression") {
        None => None,
        Some(v) => Some(
            v.parse()
                .map_err(|e| anyhow::anyhow!("--fail-on-regression={v}: {e}"))?,
        ),
    };
    let base_doc = Json::parse(&std::fs::read_to_string(base_path)?)?;
    let new_doc = Json::parse(&std::fs::read_to_string(new_path)?)?;
    // Name what is being compared before diffing it: each section's
    // provenance stamp (from `make bench-json`) identifies the commit and
    // worker count behind the numbers.
    for (label, path, doc) in [("base", base_path, &base_doc), ("new", new_path, &new_doc)] {
        for line in provenance_lines(doc) {
            println!("{label} {path} {line}");
        }
    }
    let base = flatten_doc(&base_doc);
    let new = flatten_doc(&new_doc);
    if base.is_empty() {
        println!("note: {base_path} has no numeric leaves (unseeded baseline?)");
    }
    let mut table = Table::new(&["metric", "base", "new", "delta%"]);
    let mut offenders: Vec<String> = Vec::new();
    for (k, nv) in &new {
        let (b, d) = match base.get(k) {
            Some(bv) if *bv != 0.0 => {
                let pct = (nv - bv) / bv * 100.0;
                if let (Some(th), Some(hb)) = (fail_pct, higher_is_better(k)) {
                    if if hb { pct < -th } else { pct > th } {
                        offenders.push(format!(
                            "{k}: {pct:+.1}% ({} is worse for this metric)",
                            if hb { "lower" } else { "higher" }
                        ));
                    }
                }
                (format!("{bv:.6}"), format!("{pct:+.1}%"))
            }
            Some(bv) => (format!("{bv:.6}"), "-".to_string()),
            None => ("-".to_string(), "-".to_string()),
        };
        table.row(vec![k.clone(), b, format!("{nv:.6}"), d]);
    }
    for k in base.keys() {
        if !new.contains_key(k) {
            table.row(vec![k.clone(), "(dropped)".to_string(), "-".to_string(), "-".to_string()]);
        }
    }
    table.print();
    if let Some(th) = fail_pct {
        if offenders.is_empty() {
            println!("fail-on-regression: no direction-known metric moved past {th}%");
        } else {
            for o in &offenders {
                eprintln!("regression: {o}");
            }
            bail!("{} metric(s) regressed past {th}%", offenders.len());
        }
    }
    Ok(())
}

/// Direction of a metric, inferred from the leaf of its dotted path:
/// `Some(true)` when higher is better (throughput-like names), `Some(false)`
/// when lower is better (latency/cost-like names), `None` when the name
/// doesn't commit to either — such metrics are informational (shape
/// constants like `batch` or `threads`) and are never gated.
fn higher_is_better(path: &str) -> Option<bool> {
    let leaf = path.rsplit('.').next().unwrap_or(path);
    const HIGHER: [&str; 3] = ["per_sec", "speedup", "occupancy"];
    const LOWER: [&str; 6] = ["secs", "_ms", "p50", "p99", "ratio", "misses"];
    if HIGHER.iter().any(|s| leaf.contains(s)) {
        Some(true)
    } else if LOWER.iter().any(|s| leaf.contains(s)) {
        Some(false)
    } else {
        None
    }
}

/// One line per bench section carrying a provenance stamp:
/// `section=<s> commit=<c> threads=<t>`.
fn provenance_lines(j: &Json) -> Vec<String> {
    let mut out = Vec::new();
    if let Json::Obj(m) = j {
        for (section, v) in m {
            if let Some(p) = v.get("provenance") {
                let commit = p.get("git_commit").and_then(Json::as_str).unwrap_or("?");
                let threads = p.get("threads").and_then(Json::as_f64).unwrap_or(0.0);
                out.push(format!("section={section} commit={commit} threads={threads}"));
            }
        }
    }
    out
}

fn flatten_doc(j: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    flatten_into(j, String::new(), &mut out);
    out
}

fn flatten_into(j: &Json, path: String, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Num(v) => {
            out.insert(path, *v);
        }
        Json::Obj(m) => {
            for (k, v) in m {
                // Provenance stamps identify a report; they are not
                // metrics and must not show up as diffable leaves.
                if k == "provenance" {
                    continue;
                }
                let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                flatten_into(v, p, out);
            }
        }
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                flatten_into(v, format!("{path}[{i}]"), out);
            }
        }
        _ => {}
    }
}

/// Nearest-rank percentile of an ascending-sorted sample (0 when empty).
fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i]
}

fn info() -> Result<()> {
    let rt = experiments::common::load_runtime()?;
    println!(
        "platform: {} ({} devices)",
        rt.client.platform_name(),
        rt.client.device_count()
    );
    println!("models:");
    for (name, m) in &rt.manifest.models {
        println!("  {name:<10} {:>8} params  ({})", m.total, m.params_file);
    }
    println!("executables: {}", rt.manifest.executables.len());
    let mut by_kind: std::collections::BTreeMap<&str, usize> = Default::default();
    for e in rt.manifest.executables.values() {
        *by_kind.entry(e.kind.as_str()).or_default() += 1;
    }
    for (k, n) in by_kind {
        println!("  {k:<14} {n}");
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let rt = experiments::common::load_runtime()?;
    let artifact = args.require("artifact")?.to_string();
    let iters = args.usize_or("iters", 100)?;
    let lam = args.f32_or("lam", 0.0)?;
    let lr = args.f32_or("lr", 0.05)?;
    let seed = args.u64_or("seed", 0)?;
    let spec = rt.manifest.exec_spec(&artifact)?.clone();
    let model = spec.model.clone();
    let hyper = rt.manifest.model(&model)?.hyper.clone();

    // Pre-build the data pipeline for the artifact's model.
    let mnist_ds: Option<Dataset> = if model == "mnist" {
        let b = hyper.usize_of("batch")?;
        let d = hyper.usize_of("d")?;
        let raw = synth_mnist::generate(8 * b, seed);
        Some(Dataset::new(raw.images, d).with_labels(raw.labels))
    } else {
        None
    };
    let mut mnist_batcher = mnist_ds
        .as_ref()
        .map(|ds| Batcher::new(ds, hyper.usize_of("batch").unwrap(), seed));
    let latent_h = if model == "latent" {
        Some(experiments::common::LatentHarness::new(&rt, seed)?)
    } else {
        None
    };
    let cnf_h = if model.starts_with("cnf") {
        Some(experiments::common::CnfHarness::new(&rt, &model, 512, seed)?)
    } else {
        None
    };
    let mut rng = Pcg::new(seed ^ 0xfeed);

    let mut tr = Trainer::new(&rt, &artifact, seed)?;
    for it in 0..iters {
        let inputs = match model.as_str() {
            "toy" => BatchInputs::default()
                .f("x", experiments::common::toy_data(128, seed)),
            "mnist" => {
                let bt = mnist_batcher.as_mut().unwrap().next();
                BatchInputs::default().f("x", bt.x).i("labels", bt.labels)
            }
            "latent" => {
                let h = latent_h.as_ref().unwrap();
                BatchInputs::default().f("x", h.x.clone()).f("mask", h.mask.clone())
            }
            m if m.starts_with("cnf") => {
                let h = cnf_h.as_ref().unwrap();
                BatchInputs::default().f("x", h.batch(&mut rng))
            }
            other => bail!("no batch provider for model {other:?}"),
        };
        let m = tr.step(&inputs, lam, lr)?;
        if it % 10 == 0 || it == iters - 1 {
            println!("step {it:>5}  loss {:>10.5}  metrics {:?}", m.loss(), m.values);
        }
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let rt = experiments::common::load_runtime()?;
    let model = args.require("model")?.to_string();
    let solver = args.str_or("solver", "dopri5").to_string();
    let tb = tableau::by_name(&solver)
        .ok_or_else(|| anyhow::anyhow!("unknown solver {solver:?}"))?;
    let mut opts = experiments::common::eval_opts();
    opts.rtol = args.f32_or("rtol", opts.rtol)?;
    opts.atol = opts.rtol * 1e-2;

    match model.as_str() {
        "mnist" => {
            let h = experiments::common::MnistHarness::new(&rt, 256, 0)?;
            let tr = Trainer::new(&rt, "mnist_train_unreg_s2", 0)?;
            let (x, l) = h.eval_batch(&h.train, 0);
            let ev = evaluator::mnist_eval(&rt, &tr.store, &x, &l, &tb, &opts)?;
            println!("mnist: ce {:.4} err {:.3} NFE {}", ev.ce, ev.err_rate, ev.nfe);
        }
        "toy" => {
            let tr = Trainer::new(&rt, "toy_train_unreg_s16", 0)?;
            let x = experiments::common::toy_data(128, 0);
            let ev = evaluator::toy_eval(&rt, &tr.store, &x, &tb, &opts)?;
            println!("toy: mse {:.5} NFE {}", ev.mse, ev.nfe);
        }
        other => bail!("eval supports toy|mnist, got {other:?}"),
    }
    Ok(())
}
