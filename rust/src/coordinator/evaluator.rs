//! Evaluation-time measurement — the paper's instrument panel.
//!
//! Everything here runs *adaptive* Rust solvers over exported dynamics
//! executables and reports NFE plus task metrics, matching the paper's
//! "Evaluation using adaptive solvers" table columns (NFE, loss/bits-dim,
//! R_2, and Finlay et al.'s K and B integrals).

use anyhow::{anyhow, Result};

use crate::autodiff::div::Divergence;
use crate::nn::ValueDynamics;
use crate::runtime::client::{literal_f32, literal_i32};
use crate::runtime::{ParamStore, Runtime, XlaDynamics};
use crate::solvers::adaptive::{solve_adaptive_mut, AdaptiveOpts, SolveStats};
use crate::solvers::batch::{
    solve_adaptive_batch, solve_adaptive_batch_pooled, solve_to_times_batch, split_aug_cols,
    split_quadrature, LogDetBatchDynamics, RegularizedBatchDynamics, Rowwise,
};
use crate::solvers::tableau::Tableau;
use crate::taylor::BatchSeriesDynamics;
use crate::util::pool::Pool;

/// Split a flat row-major [B, W] state into the first `d` columns (flattened
/// [B, d]) and per-row scalars for columns d..W.
pub fn split_state(state: &[f32], b: usize, w: usize, d: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
    let mut z = Vec::with_capacity(b * d);
    let mut cols = vec![Vec::with_capacity(b); w - d];
    for r in 0..b {
        let row = &state[r * w..(r + 1) * w];
        z.extend_from_slice(&row[..d]);
        for (k, c) in cols.iter_mut().enumerate() {
            c.push(row[d + k]);
        }
    }
    (z, cols)
}

fn mean(xs: &[f32]) -> f64 {
    xs.iter().map(|x| *x as f64).sum::<f64>() / xs.len().max(1) as f64
}

// ---------------------------------------------------------------------------
// MNIST classifier
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct MnistEval {
    pub ce: f32,
    pub err_rate: f32,
    pub nfe: usize,
    pub stats: SolveStats,
}

/// Adaptive solve of the classifier ODE + head metrics.
/// `images` must be exactly the artifact batch ([B*196]).
pub fn mnist_eval(
    rt: &Runtime,
    store: &ParamStore,
    images: &[f32],
    labels: &[i32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<MnistEval> {
    let mut dyn_f = XlaDynamics::from_store(rt, "mnist_dynamics", store, None)?;
    if images.len() != dyn_f.state_len() {
        return Err(anyhow!(
            "mnist_eval: need {} floats, got {}",
            dyn_f.state_len(),
            images.len()
        ));
    }
    let (b, d) = (dyn_f.batch, dyn_f.dim);
    let res = solve_adaptive_mut(&mut dyn_f, 0.0, 1.0, images, tb, opts);

    let head = rt.exec("mnist_head")?;
    let inputs = vec![
        literal_f32(store.shape("wh")?, store.value("wh")?)?,
        literal_f32(store.shape("bh")?, store.value("bh")?)?,
        literal_f32(&[b, d], &res.y)?,
        literal_i32(&[b], labels)?,
    ];
    let out = head.run(&inputs)?;
    let ce = out[0].get_first_element::<f32>()?;
    let err = out[1].get_first_element::<f32>()?;
    Ok(MnistEval {
        ce,
        err_rate: err / b as f32,
        nfe: res.stats.nfe,
        stats: res.stats,
    })
}

/// Integrate the instrumented dynamics to measure the table columns:
/// (R_1..R_4, K, B) averaged over the batch, plus the NFE of the
/// instrumented solve.
#[derive(Clone, Debug)]
pub struct RegQuantities {
    pub r: [f64; 4],
    pub kinetic: f64,
    pub jacobian: f64,
    pub nfe: usize,
}

pub fn mnist_reg_quantities(
    rt: &Runtime,
    store: &ParamStore,
    images: &[f32],
    probe: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<RegQuantities> {
    let mut dyn_f = XlaDynamics::from_store(rt, "mnist_aug_dynamics", store, Some(probe))?;
    let (b, w) = (dyn_f.batch, dyn_f.dim);
    let d = w - 6;
    let mut state = vec![0.0f32; b * w];
    for r in 0..b {
        state[r * w..r * w + d].copy_from_slice(&images[r * d..(r + 1) * d]);
    }
    let res = solve_adaptive_mut(&mut dyn_f, 0.0, 1.0, &state, tb, opts);
    let (_, cols) = split_state(&res.y, b, w, d);
    Ok(RegQuantities {
        r: [mean(&cols[0]), mean(&cols[1]), mean(&cols[2]), mean(&cols[3])],
        kinetic: mean(&cols[4]),
        jacobian: mean(&cols[5]),
        nfe: res.stats.nfe,
    })
}

/// Per-example NFE (Fig 8b / Fig 10): every example is one trajectory of
/// the batched engine — per-trajectory adaptive step control with
/// active-set compaction, so cheap examples retire early instead of each
/// paying for a full standalone solve.  The per-example NFE values are
/// bit-identical to the old one-solve-per-example loop (the batched driver
/// reproduces the scalar driver exactly; see `solvers::batch` tests).
pub fn mnist_per_example_nfe(
    rt: &Runtime,
    store: &ParamStore,
    images: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<Vec<usize>> {
    let dyn_f = XlaDynamics::from_store(rt, "mnist_dynamics_b1", store, None)?;
    let d = dyn_f.dim;
    let n = images.len() / d;
    let res = solve_adaptive_batch(
        Rowwise::new(dyn_f, d),
        0.0,
        1.0,
        &images[..n * d],
        tb,
        opts,
    );
    Ok(res.nfes())
}

// ---------------------------------------------------------------------------
// Native R_K (batched Taylor jets — no XLA artifact needed)
// ---------------------------------------------------------------------------

/// Result of a native batched `R_K` measurement: the plain final states,
/// the per-trajectory regularizer values, and the per-trajectory solver
/// statistics of the augmented solve.
#[derive(Clone, Debug)]
pub struct RkEval {
    /// Un-augmented per-trajectory state dimension.
    pub n: usize,
    /// Final states, row-major `[B, n]`.
    pub y: Vec<f32>,
    /// Per-trajectory `R_K = ∫ ‖d^K y/dt^K‖²/n dt`.
    pub r_k: Vec<f32>,
    /// Batch mean of `r_k` — the table column the paper reports.
    pub mean_r_k: f64,
    /// Per-trajectory stats of the augmented solve (one NFE = one batched
    /// jet sweep = K series evaluations of the dynamics).
    pub stats: Vec<SolveStats>,
}

/// Measure the paper's regularizer `R_K` natively for every trajectory of a
/// batch: wrap a series-generic vector field in
/// [`RegularizedBatchDynamics`], integrate the quadrature-augmented system
/// `[y, r]` adaptively from `t0` to `t1`, and split the result.  The K-th
/// total derivatives come from `taylor::ode_jet_batch`, one sweep per
/// solver evaluation for the whole active set — there is no per-row scalar
/// jet loop anywhere on this path, yet each row is bit-identical to one
/// (see `solvers::batch` tests).
pub fn batch_rk_eval<F: BatchSeriesDynamics>(
    f: F,
    order: usize,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> RkEval {
    let n = f.dim();
    let reg = RegularizedBatchDynamics::new(f, order);
    let aug = reg.augment(y0);
    let res = solve_adaptive_batch(reg, t0, t1, &aug, tb, opts);
    let (y, r_k) = split_quadrature(&res);
    let mean_r_k = mean(&r_k);
    RkEval { n, y, r_k, mean_r_k, stats: res.stats }
}

/// [`batch_rk_eval`] sharded across a worker pool: the quadrature-augmented
/// batch splits into contiguous per-worker sub-batches, each integrating on
/// its own clone of the (series-generic) dynamics.  Per-trajectory results
/// are bit-identical to the serial instrument at any thread count (see
/// `solvers::batch` — no arithmetic crosses rows).
pub fn batch_rk_eval_pooled<F>(
    pool: &Pool,
    f: &F,
    order: usize,
    t0: f32,
    t1: f32,
    y0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> RkEval
where
    F: BatchSeriesDynamics + Clone + Send + Sync,
{
    let n = f.dim();
    let reg = RegularizedBatchDynamics::new(f.clone(), order);
    let aug = reg.augment(y0);
    let res = solve_adaptive_batch_pooled(pool, &reg, t0, t1, &aug, tb, opts);
    let (y, r_k) = split_quadrature(&res);
    let mean_r_k = mean(&r_k);
    RkEval { n, y, r_k, mean_r_k, stats: res.stats }
}

// ---------------------------------------------------------------------------
// Native CNF NLL (log-det augmented solve — no XLA artifact needed)
// ---------------------------------------------------------------------------

/// Standard-normal NLL of one latent state with its integrated log-det:
/// `½‖z‖² + (n/2)·ln 2π − ℓ`, accumulated in f64 exactly like the CNF
/// trainer, cast once at the end.  Shared by [`cnf_nll_eval_pooled`] and
/// the serving layer's density handler so both score bit-identically.
pub fn latent_nll(z: &[f32], logdet: f32) -> f32 {
    let half_ln_2pi = 0.5 * (2.0 * std::f64::consts::PI).ln();
    let mut sq = 0.0f64;
    for zi in z {
        let z64 = f64::from(*zi);
        sq += z64 * z64;
    }
    (0.5 * sq + z.len() as f64 * half_ln_2pi - logdet as f64) as f32
}

/// Adaptive-solver evaluation of a **native** CNF: one log-det + `R_K`
/// augmented batched solve, scored as negative log-likelihood in nats
/// under the standard-normal base distribution.  (The artifact-backed
/// FFJORD instrument is [`cnf_eval`] below; this one needs no runtime.)
#[derive(Clone, Debug)]
pub struct CnfNllEval {
    /// Un-augmented per-trajectory state dimension.
    pub n: usize,
    /// Batch-mean NLL in nats — the FFJORD table column.
    pub nll: f64,
    /// Per-trajectory NLL.
    pub per_nll: Vec<f32>,
    /// Final latent states, row-major `[B, n]`.
    pub y: Vec<f32>,
    /// Batch-mean integrated log-determinant.
    pub mean_logdet: f64,
    /// Batch-mean `R_K`.
    pub mean_r_k: f64,
    /// Per-trajectory stats of the augmented solve.
    pub stats: Vec<SolveStats>,
}

/// Integrate the `[z, ℓ, q]` system adaptively for the whole batch,
/// sharded across the pool, and score each trajectory:
/// `NLL = ½‖z(1)‖² + (n/2)·ln 2π − ℓ(1)` (data → base over `t ∈ [0, 1]`,
/// so `ℓ` accumulates `+∇·f`).  The divergence mode is the caller's:
/// exact for table columns, Hutchinson to measure the estimator's cost —
/// either way the pooled solve is bit-identical to serial.
pub fn cnf_nll_eval_pooled<F>(
    pool: &Pool,
    f: &F,
    order: usize,
    div: &Divergence,
    x0: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> CnfNllEval
where
    F: ValueDynamics + BatchSeriesDynamics + Clone + Send + Sync,
{
    let n = ValueDynamics::dim(f);
    let aug_dyn = LogDetBatchDynamics::new(f.clone(), div.clone()).with_regularizer(order);
    let aug = aug_dyn.augment(x0);
    let res = solve_adaptive_batch_pooled(pool, &aug_dyn, 0.0, 1.0, &aug, tb, opts);
    let (y, cols) = split_aug_cols(&res, n);
    let b = res.batch();
    let mut per_nll = Vec::with_capacity(b);
    for r in 0..b {
        per_nll.push(latent_nll(&y[r * n..(r + 1) * n], cols[0][r]));
    }
    CnfNllEval {
        n,
        nll: mean(&per_nll),
        per_nll,
        y,
        mean_logdet: mean(&cols[0]),
        mean_r_k: mean(&cols[1]),
        stats: res.stats,
    }
}

// ---------------------------------------------------------------------------
// CNF / FFJORD
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct CnfEval {
    pub nll: f32,
    pub bpd: f32,
    pub nfe: usize,
    pub r2: f64,
    pub kinetic: f64,
    pub jacobian: f64,
}

/// Adaptive solve of the augmented CNF system (z, logdet, R2, K, B) and
/// likelihood metrics.  `model` is "cnf_tab" or "cnf_img".
pub fn cnf_eval(
    rt: &Runtime,
    model: &str,
    store: &ParamStore,
    x: &[f32],
    probe: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<CnfEval> {
    let mut dyn_f =
        XlaDynamics::from_store(rt, &format!("{model}_aug_dynamics"), store, Some(probe))?;
    let (b, w) = (dyn_f.batch, dyn_f.dim);
    let d = w - 4;
    let mut state = vec![0.0f32; b * w];
    for r in 0..b {
        state[r * w..r * w + d].copy_from_slice(&x[r * d..(r + 1) * d]);
    }
    let res = solve_adaptive_mut(&mut dyn_f, 0.0, 1.0, &state, tb, opts);
    let (z1, cols) = split_state(&res.y, b, w, d);

    let nll_exec = rt.exec(&format!("{model}_nll"))?;
    let out = nll_exec.run(&[
        literal_f32(&[b, d], &z1)?,
        literal_f32(&[b], &cols[0])?,
    ])?;
    Ok(CnfEval {
        nll: out[0].get_first_element::<f32>()?,
        bpd: out[1].get_first_element::<f32>()?,
        nfe: res.stats.nfe,
        r2: mean(&cols[1]),
        kinetic: mean(&cols[2]),
        jacobian: mean(&cols[3]),
    })
}

// ---------------------------------------------------------------------------
// Latent ODE
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct LatentEval {
    pub nll: f32,
    pub mse: f32,
    pub nfe: usize,
}

/// Encode (posterior mean), adaptively solve the latent trajectory through
/// the observation grid, decode, and report masked NLL/MSE + NFE.
pub fn latent_eval(
    rt: &Runtime,
    store: &ParamStore,
    x: &[f32],
    mask: &[f32],
    t_pts: usize,
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<LatentEval> {
    // 1) encode
    let enc = rt.exec("latent_encode")?;
    let mut inputs = vec![];
    let x_shape;
    {
        let spec = &enc.spec;
        for inp in &spec.inputs {
            match inp.role_kind() {
                "param" => inputs.push(literal_f32(&inp.shape, store.value(&inp.name)?)?),
                "batch" => {
                    let data = if inp.name == "x" { x } else { mask };
                    inputs.push(literal_f32(&inp.shape, data)?);
                }
                other => return Err(anyhow!("latent_encode role {other}")),
            }
        }
        x_shape = spec
            .inputs
            .iter()
            .find(|i| i.name == "x")
            .map(|i| i.shape.clone())
            .ok_or_else(|| anyhow!("latent_encode artifact has no input `x`"))?;
    }
    let out = enc.run(&inputs)?;
    let mu = out[0].to_vec::<f32>()?; // posterior mean as z0

    // 2) adaptive latent solve through the grid, via the batched grid
    // driver: the exported latent executable consumes the whole [B, L]
    // batch with one shared time, so it rides as a single trajectory of
    // the engine (the B=1 specialization, bit-equal to the scalar
    // `solve_to_times`).  Per-example step control needs a batch-1 latent
    // artifact with a per-row time input — see ROADMAP open items.
    let dyn_f = XlaDynamics::from_store(rt, "latent_dynamics", store, None)?;
    let (b, l) = (dyn_f.batch, dyn_f.dim);
    let state_len = dyn_f.state_len();
    let times: Vec<f32> = (0..t_pts)
        .map(|i| i as f32 / (t_pts - 1) as f32)
        .collect();
    let (traj, stats) =
        solve_to_times_batch(Rowwise::new(dyn_f, state_len), &times, &mu, tb, opts);

    // 3) decode + metrics
    let mut ztraj = Vec::with_capacity(t_pts * b * l);
    for z in &traj {
        ztraj.extend_from_slice(z);
    }
    let met = rt.exec("latent_traj_metrics")?;
    let mut minputs = vec![];
    for inp in &met.spec.inputs {
        match inp.role_kind() {
            "param" => minputs.push(literal_f32(&inp.shape, store.value(&inp.name)?)?),
            "batch" => match inp.name.as_str() {
                "ztraj" => minputs.push(literal_f32(&inp.shape, &ztraj)?),
                "x" => minputs.push(literal_f32(&x_shape, x)?),
                "mask" => minputs.push(literal_f32(&x_shape, mask)?),
                other => return Err(anyhow!("latent metrics input {other}")),
            },
            other => return Err(anyhow!("latent metrics role {other}")),
        }
    }
    let mout = met.run(&minputs)?;
    Ok(LatentEval {
        nll: mout[0].get_first_element::<f32>()?,
        mse: mout[1].get_first_element::<f32>()?,
        nfe: stats.first().map(|s| s.nfe).unwrap_or(0),
    })
}

// ---------------------------------------------------------------------------
// Toy model (Figs 1, 9)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ToyEval {
    pub mse: f32,
    pub nfe: usize,
}

/// Adaptive solve of the toy ODE and MSE against the target map x + x^3.
/// The exported executable consumes the whole batch per evaluation, so it
/// rides the batched engine as one trajectory (B=1 specialization —
/// bit-equal to the old scalar solve, NFE semantics unchanged).
pub fn toy_eval(
    rt: &Runtime,
    store: &ParamStore,
    x: &[f32],
    tb: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<ToyEval> {
    let dyn_f = XlaDynamics::from_store(rt, "toy_dynamics", store, None)?;
    let state_len = dyn_f.state_len();
    let res = solve_adaptive_batch(Rowwise::new(dyn_f, state_len), 0.0, 1.0, x, tb, opts);
    let mse = x
        .iter()
        .zip(&res.y)
        .map(|(x0, z1)| {
            let tgt = x0 + x0 * x0 * x0;
            (z1 - tgt) * (z1 - tgt)
        })
        .sum::<f32>()
        / x.len() as f32;
    Ok(ToyEval {
        mse,
        nfe: res.stats.first().map(|s| s.nfe).unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::tableau;
    use crate::taylor::{SeriesFn, SeriesVec};

    #[test]
    fn batch_rk_eval_exponential_matches_analytic() {
        // dz/dt = z: every total derivative of the solution equals z, so
        // for ANY order K, R_K = ∫ z(t)² dt = z0² (e² − 1)/2 over [0, 1] —
        // one closed form validates the whole jet/quadrature pipeline.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts { rtol: 1e-6, atol: 1e-8, ..Default::default() };
        let y0 = [1.0f32, 0.5, -2.0];
        let coef = (std::f64::consts::E.powi(2) - 1.0) / 2.0;
        for order in [1usize, 2, 3, 4] {
            let f = SeriesFn::new(1, |_ids: &[usize], z: &SeriesVec, _t: &SeriesVec| z.clone());
            let ev = batch_rk_eval(f, order, 0.0, 1.0, &y0, &tb, &opts);
            assert_eq!(ev.n, 1);
            assert_eq!(ev.r_k.len(), y0.len());
            let mut want_mean = 0.0f64;
            for (r, z0) in y0.iter().enumerate() {
                let want = (*z0 as f64) * (*z0 as f64) * coef;
                want_mean += want;
                let got = ev.r_k[r] as f64;
                assert!(
                    (got - want).abs() < 1e-3 * want.max(1.0),
                    "K={order} row {r}: {got} vs {want}"
                );
                let wy = *z0 * std::f32::consts::E;
                assert!(
                    (ev.y[r] - wy).abs() < 1e-3 * wy.abs(),
                    "K={order} row {r}: y {} vs {wy}",
                    ev.y[r]
                );
            }
            want_mean /= y0.len() as f64;
            assert!((ev.mean_r_k - want_mean).abs() < 1e-2 * want_mean);
            assert!(ev.stats.iter().all(|s| s.nfe > 0 && s.accepted > 0));
        }
    }

    #[test]
    fn batch_rk_eval_pooled_matches_serial_bit_for_bit() {
        // The pooled instrument must report exactly what the serial one
        // does, per trajectory, at every thread count.
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let y0: Vec<f32> = (0..9).map(|i| 0.2 * i as f32 - 0.8).collect();
        let f = SeriesFn::new(1, |_ids: &[usize], z: &SeriesVec, _t: &SeriesVec| z.clone());
        let serial = batch_rk_eval(f.clone(), 2, 0.0, 1.0, &y0, &tb, &opts);
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let ev = batch_rk_eval_pooled(&pool, &f, 2, 0.0, 1.0, &y0, &tb, &opts);
            for r in 0..y0.len() {
                assert_eq!(serial.y[r].to_bits(), ev.y[r].to_bits(), "y row {r}");
                assert_eq!(serial.r_k[r].to_bits(), ev.r_k[r].to_bits(), "R_K row {r}");
                assert_eq!(serial.stats[r].nfe, ev.stats[r].nfe, "NFE row {r}");
            }
            assert_eq!(serial.mean_r_k.to_bits(), ev.mean_r_k.to_bits());
        }
    }

    #[test]
    fn cnf_nll_eval_pooled_matches_pool_of_one_bit_for_bit() {
        // There is no standalone serial `cnf_nll_eval`; a Pool::new(1)
        // solve runs every shard inline on the caller's thread and is the
        // serial reference the determinism contract (lint rule D5) pins.
        use crate::nn::Cnf;
        let cnf = Cnf::new(2, &[6], 11);
        let tb = tableau::dopri5();
        let opts = AdaptiveOpts::default();
        let x0: Vec<f32> = (0..10).map(|i| 0.15 * i as f32 - 0.7).collect(); // [B=5, n=2]
        let serial =
            cnf_nll_eval_pooled(&Pool::new(1), &cnf, 2, &Divergence::Exact, &x0, &tb, &opts);
        for threads in [2usize, 3, 4] {
            let pool = Pool::new(threads);
            let ev = cnf_nll_eval_pooled(&pool, &cnf, 2, &Divergence::Exact, &x0, &tb, &opts);
            assert_eq!(serial.nll.to_bits(), ev.nll.to_bits(), "{threads} threads");
            assert_eq!(serial.mean_logdet.to_bits(), ev.mean_logdet.to_bits());
            assert_eq!(serial.mean_r_k.to_bits(), ev.mean_r_k.to_bits());
            for r in 0..serial.per_nll.len() {
                assert_eq!(serial.per_nll[r].to_bits(), ev.per_nll[r].to_bits(), "row {r}");
                assert_eq!(serial.stats[r].nfe, ev.stats[r].nfe, "NFE row {r}");
            }
            for (a, b) in serial.y.iter().zip(&ev.y) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn batch_rk_eval_zero_batch() {
        let tb = tableau::dopri5();
        let f = SeriesFn::new(1, |_ids: &[usize], z: &SeriesVec, _t: &SeriesVec| z.clone());
        let ev = batch_rk_eval(f, 2, 0.0, 1.0, &[], &tb, &AdaptiveOpts::default());
        assert!(ev.r_k.is_empty());
        assert!(ev.y.is_empty());
    }
}
