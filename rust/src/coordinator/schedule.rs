//! Learning-rate and regularization-weight schedules.  The paper's MNIST
//! recipe is a piecewise-constant lr decay (App. B.2); the related-work
//! discussion (Chang et al.) motivates optional λ tapering.

#[derive(Clone, Debug)]
pub enum Schedule {
    Const(f32),
    /// (boundaries in steps, values); values has one more entry.
    Piecewise(Vec<usize>, Vec<f32>),
    /// Linear decay from `from` to `to` over `steps`.
    Linear { from: f32, to: f32, steps: usize },
}

impl Schedule {
    pub fn at(&self, step: usize) -> f32 {
        match self {
            Schedule::Const(v) => *v,
            Schedule::Piecewise(bounds, values) => {
                let mut i = 0;
                while i < bounds.len() && step >= bounds[i] {
                    i += 1;
                }
                values[i]
            }
            Schedule::Linear { from, to, steps } => {
                if *steps == 0 || step >= *steps {
                    *to
                } else {
                    from + (to - from) * step as f32 / *steps as f32
                }
            }
        }
    }

    /// The paper's MNIST decay (scaled): drop by 10x at the given fractions
    /// of the total budget.
    pub fn mnist_lr(base: f32, total: usize) -> Schedule {
        Schedule::Piecewise(
            vec![total * 3 / 8, total * 5 / 8, total * 7 / 8],
            vec![base, base * 0.1, base * 0.01, base * 0.001],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn piecewise_boundaries() {
        let s = Schedule::Piecewise(vec![10, 20], vec![1.0, 0.1, 0.01]);
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert_eq!(s.at(10), 0.1);
        assert_eq!(s.at(19), 0.1);
        assert_eq!(s.at(20), 0.01);
        assert_eq!(s.at(1000), 0.01);
    }

    #[test]
    fn linear_decay() {
        let s = Schedule::Linear { from: 1.0, to: 0.0, steps: 10 };
        assert_eq!(s.at(0), 1.0);
        assert!((s.at(5) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(10), 0.0);
        assert_eq!(s.at(99), 0.0);
    }

    #[test]
    fn mnist_schedule_monotone() {
        let s = Schedule::mnist_lr(0.1, 160);
        let mut prev = f32::MAX;
        for step in [0, 60, 100, 140, 159] {
            let v = s.at(step);
            assert!(v <= prev);
            prev = v;
        }
        assert!((s.at(159) - 1e-4).abs() < 1e-7);
    }
}
