//! The training orchestrator: drives an exported train-step executable,
//! feeding parameters/optimizer state/batch/probes/scalars per the manifest
//! roles and writing updated state back into the `ParamStore`.
//!
//! One `Trainer::step` = one optimizer update = one PJRT execution of the
//! whole fused train step (ODE solve + loss + `R_K` via jet + optimizer),
//! exactly the paper's fixed-grid training regime.

use std::collections::BTreeMap; // taylint: allow(D1) -- ordered by name, never feeds a float reduction
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::runtime::client::{literal_f32, literal_i32, Executable, Literal, Runtime};
use crate::runtime::params::ParamStore;
use crate::util::rng::Pcg;

/// Named batch arrays fed to `batch:*` inputs (f32) and `batch:labels`
/// (i32) — filled by the experiment's data pipeline each step.
#[derive(Default)]
pub struct BatchInputs {
    pub f32s: BTreeMap<String, Vec<f32>>, // taylint: allow(D1) -- keyed lookup by input name; iteration is name-sorted and deterministic
    pub i32s: BTreeMap<String, Vec<i32>>,
}

impl BatchInputs {
    pub fn f(mut self, name: &str, data: Vec<f32>) -> Self {
        self.f32s.insert(name.to_string(), data);
        self
    }

    pub fn i(mut self, name: &str, data: Vec<i32>) -> Self {
        self.i32s.insert(name.to_string(), data);
        self
    }
}

/// Scalar metrics a train step returns (everything after the state outputs).
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub values: Vec<f32>,
}

impl StepMetrics {
    /// Train steps order their metric outputs (loss, primary, reg, ...).
    pub fn loss(&self) -> f32 {
        self.values.first().copied().unwrap_or(f32::NAN)
    }

    /// The task metric (cross-entropy, NLL, MSE — position 1); NaN when the
    /// artifact reports fewer outputs.
    pub fn primary(&self) -> f32 {
        self.values.get(1).copied().unwrap_or(f32::NAN)
    }

    /// The regularization term `R_K` as the fused train step measured it on
    /// its fixed grid (position 2); NaN when the artifact is unregularized.
    /// The native batched counterpart measured with adaptive quadrature is
    /// `coordinator::evaluator::batch_rk_eval`.
    pub fn reg(&self) -> f32 {
        self.values.get(2).copied().unwrap_or(f32::NAN)
    }
}

impl crate::obs::StepScalars for StepMetrics {
    fn loss(&self) -> f32 {
        StepMetrics::loss(self)
    }

    fn task(&self) -> f32 {
        self.primary()
    }

    fn reg(&self) -> f32 {
        StepMetrics::reg(self)
    }
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    exec: Rc<Executable>,
    pub store: ParamStore,
    pub step_count: usize,
    rng: Pcg,
    /// Ordered (role, name) of state inputs — outputs map back positionally.
    state_roles: Vec<(String, String)>,
}

impl<'rt> Trainer<'rt> {
    /// Build a trainer for a train-step artifact; loads the model's initial
    /// parameters and creates whatever optimizer slots the artifact needs.
    pub fn new(rt: &'rt Runtime, artifact: &str, seed: u64) -> Result<Trainer<'rt>> {
        let exec = rt.exec(artifact)?;
        if exec.spec.kind != "train" {
            bail!("{artifact} is kind {:?}, not train", exec.spec.kind);
        }
        let model = rt.manifest.model(&exec.spec.model)?.clone();
        let values = rt.load_params(&exec.spec.model)?;
        let mut store = ParamStore::new(model.layout, values);

        let mut state_roles = vec![];
        for inp in &exec.spec.inputs {
            let kind = inp.role_kind();
            if kind == "param" {
                state_roles.push(("param".to_string(), inp.name.clone()));
            } else if kind == "opt" {
                let mut parts = inp.role.splitn(3, ':');
                parts.next();
                let slot = parts
                    .next()
                    .ok_or_else(|| anyhow!("bad opt role {:?}", inp.role))?
                    .to_string();
                let pname = parts
                    .next()
                    .ok_or_else(|| anyhow!("bad opt role {:?}", inp.role))?
                    .to_string();
                if !store.slots.contains_key(&slot) {
                    store.add_slot(&slot);
                }
                state_roles.push((format!("opt:{slot}"), pname));
            }
        }
        Ok(Trainer {
            rt,
            exec,
            store,
            step_count: 0,
            rng: Pcg::new(seed),
            state_roles,
        })
    }

    pub fn model_name(&self) -> &str {
        &self.exec.spec.model
    }

    pub fn artifact_name(&self) -> &str {
        &self.exec.spec.name
    }

    /// Run one train step: assemble inputs by role, execute, write back
    /// state, return the metric outputs.
    pub fn step(&mut self, batch: &BatchInputs, lam: f32, lr: f32) -> Result<StepMetrics> {
        self.step_count += 1;
        // Cheap Rc clone so the spec can be iterated while `self` stays
        // free for the store/rng borrows below (the seed deep-cloned the
        // whole input-spec Vec every step).
        let exec = self.exec.clone();
        let mut inputs: Vec<Literal> = Vec::with_capacity(exec.spec.inputs.len());
        for inp in &exec.spec.inputs {
            let lit = match inp.role_kind() {
                "param" => literal_f32(&inp.shape, self.store.value(&inp.name)?)?,
                "opt" => {
                    let mut parts = inp.role.splitn(3, ':');
                    parts.next();
                    let slot = parts
                        .next()
                        .ok_or_else(|| anyhow!("opt role {:?} is missing its slot", inp.role))?;
                    let pname = parts
                        .next()
                        .ok_or_else(|| anyhow!("opt role {:?} is missing its param", inp.role))?;
                    literal_f32(&inp.shape, self.store.slot_value(slot, pname)?)?
                }
                "batch" => {
                    if inp.dtype.starts_with("int") {
                        let data = batch.i32s.get(&inp.name).ok_or_else(|| {
                            anyhow!("missing i32 batch input {:?}", inp.name)
                        })?;
                        literal_i32(&inp.shape, data)?
                    } else {
                        let data = batch.f32s.get(&inp.name).ok_or_else(|| {
                            anyhow!("missing batch input {:?}", inp.name)
                        })?;
                        literal_f32(&inp.shape, data)?
                    }
                }
                "rng" => {
                    // eps  -> Rademacher probe (Hutchinson / RNODE B-term)
                    // eps_z-> standard normal (posterior sampling)
                    let n = inp.elems();
                    let data = if inp.name.contains("_z") {
                        self.rng.normal_vec(n)
                    } else {
                        self.rng.rademacher(n)
                    };
                    literal_f32(&inp.shape, &data)?
                }
                "scalar" => {
                    let v = match inp.name.as_str() {
                        "lam" => lam,
                        "lr" => lr,
                        "step" => self.step_count as f32,
                        other => bail!("unknown scalar input {other:?}"),
                    };
                    Literal::scalar(v)
                }
                other => bail!("unsupported role kind {other:?}"),
            };
            inputs.push(lit);
        }

        let outputs = self.exec.run(&inputs)?;
        let n_state = self.state_roles.len();
        if outputs.len() < n_state {
            bail!("train step returned {} outputs < state {}", outputs.len(), n_state);
        }
        // Index loop: `state_roles` and `store` are disjoint fields, so the
        // roles can be borrowed while the store is written — no need to
        // clone the whole role Vec every optimizer step (as the seed did).
        for i in 0..n_state {
            let data = outputs[i].to_vec::<f32>()?;
            let (role, pname) = &self.state_roles[i];
            let idx = self.store.index_of(pname)?;
            if role == "param" {
                self.store.set_value(idx, data);
            } else {
                let slot = role
                    .strip_prefix("opt:")
                    .ok_or_else(|| anyhow!("unexpected state role {role:?}"))?;
                self.store.set_slot_value(slot, idx, data);
            }
        }
        let mut metrics = StepMetrics::default();
        for out in &outputs[n_state..] {
            metrics.values.push(out.get_first_element::<f32>()?);
        }
        Ok(metrics)
    }
}
