//! Metrics recording: in-memory step logs with CSV/JSONL export, used by
//! every experiment to persist the series the paper's figures plot.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::obs::StepScalars;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct MetricsLog {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl MetricsLog {
    pub fn new(columns: &[&str]) -> MetricsLog {
        MetricsLog {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "metrics row width");
        self.rows.push(row);
    }

    /// Append a training-step row in the canonical [`StepScalars`] order —
    /// `step`, `loss`, `task`, `reg` — followed by `extra` columns.  Both
    /// trainer paths (XLA [`StepMetrics`], native [`NativeMetrics`]) log
    /// through this one taxonomy instead of positional field indexing.
    ///
    /// [`StepMetrics`]: crate::coordinator::StepMetrics
    /// [`NativeMetrics`]: crate::coordinator::NativeMetrics
    pub fn push_step(&mut self, step: usize, m: &impl StepScalars, extra: &[f64]) {
        let mut row = vec![step as f64, m.loss() as f64, m.task() as f64, m.reg() as f64];
        row.extend_from_slice(extra);
        self.push(row);
    }

    pub fn col(&self, name: &str) -> Vec<f64> {
        let idx = self
            .columns
            .iter()
            .position(|c| c == name)
            .unwrap_or_else(|| panic!("no metrics column {name:?}"));
        self.rows.iter().map(|r| r[idx]).collect()
    }

    pub fn last(&self, name: &str) -> f64 {
        *self.col(name).last().expect("non-empty log") // taylint: allow(D4) -- asking for the last value of an empty log is a caller bug
    }

    /// Mean of the last `k` entries of a column (smoothed terminal value).
    pub fn tail_mean(&self, name: &str, k: usize) -> f64 {
        let c = self.col(name);
        let t = &c[c.len().saturating_sub(k)..];
        t.iter().sum::<f64>() / t.len().max(1) as f64
    }

    pub fn to_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.columns.join(","))?;
        for r in &self.rows {
            let cells: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        Ok(())
    }

    pub fn to_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for r in &self.rows {
            let obj = Json::Obj(
                self.columns
                    .iter()
                    .cloned()
                    .zip(r.iter().map(|v| Json::Num(*v)))
                    .collect(),
            );
            writeln!(f, "{}", obj.to_string())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn columns_and_tail() {
        let mut m = MetricsLog::new(&["step", "loss"]);
        for i in 0..10 {
            m.push(vec![i as f64, 10.0 - i as f64]);
        }
        assert_eq!(m.col("loss")[0], 10.0);
        assert_eq!(m.last("loss"), 1.0);
        assert!((m.tail_mean("loss", 2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn csv_jsonl_roundtrip() {
        let mut m = MetricsLog::new(&["a", "b"]);
        m.push(vec![1.0, 2.5]);
        let dir = std::env::temp_dir()
            .join(format!("taynode-metrics-{}", std::process::id()));
        m.to_csv(&dir.join("m.csv")).unwrap();
        m.to_jsonl(&dir.join("m.jsonl")).unwrap();
        let csv = std::fs::read_to_string(dir.join("m.csv")).unwrap();
        assert!(csv.starts_with("a,b\n1,2.5"));
        let jl = std::fs::read_to_string(dir.join("m.jsonl")).unwrap();
        let j = Json::parse(jl.lines().next().unwrap()).unwrap();
        assert_eq!(j.req("b").unwrap().as_f64().unwrap(), 2.5);
    }

    #[test]
    fn push_step_uses_the_canonical_taxonomy() {
        struct Fake;
        impl StepScalars for Fake {
            fn loss(&self) -> f32 {
                3.5
            }
            fn task(&self) -> f32 {
                3.0
            }
            fn reg(&self) -> f32 {
                0.5
            }
        }
        let mut m = MetricsLog::new(&["step", "loss", "task", "reg", "nfe"]);
        m.push_step(7, &Fake, &[104.0]);
        assert_eq!(m.last("step"), 7.0);
        assert_eq!(m.last("loss"), 3.5);
        assert_eq!(m.last("task"), 3.0);
        assert_eq!(m.last("reg"), 0.5);
        assert_eq!(m.last("nfe"), 104.0);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut m = MetricsLog::new(&["a"]);
        m.push(vec![1.0, 2.0]);
    }
}
