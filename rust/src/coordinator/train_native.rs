//! Native training: reverse-mode discrete adjoint through the batched
//! fixed-grid RK driver, with the paper's `R_K`-regularized objective —
//! no XLA artifacts required (closes the ROADMAP "native training path"
//! item; the exported-executable trainer in [`super::trainer`] remains the
//! `pjrt` path).
//!
//! One [`NativeTrainer::step_mse`] / [`step_ce`](NativeTrainer::step_ce) is
//! one optimizer update of `L = task + λ·R_K`:
//!
//! 1. **Forward** — the MLP dynamics, lifted by
//!    [`RegularizedBatchDynamics`] into the quadrature-augmented system
//!    `[y, q]` with `dq/dt = ‖d^K y/dt^K‖²/n`, is integrated on a fixed
//!    grid by [`solve_fixed_batch_record_pooled`], which caches every
//!    stage's input state — the whole active set per model evaluation,
//!    exactly the serving-path engine, sharded across the worker pool.
//! 2. **Backward** — [`adjoint_grads`] runs the textbook discrete adjoint
//!    of the explicit RK step (Hairer; Sanz-Serna 2016): per step, in
//!    reverse stage order, `k̄_i = h·b_i·ȳ' + Σ_{i'>i} h·a_{i'i}·ū_{i'}`,
//!    one tape VJP of the augmented dynamics per stage turns `k̄_i` into
//!    `ū_i` and parameter cotangents.  The VJP re-evaluates the model at
//!    the cached stage state on a reverse-mode tape — through the **whole
//!    Taylor-mode jet** (`ode_jet_values` with tape coefficients), so the
//!    `λ·R_K` term differentiates exactly, not by surrogate.  The batch
//!    shards across the pool (rows only couple through the final
//!    row-summed `θ̄`), each worker reusing one arena tape across its
//!    stage VJPs; results are bit-identical at every thread count.
//! 3. **Update** — [`Adam`](crate::autodiff::Adam) on the flat parameter
//!    vector (dynamics MLP, plus the linear classifier head when present).
//!
//! Gradients are verified against central finite differences end-to-end
//! (tests below), and the λ-sweep direction — larger λ ⇒ smaller `R_K` ⇒
//! fewer adaptive-solver NFE at evaluation — is exercised by
//! `experiments::native_train`.
//!
//! The adjoint recursion itself is **model-agnostic**: everything specific
//! to the augmented system lives in one [`StageVjp`] implementation.
//! [`RkStageVjp`] is the `[y, q]` regression/classification path above;
//! [`CnfStageVjp`] is the `[z, ℓ, q]` density-estimation path, where
//! [`NativeCnfTrainer`] trains a concat-squash CNF on the exact NLL
//! objective `mean(½‖z(1)‖² + (n/2)·ln 2π − ℓ(1)) + λ·R_K` — the log-det
//! column's cotangent flows back through a forward-mode Jacobian-trace
//! recomputation on the tape ([`divergence_values`]), so even the
//! divergence differentiates exactly.

use std::ops::Range;

use crate::autodiff::div::{divergence_values, Divergence};
use crate::autodiff::{Adam, Tape, Var};
use crate::kern;
use crate::nn::{ode_jet_values, Cnf, Mlp, SeriesOf, Value};
use crate::obs::{Counter, Hist, Recorder};
use crate::solvers::adaptive::AdaptiveOpts;
use crate::solvers::batch::{
    solve_fixed_batch_record_pooled, FixedGridRecord, LogDetBatchDynamics,
    RegularizedBatchDynamics,
};
use crate::solvers::stage::TableauCoeffs;
use crate::solvers::tableau::Tableau;
use crate::util::pool::{shard_ranges, Pool};
use crate::util::rng::Pcg;

use super::evaluator::{batch_rk_eval_pooled, cnf_nll_eval_pooled, CnfNllEval, RkEval};

// ---------------------------------------------------------------------------
// Stage VJP and the discrete adjoint
// ---------------------------------------------------------------------------

/// One stage's reverse-mode pullback, pluggable into the generic discrete
/// adjoint ([`adjoint_stage_grads_pooled`]): given a cached stage input `u`
/// (`[b, width]`, one worker shard's rows) and the stage-output cotangent
/// `kbar`, write the stage-input cotangent into `ubar` and accumulate
/// parameter cotangents into `pbar`.  Implementations re-record their
/// augmented dynamics on the worker's reused arena `tape` (`tape.rows()`
/// must equal the shard batch); each call clears it, so every stage is a
/// fresh recording on warm buffers.
pub trait StageVjp: Sync {
    /// Width of the augmented per-trajectory state the record carries.
    fn width(&self) -> usize;
    /// Flat dynamics-parameter count `pbar` accumulates into.
    fn n_params(&self) -> usize;
    fn stage_vjp(
        &self,
        tape: &Tape,
        u: &[f32],
        t: f32,
        kbar: &[f64],
        pbar: &mut [f64],
        ubar: &mut [f64],
    );
}

/// The shared body of both stage VJPs: record the z columns, the
/// gradient-tracked parameter leaves, the Taylor jets (x₁ and the `R_K`
/// integrand `‖x_K‖²/n`), and — when `with_divergence` — the forward-mode
/// Jacobian trace, all through ONE model closure on the worker's tape;
/// then seed the augmented cotangent columns (`[x̄₁…, (d̄,) ḡ]`) and pull
/// back.  `model` maps (lifted parameter series, state series, time
/// series) to the dynamics output; the parameters enter as constant series
/// over gradient-tracked order-0 coefficients — one shared zero node pads
/// the higher orders, and the structural-zero mask keeps those columns
/// from recording any arithmetic on the tape.
fn augmented_stage_vjp<F>(
    tape: &Tape,
    params: &[f32],
    n: usize,
    order: usize,
    with_divergence: bool,
    u: &[f32],
    t: f32,
    kbar: &[f64],
    pbar: &mut [f64],
    ubar: &mut [f64],
    model: F,
) where
    F: Fn(&[SeriesOf<Var>], &[SeriesOf<Var>], &SeriesOf<Var>) -> Vec<SeriesOf<Var>>,
{
    let w = n + 1 + usize::from(with_divergence);
    let b = u.len() / w;
    debug_assert_eq!(tape.rows(), b, "stage_vjp: tape rows vs shard batch");
    tape.clear();
    let mut colbuf = vec![0.0f64; b];
    let zvars: Vec<Var> = (0..n)
        .map(|j| {
            kern::axpy::gather_col_f32(u, w, j, &mut colbuf);
            tape.input(&colbuf)
        })
        .collect();
    let tvar = tape.constant(t as f64);
    let pvars: Vec<Var> = params
        .iter()
        .enumerate()
        .map(|(i, p)| tape.param(i, *p as f64))
        .collect();
    let mut fs = |zs: &[SeriesOf<Var>], ts: &SeriesOf<Var>| {
        let ord = ts.order();
        let zero = tvar.lift(0.0);
        let ps: Vec<SeriesOf<Var>> = pvars
            .iter()
            .map(|p| SeriesOf::constant_padded(p.clone(), &zero, ord))
            .collect();
        model(&ps, zs, ts)
    };
    let jets = ode_jet_values(&mut fs, &zvars, &tvar, order);
    let x1 = &jets[0];
    let xk = &jets[order - 1];
    let mut g = xk[0].mul(&xk[0]);
    for xi in xk.iter().skip(1) {
        g = g.add(&xi.mul(xi));
    }
    let g = g.scale(1.0 / n as f64);
    let d = if with_divergence {
        Some(divergence_values(&mut fs, &zvars, &tvar))
    } else {
        None
    };
    let mut seed_cols: Vec<Vec<f64>> = Vec::with_capacity(w);
    for j in 0..w {
        let mut col = vec![0.0f64; b];
        kern::axpy::gather_col(kbar, w, j, &mut col);
        seed_cols.push(col);
    }
    let mut seeds: Vec<(&Var, &[f64])> = Vec::with_capacity(w);
    for (j, xj) in x1.iter().enumerate() {
        seeds.push((xj, &seed_cols[j]));
    }
    if let Some(dv) = &d {
        seeds.push((dv, &seed_cols[n]));
    }
    seeds.push((&g, &seed_cols[w - 1]));
    let grads = tape.backward(&seeds);
    for (pb, gp) in pbar.iter_mut().zip(grads.param_vec(params.len())) {
        *pb += gp;
    }
    for (j, zv) in zvars.iter().enumerate() {
        let gz = grads.wrt(zv);
        kern::axpy::scatter_col(&gz, w, j, ubar);
    }
    // The integrands read none of the augmented columns (ℓ, q).
    for r in 0..b {
        for j in n..w {
            ubar[r * w + j] = 0.0;
        }
    }
}

/// The `[y, q]` quadrature-augmented system of [`RegularizedBatchDynamics`]
/// over an [`Mlp`] — the regression/classification training path.  The
/// augmented output is `[x_1, ‖x_K‖²/n]` with jets from [`ode_jet_values`]
/// over tape values — the same recursion the f32 forward ran through
/// `ode_jet_batch`, now differentiable.
pub struct RkStageVjp<'a> {
    pub mlp: &'a Mlp,
    pub order: usize,
}

impl StageVjp for RkStageVjp<'_> {
    fn width(&self) -> usize {
        self.mlp.state_dim() + 1
    }

    fn n_params(&self) -> usize {
        self.mlp.n_params()
    }

    fn stage_vjp(
        &self,
        tape: &Tape,
        u: &[f32],
        t: f32,
        kbar: &[f64],
        pbar: &mut [f64],
        ubar: &mut [f64],
    ) {
        let mlp = self.mlp;
        augmented_stage_vjp(
            tape,
            &mlp.params,
            mlp.state_dim(),
            self.order,
            false,
            u,
            t,
            kbar,
            pbar,
            ubar,
            |ps, zs, ts| mlp.forward(ps, zs, Some(ts)),
        );
    }
}

/// The `[z, ℓ, q]` log-det + quadrature system of [`LogDetBatchDynamics`]
/// over a [`Cnf`] — the density-estimation training path.  Jets and
/// divergence both record through ONE closure on the worker's tape: the
/// jets give `x_1` and the `R_K` integrand exactly as in [`RkStageVjp`],
/// and the divergence is recomputed in *forward* mode
/// ([`divergence_values`]: n first-order series probes), so seeding the ℓ̄
/// column back-propagates exactly through the Jacobian trace
/// (reverse-over-forward — matching the exact-divergence forward solve).
pub struct CnfStageVjp<'a> {
    pub cnf: &'a Cnf,
    pub order: usize,
}

impl StageVjp for CnfStageVjp<'_> {
    fn width(&self) -> usize {
        self.cnf.state_dim() + 2
    }

    fn n_params(&self) -> usize {
        self.cnf.n_params()
    }

    fn stage_vjp(
        &self,
        tape: &Tape,
        u: &[f32],
        t: f32,
        kbar: &[f64],
        pbar: &mut [f64],
        ubar: &mut [f64],
    ) {
        let cnf = self.cnf;
        augmented_stage_vjp(
            tape,
            &cnf.params,
            cnf.state_dim(),
            self.order,
            true,
            u,
            t,
            kbar,
            pbar,
            ubar,
            |ps, zs, ts| cnf.forward(ps, zs, ts),
        );
    }
}

/// Rows per adjoint worker shard.  The canonical layout splits a batch
/// into `ceil(B / GRAD_SHARD_ROWS)` contiguous shards — a pure function of
/// the batch size, never of the thread count, so the per-shard partial
/// gradients and their fixed-order reduction are **bit-identical at every
/// `TAYNODE_THREADS` setting**.  (A batch of at most this many rows is a
/// single shard: exactly the unsharded full-batch recursion.)
const GRAD_SHARD_ROWS: usize = 16;

/// The discrete adjoint of a recorded fixed-grid solve of the
/// quadrature-augmented system: given `∂L/∂y(T)` (`ybar_final`, laid out
/// `[B, n+1]` like the record), return `(∂L/∂θ, ∂L/∂y(0))`.
///
/// Per step, processed last-to-first with cotangents of the step update
/// `y' = y + h Σ b_i k_i`,  `u_i = y + h Σ_{j<i} a_{ij} k_j`:
///
/// ```text
/// k̄_i = h b_i ȳ'  +  Σ_{i' > i} h a_{i'i} ū_{i'}
/// ū_i = (∂F/∂u)ᵀ k̄_i      (tape VJP; θ̄ += (∂F/∂θ)ᵀ k̄_i)
/// ȳ  = ȳ' + Σ_i ū_i
/// ```
///
/// The recursion is row-independent except for the row-sum into `θ̄`, so
/// the batch shards across a worker pool ([`adjoint_grads_pooled`]; this
/// wrapper uses the `TAYNODE_THREADS` pool): each worker runs the full
/// reverse sweep for its rows on one reused arena tape, and the per-worker
/// flat gradients reduce in fixed shard order.  State cotangents `ȳ(0)`
/// are bit-identical to the unsharded sweep at any layout; `θ̄` is
/// bit-identical across thread counts (fixed layout) and equal to the
/// unsharded row-sum up to addition reordering across shards.
pub fn adjoint_grads(
    mlp: &Mlp,
    order: usize,
    rec: &FixedGridRecord,
    tb: &Tableau,
    ybar_final: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    adjoint_grads_pooled(&Pool::from_env(), mlp, order, rec, tb, ybar_final)
}

/// [`adjoint_grads`] on an explicit worker pool (see there for the
/// determinism contract).
pub fn adjoint_grads_pooled(
    pool: &Pool,
    mlp: &Mlp,
    order: usize,
    rec: &FixedGridRecord,
    tb: &Tableau,
    ybar_final: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    adjoint_stage_grads_pooled(pool, &RkStageVjp { mlp, order }, rec, tb, ybar_final)
}

/// The model-agnostic adjoint entry point: the same recursion for ANY
/// augmented system, with everything model-specific behind one
/// [`StageVjp`].  Same determinism contract as [`adjoint_grads`] (fixed
/// shard layout from the batch size alone, fixed reduction order).
pub fn adjoint_stage_grads_pooled<V: StageVjp>(
    pool: &Pool,
    vjp: &V,
    rec: &FixedGridRecord,
    tb: &Tableau,
    ybar_final: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    adjoint_stage_grads_traced_pooled(pool, vjp, rec, tb, ybar_final, &mut Recorder::off())
}

/// [`adjoint_stage_grads_pooled`] with telemetry: per-shard sub-recorders
/// capture the stage-VJP count and the tape arena's node/byte high-water
/// marks, merged into `tel` in fixed shard order
/// ([`Recorder::absorb_in_order`]) so the trace is bit-identical at every
/// thread count.  With `tel` off this is exactly the untraced call.
pub fn adjoint_stage_grads_traced_pooled<V: StageVjp>(
    pool: &Pool,
    vjp: &V,
    rec: &FixedGridRecord,
    tb: &Tableau,
    ybar_final: &[f64],
    tel: &mut Recorder,
) -> (Vec<f64>, Vec<f64>) {
    adjoint_grads_sharded(pool, vjp, rec, tb, ybar_final, GRAD_SHARD_ROWS, tel)
}

/// Layout-parameterized core (tests pass `shard_rows >= B` to reproduce
/// the unsharded full-batch recursion as a reference).
fn adjoint_grads_sharded<V: StageVjp>(
    pool: &Pool,
    vjp: &V,
    rec: &FixedGridRecord,
    tb: &Tableau,
    ybar_final: &[f64],
    shard_rows: usize,
    tel: &mut Recorder,
) -> (Vec<f64>, Vec<f64>) {
    let w = vjp.width();
    assert_eq!(rec.n, w, "record width vs the stage VJP's augmented system");
    let m = rec.batch * w;
    assert_eq!(ybar_final.len(), m, "cotangent length vs record");
    assert!(shard_rows >= 1, "adjoint shard size must be positive");
    let tbf = TableauCoeffs::new(tb);
    let shards = shard_ranges(rec.batch, rec.batch.div_ceil(shard_rows));
    if shards.is_empty() {
        return (vec![0.0f64; vjp.n_params()], vec![]);
    }
    let tracing = tel.is_on();
    let parts = pool.run_range_shards(&shards, |_, r| {
        // Each worker records into its own sub-recorder and *returns* it —
        // no shared telemetry state — so the fixed-order merge below is
        // independent of which worker ran which shard.
        let mut sub = if tracing { Recorder::enabled() } else { Recorder::off() };
        let out = adjoint_shard(vjp, rec, &tbf, ybar_final, r.clone(), &mut sub);
        (out, sub)
    });
    let mut pbar = vec![0.0f64; vjp.n_params()];
    let mut ybar = Vec::with_capacity(m);
    let mut subs = Vec::new();
    for ((p, y), sub) in parts {
        // Deterministic reduction: fixed shard order, independent of which
        // worker computed which shard.
        for (acc, v) in pbar.iter_mut().zip(&p) {
            *acc += *v;
        }
        ybar.extend(y);
        if tracing {
            subs.push(sub);
        }
    }
    tel.absorb_in_order(subs);
    (pbar, ybar)
}

/// The full reverse sweep for one contiguous row shard, on one reused
/// arena tape: returns the shard's flat parameter cotangent and its rows'
/// state cotangent `ȳ(0)`.
fn adjoint_shard<V: StageVjp>(
    vjp: &V,
    rec: &FixedGridRecord,
    tbf: &TableauCoeffs,
    ybar_final: &[f64],
    rows: Range<usize>,
    tel: &mut Recorder,
) -> (Vec<f64>, Vec<f64>) {
    let w = vjp.width();
    let m = rows.len() * w;
    let h = rec.dt as f64;
    let tape = Tape::new(rows.len());
    let mut pbar = vec![0.0f64; vjp.n_params()];
    let mut ybar = ybar_final[rows.start * w..rows.end * w].to_vec();
    let mut kbar: Vec<Vec<f64>> = vec![vec![0.0f64; m]; tbf.stages];
    let mut ubar = vec![0.0f64; m];
    let mut vjps = 0u64;
    for s in (0..rec.stage_y.len()).rev() {
        for (i, kb) in kbar.iter_mut().enumerate() {
            let c = h * tbf.b[i] as f64;
            kern::axpy::scale_into(c, &ybar, kb);
        }
        for i in (0..tbf.stages).rev() {
            if kbar[i].iter().all(|v| *v == 0.0) {
                continue; // a dead stage contributes neither ū nor θ̄
            }
            vjps += 1;
            vjp.stage_vjp(
                &tape,
                &rec.stage_y[s][i][rows.start * w..rows.end * w],
                rec.stage_t[s][i],
                &kbar[i],
                &mut pbar,
                &mut ubar,
            );
            kern::axpy::add_assign(&ubar, &mut ybar);
            if i >= 1 {
                let arow = &tbf.a[i - 1];
                for j in 0..i {
                    let c = h * arow[j] as f64;
                    if c != 0.0 {
                        kern::axpy::axpy_f64(c, &ubar, &mut kbar[j]);
                    }
                }
            }
        }
    }
    if tel.is_on() {
        // `tape.len()` is the last stage recording's node count; the arena
        // bytes are the reused buffers' high-water capacity.
        let nodes = tape.len() as u64;
        let bytes = tape.arena_bytes() as u64;
        tel.inc(Counter::StageVjps, vjps);
        tel.inc(Counter::TapeNodes, nodes);
        tel.inc(Counter::TapeBytes, bytes);
        tel.observe(Hist::TapeNodes, nodes as f32);
        tel.observe(Hist::TapeBytes, bytes as f32);
        tel.span(
            "adjoint_shard",
            rows.start as u64,
            0,
            rec.stage_y.len() as u64,
            [("rows", rows.len() as f64), ("vjps", vjps as f64)],
        );
    }
    (pbar, ybar)
}

// ---------------------------------------------------------------------------
// Classifier head (closed-form gradients; the tape stays on the dynamics)
// ---------------------------------------------------------------------------

/// A linear softmax head `logits = y W + b` on the ODE's final state.  Its
/// gradients are one closed-form matmul, so it never touches the tape.
#[derive(Clone, Debug)]
pub struct LinearHead {
    pub d: usize,
    pub classes: usize,
    /// Row-major `[d, classes]`.
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl LinearHead {
    pub fn new(d: usize, classes: usize, seed: u64) -> LinearHead {
        assert!(d > 0 && classes > 1);
        let mut rng = Pcg::new(seed);
        let sd = 1.0 / (d as f32).sqrt();
        let w = (0..d * classes).map(|_| rng.normal() * sd).collect();
        LinearHead { d, classes, w, b: vec![0.0f32; classes] }
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn logits_row(&self, y: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.classes];
        for (c, o) in out.iter_mut().enumerate() {
            let mut acc = self.b[c] as f64;
            for i in 0..self.d {
                acc += y[i] as f64 * self.w[i * self.classes + c] as f64;
            }
            *o = acc;
        }
        out
    }

    /// Mean cross-entropy and error rate of final states `y` (`[B, d]`).
    pub fn metrics(&self, y: &[f32], labels: &[i32]) -> (f32, f32) {
        let bsz = labels.len();
        assert_eq!(y.len(), bsz * self.d, "head metrics: batch shape");
        let mut ce = 0.0f64;
        let mut err = 0usize;
        for (r, lab) in labels.iter().enumerate() {
            let lg = self.logits_row(&y[r * self.d..(r + 1) * self.d]);
            let (p, arg) = softmax_row(&lg);
            ce += -(p[*lab as usize].max(1e-12)).ln();
            if arg != *lab as usize {
                err += 1;
            }
        }
        ((ce / bsz as f64) as f32, err as f32 / bsz as f32)
    }
}

/// Numerically-stable softmax of one logit row, plus the argmax.
fn softmax_row(lg: &[f64]) -> (Vec<f64>, usize) {
    let mut mx = lg[0];
    let mut arg = 0usize;
    for (c, v) in lg.iter().enumerate() {
        if *v > mx {
            mx = *v;
            arg = c;
        }
    }
    let ex: Vec<f64> = lg.iter().map(|v| (v - mx).exp()).collect();
    let s: f64 = ex.iter().sum();
    (ex.iter().map(|e| e / s).collect(), arg)
}

// ---------------------------------------------------------------------------
// The trainer
// ---------------------------------------------------------------------------

/// Scalar metrics of one native train step (mirrors the artifact trainer's
/// `StepMetrics` column order: loss, task, reg).
#[derive(Clone, Debug)]
pub struct NativeMetrics {
    /// `task + λ·R_K`.
    pub loss: f32,
    /// Task term: MSE (regression) or mean cross-entropy (classification).
    pub task: f32,
    /// Batch-mean `R_K` as integrated on the training grid.
    pub reg: f32,
    /// Classification error rate (NaN on the regression path).
    pub err_rate: f32,
    /// Fixed-grid NFE the forward spent per trajectory (steps · stages).
    pub nfe: usize,
}

impl crate::obs::StepScalars for NativeMetrics {
    fn loss(&self) -> f32 {
        self.loss
    }

    fn task(&self) -> f32 {
        self.task
    }

    fn reg(&self) -> f32 {
        self.reg
    }
}

/// The native fixed-grid trainer: MLP dynamics on `t ∈ [0, 1]`, optional
/// linear classifier head, discrete-adjoint gradients, Adam updates.
pub struct NativeTrainer {
    pub mlp: Mlp,
    pub head: Option<LinearHead>,
    /// The paper's K in `R_K`.
    pub order: usize,
    /// Regularization weight λ (0 turns the objective term off; `R_K` is
    /// still measured and reported).
    pub lam: f32,
    /// Fixed-grid steps per solve.
    pub steps: usize,
    pub tb: Tableau,
    opt: Adam,
    /// Worker pool behind the forward, the adjoint, and adaptive eval.
    pool: Pool,
    /// Telemetry sink (off by default; see
    /// [`enable_recording`](NativeTrainer::enable_recording)).
    recorder: Recorder,
}

impl NativeTrainer {
    pub fn new(
        mlp: Mlp,
        head: Option<LinearHead>,
        order: usize,
        lam: f32,
        steps: usize,
        tb: Tableau,
        lr: f32,
    ) -> NativeTrainer {
        assert!(order >= 1, "R_K needs K >= 1");
        assert!(steps > 0);
        if let Some(h) = &head {
            assert_eq!(h.d, mlp.state_dim(), "head input dim vs state dim");
        }
        let nprm = mlp.n_params() + head.as_ref().map_or(0, |h| h.n_params());
        NativeTrainer {
            mlp,
            head,
            order,
            lam,
            steps,
            tb,
            opt: Adam::new(nprm, lr),
            pool: Pool::from_env(),
            recorder: Recorder::off(),
        }
    }

    /// Turn on telemetry: forward solves and adjoint shards record into
    /// the trainer's [`Recorder`], with ticks set to the optimizer step
    /// count — deterministic at every thread count, and recording never
    /// touches the numerics.
    pub fn enable_recording(&mut self) {
        self.recorder = Recorder::enabled();
    }

    /// Take the recorder out, leaving telemetry off.
    pub fn take_recorder(&mut self) -> Recorder {
        std::mem::take(&mut self.recorder)
    }

    /// Override the worker-pool thread count (defaults to
    /// `TAYNODE_THREADS` / available parallelism).  Forward solves and
    /// gradients are bit-identical at any setting.
    pub fn with_threads(mut self, threads: usize) -> NativeTrainer {
        self.pool = Pool::new(threads);
        self
    }

    /// Optimizer updates taken so far (the optimizer's own counter).
    pub fn steps_taken(&self) -> usize {
        self.opt.steps()
    }

    /// The recorded forward solve of the quadrature-augmented system over
    /// `t ∈ [0, 1]` — shared by training steps and loss evaluation, and
    /// sharded across the worker pool (each shard clones the model, so the
    /// trainer's own instance is untouched).
    pub fn forward_record(&mut self, x0: &[f32]) -> FixedGridRecord {
        assert_eq!(x0.len() % self.mlp.state_dim(), 0, "batch shape");
        let reg = RegularizedBatchDynamics::new(self.mlp.clone(), self.order);
        let aug = reg.augment(x0);
        let rec =
            solve_fixed_batch_record_pooled(&self.pool, &reg, 0.0, 1.0, &aug, self.steps, &self.tb);
        if self.recorder.is_on() {
            let ts = self.recorder.now_ticks();
            let rows = (x0.len() / self.mlp.state_dim()) as f64;
            self.recorder.inc(Counter::Nfe, rec.nfe as u64);
            self.recorder.span("forward", 0, ts, 1, [("nfe", rec.nfe as f64), ("rows", rows)]);
        }
        rec
    }

    /// Loss, metrics, and adjoint gradients of the MSE objective
    /// `mean((y(1) − targets)²) + λ·R_K` — no parameter update.
    pub fn mse_grads(&mut self, x0: &[f32], targets: &[f32]) -> (NativeMetrics, Vec<f64>) {
        let n = self.mlp.state_dim();
        assert_eq!(x0.len(), targets.len(), "mse_grads: target shape");
        assert!(self.head.is_none(), "mse path is headless; use ce_grads");
        let bsz = x0.len() / n;
        assert!(bsz > 0, "mse_grads: empty batch");
        self.recorder.set_ticks(self.opt.steps() as u64);
        let rec = self.forward_record(x0);
        let w = n + 1;
        let lam = self.lam as f64;
        let denom = (bsz * n) as f64;
        let mut task = 0.0f64;
        let mut reg = 0.0f64;
        let mut ybar = vec![0.0f64; bsz * w];
        for r in 0..bsz {
            for i in 0..n {
                let d = rec.y[r * w + i] as f64 - targets[r * n + i] as f64;
                task += d * d / denom;
                ybar[r * w + i] = 2.0 * d / denom;
            }
            ybar[r * w + n] = lam / bsz as f64;
            reg += rec.y[r * w + n] as f64 / bsz as f64;
        }
        let vjp = RkStageVjp { mlp: &self.mlp, order: self.order };
        let (grads, _) = adjoint_stage_grads_traced_pooled(
            &self.pool,
            &vjp,
            &rec,
            &self.tb,
            &ybar,
            &mut self.recorder,
        );
        let metrics = NativeMetrics {
            loss: (task + lam * reg) as f32,
            task: task as f32,
            reg: reg as f32,
            err_rate: f32::NAN,
            nfe: rec.nfe,
        };
        (metrics, grads)
    }

    /// Loss, metrics, and adjoint gradients (dynamics ++ head, the flat
    /// optimizer layout) of the cross-entropy objective — no update.
    pub fn ce_grads(&mut self, x0: &[f32], labels: &[i32]) -> (NativeMetrics, Vec<f64>) {
        let n = self.mlp.state_dim();
        let bsz = labels.len();
        assert!(bsz > 0, "ce_grads: empty batch");
        assert_eq!(x0.len(), bsz * n, "ce_grads: batch shape");
        self.recorder.set_ticks(self.opt.steps() as u64);
        let rec = self.forward_record(x0);
        let w = n + 1;
        let head = self.head.as_ref().expect("ce_grads needs a classifier head"); // taylint: allow(D4) -- documented precondition of the CE path
        let c = head.classes;
        let lam = self.lam as f64;
        let mut ce = 0.0f64;
        let mut err = 0usize;
        let mut reg = 0.0f64;
        let mut ybar = vec![0.0f64; bsz * w];
        let mut gw = vec![0.0f64; head.w.len()];
        let mut gb = vec![0.0f64; c];
        for r in 0..bsz {
            let yr = &rec.y[r * w..r * w + n];
            let lg = head.logits_row(yr);
            let (p, arg) = softmax_row(&lg);
            let lab = labels[r] as usize;
            assert!(lab < c, "label {lab} out of {c} classes");
            ce += -(p[lab].max(1e-12)).ln() / bsz as f64;
            if arg != lab {
                err += 1;
            }
            // dL/dlogit = (softmax − onehot)/B; pull back through the head
            for cc in 0..c {
                let dl = (p[cc] - if cc == lab { 1.0 } else { 0.0 }) / bsz as f64;
                gb[cc] += dl;
                for i in 0..n {
                    gw[i * c + cc] += yr[i] as f64 * dl;
                    ybar[r * w + i] += dl * head.w[i * c + cc] as f64;
                }
            }
            ybar[r * w + n] = lam / bsz as f64;
            reg += rec.y[r * w + n] as f64 / bsz as f64;
        }
        let vjp = RkStageVjp { mlp: &self.mlp, order: self.order };
        let (pbar, _) = adjoint_stage_grads_traced_pooled(
            &self.pool,
            &vjp,
            &rec,
            &self.tb,
            &ybar,
            &mut self.recorder,
        );
        let mut grads = pbar;
        grads.extend_from_slice(&gw);
        grads.extend_from_slice(&gb);
        let metrics = NativeMetrics {
            loss: (ce + lam * reg) as f32,
            task: ce as f32,
            reg: reg as f32,
            err_rate: err as f32 / bsz as f32,
            nfe: rec.nfe,
        };
        (metrics, grads)
    }

    /// One regression train step (forward, adjoint, Adam).
    pub fn step_mse(&mut self, x0: &[f32], targets: &[f32]) -> NativeMetrics {
        let (metrics, grads) = self.mse_grads(x0, targets);
        self.apply(&grads);
        metrics
    }

    /// One classification train step (forward, adjoint, Adam over
    /// dynamics ++ head).
    pub fn step_ce(&mut self, x0: &[f32], labels: &[i32]) -> NativeMetrics {
        let (metrics, grads) = self.ce_grads(x0, labels);
        self.apply(&grads);
        metrics
    }

    /// Adaptive evaluation of the current dynamics through the batched
    /// evaluator, sharded across the worker pool: per-trajectory NFE,
    /// `R_K`, and final states.
    pub fn eval_rk(&mut self, x0: &[f32], tb: &Tableau, opts: &AdaptiveOpts) -> RkEval {
        batch_rk_eval_pooled(&self.pool, &self.mlp, self.order, 0.0, 1.0, x0, tb, opts)
    }

    /// The flat parameter vector (dynamics, then head W, then head b) —
    /// the layout `Adam` and the gradient vectors share.
    pub fn flat_params(&self) -> Vec<f32> {
        let mut flat = self.mlp.params.clone();
        if let Some(h) = &self.head {
            flat.extend_from_slice(&h.w);
            flat.extend_from_slice(&h.b);
        }
        flat
    }

    /// Write a flat parameter vector back (inverse of
    /// [`flat_params`](NativeTrainer::flat_params)).
    pub fn set_flat_params(&mut self, flat: &[f32]) {
        let np = self.mlp.params.len();
        self.mlp.params.copy_from_slice(&flat[..np]);
        if let Some(h) = &mut self.head {
            let dw = h.w.len();
            h.w.copy_from_slice(&flat[np..np + dw]);
            h.b.copy_from_slice(&flat[np + dw..]);
        } else {
            assert_eq!(flat.len(), np, "flat parameter arity");
        }
    }

    fn apply(&mut self, grads: &[f64]) {
        let mut flat = self.flat_params();
        self.opt.step(&mut flat, grads);
        self.set_flat_params(&flat);
    }
}

// ---------------------------------------------------------------------------
// The CNF trainer: NLL through the log-det discrete adjoint
// ---------------------------------------------------------------------------

/// The native density-estimation trainer: a concat-squash [`Cnf`] flows
/// data → base over `t ∈ [0, 1]` with the exact-divergence log-det and the
/// `R_K` quadrature integrated alongside ([`LogDetBatchDynamics`]), and
/// each step descends `L = NLL + λ·R_K` with
/// `NLL = mean_r(½‖z_r(1)‖² + (n/2)·ln 2π − ℓ_r(1))` — the standard-normal
/// change-of-variables objective — via the generic discrete adjoint
/// ([`CnfStageVjp`]) and Adam.  Gradients are exact through the log-det
/// path (FD-verified in the tests) and bit-identical at every thread
/// count, exactly like [`NativeTrainer`].
pub struct NativeCnfTrainer {
    pub cnf: Cnf,
    /// The paper's K in `R_K`.
    pub order: usize,
    /// Regularization weight λ (0 turns the objective term off; `R_K` is
    /// still measured and reported).
    pub lam: f32,
    /// Fixed-grid steps per solve.
    pub steps: usize,
    pub tb: Tableau,
    opt: Adam,
    /// Worker pool behind the forward, the adjoint, and adaptive eval.
    pool: Pool,
}

impl NativeCnfTrainer {
    pub fn new(
        cnf: Cnf,
        order: usize,
        lam: f32,
        steps: usize,
        tb: Tableau,
        lr: f32,
    ) -> NativeCnfTrainer {
        assert!(order >= 1, "R_K needs K >= 1");
        assert!(steps > 0);
        let nprm = cnf.n_params();
        NativeCnfTrainer {
            cnf,
            order,
            lam,
            steps,
            tb,
            opt: Adam::new(nprm, lr),
            pool: Pool::from_env(),
        }
    }

    /// Override the worker-pool thread count (defaults to
    /// `TAYNODE_THREADS` / available parallelism).  Forward solves and
    /// gradients are bit-identical at any setting.
    pub fn with_threads(mut self, threads: usize) -> NativeCnfTrainer {
        self.pool = Pool::new(threads);
        self
    }

    /// Optimizer updates taken so far (the optimizer's own counter).
    pub fn steps_taken(&self) -> usize {
        self.opt.steps()
    }

    /// The recorded forward solve of the `[z, ℓ, q]` system over
    /// `t ∈ [0, 1]`: **exact** divergence (training differentiates the same
    /// trace the forward integrated; Hutchinson is an evaluation-cost mode,
    /// not a training mode), `R_K` quadrature composed in, sharded across
    /// the worker pool.
    pub fn forward_record(&self, x0: &[f32]) -> FixedGridRecord {
        assert_eq!(x0.len() % self.cnf.state_dim(), 0, "batch shape");
        let aug_dyn = LogDetBatchDynamics::new(self.cnf.clone(), Divergence::Exact)
            .with_regularizer(self.order);
        let aug = aug_dyn.augment(x0);
        solve_fixed_batch_record_pooled(&self.pool, &aug_dyn, 0.0, 1.0, &aug, self.steps, &self.tb)
    }

    /// Loss, metrics, and adjoint gradients of the NLL objective — no
    /// parameter update.  `task` in the metrics is the mean NLL in nats.
    pub fn nll_grads(&mut self, x0: &[f32]) -> (NativeMetrics, Vec<f64>) {
        let n = self.cnf.state_dim();
        let bsz = x0.len() / n;
        assert!(bsz > 0, "nll_grads: empty batch");
        let rec = self.forward_record(x0);
        let w = n + 2;
        let lam = self.lam as f64;
        let half_ln_2pi = 0.5 * (2.0 * std::f64::consts::PI).ln();
        let mut task = 0.0f64;
        let mut reg = 0.0f64;
        let mut ybar = vec![0.0f64; bsz * w];
        for r in 0..bsz {
            let mut sq = 0.0f64;
            for i in 0..n {
                let zi = rec.y[r * w + i] as f64;
                sq += zi * zi;
                ybar[r * w + i] = zi / bsz as f64;
            }
            let ldet = rec.y[r * w + n] as f64;
            task += (0.5 * sq + n as f64 * half_ln_2pi - ldet) / bsz as f64;
            ybar[r * w + n] = -1.0 / bsz as f64;
            ybar[r * w + n + 1] = lam / bsz as f64;
            reg += rec.y[r * w + n + 1] as f64 / bsz as f64;
        }
        let vjp = CnfStageVjp { cnf: &self.cnf, order: self.order };
        let (grads, _) = adjoint_stage_grads_pooled(&self.pool, &vjp, &rec, &self.tb, &ybar);
        let metrics = NativeMetrics {
            loss: (task + lam * reg) as f32,
            task: task as f32,
            reg: reg as f32,
            err_rate: f32::NAN,
            nfe: rec.nfe,
        };
        (metrics, grads)
    }

    /// One density-estimation train step (forward, adjoint, Adam).  The
    /// CNF has no classifier head, so the flat optimizer vector IS the
    /// model's parameter vector — no round-trip copy.
    pub fn step_nll(&mut self, x0: &[f32]) -> NativeMetrics {
        let (metrics, grads) = self.nll_grads(x0);
        self.opt.step(&mut self.cnf.params, &grads);
        metrics
    }

    /// Adaptive evaluation of the current flow through the batched
    /// evaluator, sharded across the worker pool: NLL, per-trajectory NFE,
    /// log-det, and `R_K`.
    pub fn eval_nll(&self, x0: &[f32], tb: &Tableau, opts: &AdaptiveOpts) -> CnfNllEval {
        cnf_nll_eval_pooled(&self.pool, &self.cnf, self.order, &Divergence::Exact, x0, tb, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::tableau;
    use crate::util::rng::Pcg;

    fn toy_batch(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let x0: Vec<f32> = (0..n).map(|_| rng.range(-1.2, 1.2)).collect();
        let targets = x0.iter().map(|x| x + x * x * x).collect();
        (x0, targets)
    }

    fn fd_close(fd: f64, adj: f64) -> bool {
        (fd - adj).abs() <= 1e-3 * fd.abs().max(adj.abs()).max(1.0)
    }

    #[test]
    fn adjoint_matches_finite_differences_mse() {
        // The acceptance criterion: tape/adjoint gradients of the full
        // regularized objective through a 2-step fixed-grid solve match
        // central finite differences of the actual forward loss to 1e-3
        // relative, for every parameter.
        let mlp = Mlp::new(1, &[3], true, 5);
        let mut tr = NativeTrainer::new(mlp, None, 2, 0.3, 2, tableau::rk4(), 0.01);
        let (x0, targets) = toy_batch(3, 17);
        let (_, grads) = tr.mse_grads(&x0, &targets);
        let flat = tr.flat_params();
        assert_eq!(grads.len(), flat.len());
        assert!(grads.iter().any(|g| g.abs() > 1e-8), "gradients all ~0");
        let eps = 4e-3f32;
        for i in 0..flat.len() {
            let mut fp = flat.clone();
            fp[i] = flat[i] + eps;
            tr.set_flat_params(&fp);
            let (mp, _) = tr.mse_grads(&x0, &targets);
            fp[i] = flat[i] - eps;
            tr.set_flat_params(&fp);
            let (mm, _) = tr.mse_grads(&x0, &targets);
            fp[i] = flat[i];
            tr.set_flat_params(&fp);
            let fd = (mp.loss as f64 - mm.loss as f64) / (2.0 * eps as f64);
            assert!(
                fd_close(fd, grads[i]),
                "param {i}: fd {fd} vs adjoint {}",
                grads[i]
            );
        }
    }

    #[test]
    fn adjoint_matches_finite_differences_ce_with_head() {
        // Same check on the classification path: dynamics AND head slots of
        // the flat gradient vector, through the softmax/CE closed form.
        let mlp = Mlp::new(2, &[3], true, 7);
        let head = LinearHead::new(2, 3, 8);
        let mut tr = NativeTrainer::new(mlp, Some(head), 1, 0.2, 2, tableau::bosh3(), 0.01);
        let mut rng = Pcg::new(4);
        let bsz = 4usize;
        let x0: Vec<f32> = (0..bsz * 2).map(|_| rng.range(-1.0, 1.0)).collect();
        let labels: Vec<i32> = (0..bsz).map(|r| (r % 3) as i32).collect();
        let (_, grads) = tr.ce_grads(&x0, &labels);
        let flat = tr.flat_params();
        assert_eq!(grads.len(), flat.len());
        let eps = 4e-3f32;
        for i in 0..flat.len() {
            let mut fp = flat.clone();
            fp[i] = flat[i] + eps;
            tr.set_flat_params(&fp);
            let (mp, _) = tr.ce_grads(&x0, &labels);
            fp[i] = flat[i] - eps;
            tr.set_flat_params(&fp);
            let (mm, _) = tr.ce_grads(&x0, &labels);
            fp[i] = flat[i];
            tr.set_flat_params(&fp);
            let fd = (mp.loss as f64 - mm.loss as f64) / (2.0 * eps as f64);
            assert!(
                fd_close(fd, grads[i]),
                "param {i}: fd {fd} vs adjoint {}",
                grads[i]
            );
        }
    }

    #[test]
    fn adjoint_bit_identical_across_thread_counts_and_vs_unsharded() {
        // B = 40 spans three canonical shards.  The flat gradient and the
        // state cotangent must be bit-identical at 1, 2, and 4 threads
        // (fixed layout + fixed reduction order); the state cotangent must
        // also equal the unsharded full-batch recursion bit-for-bit (rows
        // never interact), while the sharded θ̄ matches it to
        // addition-reordering tolerance.
        let mlp = Mlp::new(1, &[5], true, 31);
        let order = 2usize;
        let steps = 2usize;
        let tb = tableau::bosh3();
        let b = 40usize;
        let mut rng = Pcg::new(77);
        let x0: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let reg = RegularizedBatchDynamics::new(mlp.clone(), order);
        let aug = reg.augment(&x0);
        let rec = crate::solvers::batch::solve_fixed_batch_record_pooled(
            &Pool::new(1),
            &reg,
            0.0,
            1.0,
            &aug,
            steps,
            &tb,
        );
        let ybar: Vec<f64> = (0..b * 2).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let (p1, y1) = adjoint_grads_pooled(&Pool::new(1), &mlp, order, &rec, &tb, &ybar);
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let (pt, yt) = adjoint_grads_pooled(&pool, &mlp, order, &rec, &tb, &ybar);
            for (a, w) in pt.iter().zip(&p1) {
                assert_eq!(a.to_bits(), w.to_bits(), "θ̄ threads={threads}");
            }
            for (a, w) in yt.iter().zip(&y1) {
                assert_eq!(a.to_bits(), w.to_bits(), "ȳ threads={threads}");
            }
        }
        // the unsharded reference: one shard spanning the whole batch
        let vjp = RkStageVjp { mlp: &mlp, order };
        let (pu, yu) =
            adjoint_grads_sharded(&Pool::new(1), &vjp, &rec, &tb, &ybar, b, &mut Recorder::off());
        for (a, w) in y1.iter().zip(&yu) {
            assert_eq!(a.to_bits(), w.to_bits(), "sharded ȳ vs unsharded");
        }
        for (i, (a, w)) in p1.iter().zip(&pu).enumerate() {
            // addition reordering across 3 shards: ulp-level, but allow an
            // absolute floor for cancellation-heavy slots
            assert!(
                (a - w).abs() <= 1e-10 + 1e-9 * a.abs().max(w.abs()),
                "θ̄[{i}] sharded {a} vs unsharded {w}"
            );
        }
    }

    #[test]
    fn adjoint_stage_grads_pooled_matches_pool_of_one_bit_for_bit() {
        // The model-agnostic entry point has no standalone serial twin; a
        // Pool::new(1) sweep runs every gradient shard inline and is the
        // serial reference the determinism contract (lint rule D5) pins.
        let mlp = Mlp::new(1, &[4], true, 13);
        let order = 2usize;
        let b = 25usize; // spans two canonical GRAD_SHARD_ROWS shards
        let mut rng = Pcg::new(21);
        let x0: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let reg = RegularizedBatchDynamics::new(mlp.clone(), order);
        let aug = reg.augment(&x0);
        let tb = tableau::rk4();
        let rec = crate::solvers::batch::solve_fixed_batch_record_pooled(
            &Pool::new(1),
            &reg,
            0.0,
            1.0,
            &aug,
            2,
            &tb,
        );
        let ybar: Vec<f64> = (0..b * 2).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let vjp = RkStageVjp { mlp: &mlp, order };
        let (p1, y1) = adjoint_stage_grads_pooled(&Pool::new(1), &vjp, &rec, &tb, &ybar);
        for threads in [2usize, 3, 4] {
            let pool = Pool::new(threads);
            let (pt, yt) = adjoint_stage_grads_pooled(&pool, &vjp, &rec, &tb, &ybar);
            for (a, w) in pt.iter().zip(&p1) {
                assert_eq!(a.to_bits(), w.to_bits(), "θ̄ threads={threads}");
            }
            for (a, w) in yt.iter().zip(&y1) {
                assert_eq!(a.to_bits(), w.to_bits(), "ȳ threads={threads}");
            }
        }
    }

    #[test]
    fn adjoint_stage_grads_traced_pooled_matches_untraced_and_pool_of_one() {
        // The telemetry-carrying entry point: recording (off or on) must
        // not move a gradient bit, and the recorded stream itself must be
        // identical at every thread count (Pool::new(1) is the serial
        // reference the determinism contract, lint rule D5, pins).
        let mlp = Mlp::new(1, &[4], true, 29);
        let order = 2usize;
        let b = 25usize; // spans two canonical GRAD_SHARD_ROWS shards
        let mut rng = Pcg::new(31);
        let x0: Vec<f32> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let reg = RegularizedBatchDynamics::new(mlp.clone(), order);
        let aug = reg.augment(&x0);
        let tb = tableau::rk4();
        let rec = crate::solvers::batch::solve_fixed_batch_record_pooled(
            &Pool::new(1),
            &reg,
            0.0,
            1.0,
            &aug,
            2,
            &tb,
        );
        let ybar: Vec<f64> = (0..b * 2).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let vjp = RkStageVjp { mlp: &mlp, order };
        let (p1, y1) = adjoint_stage_grads_pooled(&Pool::new(1), &vjp, &rec, &tb, &ybar);
        let serial = Pool::new(1);
        let mut base_tel = Recorder::enabled();
        let (bp, by) =
            adjoint_stage_grads_traced_pooled(&serial, &vjp, &rec, &tb, &ybar, &mut base_tel);
        assert_eq!(bp, p1, "traced-on θ̄ vs untraced");
        assert_eq!(by, y1, "traced-on ȳ vs untraced");
        assert!(!base_tel.events().is_empty(), "adjoint shards must record spans");
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let mut off = Recorder::off();
            let (po, yo) =
                adjoint_stage_grads_traced_pooled(&pool, &vjp, &rec, &tb, &ybar, &mut off);
            assert_eq!(po, p1, "traced-off θ̄ threads={threads}");
            assert_eq!(yo, y1, "traced-off ȳ threads={threads}");
            let mut tel = Recorder::enabled();
            adjoint_stage_grads_traced_pooled(&pool, &vjp, &rec, &tb, &ybar, &mut tel);
            assert_eq!(tel.events(), base_tel.events(), "trace threads={threads}");
            assert_eq!(tel.registry(), base_tel.registry(), "registry threads={threads}");
        }
    }

    #[test]
    fn small_batch_adjoint_is_the_unsharded_recursion_bit_for_bit() {
        // A batch that fits one canonical shard (B <= GRAD_SHARD_ROWS) IS
        // the pre-refactor full-batch recursion: the public entry point
        // must reproduce the shard_rows = B reference exactly, θ̄ included.
        let mlp = Mlp::new(2, &[4], true, 9);
        let order = 2usize;
        let b = 6usize;
        let mut rng = Pcg::new(5);
        let x0: Vec<f32> = (0..b * 2).map(|_| rng.range(-1.0, 1.0)).collect();
        let reg = RegularizedBatchDynamics::new(mlp.clone(), order);
        let aug = reg.augment(&x0);
        let tb = tableau::rk4();
        let rec = crate::solvers::batch::solve_fixed_batch_record_pooled(
            &Pool::new(1),
            &reg,
            0.0,
            1.0,
            &aug,
            3,
            &tb,
        );
        let ybar: Vec<f64> = (0..b * 3).map(|_| rng.range(-1.0, 1.0) as f64).collect();
        let (p, y) = adjoint_grads_pooled(&Pool::new(4), &mlp, order, &rec, &tb, &ybar);
        let vjp = RkStageVjp { mlp: &mlp, order };
        let (pu, yu) =
            adjoint_grads_sharded(&Pool::new(1), &vjp, &rec, &tb, &ybar, b, &mut Recorder::off());
        for (a, w) in p.iter().zip(&pu) {
            assert_eq!(a.to_bits(), w.to_bits(), "θ̄");
        }
        for (a, w) in y.iter().zip(&yu) {
            assert_eq!(a.to_bits(), w.to_bits(), "ȳ");
        }
    }

    #[test]
    fn trainer_gradients_bit_identical_across_thread_counts() {
        // End-to-end determinism: the whole train-step gradient (pooled
        // forward record + pooled adjoint) is reproducible at any
        // TAYNODE_THREADS setting.
        let (x0, targets) = toy_batch(40, 3);
        let grads_at = |threads: usize| {
            let mlp = Mlp::new(1, &[6], true, 4);
            let mut tr = NativeTrainer::new(mlp, None, 2, 0.3, 2, tableau::rk4(), 0.01)
                .with_threads(threads);
            tr.mse_grads(&x0, &targets)
        };
        let (m1, g1) = grads_at(1);
        for threads in [2usize, 4] {
            let (mt, gt) = grads_at(threads);
            assert_eq!(m1.loss.to_bits(), mt.loss.to_bits(), "loss threads={threads}");
            assert_eq!(m1.reg.to_bits(), mt.reg.to_bits(), "reg threads={threads}");
            for (a, w) in gt.iter().zip(&g1) {
                assert_eq!(a.to_bits(), w.to_bits(), "grad threads={threads}");
            }
        }
    }

    #[test]
    fn lambda_regularization_reduces_rk_while_task_stays_finite() {
        // The paper's smoke direction: training the same model from the
        // same init with λ = 1 must end with R_K no larger than the λ = 0
        // run, and both task losses stay finite (λ = 0 also has to learn).
        let (x0, targets) = toy_batch(16, 23);
        let train = |lam: f32| {
            let mlp = Mlp::new(1, &[8, 8], true, 9);
            let mut tr = NativeTrainer::new(mlp, None, 2, lam, 4, tableau::rk4(), 0.02);
            let (init, _) = tr.mse_grads(&x0, &targets);
            let mut last = init.clone();
            for _ in 0..60 {
                last = tr.step_mse(&x0, &targets);
            }
            (init, last)
        };
        let (i0, f0) = train(0.0);
        let (_, f1) = train(1.0);
        assert!(f0.task.is_finite() && f1.task.is_finite());
        assert!(f0.loss.is_finite() && f1.loss.is_finite());
        assert!(
            f0.task < i0.task,
            "λ=0 did not learn: {} -> {}",
            i0.task,
            f0.task
        );
        assert!(
            f1.reg <= f0.reg + 1e-6,
            "R_K with λ=1 ({}) exceeds λ=0 ({})",
            f1.reg,
            f0.reg
        );
    }

    #[test]
    fn eval_rk_wires_the_batched_evaluator() {
        let mlp = Mlp::new(1, &[4], true, 2);
        let mut tr = NativeTrainer::new(mlp, None, 2, 0.0, 4, tableau::rk4(), 0.01);
        let opts = AdaptiveOpts::default();
        let ev = tr.eval_rk(&[0.3, -0.5], &tableau::dopri5(), &opts);
        assert_eq!(ev.n, 1);
        assert_eq!(ev.r_k.len(), 2);
        assert!(ev.y.iter().all(|v| v.is_finite()));
        assert!(ev.stats.iter().all(|s| s.nfe > 0));
        assert!(ev.mean_r_k.is_finite());
    }

    #[test]
    fn flat_params_roundtrip_with_head() {
        let mlp = Mlp::new(2, &[3], false, 1);
        let head = LinearHead::new(2, 4, 2);
        let mut tr = NativeTrainer::new(mlp, Some(head), 1, 0.0, 1, tableau::euler(), 0.1);
        let flat = tr.flat_params();
        let bumped: Vec<f32> = flat.iter().map(|v| v + 1.0).collect();
        tr.set_flat_params(&bumped);
        assert_eq!(tr.flat_params(), bumped);
        assert_eq!(
            flat.len(),
            tr.mlp.n_params() + tr.head.as_ref().unwrap().n_params()
        );
    }

    #[test]
    fn cnf_adjoint_matches_finite_differences_nll() {
        // The density-estimation acceptance criterion: adjoint gradients of
        // the full NLL + λ·R_K objective — the log-det path included —
        // through a 2-step fixed-grid solve match central finite
        // differences of the actual forward loss to 1e-3 relative, for
        // every parameter (weights, biases, gates, time biases).
        let cnf = Cnf::new(2, &[3], 5);
        let mut tr = NativeCnfTrainer::new(cnf, 2, 0.3, 2, tableau::bosh3(), 0.01);
        let mut rng = Pcg::new(19);
        let x0: Vec<f32> = (0..6).map(|_| rng.range(-1.2, 1.2)).collect();
        let (_, grads) = tr.nll_grads(&x0);
        let flat = tr.cnf.params.clone();
        assert_eq!(grads.len(), flat.len());
        assert!(grads.iter().any(|g| g.abs() > 1e-8), "gradients all ~0");
        let eps = 4e-3f32;
        for i in 0..flat.len() {
            tr.cnf.params[i] = flat[i] + eps;
            let (mp, _) = tr.nll_grads(&x0);
            tr.cnf.params[i] = flat[i] - eps;
            let (mm, _) = tr.nll_grads(&x0);
            tr.cnf.params[i] = flat[i];
            let fd = (mp.loss as f64 - mm.loss as f64) / (2.0 * eps as f64);
            assert!(
                fd_close(fd, grads[i]),
                "param {i}: fd {fd} vs adjoint {}",
                grads[i]
            );
        }
    }

    #[test]
    fn cnf_gradients_bit_identical_across_thread_counts() {
        // End-to-end determinism for the CNF path: pooled forward record
        // (chunk queue) + pooled adjoint (fixed shard layout) reproduce the
        // same loss and gradient bits at any TAYNODE_THREADS setting.
        let mut rng = Pcg::new(29);
        let x0: Vec<f32> = (0..40 * 2).map(|_| rng.range(-1.2, 1.2)).collect();
        let grads_at = |threads: usize| {
            let cnf = Cnf::new(2, &[5], 4);
            let mut tr = NativeCnfTrainer::new(cnf, 2, 0.3, 2, tableau::rk4(), 0.01)
                .with_threads(threads);
            tr.nll_grads(&x0)
        };
        let (m1, g1) = grads_at(1);
        for threads in [2usize, 4] {
            let (mt, gt) = grads_at(threads);
            assert_eq!(m1.loss.to_bits(), mt.loss.to_bits(), "loss threads={threads}");
            assert_eq!(m1.reg.to_bits(), mt.reg.to_bits(), "reg threads={threads}");
            for (a, w) in gt.iter().zip(&g1) {
                assert_eq!(a.to_bits(), w.to_bits(), "grad threads={threads}");
            }
        }
    }

    #[test]
    fn cnf_training_reduces_nll_on_the_toy_density() {
        // The subsystem must actually do density estimation: NLL on the
        // 2-D two-Gaussians toy density decreases over training.
        let x = crate::data::toy_density::sample("two_gaussians", 24, 7);
        let cnf = Cnf::new(2, &[8], 3);
        let mut tr = NativeCnfTrainer::new(cnf, 2, 0.0, 4, tableau::rk4(), 0.02);
        let (init, _) = tr.nll_grads(&x);
        let mut last = init.clone();
        for _ in 0..40 {
            last = tr.step_nll(&x);
        }
        assert!(last.task.is_finite());
        assert!(
            last.task < init.task,
            "NLL did not decrease: {} -> {}",
            init.task,
            last.task
        );
        assert_eq!(tr.steps_taken(), 40);
    }

    #[test]
    fn cnf_lambda_regularization_reduces_rk() {
        // The paper's density-estimation smoke direction: training from the
        // same init with λ = 1 must end with R_K no larger than λ = 0, and
        // both NLLs stay finite.
        let x = crate::data::toy_density::sample("two_gaussians", 16, 23);
        let train = |lam: f32| {
            let cnf = Cnf::new(2, &[8], 9);
            let mut tr = NativeCnfTrainer::new(cnf, 2, lam, 4, tableau::rk4(), 0.02);
            let mut last = tr.nll_grads(&x).0;
            for _ in 0..50 {
                last = tr.step_nll(&x);
            }
            last
        };
        let f0 = train(0.0);
        let f1 = train(1.0);
        assert!(f0.task.is_finite() && f1.task.is_finite());
        assert!(
            f1.reg <= f0.reg + 1e-6,
            "R_K with λ=1 ({}) exceeds λ=0 ({})",
            f1.reg,
            f0.reg
        );
    }

    #[test]
    fn cnf_eval_wires_the_nll_evaluator() {
        let cnf = Cnf::new(2, &[4], 2);
        let tr = NativeCnfTrainer::new(cnf, 2, 0.0, 4, tableau::rk4(), 0.01);
        let opts = AdaptiveOpts::default();
        let x0 = [0.3f32, -0.5, 0.8, 0.1];
        let ev = tr.eval_nll(&x0, &tableau::dopri5(), &opts);
        assert_eq!(ev.n, 2);
        assert_eq!(ev.per_nll.len(), 2);
        assert!(ev.nll.is_finite());
        assert!(ev.mean_logdet.is_finite());
        assert!(ev.mean_r_k.is_finite());
        assert!(ev.stats.iter().all(|s| s.nfe > 0));
    }

    #[test]
    fn head_metrics_match_grads_path() {
        // LinearHead::metrics (evaluation) and ce_grads (training) must
        // report the same cross-entropy/error on identical states.
        let mlp = Mlp::new(2, &[], false, 3);
        let head = LinearHead::new(2, 3, 4);
        let head_copy = head.clone();
        let mut tr = NativeTrainer::new(mlp, Some(head), 1, 0.0, 1, tableau::euler(), 0.1);
        let mut rng = Pcg::new(6);
        let x0: Vec<f32> = (0..8).map(|_| rng.range(-1.0, 1.0)).collect();
        let labels = vec![0i32, 1, 2, 1];
        let (m, _) = tr.ce_grads(&x0, &labels);
        // reproduce the final states and compare head metrics
        let rec = tr.forward_record(&x0);
        let mut yfin = Vec::with_capacity(8);
        for r in 0..4 {
            yfin.extend_from_slice(&rec.y[r * 3..r * 3 + 2]);
        }
        let (ce, err) = head_copy.metrics(&yfin, &labels);
        assert!((ce - m.task).abs() < 1e-5, "{ce} vs {}", m.task);
        assert!((err - m.err_rate).abs() < 1e-6);
    }
}
