//! L3 coordinator: training orchestration (`trainer`), evaluation
//! instrumentation (`evaluator`), schedules, and metrics persistence.

pub mod evaluator;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use evaluator::{
    batch_rk_eval, cnf_eval, latent_eval, mnist_eval, mnist_reg_quantities, toy_eval, RkEval,
};
pub use metrics::MetricsLog;
pub use schedule::Schedule;
pub use trainer::{BatchInputs, StepMetrics, Trainer};
