//! L3 coordinator: training orchestration (the exported-artifact `trainer`
//! and the native discrete-adjoint `train_native`), evaluation
//! instrumentation (`evaluator`), schedules, and metrics persistence.

pub mod evaluator;
pub mod metrics;
pub mod schedule;
pub mod train_native;
pub mod trainer;

pub use evaluator::{
    batch_rk_eval, batch_rk_eval_pooled, cnf_eval, latent_eval, mnist_eval, mnist_reg_quantities,
    toy_eval, RkEval,
};
pub use metrics::MetricsLog;
pub use schedule::Schedule;
pub use train_native::{
    adjoint_grads, adjoint_grads_pooled, adjoint_stage_grads_traced_pooled, LinearHead,
    NativeMetrics, NativeTrainer,
};
pub use trainer::{BatchInputs, StepMetrics, Trainer};
