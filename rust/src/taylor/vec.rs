//! Batched truncated Taylor series over an SoA coefficient slab — the
//! `[B, n]` counterpart of the scalar [`Series`](super::Series), and the
//! substrate for native batched `R_K` regularization (paper §3–4).
//!
//! A [`SeriesVec`] holds its coefficients in ONE contiguous `[K+1, m]`
//! slab (`m = rows · cols`; coefficient row k at `c[k·m..(k+1)·m]`) and
//! routes the Cauchy product and the ODE recurrences through the blocked
//! kernels in [`crate::kern::cauchy`], which apply **exactly the scalar
//! propagation rules elementwise, in the scalar operation order**, so
//! every element of a batched series is bit-identical to the scalar
//! `Series` computation on that element (property-tested below).
//! [`ode_jet_batch`] then lifts a [`BatchSeriesDynamics`] vector field
//! recursively (Algorithm 1) to produce the solution jets x₁..x_K for a
//! whole active set in one sweep — one series evaluation per jet order
//! for the entire batch, instead of one per trajectory per order.
//!
//! ```
//! use taynode::taylor::{ode_jet_batch, SeriesFn, SeriesVec};
//!
//! // Two rows of dz/dt = z: every derivative of the solution equals z0.
//! let mut f = SeriesFn::new(1, |_ids: &[usize], z: &SeriesVec, _t: &SeriesVec| z.clone());
//! let jets = ode_jet_batch(&mut f, &[0, 1], &[2.0, 3.0], &[0.0, 0.0], 3);
//! assert_eq!(jets.len(), 3);
//! for x in &jets {
//!     assert_eq!(x[0], 2.0);
//!     assert_eq!(x[1], 3.0);
//! }
//! ```

use super::factorial;
use crate::kern::cauchy;

/// A batch of truncated Taylor polynomials, stored structure-of-arrays on
/// one flat slab: coefficient row k holds the k-th normalized coefficient
/// for every element of a row-major `[rows, cols]` matrix.  Rows are
/// trajectories, columns are state dimensions; elementwise ops share one
/// contiguous allocation for the whole batch across all orders — the
/// layout the blocked kernels ([`crate::kern::cauchy`]) stream over.
#[derive(Clone, Debug, PartialEq)]
pub struct SeriesVec {
    rows: usize,
    cols: usize,
    /// Order + 1 — the number of `[rows, cols]` coefficient rows in `c`.
    k1: usize,
    /// Flat `[k1, rows * cols]` slab; row k is `c[k * m..(k + 1) * m]`.
    c: Vec<f64>,
}

impl SeriesVec {
    /// Build from explicit coefficient matrices (each `rows * cols` long).
    pub fn new(c: Vec<Vec<f64>>, rows: usize, cols: usize) -> SeriesVec {
        assert!(!c.is_empty(), "SeriesVec needs at least the order-0 coefficient");
        let m = rows * cols;
        for (k, ck) in c.iter().enumerate() {
            assert_eq!(ck.len(), m, "coefficient {k} length vs {rows}x{cols}");
        }
        let k1 = c.len();
        let mut slab = Vec::with_capacity(k1 * m);
        for ck in &c {
            slab.extend_from_slice(ck);
        }
        SeriesVec { rows, cols, k1, c: slab }
    }

    /// A constant batch: order-0 coefficients from `vals`, the rest zero.
    pub fn constant(vals: &[f64], rows: usize, cols: usize, order: usize) -> SeriesVec {
        let m = rows * cols;
        assert_eq!(vals.len(), m, "constant values vs {rows}x{cols}");
        let mut c = vec![0.0; (order + 1) * m];
        c[..m].copy_from_slice(vals);
        SeriesVec { rows, cols, k1: order + 1, c }
    }

    /// A uniform constant batch (every element `x`).
    pub fn fill(x: f64, rows: usize, cols: usize, order: usize) -> SeriesVec {
        let m = rows * cols;
        let mut c = vec![0.0; (order + 1) * m];
        for v in c[..m].iter_mut() {
            *v = x;
        }
        SeriesVec { rows, cols, k1: order + 1, c }
    }

    /// The independent variable per row: `t0[r] + 1·t`, as a single-column
    /// batch (broadcast against `[rows, n]` states with
    /// [`broadcast_cols`](SeriesVec::broadcast_cols)).
    pub fn time(t0: &[f64], order: usize) -> SeriesVec {
        let rows = t0.len();
        let mut c = vec![0.0; (order + 1) * rows];
        c[..rows].copy_from_slice(t0);
        if order >= 1 {
            for v in c[rows..2 * rows].iter_mut() {
                *v = 1.0;
            }
        }
        SeriesVec { rows, cols: 1, k1: order + 1, c }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn order(&self) -> usize {
        self.k1 - 1
    }

    /// The k-th normalized coefficient matrix, row-major `[rows, cols]` —
    /// a view into the flat slab.
    pub fn coeff(&self, k: usize) -> &[f64] {
        let m = self.elems();
        &self.c[k * m..(k + 1) * m]
    }

    /// Mutable view of the k-th coefficient row (test scaffolding only —
    /// production construction goes through the public constructors).
    #[cfg(test)]
    fn coeff_mut(&mut self, k: usize) -> &mut [f64] {
        let m = self.rows * self.cols;
        &mut self.c[k * m..(k + 1) * m]
    }

    /// Unnormalized derivative matrix d^k x/dt^k = k! c[k].
    pub fn derivative(&self, k: usize) -> Vec<f64> {
        let f = factorial(k);
        self.coeff(k).iter().map(|v| v * f).collect()
    }

    fn assert_same_shape(&self, o: &SeriesVec, op: &str) {
        assert_eq!(self.rows, o.rows, "{op}: row mismatch");
        assert_eq!(self.cols, o.cols, "{op}: column mismatch");
        assert_eq!(self.k1, o.k1, "{op}: order mismatch");
    }

    fn elems(&self) -> usize {
        self.rows * self.cols
    }

    /// Same-shape result from a freshly computed slab.
    fn with_slab(&self, c: Vec<f64>) -> SeriesVec {
        debug_assert_eq!(c.len(), self.c.len());
        SeriesVec { rows: self.rows, cols: self.cols, k1: self.k1, c }
    }

    /// Extract one state dimension as a `[rows, 1]` column series — how the
    /// value-generic model forward (`nn::Mlp`) consumes a `[rows, n]` batch:
    /// activations are columns, elementwise ops cover the whole batch.
    pub fn col(&self, j: usize) -> SeriesVec {
        assert!(j < self.cols, "col {j} out of {} columns", self.cols);
        let mut c = Vec::with_capacity(self.k1 * self.rows);
        for k in 0..self.k1 {
            let ck = self.coeff(k);
            for r in 0..self.rows {
                c.push(ck[r * self.cols + j]);
            }
        }
        SeriesVec { rows: self.rows, cols: 1, k1: self.k1, c }
    }

    /// Reassemble `[rows, 1]` column series into one `[rows, n]` batch —
    /// the inverse of per-column [`col`](SeriesVec::col) extraction.
    pub fn from_cols(cols: &[SeriesVec]) -> SeriesVec {
        assert!(!cols.is_empty(), "from_cols needs at least one column");
        let rows = cols[0].rows;
        let ord = cols[0].order();
        let n = cols.len();
        for (j, cj) in cols.iter().enumerate() {
            assert_eq!(cj.cols, 1, "from_cols: column {j} is not single-column");
            assert_eq!(cj.rows, rows, "from_cols: column {j} row mismatch");
            assert_eq!(cj.order(), ord, "from_cols: column {j} order mismatch");
        }
        let mut c = Vec::with_capacity((ord + 1) * rows * n);
        for k in 0..=ord {
            for r in 0..rows {
                for cj in cols {
                    c.push(cj.coeff(k)[r]);
                }
            }
        }
        SeriesVec { rows, cols: n, k1: ord + 1, c }
    }

    /// Replicate a single-column batch across `cols` columns — how per-row
    /// time series meet `[rows, n]` states in elementwise vector fields.
    pub fn broadcast_cols(&self, cols: usize) -> SeriesVec {
        assert_eq!(self.cols, 1, "broadcast_cols needs a single-column series");
        assert!(cols > 0);
        let mut c = Vec::with_capacity(self.k1 * self.rows * cols);
        for k in 0..self.k1 {
            let ck = self.coeff(k);
            for r in 0..self.rows {
                for _ in 0..cols {
                    c.push(ck[r]);
                }
            }
        }
        SeriesVec { rows: self.rows, cols, k1: self.k1, c }
    }

    pub fn add(&self, o: &SeriesVec) -> SeriesVec {
        self.assert_same_shape(o, "add");
        self.with_slab(self.c.iter().zip(&o.c).map(|(x, y)| x + y).collect())
    }

    pub fn sub(&self, o: &SeriesVec) -> SeriesVec {
        self.assert_same_shape(o, "sub");
        self.with_slab(self.c.iter().zip(&o.c).map(|(x, y)| x - y).collect())
    }

    pub fn scale(&self, a: f64) -> SeriesVec {
        self.with_slab(self.c.iter().map(|x| a * x).collect())
    }

    /// Per-row scaling: every element of row r (all columns, all orders) is
    /// multiplied by `a[r]` — how per-trajectory conditioning (per-seed
    /// coefficients, per-request parameters) enters a batched series.
    pub fn scale_rows(&self, a: &[f64]) -> SeriesVec {
        assert_eq!(a.len(), self.rows, "scale_rows length vs rows");
        let mut c = Vec::with_capacity(self.c.len());
        for k in 0..self.k1 {
            let ck = self.coeff(k);
            for r in 0..self.rows {
                for j in 0..self.cols {
                    c.push(a[r] * ck[r * self.cols + j]);
                }
            }
        }
        self.with_slab(c)
    }

    /// Add a scalar to every element's constant coefficient.  Writes the
    /// result into one fresh slab directly — no clone-then-mutate of the
    /// whole coefficient storage just to touch row 0.
    pub fn add_scalar(&self, a: f64) -> SeriesVec {
        let m = self.elems();
        let mut c = Vec::with_capacity(self.c.len());
        c.extend(self.c[..m].iter().map(|v| v + a));
        c.extend_from_slice(&self.c[m..]);
        self.with_slab(c)
    }

    /// Truncated Cauchy product, elementwise (Table 1 row 2), via the
    /// blocked kernel; per-element accumulation order matches scalar
    /// `Series::mul` exactly.
    pub fn mul(&self, o: &SeriesVec) -> SeriesVec {
        self.assert_same_shape(o, "mul");
        let mut out = vec![0.0; self.c.len()];
        cauchy::mul_into(self.k1, self.elems(), &self.c, &o.c, &mut out);
        self.with_slab(out)
    }

    /// Division, elementwise (Table 1 row 3).
    pub fn div(&self, o: &SeriesVec) -> SeriesVec {
        self.assert_same_shape(o, "div");
        let mut out = vec![0.0; self.c.len()];
        cauchy::div_into(self.k1, self.elems(), &self.c, &o.c, &mut out);
        self.with_slab(out)
    }

    pub fn exp(&self) -> SeriesVec {
        let mut out = vec![0.0; self.c.len()];
        cauchy::exp_into(self.k1, self.elems(), &self.c, &mut out);
        self.with_slab(out)
    }

    pub fn ln(&self) -> SeriesVec {
        let mut out = vec![0.0; self.c.len()];
        cauchy::ln_into(self.k1, self.elems(), &self.c, &mut out);
        self.with_slab(out)
    }

    pub fn sqrt(&self) -> SeriesVec {
        let mut out = vec![0.0; self.c.len()];
        cauchy::sqrt_into(self.k1, self.elems(), &self.c, &mut out);
        self.with_slab(out)
    }

    pub fn sin_cos(&self) -> (SeriesVec, SeriesVec) {
        let mut s = vec![0.0; self.c.len()];
        let mut c = vec![0.0; self.c.len()];
        cauchy::sin_cos_into(self.k1, self.elems(), &self.c, &mut s, &mut c);
        (self.with_slab(s), self.with_slab(c))
    }

    /// tanh via the ODE s' = (1 - s²) z', elementwise.
    pub fn tanh(&self) -> SeriesVec {
        let mut out = vec![0.0; self.c.len()];
        cauchy::tanh_into(self.k1, self.elems(), &self.c, &mut out);
        self.with_slab(out)
    }

    /// Logistic sigmoid via the ODE s' = s (1 - s) z', elementwise.
    pub fn sigmoid(&self) -> SeriesVec {
        let mut out = vec![0.0; self.c.len()];
        cauchy::sigmoid_into(self.k1, self.elems(), &self.c, &mut out);
        self.with_slab(out)
    }

    pub fn powi(&self, n: usize) -> SeriesVec {
        let mut out = SeriesVec::fill(1.0, self.rows, self.cols, self.order());
        for _ in 0..n {
            out = out.mul(self);
        }
        out
    }

    /// Evaluate every element's polynomial at offset t (Horner).
    pub fn eval(&self, t: f64) -> Vec<f64> {
        let m = self.elems();
        let mut acc = vec![0.0; m];
        for k in (0..self.k1).rev() {
            let ck = self.coeff(k);
            for (a, cv) in acc.iter_mut().zip(ck) {
                *a = *a * t + *cv;
            }
        }
        acc
    }
}

/// A vector field over a batch of trajectories, evaluated on truncated
/// Taylor series: the series-lifted counterpart of
/// [`BatchDynamics`](crate::solvers::batch::BatchDynamics).  `z` is a
/// `[rows, dim()]` series batch, `t` the per-row time series (`[rows, 1]`,
/// broadcast as needed); `ids[r]` is the stable trajectory index of row r,
/// for per-trajectory conditioning under active-set compaction.
pub trait BatchSeriesDynamics {
    /// Per-trajectory state dimension n (must be positive).
    fn dim(&self) -> usize;
    /// Evaluate dz/dt = f(z, t) for every row, on series arguments.
    fn eval(&mut self, ids: &[usize], z: &SeriesVec, t: &SeriesVec) -> SeriesVec;
}

/// A `&mut` reference forwards, so callers can lend instrumented dynamics
/// (eval counters, staging buffers) to a jet sweep and keep ownership.
impl<T: BatchSeriesDynamics + ?Sized> BatchSeriesDynamics for &mut T {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn eval(&mut self, ids: &[usize], z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
        (**self).eval(ids, z, t)
    }
}

/// Adapter: a series-generic closure `(ids, z, t) -> dz` plus its row
/// dimension (mirrors [`BatchFn`](crate::solvers::batch::BatchFn)).
/// `Clone` (for cloneable closures) lets it ride the pooled drivers.
#[derive(Clone)]
pub struct SeriesFn<F> {
    f: F,
    n: usize,
}

impl<F: FnMut(&[usize], &SeriesVec, &SeriesVec) -> SeriesVec> SeriesFn<F> {
    pub fn new(n: usize, f: F) -> SeriesFn<F> {
        assert!(n > 0, "SeriesFn: state dimension must be positive");
        SeriesFn { f, n }
    }
}

impl<F: FnMut(&[usize], &SeriesVec, &SeriesVec) -> SeriesVec> BatchSeriesDynamics for SeriesFn<F> {
    fn dim(&self) -> usize {
        self.n
    }

    fn eval(&mut self, ids: &[usize], z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
        (self.f)(ids, z, t)
    }
}

/// Derivative coefficient matrices `[x_1, ..., x_order]` (each row-major
/// `[rows, n]`) of the solutions of dz/dt = f(z, t) through per-row
/// `(z0[r], t0[r])` — Algorithm 1 lifted to the whole batch.  One call of
/// `f` per jet order covers every trajectory; each row's result is
/// bit-identical to the scalar [`ode_jet`](super::ode_jet) on that row
/// (the elementwise propagation rules share the scalar operation order).
pub fn ode_jet_batch<F: BatchSeriesDynamics + ?Sized>(
    f: &mut F,
    ids: &[usize],
    z0: &[f64],
    t0: &[f64],
    order: usize,
) -> Vec<Vec<f64>> {
    let n = f.dim();
    let rows = t0.len();
    assert!(n > 0, "ode_jet_batch: dim() must be positive");
    assert!(order >= 1, "ode_jet_batch: order must be >= 1");
    assert_eq!(z0.len(), rows * n, "ode_jet_batch: state length vs rows * dim");
    assert_eq!(ids.len(), rows, "ode_jet_batch: ids length vs rows");
    let mut x: Vec<Vec<f64>> = Vec::with_capacity(order);
    // x_1 = f(z0, t0)
    let f0 = f.eval(
        ids,
        &SeriesVec::constant(z0, rows, n, 0),
        &SeriesVec::time(t0, 0),
    );
    x.push(f0.coeff(0).to_vec());
    for k in 1..order {
        // The k-truncated solution path: [z0, x_1/1!, ..., x_k/k!].
        let mut zc: Vec<Vec<f64>> = Vec::with_capacity(k + 1);
        zc.push(z0.to_vec());
        for (i, xi) in x.iter().enumerate() {
            let fct = factorial(i + 1);
            zc.push(xi.iter().map(|v| v / fct).collect());
        }
        let zs = SeriesVec::new(zc, rows, n);
        let ts = SeriesVec::time(t0, k);
        let y = f.eval(ids, &zs, &ts);
        // dz/dt = y  =>  x_{k+1} = k! * y_[k]
        let fct = factorial(k);
        x.push(y.coeff(k).iter().map(|v| v * fct).collect());
    }
    x
}

#[cfg(test)]
mod tests {
    use super::super::{ode_jet, Series};
    use super::*;
    use crate::util::ptest::{gen, Prop};
    use crate::util::rng::Pcg;

    /// Extract one element of a batched series as a scalar Series.
    fn elem(v: &SeriesVec, e: usize) -> Series {
        Series::new((0..=v.order()).map(|k| v.coeff(k)[e]).collect())
    }

    fn random_vec(
        rng: &mut Pcg,
        rows: usize,
        cols: usize,
        ord: usize,
        lo: f64,
        hi: f64,
    ) -> SeriesVec {
        let c = (0..=ord)
            .map(|_| gen::vec_f64(rng, rows * cols, lo, hi))
            .collect();
        SeriesVec::new(c, rows, cols)
    }

    fn assert_bits_eq(a: &Series, v: &SeriesVec, e: usize, ctx: &str) {
        for (k, x) in a.c.iter().enumerate() {
            assert_eq!(
                x.to_bits(),
                v.coeff(k)[e].to_bits(),
                "{ctx}: coeff {k} elem {e}: {x} vs {}",
                v.coeff(k)[e]
            );
        }
    }

    #[test]
    fn elementwise_ops_match_scalar_series_bit_for_bit() {
        // Every element of every SeriesVec op must be the scalar Series op
        // on that element, bit-for-bit — the invariant ode_jet_batch's
        // per-row equivalence is built on.
        Prop::new(60).run("seriesvec-elementwise", |rng: &mut Pcg, _| {
            let rows = 1 + rng.below(4);
            let cols = 1 + rng.below(3);
            let ord = 1 + rng.below(5);
            let a = random_vec(rng, rows, cols, ord, -1.5, 1.5);
            let mut b = random_vec(rng, rows, cols, ord, -1.5, 1.5);
            // keep divisors/sqrt/ln arguments away from 0
            for v in b.coeff_mut(0) {
                *v = v.signum() * (v.abs() + 0.5);
            }
            let bpos = {
                let mut p = b.clone();
                for v in p.coeff_mut(0) {
                    *v = v.abs();
                }
                p
            };
            let m = rows * cols;
            for e in 0..m {
                let ae = elem(&a, e);
                let be = elem(&b, e);
                assert_bits_eq(&ae.add(&be), &a.add(&b), e, "add");
                assert_bits_eq(&ae.sub(&be), &a.sub(&b), e, "sub");
                assert_bits_eq(&ae.mul(&be), &a.mul(&b), e, "mul");
                assert_bits_eq(&ae.div(&be), &a.div(&b), e, "div");
                assert_bits_eq(&ae.scale(0.7), &a.scale(0.7), e, "scale");
                assert_bits_eq(&ae.add_scalar(0.3), &a.add_scalar(0.3), e, "add_scalar");
                assert_bits_eq(&ae.exp(), &a.exp(), e, "exp");
                assert_bits_eq(&ae.tanh(), &a.tanh(), e, "tanh");
                assert_bits_eq(&elem(&bpos, e).ln(), &bpos.ln(), e, "ln");
                assert_bits_eq(&elem(&bpos, e).sqrt(), &bpos.sqrt(), e, "sqrt");
                let (ss, cs) = ae.sin_cos();
                let (sv, cv) = a.sin_cos();
                assert_bits_eq(&ss, &sv, e, "sin");
                assert_bits_eq(&cs, &cv, e, "cos");
                assert_bits_eq(&ae.powi(3), &a.powi(3), e, "powi");
            }
        });
    }

    /// A random series-generic expression in (z, t): evaluated on scalar
    /// Series and on SeriesVec with the identical operation tree, so the two
    /// paths must agree bit-for-bit.
    enum Expr {
        Z,
        T,
        Konst(f64),
        Scale(f64, Box<Expr>),
        Add(Box<Expr>, Box<Expr>),
        Mul(Box<Expr>, Box<Expr>),
        Sin(Box<Expr>),
        Tanh(Box<Expr>),
    }

    impl Expr {
        fn random(rng: &mut Pcg, depth: usize) -> Expr {
            if depth == 0 {
                return match rng.below(3) {
                    0 => Expr::Z,
                    1 => Expr::T,
                    _ => Expr::Konst(rng.range(-1.0, 1.0) as f64),
                };
            }
            match rng.below(6) {
                0 => Expr::Z,
                1 => Expr::T,
                2 => Expr::Scale(
                    rng.range(-1.0, 1.0) as f64,
                    Box::new(Expr::random(rng, depth - 1)),
                ),
                3 => Expr::Add(
                    Box::new(Expr::random(rng, depth - 1)),
                    Box::new(Expr::random(rng, depth - 1)),
                ),
                4 => Expr::Mul(
                    Box::new(Expr::random(rng, depth - 1)),
                    Box::new(Expr::random(rng, depth - 1)),
                ),
                _ => {
                    if rng.below(2) == 0 {
                        Expr::Sin(Box::new(Expr::random(rng, depth - 1)))
                    } else {
                        Expr::Tanh(Box::new(Expr::random(rng, depth - 1)))
                    }
                }
            }
        }

        fn eval_s(&self, z: &Series, t: &Series) -> Series {
            match self {
                Expr::Z => z.clone(),
                Expr::T => t.clone(),
                Expr::Konst(v) => Series::constant(*v, z.order()),
                Expr::Scale(a, e) => e.eval_s(z, t).scale(*a),
                Expr::Add(a, b) => a.eval_s(z, t).add(&b.eval_s(z, t)),
                Expr::Mul(a, b) => a.eval_s(z, t).mul(&b.eval_s(z, t)),
                Expr::Sin(e) => e.eval_s(z, t).sin_cos().0,
                Expr::Tanh(e) => e.eval_s(z, t).tanh(),
            }
        }

        fn eval_v(&self, z: &SeriesVec, t: &SeriesVec) -> SeriesVec {
            match self {
                Expr::Z => z.clone(),
                Expr::T => t.clone(),
                Expr::Konst(v) => SeriesVec::fill(*v, z.rows(), z.cols(), z.order()),
                Expr::Scale(a, e) => e.eval_v(z, t).scale(*a),
                Expr::Add(a, b) => a.eval_v(z, t).add(&b.eval_v(z, t)),
                Expr::Mul(a, b) => a.eval_v(z, t).mul(&b.eval_v(z, t)),
                Expr::Sin(e) => e.eval_v(z, t).sin_cos().0,
                Expr::Tanh(e) => e.eval_v(z, t).tanh(),
            }
        }
    }

    #[test]
    fn ode_jet_batch_rows_match_scalar_jets_property() {
        // The acceptance property: at any B, every row of ode_jet_batch is
        // bit-for-bit the scalar ode_jet of that row, over random dynamics
        // (expression trees), orders, z0, and t0.
        Prop::new(50).run("jet-batch-equiv", |rng: &mut Pcg, _| {
            let order = 1 + rng.below(5);
            let b = 1 + rng.below(5);
            let expr = Expr::random(rng, 3);
            let z0 = gen::vec_f64(rng, b, -1.2, 1.2);
            let t0 = gen::vec_f64(rng, b, -1.0, 1.0);
            let ids: Vec<usize> = (0..b).collect();
            let mut fv = SeriesFn::new(1, |_ids: &[usize], z: &SeriesVec, t: &SeriesVec| {
                expr.eval_v(z, t)
            });
            let jets = ode_jet_batch(&mut fv, &ids, &z0, &t0, order);
            assert_eq!(jets.len(), order);
            for r in 0..b {
                let scalar = ode_jet(|z, t| expr.eval_s(z, t), z0[r], t0[r], order);
                for (k, sk) in scalar.iter().enumerate() {
                    assert_eq!(
                        sk.to_bits(),
                        jets[k][r].to_bits(),
                        "row {r} order {k}: {sk} vs {}",
                        jets[k][r]
                    );
                }
            }
        });
    }

    #[test]
    fn ode_jet_batch_multi_dim_matches_scalar_per_element() {
        // Elementwise vector field dz_i/dt = z_i · sin(t) on [B, n]: each
        // element is an independent scalar ODE, so every (row, col) must
        // reproduce the scalar jet bit-for-bit (time enters via broadcast).
        let (b, n, order) = (3usize, 2usize, 5usize);
        let z0 = [0.4f64, -1.1, 0.9, 0.2, -0.6, 1.3];
        let t0 = [0.0f64, 0.7, -0.3];
        let ids: Vec<usize> = (0..b).collect();
        let mut f = SeriesFn::new(n, |_ids: &[usize], z: &SeriesVec, t: &SeriesVec| {
            z.mul(&t.sin_cos().0.broadcast_cols(z.cols()))
        });
        let jets = ode_jet_batch(&mut f, &ids, &z0, &t0, order);
        for r in 0..b {
            for i in 0..n {
                let scalar = ode_jet(
                    |z, t| z.mul(&t.sin_cos().0),
                    z0[r * n + i],
                    t0[r],
                    order,
                );
                for (k, sk) in scalar.iter().enumerate() {
                    assert_eq!(
                        sk.to_bits(),
                        jets[k][r * n + i].to_bits(),
                        "row {r} col {i} order {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn ode_jet_batch_per_row_conditioning_keys_on_ids() {
        // dz/dt = a_id · z: d^k z = a^k z0.  Conditioning must follow the
        // engine-provided ids, not the row position.
        let a = [0.5f64, 2.0, -1.5];
        let z0 = [1.0f64, 1.0];
        let t0 = [0.0f64, 0.0];
        // Rows carry ids 2 and 0 (as after compaction reordered the set).
        let ids = [2usize, 0];
        let mut f = SeriesFn::new(1, |ids: &[usize], z: &SeriesVec, _t: &SeriesVec| {
            let sel: Vec<f64> = ids.iter().map(|id| a[*id]).collect();
            z.scale_rows(&sel)
        });
        let jets = ode_jet_batch(&mut f, &ids, &z0, &t0, 4);
        for (k, xk) in jets.iter().enumerate() {
            let want0 = a[2].powi(k as i32 + 1);
            let want1 = a[0].powi(k as i32 + 1);
            assert!((xk[0] - want0).abs() < 1e-12, "k={k}: {} vs {want0}", xk[0]);
            assert!((xk[1] - want1).abs() < 1e-12, "k={k}: {} vs {want1}", xk[1]);
        }
    }

    #[test]
    fn polynomial_rows_have_vanishing_high_orders() {
        // dz/dt = 3t² per row: derivative matrices above order 3 vanish —
        // the batched form of the property Fig 2 is built on.
        let t0 = [0.5f64, -0.25];
        let z0 = [0.0f64, 1.0];
        let ids = [0usize, 1];
        let mut f = SeriesFn::new(1, |_ids: &[usize], _z: &SeriesVec, t: &SeriesVec| {
            t.mul(t).scale(3.0)
        });
        let jets = ode_jet_batch(&mut f, &ids, &z0, &t0, 6);
        for (r, tr) in t0.iter().enumerate() {
            assert!((jets[0][r] - 3.0 * tr * tr).abs() < 1e-12);
            assert!((jets[1][r] - 6.0 * tr).abs() < 1e-12);
            assert!((jets[2][r] - 6.0).abs() < 1e-12);
            for xk in &jets[3..] {
                assert!(xk[r].abs() < 1e-12, "row {r}: {:?}", xk);
            }
        }
    }

    #[test]
    fn col_from_cols_roundtrip_property() {
        Prop::new(40).run("col-roundtrip", |rng: &mut Pcg, _| {
            let rows = 1 + rng.below(4);
            let cols = 1 + rng.below(4);
            let ord = 1 + rng.below(4);
            let v = random_vec(rng, rows, cols, ord, -2.0, 2.0);
            let split: Vec<SeriesVec> = (0..cols).map(|j| v.col(j)).collect();
            for (j, cj) in split.iter().enumerate() {
                assert_eq!(cj.rows(), rows);
                assert_eq!(cj.cols(), 1);
                for k in 0..=ord {
                    for r in 0..rows {
                        assert_eq!(
                            cj.coeff(k)[r].to_bits(),
                            v.coeff(k)[r * cols + j].to_bits(),
                            "col {j} order {k} row {r}"
                        );
                    }
                }
            }
            let back = SeriesVec::from_cols(&split);
            assert_eq!(back, v);
        });
    }

    #[test]
    fn broadcast_and_eval_helpers() {
        let t = SeriesVec::time(&[0.5, -1.0], 2);
        let wide = t.broadcast_cols(3);
        assert_eq!(wide.rows(), 2);
        assert_eq!(wide.cols(), 3);
        assert_eq!(wide.coeff(0), &[0.5, 0.5, 0.5, -1.0, -1.0, -1.0]);
        // eval at dt: t0 + dt per element
        let vals = wide.eval(0.25);
        for (e, v) in vals.iter().enumerate() {
            let want = if e < 3 { 0.75 } else { -0.75 };
            assert!((v - want).abs() < 1e-15);
        }
        // derivative matrices unnormalize with k!
        let s = SeriesVec::new(
            vec![vec![1.0], vec![1.0], vec![0.5], vec![1.0 / 6.0]],
            1,
            1,
        );
        for k in 0..4 {
            assert!((s.derivative(k)[0] - 1.0).abs() < 1e-12);
        }
    }
}
