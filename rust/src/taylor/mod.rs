//! Truncated Taylor-series arithmetic and ODE-solution jets in pure Rust —
//! the native counterpart of `python/compile/taylor.py` (paper §4 / App. A).
//!
//! Two tiers share one set of propagation rules:
//!
//! * [`Series`] / [`ode_jet`] — one scalar trajectory, the reference
//!   implementation of Algorithm 1;
//! * [`SeriesVec`] / [`ode_jet_batch`] ([mod@vec]) — the same rules applied
//!   elementwise over an SoA `[B, n]` coefficient matrix, so higher-order
//!   trajectory derivatives (and with them the paper's `R_K` regularizer)
//!   ride the batched solver engine: one series evaluation per jet order
//!   for a whole active set, per-row bit-identical to the scalar jet.
//!
//! Used by: the Fig 2 polynomial-order experiments, native `R_K`
//! measurement (`solvers::batch::RegularizedBatchDynamics`), the
//! toy-dynamics experiments that run without XLA, and property tests
//! cross-checking the propagation rules against the Python implementation.
//! Coefficients are *normalized Taylor coefficients* c[i] = x_i / i!.
//!
//! ```
//! use taynode::taylor::{ode_jet, Series};
//!
//! // dz/dt = z through (z0, t0) = (2, 0): every derivative equals z0.
//! let jet = ode_jet(|z: &Series, _t: &Series| z.clone(), 2.0, 0.0, 3);
//! assert_eq!(jet, vec![2.0; 3]);
//! ```

pub mod vec;

pub use vec::{ode_jet_batch, BatchSeriesDynamics, SeriesFn, SeriesVec};

/// A scalar truncated Taylor polynomial sum_i c[i] t^i.
#[derive(Clone, Debug, PartialEq)]
pub struct Series {
    pub c: Vec<f64>,
}

impl Series {
    pub fn new(c: Vec<f64>) -> Series {
        assert!(!c.is_empty());
        Series { c }
    }

    pub fn constant(x: f64, order: usize) -> Series {
        let mut c = vec![0.0; order + 1];
        c[0] = x;
        Series { c }
    }

    /// The independent variable itself: t0 + 1*t.
    pub fn time(t0: f64, order: usize) -> Series {
        let mut c = vec![0.0; order + 1];
        c[0] = t0;
        if order >= 1 {
            c[1] = 1.0;
        }
        Series { c }
    }

    pub fn order(&self) -> usize {
        self.c.len() - 1
    }

    /// Unnormalized derivative coefficient d^k x/dt^k = k! c[k].
    pub fn derivative(&self, k: usize) -> f64 {
        self.c[k] * factorial(k)
    }

    pub fn add(&self, o: &Series) -> Series {
        assert_eq!(self.order(), o.order());
        Series::new(self.c.iter().zip(&o.c).map(|(a, b)| a + b).collect())
    }

    pub fn sub(&self, o: &Series) -> Series {
        assert_eq!(self.order(), o.order());
        Series::new(self.c.iter().zip(&o.c).map(|(a, b)| a - b).collect())
    }

    pub fn scale(&self, a: f64) -> Series {
        Series::new(self.c.iter().map(|x| a * x).collect())
    }

    pub fn add_scalar(&self, a: f64) -> Series {
        let mut c = self.c.clone();
        c[0] += a;
        Series::new(c)
    }

    /// Truncated Cauchy product (Table 1 row 2).
    pub fn mul(&self, o: &Series) -> Series {
        assert_eq!(self.order(), o.order());
        let k1 = self.c.len();
        let mut out = vec![0.0; k1];
        for k in 0..k1 {
            for j in 0..=k {
                out[k] += self.c[j] * o.c[k - j];
            }
        }
        Series::new(out)
    }

    /// Division (Table 1 row 3).
    pub fn div(&self, o: &Series) -> Series {
        assert_eq!(self.order(), o.order());
        let k1 = self.c.len();
        let mut out = vec![0.0; k1];
        for k in 0..k1 {
            let mut acc = self.c[k];
            for j in 0..k {
                acc -= out[j] * o.c[k - j];
            }
            out[k] = acc / o.c[0];
        }
        Series::new(out)
    }

    pub fn exp(&self) -> Series {
        let k1 = self.c.len();
        let mut y = vec![0.0; k1];
        y[0] = self.c[0].exp();
        for k in 1..k1 {
            let mut acc = 0.0;
            for j in 1..=k {
                acc += j as f64 * self.c[j] * y[k - j];
            }
            y[k] = acc / k as f64;
        }
        Series::new(y)
    }

    pub fn ln(&self) -> Series {
        let k1 = self.c.len();
        let mut y = vec![0.0; k1];
        y[0] = self.c[0].ln();
        for k in 1..k1 {
            let mut acc = k as f64 * self.c[k];
            for j in 1..k {
                acc -= (k - j) as f64 * y[k - j] * self.c[j];
            }
            y[k] = acc / (k as f64 * self.c[0]);
        }
        Series::new(y)
    }

    pub fn sqrt(&self) -> Series {
        let k1 = self.c.len();
        let mut y = vec![0.0; k1];
        y[0] = self.c[0].sqrt();
        for k in 1..k1 {
            let mut acc = self.c[k];
            for j in 1..k {
                acc -= y[j] * y[k - j];
            }
            y[k] = acc / (2.0 * y[0]);
        }
        Series::new(y)
    }

    pub fn sin_cos(&self) -> (Series, Series) {
        let k1 = self.c.len();
        let mut s = vec![0.0; k1];
        let mut c = vec![0.0; k1];
        s[0] = self.c[0].sin();
        c[0] = self.c[0].cos();
        for k in 1..k1 {
            let mut sa = 0.0;
            let mut ca = 0.0;
            for j in 1..=k {
                let zj = j as f64 * self.c[j];
                sa += zj * c[k - j];
                ca += zj * s[k - j];
            }
            s[k] = sa / k as f64;
            c[k] = -ca / k as f64;
        }
        (Series::new(s), Series::new(c))
    }

    /// tanh via the ODE s' = (1 - s^2) z'.
    pub fn tanh(&self) -> Series {
        let k1 = self.c.len();
        let mut s = vec![0.0; k1];
        s[0] = self.c[0].tanh();
        for k in 1..k1 {
            let mut acc = 0.0;
            for j in 1..=k {
                let m = k - j;
                // u[m] = delta_{m0} - (s*s)[m], with s[0..=m] already known
                let mut ssm = 0.0;
                for i in 0..=m {
                    ssm += s[i] * s[m - i];
                }
                let u = if m == 0 { 1.0 - ssm } else { -ssm };
                acc += j as f64 * self.c[j] * u;
            }
            s[k] = acc / k as f64;
        }
        Series::new(s)
    }

    /// Logistic sigmoid via the ODE s' = s (1 - s) z'.
    pub fn sigmoid(&self) -> Series {
        let k1 = self.c.len();
        let mut s = vec![0.0; k1];
        s[0] = 1.0 / (1.0 + (-self.c[0]).exp());
        for k in 1..k1 {
            let mut acc = 0.0;
            for j in 1..=k {
                let m = k - j;
                // u[m] = s[m] - (s*s)[m], with s[0..=m] already known
                let mut ssm = 0.0;
                for i in 0..=m {
                    ssm += s[i] * s[m - i];
                }
                acc += j as f64 * self.c[j] * (s[m] - ssm);
            }
            s[k] = acc / k as f64;
        }
        Series::new(s)
    }

    pub fn powi(&self, n: usize) -> Series {
        let mut out = Series::constant(1.0, self.order());
        for _ in 0..n {
            out = out.mul(self);
        }
        out
    }

    /// Evaluate the polynomial at offset t.
    pub fn eval(&self, t: f64) -> f64 {
        let mut acc = 0.0;
        for &ci in self.c.iter().rev() {
            acc = acc * t + ci;
        }
        acc
    }
}

pub fn factorial(k: usize) -> f64 {
    (1..=k).map(|i| i as f64).product()
}

/// Derivative coefficients [x_1, ..., x_order] of the solution of the scalar
/// ODE dz/dt = f(z, t) through (z0, t0) — Algorithm 1, with `f` evaluated on
/// `Series` arguments.  `f` may be stateful (`FnMut`) so instrumented
/// dynamics can count their series evaluations, exactly like the solver
/// drivers count NFE.
pub fn ode_jet<F: FnMut(&Series, &Series) -> Series>(
    mut f: F,
    z0: f64,
    t0: f64,
    order: usize,
) -> Vec<f64> {
    let mut x: Vec<f64> = Vec::with_capacity(order);
    // x_1 = f(z0, t0)
    let f0 = f(&Series::constant(z0, 0), &Series::constant(t0, 0));
    x.push(f0.c[0]);
    for k in 1..order {
        let mut zc = vec![z0];
        for (i, xi) in x.iter().enumerate() {
            zc.push(xi / factorial(i + 1));
        }
        let zs = Series::new(zc);
        let ts = Series::time(t0, k);
        let y = f(&zs, &ts);
        // dz/dt = y  =>  x_{k+1} = k! * y_[k]
        x.push(y.c[k] * factorial(k));
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::Prop;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn mul_matches_polynomial_multiplication() {
        let a = Series::new(vec![1.0, 2.0, 3.0]);
        let b = Series::new(vec![4.0, 5.0, 6.0]);
        let p = a.mul(&b);
        // (1+2t+3t^2)(4+5t+6t^2) = 4 + 13t + 28t^2 + ...
        assert_eq!(p.c, vec![4.0, 13.0, 28.0]);
    }

    #[test]
    fn div_inverts_mul_property() {
        Prop::new(100).run("div-inverts-mul", |rng, _| {
            let k = 1 + rng.below(6);
            let a = Series::new((0..=k).map(|_| rng.range(-2.0, 2.0) as f64).collect());
            let mut bc: Vec<f64> =
                (0..=k).map(|_| rng.range(-2.0, 2.0) as f64).collect();
            bc[0] = bc[0].signum() * (bc[0].abs() + 0.5); // keep away from 0
            let b = Series::new(bc);
            let q = a.mul(&b).div(&b);
            for (x, y) in q.c.iter().zip(&a.c) {
                assert!(close(*x, *y, 1e-9), "{:?} vs {:?}", q.c, a.c);
            }
        });
    }

    #[test]
    fn exp_ln_roundtrip_property() {
        Prop::new(100).run("exp-ln", |rng, _| {
            let k = 1 + rng.below(6);
            let mut c: Vec<f64> =
                (0..=k).map(|_| rng.range(-1.0, 1.0) as f64).collect();
            c[0] = rng.range(0.5, 3.0) as f64;
            let a = Series::new(c);
            let r = a.exp().ln();
            for (x, y) in r.c.iter().zip(&a.c) {
                assert!(close(*x, *y, 1e-8), "{:?} vs {:?}", r.c, a.c);
            }
        });
    }

    #[test]
    fn sqrt_squares_back_property() {
        Prop::new(100).run("sqrt-sq", |rng, _| {
            let k = 1 + rng.below(5);
            let mut c: Vec<f64> =
                (0..=k).map(|_| rng.range(-1.0, 1.0) as f64).collect();
            c[0] = rng.range(0.5, 4.0) as f64;
            let a = Series::new(c);
            let r = a.sqrt();
            let sq = r.mul(&r);
            for (x, y) in sq.c.iter().zip(&a.c) {
                assert!(close(*x, *y, 1e-9));
            }
        });
    }

    #[test]
    fn sin_cos_pythagorean_property() {
        Prop::new(100).run("sin2cos2", |rng, _| {
            let k = 1 + rng.below(6);
            let a = Series::new((0..=k).map(|_| rng.range(-2.0, 2.0) as f64).collect());
            let (s, c) = a.sin_cos();
            let ident = s.mul(&s).add(&c.mul(&c));
            assert!(close(ident.c[0], 1.0, 1e-10));
            for v in &ident.c[1..] {
                assert!(v.abs() < 1e-9, "{:?}", ident.c);
            }
        });
    }

    #[test]
    fn tanh_matches_sinh_cosh_ratio() {
        Prop::new(60).run("tanh-ratio", |rng, _| {
            let k = 1 + rng.below(5);
            let a = Series::new((0..=k).map(|_| rng.range(-1.0, 1.0) as f64).collect());
            let t1 = a.tanh();
            // tanh = (e^{2z} - 1)/(e^{2z} + 1)
            let e2 = a.scale(2.0).exp();
            let t2 = e2.add_scalar(-1.0).div(&e2.add_scalar(1.0));
            for (x, y) in t1.c.iter().zip(&t2.c) {
                assert!(close(*x, *y, 1e-8), "{:?} vs {:?}", t1.c, t2.c);
            }
        });
    }

    #[test]
    fn derivative_coefficients_unnormalize() {
        let s = Series::new(vec![1.0, 1.0, 0.5, 1.0 / 6.0]); // e^t
        for k in 0..4 {
            assert!(close(s.derivative(k), 1.0, 1e-12));
        }
    }

    #[test]
    fn ode_jet_exponential() {
        // dz/dt = z  =>  all derivative coefficients equal z0.
        let x = ode_jet(|z, _t| z.clone(), 2.0, 0.0, 6);
        for v in &x {
            assert!(close(*v, 2.0, 1e-12), "{x:?}");
        }
    }

    #[test]
    fn ode_jet_time_dependent() {
        // dz/dt = sin(t) => z^(k) = d^{k-1} sin(t).
        let t0 = 0.7f64;
        let x = ode_jet(|_z, t| t.sin_cos().0, 1.0, t0, 5);
        let want = [t0.sin(), t0.cos(), -t0.sin(), -t0.cos(), t0.sin()];
        for (a, b) in x.iter().zip(&want) {
            assert!(close(*a, *b, 1e-10), "{x:?}");
        }
    }

    #[test]
    fn ode_jet_logistic_vs_analytic() {
        // dz/dt = z(1-z), z(0)=1/2 => z(t) = 1/(1+e^{-t}); check derivatives
        // by finite differences of the analytic solution.
        let order = 5;
        let x = ode_jet(
            |z, _t| z.mul(&z.scale(-1.0).add_scalar(1.0)),
            0.5,
            0.0,
            order,
        );
        let z = |t: f64| 1.0 / (1.0 + (-t).exp());
        let h = 1e-2;
        // central differences for k = 1, 2
        let d1 = (z(h) - z(-h)) / (2.0 * h);
        let d2 = (z(h) - 2.0 * z(0.0) + z(-h)) / (h * h);
        assert!(close(x[0], d1, 1e-4), "{} vs {}", x[0], d1);
        assert!(close(x[1], d2, 1e-3), "{} vs {}", x[1], d2);
    }

    #[test]
    fn polynomial_trajectory_has_vanishing_high_orders() {
        // dz/dt = 3t^2 (so z is cubic): derivative coefficients above order
        // 3 must vanish — the property Fig 2 is built on.
        let x = ode_jet(
            |_z, t| t.mul(t).scale(3.0),
            0.0,
            0.5,
            6,
        );
        // z' = 3t^2, z'' = 6t, z''' = 6, z'''' = 0 ...
        assert!(close(x[0], 0.75, 1e-12));
        assert!(close(x[1], 3.0, 1e-12));
        assert!(close(x[2], 6.0, 1e-12));
        for v in &x[3..] {
            assert!(v.abs() < 1e-10, "{x:?}");
        }
    }
}
