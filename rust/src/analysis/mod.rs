//! `taylint` — the repo's determinism lint.
//!
//! The crate's core guarantee is that pooled solves, adjoints, and CNF
//! evaluations are bit-identical to their serial counterparts at any
//! `TAYNODE_THREADS`.  That guarantee is easy to break silently: one keyed
//! collection feeding a float reduction, one stray atomic merge, one
//! wall-clock read in a library path.  This module is a dependency-free
//! static-analysis pass (the container is offline, so no `syn`) that walks
//! `rust/src`, `rust/tests`, `benches/`, and `examples/` and enforces the
//! invariant catalog:
//!
//! | id | invariant |
//! |----|-----------|
//! | D1 | no `HashMap`/`HashSet`/`BTreeMap` in the numeric crates (`solvers`, `autodiff`, `taylor`, `nn`, `coordinator`) |
//! | D2 | atomics / `std::sync` only on allowlisted lines of `util/pool.rs` |
//! | D3 | no `std::env` or RNG-seeding reads outside `util/{pool,cli,rng}.rs` |
//! | D4 | no `.unwrap()`/`.expect()` in library code outside `#[cfg(test)]` |
//! | D5 | every public `*_pooled` fn is named by a test asserting bit-equality against its serial counterpart; every `benches/perf_*.rs` asserts equality before timing |
//! | D6 | no `std::time` (`Instant`, `SystemTime`) outside `util/clock.rs` — everything else takes ticks through the `Clock` trait |
//! | A0 | allowlist markers must be well-formed |
//! | A1 | allowlist markers must suppress something |
//!
//! A line can opt out of a rule with a marker of the form
//! `taylint: allow(<rule>) -- <reason>` in a line comment; it covers its
//! own line and the line directly below, the reason is mandatory, and a
//! marker that suppresses nothing is itself a diagnostic (A1).  Run the
//! pass with `make lint` (or `cargo run --release --bin taylint`); CI
//! treats a nonzero exit as a build failure, next to fmt/clippy/doc.

pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::Path;

/// One file presented to the lint: repo-relative forward-slash path plus
/// full text.  Tests construct these in memory; the binary loads them via
/// [`collect_sources`].
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// One diagnostic, keyed and deduplicated by `(path, line, rule)`.
#[derive(Clone, Debug)]
pub struct Diag {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {} {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Run the full rule catalog over a set of sources and return the
/// surviving diagnostics, sorted by `(path, line, rule)`.
pub fn lint_sources(files: &[SourceFile]) -> Vec<Diag> {
    let mut diags: Vec<Diag> = Vec::new();
    let mut facts = rules::Facts::default();
    let mut allows: Vec<(usize, lexer::Allow)> = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        let lexed = lexer::lex(&f.text);
        for (line, msg) in &lexed.errors {
            diags.push(Diag { path: f.path.clone(), line: *line, rule: "A0", msg: msg.clone() });
        }
        let whole_file = f.path.starts_with("rust/tests/");
        let in_test = rules::test_regions(&lexed.toks, whole_file);
        rules::lint_file(&f.path, &lexed.toks, &in_test, &mut diags);
        rules::collect_facts(&f.path, &lexed.toks, &in_test, &mut facts, &mut diags);
        for a in lexed.allows {
            allows.push((fi, a));
        }
    }
    rules::check_pooled_coverage(&facts, &mut diags);

    let key = |d: &Diag| (d.path.clone(), d.line, d.rule);
    diags.sort_by_key(key);
    diags.dedup_by(|a, b| a.path == b.path && a.line == b.line && a.rule == b.rule);

    // Allowlist pass: a marker suppresses matching rules on its own line
    // and the line directly below; every marker must earn its keep.
    let mut used = vec![false; allows.len()];
    diags.retain(|d| {
        let mut suppressed = false;
        for (k, (fi, a)) in allows.iter().enumerate() {
            if files[*fi].path == d.path
                && a.rules.iter().any(|r| r == d.rule)
                && (a.line == d.line || a.line + 1 == d.line)
            {
                used[k] = true;
                suppressed = true;
            }
        }
        !suppressed
    });
    for (k, (fi, a)) in allows.iter().enumerate() {
        if !used[k] {
            diags.push(Diag {
                path: files[*fi].path.clone(),
                line: a.line,
                rule: "A1",
                msg: format!(
                    "unused allowlist marker for {}: nothing suppressed on this \
                     or the next line",
                    a.rules.join(", ")
                ),
            });
        }
    }
    diags.sort_by_key(key);
    diags
}

/// Load every `.rs` file the lint covers, as repo-relative forward-slash
/// paths in deterministic (sorted) order.
pub fn collect_sources(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    for top in ["rust/src", "rust/tests", "benches", "examples"] {
        let dir = root.join(top);
        if dir.is_dir() {
            walk(&dir, top, &mut files)?;
        }
    }
    Ok(files)
}

fn walk(dir: &Path, rel: &str, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = e.file_name().to_string_lossy().into_owned();
        let path = e.path();
        let rel = format!("{rel}/{name}");
        if path.is_dir() {
            walk(&path, &rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(SourceFile { path: rel, text: fs::read_to_string(&path)? });
        }
    }
    Ok(())
}
