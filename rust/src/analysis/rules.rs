//! The determinism-invariant catalog (rules `D1`–`D7`) over the token
//! stream of [`super::lexer`].
//!
//! Every rule has a machine-readable id, a file scope, and a line-level
//! allowlist escape (`// taylint: allow(<id>) -- <reason>`); the catalog
//! itself is data ([`RULES`]) so the binary's `--rules` listing and the
//! README table can't drift from the implementation silently.
//!
//! Scope conventions, applied by path prefix:
//! * *numeric crates* —
//!   `rust/src/{solvers,autodiff,taylor,nn,coordinator,kern}`:
//!   the modules whose float reductions carry the bit-identity guarantee.
//! * *library code* — everything under `rust/src/` except the `repro`
//!   binary (`main.rs`) and `rust/src/bin/`: entry points may read the
//!   environment and panic on bad invocations; the library must not.
//! * `#[cfg(test)]` / `#[test]` items and `rust/tests/` are exempt from
//!   D1–D4 (tests assert with `unwrap` freely and may time things), but
//!   they are exactly where D5 *looks* for the determinism proofs.

use std::collections::BTreeSet;

use super::lexer::{Tok, TokKind};
use super::Diag;

/// One catalog entry; `detail` is the one-line rationale shown by
/// `taylint --rules` and mirrored in the README table.
pub struct Rule {
    pub id: &'static str,
    pub title: &'static str,
    pub detail: &'static str,
}

/// The invariant catalog.  `A0`/`A1` police the allowlist itself.
pub const RULES: &[Rule] = &[
    Rule {
        id: "D1",
        title: "no keyed-collection iteration in numeric crates",
        detail: "HashMap/HashSet/BTreeMap in solvers, autodiff, taylor, nn, coordinator: \
                 keyed iteration order must never feed a float reduction",
    },
    Rule {
        id: "D2",
        title: "sync primitives only in the sanctioned pool queue",
        detail: "atomics and std::sync appear only on allowlisted lines of util/pool.rs — \
                 every other concurrent construct bypasses the determinism contract",
    },
    Rule {
        id: "D3",
        title: "nondeterminism enters only through sanctioned doors",
        detail: "std::env and RNG seeding live in util/{pool,cli,rng}.rs; \
                 library code never reads the environment or seeds from the world",
    },
    Rule {
        id: "D4",
        title: "panic-free library hot paths",
        detail: "no .unwrap()/.expect() in library code outside #[cfg(test)]; \
                 justified invariants carry an allow marker instead",
    },
    Rule {
        id: "D5",
        title: "pooled entry points ship with their determinism proof",
        detail: "every public *_pooled fn is named by a test that asserts bit-equality \
                 against its serial counterpart, and every benches/perf_*.rs asserts \
                 equality before timing",
    },
    Rule {
        id: "D6",
        title: "wall clocks only behind util/clock.rs",
        detail: "std::time (Instant, SystemTime) appears only in util/clock.rs; everything \
                 else takes ticks through the Clock trait, so traces and benches cannot \
                 leak wall-clock nondeterminism",
    },
    Rule {
        id: "D7",
        title: "no order-sensitive reductions in numeric crates",
        detail: "`.sum()`/`.fold()` downstream of rev/rchunks/chunks/keys/values adapters \
                 in solvers, autodiff, taylor, nn, coordinator, kern: a float reduction \
                 must not bake a position- or key-dependent traversal order into its \
                 result",
    },
    Rule {
        id: "A0",
        title: "well-formed allowlist markers",
        detail: "a comment starting `taylint:` must parse as `allow(<rule>) -- <reason>`; \
                 a typo must not silently suppress anything",
    },
    Rule {
        id: "A1",
        title: "no stale allowlist markers",
        detail: "an allow that suppresses nothing on its own or the next line is rot \
                 and must be removed",
    },
];

const NUMERIC_CRATES: &[&str] = &[
    "rust/src/solvers/",
    "rust/src/autodiff/",
    "rust/src/taylor/",
    "rust/src/nn/",
    "rust/src/coordinator/",
    "rust/src/kern/",
    // Observability aggregates (cost ledgers, rollups, SLO windows) feed
    // reports that must be byte-identical across thread counts, so keyed
    // iteration order is banned there too.
    "rust/src/obs/",
];

/// `util/{pool,cli,rng}.rs` — the sanctioned nondeterminism doors (D3).
const D3_DOORS: &[&str] =
    &["rust/src/util/pool.rs", "rust/src/util/cli.rs", "rust/src/util/rng.rs"];

/// `util/clock.rs` — the one sanctioned door to `std::time` (D6).
const D6_DOOR: &str = "rust/src/util/clock.rs";

/// Sync-primitive identifiers beyond the `Atomic*` family (D2).
const SYNC_IDENTS: &[&str] = &[
    "Mutex",
    "RwLock",
    "Condvar",
    "Barrier",
    "OnceLock",
    "mpsc",
    "fetch_add",
    "fetch_sub",
    "fetch_or",
    "fetch_and",
    "fetch_xor",
    "compare_exchange",
    "compare_exchange_weak",
];

/// Seeding-from-the-world identifiers (D3); the repo's own `Pcg` takes
/// explicit seeds, so none of these should ever appear.
const RNG_SEED_IDENTS: &[&str] = &["from_entropy", "thread_rng", "getrandom", "RandomState"];

/// Environment readers reached through a bare `env::` path (D3).
const ENV_READS: &[&str] = &["var", "vars", "var_os", "args", "args_os", "temp_dir"];

/// Iterator adapters whose traversal order is position- or key-dependent
/// (D7): a float reduction downstream of one bakes that order into the
/// result, so pooled re-sharding (or a refactor of the chunking) moves
/// bits.
const D7_ORDERED_ADAPTERS: &[&str] = &[
    "rev",
    "rchunks",
    "chunks",
    "chunks_exact",
    "keys",
    "values",
    "into_keys",
    "into_values",
];

/// How far a D7 backward scan walks before giving up (it also stops at any
/// `;`/`{`/`}` — statement or block bounds, including closure bodies).
const D7_SCAN_LIMIT: usize = 64;

fn is_numeric_crate(path: &str) -> bool {
    NUMERIC_CRATES.iter().any(|p| path.starts_with(p))
}

fn is_library(path: &str) -> bool {
    path.starts_with("rust/src/")
        && !path.starts_with("rust/src/bin/")
        && path != "rust/src/main.rs"
}

fn is_punct(t: &Tok, p: &str) -> bool {
    t.kind == TokKind::Punct && t.text == p
}

/// Does the token text sequence `pat` start at `i`?
fn tseq(toks: &[Tok], i: usize, pat: &[&str]) -> bool {
    i + pat.len() <= toks.len() && pat.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

/// Mark every token covered by a `#[test]` / `#[cfg(test)]` item (the
/// attribute through the item's closing brace or semicolon).  Files under
/// `rust/tests/` are test code wholesale (`whole_file`).
pub fn test_regions(toks: &[Tok], whole_file: bool) -> Vec<bool> {
    let mut mark = vec![whole_file; toks.len()];
    if whole_file {
        return mark;
    }
    let mut i = 0usize;
    while i < toks.len() {
        if is_punct(&toks[i], "#") && i + 1 < toks.len() && is_punct(&toks[i + 1], "[") {
            // attribute extent + the identifiers inside it
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut idents: Vec<&str> = Vec::new();
            while j < toks.len() {
                if is_punct(&toks[j], "[") {
                    depth += 1;
                } else if is_punct(&toks[j], "]") {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        break;
                    }
                } else if depth >= 1 && toks[j].kind == TokKind::Ident {
                    idents.push(&toks[j].text);
                }
                j += 1;
            }
            let is_test = idents == ["test"]
                || (idents.first() == Some(&"cfg")
                    && idents.contains(&"test")
                    && !idents.contains(&"not"));
            if is_test {
                let end = item_end(toks, j + 1);
                for m in mark.iter_mut().take(end + 1).skip(i) {
                    *m = true;
                }
                i = end + 1;
                continue;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    mark
}

/// Index of the token ending the item starting at `from`: the matching
/// close of its first brace block, or a top-level `;`, whichever first.
fn item_end(toks: &[Tok], from: usize) -> usize {
    let mut depth = 0usize;
    let mut k = from;
    while k < toks.len() {
        if is_punct(&toks[k], "{") {
            depth += 1;
        } else if is_punct(&toks[k], "}") {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return k;
            }
        } else if is_punct(&toks[k], ";") && depth == 0 {
            return k;
        }
        k += 1;
    }
    toks.len().saturating_sub(1)
}

/// Apply the line-level rules (D1–D4, D6, D7) to one file's tokens.
pub fn lint_file(path: &str, toks: &[Tok], in_test: &[bool], diags: &mut Vec<Diag>) {
    let mut push = |line: u32, rule: &'static str, msg: String, out: &mut Vec<Diag>| {
        out.push(Diag { path: path.to_string(), line, rule, msg });
    };
    for (i, t) in toks.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        // D1 — keyed collections in the numeric crates
        if is_numeric_crate(path)
            && t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "HashMap" | "HashSet" | "BTreeMap")
        {
            push(
                t.line,
                "D1",
                format!(
                    "keyed collection `{}` in a numeric crate: iteration order \
                     can feed a float reduction",
                    t.text
                ),
                diags,
            );
        }
        // D7 — order-sensitive reductions in the numeric crates: a
        // `.sum()`/`.fold()` whose same-expression upstream (scanning back
        // to the statement/block bound) contains an order-dependent adapter
        // call.  Token-level like every rule here: a chain broken by a
        // block closure scans clean — the catalog trades recall for zero
        // false positives, same as D5's bench check.
        if is_numeric_crate(path)
            && t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "sum" | "fold")
            && i >= 1
            && is_punct(&toks[i - 1], ".")
            && i + 1 < toks.len()
            && (is_punct(&toks[i + 1], "(") || toks[i + 1].text == "::")
        {
            let mut j = i - 1;
            let mut steps = 0usize;
            while j > 0 && steps < D7_SCAN_LIMIT {
                j -= 1;
                steps += 1;
                let tj = &toks[j];
                if is_punct(tj, ";") || is_punct(tj, "{") || is_punct(tj, "}") {
                    break;
                }
                if tj.kind == TokKind::Ident
                    && D7_ORDERED_ADAPTERS.contains(&tj.text.as_str())
                    && j >= 1
                    && is_punct(&toks[j - 1], ".")
                    && j + 1 < toks.len()
                    && is_punct(&toks[j + 1], "(")
                {
                    push(
                        t.line,
                        "D7",
                        format!(
                            "`.{}()` downstream of `.{}()` in a numeric crate: the \
                             reduction bakes a position/key-dependent order into a \
                             float result",
                            t.text, tj.text
                        ),
                        diags,
                    );
                    break;
                }
            }
        }
        // D2 — sync primitives anywhere (the pool's own queue is allowlisted)
        if t.kind == TokKind::Ident
            && (t.text.starts_with("Atomic") || SYNC_IDENTS.contains(&t.text.as_str()))
        {
            push(
                t.line,
                "D2",
                format!("sync primitive `{}` outside the sanctioned pool queue", t.text),
                diags,
            );
        }
        if t.text == "std" && tseq(toks, i, &["std", "::", "sync"]) {
            push(
                t.line,
                "D2",
                "`std::sync` outside the sanctioned pool queue".to_string(),
                diags,
            );
        }
        // D3 — environment, world-seeded RNG
        if is_library(path) && !D3_DOORS.contains(&path) {
            let hit = if tseq(toks, i, &["std", "::", "env"]) {
                Some("std::env")
            } else if t.kind == TokKind::Ident && RNG_SEED_IDENTS.contains(&t.text.as_str()) {
                Some(t.text.as_str())
            } else if t.kind == TokKind::Ident
                && t.text == "env"
                && i + 2 < toks.len()
                && toks[i + 1].text == "::"
                && ENV_READS.contains(&toks[i + 2].text.as_str())
            {
                Some("env::*")
            } else {
                None
            };
            if let Some(h) = hit {
                push(
                    t.line,
                    "D3",
                    format!("nondeterminism door `{h}` outside util/{{pool,cli,rng}}.rs"),
                    diags,
                );
            }
        }
        // D6 — wall clocks outside the sanctioned clock module
        if is_library(path) && path != D6_DOOR {
            let hit = if tseq(toks, i, &["std", "::", "time"]) {
                Some("std::time")
            } else if tseq(toks, i, &["Instant", "::", "now"]) {
                Some("Instant::now")
            } else if t.kind == TokKind::Ident && t.text == "SystemTime" {
                Some("SystemTime")
            } else {
                None
            };
            if let Some(h) = hit {
                push(
                    t.line,
                    "D6",
                    format!("wall-clock access `{h}` outside util/clock.rs"),
                    diags,
                );
            }
        }
        // D4 — panicking extractors in library code
        if is_library(path)
            && is_punct(t, ".")
            && i + 2 < toks.len()
            && toks[i + 1].kind == TokKind::Ident
            && matches!(toks[i + 1].text.as_str(), "unwrap" | "expect")
            && is_punct(&toks[i + 2], "(")
        {
            push(
                toks[i + 1].line,
                "D4",
                format!(
                    "`.{}()` in library code outside #[cfg(test)]",
                    toks[i + 1].text
                ),
                diags,
            );
        }
    }
}

/// One test function's searchable surface for the D5 cross-reference.
pub struct TestFn {
    pub name: String,
    pub idents: BTreeSet<String>,
    /// Body contains `Pool::new(1)` — the serial reference when a pooled
    /// entry point has no standalone serial twin.
    pub pool_one: bool,
}

/// Cross-file facts gathered in one pass, consumed by
/// [`check_pooled_coverage`].
#[derive(Default)]
pub struct Facts {
    /// `(path, line, name)` of every public `*_pooled` fn in library code.
    pub pooled: Vec<(String, u32, String)>,
    pub tests: Vec<TestFn>,
}

/// Collect D5 facts from one file and emit the per-bench half of D5
/// (equality asserted before the first `time_fn` call) directly.
pub fn collect_facts(
    path: &str,
    toks: &[Tok],
    in_test: &[bool],
    facts: &mut Facts,
    diags: &mut Vec<Diag>,
) {
    // public pooled entry points (library code only, outside tests)
    if path.starts_with("rust/src/") {
        for i in 0..toks.len().saturating_sub(2) {
            if !in_test[i]
                && toks[i].text == "pub"
                && toks[i + 1].text == "fn"
                && toks[i + 2].kind == TokKind::Ident
                && toks[i + 2].text.ends_with("_pooled")
            {
                facts.pooled.push((
                    path.to_string(),
                    toks[i + 2].line,
                    toks[i + 2].text.clone(),
                ));
            }
        }
    }
    // test fns: name + ident set + Pool::new(1) marker
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].text == "fn" && in_test[i] && toks[i + 1].kind == TokKind::Ident {
            let end = item_end(toks, i + 2);
            let body = &toks[i..=end.min(toks.len() - 1)];
            let idents: BTreeSet<String> = body
                .iter()
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text.clone())
                .collect();
            let pool_one =
                (0..body.len()).any(|k| tseq(body, k, &["Pool", "::", "new", "(", "1", ")"]));
            facts.tests.push(TestFn { name: toks[i + 1].text.clone(), idents, pool_one });
            i = end + 1;
            continue;
        }
        i += 1;
    }
    // perf benches must assert before they time
    let is_perf_bench = path.starts_with("benches/perf_") && path.ends_with(".rs");
    if is_perf_bench {
        let mut assert_seen = false;
        for (k, t) in toks.iter().enumerate() {
            if t.kind == TokKind::Ident
                && (t.text.starts_with("assert") || t.text.starts_with("debug_assert"))
            {
                assert_seen = true;
            }
            if t.kind == TokKind::Ident
                && t.text == "time_fn"
                && k + 1 < toks.len()
                && is_punct(&toks[k + 1], "(")
            {
                if !assert_seen {
                    diags.push(Diag {
                        path: path.to_string(),
                        line: t.line,
                        rule: "D5",
                        msg: "perf bench times before asserting equality with the \
                              reference path"
                            .to_string(),
                    });
                }
                break;
            }
        }
    }
}

/// The cross-reference half of D5: every public `*_pooled` fn must be
/// named by a test that also asserts and carries serial evidence — the
/// serial counterpart's exact identifier, or a `Pool::new(1)` reference.
pub fn check_pooled_coverage(facts: &Facts, diags: &mut Vec<Diag>) {
    for (path, line, name) in &facts.pooled {
        let serial = name.trim_end_matches("_pooled");
        let proven = facts.tests.iter().any(|t| {
            let mentions = t.name.contains(name.as_str()) || t.idents.contains(name.as_str());
            let serial_evidence = t.idents.contains(serial) || t.pool_one;
            let asserts = t
                .idents
                .iter()
                .any(|x| x.starts_with("assert") || x.starts_with("debug_assert"));
            mentions && serial_evidence && asserts
        });
        if !proven {
            diags.push(Diag {
                path: path.clone(),
                line: *line,
                rule: "D5",
                msg: format!(
                    "public pooled entry point `{name}` has no test asserting \
                     bit-equality against `{serial}` (or a Pool::new(1) reference)"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{lint_sources, SourceFile};
    use crate::util::ptest::Prop;

    fn run(files: &[(&str, &str)]) -> Vec<super::Diag> {
        let files: Vec<SourceFile> = files
            .iter()
            .map(|(p, s)| SourceFile { path: p.to_string(), text: s.to_string() })
            .collect();
        lint_sources(&files)
    }

    fn rules_of(diags: &[super::Diag]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    // -- seeded-violation fixtures: each rule must trip ------------------

    #[test]
    fn d1_trips_on_keyed_collections_in_numeric_crates() {
        let d = run(&[(
            "rust/src/solvers/bad.rs",
            "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, f32> = HashMap::new(); }\n",
        )]);
        assert!(rules_of(&d).contains(&"D1"), "{d:?}");
        // the same text outside a numeric crate is D1-clean
        let d = run(&[("rust/src/util/ok.rs", "use std::collections::HashMap;\n")]);
        assert!(!rules_of(&d).contains(&"D1"), "{d:?}");
        // obs/ is a numeric crate too: its aggregates feed byte-identical
        // reports, so keyed iteration is banned the same way
        let d = run(&[(
            "rust/src/obs/bad.rs",
            "use std::collections::BTreeMap;\nfn f() { let m: BTreeMap<u32, f32> = BTreeMap::new(); }\n",
        )]);
        assert!(rules_of(&d).contains(&"D1"), "{d:?}");
    }

    #[test]
    fn d2_trips_on_sync_primitives() {
        let d = run(&[(
            "rust/src/tensor/bad.rs",
            "use std::sync::atomic::AtomicUsize;\nstatic N: AtomicUsize = AtomicUsize::new(0);\n",
        )]);
        assert!(rules_of(&d).contains(&"D2"), "{d:?}");
        // benches are covered too
        let d = run(&[("benches/fig0_bad.rs", "use std::sync::Mutex;\nfn main() {}\n")]);
        assert!(rules_of(&d).contains(&"D2"), "{d:?}");
    }

    #[test]
    fn d3_trips_on_env_and_seeding() {
        for src in [
            "fn f() -> Option<String> { std::env::var(\"HOME\").ok() }\n",
            "fn f() { let rng = thread_rng(); }\n",
        ] {
            let d = run(&[("rust/src/nn/bad.rs", src)]);
            assert!(rules_of(&d).contains(&"D3"), "{src}: {d:?}");
        }
        // the sanctioned doors are exempt by scope
        let d = run(&[(
            "rust/src/util/cli.rs",
            "pub fn argv() -> Vec<String> { std::env::args().collect() }\n",
        )]);
        assert!(!rules_of(&d).contains(&"D3"), "{d:?}");
    }

    #[test]
    fn d6_trips_on_wall_clocks_outside_the_clock_door() {
        for src in [
            "fn f() -> u128 { std::time::Instant::now().elapsed().as_nanos() }\n",
            "use std::time::Instant;\nfn f() -> Instant { Instant::now() }\n",
            "fn f() { let _ = SystemTime::now(); }\n",
        ] {
            let d = run(&[("rust/src/nn/bad.rs", src)]);
            let r = rules_of(&d);
            assert!(r.contains(&"D6"), "{src}: {d:?}");
            assert!(!r.contains(&"D3"), "time is D6's beat, not D3's: {src}: {d:?}");
        }
        // the clock module is the door
        let d = run(&[(
            "rust/src/util/clock.rs",
            "use std::time::Instant;\npub fn now() -> Instant { Instant::now() }\n",
        )]);
        assert!(!rules_of(&d).contains(&"D6"), "{d:?}");
        // the allow escape hatch works for D6 like every other rule
        let d = run(&[(
            "rust/src/nn/allowed.rs",
            "// taylint: allow(D6) -- fixture: justified wall-clock read\nuse std::time::Instant;\n",
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn d7_trips_on_order_sensitive_reductions() {
        // rev().map().sum() in a numeric crate bakes reversal into a float
        let d = run(&[(
            "rust/src/taylor/bad.rs",
            "pub fn f(x: &[f64]) -> f64 { x.iter().rev().map(|v| v * 2.0).sum() }\n",
        )]);
        assert!(rules_of(&d).contains(&"D7"), "{d:?}");
        // kern is a numeric crate; chunked fold trips there too
        let d = run(&[(
            "rust/src/kern/bad.rs",
            "pub fn g(x: &[f64]) -> f64 { x.chunks(4).fold(0.0, |a, c| a + c[0]) }\n",
        )]);
        assert!(rules_of(&d).contains(&"D7"), "{d:?}");
        // the turbofish form is the same reduction
        let d = run(&[(
            "rust/src/solvers/bad.rs",
            "pub fn h(x: &[f64]) -> f64 { x.iter().rev().sum::<f64>() }\n",
        )]);
        assert!(rules_of(&d).contains(&"D7"), "{d:?}");
    }

    #[test]
    fn d7_negative_controls_stay_clean() {
        // a zip/map dot product reduces in slice order — order-honest
        let d = run(&[(
            "rust/src/solvers/ok.rs",
            "pub fn dot(x: &[f64], y: &[f64]) -> f64 { x.iter().zip(y).map(|(a, b)| a * b).sum() }\n",
        )]);
        assert!(!rules_of(&d).contains(&"D7"), "{d:?}");
        // the same rev().sum() outside the numeric crates is out of scope
        let d = run(&[(
            "rust/src/util/ok.rs",
            "pub fn f(x: &[f64]) -> f64 { x.iter().rev().sum() }\n",
        )]);
        assert!(!rules_of(&d).contains(&"D7"), "{d:?}");
        // an ordered adapter with no reduction downstream is fine
        let d = run(&[(
            "rust/src/kern/ok.rs",
            "pub fn f(x: &[f64]) { for c in x.chunks(4) { let _ = c.len(); } }\n",
        )]);
        assert!(!rules_of(&d).contains(&"D7"), "{d:?}");
        // a prior rev in a *different statement* does not taint a later sum
        let d = run(&[(
            "rust/src/taylor/ok.rs",
            "pub fn f(x: &[f64]) -> f64 { let n = x.iter().rev().count(); let s: f64 = x.iter().sum(); s + n as f64 }\n",
        )]);
        assert!(!rules_of(&d).contains(&"D7"), "{d:?}");
        // the allow escape hatch works for D7 like every other rule
        let d = run(&[(
            "rust/src/taylor/allowed.rs",
            "pub fn f(x: &[f64]) -> f64 { x.iter().rev().sum() } // taylint: allow(D7) -- fixture: reversal is the spec\n",
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn d4_trips_on_unwrap_in_library_code() {
        let d = run(&[(
            "rust/src/util/bad.rs",
            "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\npub fn g(x: Option<u32>) -> u32 { x.expect(\"set\") }\n",
        )]);
        assert_eq!(rules_of(&d), vec!["D4", "D4"], "{d:?}");
        // binaries may panic on bad invocations
        let d = run(&[(
            "rust/src/bin/tool.rs",
            "fn main() { std::env::args().next().unwrap(); }\n",
        )]);
        assert!(d.is_empty(), "{d:?}");
        // unwrap_or and friends are fine
        let d = run(&[(
            "rust/src/util/ok.rs",
            "pub fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n",
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn d5_trips_on_unproven_pooled_fn_and_accepts_a_proof() {
        let lib = "pub fn frobnicate_pooled(x: u32) -> u32 { frobnicate(x) }\npub fn frobnicate(x: u32) -> u32 { x }\n";
        let d = run(&[("rust/src/solvers/p.rs", lib)]);
        assert!(rules_of(&d).contains(&"D5"), "{d:?}");
        // a test naming pooled + serial + asserting is the proof
        let test = "#[cfg(test)]\nmod tests {\n  #[test]\n  fn frob_pooled_matches_serial() {\n    assert_eq!(super::frobnicate_pooled(3), super::frobnicate(3));\n  }\n}\n";
        let d = run(&[("rust/src/solvers/p.rs", &format!("{lib}{test}"))]);
        assert!(!rules_of(&d).contains(&"D5"), "{d:?}");
    }

    #[test]
    fn d5_trips_on_bench_that_times_before_asserting() {
        let bad = "fn main() { time_fn(1, 5, || {}); assert_eq!(1, 1); }\n";
        let d = run(&[("benches/perf_bad.rs", bad)]);
        assert!(rules_of(&d).contains(&"D5"), "{d:?}");
        let good = "fn main() { assert_eq!(two(), 2); time_fn(1, 5, || {}); }\nfn two() -> u32 { 2 }\n";
        let d = run(&[("benches/perf_good.rs", good)]);
        assert!(!rules_of(&d).contains(&"D5"), "{d:?}");
        // only perf_* benches are held to the equality-first contract
        let d = run(&[("benches/fig9_x.rs", bad)]);
        assert!(!rules_of(&d).contains(&"D5"), "{d:?}");
    }

    // -- no false positives from strings, comments, tests ----------------

    #[test]
    fn strings_comments_and_cfg_test_do_not_trip() {
        let src = r#"
// HashMap in a comment is fine
/* std::sync::Mutex in a block comment too */
pub fn f() -> &'static str {
    "HashMap std::env thread_rng .unwrap()"
}
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    #[test]
    fn t() {
        let m: HashMap<u32, u32> = HashMap::new();
        assert_eq!(m.len(), 0);
        let _ = std::env::var("X");
        Some(1).unwrap();
    }
}
"#;
        let d = run(&[("rust/src/solvers/clean.rs", src)]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn integration_test_files_are_exempt_from_line_rules() {
        let d = run(&[(
            "rust/tests/integration.rs",
            "fn t() { Some(1).unwrap(); let _ = std::env::var(\"X\"); }\n",
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    // -- the allowlist ---------------------------------------------------

    #[test]
    fn allow_suppresses_on_own_and_next_line() {
        let src = "// taylint: allow(D1) -- fixture: order never feeds a reduction\nuse std::collections::HashMap;\n";
        let d = run(&[("rust/src/solvers/allowed.rs", src)]);
        assert!(d.is_empty(), "{d:?}");
        let trailing = "use std::collections::HashMap; // taylint: allow(D1) -- fixture\n";
        let d = run(&[("rust/src/solvers/allowed.rs", trailing)]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "// taylint: allow(D2) -- fixture\nuse std::collections::HashMap;\n";
        let d = run(&[("rust/src/solvers/allowed.rs", src)]);
        let r = rules_of(&d);
        assert!(r.contains(&"D1"), "{d:?}");
        assert!(r.contains(&"A1"), "wrong-rule allow must surface as unused: {d:?}");
    }

    #[test]
    fn unused_allow_is_flagged() {
        let d = run(&[(
            "rust/src/util/ok.rs",
            "// taylint: allow(D4) -- fixture: nothing here needs it\npub fn f() {}\n",
        )]);
        assert_eq!(rules_of(&d), vec!["A1"], "{d:?}");
    }

    #[test]
    fn malformed_directive_is_flagged() {
        let d = run(&[(
            "rust/src/util/ok.rs",
            "// taylint: allow(D4)\npub fn f() {}\n",
        )]);
        assert_eq!(rules_of(&d), vec!["A0"], "{d:?}");
    }

    #[test]
    fn rule_catalog_ids_are_unique() {
        let mut ids: Vec<&str> = super::RULES.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    // -- property: detection depends only on the embedding site ----------

    #[test]
    fn banned_ident_trips_iff_it_is_code() {
        Prop::new(64).run("site determines detection", |rng, _| {
            let fillers = ["fn okay() {}", "const Z: u32 = 3;", "// quiet line", ""];
            let pre = fillers[rng.below(fillers.len())];
            let post = fillers[rng.below(fillers.len())];
            let (site, trips) = match rng.below(6) {
                0 => ("use std::collections::HashMap;", true),
                1 => ("// a HashMap mention in a comment", false),
                2 => ("/* HashMap\n   across lines */", false),
                3 => ("const S: &str = \"HashMap\";", false),
                4 => ("const R: &str = r#\"HashMap\"#;", false),
                _ => ("#[cfg(test)]\nmod t { use std::collections::HashMap; }", false),
            };
            let src = format!("{pre}\n{site}\n{post}\n");
            let d = run(&[("rust/src/taylor/p.rs", &src)]);
            let hit = d.iter().any(|x| x.rule == "D1");
            assert_eq!(hit, trips, "site {site:?} in:\n{src}\n{d:?}");
        });
    }
}
