//! Comment- and string-aware Rust tokenizer for `taylint`.
//!
//! Hand-rolled because the container is offline (`syn` is unavailable) and
//! the lint needs a token stream, never a full AST: identifiers,
//! punctuation, and literals with 1-based line numbers, with comment and
//! string *bodies* skipped entirely — so a `HashMap` inside a doc comment
//! or a format string can never trip a rule.  Handled verbatim: nested
//! `/* /* */ */` block comments, `"…"` strings with escapes, raw strings
//! `r#"…"#` at any hash depth, byte strings, char literals vs lifetimes,
//! and numeric literals (so `0..4` lexes as two numbers around `..`, not a
//! float).
//!
//! Allowlist markers are collected from line comments during the same
//! scan: a comment whose text begins with `taylint: allow(<rules>) --
//! <reason>` yields an [`Allow`]; a comment that begins with `taylint:`
//! but does not parse (missing rule list or missing reason) is reported as
//! a malformed-directive error so a typo can never silently suppress
//! diagnostics.

/// Token class — just enough structure for the pattern rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Operator / delimiter; `::` is one token, everything else one char.
    Punct,
    /// String / char / numeric literal — preserved but never rule-matched.
    Lit,
}

/// One lexed token with the 1-based line it starts on.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A parsed `// taylint: allow(<rules>) -- <reason>` marker.  It
/// suppresses matching diagnostics on its own line and on the line
/// directly below, so it works both as a trailing comment and as a
/// whole-line comment above the flagged statement.
#[derive(Clone, Debug)]
pub struct Allow {
    pub line: u32,
    pub rules: Vec<String>,
}

/// Lexer output: the token stream, the allow markers, and malformed
/// directives (surfaced as `A0` diagnostics by the driver).
#[derive(Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
    pub errors: Vec<(u32, String)>,
}

impl Lexed {
    fn push(&mut self, kind: TokKind, text: String, line: u32) {
        self.toks.push(Tok { kind, text, line });
    }
}

/// Tokenize one source file.  Never fails: unterminated constructs lex to
/// end-of-file (the compiler, not the lint, owns syntax errors).
pub fn lex(src: &str) -> Lexed {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment (incl. /// and //!): scan to newline, check directive
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            let comment: String = s[i..j].iter().collect();
            scan_directive(&comment, line, &mut out);
            i = j;
            continue;
        }
        // block comment — Rust block comments nest
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if s[i] == '/' && i + 1 < n && s[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && i + 1 < n && s[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // char literal or lifetime
        if c == '\'' {
            if i + 1 < n && (s[i + 1].is_alphabetic() || s[i + 1] == '_') {
                // scan the name; a trailing quote makes it a char literal
                let mut j = i + 1;
                while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                    j += 1;
                }
                if j < n && s[j] == '\'' {
                    out.push(TokKind::Lit, s[i..=j].iter().collect(), line);
                    i = j + 1;
                } else {
                    // lifetime: emit the quote, skip the name
                    out.push(TokKind::Punct, "'".to_string(), line);
                    i = j;
                }
                continue;
            }
            // char literal with escape or punctuation payload
            let mut j = i + 1;
            while j < n && s[j] != '\'' {
                j += if s[j] == '\\' { 2 } else { 1 };
            }
            let end = j.min(n.saturating_sub(1));
            out.push(TokKind::Lit, s[i..=end].iter().collect(), line);
            i = j + 1;
            continue;
        }
        // identifier / keyword — or a raw/byte string prefix
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            let word: String = s[i..j].iter().collect();
            let is_str_prefix = matches!(word.as_str(), "r" | "b" | "br" | "rb");
            if is_str_prefix && j < n && (s[j] == '"' || s[j] == '#') {
                if let Some((end, nl)) = scan_prefixed_string(&s, i, j, line) {
                    out.push(TokKind::Lit, s[i..end].iter().collect(), line);
                    line = nl;
                    i = end;
                    continue;
                }
            }
            out.push(TokKind::Ident, word, line);
            i = j;
            continue;
        }
        // plain string literal
        if c == '"' {
            let (end, nl) = scan_escaped_string(&s, i, line);
            out.push(TokKind::Lit, s[i..end].iter().collect(), line);
            line = nl;
            i = end;
            continue;
        }
        // numeric literal: alnum run, optional .digit fraction + exponent
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            if j < n && s[j] == '.' && j + 1 < n && s[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                    j += 1;
                }
            }
            if j < n && (s[j] == '+' || s[j] == '-') && matches!(s[j - 1], 'e' | 'E') {
                j += 1;
                while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                    j += 1;
                }
            }
            out.push(TokKind::Lit, s[i..j].iter().collect(), line);
            i = j;
            continue;
        }
        // punctuation; `::` is one token
        if c == ':' && i + 1 < n && s[i + 1] == ':' {
            out.push(TokKind::Punct, "::".to_string(), line);
            i += 2;
            continue;
        }
        out.push(TokKind::Punct, c.to_string(), line);
        i += 1;
    }
    out
}

/// `"…"` with `\` escapes; returns (index after the closing quote, line).
fn scan_escaped_string(s: &[char], start: usize, mut line: u32) -> (usize, u32) {
    let n = s.len();
    let mut j = start + 1;
    while j < n {
        match s[j] {
            '\\' => j += 2,
            '\n' => {
                line += 1;
                j += 1;
            }
            '"' => return (j + 1, line),
            _ => j += 1,
        }
    }
    (n, line)
}

/// Raw / byte string starting at `start` (the prefix) with the quote or
/// first `#` at `hash_start`.  Returns (index after the close, line), or
/// None if this isn't actually a string (e.g. `r # !` attribute-ish).
fn scan_prefixed_string(
    s: &[char],
    start: usize,
    hash_start: usize,
    line: u32,
) -> Option<(usize, u32)> {
    let n = s.len();
    let mut k = hash_start;
    let mut hashes = 0usize;
    while k < n && s[k] == '#' {
        hashes += 1;
        k += 1;
    }
    if k >= n || s[k] != '"' {
        return None;
    }
    let prefix: String = s[start..hash_start].iter().collect();
    if hashes == 0 && (prefix == "b" || prefix == "rb") {
        // b"…" keeps backslash escapes
        let (end, nl) = scan_escaped_string(s, k, line);
        return Some((end, nl));
    }
    // raw string: ends at `"` followed by the same number of `#`s
    let mut j = k + 1;
    let mut nl = line;
    while j < n {
        if s[j] == '\n' {
            nl += 1;
            j += 1;
            continue;
        }
        if s[j] == '"' {
            let mut h = 0usize;
            while j + 1 + h < n && h < hashes && s[j + 1 + h] == '#' {
                h += 1;
            }
            if h == hashes {
                return Some((j + 1 + hashes, nl));
            }
        }
        j += 1;
    }
    Some((n, nl))
}

/// Parse a `taylint:` directive from one line comment.  Only a comment
/// whose text *begins* with the directive counts — prose that merely
/// mentions the syntax mid-sentence is ignored.
fn scan_directive(comment: &str, line: u32, out: &mut Lexed) {
    let body = comment.trim_start_matches('/').trim_start_matches('!').trim_start();
    let Some(rest) = body.strip_prefix("taylint:") else {
        return;
    };
    let rest = rest.trim_start();
    let malformed = |out: &mut Lexed| {
        out.errors.push((
            line,
            "malformed taylint directive: expected `taylint: allow(<rule>) -- <reason>`"
                .to_string(),
        ));
    };
    let Some(rest) = rest.strip_prefix("allow(") else {
        malformed(out);
        return;
    };
    let Some(close) = rest.find(')') else {
        malformed(out);
        return;
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let reason_ok = rest[close + 1..]
        .split_once("--")
        .is_some_and(|(_, reason)| !reason.trim().is_empty());
    if rules.is_empty() || !reason_ok {
        malformed(out);
        return;
    }
    out.allows.push(Allow { line, rules });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_yield_no_idents() {
        // the banned names appear only inside literals and comments, so
        // the token stream must contain none of them
        let src = r####"
            // HashMap in a line comment
            /// HashMap in a doc comment
            /* HashMap in /* a nested */ block comment */
            let a = "HashMap in a string";
            let b = r#"HashMap in a raw string"#;
            let c = b"HashMap in a byte string";
            let d = r##"quote " and hash # inside"##;
        "####;
        let ids = idents(src);
        assert!(!ids.iter().any(|i| i == "HashMap"), "leaked from: {ids:?}");
        assert!(ids.contains(&"let".to_string()));
    }

    #[test]
    fn lines_are_attributed_correctly() {
        let l = lex("a\nbb \"s\ntring\" cc\ndd");
        let find = |name: &str| {
            l.toks
                .iter()
                .find(|t| t.text == name)
                .map(|t| t.line)
                .unwrap_or(0)
        };
        assert_eq!(find("a"), 1);
        assert_eq!(find("bb"), 2);
        assert_eq!(find("cc"), 3); // the string body swallowed one newline
        assert_eq!(find("dd"), 4);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lits: Vec<_> = l.toks.iter().filter(|t| t.kind == TokKind::Lit).collect();
        assert_eq!(lits.len(), 1);
        assert_eq!(lits[0].text, "'x'");
        // lifetime names never surface as identifiers
        assert!(!l.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "a"));
    }

    #[test]
    fn escaped_quote_char_literal() {
        let l = lex(r"let q = '\''; let b = '\\'; x");
        assert!(l.toks.iter().any(|t| t.text == "x"), "lexer resynced after escapes");
    }

    #[test]
    fn ranges_do_not_merge_into_floats() {
        let l = lex("for i in 0..4 { y[i] = 1.5e-3; }");
        let texts: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"0"));
        assert!(texts.contains(&"4"));
        assert!(texts.contains(&"1.5e-3"));
    }

    #[test]
    fn double_colon_is_one_token() {
        let l = lex("std::sync::atomic");
        let texts: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["std", "::", "sync", "::", "atomic"]);
    }

    #[test]
    fn allow_directives_parse() {
        let l = lex("// taylint: allow(D1, D4) -- fixture reason\nlet x = 1;");
        assert_eq!(l.allows.len(), 1);
        assert_eq!(l.allows[0].line, 1);
        assert_eq!(l.allows[0].rules, vec!["D1".to_string(), "D4".to_string()]);
        assert!(l.errors.is_empty());
    }

    #[test]
    fn malformed_directives_are_errors() {
        // missing reason, missing rules, unknown verb — all malformed
        for bad in [
            "// taylint: allow(D1)",
            "// taylint: allow(D1) -- ",
            "// taylint: allow() -- why",
            "// taylint: disable(D1) -- why",
        ] {
            let l = lex(bad);
            assert_eq!(l.allows.len(), 0, "{bad}");
            assert_eq!(l.errors.len(), 1, "{bad}");
        }
        // prose mentioning the syntax mid-comment is NOT a directive
        let l = lex("// the marker `taylint: allow(D2) -- why` suppresses a line");
        assert!(l.allows.is_empty() && l.errors.is_empty());
    }
}
