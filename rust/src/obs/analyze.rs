//! Offline trace analytics: load an exported NDJSON trace back into a
//! queryable view, attribute time per phase, extract the critical path,
//! and diff two traces.
//!
//! The trace file is the interchange point: `repro trace …` writes it,
//! Perfetto renders it, and this module gives the CLI (`repro report`)
//! the same visibility without a browser.  Everything here is pure
//! deterministic computation over parsed lines — byte-identical inputs
//! (which the recorder guarantees across thread counts) produce
//! byte-identical reports.
//!
//! Time attribution is **self-vs-child** over logical ticks: a span's
//! self time is its duration minus the durations of spans properly
//! nested inside it on the same `(pid, tid)` lane, so a phase that
//! merely contains expensive children stops looking expensive itself.
//!
//! ```
//! use taynode::obs::analyze::TraceView;
//! let ndjson = concat!(
//!     r#"{"args":{"name":"solve"},"name":"process_name","ph":"M","pid":0,"tid":0}"#, "\n",
//!     r#"{"args":{},"dur":8,"name":"traj","ph":"X","pid":0,"tid":1,"ts":0}"#, "\n",
//!     r#"{"args":{},"dur":3,"name":"step","ph":"X","pid":0,"tid":1,"ts":2}"#, "\n",
//! );
//! let view = TraceView::parse(ndjson)?;
//! assert_eq!(view.processes, vec![(0, "solve".to_string())]);
//! let rollup = view.span_rollup();
//! let traj = rollup.rows.iter().find(|r| r.name == "traj").unwrap();
//! assert_eq!((traj.total, traj.self_ticks), (8, 5)); // 3 ticks belong to "step"
//! # anyhow::Ok(())
//! ```

use anyhow::{bail, Context, Result};

use crate::obs::cost::CostEvent;
use crate::util::bench::Table;
use crate::util::json::Json;

/// A completed span (`ph:"X"`, or a matched `"B"`/`"E"` pair).
#[derive(Clone, Debug, PartialEq)]
pub struct TSpan {
    pub pid: u64,
    pub tid: u64,
    pub name: String,
    pub ts: u64,
    pub dur: u64,
    /// Numeric `args`, in canonical (key-sorted) order.
    pub args: Vec<(String, f64)>,
}

impl TSpan {
    pub fn end(&self) -> u64 {
        self.ts + self.dur
    }

    pub fn arg(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }
}

/// An instant event (`ph:"i"`).
#[derive(Clone, Debug, PartialEq)]
pub struct TInstant {
    pub pid: u64,
    pub tid: u64,
    pub name: String,
    pub ts: u64,
    pub args: Vec<(String, f64)>,
}

impl TInstant {
    pub fn arg(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }
}

/// A counter sample (`ph:"C"`).
#[derive(Clone, Debug, PartialEq)]
pub struct TCounter {
    pub pid: u64,
    pub name: String,
    pub ts: u64,
    pub value: f64,
}

/// A parsed trace: processes, events, and per-process registry metadata.
#[derive(Clone, Debug, Default)]
pub struct TraceView {
    /// `(pid, name)` from `process_name` metadata, ascending pid.
    pub processes: Vec<(u64, String)>,
    pub spans: Vec<TSpan>,
    pub instants: Vec<TInstant>,
    pub counters: Vec<TCounter>,
    /// `(pid, registry args)` from `registry` metadata records.
    pub registries: Vec<(u64, Json)>,
}

fn num_field(j: &Json, key: &str) -> Result<u64> {
    let v = j.req(key)?.as_f64().with_context(|| format!("field {key:?} is not a number"))?;
    if !v.is_finite() || v < 0.0 {
        bail!("field {key:?} out of range: {v}");
    }
    Ok(v as u64)
}

fn numeric_args(j: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(m) = j.get("args").and_then(Json::as_obj) {
        for (k, v) in m {
            if let Some(x) = v.as_f64() {
                out.push((k.clone(), x));
            }
        }
    }
    out
}

impl TraceView {
    /// Parse an NDJSON trace.  Tolerates blank lines and unknown metadata;
    /// rejects — naming the offending line — malformed JSON, missing or
    /// non-numeric required fields, unknown phases, an `E` with no open
    /// `B` on its `(pid, tid)` lane, a `B` left unclosed at end of input,
    /// and a duplicate `process_name` for the same pid.
    pub fn parse(s: &str) -> Result<TraceView> {
        let mut view = TraceView::default();
        // Open `ph:"B"` begins per (pid, tid) lane: (pid, tid, name, ts, line).
        let mut open: Vec<(u64, u64, String, u64, usize)> = Vec::new();
        for (i, line) in s.lines().enumerate() {
            let lineno = i + 1;
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line).with_context(|| format!("ndjson line {lineno}"))?;
            (|| -> Result<()> {
                let ph = j.str_of("ph")?;
                match ph {
                    "M" => match j.str_of("name")? {
                        "process_name" => {
                            let pid = num_field(&j, "pid")?;
                            if view.processes.iter().any(|(p, _)| *p == pid) {
                                bail!("duplicate process_name for pid {pid}");
                            }
                            let name = j.req("args")?.str_of("name")?.to_string();
                            view.processes.push((pid, name));
                        }
                        "registry" => {
                            let pid = num_field(&j, "pid")?;
                            view.registries.push((pid, j.req("args")?.clone()));
                        }
                        _ => {} // other metadata is viewer-specific; skip
                    },
                    "X" => view.spans.push(TSpan {
                        pid: num_field(&j, "pid")?,
                        tid: num_field(&j, "tid")?,
                        name: j.str_of("name")?.to_string(),
                        ts: num_field(&j, "ts")?,
                        dur: num_field(&j, "dur")?,
                        args: numeric_args(&j),
                    }),
                    "B" => open.push((
                        num_field(&j, "pid")?,
                        num_field(&j, "tid")?,
                        j.str_of("name")?.to_string(),
                        num_field(&j, "ts")?,
                        lineno,
                    )),
                    "E" => {
                        let (pid, tid) = (num_field(&j, "pid")?, num_field(&j, "tid")?);
                        let ts = num_field(&j, "ts")?;
                        // LIFO per lane: close the most recent open B.
                        let Some(pos) = open.iter().rposition(|(p, t, ..)| (*p, *t) == (pid, tid))
                        else {
                            bail!("span end (ph:\"E\") with no open begin on pid {pid} tid {tid}");
                        };
                        let (_, _, name, b_ts, _) = open.remove(pos);
                        if ts < b_ts {
                            bail!("span end at ts {ts} precedes its begin at ts {b_ts}");
                        }
                        view.spans.push(TSpan {
                            pid,
                            tid,
                            name,
                            ts: b_ts,
                            dur: ts - b_ts,
                            args: numeric_args(&j),
                        });
                    }
                    "i" => view.instants.push(TInstant {
                        pid: num_field(&j, "pid")?,
                        tid: num_field(&j, "tid")?,
                        name: j.str_of("name")?.to_string(),
                        ts: num_field(&j, "ts")?,
                        args: numeric_args(&j),
                    }),
                    "C" => view.counters.push(TCounter {
                        pid: num_field(&j, "pid")?,
                        name: j.str_of("name")?.to_string(),
                        ts: num_field(&j, "ts")?,
                        value: j
                            .req("args")?
                            .get("value")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    }),
                    other => bail!("unknown trace phase {other:?}"),
                }
                Ok(())
            })()
            .with_context(|| format!("ndjson line {lineno}"))?;
        }
        if let Some((pid, tid, name, _, lineno)) = open.first() {
            bail!(
                "span begin {name:?} on pid {pid} tid {tid} (ndjson line {lineno}) never closed"
            );
        }
        view.processes.sort();
        Ok(view)
    }

    pub fn process_name(&self, pid: u64) -> &str {
        self.processes
            .iter()
            .find(|(p, _)| *p == pid)
            .map_or("?", |(_, n)| n.as_str())
    }

    /// Registry metadata for `pid`, if the trace carried one.
    pub fn registry(&self, pid: u64) -> Option<&Json> {
        self.registries.iter().find(|(p, _)| *p == pid).map(|(_, j)| j)
    }

    /// Per-name span aggregation with self-vs-child time attribution.
    pub fn span_rollup(&self) -> SpanRollup {
        // Sort within each (pid, tid) lane: ts ascending, then longer
        // spans first so a parent precedes children starting at the same
        // tick; name breaks exact-interval ties (deterministic, and it
        // makes "request" the parent of a coincident "traj").
        let mut order: Vec<usize> = (0..self.spans.len()).collect();
        order.sort_by(|&a, &b| {
            let (sa, sb) = (&self.spans[a], &self.spans[b]);
            (sa.pid, sa.tid, sa.ts, u64::MAX - sa.dur, &sa.name, a).cmp(&(
                sb.pid,
                sb.tid,
                sb.ts,
                u64::MAX - sb.dur,
                &sb.name,
                b,
            ))
        });
        let mut child_ticks = vec![0u64; self.spans.len()];
        let mut stack: Vec<usize> = Vec::new(); // indices of enclosing spans
        let mut prev_lane = None;
        for &i in &order {
            let s = &self.spans[i];
            if prev_lane != Some((s.pid, s.tid)) {
                stack.clear();
                prev_lane = Some((s.pid, s.tid));
            }
            // Pop lanes' spans we've left (or that merely overlap: only
            // proper containment counts as parentage).
            while let Some(&top) = stack.last() {
                let t = &self.spans[top];
                if s.ts >= t.end() || s.end() > t.end() {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&top) = stack.last() {
                child_ticks[top] += s.dur; // direct child only
            }
            stack.push(i);
        }
        let mut rows: Vec<RollupRow> = Vec::new();
        for (i, s) in self.spans.iter().enumerate() {
            let self_ticks = s.dur.saturating_sub(child_ticks[i]);
            match rows.iter().position(|r| r.name == s.name) {
                Some(p) => {
                    let r = &mut rows[p];
                    r.count += 1;
                    r.total += s.dur;
                    r.self_ticks += self_ticks;
                    r.max_dur = r.max_dur.max(s.dur);
                }
                None => rows.push(RollupRow {
                    name: s.name.clone(),
                    count: 1,
                    total: s.dur,
                    self_ticks,
                    max_dur: s.dur,
                }),
            }
        }
        rows.sort_by(|a, b| (u64::MAX - a.total, &a.name).cmp(&(u64::MAX - b.total, &b.name)));
        SpanRollup { rows }
    }

    /// The critical path through one process: a greedy furthest-end walk
    /// over its spans.  From the current span, the successor is the
    /// overlapping span that extends furthest past the current end; when
    /// nothing overlaps, the walk jumps over the idle gap to the next
    /// span to start.  Ties break by (earlier start, name, tid), so the
    /// path is deterministic.
    pub fn critical_path(&self, pid: u64) -> Vec<CritStep> {
        let mut spans: Vec<&TSpan> = self.spans.iter().filter(|s| s.pid == pid).collect();
        spans.sort_by(|a, b| {
            (a.ts, u64::MAX - a.end(), &a.name, a.tid)
                .cmp(&(b.ts, u64::MAX - b.end(), &b.name, b.tid))
        });
        let mut path = Vec::new();
        let Some(first) = spans.first() else { return path };
        let mut cur = *first;
        loop {
            path.push(CritStep {
                name: cur.name.clone(),
                tid: cur.tid,
                ts: cur.ts,
                dur: cur.dur,
            });
            let cur_end = cur.end();
            // Overlapping successor extending furthest past the frontier…
            let next = spans
                .iter()
                .filter(|s| s.ts <= cur_end && s.end() > cur_end)
                .min_by_key(|s| (u64::MAX - s.end(), s.ts, s.name.clone(), s.tid))
                // …or jump the gap to the next span to start.
                .or_else(|| {
                    spans
                        .iter()
                        .filter(|s| s.ts > cur_end)
                        .min_by_key(|s| (s.ts, u64::MAX - s.end(), s.name.clone(), s.tid))
                });
            match next {
                Some(s) => cur = *s,
                None => return path,
            }
        }
    }

    /// Bridge into the cost ledger: `accept`/`reject` instants and `traj`
    /// spans of process `pid`, in file order (per-track chronological).
    pub fn cost_events(&self, pid: u64) -> Vec<CostEvent> {
        let mut out = Vec::new();
        for i in &self.instants {
            if i.pid != pid {
                continue;
            }
            let (err, h) = (i.arg("err").unwrap_or(0.0), i.arg("h").unwrap_or(0.0));
            match i.name.as_str() {
                "accept" => out.push(CostEvent::Accept { track: i.tid, err, h }),
                "reject" => out.push(CostEvent::Reject { track: i.tid, err, h }),
                _ => {}
            }
        }
        for s in &self.spans {
            if s.pid == pid && s.name == "traj" {
                out.push(CostEvent::Traj {
                    track: s.tid,
                    attempts: s.dur,
                    nfe: s.arg("nfe").unwrap_or(0.0) as u64,
                    rejected: s.arg("rejected").unwrap_or(0.0) as u64,
                });
            }
        }
        out
    }
}

/// One aggregated span name in a [`SpanRollup`].
#[derive(Clone, Debug, PartialEq)]
pub struct RollupRow {
    pub name: String,
    pub count: u64,
    /// Σ durations (ticks).
    pub total: u64,
    /// Σ durations minus time spent in directly nested spans.
    pub self_ticks: u64,
    pub max_dur: u64,
}

/// Span aggregation by name, descending total ticks.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpanRollup {
    pub rows: Vec<RollupRow>,
}

impl SpanRollup {
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["span", "count", "total", "self", "max"]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.count.to_string(),
                r.total.to_string(),
                r.self_ticks.to_string(),
                r.max_dur.to_string(),
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(&r.name)),
                        ("count", Json::num(r.count as f64)),
                        ("total", Json::num(r.total as f64)),
                        ("self", Json::num(r.self_ticks as f64)),
                        ("max", Json::num(r.max_dur as f64)),
                    ])
                })
                .collect(),
        )
    }
}

/// One step of a critical path.
#[derive(Clone, Debug, PartialEq)]
pub struct CritStep {
    pub name: String,
    pub tid: u64,
    pub ts: u64,
    pub dur: u64,
}

/// One span name's change between two traces (a − b).
#[derive(Clone, Debug, PartialEq)]
pub struct DiffRow {
    pub name: String,
    pub count_a: u64,
    pub count_b: u64,
    pub total_a: u64,
    pub total_b: u64,
}

impl DiffRow {
    pub fn delta(&self) -> i64 {
        self.total_a as i64 - self.total_b as i64
    }
}

/// Diff two traces' span rollups: every name present in either, sorted by
/// |Δ total ticks| descending (name ascending on ties).
pub fn diff(a: &TraceView, b: &TraceView) -> Vec<DiffRow> {
    let (ra, rb) = (a.span_rollup(), b.span_rollup());
    let mut rows: Vec<DiffRow> = Vec::new();
    for r in &ra.rows {
        rows.push(DiffRow {
            name: r.name.clone(),
            count_a: r.count,
            count_b: 0,
            total_a: r.total,
            total_b: 0,
        });
    }
    for r in &rb.rows {
        match rows.iter().position(|d| d.name == r.name) {
            Some(p) => {
                rows[p].count_b = r.count;
                rows[p].total_b = r.total;
            }
            None => rows.push(DiffRow {
                name: r.name.clone(),
                count_a: 0,
                count_b: r.count,
                total_a: 0,
                total_b: r.total,
            }),
        }
    }
    rows.sort_by(|x, y| {
        (i64::MAX - x.delta().abs(), &x.name).cmp(&(i64::MAX - y.delta().abs(), &y.name))
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Recorder, TraceDoc, NO_ARGS};

    fn sample_trace() -> String {
        let mut rec = Recorder::enabled();
        rec.span("request", 7, 2, 6, [("nfe", 30.0), ("miss", 0.0)]);
        rec.span("traj", 7, 3, 5, [("nfe", 30.0), ("rejected", 1.0)]);
        rec.instant("reject", 7, 0, [("err", 2.0), ("h", 0.5)]);
        rec.instant("accept", 7, 1, [("err", 0.5), ("h", 0.25)]);
        rec.counter("queue_depth", 2, 3.0);
        let mut doc = TraceDoc::new();
        doc.add_process(0, "serve/toy", &rec);
        doc.to_ndjson()
    }

    #[test]
    fn parses_every_emitted_phase() {
        let v = TraceView::parse(&sample_trace()).unwrap();
        assert_eq!(v.processes, vec![(0, "serve/toy".to_string())]);
        assert_eq!(v.spans.len(), 2);
        assert_eq!(v.instants.len(), 2);
        assert_eq!(v.counters.len(), 1);
        assert_eq!(v.registries.len(), 1);
        assert_eq!(v.process_name(0), "serve/toy");
        assert_eq!(v.process_name(9), "?");
        assert_eq!(v.spans[0].arg("nfe"), Some(30.0));
    }

    #[test]
    fn rollup_attributes_self_vs_child_time() {
        let v = TraceView::parse(&sample_trace()).unwrap();
        let roll = v.span_rollup();
        let req = roll.rows.iter().find(|r| r.name == "request").unwrap();
        let traj = roll.rows.iter().find(|r| r.name == "traj").unwrap();
        // request [2,8) contains traj [3,8): 5 of its 6 ticks are child time.
        assert_eq!((req.total, req.self_ticks), (6, 1));
        assert_eq!((traj.total, traj.self_ticks), (5, 5));
        assert_eq!(roll.rows[0].name, "request"); // sorted by total desc
        assert_eq!(roll.table().row_count(), 2);
    }

    #[test]
    fn coincident_request_and_traj_nest_by_name() {
        // Identical intervals: the name tie-break makes "request" the
        // parent, so its self time is zero — not double-counted.
        let mut rec = Recorder::enabled();
        rec.span("request", 1, 0, 4, NO_ARGS);
        rec.span("traj", 1, 0, 4, NO_ARGS);
        let mut doc = TraceDoc::new();
        doc.add_process(0, "p", &rec);
        let v = TraceView::parse(&doc.to_ndjson()).unwrap();
        let roll = v.span_rollup();
        let req = roll.rows.iter().find(|r| r.name == "request").unwrap();
        assert_eq!(req.self_ticks, 0);
    }

    #[test]
    fn begin_end_pairs_parse_and_mismatches_name_lines() {
        let ok = concat!(
            r#"{"name":"load","ph":"B","pid":0,"tid":2,"ts":1}"#,
            "\n",
            r#"{"name":"load","ph":"E","pid":0,"tid":2,"ts":6}"#,
            "\n"
        );
        let v = TraceView::parse(ok).unwrap();
        assert_eq!(v.spans, vec![TSpan {
            pid: 0,
            tid: 2,
            name: "load".to_string(),
            ts: 1,
            dur: 5,
            args: vec![],
        }]);

        // Orphan E: rejected, naming its line.
        let orphan = concat!(
            r#"{"name":"x","ph":"i","pid":0,"tid":0,"ts":0,"args":{}}"#,
            "\n",
            r#"{"name":"load","ph":"E","pid":0,"tid":2,"ts":6}"#,
            "\n"
        );
        let err = format!("{:#}", TraceView::parse(orphan).unwrap_err());
        assert!(err.contains("ndjson line 2") && err.contains("no open begin"), "{err}");

        // Unclosed B: rejected, naming the begin's line.
        let unclosed = r#"{"name":"load","ph":"B","pid":0,"tid":2,"ts":1}"#;
        let err = format!("{:#}", TraceView::parse(unclosed).unwrap_err());
        assert!(err.contains("line 1") && err.contains("never closed"), "{err}");
    }

    #[test]
    fn duplicate_process_ids_and_unknown_phases_are_rejected() {
        let dup = concat!(
            r#"{"args":{"name":"a"},"name":"process_name","ph":"M","pid":3,"tid":0}"#,
            "\n",
            r#"{"args":{"name":"b"},"name":"process_name","ph":"M","pid":3,"tid":0}"#,
            "\n"
        );
        let err = format!("{:#}", TraceView::parse(dup).unwrap_err());
        assert!(err.contains("ndjson line 2") && err.contains("duplicate"), "{err}");

        let unknown = r#"{"name":"x","ph":"Q","pid":0,"tid":0,"ts":0}"#;
        let err = format!("{:#}", TraceView::parse(unknown).unwrap_err());
        assert!(err.contains("ndjson line 1") && err.contains("unknown trace phase"), "{err}");
    }

    #[test]
    fn critical_path_walks_overlaps_and_gaps() {
        let mut rec = Recorder::enabled();
        rec.span("a", 0, 0, 4, NO_ARGS); // [0,4)
        rec.span("b", 1, 2, 5, NO_ARGS); // [2,7) extends past a
        rec.span("c", 0, 9, 2, NO_ARGS); // gap, then [9,11)
        let mut doc = TraceDoc::new();
        doc.add_process(0, "p", &rec);
        let v = TraceView::parse(&doc.to_ndjson()).unwrap();
        let names: Vec<String> = v.critical_path(0).into_iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        assert!(v.critical_path(5).is_empty());
    }

    #[test]
    fn diff_ranks_by_absolute_delta() {
        let mk = |durs: &[(&'static str, u64)]| {
            let mut rec = Recorder::enabled();
            for (name, d) in durs {
                rec.span(name, 0, 0, *d, NO_ARGS);
            }
            let mut doc = TraceDoc::new();
            doc.add_process(0, "p", &rec);
            TraceView::parse(&doc.to_ndjson()).unwrap()
        };
        let a = mk(&[("traj", 10), ("forward", 3)]);
        let b = mk(&[("traj", 4), ("adjoint_shard", 2)]);
        let rows = diff(&a, &b);
        assert_eq!(rows[0].name, "traj");
        assert_eq!(rows[0].delta(), 6);
        let fwd = rows.iter().find(|r| r.name == "forward").unwrap();
        assert_eq!((fwd.total_a, fwd.total_b), (3, 0));
        let adj = rows.iter().find(|r| r.name == "adjoint_shard").unwrap();
        assert_eq!((adj.count_a, adj.count_b, adj.total_b), (0, 1, 2));
    }

    #[test]
    fn cost_events_bridge_to_the_ledger() {
        let v = TraceView::parse(&sample_trace()).unwrap();
        let evs = v.cost_events(0);
        assert_eq!(evs.len(), 3);
        let ledger = crate::obs::cost::CostLedger::from_cost_events(evs);
        assert_eq!(ledger.trajs.len(), 1);
        assert_eq!(ledger.trajs[0].nfe, 30);
        assert_eq!(ledger.trajs[0].longest_streak, 1);
        assert!(v.cost_events(4).is_empty());
    }
}
