//! Deterministic report rendering for `repro report` and `repro slo`:
//! ANSI-free fixed-width text plus a canonical JSON twin, byte-identical
//! at any `TAYNODE_THREADS`.
//!
//! Rendering is a pure function of parsed trace/SLO state — tables come
//! from [`Table::render`], JSON from the canonical key-sorted writer —
//! so CI can `cmp` two reports produced at different worker counts, and
//! the FNV-1a witness ([`ReportDoc::hash`]) gives scripts a one-line
//! identity check without shipping the whole file.
//!
//! ```
//! use taynode::obs::analyze::TraceView;
//! use taynode::obs::report::trace_report;
//! let ndjson = concat!(
//!     r#"{"args":{"name":"solve"},"name":"process_name","ph":"M","pid":0,"tid":0}"#, "\n",
//!     r#"{"args":{"nfe":12,"rejected":1},"dur":4,"name":"traj","ph":"X","pid":0,"tid":0,"ts":0}"#, "\n",
//! );
//! let doc = trace_report(&TraceView::parse(ndjson)?)?;
//! assert!(doc.text.contains("traj"));
//! assert_eq!(doc.hash(), trace_report(&TraceView::parse(ndjson)?)?.hash());
//! # anyhow::Ok(())
//! ```

use anyhow::Result;

use crate::obs::analyze::{diff, TraceView};
use crate::obs::cost::CostLedger;
use crate::obs::slo::SloTracker;
use crate::obs::Log2Hist;
use crate::util::bench::Table;
use crate::util::json::Json;

/// A rendered report: display text and its canonical JSON twin.
pub struct ReportDoc {
    pub text: String,
    pub json: Json,
}

impl ReportDoc {
    /// FNV-1a over the text bytes — the same witness the trace exporter
    /// uses, so "same hash" means "same report".
    pub fn hash(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.text.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

fn section(out: &mut String, title: &str) {
    if !out.is_empty() {
        out.push('\n');
    }
    out.push_str("== ");
    out.push_str(title);
    out.push_str(" ==\n");
}

/// How many critical-path steps the text report prints (the JSON twin
/// carries the path length, not the steps, to stay compact).
const CRIT_STEPS: usize = 12;
/// How many top-NFE trajectories the cost-ledger table prints.
const LEDGER_TOP: usize = 8;

fn registry_tables(reg: &Json) -> Result<(Table, Table)> {
    let mut counters = Table::new(&["counter", "value"]);
    if let Some(m) = reg.get("counters").and_then(Json::as_obj) {
        for (k, v) in m {
            counters.row(vec![
                k.clone(),
                format!("{}", v.as_f64().unwrap_or(0.0)),
            ]);
        }
    }
    let mut hists = Table::new(&["hist", "count", "p50", "p90", "p99"]);
    if let Some(m) = reg.get("hists").and_then(Json::as_obj) {
        for (k, v) in m {
            let h = Log2Hist::from_json(v)?;
            hists.row(vec![
                k.clone(),
                h.count().to_string(),
                format!("{:.3e}", h.quantile(0.5)),
                format!("{:.3e}", h.quantile(0.9)),
                format!("{:.3e}", h.quantile(0.99)),
            ]);
        }
    }
    Ok((counters, hists))
}

/// Render the full analytics report for one parsed trace: the process
/// roster, then per process its span rollup (self-vs-child attribution),
/// critical path, cost ledger (when the trace carries solver attribution
/// events), and registry counters with histogram quantiles.
pub fn trace_report(view: &TraceView) -> Result<ReportDoc> {
    let mut text = String::new();
    let mut json_sections: Vec<(&str, Json)> = Vec::new();

    section(&mut text, "processes");
    let mut proc_table = Table::new(&["pid", "process", "spans", "instants", "counters"]);
    for (pid, name) in &view.processes {
        proc_table.row(vec![
            pid.to_string(),
            name.clone(),
            view.spans.iter().filter(|s| s.pid == *pid).count().to_string(),
            view.instants.iter().filter(|i| i.pid == *pid).count().to_string(),
            view.counters.iter().filter(|c| c.pid == *pid).count().to_string(),
        ]);
    }
    text.push_str(&proc_table.render());
    json_sections.push((
        "processes",
        Json::Arr(
            view.processes
                .iter()
                .map(|(pid, name)| {
                    Json::obj(vec![
                        ("pid", Json::num(*pid as f64)),
                        ("name", Json::str(name)),
                    ])
                })
                .collect(),
        ),
    ));

    let mut proc_json = Vec::new();
    for (pid, name) in &view.processes {
        // Per-process sub-view: rollups and paths must not mix lanes of
        // unrelated processes.
        let sub = TraceView {
            processes: vec![(*pid, name.clone())],
            spans: view.spans.iter().filter(|s| s.pid == *pid).cloned().collect(),
            instants: view.instants.iter().filter(|i| i.pid == *pid).cloned().collect(),
            counters: view.counters.iter().filter(|c| c.pid == *pid).cloned().collect(),
            registries: Vec::new(),
        };
        let mut fields: Vec<(&str, Json)> = vec![
            ("pid", Json::num(*pid as f64)),
            ("name", Json::str(name)),
        ];

        let rollup = sub.span_rollup();
        if !rollup.rows.is_empty() {
            section(&mut text, &format!("{name}: span rollup (ticks)"));
            text.push_str(&rollup.table().render());
            fields.push(("span_rollup", rollup.to_json()));
        }

        let path = sub.critical_path(*pid);
        if !path.is_empty() {
            section(
                &mut text,
                &format!("{name}: critical path ({} steps)", path.len()),
            );
            let mut t = Table::new(&["#", "span", "tid", "ts", "dur"]);
            for (i, s) in path.iter().take(CRIT_STEPS).enumerate() {
                t.row(vec![
                    i.to_string(),
                    s.name.clone(),
                    s.tid.to_string(),
                    s.ts.to_string(),
                    s.dur.to_string(),
                ]);
            }
            text.push_str(&t.render());
            if path.len() > CRIT_STEPS {
                text.push_str(&format!("... {} more steps\n", path.len() - CRIT_STEPS));
            }
            fields.push(("critical_path_len", Json::num(path.len() as f64)));
        }

        let cost = sub.cost_events(*pid);
        if !cost.is_empty() {
            let ledger = CostLedger::from_cost_events(cost);
            section(
                &mut text,
                &format!("{name}: cost ledger (top {LEDGER_TOP} by NFE)"),
            );
            text.push_str(&ledger.table(LEDGER_TOP).render());
            let hist = ledger.streak_hist();
            if !hist.is_empty() {
                let parts: Vec<String> =
                    hist.iter().map(|(l, n)| format!("{n}x len {l}")).collect();
                text.push_str(&format!("reject streaks: {}\n", parts.join(", ")));
            }
            fields.push(("cost", ledger.to_json()));
        }

        if let Some(reg) = view.registry(*pid) {
            let (counters, hists) = registry_tables(reg)?;
            if counters.row_count() > 0 {
                section(&mut text, &format!("{name}: counters"));
                text.push_str(&counters.render());
            }
            if hists.row_count() > 0 {
                section(&mut text, &format!("{name}: histogram quantiles"));
                text.push_str(&hists.render());
            }
            fields.push(("registry", reg.clone()));
        }
        proc_json.push(Json::obj(fields));
    }
    json_sections.push(("per_process", Json::Arr(proc_json)));

    let doc = ReportDoc { text, json: Json::obj(json_sections) };
    let mut text = doc.text;
    text.push_str(&format!("\nreport hash: {:016x}\n", {
        let probe = ReportDoc { text: text.clone(), json: Json::Null };
        probe.hash()
    }));
    Ok(ReportDoc { text, json: doc.json })
}

/// Render the diff of two traces' span rollups (`a − b` in ticks).
pub fn trace_diff_report(
    a: &TraceView,
    label_a: &str,
    b: &TraceView,
    label_b: &str,
) -> ReportDoc {
    let rows = diff(a, b);
    let mut text = String::new();
    section(&mut text, &format!("span diff: {label_a} vs {label_b}"));
    let mut t = Table::new(&["span", "count_a", "count_b", "ticks_a", "ticks_b", "delta"]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            r.count_a.to_string(),
            r.count_b.to_string(),
            r.total_a.to_string(),
            r.total_b.to_string(),
            format!("{:+}", r.delta()),
        ]);
    }
    text.push_str(&t.render());
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("count_a", Json::num(r.count_a as f64)),
                    ("count_b", Json::num(r.count_b as f64)),
                    ("ticks_a", Json::num(r.total_a as f64)),
                    ("ticks_b", Json::num(r.total_b as f64)),
                    ("delta", Json::num(r.delta() as f64)),
                ])
            })
            .collect(),
    );
    ReportDoc { text, json }
}

/// Render the per-class SLO report.
pub fn slo_report(slo: &SloTracker) -> ReportDoc {
    let mut text = String::new();
    section(&mut text, "serving SLO (deadline-miss budgets, step ticks)");
    text.push_str(&slo.table().render());
    ReportDoc { text, json: slo.to_json() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Counter, Hist, Recorder, TraceDoc};

    fn serve_like_trace() -> String {
        let mut rec = Recorder::enabled();
        rec.span("request", 2, 0, 5, [("nfe", 20.0), ("miss", 0.0)]);
        rec.span("traj", 2, 1, 4, [("nfe", 20.0), ("rejected", 1.0)]);
        rec.instant("reject", 2, 0, [("err", 3.0), ("h", 0.5)]);
        rec.instant("accept", 2, 1, [("err", 0.5), ("h", 0.25)]);
        rec.counter("queue_depth", 1, 1.0);
        rec.inc(Counter::Retired, 1);
        rec.observe(Hist::LatencySteps, 5.0);
        let mut doc = TraceDoc::new();
        doc.add_process(0, "serve/toy", &rec);
        doc.to_ndjson()
    }

    #[test]
    fn trace_report_renders_every_section_deterministically() {
        let v = TraceView::parse(&serve_like_trace()).unwrap();
        let doc = trace_report(&v).unwrap();
        for needle in [
            "== processes ==",
            "span rollup",
            "critical path",
            "cost ledger",
            "reject streaks: 1x len 1",
            "counters",
            "histogram quantiles",
            "report hash:",
        ] {
            assert!(doc.text.contains(needle), "missing {needle:?} in:\n{}", doc.text);
        }
        assert!(!doc.text.contains('\u{1b}'), "report must be ANSI-free");
        let again = trace_report(&TraceView::parse(&serve_like_trace()).unwrap()).unwrap();
        assert_eq!(doc.text, again.text);
        assert_eq!(doc.hash(), again.hash());
        assert_eq!(doc.json.to_string(), again.json.to_string());
        // The JSON twin carries the ledger.
        let per_proc = doc.json.req("per_process").unwrap().as_arr().unwrap();
        let cost = per_proc[0].req("cost").unwrap();
        assert_eq!(cost.req("nfe").unwrap().as_f64(), Some(20.0));
    }

    #[test]
    fn diff_report_names_what_it_compares() {
        let v = TraceView::parse(&serve_like_trace()).unwrap();
        let doc = trace_diff_report(&v, "t1.ndjson", &v, "t4.ndjson");
        assert!(doc.text.contains("t1.ndjson vs t4.ndjson"), "{}", doc.text);
        let rows = doc.json.as_arr().unwrap();
        assert!(rows.iter().all(|r| r.req("delta").unwrap().as_f64() == Some(0.0)));
    }

    #[test]
    fn slo_report_round_trips_to_json() {
        let mut slo = SloTracker::standard();
        slo.record("realtime", 3, true);
        let doc = slo_report(&slo);
        assert!(doc.text.contains("realtime"));
        let rows = doc.json.as_arr().unwrap();
        assert_eq!(rows[0].req("missed").unwrap().as_f64(), Some(1.0));
    }
}
