//! Deterministic telemetry: structured tracing + a metrics registry for
//! the solver, adjoint, and serving hot paths.
//!
//! Design constraints (the D-catalog, by construction):
//!
//! * **Zero-cost off.** [`Recorder::off`] holds no buffer; every record
//!   method is an `#[inline]` early return on a `None` check, so disabled
//!   telemetry adds a handful of predicted branches to the hot path
//!   (gated ≤ 5% in `benches/perf_obs.rs`).
//! * **Bit-identical at any thread count.** Timestamps come from the
//!   deterministic [`util::clock::StepClock`] (solver attempts, engine
//!   steps — never wall time).  Parallel regions record into per-shard
//!   sub-recorders that workers *return* (no shared state, no sync — D2
//!   stays clean) and the caller merges: in fixed shard order when the
//!   shard layout is thread-count independent ([`Recorder::absorb_in_order`],
//!   adjoint shards), or canonicalized by trajectory track when it is not
//!   ([`Recorder::absorb_by_track`], pooled solves whose chunk layout
//!   depends on the worker count).  Either way the merged trace is a pure
//!   function of the seed.
//! * **Allocation-light.** Events are plain-old-data with `&'static str`
//!   names and at most two inline f64 args; histograms are fixed arrays
//!   ([`registry::Log2Hist`]); nothing keyed, nothing hashed.
//!
//! Export is Chrome Trace Event Format NDJSON via [`trace::TraceDoc`]
//! (`repro trace <experiment|serve>`), loadable in Perfetto or
//! `chrome://tracing`.  The analytics side reads that format back:
//! [`analyze::TraceView`] parses a trace into a queryable view,
//! [`cost::CostLedger`] attributes solve cost per trajectory,
//! [`slo::SloTracker`] budgets deadline misses per tolerance class over
//! step ticks, and [`report`] renders it all (`repro report`, `repro
//! slo`) as byte-stable text + canonical JSON.
//!
//! [`util::clock::StepClock`]: crate::util::clock::StepClock

pub mod analyze;
pub mod cost;
pub mod registry;
pub mod report;
pub mod slo;
pub mod trace;

pub use cost::{CostLedger, RkNfeTable};
pub use registry::{Counter, Hist, Log2Hist, Registry};
pub use slo::SloTracker;
pub use trace::TraceDoc;

use crate::solvers::SolveStats;
use crate::util::clock::{Clock, StepClock};

/// Event kind, mapping onto Chrome Trace phases: `Span` → complete event
/// `"X"`, `Instant` → `"i"`, `Counter` → `"C"`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Span,
    Instant,
    Counter,
}

/// Unused argument slots carry an empty name and are skipped on export.
pub const NO_ARGS: [(&str, f64); 2] = [("", 0.0), ("", 0.0)];

/// One telemetry event: plain old data, no allocation.  `track` maps to
/// the Chrome trace `tid` (a trajectory id, request id, or shard index —
/// whatever is stable across thread counts for the emitting layer); `ts`
/// and `dur` are deterministic ticks, not wall time.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub name: &'static str,
    pub kind: EventKind,
    pub track: u64,
    pub ts: u64,
    pub dur: u64,
    pub args: [(&'static str, f64); 2],
}

struct RecBuf {
    events: Vec<Event>,
    reg: Registry,
    clock: StepClock,
}

/// The event/metrics recorder.  Off by default ([`Recorder::off`]); every
/// instrumented structure owns one and exposes it via accessors, so
/// enabling telemetry is a per-run decision with no type changes.
#[derive(Default)]
pub struct Recorder {
    buf: Option<Box<RecBuf>>,
}

impl Recorder {
    /// The no-op recorder: no buffer, every record call an early return.
    pub fn off() -> Recorder {
        Recorder { buf: None }
    }

    pub fn enabled() -> Recorder {
        Recorder {
            buf: Some(Box::new(RecBuf {
                events: Vec::new(),
                reg: Registry::new(),
                clock: StepClock::new(),
            })),
        }
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        self.buf.is_some()
    }

    /// Set the deterministic clock to an externally-maintained tick count
    /// (e.g. the serving engine's step number).  No-op when off.
    #[inline]
    pub fn set_ticks(&mut self, ticks: u64) {
        if let Some(b) = &mut self.buf {
            b.clock.set_ticks(ticks);
        }
    }

    /// Current deterministic ticks (0 when off).
    #[inline]
    pub fn now_ticks(&self) -> u64 {
        self.buf.as_ref().map_or(0, |b| b.clock.now_ticks())
    }

    #[inline]
    pub fn span(
        &mut self,
        name: &'static str,
        track: u64,
        ts: u64,
        dur: u64,
        args: [(&'static str, f64); 2],
    ) {
        if let Some(b) = &mut self.buf {
            b.events.push(Event { name, kind: EventKind::Span, track, ts, dur, args });
        }
    }

    #[inline]
    pub fn instant(
        &mut self,
        name: &'static str,
        track: u64,
        ts: u64,
        args: [(&'static str, f64); 2],
    ) {
        if let Some(b) = &mut self.buf {
            b.events.push(Event { name, kind: EventKind::Instant, track, ts, dur: 0, args });
        }
    }

    /// A Chrome counter-track sample (`ph:"C"`): `value` at tick `ts`.
    #[inline]
    pub fn counter(&mut self, name: &'static str, ts: u64, value: f64) {
        if let Some(b) = &mut self.buf {
            b.events.push(Event {
                name,
                kind: EventKind::Counter,
                track: 0,
                ts,
                dur: 0,
                args: [("value", value), ("", 0.0)],
            });
        }
    }

    #[inline]
    pub fn inc(&mut self, c: Counter, by: u64) {
        if let Some(b) = &mut self.buf {
            b.reg.inc(c, by);
        }
    }

    #[inline]
    pub fn observe(&mut self, h: Hist, v: f32) {
        if let Some(b) = &mut self.buf {
            b.reg.observe(h, v);
        }
    }

    /// Fold a retired trajectory's [`SolveStats`] into the counters — the
    /// single stats→counters conversion (see [`Registry::absorb_solve_stats`]).
    #[inline]
    pub fn absorb_stats(&mut self, s: &SolveStats) {
        if let Some(b) = &mut self.buf {
            b.reg.absorb_solve_stats(s);
        }
    }

    /// Recorded events, in buffer order (empty when off).
    pub fn events(&self) -> &[Event] {
        self.buf.as_ref().map_or(&[], |b| &b.events)
    }

    /// The metrics registry, if recording.
    pub fn registry(&self) -> Option<&Registry> {
        self.buf.as_ref().map(|b| &b.reg)
    }

    /// Merge per-shard sub-recorders whose shard layout is fixed (does not
    /// depend on the worker count, e.g. adjoint shards of `ceil(B/16)`
    /// rows): events concatenate in the given order, registries sum.
    /// Deterministic because the caller supplies shards in index order.
    pub fn absorb_in_order(&mut self, parts: Vec<Recorder>) {
        let Some(b) = &mut self.buf else { return };
        for p in parts {
            if let Some(pb) = p.buf {
                b.events.extend(pb.events);
                b.reg.absorb(&pb.reg);
            }
        }
    }

    /// Merge per-chunk sub-recorders whose chunk layout *does* depend on
    /// the worker count (pooled solves over `chunk_ranges(b, threads)`).
    /// Only per-track (per-trajectory) events may be recorded in such
    /// regions; concatenating in chunk order and stable-sorting by track
    /// then canonicalizes the stream — each track lives in exactly one
    /// chunk and its internal order is preserved, so the result is
    /// identical for every chunking of the same rows.
    pub fn absorb_by_track(&mut self, parts: Vec<Recorder>) {
        let Some(b) = &mut self.buf else { return };
        let start = b.events.len();
        for p in parts {
            if let Some(pb) = p.buf {
                b.events.extend(pb.events);
                b.reg.absorb(&pb.reg);
            }
        }
        b.events[start..].sort_by_key(|e| e.track);
    }
}

/// The canonical per-step scalar accessors shared by the XLA-path
/// [`StepMetrics`] and the native-path [`NativeMetrics`], so loggers and
/// the CLI consume one taxonomy instead of per-trainer field names.
///
/// [`StepMetrics`]: crate::coordinator::StepMetrics
/// [`NativeMetrics`]: crate::coordinator::NativeMetrics
pub trait StepScalars {
    /// Total objective (task + regularization).
    fn loss(&self) -> f32;
    /// Task term (MSE / NLL / cross-entropy).
    fn task(&self) -> f32;
    /// Regularization term (λ·R_K or zero).
    fn reg(&self) -> f32;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_recorder_records_nothing() {
        let mut r = Recorder::off();
        r.span("s", 0, 0, 1, NO_ARGS);
        r.inc(Counter::Nfe, 5);
        r.observe(Hist::StepSize, 0.1);
        r.counter("q", 0, 1.0);
        r.set_ticks(9);
        assert!(!r.is_on());
        assert_eq!(r.now_ticks(), 0);
        assert!(r.events().is_empty());
        assert!(r.registry().is_none());
    }

    #[test]
    fn track_merge_is_chunking_independent() {
        // Six per-track events split two different ways must merge to the
        // same stream (the pooled-solve determinism argument in miniature).
        let mk = |tracks: &[u64]| {
            let mut r = Recorder::enabled();
            for (i, t) in tracks.iter().enumerate() {
                r.span("traj", *t, 0, i as u64, NO_ARGS);
                r.inc(Counter::Retired, 1);
            }
            r
        };
        let mut a = Recorder::enabled();
        a.absorb_by_track(vec![mk(&[0, 1, 1]), mk(&[2, 3, 3])]);
        let mut b = Recorder::enabled();
        b.absorb_by_track(vec![mk(&[0]), mk(&[1, 1, 2]), mk(&[3, 3])]);
        let key = |r: &Recorder| -> Vec<(u64, u64)> {
            r.events().iter().map(|e| (e.track, e.dur)).collect()
        };
        assert_eq!(key(&a), key(&b));
        assert_eq!(
            a.registry().unwrap().get(Counter::Retired),
            b.registry().unwrap().get(Counter::Retired)
        );
    }

    #[test]
    fn in_order_merge_concatenates() {
        let mut part1 = Recorder::enabled();
        part1.span("shard", 0, 0, 3, NO_ARGS);
        let mut part2 = Recorder::enabled();
        part2.span("shard", 1, 0, 3, NO_ARGS);
        let mut root = Recorder::enabled();
        root.absorb_in_order(vec![part1, part2]);
        let tracks: Vec<u64> = root.events().iter().map(|e| e.track).collect();
        assert_eq!(tracks, vec![0, 1]);
    }

    #[test]
    fn clock_ticks_stamp_events() {
        let mut r = Recorder::enabled();
        r.set_ticks(7);
        let ts = r.now_ticks();
        r.instant("admit_wave", 0, ts, [("rows", 4.0), ("", 0.0)]);
        assert_eq!(r.events()[0].ts, 7);
    }
}
