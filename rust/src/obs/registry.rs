//! The metrics registry: one fixed counter/histogram taxonomy for every
//! layer (solver, adjoint, tape, serving), so NFE/accept accounting lives
//! in exactly one place and cannot double-count across paths.
//!
//! Counters are monotonic `u64` adds and histograms are fixed-bucket
//! log₂ tallies, so merging per-shard registries is an elementwise sum —
//! associative and commutative — and the merged registry is bit-identical
//! at any thread count by construction.

use anyhow::{bail, Result};

use crate::solvers::SolveStats;
use crate::util::json::Json;

/// The monotonic counters.  [`Registry::absorb_solve_stats`] is the one
/// sanctioned fold from per-trajectory [`SolveStats`] into `Nfe` /
/// `Accepted` / `Rejected`: the solver layer counts at retirement and no
/// other layer re-counts (the "one counter taxonomy" invariant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Model evaluations, folded from retired trajectories' stats.
    Nfe,
    /// Accepted solver steps (same fold).
    Accepted,
    /// Rejected solver steps (same fold).
    Rejected,
    /// Rows admitted into a stepper's working set.
    Admitted,
    /// Rows retired from a stepper's working set.
    Retired,
    /// Requests that exhausted their deadline budget (serving layer).
    DeadlineMiss,
    /// Reverse-mode stage VJP invocations (adjoint layer).
    StageVjps,
    /// Tape nodes allocated across stage VJPs (adjoint layer).
    TapeNodes,
    /// Tape arena bytes touched across stage VJPs (adjoint layer).
    TapeBytes,
}

impl Counter {
    pub const ALL: [Counter; 9] = [
        Counter::Nfe,
        Counter::Accepted,
        Counter::Rejected,
        Counter::Admitted,
        Counter::Retired,
        Counter::DeadlineMiss,
        Counter::StageVjps,
        Counter::TapeNodes,
        Counter::TapeBytes,
    ];

    /// Canonical wire name (JSON exports, tables, MetricsLog columns).
    pub fn name(self) -> &'static str {
        match self {
            Counter::Nfe => "nfe",
            Counter::Accepted => "accepted",
            Counter::Rejected => "rejected",
            Counter::Admitted => "admitted",
            Counter::Retired => "retired",
            Counter::DeadlineMiss => "deadline_miss",
            Counter::StageVjps => "stage_vjps",
            Counter::TapeNodes => "tape_nodes",
            Counter::TapeBytes => "tape_bytes",
        }
    }
}

/// The fixed log₂ histograms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Accepted step magnitudes `|h|`.
    StepSize,
    /// Per-attempt embedded error norms.
    ErrNorm,
    /// Admission-wave sizes (serving layer).
    AdmitWave,
    /// Queue depth per engine step (serving layer).
    QueueDepth,
    /// Admit→retire latency in engine steps per request (serving layer).
    LatencySteps,
    /// Tape node count per stage VJP (adjoint layer).
    TapeNodes,
    /// Tape arena bytes per stage VJP (adjoint layer).
    TapeBytes,
}

impl Hist {
    pub const ALL: [Hist; 7] = [
        Hist::StepSize,
        Hist::ErrNorm,
        Hist::AdmitWave,
        Hist::QueueDepth,
        Hist::LatencySteps,
        Hist::TapeNodes,
        Hist::TapeBytes,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Hist::StepSize => "step_size",
            Hist::ErrNorm => "err_norm",
            Hist::AdmitWave => "admit_wave",
            Hist::QueueDepth => "queue_depth",
            Hist::LatencySteps => "latency_steps",
            Hist::TapeNodes => "tape_nodes",
            Hist::TapeBytes => "tape_bytes",
        }
    }
}

/// A fixed-bucket log₂ histogram: bucket index is the IEEE-754 biased
/// exponent of `|v|` as an `f32`, so bucket `i` tallies values with
/// `floor(log₂|v|) == i − 127` (bucket 0 holds zero/subnormals, bucket
/// 255 non-finite values).  Bucketing is pure bit arithmetic — no float
/// comparisons, no allocation — so observation order never matters and
/// merged histograms are exact sums.
#[derive(Clone, PartialEq, Eq)]
pub struct Log2Hist {
    buckets: [u64; 256],
}

impl Default for Log2Hist {
    fn default() -> Log2Hist {
        Log2Hist { buckets: [0u64; 256] }
    }
}

impl Log2Hist {
    pub fn new() -> Log2Hist {
        Log2Hist::default()
    }

    #[inline]
    pub fn observe(&mut self, v: f32) {
        let idx = ((v.abs().to_bits() >> 23) & 0xff) as usize;
        self.buckets[idx] += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Observations in the bucket for `floor(log₂|v|) == e`.
    pub fn bucket(&self, e: i32) -> u64 {
        let idx = e + 127;
        if (0..=255).contains(&idx) {
            self.buckets[idx as usize]
        } else {
            0
        }
    }

    pub fn absorb(&mut self, other: &Log2Hist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
    }

    /// Estimated `q`-quantile (`0 ≤ q ≤ 1`) by linear interpolation inside
    /// the covering log₂ bucket: a bucket at biased exponent `i` spans
    /// `[2^(i−127), 2^(i−126))`, so the estimate is exact to within a
    /// factor of two of the true sample percentile — enough for registry
    /// tables to print `p50`/`p99` magnitudes instead of raw bucket
    /// counts.  Zero/subnormal observations report as `0.0`, non-finite
    /// ones as `+∞`; an empty histogram reports `0.0`.
    ///
    /// ```
    /// use taynode::obs::Log2Hist;
    /// let mut h = Log2Hist::new();
    /// for v in [1.0f32, 1.2, 1.5, 1.9] {
    ///     h.observe(v); // all in the [1, 2) bucket
    /// }
    /// let p50 = h.quantile(0.5);
    /// assert!((1.0..2.0).contains(&p50));
    /// ```
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut seen = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            if *c == 0 {
                continue;
            }
            let below = seen as f64;
            seen += c;
            if seen as f64 >= target {
                if i == 0 {
                    return 0.0; // zero/subnormal bucket
                }
                if i == 255 {
                    return f64::INFINITY; // non-finite bucket
                }
                let lo = 2f64.powi(i as i32 - 127);
                let frac = ((target - below) / *c as f64).clamp(0.0, 1.0);
                return lo + lo * frac; // linear within [lo, 2·lo)
            }
        }
        f64::INFINITY
    }

    /// Rebuild a histogram from its [`Log2Hist::to_json`] form (`[[log2,
    /// count], ...]`) — how `repro report` recovers quantiles from the
    /// registry metadata record of an NDJSON trace.
    pub fn from_json(j: &Json) -> Result<Log2Hist> {
        let Some(pairs) = j.as_arr() else {
            bail!("log2 histogram JSON must be an array of [log2, count] pairs");
        };
        let mut h = Log2Hist::new();
        for p in pairs {
            let pair = p.as_arr().unwrap_or(&[]);
            let (Some(e), Some(c)) =
                (pair.first().and_then(Json::as_f64), pair.get(1).and_then(Json::as_f64))
            else {
                bail!("malformed [log2, count] pair: {}", p.to_string());
            };
            let idx = e as i64 + 127;
            if !(0..=255).contains(&idx) || c < 0.0 || c.fract() != 0.0 {
                bail!("[log2, count] pair out of range: {}", p.to_string());
            }
            h.buckets[idx as usize] += c as u64;
        }
        Ok(h)
    }

    /// Non-empty buckets as `[log2, count]` pairs, ascending.
    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for (i, c) in self.buckets.iter().enumerate() {
            if *c > 0 {
                arr.push(Json::Arr(vec![
                    Json::Num(i as f64 - 127.0),
                    Json::Num(*c as f64),
                ]));
            }
        }
        Json::Arr(arr)
    }
}

/// A fixed-size counter + histogram set; see the module docs.
#[derive(Clone, Default, PartialEq, Eq)]
pub struct Registry {
    counters: [u64; Counter::ALL.len()],
    hists: [Log2Hist; Hist::ALL.len()],
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    #[inline]
    pub fn inc(&mut self, c: Counter, by: u64) {
        self.counters[c as usize] += by;
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    #[inline]
    pub fn observe(&mut self, h: Hist, v: f32) {
        self.hists[h as usize].observe(v);
    }

    pub fn hist(&self, h: Hist) -> &Log2Hist {
        &self.hists[h as usize]
    }

    /// The one fold from solver stats into the counter taxonomy: called at
    /// trajectory retirement (and nowhere else, so nothing double-counts).
    pub fn absorb_solve_stats(&mut self, s: &SolveStats) {
        self.inc(Counter::Nfe, s.nfe as u64);
        self.inc(Counter::Accepted, s.accepted as u64);
        self.inc(Counter::Rejected, s.rejected as u64);
    }

    /// Elementwise merge (used when per-shard registries join in fixed
    /// shard order; sums are order-independent anyway).
    pub fn absorb(&mut self, other: &Registry) {
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a += *b;
        }
        for (a, b) in self.hists.iter_mut().zip(&other.hists) {
            a.absorb(b);
        }
    }

    /// `{"counters": {...}, "hists": {name: [[log2, count], ...]}}` with
    /// zero entries omitted — the registry's canonical JSON form.
    pub fn to_json(&self) -> Json {
        let mut counters = Vec::new();
        for c in Counter::ALL {
            if self.get(c) > 0 {
                counters.push((c.name(), Json::Num(self.get(c) as f64)));
            }
        }
        let mut hists = Vec::new();
        for h in Hist::ALL {
            if self.hist(h).count() > 0 {
                hists.push((h.name(), self.hist(h).to_json()));
            }
        }
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("hists", Json::obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets_land_on_the_exponent() {
        let mut h = Log2Hist::new();
        h.observe(1.0); // 2^0
        h.observe(1.5); // still 2^0
        h.observe(0.25); // 2^-2
        h.observe(-0.25); // magnitude bucketing
        h.observe(1024.0); // 2^10
        h.observe(0.0); // zero bucket
        assert_eq!(h.bucket(0), 2);
        assert_eq!(h.bucket(-2), 2);
        assert_eq!(h.bucket(10), 1);
        assert_eq!(h.bucket(-127), 1);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn quantiles_bracket_exact_percentiles_on_seeded_data() {
        use crate::util::rng::Pcg;
        use crate::util::stats::percentile;
        // Log-uniform seeded samples across ~8 decades: the bucketed
        // estimate must land within one bucket (a factor of two) of the
        // exact linear-interpolated percentile, at both tails.
        let mut rng = Pcg::new(1234);
        let mut h = Log2Hist::new();
        let mut xs: Vec<f64> = Vec::new();
        for _ in 0..5000 {
            let v = 10f64.powf(rng.range(-6.0, 2.0) as f64);
            xs.push(v);
            h.observe(v as f32);
        }
        xs.sort_by(f64::total_cmp);
        for q in [0.05, 0.5, 0.9, 0.99] {
            let exact = percentile(&xs, q);
            let est = h.quantile(q);
            assert!(
                est >= exact / 2.0 && est <= exact * 2.0,
                "q={q}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn quantile_edge_cases() {
        let mut h = Log2Hist::new();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        h.observe(0.0);
        assert_eq!(h.quantile(0.99), 0.0, "zero bucket reports 0");
        let mut inf = Log2Hist::new();
        inf.observe(f32::INFINITY);
        assert_eq!(inf.quantile(0.5), f64::INFINITY);
    }

    #[test]
    fn hist_json_round_trips() {
        let mut h = Log2Hist::new();
        for v in [0.25f32, 0.3, 1.5, 1024.0, 0.0] {
            h.observe(v);
        }
        let back = Log2Hist::from_json(&h.to_json()).unwrap();
        assert!(back == h, "to_json → from_json must be lossless");
        assert!(Log2Hist::from_json(&Json::num(3.0)).is_err());
        assert!(Log2Hist::from_json(&Json::parse("[[300,1]]").unwrap()).is_err());
        assert!(Log2Hist::from_json(&Json::parse("[[0,1.5]]").unwrap()).is_err());
    }

    #[test]
    fn registry_merge_is_an_elementwise_sum() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        a.inc(Counter::Nfe, 3);
        b.inc(Counter::Nfe, 4);
        b.inc(Counter::Retired, 1);
        a.observe(Hist::StepSize, 0.5);
        b.observe(Hist::StepSize, 0.5);
        a.absorb(&b);
        assert_eq!(a.get(Counter::Nfe), 7);
        assert_eq!(a.get(Counter::Retired), 1);
        assert_eq!(a.hist(Hist::StepSize).bucket(-1), 2);
    }

    #[test]
    fn solve_stats_fold_hits_the_three_counters() {
        let mut r = Registry::new();
        let s = SolveStats { nfe: 10, accepted: 3, rejected: 1, h_final: 0.1 };
        r.absorb_solve_stats(&s);
        assert_eq!(r.get(Counter::Nfe), 10);
        assert_eq!(r.get(Counter::Accepted), 3);
        assert_eq!(r.get(Counter::Rejected), 1);
    }

    #[test]
    fn json_form_omits_zero_entries() {
        let mut r = Registry::new();
        r.inc(Counter::Admitted, 2);
        r.observe(Hist::AdmitWave, 2.0);
        let j = r.to_json();
        let c = j.req("counters").unwrap();
        assert_eq!(c.req("admitted").unwrap().as_f64(), Some(2.0));
        assert!(c.get("nfe").is_none());
        let hist = j.req("hists").unwrap().req("admit_wave").unwrap();
        assert_eq!(hist.to_string(), "[[1,1]]");
    }
}
